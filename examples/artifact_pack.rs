//! The `.sefp` artifact end to end, no AOT artifacts needed:
//! pack a synthetic f32 master into the on-device container, reopen it,
//! walk the ladder with zero-copy truncate-at-load views, and build a
//! serving `PrecisionLadder` straight from the planes.
//!
//!   cargo run --release --example artifact_pack

use otaro::artifact::{write_artifact, Artifact, ArtifactMeta};
use otaro::data::Rng;
use otaro::runtime::ParamStore;
use otaro::sefp::{Precision, SefpSpec, SefpTensor};
use otaro::serve::{LadderTensor, PrecisionLadder};

fn main() -> anyhow::Result<()> {
    // a toy 2-layer master: quantized 2-D weights + f32 norm gains
    let mut rng = Rng::new(42);
    let mut tensors = Vec::new();
    let mut names = Vec::new();
    let mut shapes = Vec::new();
    let mut quantized = Vec::new();
    for l in 0..2 {
        tensors.push((0..64 * 64).map(|_| rng.normal() as f32 * 0.1).collect());
        names.push(format!("layer{l}.w"));
        shapes.push(vec![64, 64]);
        quantized.push(true);
        tensors.push(vec![1.0f32; 64]);
        names.push(format!("layer{l}.ln"));
        shapes.push(vec![64]);
        quantized.push(false);
    }
    let params = ParamStore { tensors, names, shapes, quantized };
    let f32_bytes = params.total_len() * 4;

    // pack at the top of the paper's ladder and reopen
    let dir = std::env::temp_dir().join("otaro_artifact_example");
    let path = dir.join("master.sefp");
    let written = write_artifact(&path, &params, &ArtifactMeta::new(Precision::of(8)))?;
    println!(
        "packed {} weights: f32 {} B -> .sefp {} B ({:.1}%)",
        params.total_len(),
        f32_bytes,
        written,
        written as f64 / f32_bytes as f64 * 100.0
    );

    let a = Artifact::open(&path)?;
    println!("\nper-rung borrowed footprint (zero-copy truncate-at-load):");
    for p in Precision::LADDER {
        println!(
            "  {p}: {:>6} B borrowed ({:.1}% of f32)",
            a.view_bytes_at(p),
            a.view_bytes_at(p) as f64 / f32_bytes as f64 * 100.0
        );
    }

    // ladder exactness through the container: opening at E5M4 equals
    // re-encoding the original floats at E5M4
    let v4 = a.view(0, Precision::of(4))?;
    let direct = SefpTensor::encode(&params.tensors[0], &SefpSpec::new(Precision::of(4)));
    assert_eq!(v4.to_tensor(), direct);
    println!("\nview_at(E5M4) == encode(w, E5M4): exact (ladder-exactness through the file)");

    // and the serve layer consumes the container directly — no f32
    // master is ever rebuilt
    let mut ladder = PrecisionLadder::from_artifact(&a)?;
    let view = ladder.view_at(Precision::of(3))?;
    let quant_slots = view
        .tensors()
        .iter()
        .filter(|t| matches!(t, LadderTensor::Quant(_)))
        .count();
    println!(
        "serving ladder from artifact: top {}, E5M3 view has {quant_slots} quantized slots",
        ladder.top()
    );

    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
