//! Multi-precision serving demo: ONE SEFP master model serving mixed
//! generation/understanding traffic at different precisions, switched by
//! mantissa truncation — the deployment scenario of the paper's intro and
//! fig. 1.  Clients run as concurrent threads feeding the synchronous
//! serving core through a channel (Python is nowhere in sight).
//!
//! Run: `make artifacts && cargo run --release --example multi_precision_serving`

use std::sync::mpsc;

use otaro::config::ServeConfig;
use otaro::data::{Lang, Rng, Tokenizer};
use otaro::runtime::Engine;
use otaro::sefp::Precision;
use otaro::serve::{
    DynamicBatcher, PrecisionLadder, Request, Router, SchedPolicy, Server, TaskClass,
};

fn main() -> anyhow::Result<()> {
    let n_clients = 6usize;
    let reqs_per_client = 16usize;

    let engine = Engine::new(std::path::Path::new("artifacts"))?;
    // prefer the fine-tuned model if the e2e example has produced one
    let mut params = engine.init_params()?;
    for cand in ["runs/e2e/otaro_model.bin", "runs/pretrained.bin"] {
        let p = std::path::Path::new(cand);
        if p.exists() {
            params.load_into(p)?;
            println!("serving checkpoint {cand}");
            break;
        }
    }

    let serve_cfg = ServeConfig::default();
    let ladder = PrecisionLadder::from_params(&params)
        .with_budget(serve_cfg.ladder_budget_bytes);
    println!(
        "single SEFP master: {} KiB (vs {} KiB for a 6-precision model zoo) — {:.1}x smaller",
        ladder.master_bytes() / 1024,
        ladder.zoo_bytes(&Precision::LADDER) / 1024,
        ladder.zoo_bytes(&Precision::LADDER) as f64 / ladder.master_bytes() as f64
    );

    // concurrent clients produce requests into a channel
    let (tx, rx) = mpsc::channel::<Request>();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let tx = tx.clone();
        handles.push(std::thread::spawn(move || {
            let lang = Lang::new(0x1A06);
            let tok = Tokenizer::new();
            let mut rng = Rng::new(c as u64 + 1);
            for i in 0..reqs_per_client {
                let class = match (c + i) % 3 {
                    0 => TaskClass::Generation,
                    1 => TaskClass::Understanding,
                    _ => TaskClass::Other,
                };
                // generation-class requests decode several tokens, the
                // rest are next-token — mixed multi-token traffic
                let max_new = if matches!(class, TaskClass::Generation) { 4 } else { 1 };
                let req = Request::new(
                    (c * 1000 + i) as u64,
                    class,
                    tok.encode_with_bos(&lang.sentence(&mut rng)),
                )
                .with_max_new_tokens(max_new);
                if tx.send(req).is_err() {
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        }));
    }
    drop(tx);

    // serving loop: drain the channel into the scheduler, dispatch
    // from_config honors serve_cfg.policy.adaptive (static by default)
    let router = Router::from_config(serve_cfg.clone());
    let batcher = DynamicBatcher::new(engine.batch_size(), 256)
        .with_policy(SchedPolicy::from_config(&serve_cfg));
    let mut server = Server::new(engine.into_handle(), ladder, router, batcher);
    let mut responses = Vec::new();
    while let Ok(req) = rx.recv() {
        if !server.submit(req) {
            continue; // backpressure: shed
        }
        // dispatch whenever a full batch is available
        if server.batcher.len() >= server.batcher.max_batch {
            responses.extend(server.process_all()?);
        }
    }
    responses.extend(server.process_all()?);
    for h in handles {
        let _ = h.join();
    }

    let stats = server.stats().clone();
    println!(
        "\nserved {} responses ({} tokens over {} decode steps) in {} scheduled runs, \
         {:.1} req/s / {:.1} tok/s",
        stats.served,
        stats.tokens_generated,
        stats.decode_steps,
        stats.batches,
        stats.throughput_rps(),
        stats.throughput_tps()
    );
    println!(
        "per-precision request counts (router policy: gen->E5M8, und->E5M4, other->E5M6): {:?}",
        stats.per_precision
    );
    println!(
        "ladder switches: {} hits / {} misses / {} evictions; derived views resident: {} B",
        stats.switch_hits, stats.switch_misses, stats.switch_evictions,
        stats.ladder_resident_bytes
    );
    println!(
        "compute per batch: mean {:.1} ms; queue wait: mean {:.1} ms",
        stats.compute_ms.mean(),
        stats.queue_ms.mean()
    );
    // precision switch costs (cold, no cache)
    let ladder2 = PrecisionLadder::from_params(&params);
    for m in [8u8, 5, 3] {
        let p = Precision::of(m);
        println!("cold precision switch to {p}: {:.2} ms", ladder2.switch_cost_ms(p));
    }
    println!("\nserving demo OK");
    Ok(())
}
