//! Adaptive precision control-plane demo — the serve-time feedback loop
//! on a deterministic in-process backend (no AOT artifacts needed).
//!
//! Three load phases over one `AdaptivePolicy` server:
//!
//! 1. **calm** — light traffic, everything serves at the configured
//!    static precisions;
//! 2. **latency burst** — the simulated decode step slows down, the
//!    p95 SLO is violated, and the controller demotes the
//!    latency-sensitive Understanding class to a lower rung (probes
//!    confirm quality headroom);
//! 3. **quality loss** — the backend's quality model is degraded so
//!    low-precision argmaxes diverge from the master; shadow probes
//!    catch it and the controller promotes back up.
//!
//! Run: `cargo run --release --example adaptive_serving`

use std::time::Duration;

use otaro::config::{PolicyConfig, ServeConfig};
use otaro::data::Rng;
use otaro::runtime::ParamStore;
use otaro::serve::{
    DynamicBatcher, PrecisionLadder, Request, Router, SchedPolicy, Server, SimBackend, TaskClass,
};

fn ladder() -> PrecisionLadder {
    let mut rng = Rng::new(42);
    let params = ParamStore {
        tensors: vec![(0..4096).map(|_| rng.normal() as f32 * 0.1).collect(), vec![1.0; 64]],
        names: vec!["w".into(), "ln".into()],
        shapes: vec![vec![64, 64], vec![64]],
        quantized: vec![true, false],
    };
    PrecisionLadder::from_params(&params)
}

fn phase(
    server: &mut Server<SimBackend>,
    rng: &mut Rng,
    name: &str,
    rounds: usize,
    per_round: u64,
    next_id: &mut u64,
) -> anyhow::Result<()> {
    let before = server.stats().clone();
    for _ in 0..rounds {
        for _ in 0..per_round {
            let id = *next_id;
            *next_id += 1;
            // understanding-heavy mix: the latency-sensitive class the
            // controller steers
            let class = match rng.below(10) {
                0..=6 => TaskClass::Understanding,
                7 | 8 => TaskClass::Other,
                _ => TaskClass::Generation,
            };
            let max_new = if matches!(class, TaskClass::Generation) { 4 } else { 2 };
            let prompt: Vec<i32> = (0..rng.below(6) + 2).map(|_| rng.below(32) as i32).collect();
            let req = Request::new(id, class, prompt).with_max_new_tokens(max_new);
            server.submit(req);
        }
        server.process_all()?;
    }
    let s = server.stats();
    println!("\n== phase: {name} ==");
    println!(
        "served {} (+{}), per-precision {:?}",
        s.served,
        s.served - before.served,
        s.per_precision
    );
    println!(
        "latency: queue p50/p95/p99 = {:.2}/{:.2}/{:.2} ms, compute p50/p95 = {:.2}/{:.2} ms",
        s.queue_ms.p50(),
        s.queue_ms.p95(),
        s.queue_ms.p99(),
        s.compute_ms.p50(),
        s.compute_ms.p95(),
    );
    println!(
        "policy: {} demotions (+{}), {} promotions (+{}), {} probes, agreement p50 {:.2}",
        s.demotions,
        s.demotions - before.demotions,
        s.promotions,
        s.promotions - before.promotions,
        s.probes_run,
        s.probe_agreement.p50(),
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let cfg = ServeConfig {
        policy: PolicyConfig {
            adaptive: true,
            slo_p95_ms: 1.0,
            probe_rate: 0.25,
            quality_floor: 0.5,
            quality_headroom: 0.1,
            window: 64,
            min_samples: 8,
            cooldown: 4,
            ..PolicyConfig::default()
        },
        ..ServeConfig::default()
    };
    let backend = SimBackend::new(8, 16, 64).with_quality_model(1e-3);
    let batcher =
        DynamicBatcher::new(8, 4096).with_policy(SchedPolicy::from_config(&cfg));
    let mut server = Server::new(backend, ladder(), Router::from_config(cfg), batcher);
    let mut rng = Rng::new(0xADA);
    let mut next_id = 0u64;

    println!("adaptive precision control plane over ONE SEFP master (ladder E5M8..E5M3)");

    // phase 1: calm — no pressure, no movement
    phase(&mut server, &mut rng, "calm", 4, 8, &mut next_id)?;

    // phase 2: latency burst — every decode step now costs 2 ms, the
    // 1 ms p95 SLO is violated, Understanding demotes
    server.backend_mut().step_delay = Duration::from_millis(2);
    phase(&mut server, &mut rng, "latency burst -> demotion", 6, 16, &mut next_id)?;

    // phase 3: quality loss — the burst passes, but the backend's
    // low-precision fidelity collapses; probes drive promotion
    server.backend_mut().step_delay = Duration::ZERO;
    server.backend_mut().quality_noise = Some(10.0);
    phase(&mut server, &mut rng, "quality loss -> promotion", 6, 16, &mut next_id)?;

    let s = server.stats();
    println!(
        "\ntotal: {} served, {:.1} req/s, {} ladder switches ({} hits), \
         {} demotions / {} promotions / {} probes",
        s.served,
        s.throughput_rps(),
        s.switch_hits + s.switch_misses,
        s.switch_hits,
        s.demotions,
        s.promotions,
        s.probes_run,
    );
    Ok(())
}
