//! Quickstart: the SEFP format + engine in ~60 lines.
//!
//! 1. encode a weight vector to SEFP E5M8,
//! 2. walk the precision ladder by pure mantissa truncation,
//! 3. load the AOT artifacts and run one eval step per bit-width.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use otaro::data::{corpus, Lang, StreamBatcher};
use otaro::runtime::{Engine, Width};
use otaro::sefp::{Precision, SefpSpec, SefpTensor};

fn main() -> anyhow::Result<()> {
    // --- 1. the format ---------------------------------------------------
    let mut rng = otaro::data::Rng::new(7);
    let weights: Vec<f32> = (0..256).map(|_| rng.normal() as f32 * 0.1).collect();
    let spec = SefpSpec::new(Precision::of(8));
    let master = SefpTensor::encode(&weights, &spec);
    println!("encoded {} weights at E5M8: {} groups, {} packed bytes", master.len,
             master.n_groups(), master.ideal_bits() / 8);

    // --- 2. the ladder: ONE model, every precision -----------------------
    for p in &Precision::LADDER[1..] {
        let t = master.truncate(*p); // integer shifts only — no floats touched
        let direct = SefpTensor::encode(&weights, &spec.at(*p));
        let err: f32 = t
            .decode()
            .iter()
            .zip(&weights)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert_eq!(t.decode(), direct.decode(), "truncation == direct encode");
        println!("  {p}: max |Q(w)-w| = {err:.6}  (truncated from E5M8, bit-exact)");
    }

    // --- 3. the engine: eval loss across the ladder ----------------------
    let artifacts = std::path::Path::new("artifacts");
    if !artifacts.join("manifest.json").exists() {
        println!("\nartifacts/ missing — run `make artifacts` to enable the engine demo");
        return Ok(());
    }
    let mut engine = Engine::new(artifacts)?;
    let params = engine.init_params()?;
    let lang = Lang::new(0x1A06);
    let (b, t) = engine.batch_shape();
    let (_, test) = corpus::tinytext_corpus(&lang, 0, 2_000, 400);
    let mut batcher = StreamBatcher::new(test, b, t, 1);
    let batch = batcher.next_batch();
    println!("\neval loss per precision (init params, one batch):");
    let widths = [8u8, 6, 4, 3].map(|m| Width::m(Precision::of(m)));
    for w in std::iter::once(Width::FP).chain(widths) {
        let loss = engine.eval_step(&params, &batch, w)?;
        println!("  {:6} loss = {loss:.4}", w.label());
    }
    println!("\nquickstart OK");
    Ok(())
}
