//! Batched SEFP decode serving, fully in-process: a model-shaped
//! parameter set is encoded ONCE into a `PrecisionLadder` master, and
//! the [`DecoderBackend`] serves mixed-precision traffic with REAL
//! quantized matmuls + KV-cache attention — no PJRT, no AOT artifacts,
//! no hash logits.  This is the infer↔serve gap closed: the
//! continuous-batching scheduler drives the pure-Rust decode engine
//! end-to-end, and the same traffic is replayed at 1 and 2 matmul
//! worker threads to show the batched kernels are a throughput knob,
//! never a numerics one (responses are bit-identical).
//!
//! Run: `cargo run --release --example batched_decode_serving`

use otaro::config::ServeConfig;
use otaro::data::Rng;
use otaro::infer::SimConfig;
use otaro::sefp::Precision;
use otaro::serve::{
    demo_decoder_params, DecoderBackend, DynamicBatcher, PrecisionLadder, Request, Router,
    SchedPolicy, Server, TaskClass,
};

fn main() -> anyhow::Result<()> {
    let cfg = SimConfig { d_model: 128, d_ff: 256, n_layers: 2, vocab: 256, context: 32 };
    let params = demo_decoder_params(&cfg, 42);
    let serve_cfg = ServeConfig::default();

    let run = |threads: usize| -> anyhow::Result<(Vec<Vec<i32>>, f64, u64)> {
        let ladder = PrecisionLadder::from_params(&params)
            .with_budget(serve_cfg.ladder_budget_bytes);
        let backend = DecoderBackend::from_ladder(&ladder, 8, 32, threads)?;
        let router = Router::from_config(serve_cfg.clone());
        let batcher =
            DynamicBatcher::new(8, 4096).with_policy(SchedPolicy::from_config(&serve_cfg));
        let mut server = Server::new(backend, ladder, router, batcher);

        let mut rng = Rng::new(7);
        for i in 0..96u64 {
            let (class, m, max_new) = match i % 3 {
                0 => (TaskClass::Generation, 8u8, 6),
                1 => (TaskClass::Understanding, 4, 1),
                _ => (TaskClass::Other, 3, 3),
            };
            let prompt: Vec<i32> =
                (0..rng.below(20) + 4).map(|_| rng.below(250) as i32).collect();
            let req = Request::new(i, class, prompt)
                .with_precision(Precision::of(m))
                .with_max_new_tokens(max_new);
            assert!(server.submit(req));
        }
        let t0 = std::time::Instant::now();
        let mut responses = server.process_all()?;
        let secs = t0.elapsed().as_secs_f64();
        responses.sort_by_key(|r| r.id);
        let stats = server.stats();
        println!(
            "threads={threads}: served {} requests / {} tokens in {:.3}s \
             ({:.0} tok/s, {} decode steps, {} scheduled runs, widths {:?})",
            stats.served,
            stats.tokens_generated,
            secs,
            stats.tokens_generated as f64 / secs,
            stats.decode_steps,
            stats.batches,
            stats.per_precision
        );
        Ok((responses.into_iter().map(|r| r.tokens).collect(), secs, stats.tokens_generated))
    };

    let (gen1, _, _) = run(1)?;
    let (gen2, _, _) = run(2)?;
    assert_eq!(
        gen1, gen2,
        "generations must be bit-identical regardless of matmul worker count"
    );
    println!("\n1-thread and 2-thread generations are bit-identical — real SEFP logits,");
    println!("deterministic engine, thread count is purely a throughput knob.");
    Ok(())
}
