//! Text generation across the precision ladder: the same model (one
//! checkpoint) answering TinyLang queries at every SEFP width — the
//! qualitative face of the paper's robustness claim.  Prompts with a
//! deterministic correct continuation are used (single-digit arithmetic
//! and KB-fact completion) so precision degradation is directly visible.
//!
//! Run: `make artifacts && cargo run --release --example precision_generation`
//! (better after `otaro pretrain` has left a checkpoint)

use otaro::data::tokenizer::{EOS, PAD};
use otaro::data::{lang::Lang, Tokenizer};
use otaro::runtime::{Engine, ParamStore, Width};
use otaro::sefp::Precision;

fn generate(
    engine: &mut Engine,
    params: &ParamStore,
    prompt: &str,
    width: Width,
    max_new: usize,
) -> anyhow::Result<String> {
    let tok = Tokenizer::new();
    let (bsz, seq_len) = engine.batch_shape();
    let vocab = engine.vocab_size();
    // the pretraining stream separates sentences with EOS (never BOS), so
    // EOS is the in-distribution "start of sentence" context
    let mut seq = vec![EOS];
    seq.extend(tok.encode(prompt));
    let prompt_len = seq.len();
    for _ in 0..max_new {
        if seq.len() >= seq_len {
            break;
        }
        let mut tokens = vec![PAD; bsz * seq_len];
        tokens[..seq.len()].copy_from_slice(&seq);
        let logits = engine.logits_step(params, &tokens, width)?;
        let off = (seq.len() - 1) * vocab;
        let next = logits[off..off + vocab]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as i32;
        seq.push(next);
        if next == EOS || next == b'.' as i32 {
            break;
        }
    }
    Ok(tok.decode(&seq[prompt_len..]))
}

fn main() -> anyhow::Result<()> {
    let mut engine = Engine::new(std::path::Path::new("artifacts"))?;
    let mut params = engine.init_params()?;
    for cand in ["runs/pretrained.bin", "runs/e2e/otaro_model.bin"] {
        if std::path::Path::new(cand).exists() {
            params.load_into(std::path::Path::new(cand))?;
            println!("generating with checkpoint {cand}\n");
            break;
        }
    }

    let lang = Lang::new(0x1A06);
    // qualitative probe: the SAME model continues TinyLang prompts at
    // every precision — high widths stay grammatical (noun phrases with
    // the right class suffixes), low widths visibly degrade.  With a
    // longer pretraining budget the KB/arithmetic answers also become
    // exact; at the default 800 steps the structure signal is the point.
    let s = 5usize;
    let (noun_a, class_a) = lang.noun(2);
    let prompts: Vec<String> = vec![
        format!("{} pide", lang.noun(s).0),
        format!("{} {} ", Lang::determiner(class_a), noun_a),
    ];

    for prompt in &prompts {
        println!("prompt {prompt:?}");
        let quant = [8u8, 6, 4, 3].map(|m| Width::m(Precision::of(m)));
        for width in std::iter::once(Width::FP).chain(quant) {
            let out = generate(&mut engine, &params, prompt, width, 20)?;
            println!("  {:6} -> {}", width.label(), out.trim());
        }
        println!();
    }
    println!("generation demo OK");
    Ok(())
}
