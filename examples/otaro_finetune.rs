//! End-to-end driver (DESIGN.md §End-to-end validation): pretrain a
//! transformer in-repo, OTARo-fine-tune it (BPS + LAA, Algorithm 1) for a
//! few hundred steps, and evaluate perplexity at EVERY precision of the
//! ladder — proving all three layers compose: Pallas SEFP kernels inside
//! the AOT HLO (L1), the JAX model (L2), and the Rust coordinator (L3).
//!
//! Run: `make artifacts && cargo run --release --example otaro_finetune`
//! Env: OTARO_STEPS / OTARO_PRETRAIN_STEPS to resize (defaults 240/600).

use otaro::config::{Method, TrainConfig};
use otaro::coordinator::Trainer;
use otaro::data::{corpus, Lang, StreamBatcher};
use otaro::eval::ppl::perplexity;
use otaro::metrics::MetricsSink;
use otaro::runtime::{Engine, Width};
use otaro::sefp::Precision;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let pretrain_steps = env_usize("OTARO_PRETRAIN_STEPS", 600);
    let ft_steps = env_usize("OTARO_STEPS", 240);
    let run_dir = std::path::PathBuf::from("runs/e2e");
    std::fs::create_dir_all(&run_dir)?;

    let mut engine = Engine::new(std::path::Path::new("artifacts"))?;
    let mut params = engine.init_params()?;
    let lang = Lang::new(0x1A06);
    let (b, t) = engine.batch_shape();
    println!(
        "model: {} params, batch {}x{}",
        engine.manifest.total_params(),
        b,
        t
    );

    // ---- phase 1: pretrain (fp) on the TinyLang corpus ------------------
    let stream = corpus::pretrain_corpus(&lang, 0, 12_000);
    let mut batches = StreamBatcher::new(stream, b, t, 9);
    let cfg = TrainConfig { method: Method::Fp, lr: 3e-2, steps: pretrain_steps, ..Default::default() };
    let mut sink = MetricsSink::to_file(&run_dir.join("pretrain.jsonl"))?;
    let rep = Trainer::new(&mut engine, &mut params, &mut batches, cfg).run(&mut sink)?;
    println!(
        "pretrain {} steps in {:.1}s: loss {:.3} -> {:.3}",
        pretrain_steps,
        rep.wall_secs,
        rep.losses.first().unwrap(),
        rep.losses.last().unwrap()
    );

    // ---- phase 2: OTARo fine-tune on TinyText ---------------------------
    let (train, test) = corpus::tinytext_corpus(&lang, 0, 8_000, 1_000);
    let mut batches = StreamBatcher::new(train, b, t, 5);
    let cfg = TrainConfig { method: Method::Otaro, lr: 1e-2, steps: ft_steps, ..Default::default() };
    let mut sink = MetricsSink::to_file(&run_dir.join("otaro_finetune.jsonl"))?;
    let rep = Trainer::new(&mut engine, &mut params, &mut batches, cfg).run(&mut sink)?;
    println!(
        "OTARo fine-tune {} steps in {:.1}s; BPS path histogram {:?}; LAA flushes {} (deferred {})",
        ft_steps, rep.wall_secs, rep.width_histogram, rep.laa_flushes, rep.laa_deferred
    );
    // loss curve summary (every ft_steps/8-th step)
    let k = (rep.losses.len() / 8).max(1);
    let curve: Vec<String> =
        rep.losses.iter().step_by(k).map(|l| format!("{l:.3}")).collect();
    println!("loss curve: {}", curve.join(" -> "));

    // ---- phase 3: evaluate the ONE model at every precision -------------
    println!("\nfinal PPL across the ladder (one model, once tuned):");
    for w in std::iter::once(Width::FP).chain(Precision::LADDER.map(Width::m)) {
        let ppl = perplexity(&mut engine, &params, &test, w)?;
        println!("  {:6} ppl = {ppl:.3}", w.label());
    }
    params.save(&run_dir.join("otaro_model.bin"))?;
    println!("\nsaved runs/e2e/otaro_model.bin — e2e OK");
    Ok(())
}
