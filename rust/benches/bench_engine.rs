//! Engine step latency per bit-width (train/eval/logits) — the L3 hot
//! path over AOT-compiled HLO.  Requires `make artifacts`.

use otaro::benchutil::{group, Bench};
use otaro::data::{corpus, Lang, StreamBatcher};
use otaro::runtime::{Engine, Width};
use otaro::sefp::Precision;

fn main() {
    let artifacts = std::path::Path::new("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("skipping engine benches: run `make artifacts` first");
        return;
    }
    let mut engine = Engine::new(artifacts).expect("engine");
    let params = engine.init_params().expect("params");
    let lang = Lang::new(0x1A06);
    let (bsz, t) = engine.batch_shape();
    let stream = corpus::pretrain_corpus(&lang, 0, 2_000);
    let mut batcher = StreamBatcher::new(stream, bsz, t, 3);
    let batch = batcher.next_batch();

    let mut b = Bench::new();
    b.budget_ms = 2_000.0;
    b.max_iters = 60;

    group("engine train_step");
    let quant = |m: u8| Width::m(Precision::of(m));
    for w in [Width::FP, quant(8), quant(4), quant(3)] {
        b.run(&format!("train_{}", w.tag()), || {
            engine.train_step(&params, &batch, w).unwrap()
        });
    }

    group("engine eval_step");
    for w in [Width::FP, quant(4)] {
        b.run(&format!("eval_{}", w.tag()), || {
            engine.eval_step(&params, &batch, w).unwrap()
        });
    }

    group("engine logits_step");
    for w in [quant(8), quant(3)] {
        b.run(&format!("logits_{}", w.tag()), || {
            engine.logits_step(&params, &batch.tokens, w).unwrap()
        });
    }

    println!(
        "\nquantized train-step overhead vs fp: {:.2}x",
        b.ratio("train_m4", "train_fp").unwrap_or(f64::NAN)
    );
}
