//! SEFP format hot paths — encode, decode, truncate (the precision-switch
//! operation), packed pack/unpack, and the group-size ablation from
//! DESIGN.md §6.  Runs under `cargo bench` via the in-repo harness.

use otaro::benchutil::{black_box, group, Bench};
use otaro::data::Rng;
use otaro::sefp::{PackedSefp, Precision, Rounding, SefpSpec, SefpTensor};

fn weights(n: usize) -> Vec<f32> {
    let mut rng = Rng::new(42);
    (0..n).map(|_| rng.normal() as f32 * 0.1).collect()
}

fn main() {
    let mut b = Bench::new();
    let w = weights(1 << 16);
    let n = w.len() as u64;

    group("sefp_encode (65536 elems)");
    for m in [8u8, 4, 3] {
        let spec = SefpSpec::new(Precision::of(m));
        b.run_elems(&format!("encode_m{m}"), n, || {
            SefpTensor::encode(black_box(&w), &spec)
        });
    }
    let nearest = SefpSpec::new(Precision::of(4)).with_rounding(Rounding::Nearest);
    b.run_elems("encode_m4_nearest", n, || {
        SefpTensor::encode(black_box(&w), &nearest)
    });

    group("sefp_encode group-size ablation (m=4)");
    for gs in [32usize, 64, 128] {
        let spec = SefpSpec::new(Precision::of(4)).with_group_size(gs);
        b.run_elems(&format!("encode_g{gs}"), n, || {
            SefpTensor::encode(black_box(&w), &spec)
        });
    }

    group("sefp_truncate (the precision switch)");
    let t8 = SefpTensor::encode(&w, &SefpSpec::new(Precision::of(8)));
    for m in [7u8, 4, 3] {
        let p = Precision::of(m);
        b.run_elems(&format!("truncate_m8_to_m{m}"), n, || black_box(&t8).truncate(p));
    }

    group("sefp_decode");
    let t4 = SefpTensor::encode(&w, &SefpSpec::new(Precision::of(4)));
    b.run_elems("decode_m4", n, || black_box(&t4).decode());
    b.run_elems("decode_m8", n, || black_box(&t8).decode());

    group("sefp_packed (bitstream)");
    let p4 = PackedSefp::from_tensor(&t4);
    let p8 = PackedSefp::from_tensor(&t8);
    b.run_elems("pack_m4", n, || PackedSefp::from_tensor(black_box(&t4)));
    b.run_elems("unpack_m4", n, || black_box(&p4).to_tensor());
    b.run_elems("truncate_packed_m8_to_m4", n, || black_box(&p8).truncate(Precision::of(4)));

    println!(
        "\nencode->truncate speedup at m=4: {:.1}x (switch vs re-encode)",
        b.ratio("encode_m4", "truncate_m8_to_m4").unwrap_or(f64::NAN)
    );
}
