//! Policy-layer benchmarks: the per-request decision + observation hot
//! path must be effectively free next to a decode step.
//!
//! Run: `cargo bench --bench bench_policy`
//!
//! The acceptance bound is asserted, not just printed: the adaptive
//! decide+observe path (telemetry ring push + controller tick) must
//! stay under 1 µs per request — the serve loop calls it once per
//! completion, so anything slower would tax every request.

use otaro::benchutil::{black_box, group, Bench};
use otaro::config::{PolicyConfig, ServeConfig};
use otaro::policy::{AdaptivePolicy, Observation, PrecisionPolicy, StaticPolicy};
use otaro::sefp::Precision;
use otaro::serve::{LogitsBackend, PrecisionLadder, Router, SimBackend, TaskClass};

fn adaptive_cfg() -> ServeConfig {
    ServeConfig {
        policy: PolicyConfig { adaptive: true, ..PolicyConfig::default() },
        ..ServeConfig::default()
    }
}

fn obs(class: TaskClass, p: Precision, ms: f64) -> Observation {
    Observation {
        class,
        precision: p,
        queue_ms: ms / 2.0,
        compute_ms: ms / 2.0,
        tokens: 2,
        queue_depth: 5,
    }
}

const CLASSES: [TaskClass; 3] =
    [TaskClass::Generation, TaskClass::Understanding, TaskClass::Other];

fn main() {
    let mut b = Bench::new();

    group("per-request decision + observation path");
    let cfg = adaptive_cfg();
    let mut adaptive = AdaptivePolicy::new(&cfg);
    // warm the telemetry lanes so the rings are full (steady state:
    // no allocation on push)
    for i in 0..256 {
        let class = CLASSES[i % 3];
        let at = adaptive.decide(class);
        let _ = adaptive.observe(&obs(class, at, 1.0 + (i % 7) as f64));
    }
    let mut i = 0u64;
    let adaptive_res = b
        .run("adaptive_decide_plus_observe", || {
            i += 1;
            let class = CLASSES[(i % 3) as usize];
            let at = adaptive.decide(class);
            let _ = adaptive.observe(&obs(class, at, 1.0 + (i % 7) as f64));
            at
        })
        .median_ns;

    let mut stat = StaticPolicy::new(&ServeConfig::default());
    b.run("static_decide", || black_box(stat.decide(TaskClass::Understanding)));

    let mut router = Router::from_config(adaptive_cfg());
    b.run("router_route_forced_clamp", || {
        black_box(router.route(TaskClass::Other, Some(Precision::of(1))))
    });

    group("scale reference: one SimBackend decode step (8x32, vocab 320)");
    let params = otaro::runtime::ParamStore {
        tensors: vec![vec![0.5; 64]],
        names: vec!["w".into()],
        shapes: vec![vec![8, 8]],
        quantized: vec![true],
    };
    let mut ladder = PrecisionLadder::from_params(&params);
    let mut sim = SimBackend::new(8, 32, 320);
    let view = ladder.view_at(Precision::of(4)).unwrap();
    sim.load_view(&view).unwrap();
    let tokens = vec![1i32; 8 * 32];
    let step_res = b
        .run("sim_logits_step_8x32x320", || sim.logits_step(&tokens).unwrap())
        .median_ns;

    println!(
        "\ndecision path is {:.0}x cheaper than one simulated decode step \
         ({:.0} ns vs {:.0} ns)",
        step_res / adaptive_res.max(1.0),
        adaptive_res,
        step_res
    );
    assert!(
        adaptive_res < 1_000.0,
        "adaptive decide+observe took {adaptive_res:.0} ns/iter — the decision \
         path must stay under 1 µs"
    );
    println!("OK: decision + observation path < 1 µs");
}
