//! Serving-layer benchmarks: scheduler overhead and sustained
//! mixed-precision continuous-batching throughput over the
//! deterministic [`SimBackend`] (no AOT artifacts needed — this
//! measures the serve layer itself, not the engine forward).
//!
//! Run: `cargo bench --bench bench_serve`

use std::time::Instant;

use otaro::benchutil::{black_box, group, maybe_write_json, quick_mode, rate, Bench};
use otaro::config::ServeConfig;
use otaro::data::Rng;
use otaro::infer::SimConfig;
use otaro::runtime::ParamStore;
use otaro::sefp::Precision;
use otaro::serve::{
    demo_decoder_params, DecoderBackend, DynamicBatcher, PrecisionLadder, Request, Router,
    SchedPolicy, Server, SimBackend, TaskClass,
};

fn ladder(cfg: &ServeConfig) -> PrecisionLadder {
    let mut rng = Rng::new(11);
    let params = ParamStore {
        tensors: vec![(0..4096).map(|_| rng.normal() as f32 * 0.1).collect(), vec![1.0; 64]],
        names: vec!["w".into(), "ln".into()],
        shapes: vec![vec![64, 64], vec![64]],
        quantized: vec![true, false],
    };
    PrecisionLadder::from_params(&params).with_budget(cfg.ladder_budget_bytes)
}

fn mixed_request(rng: &mut Rng, id: u64) -> Request {
    // 70% understanding-style next-token at low widths, 30% generation
    let (m, max_new) = match rng.below(10) {
        0..=3 => (4, 1),
        4..=6 => (6, 1),
        7 | 8 => (8, 4),
        _ => (3, 8),
    };
    // token ids stay below EOS/PAD (257/258): reserved ids are invalid
    // in prompts (submit rejects them) and EOS would cut decodes short
    let prompt: Vec<i32> = (0..rng.below(24) + 4).map(|_| rng.below(256) as i32).collect();
    Request::new(id, TaskClass::Other, prompt)
        .with_precision(Precision::of(m))
        .with_max_new_tokens(max_new)
}

fn main() {
    let mut b = Bench::from_env();
    let serve_cfg = ServeConfig::default();
    // OTARO_BENCH_QUICK caps the sustained-traffic loops so the CI
    // smoke step finishes in seconds while every assert still runs
    let quick = quick_mode();

    group("scheduler: push + pop_batch, 4-width mix");
    b.run_elems("sched_push64_pop_all", 64, || {
        let mut db = DynamicBatcher::new(8, 1024).with_policy(SchedPolicy::from_config(&serve_cfg));
        let mut rng = Rng::new(3);
        for i in 0..64u64 {
            let req = Request::new(i, TaskClass::Other, vec![65, 66]);
            db.push(req, Precision::of([3u8, 4, 6, 8][rng.below(4)])).unwrap();
        }
        let mut n = 0;
        while let Some((_, batch)) = db.pop_batch() {
            n += batch.len();
        }
        n
    });

    group("generation engine: one full drain, mixed precisions");
    let drain = |n_requests: u64| -> (f64, u64, u64) {
        let backend = SimBackend::new(8, 32, 320);
        let batcher = DynamicBatcher::new(8, usize::MAX)
            .with_policy(SchedPolicy::from_config(&serve_cfg));
        let mut server =
            Server::new(backend, ladder(&serve_cfg), Router::new(serve_cfg.clone()), batcher);
        let mut rng = Rng::new(17);
        for i in 0..n_requests {
            assert!(server.submit(mixed_request(&mut rng, i)));
        }
        let t0 = Instant::now();
        let responses = server.process_all().unwrap();
        let secs = t0.elapsed().as_secs_f64();
        assert_eq!(responses.len() as u64, n_requests);
        let stats = server.stats();
        (secs, stats.tokens_generated, stats.decode_steps)
    };
    b.run("serve_drain_256_mixed", || black_box(drain(if quick { 32 } else { 256 })));

    group("DecoderBackend: continuous batching over REAL SEFP logits");
    // a model-shaped ladder (tok_embed + layerN projections) feeds the
    // pure-Rust batched decode engine — this measures end-to-end serving
    // on actual quantized matmuls + KV-cache attention, no PJRT, no hash
    let dec_cfg = SimConfig { d_model: 64, d_ff: 128, n_layers: 2, vocab: 256, context: 16 };
    let dec_params = demo_decoder_params(&dec_cfg, 29);
    let dec_n = if quick { 16u64 } else { 64 };
    for threads in [1usize, 2] {
        let mut ladder = PrecisionLadder::from_params(&dec_params)
            .with_budget(serve_cfg.ladder_budget_bytes);
        // derive the sub-master views once so the timed drain measures
        // decode, not first-switch truncation
        for m in [3u8, 4, 6] {
            let _ = ladder.view_at(Precision::of(m)).unwrap();
        }
        let backend = DecoderBackend::from_ladder(&ladder, 8, 16, threads).unwrap();
        let batcher = DynamicBatcher::new(8, usize::MAX)
            .with_policy(SchedPolicy::from_config(&serve_cfg));
        let mut server =
            Server::new(backend, ladder, Router::new(serve_cfg.clone()), batcher);
        let mut rng = Rng::new(31);
        for i in 0..dec_n {
            assert!(server.submit(mixed_request(&mut rng, i)));
        }
        let t0 = Instant::now();
        let responses = server.process_all().unwrap();
        let secs = t0.elapsed().as_secs_f64();
        assert_eq!(responses.len() as u64, dec_n, "decoder backend must serve everything");
        let stats = server.stats();
        rate(&format!("decoder_drain_t{threads}_requests"), dec_n, secs);
        rate(&format!("decoder_drain_t{threads}_tokens"), stats.tokens_generated, secs);
    }

    group("sustained mixed-precision traffic (requests/sec)");
    // arrival loop: submit in bursts, drain between bursts — the
    // number this bench exists for is the sustained req/s line below
    let backend = SimBackend::new(8, 32, 320);
    let batcher =
        DynamicBatcher::new(8, 4096).with_policy(SchedPolicy::from_config(&serve_cfg));
    let mut server =
        Server::new(backend, ladder(&serve_cfg), Router::new(serve_cfg.clone()), batcher);
    let mut rng = Rng::new(23);
    let bursts = if quick { 20u64 } else { 200 };
    let per_burst = 16u64;
    let t0 = Instant::now();
    let mut served = 0u64;
    for burst in 0..bursts {
        for i in 0..per_burst {
            let _ = server.submit(mixed_request(&mut rng, burst * per_burst + i));
        }
        served += server.process_all().unwrap().len() as u64;
    }
    let secs = t0.elapsed().as_secs_f64();
    let stats = server.stats().clone();
    rate("sustained_mixed_requests", served, secs);
    rate("sustained_mixed_tokens", stats.tokens_generated, secs);
    rate("decode_steps", stats.decode_steps, secs);
    println!(
        "scheduled runs: {}; queue p50/p95/p99 {:.2}/{:.2}/{:.2} ms; \
         compute p50/p95 {:.3}/{:.3} ms; widths {:?}",
        stats.batches,
        stats.queue_ms.p50(),
        stats.queue_ms.p95(),
        stats.queue_ms.p99(),
        stats.compute_ms.p50(),
        stats.compute_ms.p95(),
        stats.per_precision
    );
    println!(
        "ladder switches: {} hits / {} misses / {} evictions (mean derive {:.3} ms)",
        stats.switch_hits, stats.switch_misses, stats.switch_evictions,
        stats.switch_ms.mean()
    );
    println!(
        "server-side throughput accounting: {:.1} req/s / {:.1} tok/s over {:.3}s of work",
        stats.throughput_rps(),
        stats.throughput_tps(),
        stats.wall_secs
    );

    // OTARO_BENCH_JSON=<dir> drops BENCH_serve.json for trend tooling;
    // unset leaves the default run console-only
    maybe_write_json(&b, "serve");
}
