//! `.sefp` artifact vs the f32 checkpoint path, at equal model size:
//!
//!   * pack          — f32 master -> container bytes (offline cost)
//!   * f32 path      — read + parse `init_params.bin`-style f32, then
//!                     SEFP-encode the ladder master (what every startup
//!                     paid before the artifact existed)
//!   * artifact path — read + validate (checksums included) + build the
//!                     ladder master from the planes
//!   * view_at       — the zero-copy borrowed open at each rung
//!
//! Two guard assertions keep the wins from regressing: the artifact
//! open must beat the f32 parse-then-encode path outright, and the bulk
//! f32 parse itself must sustain a floor throughput (the seed's
//! element-by-element parse was far below it).

use std::collections::HashMap;

use otaro::artifact::{pack_params, write_artifact, Artifact, ArtifactMeta};
use otaro::benchutil::{black_box, group, Bench};
use otaro::data::Rng;
use otaro::runtime::manifest::{Manifest, ModelConfig, ParamEntry};
use otaro::runtime::ParamStore;
use otaro::sefp::Precision;
use otaro::serve::PrecisionLadder;

/// ~1M weights across 17 tensors, every 4th a passthrough 1-D tensor —
/// the shape mix of a real decoder checkpoint.
fn make_params() -> ParamStore {
    let mut rng = Rng::new(0xA271FAC7);
    let mut tensors = Vec::new();
    let mut names = Vec::new();
    let mut shapes = Vec::new();
    let mut quantized = Vec::new();
    for i in 0..17usize {
        let n = if i % 4 == 3 { 256 } else { 65_536 };
        tensors.push((0..n).map(|_| rng.normal() as f32 * 0.1).collect());
        names.push(format!("t{i}"));
        shapes.push(if i % 4 == 3 { vec![n] } else { vec![256, 256] });
        quantized.push(i % 4 != 3);
    }
    ParamStore { tensors, names, shapes, quantized }
}

fn manifest_for(params: &ParamStore) -> Manifest {
    Manifest {
        preset: "bench".into(),
        quant_impl: "none".into(),
        config: ModelConfig {
            vocab_size: 0,
            d_model: 256,
            n_heads: 4,
            n_layers: 4,
            d_ff: 1024,
            max_seq: 64,
            batch_size: 8,
            group_size: 64,
            rounding: "trunc".into(),
        },
        mantissa_widths: Precision::LADDER.to_vec(),
        params: params
            .names
            .iter()
            .zip(&params.shapes)
            .zip(&params.quantized)
            .map(|((name, shape), &quantized)| ParamEntry {
                name: name.clone(),
                shape: shape.clone(),
                quantized,
            })
            .collect(),
        artifacts: HashMap::new(),
        init_params_sha256: String::new(),
    }
}

fn main() {
    let params = make_params();
    let manifest = manifest_for(&params);
    let meta = ArtifactMeta::new(Precision::of(8));
    let n_weights: u64 = params.tensors.iter().map(|t| t.len() as u64).sum();

    let dir = std::env::temp_dir().join("otaro_bench_artifact");
    std::fs::create_dir_all(&dir).unwrap();
    let bin_path = dir.join("master.bin");
    let sefp_path = dir.join("master.sefp");
    params.save(&bin_path).unwrap();
    let sefp_bytes = write_artifact(&sefp_path, &params, &meta).unwrap();
    let f32_bytes = n_weights * 4;
    println!(
        "model: {n_weights} weights; f32 checkpoint {} KiB, .sefp artifact {} KiB ({:.1}%)\n",
        f32_bytes / 1024,
        sefp_bytes / 1024,
        sefp_bytes as f64 / f32_bytes as f64 * 100.0
    );

    let mut b = Bench::new();

    group("offline pack");
    b.run_elems("pack_f32_to_sefp", n_weights, || pack_params(black_box(&params), &meta));

    group("startup: f32 checkpoint path");
    b.run_elems("f32_read_parse", n_weights, || {
        ParamStore::from_manifest_bin(black_box(&manifest), &bin_path).unwrap()
    });
    b.run_elems("f32_parse_then_encode_ladder", n_weights, || {
        let p = ParamStore::from_manifest_bin(black_box(&manifest), &bin_path).unwrap();
        PrecisionLadder::from_params(&p)
    });

    group("startup: .sefp artifact path");
    b.run_elems("artifact_open_checksummed", n_weights, || {
        Artifact::open(black_box(&sefp_path)).unwrap()
    });
    b.run_elems("artifact_open_then_ladder", n_weights, || {
        let a = Artifact::open(black_box(&sefp_path)).unwrap();
        PrecisionLadder::from_artifact(&a).unwrap()
    });

    group("startup pinned at E5M4 (truncate-at-load vs re-encode)");
    let m4 = Precision::of(4);
    b.run_elems("f32_parse_then_encode_at_m4", n_weights, || {
        let p = ParamStore::from_manifest_bin(black_box(&manifest), &bin_path).unwrap();
        PrecisionLadder::from_params_at(&p, m4)
    });
    b.run_elems("artifact_open_then_ladder_at_m4", n_weights, || {
        let a = Artifact::open(black_box(&sefp_path)).unwrap();
        PrecisionLadder::from_artifact_at(&a, m4).unwrap()
    });

    group("zero-copy views (artifact already open)");
    let a = Artifact::open(&sefp_path).unwrap();
    for m in [8u8, 4, 3] {
        let p = Precision::of(m);
        b.run_elems(&format!("view_at_m{m}"), n_weights, || {
            let mut total = 0usize;
            for i in 0..a.tensor_count() {
                if a.tensors()[i].quantized {
                    total += black_box(a.view(i, p).unwrap()).borrowed_bytes();
                }
            }
            total
        });
    }

    // --- guard assertions -------------------------------------------------
    // 1. acceptance: the full artifact startup (open + checksums + ladder
    //    build) must beat the full f32 startup (parse + encode + ladder)
    //    apples-to-apples — open-only would hide a from_artifact regression
    let speedup = b
        .ratio("f32_parse_then_encode_ladder", "artifact_open_then_ladder")
        .unwrap();
    let open_only = b
        .ratio("f32_parse_then_encode_ladder", "artifact_open_checksummed")
        .unwrap();
    println!(
        "\nartifact startup vs f32 startup: {speedup:.1}x faster ({open_only:.1}x to open alone)"
    );
    assert!(
        speedup > 1.0,
        "artifact load must be strictly faster than the f32-parse-then-encode path \
         (got {speedup:.2}x end-to-end)"
    );

    // 2. load-throughput floor: the bulk chunks_exact f32 parse sustains
    //    well over 1 GB/s on any modern machine; 300 MB/s is far below
    //    that but far above what the seed's per-element parse loop
    //    regression would deliver alongside its allocator churn
    let parse = b
        .results()
        .iter()
        .find(|r| r.name == "f32_read_parse")
        .unwrap();
    let mb_per_s = f32_bytes as f64 / (parse.median_ns * 1e-9) / 1e6;
    println!("f32 checkpoint parse throughput: {mb_per_s:.0} MB/s");
    assert!(
        mb_per_s > 300.0,
        "f32 checkpoint parse dropped below the 300 MB/s floor ({mb_per_s:.0} MB/s) — \
         the bulk-conversion load path has regressed"
    );

    let _ = std::fs::remove_dir_all(&dir);
}
