//! Coordinator overhead — BPS scoring/selection and LAA accumulation must
//! be negligible next to a train step (target <1%, DESIGN.md §Perf) —
//! plus the serving-side precision-switch primitive.

use otaro::benchutil::{black_box, group, Bench};
use otaro::coordinator::{Bps, Laa, LaaAction, UniformSampler};
use otaro::runtime::Width;
use otaro::sefp::{PackedSefp, Precision, SefpSpec, SefpTensor};
use otaro::serve::DynamicBatcher;

fn main() {
    let mut b = Bench::new();
    let widths = Precision::LADDER;

    group("BPS");
    {
        let mut bps = Bps::new(&widths, 5.0, 0.9);
        b.run("bps_select_update", || {
            let w = bps.select();
            bps.update(w, black_box(2.5));
            w
        });
    }
    {
        let mut u = UniformSampler::new(&widths, 3);
        b.run("uniform_select", || u.select());
    }

    group("LAA accumulate (~476k params)");
    let grads: Vec<Vec<f32>> = vec![vec![0.01f32; 476_000 / 4]; 4];
    {
        let mut laa = Laa::new(usize::MAX >> 1, Precision::of(4)); // never flush
        let m3 = Width::m(Precision::of(3));
        b.run_elems("laa_observe_m3", 476_000, || {
            match laa.observe(m3, black_box(grads.clone())) {
                LaaAction::Deferred { filled } => filled,
                _ => unreachable!(),
            }
        });
    }
    b.run_elems("grads_clone_baseline", 476_000, || black_box(grads.clone()));

    group("serve dynamic batcher");
    b.run("push64_pop_all", || {
        let mut db = DynamicBatcher::new(8, 1024);
        for i in 0..64u64 {
            let req = otaro::serve::Request::new(i, otaro::serve::TaskClass::Other, vec![65, 66]);
            db.push(req, Precision::of((3 + (i % 6)) as u8)).unwrap();
        }
        let mut n = 0;
        while let Some((_, batch)) = db.pop_batch() {
            n += batch.len();
        }
        n
    });

    group("precision switch on 1M-element tensor");
    let mut rng = otaro::data::Rng::new(5);
    let w: Vec<f32> = (0..1 << 20).map(|_| rng.normal() as f32 * 0.1).collect();
    let t8 = SefpTensor::encode(&w, &SefpSpec::new(Precision::of(8)));
    let p8 = PackedSefp::from_tensor(&t8);
    let m4 = Precision::of(4);
    b.run_elems("tensor_truncate_to_m4", 1 << 20, || black_box(&t8).truncate(m4));
    b.run_elems("packed_truncate_to_m4", 1 << 20, || black_box(&p8).truncate(m4));
    b.run_elems("truncate_plus_decode", 1 << 20, || black_box(&t8).truncate(m4).decode());
    let spec4 = SefpSpec::new(m4);
    b.run_elems("full_reencode_baseline", 1 << 20, || {
        SefpTensor::encode(black_box(&w), &spec4)
    });

    println!(
        "\nswitch-vs-reencode speedup: {:.1}x",
        b.ratio("full_reencode_baseline", "tensor_truncate_to_m4").unwrap_or(f64::NAN)
    );
}
