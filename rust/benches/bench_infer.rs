//! Table-2 micro benches: packed-SEFP matvec vs f32 dense matvec, the
//! batched matmul kernels vs per-row matvec loops (the bandwidth
//! amortization the decode engine is built on), and the full decode-step
//! comparison at several widths.
//!
//! Kernel-regression gates asserted here (run on every push via the CI
//! bench-smoke step, `OTARO_BENCH_QUICK=1`):
//! * `QuantLinear::matmul` at E5M4 with B=8 strictly beats 8 sequential
//!   `matvec` calls;
//! * batched results are bit-identical to per-row matvec and to every
//!   worker-thread count.

use otaro::benchutil::{black_box, group, maybe_write_json, Bench};
use otaro::data::Rng;
use otaro::infer::{DecoderSim, DecoderWeights, DenseLinear, QuantLinear, SimConfig};
use otaro::sefp::{Precision, SefpSpec};

fn dense(in_dim: usize, out_dim: usize) -> DenseLinear {
    let mut rng = Rng::new(7);
    DenseLinear::new(
        in_dim,
        out_dim,
        (0..in_dim * out_dim).map(|_| rng.normal() as f32 * 0.05).collect(),
    )
}

fn main() {
    let mut b = Bench::from_env();

    group("matvec 1024x1024");
    let d = dense(1024, 1024);
    let mut rng = Rng::new(8);
    let x: Vec<f32> = (0..1024).map(|_| rng.normal() as f32).collect();
    let mut y = vec![0.0f32; 1024];
    let n = (1024 * 1024) as u64;
    b.run_elems("f32_dense", n, || d.matvec(black_box(&x), black_box(&mut y)));
    for m in [8u8, 4, 3] {
        let q = QuantLinear::from_dense(&d, &SefpSpec::new(Precision::of(m)));
        b.run_elems(&format!("sefp_m{m}"), n, || q.matvec(black_box(&x), black_box(&mut y)));
    }

    // 2048x2048 E5M4 = 4 MiB of significands: the weight stream exceeds
    // per-core L2, so the per-row matvec loop pays the full re-read cost
    // per sequence — the bandwidth-bound regime batched decode lives in
    group("batched matmul 2048x2048, B=8 (E5M4): column reuse vs matvec loop");
    const B: usize = 8;
    const DIM: usize = 2048;
    let d2 = dense(DIM, DIM);
    let q4 = QuantLinear::from_dense(&d2, &SefpSpec::new(Precision::of(4)));
    let xb: Vec<f32> = (0..B * DIM).map(|_| rng.normal() as f32).collect();
    let mut yb = vec![0.0f32; B * DIM];
    // correctness gate before timing: batched == per-row matvec
    // bit-for-bit, at every worker count
    let mut y_ref = vec![0.0f32; B * DIM];
    for r in 0..B {
        let (x_row, y_row) = (&xb[r * DIM..(r + 1) * DIM], &mut y_ref[r * DIM..(r + 1) * DIM]);
        q4.matvec(x_row, y_row);
    }
    for threads in [1usize, 2, 4] {
        q4.matmul(&xb, B, &mut yb, threads);
        assert_eq!(yb, y_ref, "matmul(threads={threads}) diverged from per-row matvec");
    }
    let nb = (B * DIM * DIM) as u64;
    b.run_elems("matvec_x8_loop", nb, || {
        for r in 0..B {
            let y_row = &mut yb[r * DIM..(r + 1) * DIM];
            q4.matvec(black_box(&xb[r * DIM..(r + 1) * DIM]), black_box(y_row));
        }
    });
    for threads in [1usize, 2, 4] {
        b.run_elems(&format!("matmul_b8_t{threads}"), nb, || {
            q4.matmul(black_box(&xb), B, black_box(&mut yb), threads)
        });
    }
    let batched_speedup = b.ratio("matvec_x8_loop", "matmul_b8_t1").unwrap_or(f64::NAN);
    println!(
        "\nbatched speedup matmul(B=8, 1 thread) vs 8x matvec at E5M4: {batched_speedup:.2}x"
    );
    assert!(
        batched_speedup > 1.0,
        "kernel regression: matmul(B=8) must strictly beat 8 sequential matvecs \
         (got {batched_speedup:.3}x)"
    );
    println!(
        "thread scaling at B=8: t2 {:.2}x, t4 {:.2}x over t1",
        b.ratio("matmul_b8_t1", "matmul_b8_t2").unwrap_or(f64::NAN),
        b.ratio("matmul_b8_t1", "matmul_b8_t4").unwrap_or(f64::NAN)
    );

    group("decode_step llama8b/16 sim");
    let cfg = SimConfig::llama8b_scaled(16);
    let mut dense_sim = DecoderSim::new(cfg, DecoderWeights::Dense, 1);
    let mut sefp_sim = DecoderSim::new(cfg, DecoderWeights::Sefp(Precision::of(4)), 1);
    // prefill so attention reads a realistic cache
    let _ = dense_sim.decode_throughput_prefilled(1, cfg.context, 1);
    let _ = sefp_sim.decode_throughput_prefilled(1, cfg.context, 1);
    {
        let mut xs = vec![0.1f32; cfg.d_model];
        b.run("decode_fp", || dense_sim.decode_step(black_box(&mut xs)));
    }
    {
        let mut xs = vec![0.1f32; cfg.d_model];
        b.run("decode_sefp_m4", || sefp_sim.decode_step(black_box(&mut xs)));
    }
    println!(
        "\ndecode speedup SEFP-E5M4 vs fp: {:.2}x (paper table 2: 2.45x vs FP16 on-device)",
        b.ratio("decode_fp", "decode_sefp_m4").unwrap_or(f64::NAN)
    );
    println!(
        "memory: fp16-equiv {:.1} MiB vs sefp-m4 {:.1} MiB ({:.0}% reduction)",
        dense_sim.memory_bytes() as f64 / 1048576.0,
        sefp_sim.memory_bytes() as f64 / 1048576.0,
        100.0 * (1.0 - sefp_sim.memory_bytes() as f64 / dense_sim.memory_bytes() as f64)
    );

    group("batched decode: 4-row engine step vs 4 sequential single-row sims");
    let bcfg = SimConfig::llama8b_scaled(32);
    let mut singles: Vec<DecoderSim> = (0..4)
        .map(|_| DecoderSim::new(bcfg, DecoderWeights::Sefp(Precision::of(4)), 2))
        .collect();
    let mut xs = vec![0.1f32; 4 * bcfg.d_model];
    let mut x1 = vec![0.1f32; bcfg.d_model];
    b.run("decode4_looped", || {
        let mut c = 0.0f32;
        for s in singles.iter_mut() {
            c += s.decode_step(black_box(&mut x1));
        }
        c
    });
    for threads in [1usize, 2, 4] {
        let mut batched =
            DecoderSim::new_batched(bcfg, DecoderWeights::Sefp(Precision::of(4)), 2, 4)
                .with_threads(threads);
        b.run(&format!("decode4_batched_t{threads}"), || {
            batched.decode_batch_step(black_box(&mut xs))
        });
    }
    println!(
        "\nbatched decode speedup (B=4): t1 {:.2}x, t2 {:.2}x, t4 {:.2}x vs looped singles",
        b.ratio("decode4_looped", "decode4_batched_t1").unwrap_or(f64::NAN),
        b.ratio("decode4_looped", "decode4_batched_t2").unwrap_or(f64::NAN),
        b.ratio("decode4_looped", "decode4_batched_t4").unwrap_or(f64::NAN)
    );

    // OTARO_BENCH_JSON=<dir> drops BENCH_infer.json for trend tooling;
    // unset leaves the default run console-only
    maybe_write_json(&b, "infer");
}
