//! Table-2 micro benches: packed-SEFP matvec vs f32 dense matvec, plus
//! the full decode-step comparison at several widths.

use otaro::benchutil::{black_box, group, Bench};
use otaro::data::Rng;
use otaro::infer::{DecoderSim, DecoderWeights, DenseLinear, QuantLinear, SimConfig};
use otaro::sefp::{Precision, SefpSpec};

fn dense(in_dim: usize, out_dim: usize) -> DenseLinear {
    let mut rng = Rng::new(7);
    DenseLinear::new(
        in_dim,
        out_dim,
        (0..in_dim * out_dim).map(|_| rng.normal() as f32 * 0.05).collect(),
    )
}

fn main() {
    let mut b = Bench::new();

    group("matvec 1024x1024");
    let d = dense(1024, 1024);
    let mut rng = Rng::new(8);
    let x: Vec<f32> = (0..1024).map(|_| rng.normal() as f32).collect();
    let mut y = vec![0.0f32; 1024];
    let n = (1024 * 1024) as u64;
    b.run_elems("f32_dense", n, || d.matvec(black_box(&x), black_box(&mut y)));
    for m in [8u8, 4, 3] {
        let q = QuantLinear::from_dense(&d, &SefpSpec::new(Precision::of(m)));
        b.run_elems(&format!("sefp_m{m}"), n, || q.matvec(black_box(&x), black_box(&mut y)));
    }

    group("decode_step llama8b/16 sim");
    let cfg = SimConfig::llama8b_scaled(16);
    let mut dense_sim = DecoderSim::new(cfg, DecoderWeights::Dense, 1);
    let mut sefp_sim = DecoderSim::new(cfg, DecoderWeights::Sefp(Precision::of(4)), 1);
    // prefill so attention reads a realistic cache
    let _ = dense_sim.decode_throughput_prefilled(1, cfg.context, 1);
    let _ = sefp_sim.decode_throughput_prefilled(1, cfg.context, 1);
    {
        let mut xs = vec![0.1f32; cfg.d_model];
        b.run("decode_fp", || dense_sim.decode_step(black_box(&mut xs)));
    }
    {
        let mut xs = vec![0.1f32; cfg.d_model];
        b.run("decode_sefp_m4", || sefp_sim.decode_step(black_box(&mut xs)));
    }
    println!(
        "\ndecode speedup SEFP-E5M4 vs fp: {:.2}x (paper table 2: 2.45x vs FP16 on-device)",
        b.ratio("decode_fp", "decode_sefp_m4").unwrap_or(f64::NAN)
    );
    println!(
        "memory: fp16-equiv {:.1} MiB vs sefp-m4 {:.1} MiB ({:.0}% reduction)",
        dense_sim.memory_bytes() as f64 / 1048576.0,
        sefp_sim.memory_bytes() as f64 / 1048576.0,
        100.0 * (1.0 - sefp_sim.memory_bytes() as f64 / dense_sim.memory_bytes() as f64)
    );
}
