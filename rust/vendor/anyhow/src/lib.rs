//! Minimal offline stand-in for the `anyhow` crate, covering the subset
//! this repository uses: `anyhow::Result`, the `anyhow!` / `bail!` /
//! `ensure!` macros, and `?`-conversion from any `std::error::Error`.
//!
//! Deliberately NOT implemented: `Context`, downcasting, backtraces.
//! The API is source-compatible with real anyhow for the call sites in
//! this crate, so swapping in the real dependency later is a one-line
//! `Cargo.toml` change.

use std::fmt;

/// Boxed dynamic error with a `Display`-first `Debug`, mirroring
/// anyhow's behaviour of printing the message (not the struct) when a
/// `main() -> Result<(), Error>` unwinds.
pub struct Error(Box<dyn std::error::Error + Send + Sync + 'static>);

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error(Box::new(MessageError(message.to_string())))
    }

    /// The underlying error, for callers that want to inspect it.
    pub fn inner(&self) -> &(dyn std::error::Error + Send + Sync + 'static) {
        &*self.0
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

// NOTE: `Error` itself must NOT implement `std::error::Error`, or this
// blanket conversion would conflict with the identity `From` impl.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error(Box::new(e))
    }
}

/// String-backed error used by the `anyhow!` macro.
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for MessageError {}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string (or anything `Display`).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fails() -> Result<String> {
        Ok(std::fs::read_to_string("/definitely/not/a/path")?)
    }

    fn checked(v: usize) -> Result<usize> {
        ensure!(v < 10, "v too big: {v}");
        if v == 7 {
            bail!("unlucky {}", v);
        }
        Ok(v)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fails().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn macros_format_messages() {
        let e: Error = anyhow!("x={} y={}", 1, 2);
        assert_eq!(e.to_string(), "x=1 y=2");
        assert_eq!(checked(3).unwrap(), 3);
        assert_eq!(checked(12).unwrap_err().to_string(), "v too big: 12");
        assert_eq!(checked(7).unwrap_err().to_string(), "unlucky 7");
    }
}
