//! Compile-time stand-in for the PJRT/XLA Rust bindings.
//!
//! The real serving/training engine loads AOT-compiled HLO through a
//! PJRT plugin; that shared library is not present in the offline image,
//! so this stub keeps the crate COMPILING with the exact API surface
//! `runtime::engine` uses, while erroring cleanly at runtime when a
//! client is requested.  Everything that can work host-side (literal
//! construction, reshape, round-trip to `Vec<T>`) does work, so unit
//! tests of literal plumbing are meaningful; only `PjRtClient::cpu()`
//! and executable compilation/execution report unavailability.

use std::fmt;

/// Error type mirroring the bindings' stringly-typed errors.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    pub fn new(msg: impl Into<String>) -> Self {
        Error(msg.into())
    }

    fn unavailable(what: &str) -> Self {
        Error(format!(
            "{what}: PJRT runtime not available in this offline build \
             (vendored xla stub; install a PJRT plugin and swap the real bindings in)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Host-side literal: typed element buffer + dims.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Elems,
    dims: Vec<i64>,
}

#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum Elems {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Element types a [`Literal`] can hold (sealed).
pub trait NativeType: Copy + private::Sealed {
    fn wrap(v: Vec<Self>) -> Elems;
    fn unwrap(e: &Elems) -> Option<Vec<Self>>;
}

mod private {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Elems {
        Elems::F32(v)
    }
    fn unwrap(e: &Elems) -> Option<Vec<Self>> {
        match e {
            Elems::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> Elems {
        Elems::I32(v)
    }
    fn unwrap(e: &Elems) -> Option<Vec<Self>> {
        match e {
            Elems::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        let n = v.len() as i64;
        Literal { data: T::wrap(v.to_vec()), dims: vec![n] }
    }

    fn len(&self) -> usize {
        match &self.data {
            Elems::F32(v) => v.len(),
            Elems::I32(v) => v.len(),
            Elems::Tuple(v) => v.len(),
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.len() {
            return Err(Error::new(format!(
                "reshape: {} elements into shape {dims:?}",
                self.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| Error::new("to_vec: element type mismatch"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.data {
            Elems::Tuple(v) => Ok(v.clone()),
            _ => Err(Error::new("to_tuple: literal is not a tuple")),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (text retained; the stub cannot execute it).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("read {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

#[derive(Debug, Clone)]
pub struct XlaComputation {
    _proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        XlaComputation { _proto: proto.clone() }
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(l.to_vec::<i32>().is_err());
        assert!(l.to_tuple().is_err());
    }

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("PJRT runtime not available"));
    }
}
