//! Multiple-choice scoring — the zero-shot evaluation protocol of the
//! paper's tables 1, 3-7 (lm-eval-harness style): each choice is scored
//! by the length-normalized log-likelihood of its tokens given the
//! prompt; the argmax choice is the model's answer.

use crate::data::tokenizer::{BOS, EOS, PAD, SEP};
use crate::data::{McItem, Tokenizer};
use crate::runtime::{Engine, ParamStore, Width};

/// One scoring row: fixed-length token vector + the span of positions
/// whose next-token predictions belong to the choice.
struct Row {
    tokens: Vec<i32>,
    /// (position p, target token) — logits at p predict target
    span: Vec<(usize, i32)>,
}

fn build_row(prompt_toks: &[i32], choice_toks: &[i32], seq_len: usize) -> Row {
    // full sequence: BOS prompt SEP choice EOS
    let mut seq = Vec::with_capacity(prompt_toks.len() + choice_toks.len() + 3);
    seq.push(BOS);
    seq.extend_from_slice(prompt_toks);
    seq.push(SEP);
    seq.extend_from_slice(choice_toks);
    seq.push(EOS);
    // if too long, trim the prompt head (keep BOS); the span is recomputed
    // from the SEP position afterwards so trimming is safe
    if seq.len() > seq_len + 1 {
        let excess = seq.len() - (seq_len + 1);
        seq.splice(1..1 + excess, std::iter::empty());
    }
    let sep_pos = seq.iter().rposition(|&t| t == SEP).expect("SEP present");
    let mut span = Vec::new();
    for p in sep_pos..seq.len() - 1 {
        span.push((p, seq[p + 1]));
    }
    let mut tokens = seq[..seq.len() - 1].to_vec();
    tokens.resize(seq_len, PAD);
    Row { tokens, span }
}

/// Batched evaluator: accumulates rows and runs the engine's logits step
/// once per full batch.
pub struct McEvaluator<'a> {
    engine: &'a mut Engine,
    params: &'a ParamStore,
    width: Width,
    rows: Vec<Row>,
    lls: Vec<f64>,
    batch_size: usize,
    seq_len: usize,
    vocab: usize,
}

impl<'a> McEvaluator<'a> {
    pub fn new(engine: &'a mut Engine, params: &'a ParamStore, width: Width) -> Self {
        let (b, t) = engine.batch_shape();
        let vocab = engine.vocab_size();
        McEvaluator {
            engine,
            params,
            width,
            rows: Vec::new(),
            lls: Vec::new(),
            batch_size: b,
            seq_len: t,
            vocab,
        }
    }

    fn push_row(&mut self, row: Row) -> anyhow::Result<()> {
        self.rows.push(row);
        if self.rows.len() == self.batch_size {
            self.flush()?;
        }
        Ok(())
    }

    fn flush(&mut self) -> anyhow::Result<()> {
        if self.rows.is_empty() {
            return Ok(());
        }
        let mut tokens = Vec::with_capacity(self.batch_size * self.seq_len);
        for r in &self.rows {
            tokens.extend_from_slice(&r.tokens);
        }
        // pad to full batch with PAD rows
        let real_rows = self.rows.len();
        for _ in real_rows..self.batch_size {
            tokens.extend(std::iter::repeat(PAD).take(self.seq_len));
        }
        let logits = self.engine.logits_step(self.params, &tokens, self.width)?;
        let v = self.vocab;
        for (ri, row) in self.rows.iter().enumerate() {
            let mut ll = 0.0f64;
            for &(p, target) in &row.span {
                let off = (ri * self.seq_len + p) * v;
                let slice = &logits[off..off + v];
                ll += log_softmax_at(slice, target as usize);
            }
            self.lls.push(ll / row.span.len().max(1) as f64);
        }
        self.rows.clear();
        Ok(())
    }

    fn finish(mut self) -> anyhow::Result<Vec<f64>> {
        self.flush()?;
        Ok(self.lls)
    }
}

fn log_softmax_at(logits: &[f32], idx: usize) -> f64 {
    let max = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
    let lse: f64 = logits.iter().map(|&l| ((l as f64) - max).exp()).sum::<f64>().ln() + max;
    logits[idx] as f64 - lse
}

/// Accuracy of `items` at `width`.  Returns (accuracy, n_correct).
pub fn score_items(
    engine: &mut Engine,
    params: &ParamStore,
    width: Width,
    items: &[McItem],
) -> anyhow::Result<(f64, usize)> {
    let tok = Tokenizer::new();
    let (_, seq_len) = engine.batch_shape();
    let mut ev = McEvaluator::new(engine, params, width);
    let mut arity = Vec::with_capacity(items.len());
    for item in items {
        let p = tok.encode(&item.prompt);
        arity.push(item.choices.len());
        for c in &item.choices {
            ev.push_row(build_row(&p, &tok.encode(c), seq_len))?;
        }
    }
    let lls = ev.finish()?;
    let mut correct = 0usize;
    let mut off = 0usize;
    for (item, &k) in items.iter().zip(&arity) {
        let slice = &lls[off..off + k];
        off += k;
        let best = slice
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if best == item.answer {
            correct += 1;
        }
    }
    Ok((correct as f64 / items.len().max(1) as f64, correct))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_softmax_normalizes() {
        let logits = vec![1.0f32, 2.0, 3.0];
        let total: f64 = (0..3).map(|i| log_softmax_at(&logits, i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn row_has_choice_span() {
        let r = build_row(&[65, 66], &[67, 68], 16);
        // seq: BOS 65 66 SEP 67 68 EOS -> span from SEP predicts 67,68,EOS
        assert_eq!(r.tokens.len(), 16);
        assert_eq!(r.span.len(), 3);
        assert_eq!(r.span[0].1, 67);
        assert_eq!(r.span[2].1, EOS);
    }

    #[test]
    fn long_prompt_trimmed_keeps_choice() {
        let prompt: Vec<i32> = (0..100).map(|i| 65 + (i % 26)).collect();
        let r = build_row(&prompt, &[90], 32);
        assert_eq!(r.tokens.len(), 32);
        assert_eq!(r.span.last().unwrap().1, EOS);
        assert_eq!(r.span[0].1, 90);
    }
}
