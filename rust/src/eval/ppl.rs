//! Perplexity evaluation (paper's task-specific metric, fig. 7 / table 8).
//!
//! PPL = exp(Σ token NLL / Σ valid tokens) over a deterministic
//! sequential sweep of the test stream.  The engine's eval step returns
//! the *mean* NLL per batch over valid targets, so we re-weight by each
//! batch's valid-target count to get the exact corpus-level mean.

use crate::data::StreamBatcher;
use crate::runtime::{Engine, ParamStore, Width};

pub fn perplexity(
    engine: &mut Engine,
    params: &ParamStore,
    test_stream: &[i32],
    width: Width,
) -> anyhow::Result<f64> {
    let (b, t) = engine.batch_shape();
    let batcher = StreamBatcher::new(test_stream.to_vec(), b, t, 0);
    let mut nll_sum = 0.0f64;
    let mut n_tokens = 0usize;
    for batch in batcher.sequential_batches() {
        let valid = batch.n_valid_targets();
        if valid == 0 {
            continue;
        }
        let mean_nll = engine.eval_step(params, &batch, width)? as f64;
        nll_sum += mean_nll * valid as f64;
        n_tokens += valid;
    }
    anyhow::ensure!(n_tokens > 0, "empty test stream");
    Ok((nll_sum / n_tokens as f64).exp())
}

/// PPL sweep across the precision ladder (one table-8 row).
pub fn ppl_sweep(
    engine: &mut Engine,
    params: &ParamStore,
    test_stream: &[i32],
    widths: &[Width],
) -> anyhow::Result<Vec<(Width, f64)>> {
    let mut out = Vec::with_capacity(widths.len());
    for &w in widths {
        out.push((w, perplexity(engine, params, test_stream, w)?));
    }
    Ok(out)
}
