//! Evaluation harness: perplexity (fig. 7 / table 8) and multiple-choice
//! accuracy via length-normalized log-likelihood (tables 1, 3-7), plus
//! the per-bitwidth sweep runners and table formatting.

pub mod mc;
pub mod ppl;
pub mod tables;

pub use mc::{score_items, McEvaluator};
pub use ppl::perplexity;
pub use tables::TableBuilder;
