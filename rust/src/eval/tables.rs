//! Table formatting: renders the paper-style method × bit-width tables
//! (markdown) that the bench harness prints and EXPERIMENTS.md records.

use std::fmt::Write as _;

/// Simple row-major table builder with a fixed header.
#[derive(Debug, Clone)]
pub struct TableBuilder {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl TableBuilder {
    pub fn new(title: &str, header: &[&str]) -> Self {
        TableBuilder {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells);
        self
    }

    pub fn row_f(&mut self, label: &str, values: &[f64], fmt: fn(f64) -> String) -> &mut Self {
        let mut cells = vec![label.to_string()];
        cells.extend(values.iter().map(|&v| fmt(v)));
        self.row(cells)
    }

    pub fn markdown(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "### {}\n", self.title);
        let _ = writeln!(s, "| {} |", self.header.join(" | "));
        let _ = writeln!(s, "|{}|", vec!["---"; self.header.len()].join("|"));
        for r in &self.rows {
            let _ = writeln!(s, "| {} |", r.join(" | "));
        }
        s
    }
}

pub fn pct(v: f64) -> String {
    format!("{:.2}%", 100.0 * v)
}

pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

pub fn f3(v: f64) -> String {
    format!("{v:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_markdown() {
        let mut t = TableBuilder::new("Test", &["Method", "E5M8", "E5M3"]);
        t.row_f("ours", &[0.59, 0.57], pct);
        let md = t.markdown();
        assert!(md.contains("| Method | E5M8 | E5M3 |"));
        assert!(md.contains("59.00%"));
        assert!(md.lines().count() >= 4);
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = TableBuilder::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
