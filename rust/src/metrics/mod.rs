//! Metrics substrate: JSONL event sink, timers, summary statistics.
//!
//! Every experiment binary writes its raw per-step records through
//! [`MetricsSink`] so runs are replayable and EXPERIMENTS.md numbers are
//! regenerable from the run directory.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::time::Instant;

/// Append-only JSONL sink. `None` path = in-memory only (tests).
pub struct MetricsSink {
    writer: Option<BufWriter<File>>,
    pub events: usize,
}

impl MetricsSink {
    pub fn to_file(path: &Path) -> anyhow::Result<Self> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        Ok(MetricsSink { writer: Some(BufWriter::new(File::create(path)?)), events: 0 })
    }

    pub fn null() -> Self {
        MetricsSink { writer: None, events: 0 }
    }

    pub fn log(&mut self, value: &crate::json::Value) {
        self.events += 1;
        if let Some(w) = &mut self.writer {
            // metrics loss is not worth crashing a training run over
            let _ = w.write_all(value.to_string().as_bytes());
            let _ = w.write_all(b"\n");
        }
    }

    pub fn flush(&mut self) {
        if let Some(w) = &mut self.writer {
            let _ = w.flush();
        }
    }
}

/// Wall-clock timer for step timing.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(Instant::now())
    }
    pub fn ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

/// Online mean/std/min/max accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population standard deviation (matches the paper's table 8 STD).
    pub fn std(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        (self.m2 / self.n as f64).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_stats() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.0).abs() < 1e-12);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 9.0);
    }

    #[test]
    fn null_sink_counts() {
        let mut s = MetricsSink::null();
        s.log(&crate::json::obj(vec![("a", crate::json::n(1.0))]));
        assert_eq!(s.events, 1);
    }

    #[test]
    fn file_sink_writes_jsonl() {
        let dir = std::env::temp_dir().join("otaro_metrics_test");
        let path = dir.join("m.jsonl");
        let mut s = MetricsSink::to_file(&path).unwrap();
        s.log(&crate::json::obj(vec![("step", crate::json::n(1.0)), ("loss", crate::json::n(2.5))]));
        s.log(&crate::json::obj(vec![("step", crate::json::n(2.0)), ("loss", crate::json::n(2.4))]));
        s.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
