//! Metrics substrate: JSONL event sink, timers, summary statistics.
//!
//! Every experiment binary writes its raw per-step records through
//! [`MetricsSink`] so runs are replayable and EXPERIMENTS.md numbers are
//! regenerable from the run directory.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::time::Instant;

/// Append-only JSONL sink. `None` path = in-memory only (tests).
pub struct MetricsSink {
    writer: Option<BufWriter<File>>,
    pub events: usize,
}

impl MetricsSink {
    pub fn to_file(path: &Path) -> anyhow::Result<Self> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        Ok(MetricsSink { writer: Some(BufWriter::new(File::create(path)?)), events: 0 })
    }

    pub fn null() -> Self {
        MetricsSink { writer: None, events: 0 }
    }

    pub fn log(&mut self, value: &crate::json::Value) {
        self.events += 1;
        if let Some(w) = &mut self.writer {
            // metrics loss is not worth crashing a training run over
            let _ = w.write_all(value.to_string().as_bytes());
            let _ = w.write_all(b"\n");
        }
    }

    pub fn flush(&mut self) {
        if let Some(w) = &mut self.writer {
            let _ = w.flush();
        }
    }
}

/// Wall-clock timer for step timing.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(Instant::now())
    }
    pub fn ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

/// Newest samples a [`Summary`] retains for percentile queries.  The
/// Welford aggregates (`n`/mean/std/min/max) always cover the full
/// stream; bounding the percentile window keeps a long-running server's
/// per-request stats O(1) in memory instead of growing per request.
pub const SUMMARY_SAMPLE_CAP: usize = 4096;

/// Mean/std/min/max accumulator (Welford) with exact percentiles.
///
/// Samples are retained (newest [`SUMMARY_SAMPLE_CAP`], ring-buffered)
/// so [`percentile`](Summary::percentile) is exact nearest-rank over
/// the retained window, not an approximation — tail latencies
/// (p95/p99) are the signal the serving policy layer steers by, and a
/// mean hides exactly the violations an SLO cares about.  Smaller
/// fixed windows live in `policy::telemetry`.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
    /// newest samples, ring-buffered at [`SUMMARY_SAMPLE_CAP`]
    samples: Vec<f64>,
    /// next overwrite position once the ring has wrapped
    head: usize,
}

impl Summary {
    pub fn new() -> Self {
        Summary {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            samples: Vec::new(),
            head: 0,
        }
    }

    /// Like [`Summary::new`], but the percentile ring is reserved up
    /// front at [`SUMMARY_SAMPLE_CAP`], so no [`push`](Summary::push)
    /// will ever reallocate.  The obs registry's histograms use this so
    /// the metric record path stays allocation-free from the first
    /// sample.
    pub fn preallocated() -> Self {
        let mut s = Summary::new();
        s.samples.reserve_exact(SUMMARY_SAMPLE_CAP);
        s
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if self.samples.len() < SUMMARY_SAMPLE_CAP {
            self.samples.push(x);
        } else {
            self.samples[self.head] = x;
            self.head = (self.head + 1) % SUMMARY_SAMPLE_CAP;
        }
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population standard deviation (matches the paper's table 8 STD).
    pub fn std(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        (self.m2 / self.n as f64).sqrt()
    }

    /// Exact nearest-rank percentile over the retained window — the
    /// newest [`SUMMARY_SAMPLE_CAP`] samples (`q` in [0, 100]); 0.0
    /// when empty.  Sorts a copy — this is a reporting path, not a
    /// per-event one.
    pub fn percentile(&self, q: f64) -> f64 {
        percentile_of(&self.samples, q)
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

/// Exact nearest-rank percentile of an unsorted slice (`q` in [0, 100]);
/// 0.0 when empty.  Shared by [`Summary`] and the fixed-size telemetry
/// windows in `policy::telemetry`.
pub fn percentile_of(samples: &[f64], q: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let rank = (q.clamp(0.0, 100.0) / 100.0 * sorted.len() as f64).ceil() as usize;
    sorted[rank.saturating_sub(1).min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_stats() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.0).abs() < 1e-12);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 9.0);
    }

    #[test]
    fn percentiles_are_exact_nearest_rank() {
        let mut s = Summary::new();
        for x in 1..=100 {
            s.push(x as f64);
        }
        assert_eq!(s.p50(), 50.0);
        assert_eq!(s.p95(), 95.0);
        assert_eq!(s.p99(), 99.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert_eq!(s.percentile(0.0), 1.0);
        // order-independent: a reversed stream gives the same answers
        let mut r = Summary::new();
        for x in (1..=100).rev() {
            r.push(x as f64);
        }
        assert_eq!(r.p95(), 95.0);
        // empty and singleton edge cases
        assert_eq!(Summary::new().p95(), 0.0);
        let mut one = Summary::new();
        one.push(7.0);
        assert_eq!(one.p50(), 7.0);
        assert_eq!(one.p99(), 7.0);
        assert_eq!(percentile_of(&[3.0, 1.0, 2.0], 50.0), 2.0);
    }

    #[test]
    fn retention_is_bounded_and_keeps_newest() {
        let mut s = Summary::new();
        for x in 0..(SUMMARY_SAMPLE_CAP + 1000) {
            s.push(x as f64);
        }
        // full-stream aggregates are unaffected by the ring
        assert_eq!(s.n, (SUMMARY_SAMPLE_CAP + 1000) as u64);
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, (SUMMARY_SAMPLE_CAP + 999) as f64);
        // percentiles cover the NEWEST cap samples: the minimum retained
        // value is the 1000th push, not the 0th
        assert_eq!(s.percentile(0.0), 1000.0);
        assert_eq!(s.percentile(100.0), (SUMMARY_SAMPLE_CAP + 999) as f64);
    }

    #[test]
    fn empty_window_is_all_zeros_except_minmax_sentinels() {
        let s = Summary::new();
        assert_eq!(s.n, 0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.std(), 0.0);
        assert_eq!(s.p50(), 0.0);
        assert_eq!(s.p95(), 0.0);
        assert_eq!(s.p99(), 0.0);
        // min/max are the identity elements; consumers that serialize
        // them (obs snapshot) must clamp the empty case themselves
        assert_eq!(s.min, f64::INFINITY);
        assert_eq!(s.max, f64::NEG_INFINITY);
    }

    #[test]
    fn single_sample_pins_every_statistic() {
        let mut s = Summary::new();
        s.push(3.25);
        assert_eq!(s.n, 1);
        assert_eq!(s.mean(), 3.25);
        assert_eq!(s.std(), 0.0);
        assert_eq!((s.min, s.max), (3.25, 3.25));
        for q in [0.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(s.percentile(q), 3.25);
        }
    }

    #[test]
    fn identical_samples_have_zero_spread() {
        let mut s = Summary::new();
        for _ in 0..1000 {
            s.push(42.0);
        }
        assert_eq!(s.mean(), 42.0);
        assert_eq!(s.std(), 0.0);
        assert_eq!(s.p50(), 42.0);
        assert_eq!(s.p99(), 42.0);
        assert_eq!((s.min, s.max), (42.0, 42.0));
    }

    #[test]
    fn preallocated_ring_never_regrows() {
        let mut s = Summary::preallocated();
        let cap = s.samples.capacity();
        assert!(cap >= SUMMARY_SAMPLE_CAP);
        for x in 0..(SUMMARY_SAMPLE_CAP * 2) {
            s.push(x as f64);
        }
        assert_eq!(s.samples.capacity(), cap, "push reallocated the ring");
        assert_eq!(s.samples.len(), SUMMARY_SAMPLE_CAP);
    }

    #[test]
    fn null_sink_counts() {
        let mut s = MetricsSink::null();
        s.log(&crate::json::obj(vec![("a", crate::json::n(1.0))]));
        assert_eq!(s.events, 1);
    }

    #[test]
    fn file_sink_writes_jsonl() {
        let dir = std::env::temp_dir().join("otaro_metrics_test");
        let path = dir.join("m.jsonl");
        let mut s = MetricsSink::to_file(&path).unwrap();
        s.log(&crate::json::obj(vec![("step", crate::json::n(1.0)), ("loss", crate::json::n(2.5))]));
        s.log(&crate::json::obj(vec![("step", crate::json::n(2.0)), ("loss", crate::json::n(2.4))]));
        s.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
