//! Transformer-decode simulator for the table-2 benchmark.
//!
//! Replays autoregressive decoding faithfully: each decode step runs the
//! seven projection matvecs of every layer (q, k, v, o, gate, up, down),
//! REAL single-head attention over a growing KV cache (f32 for the FP
//! baseline, SEFP-quantized for the quantized runs — the paper's table-2
//! memory number includes the cache), and the LM head.

use crate::data::Rng;
use crate::sefp::{Precision, SefpSpec};

use super::kv_cache::KvCache;
use super::{DenseLinear, QuantLinear};

#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    pub d_model: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub vocab: usize,
    /// context length for KV-cache accounting (paper: 2000 tokens)
    pub context: usize,
}

impl SimConfig {
    /// LLaMA3-8B-shaped config (the paper's table-2 subject), scaled by
    /// `scale` so CPU runs finish (ratios are scale-invariant).
    pub fn llama8b_scaled(scale: usize) -> Self {
        SimConfig {
            d_model: 4096 / scale,
            d_ff: 14336 / scale,
            n_layers: 32 / scale.min(8),
            vocab: 128_256 / scale,
            context: 2000,
        }
    }

    pub fn n_weights(&self) -> usize {
        let per_layer = 4 * self.d_model * self.d_model + 3 * self.d_model * self.d_ff;
        self.n_layers * per_layer + self.d_model * self.vocab
    }

    /// KV cache bytes for `context` tokens at `bytes_per_elem`.
    pub fn kv_cache_bytes(&self, bytes_per_elem: usize) -> usize {
        2 * self.n_layers * self.context * self.d_model * bytes_per_elem
    }
}

/// One layer's projection weights.
pub enum LayerWeights {
    Dense { proj: Vec<DenseLinear> },
    Quant { proj: Vec<QuantLinear> },
}

pub enum DecoderWeights {
    Dense,
    /// SEFP at the given precision
    Sefp(Precision),
}

/// The simulator itself.
pub struct DecoderSim {
    pub cfg: SimConfig,
    layers: Vec<LayerWeights>,
    head: LayerWeights,
    caches: Vec<KvCache>,
    quant_precision: Option<Precision>,
}

fn rand_dense(rng: &mut Rng, in_dim: usize, out_dim: usize) -> DenseLinear {
    let w: Vec<f32> = (0..in_dim * out_dim).map(|_| rng.normal() as f32 * 0.05).collect();
    DenseLinear::new(in_dim, out_dim, w)
}

impl DecoderSim {
    pub fn new(cfg: SimConfig, weights: DecoderWeights, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let dims = |cfg: &SimConfig| -> Vec<(usize, usize)> {
            vec![
                (cfg.d_model, cfg.d_model), // q
                (cfg.d_model, cfg.d_model), // k
                (cfg.d_model, cfg.d_model), // v
                (cfg.d_model, cfg.d_model), // o
                (cfg.d_model, cfg.d_ff),    // gate
                (cfg.d_model, cfg.d_ff),    // up
                (cfg.d_ff, cfg.d_model),    // down
            ]
        };
        let build_layer = |rng: &mut Rng| -> LayerWeights {
            let dense: Vec<DenseLinear> =
                dims(&cfg).into_iter().map(|(i, o)| rand_dense(rng, i, o)).collect();
            match weights {
                DecoderWeights::Dense => LayerWeights::Dense { proj: dense },
                DecoderWeights::Sefp(p) => LayerWeights::Quant {
                    proj: dense
                        .iter()
                        .map(|d| QuantLinear::from_dense(d, &SefpSpec::new(p)))
                        .collect(),
                },
            }
        };
        let layers = (0..cfg.n_layers).map(|_| build_layer(&mut rng)).collect();
        let head_dense = rand_dense(&mut rng, cfg.d_model, cfg.vocab);
        let head = match weights {
            DecoderWeights::Dense => LayerWeights::Dense { proj: vec![head_dense] },
            DecoderWeights::Sefp(p) => LayerWeights::Quant {
                proj: vec![QuantLinear::from_dense(&head_dense, &SefpSpec::new(p))],
            },
        };
        let quant_precision = match weights {
            DecoderWeights::Dense => None,
            DecoderWeights::Sefp(p) => Some(p),
        };
        let caches = (0..cfg.n_layers)
            .map(|_| match quant_precision {
                None => KvCache::f32(cfg.d_model),
                Some(p) => KvCache::sefp(cfg.d_model, Precision::of(p.m().min(7)), 64),
            })
            .collect();
        DecoderSim { cfg, layers, head, caches, quant_precision }
    }

    /// Reset the KV caches (new sequence).
    pub fn reset(&mut self) {
        let cfg = self.cfg;
        for c in &mut self.caches {
            *c = match self.quant_precision {
                None => KvCache::f32(cfg.d_model),
                Some(p) => KvCache::sefp(cfg.d_model, Precision::of(p.m().min(7)), 64),
            };
        }
    }

    /// One decode step: q/k/v projections, attention over the KV cache,
    /// o-projection, SwiGLU-shaped MLP, LM head.  Returns a checksum so
    /// the work cannot be optimized away.
    pub fn decode_step(&mut self, x: &mut [f32]) -> f32 {
        self.decode_step_logits(x).0
    }

    /// One decode step that also yields the greedy next token from the
    /// LM-head logits — serving-style generation over the simulator.
    pub fn decode_step_token(&mut self, x: &mut [f32]) -> (f32, i32) {
        let (checksum, logits) = self.decode_step_logits(x);
        (checksum, super::sampling::argmax(&logits) as i32)
    }

    fn decode_step_logits(&mut self, x: &mut [f32]) -> (f32, Vec<f32>) {
        let d = self.cfg.d_model;
        let f = self.cfg.d_ff;
        let mut q = vec![0.0f32; d];
        let mut k = vec![0.0f32; d];
        let mut v = vec![0.0f32; d];
        let mut att = vec![0.0f32; d];
        let mut buf_d = vec![0.0f32; d];
        let mut buf_f = vec![0.0f32; f];
        let mut checksum = 0.0f32;
        for (li, layer) in self.layers.iter().enumerate() {
            let mv = |i: usize, xin: &[f32], out: &mut [f32]| match layer {
                LayerWeights::Dense { proj } => proj[i].matvec(xin, out),
                LayerWeights::Quant { proj } => proj[i].matvec(xin, out),
            };
            // attention
            mv(0, x, &mut q);
            mv(1, x, &mut k);
            mv(2, x, &mut v);
            let cache = &mut self.caches[li];
            cache.append(&k, &v);
            cache.attend(&q, &mut att);
            mv(3, &att, &mut buf_d);
            checksum += buf_d[0];
            for (xv, bv) in x.iter_mut().zip(&buf_d) {
                *xv += 0.1 * bv.tanh();
            }
            // MLP (gate * up -> down)
            mv(4, x, &mut buf_f);
            let mut up = vec![0.0f32; f];
            mv(5, x, &mut up);
            for (g, u) in buf_f.iter_mut().zip(&up) {
                *g = (*g / (1.0 + (-*g).exp())) * u; // silu(g) * u
            }
            mv(6, &buf_f, &mut buf_d);
            checksum += buf_d[0];
            for (xv, bv) in x.iter_mut().zip(&buf_d) {
                *xv = 0.9 * *xv + 0.1 * bv.tanh();
            }
        }
        let mut logits0 = vec![0.0f32; self.head_out()];
        match &self.head {
            LayerWeights::Dense { proj } => proj[0].matvec(x, &mut logits0),
            LayerWeights::Quant { proj } => proj[0].matvec(x, &mut logits0),
        }
        (checksum + logits0[0], logits0)
    }

    fn head_out(&self) -> usize {
        self.cfg.vocab
    }

    /// Decode `n_tokens` tokens after pre-filling `prefill` cache entries
    /// (the paper assumes a 2000-token input); returns (tokens/sec,
    /// checksum).
    pub fn decode_throughput(&mut self, n_tokens: usize, seed: u64) -> (f64, f32) {
        self.decode_throughput_prefilled(n_tokens, 0, seed)
    }

    pub fn decode_throughput_prefilled(
        &mut self,
        n_tokens: usize,
        prefill: usize,
        seed: u64,
    ) -> (f64, f32) {
        self.reset();
        let mut rng = Rng::new(seed);
        let mut x: Vec<f32> = (0..self.cfg.d_model).map(|_| rng.normal() as f32 * 0.1).collect();
        if prefill > 0 {
            // fill caches without timing (prefill cost is a separate
            // phase in the paper's table 2)
            let d = self.cfg.d_model;
            for _ in 0..prefill {
                let k: Vec<f32> = (0..d).map(|_| rng.normal() as f32 * 0.3).collect();
                let v: Vec<f32> = (0..d).map(|_| rng.normal() as f32 * 0.3).collect();
                for c in &mut self.caches {
                    c.append(&k, &v);
                }
            }
        }
        let start = std::time::Instant::now();
        let mut checksum = 0.0f32;
        for _ in 0..n_tokens {
            checksum += self.decode_step(&mut x);
        }
        let secs = start.elapsed().as_secs_f64();
        (n_tokens as f64 / secs, checksum)
    }

    /// Measured KV-cache bytes currently held.
    pub fn cache_bytes(&self) -> usize {
        self.caches.iter().map(|c| c.bytes()).sum()
    }

    /// Weight memory in bytes for the current format.
    pub fn weight_bytes(&self) -> usize {
        let layer_bytes = |lw: &LayerWeights| -> usize {
            match lw {
                LayerWeights::Dense { proj } => proj.iter().map(|p| p.bytes_f16()).sum(),
                LayerWeights::Quant { proj } => proj.iter().map(|p| p.packed_bytes()).sum(),
            }
        };
        self.layers.iter().map(layer_bytes).sum::<usize>() + layer_bytes(&self.head)
    }

    /// Total memory report (weights + KV cache), paper table-2 style.
    /// FP16 baseline KV cache is fp16; SEFP runs quantize the KV cache to
    /// the same width (the paper includes KV-cache savings in its 69%).
    pub fn memory_bytes(&self) -> usize {
        let kv_elem = match &self.layers[0] {
            LayerWeights::Dense { .. } => 2,
            LayerWeights::Quant { proj } => proj[0].precision.bits_per_elem().div_ceil(8),
        };
        self.weight_bytes() + self.cfg.kv_cache_bytes(kv_elem.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> SimConfig {
        SimConfig { d_model: 128, d_ff: 256, n_layers: 2, vocab: 320, context: 100 }
    }

    #[test]
    fn decode_runs_and_is_finite() {
        let mut sim = DecoderSim::new(small(), DecoderWeights::Sefp(Precision::of(4)), 1);
        let mut x = vec![0.1f32; 128];
        for _ in 0..5 {
            let c = sim.decode_step(&mut x);
            assert!(c.is_finite());
        }
        assert!(x.iter().all(|v| v.is_finite()));
        assert_eq!(sim.caches[0].len(), 5);
        assert!(sim.cache_bytes() > 0);
    }

    #[test]
    fn decode_step_token_is_greedy_and_in_vocab() {
        let mut a = DecoderSim::new(small(), DecoderWeights::Sefp(Precision::of(4)), 1);
        let mut b = DecoderSim::new(small(), DecoderWeights::Sefp(Precision::of(4)), 1);
        let mut xa = vec![0.1f32; 128];
        let mut xb = vec![0.1f32; 128];
        for _ in 0..3 {
            let (ca, ta) = a.decode_step_token(&mut xa);
            let (cb, tb) = b.decode_step_token(&mut xb);
            assert!(ca.is_finite());
            assert_eq!(ca, cb, "same weights+input, same checksum");
            assert_eq!(ta, tb, "greedy decode is deterministic");
            assert!((0..320).contains(&ta));
        }
    }

    #[test]
    fn reset_clears_caches() {
        let mut sim = DecoderSim::new(small(), DecoderWeights::Dense, 1);
        let mut x = vec![0.1f32; 128];
        let _ = sim.decode_step(&mut x);
        assert_eq!(sim.caches[0].len(), 1);
        sim.reset();
        assert_eq!(sim.caches[0].len(), 0);
    }

    #[test]
    fn quant_uses_less_memory() {
        let d = DecoderSim::new(small(), DecoderWeights::Dense, 1);
        let q = DecoderSim::new(small(), DecoderWeights::Sefp(Precision::of(4)), 1);
        assert!(q.weight_bytes() * 2 < d.weight_bytes());
        assert!(q.memory_bytes() < d.memory_bytes());
    }

    #[test]
    fn memory_reduction_near_paper_band() {
        // E5M4 vs FP16 weights: expect ~68-69% reduction
        let d = DecoderSim::new(small(), DecoderWeights::Dense, 1);
        let q = DecoderSim::new(small(), DecoderWeights::Sefp(Precision::of(4)), 1);
        let red = 1.0 - q.memory_bytes() as f64 / d.memory_bytes() as f64;
        assert!((0.6..0.75).contains(&red), "reduction={red}");
    }

    #[test]
    fn n_weights_counts() {
        let c = small();
        assert_eq!(
            c.n_weights(),
            2 * (4 * 128 * 128 + 3 * 128 * 256) + 128 * 320
        );
    }
}
