//! Transformer-decode simulator for the table-2 benchmark and the
//! serve-layer [`DecoderBackend`](crate::serve::DecoderBackend).
//!
//! Replays autoregressive decoding faithfully: each decode step runs the
//! seven projection matmuls of every layer (q, k, v, o, gate, up, down),
//! REAL single-head attention over a growing KV cache (f32 for the FP
//! baseline, SEFP-quantized for the quantized runs — the paper's table-2
//! memory number includes the cache), and the LM head.
//!
//! The simulator is batched: it owns `batch` independent KV caches per
//! layer and decodes all rows of a `(batch × d_model)` activation block
//! per [`decode_batch_step`](DecoderSim::decode_batch_step), using the
//! column-reusing [`QuantLinear::matmul`] kernels (optionally
//! multi-threaded — see [`with_threads`](DecoderSim::with_threads)).
//! Rows reset independently ([`reset_row`](DecoderSim::reset_row)), so a
//! serving engine's FIFO row refill maps directly onto the sim.  All
//! per-step buffers live in a persistent scratch: the measured decode
//! hot loop performs no heap allocation.

use crate::data::Rng;
use crate::obs::profile::{Stage, StageRecorder};
use crate::sefp::{Precision, SefpSpec, GROUP_SIZE};

use super::kv_cache::KvCache;
use super::{DenseLinear, QuantLinear};

/// Shared-exponent group width of the simulator's SEFP KV caches.
pub const KV_GROUP: usize = GROUP_SIZE;

/// KV caches store i8 significands, so an m=8 weight ladder caches at
/// m=7 — the single source of truth for cache precision, used by cache
/// construction AND the config-based memory accounting.
fn kv_precision(p: Precision) -> Precision {
    Precision::of(p.m().min(7))
}

#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    pub d_model: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub vocab: usize,
    /// context length for KV-cache accounting (paper: 2000 tokens)
    pub context: usize,
}

impl SimConfig {
    /// LLaMA3-8B-shaped config (the paper's table-2 subject), scaled by
    /// `scale` so CPU runs finish (ratios are scale-invariant).
    ///
    /// Divided dimensions are rounded DOWN to the nearest multiple of
    /// the SEFP group size (minimum one group): a non-power-of-two scale
    /// such as 3 or 6 would otherwise yield `d_model`/`d_ff` that are
    /// not group-aligned and trip the `QuantLinear::from_dense` /
    /// `KvCache::sefp` alignment asserts at construction time.
    pub fn llama8b_scaled(scale: usize) -> Self {
        let scale = scale.max(1);
        let align = |x: usize| (x / KV_GROUP).max(1) * KV_GROUP;
        SimConfig {
            d_model: align(4096 / scale),
            d_ff: align(14336 / scale),
            n_layers: (32 / scale.min(8)).max(1),
            vocab: (128_256 / scale).max(KV_GROUP),
            context: 2000,
        }
    }

    pub fn n_weights(&self) -> usize {
        let per_layer = 4 * self.d_model * self.d_model + 3 * self.d_model * self.d_ff;
        self.n_layers * per_layer + self.d_model * self.vocab
    }

    /// KV cache bytes for `context` tokens at `bytes_per_elem`.
    pub fn kv_cache_bytes(&self, bytes_per_elem: usize) -> usize {
        2 * self.n_layers * self.context * self.d_model * bytes_per_elem
    }

    /// Packed KV-cache bytes for `context` tokens at cache precision
    /// `p`: the same `(1+m)` bits/element + 5 bits/group formula as
    /// [`KvCache::bytes`], so config-based and measured accounting agree
    /// (the seed billed the cache at the WEIGHT precision's whole-byte
    /// footprint and the two disagreed).
    pub fn kv_cache_packed_bytes(&self, p: Precision) -> usize {
        let elems = 2 * self.n_layers * self.context * self.d_model;
        let groups = elems / KV_GROUP;
        (elems * p.bits_per_elem() + groups * 5).div_ceil(8)
    }
}

/// One layer's projection weights.
pub enum LayerWeights {
    Dense { proj: Vec<DenseLinear> },
    Quant { proj: Vec<QuantLinear> },
}

pub enum DecoderWeights {
    Dense,
    /// SEFP at the given precision
    Sefp(Precision),
}

/// (in_dim, out_dim) of the seven per-layer projections, in storage
/// order: q, k, v, o, gate, up, down — THE single source of the layer
/// shape contract, shared with `serve::DecoderBackend`'s tensor-name
/// mapping.
pub fn proj_dims(d_model: usize, d_ff: usize) -> [(usize, usize); 7] {
    [
        (d_model, d_model), // q
        (d_model, d_model), // k
        (d_model, d_model), // v
        (d_model, d_model), // o
        (d_model, d_ff),    // gate
        (d_model, d_ff),    // up
        (d_ff, d_model),    // down
    ]
}

/// Persistent per-sim buffers for the decode hot loop — every slice the
/// seed allocated per token (q/k/v/att, MLP buffers, logits) lives here
/// instead, sized once for the full batch.
struct Scratch {
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    att: Vec<f32>,
    buf_d: Vec<f32>,
    buf_f: Vec<f32>,
    up: Vec<f32>,
    logits: Vec<f32>,
}

impl Scratch {
    fn new(cfg: &SimConfig, batch: usize) -> Self {
        Scratch {
            q: vec![0.0; batch * cfg.d_model],
            k: vec![0.0; batch * cfg.d_model],
            v: vec![0.0; batch * cfg.d_model],
            att: vec![0.0; batch * cfg.d_model],
            buf_d: vec![0.0; batch * cfg.d_model],
            buf_f: vec![0.0; batch * cfg.d_ff],
            up: vec![0.0; batch * cfg.d_ff],
            logits: vec![0.0; batch * cfg.vocab],
        }
    }
}

/// The simulator itself.
pub struct DecoderSim {
    pub cfg: SimConfig,
    layers: Vec<LayerWeights>,
    head: LayerWeights,
    /// `n_layers × batch` caches, indexed `layer * batch + row`
    caches: Vec<KvCache>,
    quant_precision: Option<Precision>,
    batch: usize,
    threads: usize,
    scratch: Scratch,
    /// batched decode steps executed (obs gauge: `backend.sim_steps`)
    pub steps: u64,
    /// single-row prompt prefill steps executed (obs gauge:
    /// `backend.sim_prefill_steps`)
    pub prefill_steps: u64,
    /// stage timer sink (disabled by default — zero timestamps taken);
    /// the serve backend drains it via `LogitsBackend::take_profile`
    pub profile: StageRecorder,
}

fn rand_dense(rng: &mut Rng, in_dim: usize, out_dim: usize) -> DenseLinear {
    let w: Vec<f32> = (0..in_dim * out_dim).map(|_| rng.normal() as f32 * 0.05).collect();
    DenseLinear::new(in_dim, out_dim, w)
}

impl DecoderSim {
    pub fn new(cfg: SimConfig, weights: DecoderWeights, seed: u64) -> Self {
        Self::new_batched(cfg, weights, seed, 1)
    }

    /// Build a `batch`-row simulator with seeded random weights (each
    /// row gets its own independent KV caches).
    pub fn new_batched(cfg: SimConfig, weights: DecoderWeights, seed: u64, batch: usize) -> Self {
        let batch = batch.max(1);
        let mut rng = Rng::new(seed);
        let build_layer = |rng: &mut Rng| -> LayerWeights {
            let dense: Vec<DenseLinear> = proj_dims(cfg.d_model, cfg.d_ff)
                .into_iter()
                .map(|(i, o)| rand_dense(rng, i, o))
                .collect();
            match weights {
                DecoderWeights::Dense => LayerWeights::Dense { proj: dense },
                DecoderWeights::Sefp(p) => LayerWeights::Quant {
                    proj: dense
                        .iter()
                        .map(|d| QuantLinear::from_dense(d, &SefpSpec::new(p)))
                        .collect(),
                },
            }
        };
        let layers = (0..cfg.n_layers).map(|_| build_layer(&mut rng)).collect();
        let head_dense = rand_dense(&mut rng, cfg.d_model, cfg.vocab);
        let head = match weights {
            DecoderWeights::Dense => LayerWeights::Dense { proj: vec![head_dense] },
            DecoderWeights::Sefp(p) => LayerWeights::Quant {
                proj: vec![QuantLinear::from_dense(&head_dense, &SefpSpec::new(p))],
            },
        };
        let quant_precision = match weights {
            DecoderWeights::Dense => None,
            DecoderWeights::Sefp(p) => Some(p),
        };
        let caches = Self::fresh_caches(&cfg, quant_precision, batch);
        let scratch = Scratch::new(&cfg, batch);
        DecoderSim {
            cfg,
            layers,
            head,
            caches,
            quant_precision,
            batch,
            threads: 1,
            scratch,
            steps: 0,
            prefill_steps: 0,
            profile: StageRecorder::disabled(),
        }
    }

    /// Build directly from already-quantized layers — the SEFP-native
    /// consumption path for `serve::DecoderBackend`: each inner vec is
    /// one layer's seven projections in q, k, v, o, gate, up, down
    /// order (`proj_dims`), `head` maps `d_model -> vocab`.  No f32 weights
    /// are ever touched.
    pub fn from_quant(
        cfg: SimConfig,
        layers: Vec<Vec<QuantLinear>>,
        head: QuantLinear,
        batch: usize,
    ) -> anyhow::Result<Self> {
        let batch = batch.max(1);
        anyhow::ensure!(
            layers.len() == cfg.n_layers,
            "expected {} layers, got {}",
            cfg.n_layers,
            layers.len()
        );
        anyhow::ensure!(
            cfg.d_model % KV_GROUP == 0,
            "d_model {} not aligned to the KV group size {KV_GROUP}",
            cfg.d_model
        );
        let dims = proj_dims(cfg.d_model, cfg.d_ff);
        for (li, projs) in layers.iter().enumerate() {
            anyhow::ensure!(projs.len() == 7, "layer {li}: expected 7 projections");
            for (pi, ((want_in, want_out), p)) in dims.iter().zip(projs).enumerate() {
                anyhow::ensure!(
                    p.in_dim == *want_in && p.out_dim == *want_out,
                    "layer {li} proj {pi}: got {}x{}, want {want_in}x{want_out}",
                    p.in_dim,
                    p.out_dim
                );
            }
        }
        anyhow::ensure!(
            head.in_dim == cfg.d_model && head.out_dim == cfg.vocab,
            "head: got {}x{}, want {}x{}",
            head.in_dim,
            head.out_dim,
            cfg.d_model,
            cfg.vocab
        );
        let quant_precision = Some(head.precision);
        let caches = Self::fresh_caches(&cfg, quant_precision, batch);
        let scratch = Scratch::new(&cfg, batch);
        Ok(DecoderSim {
            cfg,
            layers: layers
                .into_iter()
                .map(|proj| LayerWeights::Quant { proj })
                .collect(),
            head: LayerWeights::Quant { proj: vec![head] },
            caches,
            quant_precision,
            batch,
            threads: 1,
            scratch,
            steps: 0,
            prefill_steps: 0,
            profile: StageRecorder::disabled(),
        })
    }

    /// Worker threads for the column-parallel matmul kernels (1 =
    /// serial).  Output is bit-identical for every thread count.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Batch rows this sim decodes per step.
    pub fn batch(&self) -> usize {
        self.batch
    }

    fn fresh_cache(cfg: &SimConfig, qp: Option<Precision>) -> KvCache {
        match qp {
            None => KvCache::f32(cfg.d_model),
            Some(p) => KvCache::sefp(cfg.d_model, kv_precision(p), KV_GROUP),
        }
    }

    fn fresh_caches(cfg: &SimConfig, qp: Option<Precision>, batch: usize) -> Vec<KvCache> {
        (0..cfg.n_layers * batch).map(|_| Self::fresh_cache(cfg, qp)).collect()
    }

    /// Reset every row's KV caches (all sequences restart).
    pub fn reset(&mut self) {
        let cfg = self.cfg;
        for c in &mut self.caches {
            *c = Self::fresh_cache(&cfg, self.quant_precision);
        }
    }

    /// Reset ONE batch row's caches — the hook the serve engine's FIFO
    /// row refill uses when a finished request hands its row to the next
    /// queued one.  Other rows' caches are untouched.
    pub fn reset_row(&mut self, b: usize) {
        assert!(b < self.batch, "row {b} out of range for batch {}", self.batch);
        let cfg = self.cfg;
        for li in 0..self.cfg.n_layers {
            self.caches[li * self.batch + b] = Self::fresh_cache(&cfg, self.quant_precision);
        }
    }

    /// One decode step: q/k/v projections, attention over the KV cache,
    /// o-projection, SwiGLU-shaped MLP, LM head.  Returns a checksum so
    /// the work cannot be optimized away.  Single-sequence entry point —
    /// requires `batch == 1` (use [`decode_batch_step`](Self::decode_batch_step)
    /// for multi-row sims).
    pub fn decode_step(&mut self, x: &mut [f32]) -> f32 {
        assert_eq!(self.batch, 1, "decode_step drives a single-sequence sim");
        self.step_rows(x, None)
    }

    /// One decode step that also yields the greedy next token from the
    /// LM-head logits — serving-style generation over the simulator.
    pub fn decode_step_token(&mut self, x: &mut [f32]) -> (f32, i32) {
        let checksum = self.decode_step(x);
        (checksum, super::sampling::argmax(&self.scratch.logits[..self.cfg.vocab]) as i32)
    }

    /// Decode one token for EVERY batch row: `x` is the row-major
    /// `(batch × d_model)` activation block, mutated in place.  Logits
    /// land in the persistent scratch ([`logits`](Self::logits)).  Rows
    /// are computed independently (per-row caches), so a B-row step is
    /// bit-identical to B single-row sims stepping separately.
    pub fn decode_batch_step(&mut self, x: &mut [f32]) -> f32 {
        self.step_rows(x, None)
    }

    /// Like [`decode_batch_step`](Self::decode_batch_step) but rows with
    /// `active[b] == false` skip cache append/attention (their caches do
    /// not grow and their logits are meaningless) — the serve engine
    /// decodes a partially-filled batch this way.
    pub fn decode_batch_step_masked(&mut self, x: &mut [f32], active: &[bool]) -> f32 {
        debug_assert_eq!(active.len(), self.batch);
        self.step_rows(x, Some(active))
    }

    /// LM-head logits of the latest decode step, row-major
    /// `(batch × vocab)`.
    pub fn logits(&self) -> &[f32] {
        &self.scratch.logits
    }

    /// Tied-embedding lookup: materialize head column `n` (`d_model`
    /// values) into `out` — for a `from_quant` sim whose head is the
    /// `tok_embed` matrix this IS token `n`'s embedding, dequantized on
    /// demand from the same storage the head matmul computes with (no
    /// second copy of the largest tensor).
    pub fn tied_embed(&self, n: usize, out: &mut [f32]) {
        match &self.head {
            LayerWeights::Dense { proj } => {
                let p = &proj[0];
                out.copy_from_slice(&p.w[n * p.in_dim..(n + 1) * p.in_dim]);
            }
            LayerWeights::Quant { proj } => proj[0].decode_column(n, out),
        }
    }

    fn step_rows(&mut self, x: &mut [f32], active: Option<&[bool]>) -> f32 {
        // lint: region(no_alloc)
        self.steps += 1;
        let t0 = if self.profile.enabled() { Some(std::time::Instant::now()) } else { None };
        let d = self.cfg.d_model;
        let bsz = self.batch;
        let threads = self.threads;
        debug_assert_eq!(x.len(), bsz * d);
        let Scratch { q, k, v, att, buf_d, buf_f, up, logits } = &mut self.scratch;
        let is_active = |b: usize| active.is_none_or(|a| a[b]);
        let mut checksum = 0.0f32;
        for (li, layer) in self.layers.iter().enumerate() {
            let mm = |i: usize, xin: &[f32], out: &mut [f32]| match layer {
                LayerWeights::Dense { proj } => proj[i].matmul(xin, bsz, out, threads),
                LayerWeights::Quant { proj } => proj[i].matmul(xin, bsz, out, threads),
            };
            // attention
            mm(0, x, q);
            mm(1, x, k);
            mm(2, x, v);
            for b in 0..bsz {
                let (r0, r1) = (b * d, (b + 1) * d);
                if is_active(b) {
                    let cache = &mut self.caches[li * bsz + b];
                    cache.append(&k[r0..r1], &v[r0..r1]);
                    cache.attend(&q[r0..r1], &mut att[r0..r1]);
                } else {
                    att[r0..r1].fill(0.0);
                }
            }
            mm(3, att, buf_d);
            for b in 0..bsz {
                if is_active(b) {
                    checksum += buf_d[b * d];
                }
            }
            for (xv, bv) in x.iter_mut().zip(buf_d.iter()) {
                *xv += 0.1 * bv.tanh();
            }
            // MLP (gate * up -> down)
            mm(4, x, buf_f);
            mm(5, x, up);
            for (g, u) in buf_f.iter_mut().zip(up.iter()) {
                *g = (*g / (1.0 + (-*g).exp())) * u; // silu(g) * u
            }
            mm(6, buf_f, buf_d);
            for b in 0..bsz {
                if is_active(b) {
                    checksum += buf_d[b * d];
                }
            }
            for (xv, bv) in x.iter_mut().zip(buf_d.iter()) {
                *xv = 0.9 * *xv + 0.1 * bv.tanh();
            }
        }
        match &self.head {
            LayerWeights::Dense { proj } => proj[0].matmul(x, bsz, logits, threads),
            LayerWeights::Quant { proj } => proj[0].matmul(x, bsz, logits, threads),
        }
        for b in 0..bsz {
            if is_active(b) {
                checksum += logits[b * self.cfg.vocab];
            }
        }
        if let (Some(t0), Some(p)) = (t0, self.quant_precision) {
            self.profile.record(Stage::Matmul, p, t0.elapsed().as_secs_f64() * 1e3);
        }
        checksum
        // lint: end_region
    }

    /// Run the layer stack for ONE row only (single-row matvecs, no LM
    /// head, no logits): the cache-prefill path the serve backend uses
    /// to replay a refilled row's prompt without stepping the rest of
    /// the batch.  Numerics are bit-identical to a batched step of the
    /// same row (the kernels share accumulation order).
    pub fn prefill_row_step(&mut self, b: usize, x: &mut [f32]) {
        // lint: region(no_alloc)
        self.prefill_steps += 1;
        let t0 = if self.profile.enabled() { Some(std::time::Instant::now()) } else { None };
        let d = self.cfg.d_model;
        let f = self.cfg.d_ff;
        let bsz = self.batch;
        assert!(b < bsz, "row {b} out of range for batch {bsz}");
        debug_assert_eq!(x.len(), d);
        let Scratch { q, k, v, att, buf_d, buf_f, up, .. } = &mut self.scratch;
        let (r0, r1) = (b * d, (b + 1) * d);
        let (f0, f1) = (b * f, (b + 1) * f);
        for (li, layer) in self.layers.iter().enumerate() {
            let mv = |i: usize, xin: &[f32], out: &mut [f32]| match layer {
                LayerWeights::Dense { proj } => proj[i].matvec(xin, out),
                LayerWeights::Quant { proj } => proj[i].matvec(xin, out),
            };
            mv(0, x, &mut q[r0..r1]);
            mv(1, x, &mut k[r0..r1]);
            mv(2, x, &mut v[r0..r1]);
            let cache = &mut self.caches[li * bsz + b];
            cache.append(&k[r0..r1], &v[r0..r1]);
            cache.attend(&q[r0..r1], &mut att[r0..r1]);
            mv(3, &att[r0..r1], &mut buf_d[r0..r1]);
            for (xv, bv) in x.iter_mut().zip(&buf_d[r0..r1]) {
                *xv += 0.1 * bv.tanh();
            }
            mv(4, x, &mut buf_f[f0..f1]);
            mv(5, x, &mut up[f0..f1]);
            for (g, u) in buf_f[f0..f1].iter_mut().zip(&up[f0..f1]) {
                *g = (*g / (1.0 + (-*g).exp())) * u;
            }
            mv(6, &buf_f[f0..f1], &mut buf_d[r0..r1]);
            for (xv, bv) in x.iter_mut().zip(&buf_d[r0..r1]) {
                *xv = 0.9 * *xv + 0.1 * bv.tanh();
            }
        }
        if let (Some(t0), Some(p)) = (t0, self.quant_precision) {
            self.profile.record(Stage::Prefill, p, t0.elapsed().as_secs_f64() * 1e3);
        }
        // lint: end_region
    }

    /// Cache length (tokens) of one row's layer-0 cache.
    pub fn row_len(&self, b: usize) -> usize {
        self.caches[b].len()
    }

    /// Decode `n_tokens` tokens after pre-filling `prefill` cache entries
    /// (the paper assumes a 2000-token input); returns (tokens/sec,
    /// checksum).
    pub fn decode_throughput(&mut self, n_tokens: usize, seed: u64) -> (f64, f32) {
        self.decode_throughput_prefilled(n_tokens, 0, seed)
    }

    pub fn decode_throughput_prefilled(
        &mut self,
        n_tokens: usize,
        prefill: usize,
        seed: u64,
    ) -> (f64, f32) {
        assert_eq!(self.batch, 1, "throughput driver is single-sequence");
        self.reset();
        let mut rng = Rng::new(seed);
        let mut x: Vec<f32> = (0..self.cfg.d_model).map(|_| rng.normal() as f32 * 0.1).collect();
        if prefill > 0 {
            // fill caches without timing (prefill cost is a separate
            // phase in the paper's table 2)
            let d = self.cfg.d_model;
            for _ in 0..prefill {
                let k: Vec<f32> = (0..d).map(|_| rng.normal() as f32 * 0.3).collect();
                let v: Vec<f32> = (0..d).map(|_| rng.normal() as f32 * 0.3).collect();
                for c in &mut self.caches {
                    c.append(&k, &v);
                }
            }
        }
        let start = std::time::Instant::now();
        let mut checksum = 0.0f32;
        for _ in 0..n_tokens {
            checksum += self.decode_step(&mut x);
        }
        let secs = start.elapsed().as_secs_f64();
        (n_tokens as f64 / secs, checksum)
    }

    /// Measured KV-cache bytes currently held (all rows).
    pub fn cache_bytes(&self) -> usize {
        self.caches.iter().map(|c| c.bytes()).sum()
    }

    /// Weight memory in bytes for the current format.
    pub fn weight_bytes(&self) -> usize {
        let layer_bytes = |lw: &LayerWeights| -> usize {
            match lw {
                LayerWeights::Dense { proj } => proj.iter().map(|p| p.bytes_f16()).sum(),
                LayerWeights::Quant { proj } => proj.iter().map(|p| p.packed_bytes()).sum(),
            }
        };
        self.layers.iter().map(layer_bytes).sum::<usize>() + layer_bytes(&self.head)
    }

    /// Total memory report (weights + KV cache), paper table-2 style.
    /// FP16 baseline KV cache is fp16; SEFP runs bill the cache with the
    /// SAME packed-bits formula as `KvCache::bytes()` at the precision
    /// the caches are actually built at (`min(m, 7)`, 5-bit group
    /// exponents) — config-based and measured accounting agree.  Every
    /// batch row owns independent caches, so the per-sequence KV
    /// footprint is billed once per row (matching what `cache_bytes()`
    /// measures on a batched sim).
    pub fn memory_bytes(&self) -> usize {
        let kv_per_row = match self.quant_precision {
            None => self.cfg.kv_cache_bytes(2),
            Some(p) => self.cfg.kv_cache_packed_bytes(kv_precision(p)),
        };
        self.weight_bytes() + kv_per_row * self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> SimConfig {
        SimConfig { d_model: 128, d_ff: 256, n_layers: 2, vocab: 320, context: 100 }
    }

    #[test]
    fn decode_runs_and_is_finite() {
        let mut sim = DecoderSim::new(small(), DecoderWeights::Sefp(Precision::of(4)), 1);
        let mut x = vec![0.1f32; 128];
        for _ in 0..5 {
            let c = sim.decode_step(&mut x);
            assert!(c.is_finite());
        }
        assert!(x.iter().all(|v| v.is_finite()));
        assert_eq!(sim.caches[0].len(), 5);
        assert!(sim.cache_bytes() > 0);
    }

    #[test]
    fn decode_step_token_is_greedy_and_in_vocab() {
        let mut a = DecoderSim::new(small(), DecoderWeights::Sefp(Precision::of(4)), 1);
        let mut b = DecoderSim::new(small(), DecoderWeights::Sefp(Precision::of(4)), 1);
        let mut xa = vec![0.1f32; 128];
        let mut xb = vec![0.1f32; 128];
        for _ in 0..3 {
            let (ca, ta) = a.decode_step_token(&mut xa);
            let (cb, tb) = b.decode_step_token(&mut xb);
            assert!(ca.is_finite());
            assert_eq!(ca, cb, "same weights+input, same checksum");
            assert_eq!(ta, tb, "greedy decode is deterministic");
            assert!((0..320).contains(&ta));
        }
    }

    #[test]
    fn reset_clears_caches() {
        let mut sim = DecoderSim::new(small(), DecoderWeights::Dense, 1);
        let mut x = vec![0.1f32; 128];
        let _ = sim.decode_step(&mut x);
        assert_eq!(sim.caches[0].len(), 1);
        sim.reset();
        assert_eq!(sim.caches[0].len(), 0);
    }

    #[test]
    fn reset_row_is_independent() {
        let cfg = small();
        let mut sim =
            DecoderSim::new_batched(cfg, DecoderWeights::Sefp(Precision::of(4)), 1, 3);
        let mut x = vec![0.1f32; 3 * 128];
        for _ in 0..4 {
            let _ = sim.decode_batch_step(&mut x);
        }
        for b in 0..3 {
            assert_eq!(sim.row_len(b), 4);
        }
        sim.reset_row(1);
        // every layer of row 1 is cleared; rows 0 and 2 keep their caches
        for li in 0..cfg.n_layers {
            assert_eq!(sim.caches[li * 3 + 1].len(), 0, "layer {li} row 1");
            assert_eq!(sim.caches[li * 3].len(), 4, "layer {li} row 0");
            assert_eq!(sim.caches[li * 3 + 2].len(), 4, "layer {li} row 2");
        }
    }

    #[test]
    fn masked_rows_do_not_grow_caches() {
        let mut sim =
            DecoderSim::new_batched(small(), DecoderWeights::Sefp(Precision::of(4)), 1, 2);
        let mut x = vec![0.1f32; 2 * 128];
        let _ = sim.decode_batch_step_masked(&mut x, &[true, false]);
        assert_eq!(sim.row_len(0), 1);
        assert_eq!(sim.row_len(1), 0);
    }

    #[test]
    fn quant_uses_less_memory() {
        let d = DecoderSim::new(small(), DecoderWeights::Dense, 1);
        let q = DecoderSim::new(small(), DecoderWeights::Sefp(Precision::of(4)), 1);
        assert!(q.weight_bytes() * 2 < d.weight_bytes());
        assert!(q.memory_bytes() < d.memory_bytes());
    }

    #[test]
    fn memory_reduction_near_paper_band() {
        // E5M4 vs FP16 weights+KV: expect ~68-69% reduction
        let d = DecoderSim::new(small(), DecoderWeights::Dense, 1);
        let q = DecoderSim::new(small(), DecoderWeights::Sefp(Precision::of(4)), 1);
        let red = 1.0 - q.memory_bytes() as f64 / d.memory_bytes() as f64;
        assert!((0.6..0.75).contains(&red), "reduction={red}");
    }

    #[test]
    fn config_kv_accounting_matches_measured_cache_bytes() {
        // fill the caches to exactly cfg.context tokens and compare the
        // config-based packed formula with the measured per-cache sum:
        // only div_ceil placement may differ (config rounds once,
        // measurement rounds per cache), so the two are pinned within
        // one byte per cache — far less than one group
        for m in [8u8, 4, 3] {
            let cfg = small();
            let mut sim = DecoderSim::new(cfg, DecoderWeights::Sefp(Precision::of(m)), 2);
            let mut x = vec![0.1f32; 128];
            for _ in 0..cfg.context {
                let _ = sim.decode_step(&mut x);
            }
            let measured = sim.cache_bytes();
            let config = cfg.kv_cache_packed_bytes(kv_precision(Precision::of(m)));
            let diff = measured.abs_diff(config);
            assert!(
                diff <= cfg.n_layers,
                "m={m}: measured {measured} vs config {config} (diff {diff})"
            );
            // and the config formula is what memory_bytes bills
            assert_eq!(sim.memory_bytes(), sim.weight_bytes() + config);
        }
        // a batched sim bills the per-row KV footprint once PER ROW —
        // matching the measured sum over all n_layers * batch caches
        let cfg = small();
        let mut sim =
            DecoderSim::new_batched(cfg, DecoderWeights::Sefp(Precision::of(4)), 2, 2);
        let mut x = vec![0.1f32; 2 * 128];
        for _ in 0..cfg.context {
            let _ = sim.decode_batch_step(&mut x);
        }
        let measured = sim.cache_bytes();
        let config = 2 * cfg.kv_cache_packed_bytes(kv_precision(Precision::of(4)));
        assert!(
            measured.abs_diff(config) <= 2 * cfg.n_layers,
            "batched: measured {measured} vs config {config}"
        );
        assert_eq!(sim.memory_bytes(), sim.weight_bytes() + config);
    }

    #[test]
    fn llama8b_scaled_is_group_aligned_for_every_scale() {
        // non-power-of-two scales used to yield unaligned dims and trip
        // the group-size asserts at construction; every scale must now
        // produce a constructible config
        for s in 1..=32usize {
            let cfg = SimConfig::llama8b_scaled(s);
            assert_eq!(cfg.d_model % KV_GROUP, 0, "scale {s}: d_model {}", cfg.d_model);
            assert_eq!(cfg.d_ff % KV_GROUP, 0, "scale {s}: d_ff {}", cfg.d_ff);
            assert!(cfg.d_model >= KV_GROUP, "scale {s}");
            assert!(cfg.d_ff >= KV_GROUP, "scale {s}");
            assert!(cfg.n_layers >= 1, "scale {s}");
            assert!(cfg.vocab >= KV_GROUP, "scale {s}");
        }
        // power-of-two scales divide exactly — the original shapes are
        // preserved where they were already aligned
        assert_eq!(SimConfig::llama8b_scaled(16).d_model, 256);
        assert_eq!(SimConfig::llama8b_scaled(16).d_ff, 896);
    }

    #[test]
    fn llama8b_scaled_constructs_and_decodes_at_every_rung() {
        // regression for the latent panic: build the sim and decode a
        // step at ladder rungs for a sweep of non-power-of-two scales
        // (kept to the larger scales so the test stays fast; the config
        // arithmetic for ALL 1..=32 is covered above)
        for (s, rungs) in [
            (16usize, &[4u8][..]),
            (23, &[8, 3][..]),
            (29, &[8, 3][..]),
            (32, &[8, 7, 6, 5, 4, 3][..]),
        ] {
            let cfg = SimConfig::llama8b_scaled(s);
            for &m in rungs {
                let mut sim =
                    DecoderSim::new(cfg, DecoderWeights::Sefp(Precision::of(m)), 3);
                let mut x = vec![0.1f32; cfg.d_model];
                let c = sim.decode_step(&mut x);
                assert!(c.is_finite(), "scale {s} m={m}");
            }
        }
    }

    #[test]
    fn n_weights_counts() {
        let c = small();
        assert_eq!(
            c.n_weights(),
            2 * (4 * 128 * 128 + 3 * 128 * 256) + 128 * 320
        );
    }
}
