//! Token sampling over a logits row — shared by the serving generation
//! loop and the decode simulator.
//!
//! Greedy argmax is NaN-tolerant (NaN never wins) and deterministic:
//! the FIRST maximal index is chosen, so equal logits cannot reorder
//! between runs.  Temperature sampling draws from the softmax of
//! `logits / temperature` with the caller's deterministic [`Rng`].

use crate::data::Rng;

/// Index of the first maximal finite logit (0 if the row is all-NaN).
pub fn argmax(logits: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best
}

/// Sample an index: greedy when `temperature <= 0`, otherwise softmax
/// temperature sampling.
pub fn sample(logits: &[f32], temperature: f32, rng: &mut Rng) -> usize {
    if temperature <= 0.0 || logits.len() <= 1 {
        return argmax(logits);
    }
    // max-shifted softmax for numerical stability; non-finite logits
    // (NaN from a broken backend) carry zero weight instead of
    // poisoning the cumulative scan
    let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    if !max.is_finite() {
        return argmax(logits);
    }
    let weights: Vec<f64> = logits
        .iter()
        .map(|&v| {
            let w = (((v - max) / temperature) as f64).exp();
            if w.is_finite() {
                w
            } else {
                0.0
            }
        })
        .collect();
    let total: f64 = weights.iter().sum();
    if !(total > 0.0) || !total.is_finite() {
        return argmax(logits);
    }
    let mut target = rng.f64() * total;
    for (i, w) in weights.iter().enumerate() {
        target -= w;
        if target <= 0.0 {
            return i;
        }
    }
    weights.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_first_maximum() {
        assert_eq!(argmax(&[0.1, 0.9, 0.9, 0.2]), 1);
        assert_eq!(argmax(&[f32::NAN, 1.0, 2.0]), 2);
        assert_eq!(argmax(&[f32::NAN]), 0);
    }

    #[test]
    fn zero_temperature_is_greedy() {
        let mut rng = Rng::new(1);
        assert_eq!(sample(&[0.0, 3.0, 1.0], 0.0, &mut rng), 1);
    }

    #[test]
    fn temperature_sampling_is_seeded_and_covers_support() {
        let logits = [1.0f32, 1.0, 1.0, 1.0];
        let draw = |seed: u64| {
            let mut rng = Rng::new(seed);
            (0..64).map(|_| sample(&logits, 1.0, &mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(draw(7), draw(7), "same seed, same stream");
        let seen: std::collections::BTreeSet<usize> = draw(7).into_iter().collect();
        assert!(seen.len() > 1, "uniform logits must hit several tokens");
    }

    #[test]
    fn temperature_sampling_tolerates_nan_logits() {
        // a NaN logit must carry zero weight, never be emitted, and
        // never poison the cumulative scan into the last index
        let logits = [1.0f32, 5.0, f32::NAN, 0.0];
        let mut rng = Rng::new(11);
        for _ in 0..64 {
            let i = sample(&logits, 1.0, &mut rng);
            assert_ne!(i, 2, "NaN token sampled");
        }
        // all-NaN row degrades to the greedy fallback
        let mut rng = Rng::new(12);
        assert_eq!(sample(&[f32::NAN, f32::NAN], 1.0, &mut rng), 0);
    }

    #[test]
    fn low_temperature_concentrates_on_argmax() {
        let logits = [0.0f32, 10.0, 0.0];
        let mut rng = Rng::new(3);
        for _ in 0..32 {
            assert_eq!(sample(&logits, 0.05, &mut rng), 1);
        }
    }
}
