//! Pure-rust packed-SEFP inference path — the measured substrate for the
//! paper's table 2 (memory + decoding throughput, FP16 vs SEFP).
//!
//! The mechanism behind SEFP's speedup is bandwidth: a weight costs
//! (1+m) bits + 5/64 shared-exponent bits instead of 16.  The group
//! structure additionally lets the inner loop run integer
//! multiply-accumulate with ONE scale multiply per 64-element group
//! instead of a per-element scale:
//!
//! ```text
//! y[b][n] += step_g * Σ_{k∈g} x[b][k] · sig[k]
//! ```
//!
//! `QuantLinear` stores significands contiguously per output column
//! (groups along the reduction axis, same layout as the Pallas fused
//! kernel) in i8 (m ≤ 7) or i16 (m = 8).
//!
//! Two kernel shapes share that storage:
//!
//! * [`QuantLinear::matvec`] — one activation row, the single-sequence
//!   decode step.
//! * [`QuantLinear::matmul`] — a `(B × in_dim)` activation block.  Each
//!   weight column (and its per-group steps) is streamed from memory
//!   once and reused across all B rows while it is cache-hot, which
//!   amortizes the weight bandwidth that dominates SEFP decode — this is
//!   what makes the batched decode engine ([`DecoderSim`] batch mode,
//!   `serve::DecoderBackend`) beat B sequential `matvec` loops.
//!   Columns are split across `threads` scoped worker threads
//!   (`std::thread::scope`, no pool, no allocation); every output
//!   element is a pure per-column function of the inputs, so results are
//!   bit-identical to the per-row `matvec` and independent of the worker
//!   count.

pub mod decoder;
pub mod kv_cache;
pub mod sampling;

pub use decoder::{proj_dims, DecoderSim, DecoderWeights, SimConfig, KV_GROUP};
pub use kv_cache::KvCache;

use crate::sefp::{Precision, SefpSpec, SefpTensor};

/// f32 dense layer (the FP16-class baseline; f32 here, fp16 bytes are
/// reported separately for the paper-comparable memory table).
#[derive(Debug, Clone)]
pub struct DenseLinear {
    pub in_dim: usize,
    pub out_dim: usize,
    /// column-major: w[k + n*in_dim] = W[k][n]
    pub w: Vec<f32>,
}

impl DenseLinear {
    pub fn new(in_dim: usize, out_dim: usize, w: Vec<f32>) -> Self {
        assert_eq!(w.len(), in_dim * out_dim);
        DenseLinear { in_dim, out_dim, w }
    }

    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.in_dim);
        debug_assert_eq!(y.len(), self.out_dim);
        for n in 0..self.out_dim {
            let col = &self.w[n * self.in_dim..(n + 1) * self.in_dim];
            y[n] = dot_f32(x, col);
        }
    }

    /// Blocked batched matvec: `x` is a row-major `(batch × in_dim)`
    /// activation block, `y` the row-major `(batch × out_dim)` output.
    /// Each weight column is streamed once per `ROW_BLOCK` rows and
    /// columns are split across `threads` scoped workers; every output
    /// element equals the corresponding [`matvec`](Self::matvec) result
    /// bit-for-bit, independent of `threads`.
    pub fn matmul(&self, x: &[f32], batch: usize, y: &mut [f32], threads: usize) {
        // lint: region(no_alloc)
        // hard asserts (not debug): the workers write y through a raw
        // pointer, so a mis-sized buffer must panic, never write OOB
        assert_eq!(x.len(), batch * self.in_dim, "matmul: x is not batch x in_dim");
        assert_eq!(y.len(), batch * self.out_dim, "matmul: y is not batch x out_dim");
        let (in_dim, out_dim) = (self.in_dim, self.out_dim);
        let yp = ColOut(y.as_mut_ptr());
        par_columns(out_dim, threads, |cols| {
            for n in cols {
                let col = &self.w[n * in_dim..(n + 1) * in_dim];
                let mut b0 = 0;
                while b0 < batch {
                    let bl = (batch - b0).min(ROW_BLOCK);
                    let mut acc = [0.0f32; ROW_BLOCK];
                    for (bi, a) in acc.iter_mut().take(bl).enumerate() {
                        let row = &x[(b0 + bi) * in_dim..(b0 + bi + 1) * in_dim];
                        *a = dot_f32(row, col);
                    }
                    for (bi, &a) in acc.iter().take(bl).enumerate() {
                        // SAFETY: see `ColOut` — (b0+bi, n) is written by
                        // exactly one worker, and the scope outlives us
                        unsafe { yp.write((b0 + bi) * out_dim + n, a) };
                    }
                    b0 += bl;
                }
            }
        });
        // lint: end_region
    }

    pub fn bytes_f32(&self) -> usize {
        self.w.len() * 4
    }

    pub fn bytes_f16(&self) -> usize {
        self.w.len() * 2
    }
}

/// Significand storage, width-dependent.
#[derive(Debug, Clone)]
enum Sigs {
    I8(Vec<i8>),
    I16(Vec<i16>),
}

/// SIMD-friendly dot products (§Perf iteration 2): 8 independent
/// accumulator LANES in a fixed array — LLVM turns the inner loop into
/// packed FMA (scalar reassociation is not allowed for float adds, so a
/// plain `acc +=` loop cannot vectorize; per-lane accumulators make the
/// reassociation explicit and legal).  Combined with target-cpu=native
/// this reaches within ~1.5x of the single-core bandwidth roofline.
const LANES: usize = 16;

// lint: region(no_alloc)
#[inline]
fn dot_i8(x: &[f32], s: &[i8]) -> f32 {
    debug_assert_eq!(x.len(), s.len());
    let mut acc = [0.0f32; LANES];
    let mut xc = x.chunks_exact(LANES);
    let mut sc = s.chunks_exact(LANES);
    for (xs, ss) in (&mut xc).zip(&mut sc) {
        for l in 0..LANES {
            acc[l] += xs[l] * ss[l] as f32;
        }
    }
    let mut total = acc.iter().sum::<f32>();
    for (xv, &sv) in xc.remainder().iter().zip(sc.remainder()) {
        total += xv * sv as f32;
    }
    total
}

#[inline]
fn dot_i16(x: &[f32], s: &[i16]) -> f32 {
    debug_assert_eq!(x.len(), s.len());
    let mut acc = [0.0f32; LANES];
    let mut xc = x.chunks_exact(LANES);
    let mut sc = s.chunks_exact(LANES);
    for (xs, ss) in (&mut xc).zip(&mut sc) {
        for l in 0..LANES {
            acc[l] += xs[l] * ss[l] as f32;
        }
    }
    let mut total = acc.iter().sum::<f32>();
    for (xv, &sv) in xc.remainder().iter().zip(sc.remainder()) {
        total += xv * sv as f32;
    }
    total
}

#[inline]
fn dot_f32(x: &[f32], w: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), w.len());
    let mut acc = [0.0f32; LANES];
    let mut xc = x.chunks_exact(LANES);
    let mut wc = w.chunks_exact(LANES);
    for (xs, ws) in (&mut xc).zip(&mut wc) {
        for l in 0..LANES {
            acc[l] += xs[l] * ws[l];
        }
    }
    let mut total = acc.iter().sum::<f32>();
    for (xv, &wv) in xc.remainder().iter().zip(wc.remainder()) {
        total += xv * wv;
    }
    total
}
// lint: end_region

/// Rows of the activation block accumulated together per column visit:
/// the column chunk stays in registers/L1 while each of these rows dots
/// against it, so the weight stream is read once per `ROW_BLOCK` rows.
const ROW_BLOCK: usize = 8;

/// Output pointer shared across the scoped column workers of `matmul`.
///
/// SAFETY contract (upheld by `par_columns` callers): workers receive
/// disjoint column ranges and write only `y[b * out_dim + n]` for `n` in
/// their own range, so no two threads ever touch the same element, and
/// the scope joins all workers before `y` is observable again.  Writes
/// go through [`write`](ColOut::write) so closures capture the `Sync`
/// wrapper, never the bare (non-`Sync`) raw pointer field.
struct ColOut(*mut f32);
// SAFETY: the wrapper is only shared across `par_columns` workers that
// write disjoint elements (contract above), so concurrent `&ColOut`
// access never races.
unsafe impl Sync for ColOut {}

impl ColOut {
    /// SAFETY: `idx` must be in bounds of the output slice and written
    /// by exactly one worker (see the type docs).
    #[inline]
    unsafe fn write(&self, idx: usize, v: f32) {
        // SAFETY: caller upholds in-bounds `idx` and single-writer
        // disjointness (function contract above)
        unsafe { *self.0.add(idx) = v };
    }
}

/// Run `work` over `0..out_dim` split into at most `threads` contiguous
/// column ranges on scoped threads (serial when one range suffices).
/// `work` must be deterministic per column for the thread-count
/// independence contract of the batched kernels.
fn par_columns<F: Fn(std::ops::Range<usize>) + Sync>(out_dim: usize, threads: usize, work: F) {
    let threads = threads.clamp(1, out_dim.max(1));
    if threads == 1 {
        work(0..out_dim);
        return;
    }
    let chunk = out_dim.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 1..threads {
            let lo = t * chunk;
            if lo >= out_dim {
                break;
            }
            let hi = ((t + 1) * chunk).min(out_dim);
            let work = &work;
            s.spawn(move || work(lo..hi));
        }
        // the calling thread takes the first range instead of idling
        work(0..chunk.min(out_dim));
    });
}

/// SEFP-quantized linear layer with dequant-on-the-fly matvec.
#[derive(Debug, Clone)]
pub struct QuantLinear {
    pub in_dim: usize,
    pub out_dim: usize,
    pub precision: Precision,
    pub group_size: usize,
    /// one step (= 2^(E-m+1)) per (column, group)
    steps: Vec<f32>,
    sigs: Sigs,
    groups_per_col: usize,
    /// exact packed footprint in bytes (for the memory table)
    packed_bytes: usize,
}

impl QuantLinear {
    /// Quantize a column-major f32 weight matrix under `spec`; groups run
    /// along the input (reduction) axis of each column.
    pub fn from_dense(dense: &DenseLinear, spec: &SefpSpec) -> Self {
        assert_eq!(dense.in_dim % spec.group_size, 0, "in_dim must be group-aligned");
        let groups_per_col = dense.in_dim / spec.group_size;
        let mut steps = Vec::with_capacity(dense.out_dim * groups_per_col);
        let mut sig16: Vec<i16> = Vec::with_capacity(dense.w.len());
        let mut packed_bits = 0usize;
        let m = spec.precision.m();
        for n in 0..dense.out_dim {
            let col = &dense.w[n * dense.in_dim..(n + 1) * dense.in_dim];
            let t = SefpTensor::encode(col, spec);
            for g in 0..groups_per_col {
                steps.push(crate::sefp::step_for(t.exponents[g] as i32, m));
            }
            sig16.extend_from_slice(&t.significands);
            packed_bits += t.ideal_bits();
        }
        Self::from_parts(
            dense.in_dim,
            dense.out_dim,
            spec.precision,
            spec.group_size,
            steps,
            sig16,
            packed_bits,
        )
    }

    /// Build directly from an already-encoded SEFP tensor — the
    /// SEFP-native consumption path for `serve::PrecisionLadder` views:
    /// significands and exponents are reused as-is (integer copies +
    /// step-table lookups), the original f32 weights are never touched.
    ///
    /// `t` must hold the column-major weights of an `(in_dim, out_dim)`
    /// matrix with `in_dim` a multiple of the group size, so every group
    /// lies inside one column and per-column grouping coincides with the
    /// flat encode.
    pub fn from_sefp(t: &SefpTensor, in_dim: usize, out_dim: usize) -> Self {
        assert_eq!(t.len, in_dim * out_dim, "tensor length must match matrix shape");
        assert_eq!(in_dim % t.group_size, 0, "in_dim must be group-aligned");
        let m = t.precision.m();
        let steps = t
            .exponents
            .iter()
            .map(|&e| crate::sefp::step_for(e as i32, m))
            .collect();
        Self::from_parts(
            in_dim,
            out_dim,
            t.precision,
            t.group_size,
            steps,
            t.significands.clone(),
            t.ideal_bits(),
        )
    }

    fn from_parts(
        in_dim: usize,
        out_dim: usize,
        precision: Precision,
        group_size: usize,
        steps: Vec<f32>,
        sig16: Vec<i16>,
        packed_bits: usize,
    ) -> Self {
        let sigs = if precision.m() <= 7 {
            Sigs::I8(sig16.iter().map(|&s| s as i8).collect())
        } else {
            Sigs::I16(sig16)
        };
        QuantLinear {
            in_dim,
            out_dim,
            precision,
            group_size,
            steps,
            sigs,
            groups_per_col: in_dim / group_size,
            packed_bytes: packed_bits.div_ceil(8),
        }
    }

    /// Dequant-on-the-fly matvec: integer significands stream through the
    /// inner loop, one scale multiply per group.
    pub fn matvec(&self, x: &[f32], y: &mut [f32]) {
        // lint: region(no_alloc)
        debug_assert_eq!(x.len(), self.in_dim);
        debug_assert_eq!(y.len(), self.out_dim);
        let gs = self.group_size;
        match &self.sigs {
            Sigs::I8(sigs) => {
                for n in 0..self.out_dim {
                    let col = &sigs[n * self.in_dim..(n + 1) * self.in_dim];
                    let col_steps = &self.steps[n * self.groups_per_col..];
                    let mut acc = 0.0f32;
                    for (g, chunk) in col.chunks_exact(gs).enumerate() {
                        let xs = &x[g * gs..(g + 1) * gs];
                        acc += dot_i8(xs, chunk) * col_steps[g];
                    }
                    y[n] = acc;
                }
            }
            Sigs::I16(sigs) => {
                for n in 0..self.out_dim {
                    let col = &sigs[n * self.in_dim..(n + 1) * self.in_dim];
                    let col_steps = &self.steps[n * self.groups_per_col..];
                    let mut acc = 0.0f32;
                    for (g, chunk) in col.chunks_exact(gs).enumerate() {
                        let xs = &x[g * gs..(g + 1) * gs];
                        acc += dot_i16(xs, chunk) * col_steps[g];
                    }
                    y[n] = acc;
                }
            }
        }
        // lint: end_region
    }

    /// Blocked batched matvec over a row-major `(batch × in_dim)`
    /// activation block into row-major `(batch × out_dim)` `y`.
    ///
    /// The bandwidth-amortizing shape of SEFP decode: each quantized
    /// column and its per-group steps are streamed from memory ONCE and
    /// dotted against up to `ROW_BLOCK` activation rows while
    /// cache-hot, instead of being re-read for every sequence as a
    /// `matvec` loop would.  Columns split across `threads` scoped
    /// workers; per-element math is identical to
    /// [`matvec`](Self::matvec) (same group order, same accumulation
    /// order), so the output is bit-for-bit equal to B independent
    /// matvecs and independent of the worker count.
    pub fn matmul(&self, x: &[f32], batch: usize, y: &mut [f32], threads: usize) {
        // lint: region(no_alloc)
        // hard asserts (not debug): the workers write y through a raw
        // pointer, so a mis-sized buffer must panic, never write OOB
        assert_eq!(x.len(), batch * self.in_dim, "matmul: x is not batch x in_dim");
        assert_eq!(y.len(), batch * self.out_dim, "matmul: y is not batch x out_dim");
        let (in_dim, out_dim, gs) = (self.in_dim, self.out_dim, self.group_size);
        let gpc = self.groups_per_col;
        let yp = ColOut(y.as_mut_ptr());
        match &self.sigs {
            Sigs::I8(sigs) => par_columns(out_dim, threads, |cols| {
                for n in cols {
                    let col = &sigs[n * in_dim..(n + 1) * in_dim];
                    let col_steps = &self.steps[n * gpc..(n + 1) * gpc];
                    let mut b0 = 0;
                    while b0 < batch {
                        let bl = (batch - b0).min(ROW_BLOCK);
                        let mut acc = [0.0f32; ROW_BLOCK];
                        for (g, chunk) in col.chunks_exact(gs).enumerate() {
                            let step = col_steps[g];
                            for (bi, a) in acc.iter_mut().take(bl).enumerate() {
                                let xs = &x[(b0 + bi) * in_dim + g * gs
                                    ..(b0 + bi) * in_dim + (g + 1) * gs];
                                *a += dot_i8(xs, chunk) * step;
                            }
                        }
                        for (bi, &a) in acc.iter().take(bl).enumerate() {
                            // SAFETY: see `ColOut` — disjoint columns per
                            // worker, scope joins before `y` is read
                            unsafe { yp.write((b0 + bi) * out_dim + n, a) };
                        }
                        b0 += bl;
                    }
                }
            }),
            Sigs::I16(sigs) => par_columns(out_dim, threads, |cols| {
                for n in cols {
                    let col = &sigs[n * in_dim..(n + 1) * in_dim];
                    let col_steps = &self.steps[n * gpc..(n + 1) * gpc];
                    let mut b0 = 0;
                    while b0 < batch {
                        let bl = (batch - b0).min(ROW_BLOCK);
                        let mut acc = [0.0f32; ROW_BLOCK];
                        for (g, chunk) in col.chunks_exact(gs).enumerate() {
                            let step = col_steps[g];
                            for (bi, a) in acc.iter_mut().take(bl).enumerate() {
                                let xs = &x[(b0 + bi) * in_dim + g * gs
                                    ..(b0 + bi) * in_dim + (g + 1) * gs];
                                *a += dot_i16(xs, chunk) * step;
                            }
                        }
                        for (bi, &a) in acc.iter().take(bl).enumerate() {
                            // SAFETY: see `ColOut` — disjoint columns per
                            // worker, scope joins before `y` is read
                            unsafe { yp.write((b0 + bi) * out_dim + n, a) };
                        }
                        b0 += bl;
                    }
                }
            }),
        }
        // lint: end_region
    }

    /// Dequantize ONE output column (`in_dim` values) into `out` — the
    /// tied-embedding lookup path: token embeddings read the very same
    /// quantized storage the LM-head matmul computes with (identical
    /// per-group steps), so no separate f32 embedding table and no
    /// second copy of the tensor ever exists.
    pub fn decode_column(&self, n: usize, out: &mut [f32]) {
        // lint: region(no_alloc)
        assert!(n < self.out_dim, "column {n} out of range for {}", self.out_dim);
        assert_eq!(out.len(), self.in_dim, "decode_column: out is not in_dim long");
        let gs = self.group_size;
        let col_steps = &self.steps[n * self.groups_per_col..(n + 1) * self.groups_per_col];
        match &self.sigs {
            Sigs::I8(sigs) => {
                let col = &sigs[n * self.in_dim..(n + 1) * self.in_dim];
                for (g, chunk) in col.chunks_exact(gs).enumerate() {
                    let step = col_steps[g];
                    for (o, &s) in out[g * gs..(g + 1) * gs].iter_mut().zip(chunk) {
                        *o = s as f32 * step;
                    }
                }
            }
            Sigs::I16(sigs) => {
                let col = &sigs[n * self.in_dim..(n + 1) * self.in_dim];
                for (g, chunk) in col.chunks_exact(gs).enumerate() {
                    let step = col_steps[g];
                    for (o, &s) in out[g * gs..(g + 1) * gs].iter_mut().zip(chunk) {
                        *o = s as f32 * step;
                    }
                }
            }
        }
        // lint: end_region
    }

    /// Working-set bytes actually touched per matvec (what bounds CPU
    /// decode throughput): significand storage + steps.
    pub fn working_bytes(&self) -> usize {
        let sig_bytes = match &self.sigs {
            Sigs::I8(v) => v.len(),
            Sigs::I16(v) => v.len() * 2,
        };
        sig_bytes + self.steps.len() * 4
    }

    /// Ideal packed storage (paper's memory accounting).
    pub fn packed_bytes(&self) -> usize {
        self.packed_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::sefp::quant_dequant;

    fn dense(in_dim: usize, out_dim: usize, seed: u64) -> DenseLinear {
        let mut rng = Rng::new(seed);
        let w: Vec<f32> = (0..in_dim * out_dim).map(|_| rng.normal() as f32 * 0.1).collect();
        DenseLinear::new(in_dim, out_dim, w)
    }

    #[test]
    fn quant_matvec_matches_dequantized_dense() {
        let d = dense(128, 32, 1);
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..128).map(|_| rng.normal() as f32).collect();
        for p in Precision::LADDER {
            let spec = SefpSpec::new(p);
            let q = QuantLinear::from_dense(&d, &spec);
            // reference: dense matvec over explicitly dequantized columns
            let mut wq = Vec::with_capacity(d.w.len());
            for n in 0..d.out_dim {
                let col = &d.w[n * d.in_dim..(n + 1) * d.in_dim];
                wq.extend(quant_dequant(col, &spec));
            }
            let dref = DenseLinear::new(d.in_dim, d.out_dim, wq);
            let mut ya = vec![0.0; 32];
            let mut yb = vec![0.0; 32];
            q.matvec(&x, &mut ya);
            dref.matvec(&x, &mut yb);
            for (a, b) in ya.iter().zip(&yb) {
                assert!((a - b).abs() <= 1e-4 * b.abs().max(1.0), "{p} {a} vs {b}");
            }
        }
    }

    #[test]
    fn from_sefp_matches_from_dense() {
        // the SEFP-native construction must produce the same layer as the
        // f32 path, at every ladder width — no float round trip needed
        let d = dense(128, 16, 9);
        let mut rng = Rng::new(10);
        let x: Vec<f32> = (0..128).map(|_| rng.normal() as f32).collect();
        for p in Precision::LADDER {
            let spec = SefpSpec::new(p);
            let t = SefpTensor::encode(&d.w, &spec);
            let a = QuantLinear::from_dense(&d, &spec);
            let b = QuantLinear::from_sefp(&t, d.in_dim, d.out_dim);
            assert_eq!(b.precision, p);
            assert_eq!(a.packed_bytes(), b.packed_bytes());
            let mut ya = vec![0.0; 16];
            let mut yb = vec![0.0; 16];
            a.matvec(&x, &mut ya);
            b.matvec(&x, &mut yb);
            assert_eq!(ya, yb, "{p}");
        }
    }

    #[test]
    fn memory_accounting() {
        let d = dense(256, 64, 3);
        let q4 = QuantLinear::from_dense(&d, &SefpSpec::new(Precision::of(4)));
        // packed: 5 bits/elem + 5 bits per 64-group
        let expect_bits = 256 * 64 * 5 + (256 / 64) * 64 * 5;
        assert_eq!(q4.packed_bytes(), expect_bits / 8);
        assert!(q4.packed_bytes() * 3 < d.bytes_f16());
        assert!(q4.working_bytes() < d.bytes_f32() / 2);
    }

    #[test]
    fn matmul_matches_per_row_matvec_bitwise() {
        // remainder rows on purpose: 5 is not a ROW_BLOCK multiple, and
        // 33 columns does not split evenly across 4 workers
        let (in_dim, out_dim, batch) = (128, 33, 5);
        let d = dense(in_dim, out_dim, 21);
        let mut rng = Rng::new(22);
        let x: Vec<f32> = (0..batch * in_dim).map(|_| rng.normal() as f32).collect();
        for p in Precision::LADDER {
            let q = QuantLinear::from_dense(&d, &SefpSpec::new(p));
            let mut y_ref = vec![0.0f32; batch * out_dim];
            for b in 0..batch {
                let y_row = &mut y_ref[b * out_dim..(b + 1) * out_dim];
                q.matvec(&x[b * in_dim..(b + 1) * in_dim], y_row);
            }
            for threads in [1, 2, 4] {
                let mut y = vec![f32::NAN; batch * out_dim];
                q.matmul(&x, batch, &mut y, threads);
                assert_eq!(y, y_ref, "{p} threads={threads}");
            }
        }
        // dense kernel obeys the same contract
        let mut y_ref = vec![0.0f32; batch * out_dim];
        for b in 0..batch {
            d.matvec(&x[b * in_dim..(b + 1) * in_dim], &mut y_ref[b * out_dim..(b + 1) * out_dim]);
        }
        for threads in [1, 3] {
            let mut y = vec![f32::NAN; batch * out_dim];
            d.matmul(&x, batch, &mut y, threads);
            assert_eq!(y, y_ref, "dense threads={threads}");
        }
    }

    #[test]
    fn matmul_handles_degenerate_shapes() {
        // batch 1 (the matvec case) and more workers than columns
        let d = dense(64, 2, 30);
        let q = QuantLinear::from_dense(&d, &SefpSpec::new(Precision::of(4)));
        let x: Vec<f32> = (0..64).map(|i| i as f32 * 0.01).collect();
        let mut y1 = vec![0.0f32; 2];
        let mut y2 = vec![0.0f32; 2];
        q.matvec(&x, &mut y1);
        q.matmul(&x, 1, &mut y2, 8);
        assert_eq!(y1, y2);
    }

    #[test]
    fn i16_path_for_m8() {
        let d = dense(64, 16, 5);
        let q8 = QuantLinear::from_dense(&d, &SefpSpec::new(Precision::of(8)));
        let mut rng = Rng::new(6);
        let x: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
        let mut y = vec![0.0; 16];
        q8.matvec(&x, &mut y);
        // m=8 error is tiny: compare against unquantized dense
        let mut yd = vec![0.0; 16];
        d.matvec(&x, &mut yd);
        for (a, b) in y.iter().zip(&yd) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }
}
