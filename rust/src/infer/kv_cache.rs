//! KV cache with optional SEFP quantization — the second half of the
//! paper's table-2 memory claim ("storage spaces for weights AND KV
//! cache").
//!
//! Decode-time attention reads the whole cache every token, so cache
//! bytes are decode bandwidth exactly like weight bytes.  SEFP applies
//! naturally: each appended K/V row is grouped along the head dimension
//! and stored as significands + shared exponents; attention dequantizes
//! on the fly with one step-multiply per group.

use crate::sefp::{quantize_value, shared_exponent, step_for, Precision, Rounding};

/// One layer's cache for one sequence (one batch row of the decode
/// engine; `DecoderSim` owns `n_layers × batch` of these).
pub enum KvCache {
    F32 {
        k: Vec<f32>,
        v: Vec<f32>,
        d: usize,
        /// attention-score scratch, reused across `attend` calls — the
        /// decode hot loop must not allocate per token (its capacity
        /// tracks the cache length; it is working state, not cache
        /// memory, and is excluded from `bytes()`)
        scores: Vec<f32>,
    },
    Sefp(SefpKv),
}

pub struct SefpKv {
    pub precision: Precision,
    pub group_size: usize,
    pub d: usize,
    k_sigs: Vec<i8>,
    v_sigs: Vec<i8>,
    k_steps: Vec<f32>,
    v_steps: Vec<f32>,
    /// reused attention-score scratch (see the `F32` variant)
    scores: Vec<f32>,
    pub len: usize,
}

impl KvCache {
    pub fn f32(d: usize) -> Self {
        KvCache::F32 { k: Vec::new(), v: Vec::new(), d, scores: Vec::new() }
    }

    pub fn sefp(d: usize, precision: Precision, group_size: usize) -> Self {
        assert!(precision.m() <= 7, "i8 storage");
        assert_eq!(d % group_size, 0, "head dim must be group-aligned");
        KvCache::Sefp(SefpKv {
            precision,
            group_size,
            d,
            k_sigs: Vec::new(),
            v_sigs: Vec::new(),
            k_steps: Vec::new(),
            v_steps: Vec::new(),
            scores: Vec::new(),
            len: 0,
        })
    }

    pub fn len(&self) -> usize {
        match self {
            KvCache::F32 { k, d, .. } => k.len() / d,
            KvCache::Sefp(c) => c.len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Append one position's K and V vectors (length d each).
    pub fn append(&mut self, k_row: &[f32], v_row: &[f32]) {
        match self {
            KvCache::F32 { k, v, d, .. } => {
                debug_assert_eq!(k_row.len(), *d);
                k.extend_from_slice(k_row);
                v.extend_from_slice(v_row);
            }
            KvCache::Sefp(c) => {
                c.push(k_row, v_row);
            }
        }
    }

    /// Attention for one query vector: softmax(q·K/√d)·V.  Takes `&mut
    /// self` only for the persistent score scratch — the cache contents
    /// are not modified.
    pub fn attend(&mut self, q: &[f32], out: &mut [f32]) {
        // lint: region(no_alloc)
        let t = self.len();
        if t == 0 {
            out.fill(0.0);
            return;
        }
        match self {
            KvCache::F32 { k, v, d, scores } => {
                let scale = (*d as f32).sqrt().recip();
                scores.clear();
                for ti in 0..t {
                    let row = &k[ti * *d..(ti + 1) * *d];
                    scores.push(super::dot_f32(q, row) * scale);
                }
                softmax(scores);
                out.fill(0.0);
                for (ti, &s) in scores.iter().enumerate() {
                    let row = &v[ti * *d..(ti + 1) * *d];
                    for (o, &x) in out.iter_mut().zip(row) {
                        *o += s * x;
                    }
                }
            }
            KvCache::Sefp(c) => c.attend(q, out),
        }
        // lint: end_region
    }

    /// Cache memory in bytes (packed accounting for SEFP).
    pub fn bytes(&self) -> usize {
        match self {
            KvCache::F32 { k, v, .. } => (k.len() + v.len()) * 4,
            KvCache::Sefp(c) => {
                let n = c.k_sigs.len() + c.v_sigs.len();
                let groups = c.k_steps.len() + c.v_steps.len();
                // packed: (1+m) bits per element + 5 bits per group
                (n * c.precision.bits_per_elem() + groups * 5).div_ceil(8)
            }
        }
    }

    /// FP16-equivalent bytes of the same cache contents.
    pub fn fp16_bytes(&self) -> usize {
        self.len() * 2 * 2 * self.d()
    }

    fn d(&self) -> usize {
        match self {
            KvCache::F32 { d, .. } => *d,
            KvCache::Sefp(c) => c.d,
        }
    }
}

impl SefpKv {
    fn push(&mut self, k_row: &[f32], v_row: &[f32]) {
        debug_assert_eq!(k_row.len(), self.d);
        for (row, sigs, steps) in [
            (k_row, &mut self.k_sigs, &mut self.k_steps),
            (v_row, &mut self.v_sigs, &mut self.v_steps),
        ] {
            let m = self.precision.m();
            for g in row.chunks(self.group_size) {
                let maxabs = g.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
                let e = shared_exponent(maxabs);
                let step = step_for(e, m);
                steps.push(step);
                for &x in g {
                    sigs.push(quantize_value(x, step, m, Rounding::Trunc) as i8);
                }
            }
        }
        self.len += 1;
    }

    fn attend(&mut self, q: &[f32], out: &mut [f32]) {
        // lint: region(no_alloc)
        let gs = self.group_size;
        let gpr = self.d / gs; // groups per row
        let scale = (self.d as f32).sqrt().recip();
        let scores = &mut self.scores;
        scores.clear();
        for ti in 0..self.len {
            let mut acc = 0.0f32;
            for g in 0..gpr {
                let off = (ti * gpr + g) * gs;
                let sig = &self.k_sigs[off..off + gs];
                let xs = &q[g * gs..(g + 1) * gs];
                acc += super::dot_i8(xs, sig) * self.k_steps[ti * gpr + g];
            }
            scores.push(acc * scale);
        }
        softmax(scores);
        out.fill(0.0);
        for (ti, &s) in scores.iter().enumerate() {
            for g in 0..gpr {
                let off = (ti * gpr + g) * gs;
                let step = s * self.v_steps[ti * gpr + g];
                let sig = &self.v_sigs[off..off + gs];
                let o = &mut out[g * gs..(g + 1) * gs];
                for (ov, &sv) in o.iter_mut().zip(sig) {
                    *ov += step * sv as f32;
                }
            }
        }
        // lint: end_region
    }
}

fn softmax(xs: &mut [f32]) {
    let max = xs.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    let inv = sum.recip();
    for x in xs.iter_mut() {
        *x *= inv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    fn rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (0..d).map(|_| rng.normal() as f32 * 0.3).collect()).collect()
    }

    #[test]
    fn f32_attend_is_convex_combination() {
        let d = 64;
        let mut cache = KvCache::f32(d);
        let ks = rows(5, d, 1);
        let vs = rows(5, d, 2);
        for (k, v) in ks.iter().zip(&vs) {
            cache.append(k, v);
        }
        let q = vec![0.0f32; d]; // uniform scores -> mean of V rows
        let mut out = vec![0.0f32; d];
        cache.attend(&q, &mut out);
        for j in 0..d {
            let mean: f32 = vs.iter().map(|v| v[j]).sum::<f32>() / 5.0;
            assert!((out[j] - mean).abs() < 1e-5);
        }
    }

    #[test]
    fn sefp_attend_close_to_f32() {
        let d = 64;
        let mut cf = KvCache::f32(d);
        let mut cq = KvCache::sefp(d, Precision::of(6), 64);
        let ks = rows(8, d, 3);
        let vs = rows(8, d, 4);
        for (k, v) in ks.iter().zip(&vs) {
            cf.append(k, v);
            cq.append(k, v);
        }
        let q: Vec<f32> = rows(1, d, 5).remove(0);
        let mut of = vec![0.0f32; d];
        let mut oq = vec![0.0f32; d];
        cf.attend(&q, &mut of);
        cq.attend(&q, &mut oq);
        let err: f32 = of.iter().zip(&oq).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max);
        assert!(err < 0.05, "max err {err}");
        // and error grows when m shrinks
        let mut c3 = KvCache::sefp(d, Precision::of(3), 64);
        for (k, v) in ks.iter().zip(&vs) {
            c3.append(k, v);
        }
        let mut o3 = vec![0.0f32; d];
        c3.attend(&q, &mut o3);
        let err3: f32 = of.iter().zip(&o3).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max);
        assert!(err3 > err * 0.9, "m3 {err3} vs m6 {err}");
    }

    #[test]
    fn attend_scratch_reuse_is_idempotent() {
        // the persistent score scratch must not leak state between
        // calls: same query, same cache -> bit-identical output, and a
        // shorter cache after reset never reads stale tail scores
        let d = 64;
        let mut c = KvCache::sefp(d, Precision::of(5), 64);
        for (k, v) in rows(6, d, 8).iter().zip(rows(6, d, 9).iter()) {
            c.append(k, v);
        }
        let q: Vec<f32> = rows(1, d, 10).remove(0);
        let mut a = vec![0.0f32; d];
        let mut b = vec![1.0f32; d];
        c.attend(&q, &mut a);
        c.attend(&q, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn memory_accounting() {
        let d = 128;
        let mut cf = KvCache::f32(d);
        let mut cq = KvCache::sefp(d, Precision::of(4), 64);
        for (k, v) in rows(10, d, 6).iter().zip(rows(10, d, 7).iter()) {
            cf.append(k, v);
            cq.append(k, v);
        }
        assert_eq!(cf.bytes(), 10 * 2 * d * 4);
        assert_eq!(cf.fp16_bytes(), 10 * 2 * d * 2);
        // E5M4: 5 bits/elem + 5 bits per 64-group ≈ 5.08 bits
        let expect_bits = 10 * 2 * (d * 5 + (d / 64) * 5);
        assert_eq!(cq.bytes(), expect_bits / 8);
        assert!(cq.bytes() * 3 < cq.fp16_bytes());
    }

    #[test]
    fn empty_cache_attend_zeroes() {
        let mut cache = KvCache::sefp(64, Precision::of(4), 64);
        let mut out = vec![1.0f32; 64];
        cache.attend(&vec![0.5; 64], &mut out);
        assert!(out.iter().all(|&v| v == 0.0));
    }
}
