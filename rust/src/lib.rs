//! OTARo — Once Tuning for All Precisions toward Robust On-Device LLMs.
//!
//! A full-stack reproduction of the AAAI 2026 paper: the SEFP numeric
//! format, the BPS/LAA fine-tuning coordinator (Algorithm 1), a
//! multi-precision serving runtime, and the paper's complete evaluation
//! harness — three layers:
//!
//!   * **L1** Pallas kernels (`python/compile/kernels/`) — SEFP
//!     quantize-dequantize + fused dequant-matmul, lowered into the HLO.
//!   * **L2** JAX model (`python/compile/model.py`) — transformer fwd/bwd
//!     with STE fake-quant at every bit-width, AOT-exported to HLO text.
//!   * **L3** this crate — loads the artifacts via PJRT and owns
//!     everything at runtime: BPS bit-width scheduling, LAA delayed
//!     updates, SGD, data, eval, serving, analysis. Python is never on
//!     the request path.

pub mod analysis;
pub mod artifact;
pub mod benchutil;
pub mod config;
pub mod experiments;
pub mod json;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod infer;
pub mod lint;
pub mod metrics;
pub mod obs;
pub mod policy;
pub mod runtime;
pub mod sefp;
pub mod serve;
pub mod workload;
