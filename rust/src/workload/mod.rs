//! Trace-driven load harness: seeded scenarios replayed through the real
//! serving stack, with per-scenario invariant assertions and a
//! machine-readable perf trajectory.
//!
//! The paper's deployment claim (fig. 1) is an *operational* one — one
//! stored model serving many precisions under real traffic — so the repo
//! needs a way to exercise the serve/policy planes under traffic shapes
//! that actually stress them, not just unit fixtures.  This module is
//! that harness:
//!
//! * [`scenario`] — the named scenario catalog: steady heterogeneous
//!   task-class mix, a diurnal arrival ramp, burst storms that overrun
//!   the admission queue, and an adversarial client pinning off-ladder
//!   precisions and malformed prompts.  Each scenario carries its own
//!   SLO/quality bounds ([`SloChecks`]).
//! * [`trace`]   — deterministic trace generation: a scenario + seed
//!   expands to the exact same request sequence on every run (seeded
//!   [`Rng`](crate::data::Rng), no wall clock), which is what makes the
//!   accounting invariants exactly assertable.
//! * [`replay`]  — the driver: builds a real [`Server`](crate::serve::Server)
//!   over [`DecoderBackend`](crate::serve::DecoderBackend) (actual SEFP
//!   logits, not a hash stub), submits the trace tick by tick, and
//!   cross-checks the obs registry against expectations computed from
//!   the trace alone — served/shed/invalid conservation, forced-clamp
//!   accounting, token totals, queue bounds, p95 SLOs, starvation and
//!   probe-agreement floors.
//! * [`traced`]  — the same stack with request-lifecycle tracing ON and
//!   a deterministic latency-injection plan
//!   ([`LatencyPlan`](crate::obs::inject::LatencyPlan)) over the real
//!   [`DecoderBackend`](crate::serve::DecoderBackend): byte-identical
//!   `otaro.trace.v1` snapshots, per-request waterfalls, and
//!   span-vs-registry cross-checks.  CLI: `otaro trace`.
//! * [`soak`]    — the long-horizon variant: a scenario's traffic shape
//!   stretched ~10x with mid-trace config flips (ladder budget re-cap,
//!   SLO tighten, policy toggle) and a
//!   [`FlightRecorder`](crate::obs::FlightRecorder) timeline that the
//!   drift invariants — bounded queues, residency stabilization, every
//!   flip visible as a frame-delta inflection, post-demote agreement
//!   recovery — are asserted over.  CLI: `otaro soak`.
//!
//! Every run emits one record per scenario into
//! `BENCH_serve_scenarios.json` (the shared `otaro.bench.v1` envelope
//! from [`benchutil`](crate::benchutil)).  Records split into a `det`
//! section that is byte-identical run to run and a `wall` section for
//! timing-dependent fields, so trend tooling can diff the deterministic
//! part exactly.
//!
//! CLI: `otaro loadgen [--scenario <name>] [--out FILE]`.

pub mod replay;
pub mod scenario;
pub mod soak;
pub mod trace;
pub mod traced;

pub use replay::{run_scenario, ReplayReport};
pub use scenario::{catalog, Kind, Scenario, SloChecks};
pub use soak::{run_soak, soak_catalog, soak_cli, Flip, FlipKind, SoakConfig, SoakReport};
pub use trace::{generate, TraceEvent};
pub use traced::{default_plan, run_traced, trace_cli, TracedReport};

use std::path::PathBuf;

use crate::json::Value;

/// `otaro loadgen` entry point: run one named scenario (or the whole
/// catalog), assert every per-scenario invariant, and write the bench
/// records (default `BENCH_serve_scenarios.json`).
pub fn run_cli(scenario: Option<String>, out: Option<PathBuf>) -> anyhow::Result<()> {
    let all = catalog();
    let selected: Vec<Scenario> = match &scenario {
        Some(name) => {
            let Some(sc) = all.iter().find(|s| s.name == name.as_str()).cloned() else {
                let known: Vec<&str> = all.iter().map(|s| s.name).collect();
                anyhow::bail!("unknown scenario {name:?}; known: {}", known.join(", "));
            };
            vec![sc]
        }
        None => all,
    };
    let mut records = Vec::new();
    for sc in &selected {
        println!("scenario {:<24} {}", sc.name, sc.description);
        let rep = run_scenario(sc)?;
        println!(
            "  served {} / shed {} / invalid {} / clamps {} — {} invariants held",
            rep.served,
            rep.shed,
            rep.invalid,
            rep.clamps,
            rep.checks.len()
        );
        records.push(rep.record);
    }
    let path = out.unwrap_or_else(|| PathBuf::from("BENCH_serve_scenarios.json"));
    crate::benchutil::write_bench_file(&path, "serve_scenarios", Value::Arr(records))?;
    println!("wrote {}", path.display());
    Ok(())
}
