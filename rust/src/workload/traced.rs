//! The traced replay driver behind `otaro trace`: a scenario through the
//! real serve/policy stack with per-request span tracing ON and a
//! deterministic latency-injection plan making the SLO loop fire.
//!
//! Differences from [`replay`](super::replay):
//!
//! * The backend is the replay driver's [`DecoderBackend`] — real SEFP
//!   logits off the same tiny decoder ladder — wrapped in an
//!   [`InjectedBackend`] adding *synthetic*, plan-declared latency.
//!   Injected steps sleep 40 ms against a 25 ms SLO while un-injected
//!   steps finish in a few milliseconds, so every latency sample
//!   classifies the same way on every run and the resulting
//!   `otaro.trace.v1` snapshot is **byte-identical** across runs of the
//!   same (scenario, seed, plan): logits are a pure function of the
//!   ladder bytes and the token window, and sampling draws from the
//!   seeded server RNG.
//! * Routing is always adaptive: the point of the exercise is watching
//!   the controller demote the injected rung, with the trace carrying
//!   the whole causal chain — `injected` events, over-SLO completions,
//!   then a `policy_decision{demote}` on the triggering request.
//! * The anti-starvation yield is effectively disabled
//!   (`max_wait_ms = 600_000`): with real sleeps in the loop a 500 ms
//!   bound would trip wall-dependently and re-order scheduling between
//!   runs, breaking byte-identity.
//!
//! [`run_traced`] asserts the span invariants (no drops, well-nested
//! delivered spans, span-derived per-rung decode totals exactly equal to
//! the registry's `serve.rung.*.tokens` counters, demotes at injected
//! rungs preceded by an injected event on the same rung) and returns the
//! snapshot; `trace_cli` prints per-request waterfalls and writes the
//! snapshot/dashboard artifacts.

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::config::{PolicyConfig, ServeConfig};
use crate::json::{self, Value};
use crate::obs::inject::{InjectedBackend, LatencyPlan, LatencyRule};
use crate::obs::Tracer;
use crate::sefp::Precision;
use crate::serve::{
    demo_decoder_params, DecoderBackend, DynamicBatcher, PrecisionLadder, Router, SchedPolicy,
    Server,
};

use super::replay::replay_sim_config;
use super::scenario::{catalog, Kind, Scenario};
use super::trace::generate;

/// One traced run's outcome.
#[derive(Debug)]
pub struct TracedReport {
    pub name: &'static str,
    pub served: u64,
    pub shed: u64,
    pub invalid: u64,
    pub demotions: u64,
    pub promotions: u64,
    /// byte-identical across runs: the `otaro.trace.v1` snapshot
    pub trace: Value,
    /// wall-side registry snapshot (latencies, gauges — NOT byte-stable)
    pub metrics: Value,
}

/// The default injection plan: every decode step at E5M4 (the
/// understanding class's starting rung) sleeps 40 ms — unambiguously
/// over the default 25 ms SLO — with a transient fault every 5th step
/// absorbed by 2 retries.
pub fn default_plan() -> LatencyPlan {
    LatencyPlan {
        rules: vec![LatencyRule {
            precision: Some(Precision::of(4)),
            from_step: 0,
            to_step: u64::MAX,
            delay_ms: 40,
            fault_every: 5,
        }],
        max_retries: 2,
    }
}

fn traced_config(sc: &Scenario) -> ServeConfig {
    ServeConfig {
        max_batch: sc.max_batch,
        queue_cap: sc.queue_cap,
        // injected sleeps are real wall time: the anti-starvation yield
        // must never trip mid-run or scheduling order would depend on
        // the wall clock (see module docs)
        max_wait_ms: 600_000,
        policy: PolicyConfig {
            // always adaptive — the injected violations exist to be
            // acted on, even in scenarios that replay statically
            adaptive: true,
            window: 64,
            min_samples: 8,
            cooldown: 8,
            ..PolicyConfig::default()
        },
        ..ServeConfig::default()
    }
}

/// One request's span chain, flattened for waterfall math.  All times
/// are logical ticks from the trace, never wall time.
#[derive(Debug, Default, Clone)]
pub struct Waterfall {
    pub req: u64,
    pub complete: bool,
    pub admitted: u64,
    pub queued: Option<u64>,
    pub scheduled: Option<u64>,
    pub first_decode: Option<u64>,
    pub delivered: Option<u64>,
    pub shed_reason: Option<String>,
    pub decode_steps: u64,
}

impl Waterfall {
    /// admitted → delivered/shed span, in ticks.
    pub fn total_ticks(&self) -> u64 {
        self.delivered.unwrap_or(self.admitted).saturating_sub(self.admitted)
    }

    /// queued → scheduled wait, in ticks.
    pub fn queue_ticks(&self) -> u64 {
        match (self.queued, self.scheduled) {
            (Some(q), Some(s)) => s.saturating_sub(q),
            _ => 0,
        }
    }

    /// scheduled → delivered decode span, in ticks.
    pub fn decode_ticks(&self) -> u64 {
        match (self.scheduled, self.delivered) {
            (Some(s), Some(d)) => d.saturating_sub(s),
            _ => 0,
        }
    }
}

fn field_u64(ev: &Value, key: &str) -> anyhow::Result<u64> {
    ev.get(key)
        .and_then(|v| v.as_f64())
        .map(|x| x as u64)
        .ok_or_else(|| anyhow::anyhow!("trace event missing numeric field {key:?}"))
}

fn field_str(ev: &Value, key: &str) -> anyhow::Result<String> {
    ev.get(key)
        .and_then(|v| v.as_str())
        .map(str::to_string)
        .ok_or_else(|| anyhow::anyhow!("trace event missing string field {key:?}"))
}

/// Flatten every trace in an `otaro.trace.v1` snapshot to waterfalls.
pub fn waterfalls(snapshot: &Value) -> anyhow::Result<Vec<Waterfall>> {
    let traces = snapshot
        .get("traces")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow::anyhow!("snapshot has no traces array"))?;
    let mut out = Vec::with_capacity(traces.len());
    for tr in traces {
        let mut w = Waterfall {
            req: field_u64(tr, "req")?,
            complete: tr.get("complete").and_then(|v| v.as_bool()).unwrap_or(false),
            ..Waterfall::default()
        };
        let events = tr
            .get("events")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("trace has no events array"))?;
        for ev in events {
            let tick = field_u64(ev, "tick")?;
            match field_str(ev, "kind")?.as_str() {
                "admitted" => w.admitted = tick,
                "queued" => w.queued = w.queued.or(Some(tick)),
                "scheduled" => w.scheduled = w.scheduled.or(Some(tick)),
                "decode_step" => {
                    w.first_decode = w.first_decode.or(Some(tick));
                    w.decode_steps += 1;
                }
                "delivered" => w.delivered = w.delivered.or(Some(tick)),
                "shed" => w.shed_reason = Some(field_str(ev, "reason")?),
                _ => {}
            }
        }
        out.push(w);
    }
    Ok(out)
}

/// Span-derived per-rung decode-step totals: how many `decode_step`
/// events each width carries across the whole snapshot.
pub fn span_rung_tokens(snapshot: &Value) -> anyhow::Result<BTreeMap<u8, u64>> {
    let traces = snapshot
        .get("traces")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow::anyhow!("snapshot has no traces array"))?;
    let mut by_width: BTreeMap<u8, u64> = BTreeMap::new();
    for tr in traces {
        let events = tr
            .get("events")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("trace has no events array"))?;
        for ev in events {
            if ev.get("kind").and_then(|v| v.as_str()) == Some("decode_step") {
                let width = field_u64(ev, "width")? as u8;
                *by_width.entry(width).or_insert(0) += 1;
            }
        }
    }
    Ok(by_width)
}

/// `(tick, demote?, from-width)` for every policy decision in the
/// snapshot, plus `(tick, width)` for every injected event.
fn decision_and_injection_ticks(
    snapshot: &Value,
) -> anyhow::Result<(Vec<(u64, bool, u8)>, Vec<(u64, u8)>)> {
    let mut decisions = Vec::new();
    let traces = snapshot
        .get("traces")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow::anyhow!("snapshot has no traces array"))?;
    for tr in traces {
        let events = tr
            .get("events")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("trace has no events array"))?;
        for ev in events {
            if ev.get("kind").and_then(|v| v.as_str()) == Some("policy_decision") {
                decisions.push((
                    field_u64(ev, "tick")?,
                    field_str(ev, "move")? == "demote",
                    field_u64(ev, "from")? as u8,
                ));
            }
        }
    }
    let mut injections = Vec::new();
    let injected = snapshot
        .get("injected")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow::anyhow!("snapshot has no injected array"))?;
    for ev in injected {
        injections.push((field_u64(ev, "tick")?, field_u64(ev, "width")? as u8));
    }
    Ok((decisions, injections))
}

/// Replay `sc` with tracing + injection, asserting the span invariants.
pub fn run_traced(sc: &Scenario, plan: LatencyPlan) -> anyhow::Result<TracedReport> {
    let cfg = traced_config(sc);
    let injected_rungs: Vec<u8> =
        plan.rules.iter().filter_map(|r| r.precision.map(|p| p.m())).collect();
    // the replay driver's model, behind the injection wrapper: span
    // invariants now hold over real SEFP logits, not a scoring stub
    let sim = replay_sim_config();
    let params = demo_decoder_params(&sim, 5);
    let ladder = PrecisionLadder::from_params(&params).with_budget(cfg.ladder_budget_bytes);
    let backend = InjectedBackend::new(
        DecoderBackend::from_ladder(&ladder, cfg.max_batch, sim.context, cfg.decode_threads)?,
        plan,
    );
    let batcher = DynamicBatcher::new(cfg.max_batch, cfg.queue_cap)
        .with_policy(SchedPolicy::from_config(&cfg));
    let router = Router::from_config(cfg.clone());
    let mut server = Server::new(backend, ladder, router, batcher)
        .with_seed(sc.seed)
        .with_tracer(Tracer::new(1024, 32));

    let trace_in = generate(sc);
    let total: u64 = trace_in.iter().map(|t| t.len() as u64).sum();
    for events in &trace_in {
        for ev in events {
            let ok = server.submit(ev.req.clone());
            // valid requests may still shed under backpressure; only the
            // malformed ones have a fixed expected outcome
            anyhow::ensure!(
                !(ok && ev.expect_invalid),
                "scenario {}: malformed request {} was admitted",
                sc.name,
                ev.req.id
            );
        }
        server.process_all()?;
    }

    let metrics = server.metrics_snapshot();
    let stats = server.stats();
    let snap = server
        .trace_snapshot()
        .ok_or_else(|| anyhow::anyhow!("tracing was on; snapshot must exist"))?;

    macro_rules! check {
        ($name:literal, $cond:expr) => {
            anyhow::ensure!(
                $cond,
                "scenario {}: traced invariant {} violated ({})",
                sc.name,
                $name,
                stringify!($cond)
            );
        };
    }

    check!("conservation", stats.served + stats.rejected + stats.invalid == total);
    // the ring must hold the whole run — a dropped or truncated trace
    // would silently break the span/counter cross-checks below
    let dropped = snap.get("dropped").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
    let truncated = snap.get("truncated_events").and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
    check!("no-dropped-traces", dropped == 0.0 && truncated == 0.0);

    let falls = waterfalls(&snap)?;
    check!("one-trace-per-request", falls.len() as u64 == total);
    let delivered = falls.iter().filter(|w| w.delivered.is_some()).count() as u64;
    let shed = falls.iter().filter(|w| w.shed_reason.is_some()).count() as u64;
    check!("delivered-spans-match-served", delivered == stats.served);
    check!("shed-spans-carry-reasons", shed == stats.rejected + stats.invalid);
    for w in &falls {
        check!("all-spans-terminal", w.complete);
        if let Some(d) = w.delivered {
            let q = w.queued.unwrap_or(0);
            let s = w.scheduled.unwrap_or(0);
            let f = w.first_decode.unwrap_or(0);
            check!(
                "delivered-spans-well-nested",
                w.admitted <= q && q <= s && s <= f && f <= d
            );
        }
    }

    // spans vs registry: per-rung decode_step events must equal the
    // serve.rung.*.tokens counters EXACTLY (probe re-scoring steps
    // appear in neither)
    let from_spans = span_rung_tokens(&snap)?;
    let from_registry: BTreeMap<u8, u64> =
        server.metrics().tokens_per_precision().iter().map(|&(p, n)| (p.m(), n)).collect();
    check!("span-rung-tokens-match-registry", from_spans == from_registry);

    // every demotion of an injected rung must be *explained*: an
    // injected latency event on that rung strictly before the decision
    let (decisions, injections) = decision_and_injection_ticks(&snap)?;
    for &(tick, demote, from) in &decisions {
        if demote && injected_rungs.contains(&from) {
            check!(
                "demotes-explained-by-injection",
                injections.iter().any(|&(it, iw)| iw == from && it < tick)
            );
        }
    }

    Ok(TracedReport {
        name: sc.name,
        served: stats.served,
        shed: stats.rejected,
        invalid: stats.invalid,
        demotions: stats.demotions,
        promotions: stats.promotions,
        trace: snap,
        metrics,
    })
}

/// `otaro trace` entry point: traced replay of one scenario (default
/// burst-storm), waterfall summaries on stdout, optional snapshot and
/// dashboard artifacts.
pub fn trace_cli(
    scenario: Option<String>,
    out: Option<PathBuf>,
    dashboard_out: Option<PathBuf>,
) -> anyhow::Result<()> {
    let all = catalog();
    let name = scenario.unwrap_or_else(|| "burst-storm".to_string());
    let Some(sc) = all.iter().find(|s| s.name == name.as_str()).cloned() else {
        let known: Vec<&str> = all.iter().map(|s| s.name).collect();
        anyhow::bail!("unknown scenario {name:?}; known: {}", known.join(", "));
    };

    println!("tracing {:<24} {}", sc.name, sc.description);
    let rep = run_traced(&sc, default_plan())?;
    println!(
        "  served {} / shed {} / invalid {} — demotions {} promotions {}",
        rep.served, rep.shed, rep.invalid, rep.demotions, rep.promotions
    );
    if sc.kind == Kind::BurstStorm {
        // the acceptance contract: the injected E5M4 latency must force
        // at least one traced, explained demotion under the storm
        anyhow::ensure!(
            rep.demotions >= 1,
            "burst-storm with the default plan must demote at least once"
        );
    }

    let falls = waterfalls(&rep.trace)?;
    let served: Vec<&Waterfall> = falls.iter().filter(|w| w.delivered.is_some()).collect();

    // per-request waterfall: slowest-N by admitted → delivered ticks
    let mut slowest = served.clone();
    slowest.sort_by_key(|w| (std::cmp::Reverse(w.total_ticks()), w.req));
    println!("  slowest requests (logical ticks):");
    println!("    {:>6} {:>7} {:>7} {:>7} {:>6}", "req", "total", "queue", "decode", "steps");
    for w in slowest.iter().take(5) {
        println!(
            "    {:>6} {:>7} {:>7} {:>7} {:>6}",
            w.req,
            w.total_ticks(),
            w.queue_ticks(),
            w.decode_ticks(),
            w.decode_steps
        );
    }

    // per-rung decode-step histogram from spans, cross-checked exactly
    // against the registry counters inside run_traced
    let by_rung = span_rung_tokens(&rep.trace)?;
    println!("  per-rung decode steps (spans == registry):");
    for (width, steps) in &by_rung {
        println!("    e5m{width}: {steps}");
    }
    let sheds: BTreeMap<String, u64> =
        falls.iter().filter_map(|w| w.shed_reason.clone()).fold(BTreeMap::new(), |mut m, r| {
            *m.entry(r).or_insert(0) += 1;
            m
        });
    for (reason, count) in &sheds {
        println!("  shed[{reason}]: {count}");
    }

    if let Some(path) = out {
        std::fs::write(&path, format!("{}\n", rep.trace))?;
        println!("wrote trace snapshot {}", path.display());
    }
    if let Some(path) = dashboard_out {
        let spec = crate::obs::dashboard(&rep.metrics);
        std::fs::write(&path, format!("{spec}\n"))?;
        println!("wrote dashboard spec {}", path.display());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn storm(ticks: usize) -> Scenario {
        let sc = catalog()
            .into_iter()
            .find(|s| s.kind == Kind::BurstStorm)
            .unwrap_or_else(|| unreachable!("catalog always has a storm"));
        Scenario { ticks, ..sc }
    }

    #[test]
    fn traced_storm_holds_span_invariants() {
        let rep = run_traced(&storm(5), default_plan()).unwrap();
        assert!(rep.served > 0);
        assert!(rep.shed > 0, "a storm tick must overrun the queue");
        // shed spans carry machine-readable reasons
        let falls = waterfalls(&rep.trace).unwrap();
        assert!(falls
            .iter()
            .filter_map(|w| w.shed_reason.as_deref())
            .all(|r| r == "queue_full"));
    }

    #[test]
    fn empty_plan_still_traces() {
        let rep = run_traced(&storm(5), LatencyPlan::none()).unwrap();
        let injected = rep.trace.get("injected").and_then(|v| v.as_arr()).unwrap();
        assert!(injected.is_empty(), "no plan, no injected events");
    }

    #[test]
    fn default_plan_targets_the_understanding_rung() {
        let plan = default_plan();
        assert_eq!(plan.rules.len(), 1);
        assert_eq!(plan.rules[0].precision, Some(Precision::of(4)));
        assert!(plan.rules[0].delay_ms as f64 > ServeConfig::default().policy.slo_p95_ms);
        assert!(plan.max_retries > 0, "storm faults must be absorbed, not surfaced");
    }
}
