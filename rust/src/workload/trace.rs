//! Deterministic trace expansion: a [`Scenario`] + seed becomes the
//! exact same per-tick request sequence on every run.
//!
//! Nothing here reads a clock or the environment — arrivals come from
//! integer arithmetic over the tick index and a seeded
//! [`Rng`](crate::data::Rng) — so the replay driver can compute
//! served/shed/invalid/clamp expectations from the trace alone and
//! assert them *exactly* against the obs registry.
//!
//! Token hygiene: prompt ids stay in `[1, 200]`, strictly below the
//! decoder vocab (256) and far from the reserved EOS/PAD sentinels, so
//! EOS is unreachable and every admitted request decodes its full
//! `max_new_tokens` budget — which is what makes token totals exactly
//! predictable.

use crate::data::tokenizer::PAD;
use crate::data::Rng;
use crate::sefp::Precision;
use crate::serve::{Request, TaskClass};

use super::scenario::{Kind, Scenario};

/// Rungs of the default serve ladder a well-behaved client may pin.
const ON_LADDER: [u8; 4] = [8, 6, 4, 3];
/// Widths outside the default ladder (below the bottom rung or above the
/// master) an adversarial client pins — the router must snap AND count
/// every one.
const OFF_LADDER: [u8; 4] = [1, 2, 9, 12];

/// One trace entry: the request plus what the generator KNOWS the serve
/// stack must do with it (ground truth for the replay assertions).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub req: Request,
    /// malformed on purpose (empty prompt / reserved PAD id): `submit`
    /// must refuse it as invalid, before routing
    pub expect_invalid: bool,
    /// forces an off-ladder width: the router must snap it into the
    /// ladder and count the clamp
    pub expect_clamp: bool,
}

/// Expand a scenario into its per-tick arrival batches.
pub fn generate(sc: &Scenario) -> Vec<Vec<TraceEvent>> {
    let mut rng = Rng::new(sc.seed);
    let mut next_id = 0u64;
    let mut trace = Vec::with_capacity(sc.ticks);
    for tick in 0..sc.ticks {
        let n = arrivals_at(sc, tick);
        let mut events = Vec::with_capacity(n);
        for slot in 0..n {
            let id = next_id;
            next_id += 1;
            events.push(event(sc, &mut rng, id, slot));
        }
        trace.push(events);
    }
    trace
}

/// Arrivals for one tick — pure integer arithmetic over the tick index.
fn arrivals_at(sc: &Scenario, tick: usize) -> usize {
    match sc.kind {
        Kind::SteadyMix => 6,
        Kind::DiurnalRamp => {
            // triangle ramp: low overnight, peak at mid-trace, back down;
            // the peak stays at or under the queue cap so the ramp tests
            // scheduling pressure, not backpressure
            let mid = (sc.ticks / 2).max(1);
            let lo = 2usize;
            let hi = sc.queue_cap.min(24).max(lo);
            lo + (hi - lo) * mid.saturating_sub(tick.abs_diff(mid)) / mid
        }
        Kind::BurstStorm => {
            // every 4th tick a storm overruns the admission queue by
            // construction; the quiet baseline keeps latency stats sane
            if tick % 4 == 0 {
                sc.queue_cap + sc.queue_cap / 2 + 8
            } else {
                2
            }
        }
        Kind::Adversarial => 8,
    }
}

fn event(sc: &Scenario, rng: &mut Rng, id: u64, slot: usize) -> TraceEvent {
    if sc.kind == Kind::Adversarial {
        return adversarial_event(rng, id, slot);
    }
    let mut req = Request::new(id, mixed_class(rng), prompt(rng))
        .with_max_new_tokens(2 + rng.below(3));
    // a slice of steady traffic pins explicit (legal) rungs, so the
    // forced-precision path sees load without tripping the clamp counter
    if sc.kind == Kind::SteadyMix && id % 7 == 0 {
        req = req.with_precision(Precision::of(ON_LADDER[rng.below(ON_LADDER.len())]));
    }
    TraceEvent { req, expect_invalid: false, expect_clamp: false }
}

/// The adversarial tick layout, by slot: two off-ladder precision
/// forcers, one legal pin, one malformed request, and normal traffic in
/// the remaining slots.
fn adversarial_event(rng: &mut Rng, id: u64, slot: usize) -> TraceEvent {
    match slot {
        0 | 1 => {
            let w = OFF_LADDER[(id as usize) % OFF_LADDER.len()];
            let req = Request::new(id, mixed_class(rng), prompt(rng))
                .with_precision(Precision::of(w))
                .with_max_new_tokens(2 + rng.below(3));
            TraceEvent { req, expect_invalid: false, expect_clamp: true }
        }
        2 => {
            let req = Request::new(id, mixed_class(rng), prompt(rng))
                .with_precision(Precision::of(ON_LADDER[rng.below(ON_LADDER.len())]))
                .with_max_new_tokens(2 + rng.below(3));
            TraceEvent { req, expect_invalid: false, expect_clamp: false }
        }
        4 => {
            // malformed: alternate the two rejection reasons `submit`
            // validates (empty prompt / reserved PAD id in the prompt)
            let bad = if id % 2 == 0 { Vec::new() } else { vec![5, PAD, 7] };
            let req = Request::new(id, TaskClass::Other, bad);
            TraceEvent { req, expect_invalid: true, expect_clamp: false }
        }
        _ => {
            let req = Request::new(id, mixed_class(rng), prompt(rng))
                .with_max_new_tokens(2 + rng.below(3));
            TraceEvent { req, expect_invalid: false, expect_clamp: false }
        }
    }
}

/// The heterogeneous task-class mix every scenario draws from:
/// understanding-heavy with a generation tail (the paper's motivating
/// split — latency-sensitive vs quality-sensitive traffic).
fn mixed_class(rng: &mut Rng) -> TaskClass {
    match rng.below(10) {
        0..=3 => TaskClass::Understanding,
        4..=6 => TaskClass::Other,
        _ => TaskClass::Generation,
    }
}

/// 3–8 tokens, ids in `[1, 200]` (inside the decoder vocab, never a
/// reserved sentinel).
fn prompt(rng: &mut Rng) -> Vec<i32> {
    (0..3 + rng.below(6)).map(|_| (1 + rng.below(200)) as i32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::catalog;

    /// Flatten a trace to a comparable shape (Request has no PartialEq).
    fn fingerprint(trace: &[Vec<TraceEvent>]) -> Vec<(u64, Vec<i32>, usize, Option<u8>, bool, bool)> {
        trace
            .iter()
            .flatten()
            .map(|ev| {
                (
                    ev.req.id,
                    ev.req.prompt.clone(),
                    ev.req.max_new_tokens,
                    ev.req.precision.map(|p| p.m()),
                    ev.expect_invalid,
                    ev.expect_clamp,
                )
            })
            .collect()
    }

    #[test]
    fn same_seed_same_trace_different_seed_differs() {
        for sc in catalog() {
            let a = fingerprint(&generate(&sc));
            let b = fingerprint(&generate(&sc));
            assert_eq!(a, b, "{}: trace must be a pure function of the scenario", sc.name);
            let mut other = sc.clone();
            other.seed ^= 0xDEAD;
            assert_ne!(a, fingerprint(&generate(&other)), "{}: seed must matter", sc.name);
        }
    }

    #[test]
    fn ids_are_sequential_across_the_whole_trace() {
        for sc in catalog() {
            for (i, ev) in generate(&sc).iter().flatten().enumerate() {
                assert_eq!(ev.req.id, i as u64, "{}", sc.name);
            }
        }
    }

    #[test]
    fn prompts_stay_inside_the_decoder_vocab() {
        for sc in catalog() {
            for ev in generate(&sc).iter().flatten() {
                if ev.expect_invalid {
                    continue;
                }
                assert!(!ev.req.prompt.is_empty());
                assert!(ev.req.prompt.iter().all(|&t| (1..=200).contains(&t)), "{}", sc.name);
                assert!((2..=4).contains(&ev.req.max_new_tokens));
            }
        }
    }

    #[test]
    fn storm_ticks_overrun_the_queue_and_adversary_misbehaves() {
        let all = catalog();
        let storm = all.iter().find(|s| s.kind == Kind::BurstStorm).unwrap();
        let trace = generate(storm);
        let overruns = trace.iter().filter(|t| t.len() > storm.queue_cap).count();
        assert!(overruns >= 2, "storm must overrun the cap repeatedly");

        let adv = all.iter().find(|s| s.kind == Kind::Adversarial).unwrap();
        let trace = generate(adv);
        let clamps: usize = trace.iter().flatten().filter(|e| e.expect_clamp).count();
        let invalid: usize = trace.iter().flatten().filter(|e| e.expect_invalid).count();
        assert_eq!(clamps, 2 * adv.ticks);
        assert_eq!(invalid, adv.ticks);
        // clamp targets really are off the default ladder
        for ev in trace.iter().flatten().filter(|e| e.expect_clamp) {
            let w = ev.req.precision.unwrap().m();
            assert!(!(3..=8).contains(&w), "width {w} is a legal rung");
        }
    }
}
