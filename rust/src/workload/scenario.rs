//! The scenario catalog: named traffic shapes with per-scenario
//! SLO/quality bounds.
//!
//! Bounds are deliberately generous — they are *sanity rails* a healthy
//! serve stack clears with an order of magnitude of headroom on any
//! machine (including noisy shared CI runners), not tuned perf targets.
//! The exact-accounting invariants in [`replay`](super::replay) carry
//! the precision; these catch gross regressions (a starved queue, a
//! probe plane scoring garbage, an SLO blown by 100x).

use crate::benchutil::quick_mode;

/// Arrival-pattern family a scenario draws from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// constant-rate heterogeneous task-class mix
    SteadyMix,
    /// triangle ramp up to a midday peak and back down
    DiurnalRamp,
    /// quiet baseline punctured by bursts that overrun the queue cap
    BurstStorm,
    /// precision-forcing clients (off-ladder widths) + malformed prompts
    Adversarial,
}

impl Kind {
    pub fn name(&self) -> &'static str {
        match self {
            Kind::SteadyMix => "steady_mix",
            Kind::DiurnalRamp => "diurnal_ramp",
            Kind::BurstStorm => "burst_storm",
            Kind::Adversarial => "adversarial",
        }
    }
}

/// Per-scenario invariant bounds, asserted by the replay driver.
#[derive(Debug, Clone)]
pub struct SloChecks {
    /// p95 queue wait must stay under this (milliseconds)
    pub queue_p95_ms: f64,
    /// p95 per-request compute must stay under this (milliseconds)
    pub compute_p95_ms: f64,
    /// no request may wait longer than this — the starvation rail
    pub starvation_ms: f64,
    /// when shadow probes ran, mean token-agreement must clear this
    pub probe_agreement_floor: f64,
    /// the scenario must actually serve at least this many requests
    pub min_served: u64,
    /// the trace is built to overrun the queue: shed must be non-zero
    pub expect_shed: bool,
    /// the trace forces off-ladder widths: clamps must be non-zero
    pub expect_clamps: bool,
}

impl Default for SloChecks {
    fn default() -> Self {
        SloChecks {
            queue_p95_ms: 2_000.0,
            compute_p95_ms: 2_000.0,
            starvation_ms: 10_000.0,
            probe_agreement_floor: 0.05,
            min_served: 1,
            expect_shed: false,
            expect_clamps: false,
        }
    }
}

/// One named load scenario: a traffic shape, a seed, the serve knobs it
/// runs under, and the invariants it must uphold.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: &'static str,
    pub description: &'static str,
    pub kind: Kind,
    /// arrival ticks; each tick submits a batch of requests then drains
    pub ticks: usize,
    /// seeds the trace generator AND the server's sampling rng
    pub seed: u64,
    pub max_batch: usize,
    pub queue_cap: usize,
    /// route through `AdaptivePolicy` (telemetry + shadow probes)
    pub adaptive: bool,
    pub slo: SloChecks,
}

/// The named scenario catalog the CLI and the tier-1 smoke test run.
/// Under `OTARO_BENCH_QUICK` tick counts collapse so the whole catalog
/// replays in seconds; every invariant still executes.
pub fn catalog() -> Vec<Scenario> {
    let quick = quick_mode();
    let t = |full: usize, q: usize| if quick { q } else { full };
    vec![
        Scenario {
            name: "steady-mix",
            description: "constant heterogeneous class mix, static routing",
            kind: Kind::SteadyMix,
            ticks: t(24, 8),
            seed: 101,
            max_batch: 8,
            queue_cap: 64,
            adaptive: false,
            slo: SloChecks { min_served: 40, ..SloChecks::default() },
        },
        Scenario {
            name: "diurnal-ramp",
            description: "triangle arrival ramp to a midday peak, adaptive routing",
            kind: Kind::DiurnalRamp,
            ticks: t(30, 10),
            seed: 202,
            max_batch: 8,
            queue_cap: 48,
            adaptive: true,
            slo: SloChecks { min_served: 30, ..SloChecks::default() },
        },
        Scenario {
            name: "burst-storm",
            description: "quiet baseline with queue-overrunning bursts (backpressure)",
            kind: Kind::BurstStorm,
            ticks: t(20, 8),
            seed: 303,
            max_batch: 8,
            queue_cap: 16,
            adaptive: false,
            slo: SloChecks { min_served: 20, expect_shed: true, ..SloChecks::default() },
        },
        Scenario {
            name: "adversarial-precision",
            description: "clients forcing off-ladder widths + malformed prompts",
            kind: Kind::Adversarial,
            ticks: t(16, 6),
            seed: 404,
            max_batch: 8,
            queue_cap: 64,
            adaptive: true,
            slo: SloChecks { min_served: 30, expect_clamps: true, ..SloChecks::default() },
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_names_are_unique_and_cover_every_kind() {
        let all = catalog();
        assert_eq!(all.len(), 4);
        for kind in [Kind::SteadyMix, Kind::DiurnalRamp, Kind::BurstStorm, Kind::Adversarial] {
            assert_eq!(all.iter().filter(|s| s.kind == kind).count(), 1, "{kind:?}");
        }
        for (i, a) in all.iter().enumerate() {
            for b in &all[i + 1..] {
                assert_ne!(a.name, b.name);
                assert_ne!(a.seed, b.seed, "seeds must differ so traces do");
            }
        }
    }

    #[test]
    fn stress_scenarios_declare_their_expectations() {
        let all = catalog();
        let storm = all.iter().find(|s| s.kind == Kind::BurstStorm).unwrap();
        assert!(storm.slo.expect_shed, "the storm exists to exercise backpressure");
        let adv = all.iter().find(|s| s.kind == Kind::Adversarial).unwrap();
        assert!(adv.slo.expect_clamps, "the adversary exists to exercise clamping");
        // queue cap small enough that a burst actually overruns it
        assert!(storm.queue_cap < 64);
    }
}
