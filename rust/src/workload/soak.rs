//! The long-horizon soak harness behind `otaro soak`: a scenario's
//! traffic shape replayed for ~10x its catalog length through the real
//! serve stack, with mid-trace **config flips**, a declarative
//! injection plan, and a [`FlightRecorder`] timeline that the drift
//! invariants are asserted over.
//!
//! Where [`replay`](super::replay) proves exact accounting over one
//! short trace and [`traced`](super::traced) proves span causality,
//! the soak answers the question neither can: does the stack *stay*
//! healthy — no creeping queue depth, no ladder-cache churn, policy
//! recovery after perturbation — when the run is long and the
//! configuration changes underneath it?  Each flip is applied at a
//! declared tick, pinned into the timeline as a mark, and must be
//! *visible* as a frame-delta inflection near that mark:
//!
//! * [`FlipKind::LadderBudget`] re-caps the live ladder cache
//!   ([`PrecisionLadder::set_budget`]) — residency must drop or
//!   evictions rise;
//! * [`FlipKind::SloTighten`] rebuilds the router with a tighter
//!   latency SLO — the policy decision gauges must move;
//! * [`FlipKind::PolicyToggle`] flips adaptive routing on/off —
//!   rebuilding the router resets its decision counters, which is
//!   itself the visible inflection.
//!
//! The run emits one `otaro.bench.v1` record (default
//! `BENCH_soak.json`) whose `det` section embeds the
//! [`det_timeline`](FlightRecorder::det_timeline) — byte-identical
//! across runs of the same config, so the CI bench-diff gate compares
//! soak drift exactly — and whose `wall` section carries the full
//! timeline with the histogram planes (stage p95s, queue latencies).
//!
//! [`FlightRecorder`]: crate::obs::FlightRecorder
//! [`PrecisionLadder::set_budget`]: crate::serve::PrecisionLadder::set_budget

use std::path::PathBuf;

use crate::benchutil::{quick_mode, write_bench_file};
use crate::config::{PolicyConfig, ServeConfig};
use crate::json::{self, Value};
use crate::obs::inject::{InjectedBackend, LatencyPlan};
use crate::obs::FlightRecorder;
use crate::serve::{
    demo_decoder_params, DecoderBackend, DynamicBatcher, PrecisionLadder, Router, SchedPolicy,
    Server,
};

use super::replay::replay_sim_config;
use super::scenario::{catalog, Scenario};
use super::trace::generate;
use super::traced::default_plan;

/// One mid-trace configuration change.
#[derive(Debug, Clone)]
pub enum FlipKind {
    /// Re-cap the ladder cache's residency budget (bytes) on the live
    /// server — 0 = cache nothing, the memory-pressure extreme.
    LadderBudget { bytes: usize },
    /// Tighten (or relax) the latency SLO and rebuild the router.
    SloTighten { slo_p95_ms: f64 },
    /// Toggle adaptive routing and rebuild the router.
    PolicyToggle,
}

impl FlipKind {
    /// Mark label recorded into the timeline when the flip applies.
    pub fn label(&self) -> &'static str {
        match self {
            FlipKind::LadderBudget { .. } => "flip: ladder_budget",
            FlipKind::SloTighten { .. } => "flip: slo_tighten",
            FlipKind::PolicyToggle => "flip: policy_toggle",
        }
    }
}

/// A [`FlipKind`] scheduled at a logical tick.
#[derive(Debug, Clone)]
pub struct Flip {
    pub at_tick: u64,
    pub kind: FlipKind,
}

/// One soak run's full specification.  The traffic *shape* comes from a
/// named catalog scenario; the soak stretches its tick count, layers
/// flips and an injection plan on top, and samples the flight recorder
/// every `frame_every` ticks.
#[derive(Debug, Clone)]
pub struct SoakConfig {
    pub name: String,
    /// catalog scenario supplying the arrival shape and serve knobs
    pub scenario: String,
    /// soak length in ticks (the built-ins run ~10x the catalog length)
    pub ticks: usize,
    /// seeds the trace generator and the server's sampling rng
    pub seed: u64,
    /// flight-recorder sampling cadence, in ticks
    pub frame_every: usize,
    /// flight-recorder ring capacity; the built-ins size it so no frame
    /// is evicted, which is what makes delta-sum accounting exact
    pub frame_cap: usize,
    /// config flips, applied at the start of their tick
    pub flips: Vec<Flip>,
    pub plan: LatencyPlan,
}

impl SoakConfig {
    /// Parse a soak config from a JSON file body:
    ///
    /// ```json
    /// {"name": "my-soak", "scenario": "burst-storm",
    ///  "ticks": 200, "seed": 9001, "frame_every": 8, "frame_cap": 64,
    ///  "flips": [{"at_tick": 80, "kind": "slo_tighten", "slo_p95_ms": 15},
    ///            {"at_tick": 120, "kind": "ladder_budget", "bytes": 0},
    ///            {"at_tick": 160, "kind": "policy_toggle"}],
    ///  "plan": {"max_retries": 2,
    ///           "rules": [{"precision": 4, "delay_ms": 40, "fault_every": 5}]}}
    /// ```
    ///
    /// `ticks` is required; everything else defaults (`scenario`
    /// "burst-storm", cadence 8, cap 64, no flips, and the traced
    /// driver's default injection plan — pass `"plan": {}` for none).
    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        let ticks = v
            .get("ticks")
            .and_then(|x| x.as_usize())
            .ok_or_else(|| anyhow::anyhow!("soak config needs a positive integer ticks"))?;
        let field_usize = |key: &str, default: usize| -> anyhow::Result<usize> {
            match v.get(key) {
                None | Some(Value::Null) => Ok(default),
                Some(x) => x
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("{key} must be a non-negative integer")),
            }
        };
        let mut flips = Vec::new();
        if let Some(list) = v.get("flips") {
            let list =
                list.as_arr().ok_or_else(|| anyhow::anyhow!("flips must be an array"))?;
            for (i, f) in list.iter().enumerate() {
                let at_tick = f
                    .get("at_tick")
                    .and_then(|x| x.as_usize())
                    .ok_or_else(|| anyhow::anyhow!("flip {i}: at_tick is required"))?
                    as u64;
                let kind = match f.get("kind").and_then(|x| x.as_str()) {
                    Some("ladder_budget") => FlipKind::LadderBudget {
                        bytes: f
                            .get("bytes")
                            .and_then(|x| x.as_usize())
                            .ok_or_else(|| anyhow::anyhow!("flip {i}: ladder_budget needs bytes"))?,
                    },
                    Some("slo_tighten") => FlipKind::SloTighten {
                        slo_p95_ms: f.get("slo_p95_ms").and_then(|x| x.as_f64()).ok_or_else(
                            || anyhow::anyhow!("flip {i}: slo_tighten needs slo_p95_ms"),
                        )?,
                    },
                    Some("policy_toggle") => FlipKind::PolicyToggle,
                    other => anyhow::bail!("flip {i}: unknown kind {other:?}"),
                };
                flips.push(Flip { at_tick, kind });
            }
        }
        let plan = match v.get("plan") {
            None | Some(Value::Null) => default_plan(),
            Some(p) => LatencyPlan::from_json(p)?,
        };
        let cfg = SoakConfig {
            name: v.get("name").and_then(|x| x.as_str()).unwrap_or("custom-soak").to_string(),
            scenario: v
                .get("scenario")
                .and_then(|x| x.as_str())
                .unwrap_or("burst-storm")
                .to_string(),
            ticks,
            seed: v.get("seed").and_then(|x| x.as_usize()).unwrap_or(9001) as u64,
            frame_every: field_usize("frame_every", 8)?,
            frame_cap: field_usize("frame_cap", 64)?,
            flips,
            plan,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.ticks >= 2, "soak {} needs at least 2 ticks", self.name);
        anyhow::ensure!(self.frame_every >= 1, "soak {}: frame_every must be >= 1", self.name);
        anyhow::ensure!(self.frame_cap >= 1, "soak {}: frame_cap must be >= 1", self.name);
        for f in &self.flips {
            anyhow::ensure!(
                (f.at_tick as usize) < self.ticks,
                "soak {}: flip at tick {} beyond the {}-tick run",
                self.name,
                f.at_tick,
                self.ticks
            );
        }
        Ok(())
    }
}

/// The built-in soak catalog.  One entry today: the storm shape soaked
/// for 10x its catalog length with all three flip kinds mid-run.
/// Under `OTARO_BENCH_QUICK` it collapses (like the scenario catalog)
/// so CI smoke runs finish in seconds; every invariant still executes.
pub fn soak_catalog() -> Vec<SoakConfig> {
    let quick = quick_mode();
    let t = |full: usize, q: usize| if quick { q } else { full };
    vec![SoakConfig {
        name: "soak-storm-flips".to_string(),
        scenario: "burst-storm".to_string(),
        ticks: t(200, 24),
        seed: 9001,
        frame_every: t(8, 3),
        frame_cap: 64,
        flips: vec![
            Flip {
                at_tick: t(80, 9) as u64,
                kind: FlipKind::SloTighten { slo_p95_ms: 15.0 },
            },
            Flip { at_tick: t(120, 15) as u64, kind: FlipKind::LadderBudget { bytes: 0 } },
            Flip { at_tick: t(160, 20) as u64, kind: FlipKind::PolicyToggle },
        ],
        plan: default_plan(),
    }]
}

/// One soak run's outcome.
#[derive(Debug)]
pub struct SoakReport {
    pub name: String,
    pub served: u64,
    pub shed: u64,
    pub invalid: u64,
    /// peak of the policy.demotions gauge across the timeline (the live
    /// router resets on flips, so the peak is the honest count)
    pub demotions: u64,
    pub frames: usize,
    pub checks: Vec<&'static str>,
    /// byte-identical across runs of the same config
    pub det_timeline: Value,
    pub record: Value,
}

/// The serve config a soak runs under: the traced driver's idiom —
/// anti-starvation yield effectively off (real injected sleeps must not
/// reorder scheduling wall-dependently) and adaptive routing with
/// windows short enough to act within the run.
fn soak_serve_config(sc: &Scenario) -> ServeConfig {
    ServeConfig {
        max_batch: sc.max_batch,
        queue_cap: sc.queue_cap,
        max_wait_ms: 600_000,
        policy: PolicyConfig {
            adaptive: true,
            window: 64,
            min_samples: 8,
            cooldown: 8,
            ..PolicyConfig::default()
        },
        ..ServeConfig::default()
    }
}

fn resolve_base(name: &str) -> anyhow::Result<Scenario> {
    let all = catalog();
    all.iter().find(|s| s.name == name).cloned().ok_or_else(|| {
        let known: Vec<&str> = all.iter().map(|s| s.name).collect();
        anyhow::anyhow!("unknown scenario {name:?}; known: {}", known.join(", "))
    })
}

/// Run one soak end to end: replay the stretched trace with flips and
/// injection, sample the flight recorder on cadence, and assert every
/// drift invariant over the timeline itself.
pub fn run_soak(cfg: &SoakConfig) -> anyhow::Result<SoakReport> {
    cfg.validate()?;
    let base = resolve_base(&cfg.scenario)?;
    let sc = Scenario { ticks: cfg.ticks, seed: cfg.seed, ..base };
    let mut serve_cfg = soak_serve_config(&sc);

    // the replay driver's model behind the injection wrapper, with
    // stage profiling on so the timeline carries per-rung stage costs
    let sim = replay_sim_config();
    let params = demo_decoder_params(&sim, 5);
    let ladder =
        PrecisionLadder::from_params(&params).with_budget(serve_cfg.ladder_budget_bytes);
    let backend = InjectedBackend::new(
        DecoderBackend::from_ladder(&ladder, serve_cfg.max_batch, sim.context, serve_cfg.decode_threads)?,
        cfg.plan.clone(),
    );
    let batcher = DynamicBatcher::new(serve_cfg.max_batch, serve_cfg.queue_cap)
        .with_policy(SchedPolicy::from_config(&serve_cfg));
    let router = Router::from_config(serve_cfg.clone());
    let mut server = Server::new(backend, ladder, router, batcher)
        .with_seed(cfg.seed)
        .with_profiling(true);

    // freeze the metric set BEFORE attach: a snapshot lazily registers
    // the backend gauges, so the flight index covers them from frame 0
    let _ = server.metrics_snapshot();
    let mut flight = FlightRecorder::attach(server.metrics().registry(), cfg.frame_cap);

    let trace = generate(&sc);
    let total: u64 = trace.iter().map(|t| t.len() as u64).sum();
    let mut next_flip = 0usize;
    let mut flips = cfg.flips.clone();
    flips.sort_by_key(|f| f.at_tick);

    for (tick, events) in trace.iter().enumerate() {
        while next_flip < flips.len() && flips[next_flip].at_tick as usize <= tick {
            let flip = &flips[next_flip];
            flight.mark(flip.at_tick, flip.kind.label());
            match flip.kind {
                FlipKind::LadderBudget { bytes } => server.ladder.set_budget(bytes),
                FlipKind::SloTighten { slo_p95_ms } => {
                    serve_cfg.policy.slo_p95_ms = slo_p95_ms;
                    server.router = Router::from_config(serve_cfg.clone());
                }
                FlipKind::PolicyToggle => {
                    serve_cfg.policy.adaptive = !serve_cfg.policy.adaptive;
                    server.router = Router::from_config(serve_cfg.clone());
                }
            }
            next_flip += 1;
        }
        for ev in events {
            let ok = server.submit(ev.req.clone());
            anyhow::ensure!(
                !(ok && ev.expect_invalid),
                "soak {}: malformed request {} was admitted",
                cfg.name,
                ev.req.id
            );
        }
        server.process_all()?;
        if (tick + 1) % cfg.frame_every == 0 || tick + 1 == cfg.ticks {
            // snapshot on the reporting cadence so the ladder/policy/
            // backend gauges are fresh when the frame samples them
            let _ = server.metrics_snapshot();
            flight.sample(tick as u64, server.metrics().registry());
        }
    }

    let stats = server.stats();
    let mut checks: Vec<&'static str> = Vec::new();
    macro_rules! check {
        ($name:literal, $cond:expr) => {
            anyhow::ensure!(
                $cond,
                "soak {}: drift invariant {} violated ({})",
                cfg.name,
                $name,
                stringify!($cond)
            );
            checks.push($name);
        };
    }

    let frames = flight.frames_len();
    check!("timeline-has-frames", frames >= 2);
    check!("ring-held-the-run", flight.frames_dropped() == 0);
    check!("conservation", stats.served + stats.rejected + stats.invalid == total);

    // --- no unbounded queue growth -------------------------------------
    let g_depth = flight.gauge_index("serve.queue_depth").unwrap_or(usize::MAX);
    let g_peak = flight.gauge_index("serve.queue_depth_peak").unwrap_or(usize::MAX);
    check!("queue-gauges-in-timeline", g_depth != usize::MAX && g_peak != usize::MAX);
    let cap = sc.queue_cap as f64;
    check!(
        "queue-bounded-every-frame",
        (0..frames).all(|i| flight.gauge_at(i, g_depth) <= cap && flight.gauge_at(i, g_peak) <= cap)
    );

    // --- ladder-cache residency stabilizes -----------------------------
    let g_resident = flight.gauge_index("ladder.resident_bytes").unwrap_or(usize::MAX);
    check!("residency-gauge-in-timeline", g_resident != usize::MAX);
    let k = frames.min(3);
    let tail_resident = flight.gauge_at(frames - 1, g_resident);
    check!(
        "residency-stabilizes",
        (frames - k..frames).all(|i| flight.gauge_at(i, g_resident) == tail_resident)
    );

    // --- every flip visible as a frame-delta inflection ----------------
    let g_evict = flight.gauge_index("ladder.switch_evictions").unwrap_or(usize::MAX);
    let g_promo = flight.gauge_index("policy.promotions").unwrap_or(usize::MAX);
    let g_demo = flight.gauge_index("policy.demotions").unwrap_or(usize::MAX);
    let g_clamp = flight.gauge_index("policy.forced_clamps").unwrap_or(usize::MAX);
    for flip in &flips {
        let watched: &[usize] = match flip.kind {
            FlipKind::LadderBudget { .. } => &[g_resident, g_evict],
            FlipKind::SloTighten { .. } | FlipKind::PolicyToggle => &[g_promo, g_demo, g_clamp],
        };
        // baseline = the last frame strictly before the flip tick
        // (gauges start at zero when the flip precedes every frame)
        let baseline = (0..frames).rev().find(|&i| flight.frame_tick(i) < flip.at_tick);
        let horizon = flip.at_tick + 3 * cfg.frame_every as u64;
        let window = (0..frames).filter(|&i| {
            let t = flight.frame_tick(i);
            t >= flip.at_tick && t <= horizon
        });
        let mut inflected = false;
        for i in window {
            for &g in watched {
                let before = baseline.map_or(0.0, |b| flight.gauge_at(b, g));
                if flight.gauge_at(i, g) != before {
                    inflected = true;
                }
            }
        }
        anyhow::ensure!(
            inflected,
            "soak {}: {} at tick {} left no frame-delta inflection within {} ticks",
            cfg.name,
            flip.kind.label(),
            flip.at_tick,
            3 * cfg.frame_every
        );
    }
    if !flips.is_empty() {
        checks.push("flips-inflect-the-timeline");
    }

    // --- post-demote agreement recovery --------------------------------
    // after the LAST frame where the demotions gauge rose, any frame
    // that scores probes must clear the scenario's agreement floor
    let h_agree = flight.histo_index("policy.probe_agreement").unwrap_or(usize::MAX);
    check!("agreement-histo-in-timeline", h_agree != usize::MAX);
    let last_demote = (1..frames)
        .rev()
        .find(|&i| flight.gauge_at(i, g_demo) > flight.gauge_at(i - 1, g_demo));
    let mut recovered = true;
    if let Some(d) = last_demote {
        for i in d + 1..frames {
            let probes = flight.histo_count_delta(i, h_agree);
            if probes > 0 {
                let mean = flight.histo_sum_delta(i, h_agree) / probes as f64;
                recovered = mean >= sc.slo.probe_agreement_floor;
            }
        }
    }
    check!("post-demote-agreement-recovers", recovered);

    // --- frame-delta sums equal the final counters ---------------------
    // (exact because the ring held every frame and the recorder attached
    // before any traffic)
    let reg = server.metrics().registry();
    let mut deltas_match = true;
    for c in 0..reg.n_counters() {
        let summed: u64 = (0..frames).map(|i| flight.counter_delta(i, c)).sum();
        if summed != reg.counter_at(c) {
            deltas_match = false;
        }
    }
    check!("frame-deltas-sum-to-final", deltas_match);

    let demotions_peak = (0..frames)
        .map(|i| flight.gauge_at(i, g_demo) as u64)
        .max()
        .unwrap_or(0);

    let det = json::obj(vec![
        ("frames", json::n(frames as f64)),
        ("invalid", json::n(stats.invalid as f64)),
        ("served", json::n(stats.served as f64)),
        ("shed", json::n(stats.rejected as f64)),
        ("ticks", json::n(cfg.ticks as f64)),
        ("timeline", flight.det_timeline()),
        ("tokens", json::n(stats.tokens_generated as f64)),
    ]);
    let wall = json::obj(vec![
        ("throughput_rps", json::n(stats.throughput_rps())),
        ("throughput_tps", json::n(stats.throughput_tps())),
        ("timeline", flight.timeline()),
        ("wall_secs", json::n(stats.wall_secs)),
    ]);
    let record = json::obj(vec![
        ("name", json::s(cfg.name.clone())),
        ("scenario", json::s(cfg.scenario.clone())),
        ("seed", json::n(cfg.seed as f64)),
        ("det", det),
        ("wall", wall),
        ("checks", Value::Arr(checks.iter().map(|c| json::s(*c)).collect())),
    ]);

    Ok(SoakReport {
        name: cfg.name.clone(),
        served: stats.served,
        shed: stats.rejected,
        invalid: stats.invalid,
        demotions: demotions_peak,
        frames,
        checks,
        det_timeline: flight.det_timeline(),
        record,
    })
}

/// `otaro soak` entry point: run one built-in soak (default the first
/// catalog entry) or a `--config FILE` custom soak, assert every drift
/// invariant, and write the bench record (default `BENCH_soak.json`).
pub fn soak_cli(
    scenario: Option<String>,
    config: Option<PathBuf>,
    out: Option<PathBuf>,
) -> anyhow::Result<()> {
    let cfg = match config {
        Some(path) => {
            let text = std::fs::read_to_string(&path)
                .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
            let v = crate::json::parse(&text)
                .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
            SoakConfig::from_json(&v)?
        }
        None => {
            let all = soak_catalog();
            match &scenario {
                Some(name) => {
                    let found = all
                        .iter()
                        .find(|c| c.name == name.as_str() || c.scenario == name.as_str())
                        .cloned();
                    found.ok_or_else(|| {
                        let known: Vec<String> =
                            all.iter().map(|c| c.name.clone()).collect();
                        anyhow::anyhow!("unknown soak {name:?}; known: {}", known.join(", "))
                    })?
                }
                None => all
                    .into_iter()
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("empty soak catalog"))?,
            }
        }
    };
    println!(
        "soak {:<24} {} ticks of {} ({} flips, frame every {})",
        cfg.name,
        cfg.ticks,
        cfg.scenario,
        cfg.flips.len(),
        cfg.frame_every
    );
    let rep = run_soak(&cfg)?;
    println!(
        "  served {} / shed {} / invalid {} — {} frames, demotions peak {}, {} invariants held",
        rep.served,
        rep.shed,
        rep.invalid,
        rep.frames,
        rep.demotions,
        rep.checks.len()
    );
    let path = out.unwrap_or_else(|| PathBuf::from("BENCH_soak.json"));
    write_bench_file(&path, "soak", Value::Arr(vec![rep.record]))?;
    println!("wrote {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_soaks_stretch_their_base_scenarios() {
        for cfg in soak_catalog() {
            cfg.validate().unwrap();
            let base = resolve_base(&cfg.scenario).unwrap();
            assert!(
                cfg.ticks >= 3 * base.ticks,
                "{}: a soak must run well past its base trace",
                cfg.name
            );
            // the ring must hold every sampled frame (delta-sum exactness)
            let expected_frames = cfg.ticks.div_ceil(cfg.frame_every);
            assert!(cfg.frame_cap >= expected_frames, "{}: ring would evict", cfg.name);
            assert!(!cfg.flips.is_empty(), "{}: built-ins exercise flips", cfg.name);
        }
    }

    #[test]
    fn config_parses_from_json_with_defaults_and_rejects_bad_flips() {
        let v = crate::json::parse(
            r#"{"ticks": 40, "flips": [{"at_tick": 10, "kind": "policy_toggle"}]}"#,
        )
        .unwrap();
        let cfg = SoakConfig::from_json(&v).unwrap();
        assert_eq!(cfg.name, "custom-soak");
        assert_eq!(cfg.scenario, "burst-storm");
        assert_eq!((cfg.ticks, cfg.frame_every, cfg.frame_cap), (40, 8, 64));
        assert_eq!(cfg.flips.len(), 1);
        assert!(!cfg.plan.rules.is_empty(), "absent plan defaults to the traced plan");

        let empty_plan =
            crate::json::parse(r#"{"ticks": 4, "plan": {}}"#).unwrap();
        assert!(SoakConfig::from_json(&empty_plan).unwrap().plan.rules.is_empty());

        let late_flip = crate::json::parse(
            r#"{"ticks": 4, "flips": [{"at_tick": 9, "kind": "policy_toggle"}]}"#,
        )
        .unwrap();
        assert!(SoakConfig::from_json(&late_flip).is_err(), "flip beyond the run");

        let bad_kind = crate::json::parse(
            r#"{"ticks": 4, "flips": [{"at_tick": 1, "kind": "warp_core"}]}"#,
        )
        .unwrap();
        assert!(SoakConfig::from_json(&bad_kind).is_err());

        let no_bytes = crate::json::parse(
            r#"{"ticks": 4, "flips": [{"at_tick": 1, "kind": "ladder_budget"}]}"#,
        )
        .unwrap();
        assert!(SoakConfig::from_json(&no_bytes).is_err());
    }

    #[test]
    fn unknown_scenario_is_an_error() {
        let cfg = SoakConfig {
            name: "x".into(),
            scenario: "no-such-shape".into(),
            ticks: 4,
            seed: 1,
            frame_every: 2,
            frame_cap: 8,
            flips: Vec::new(),
            plan: LatencyPlan::none(),
        };
        assert!(run_soak(&cfg).is_err());
    }
}
