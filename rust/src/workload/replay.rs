//! The replay driver: a scenario trace through the REAL serving stack,
//! with exact-accounting and SLO invariants asserted against the obs
//! registry.
//!
//! The stack under test is the production wiring, not a stub:
//! [`DecoderBackend`] decoding actual SEFP logits off a
//! [`PrecisionLadder`], the deadline/age-aware [`DynamicBatcher`], and
//! the routing policy the scenario selects
//! ([`AdaptivePolicy`](crate::policy::AdaptivePolicy) when
//! `Scenario::adaptive`).  Each tick submits one arrival batch and
//! drains it; because traces are pure functions of the seed and the
//! queue cap is a global count, the driver can compute expected
//! served/shed/invalid/clamp/token totals from the trace alone and
//! require the registry to match them exactly.
//!
//! The emitted record splits into `det` (byte-identical run to run:
//! accounting totals, and the per-precision serve counts under static
//! routing) and `wall` (latency percentiles, scheduling counts, probe
//! stats, the full metric snapshot — anything downstream of the wall
//! clock; adaptive routing reacts to real latencies, so its
//! per-precision split lives here too).

use crate::config::{PolicyConfig, ServeConfig};
use crate::infer::SimConfig;
use crate::json::{self, Value};
use crate::sefp::Precision;
use crate::serve::{
    demo_decoder_params, DecoderBackend, DynamicBatcher, PrecisionLadder, Router, SchedPolicy,
    Server,
};

use super::scenario::Scenario;
use super::trace::generate;

/// Expectations computed from the trace alone, never from the server.
#[derive(Debug, Default)]
struct Expected {
    served: u64,
    invalid: u64,
    shed: u64,
    clamps: u64,
    tokens: u64,
}

/// One scenario's outcome: headline counts for the console, the names of
/// every invariant that held, and the bench record.
#[derive(Debug)]
pub struct ReplayReport {
    pub name: &'static str,
    pub served: u64,
    pub shed: u64,
    pub invalid: u64,
    pub clamps: u64,
    pub checks: Vec<&'static str>,
    pub record: Value,
}

/// The fixed model every scenario serves: big enough for real SEFP
/// matmuls + KV attention, small enough that the full catalog replays in
/// seconds.  Seed and shape are part of the determinism contract — the
/// same ladder bytes on every run.
pub(crate) fn replay_sim_config() -> SimConfig {
    SimConfig { d_model: 64, d_ff: 128, n_layers: 2, vocab: 256, context: 16 }
}

fn serve_config(sc: &Scenario) -> ServeConfig {
    ServeConfig {
        max_batch: sc.max_batch,
        queue_cap: sc.queue_cap,
        policy: PolicyConfig {
            adaptive: sc.adaptive,
            // scenario traces are short next to the serving defaults:
            // shrink the windows so the adaptive loop can actually act
            // (and be observed) within one replay
            window: 64,
            min_samples: 8,
            cooldown: 8,
            ..PolicyConfig::default()
        },
        ..ServeConfig::default()
    }
}

/// Replay one scenario end to end, asserting every invariant; any
/// violation is an error naming the scenario and the broken contract.
pub fn run_scenario(sc: &Scenario) -> anyhow::Result<ReplayReport> {
    anyhow::ensure!(sc.ticks >= 2, "scenario {} needs at least 2 ticks", sc.name);
    let cfg = serve_config(sc);
    let sim = replay_sim_config();
    let params = demo_decoder_params(&sim, 5);
    let ladder = PrecisionLadder::from_params(&params).with_budget(cfg.ladder_budget_bytes);
    let backend = DecoderBackend::from_ladder(&ladder, cfg.max_batch, sim.context, cfg.decode_threads)?;
    let batcher =
        DynamicBatcher::new(cfg.max_batch, cfg.queue_cap).with_policy(SchedPolicy::from_config(&cfg));
    let router = Router::from_config(cfg.clone());
    let mut server = Server::new(backend, ladder, router, batcher).with_seed(sc.seed);

    let trace = generate(sc);
    let total_events: u64 = trace.iter().map(|t| t.len() as u64).sum();
    // decode budgets by request id (ids are sequential across the trace)
    let mut max_new_by_id: Vec<usize> = Vec::with_capacity(total_events as usize);
    for ev in trace.iter().flatten() {
        anyhow::ensure!(
            ev.req.id as usize == max_new_by_id.len(),
            "trace ids must be sequential"
        );
        max_new_by_id.push(ev.req.max_new_tokens);
    }

    let mut exp = Expected::default();
    for events in &trace {
        let mut accepted = 0u64;
        for ev in events {
            let ok = server.submit(ev.req.clone());
            if ev.expect_invalid {
                anyhow::ensure!(
                    !ok,
                    "scenario {}: malformed request {} was admitted",
                    sc.name,
                    ev.req.id
                );
                exp.invalid += 1;
            } else if ok {
                exp.served += 1;
                exp.tokens += ev.req.max_new_tokens as u64;
                accepted += 1;
            } else {
                // backpressure may only fire once this tick has filled
                // the whole (global) queue — anything else is a shed bug
                anyhow::ensure!(
                    accepted >= sc.queue_cap as u64,
                    "scenario {}: request {} shed below queue capacity",
                    sc.name,
                    ev.req.id
                );
                exp.shed += 1;
            }
            if ev.expect_clamp {
                exp.clamps += 1;
            }
        }
        let responses = server.process_all()?;
        anyhow::ensure!(
            responses.len() as u64 == accepted,
            "scenario {}: tick admitted {accepted} but served {}",
            sc.name,
            responses.len()
        );
        for resp in &responses {
            anyhow::ensure!(
                server.router.ladder().contains(&resp.precision),
                "scenario {}: request {} served off-ladder at {:?}",
                sc.name,
                resp.id,
                resp.precision
            );
            let want = max_new_by_id.get(resp.id as usize).copied().ok_or_else(|| {
                anyhow::anyhow!("scenario {}: response id {} outside trace", sc.name, resp.id)
            })?;
            // EOS is unreachable at vocab 256, so every admitted request
            // must decode its full budget — short generations mean rows
            // were dropped or windows desynced
            anyhow::ensure!(
                resp.tokens.len() == want,
                "scenario {}: request {} generated {} of {} tokens",
                sc.name,
                resp.id,
                resp.tokens.len(),
                want
            );
        }
    }

    // snapshot syncs the ladder/policy/backend gauges, so take it before
    // deriving the stats view the invariants read
    let snapshot = server.metrics_snapshot();
    let stats = server.stats();

    let mut checks: Vec<&'static str> = Vec::new();
    macro_rules! check {
        ($name:literal, $cond:expr) => {
            anyhow::ensure!(
                $cond,
                "scenario {}: invariant {} violated ({})",
                sc.name,
                $name,
                stringify!($cond)
            );
            checks.push($name);
        };
    }

    check!(
        "exact-accounting",
        stats.served == exp.served && stats.invalid == exp.invalid && stats.rejected == exp.shed
    );
    check!("conservation", stats.served + stats.rejected + stats.invalid == total_events);
    check!("token-accounting", stats.tokens_generated == exp.tokens);
    check!("forced-clamp-accounting", stats.forced_clamps == exp.clamps);
    check!("queue-bounded", stats.queue_peak_depth <= sc.queue_cap as u64);
    // the depth gauge samples at admission AND shed time, so a burst
    // that overruns the queue must pin the peak exactly at the cap —
    // this is the regression rail for the shed-path gauge sample
    check!(
        "storm-peak-pins-the-cap",
        !sc.slo.expect_shed || stats.queue_peak_depth == sc.queue_cap as u64
    );
    check!("min-served", stats.served >= sc.slo.min_served);
    check!("queue-p95-slo", stats.queue_ms.p95() <= sc.slo.queue_p95_ms);
    check!("compute-p95-slo", stats.compute_ms.p95() <= sc.slo.compute_p95_ms);
    check!("no-starvation", stats.queue_ms.max <= sc.slo.starvation_ms);
    check!(
        "probe-agreement-floor",
        stats.probes_run == 0 || stats.probe_agreement.mean() >= sc.slo.probe_agreement_floor
    );
    check!("backpressure-exercised", !sc.slo.expect_shed || exp.shed > 0);
    check!("clamping-exercised", !sc.slo.expect_clamps || exp.clamps > 0);

    let per_precision = per_precision_json(&stats.per_precision);
    let mut det = vec![
        ("served", json::n(stats.served as f64)),
        ("invalid", json::n(stats.invalid as f64)),
        ("shed", json::n(stats.rejected as f64)),
        ("forced_clamps", json::n(stats.forced_clamps as f64)),
        ("tokens", json::n(stats.tokens_generated as f64)),
        ("ticks", json::n(sc.ticks as f64)),
        ("queue_peak_depth", json::n(stats.queue_peak_depth as f64)),
    ];
    let mut wall = vec![
        ("batches", json::n(stats.batches as f64)),
        ("decode_steps", json::n(stats.decode_steps as f64)),
        ("queue_p50_ms", json::n(stats.queue_ms.p50())),
        ("queue_p95_ms", json::n(stats.queue_ms.p95())),
        ("queue_max_ms", json::n(stats.queue_ms.max)),
        ("compute_p50_ms", json::n(stats.compute_ms.p50())),
        ("compute_p95_ms", json::n(stats.compute_ms.p95())),
        ("probes_run", json::n(stats.probes_run as f64)),
        (
            "probe_agreement_mean",
            json::n(if stats.probes_run > 0 { stats.probe_agreement.mean() } else { 0.0 }),
        ),
        ("promotions", json::n(stats.promotions as f64)),
        ("demotions", json::n(stats.demotions as f64)),
        ("throughput_rps", json::n(stats.throughput_rps())),
        ("throughput_tps", json::n(stats.throughput_tps())),
        ("wall_secs", json::n(stats.wall_secs)),
        ("metrics", snapshot),
    ];
    if sc.adaptive {
        // adaptive routing steers by real latencies: which rung served a
        // request is timing-dependent, so the split is a wall fact here
        wall.push(("per_precision", per_precision));
    } else {
        det.push(("per_precision", per_precision));
    }

    let record = json::obj(vec![
        ("name", json::s(sc.name)),
        ("kind", json::s(sc.kind.name())),
        ("seed", json::n(sc.seed as f64)),
        ("adaptive", Value::Bool(sc.adaptive)),
        ("det", json::obj(det)),
        ("wall", json::obj(wall)),
        ("checks", Value::Arr(checks.iter().map(|c| json::s(*c)).collect())),
    ]);

    Ok(ReplayReport {
        name: sc.name,
        served: stats.served,
        shed: stats.rejected,
        invalid: stats.invalid,
        clamps: stats.forced_clamps,
        checks,
        record,
    })
}

fn per_precision_json(pp: &[(Precision, u64)]) -> Value {
    Value::Arr(
        pp.iter()
            .map(|(p, c)| {
                json::obj(vec![
                    ("width", json::n(p.m() as f64)),
                    ("served", json::n(*c as f64)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{catalog, Kind};

    #[test]
    fn serve_config_carries_the_scenario_knobs() {
        for sc in catalog() {
            let cfg = serve_config(&sc);
            assert_eq!(cfg.max_batch, sc.max_batch);
            assert_eq!(cfg.queue_cap, sc.queue_cap);
            assert_eq!(cfg.policy.adaptive, sc.adaptive);
            assert!(cfg.policy.min_samples <= cfg.policy.window);
        }
    }

    #[test]
    fn per_precision_serializes_width_count_pairs() {
        let v = per_precision_json(&[(Precision::of(4), 7), (Precision::of(8), 2)]);
        let text = v.to_string();
        assert_eq!(
            text,
            r#"[{"served":7,"width":4},{"served":2,"width":8}]"#
        );
    }

    /// One end-to-end replay in-module (the tier-1 integration test
    /// covers the full catalog): the storm scenario, because it
    /// exercises the most machinery — backpressure, refusal accounting,
    /// and recovery across quiet ticks.
    #[test]
    fn burst_storm_replays_clean() {
        let sc = catalog().into_iter().find(|s| s.kind == Kind::BurstStorm).unwrap();
        // shrink for test time; invariants are tick-count independent
        let sc = Scenario { ticks: 6, ..sc };
        let rep = run_scenario(&sc).unwrap();
        assert!(rep.shed > 0, "the storm must overrun the queue");
        assert!(rep.checks.contains(&"exact-accounting"));
        assert!(rep.checks.contains(&"backpressure-exercised"));
        assert_eq!(rep.record.req_str("name").unwrap(), "burst-storm");
        assert!(rep.record.get("det").unwrap().get("shed").unwrap().as_f64().unwrap() > 0.0);
    }
}
