//! The fixed binary skeleton of the `.sefp` container (format v1,
//! frozen): header and per-tensor index records.
//!
//! Everything here is little-endian and fixed-size so the reader can
//! validate the whole skeleton with pure bounds arithmetic before it
//! trusts a single offset.  The full container layout is specified in
//! the `artifact` module docs; the byte-level freeze is enforced by
//! `rust/tests/artifact_golden.rs`.

use crate::sefp::Precision;

/// File magic, bytes 0..8 of every `.sefp` artifact.
pub const MAGIC: [u8; 8] = *b"OTARSEFP";
/// Current (and only) format version.
pub const VERSION: u32 = 1;
/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 64;
/// Fixed per-tensor index record size in bytes.
pub const INDEX_ENTRY_LEN: usize = 48;
/// Section alignment: manifest/index/tensor blobs start on this.
pub const ALIGN: usize = 8;

/// Round `x` up to the next [`ALIGN`] boundary.
pub fn align_up(x: usize) -> usize {
    x.div_ceil(ALIGN) * ALIGN
}

/// Byte length of a packed tensor blob at precision `p`: 5-bit shared
/// exponents + sign plane + `p.m()` mantissa bit-planes, each region
/// starting on a fresh byte.  The single source of the blob-size
/// arithmetic — the writer asserts against it and the reader rejects
/// index entries that disagree with it.  Taking [`Precision`] (not a
/// raw `m: u8`) keeps the width validated end to end.
pub fn packed_blob_len(len: usize, n_groups: usize, p: Precision) -> usize {
    (n_groups * 5).div_ceil(8) + len.div_ceil(8) * (1 + p.m() as usize)
}

/// Overflow-checked twin of [`packed_blob_len`] for UNTRUSTED index
/// fields: a crafted container with `len`/`n_groups` near `usize::MAX`
/// must produce a validation error, not an arithmetic panic.
pub fn checked_packed_blob_len(len: usize, n_groups: usize, p: Precision) -> Option<usize> {
    let exp = n_groups.checked_mul(5)?.div_ceil(8);
    let planes = len.div_ceil(8).checked_mul(1 + p.m() as usize)?;
    exp.checked_add(planes)
}

#[inline]
pub(crate) fn read_u32(buf: &[u8], off: usize) -> u32 {
    u32::from_le_bytes([buf[off], buf[off + 1], buf[off + 2], buf[off + 3]])
}

#[inline]
pub(crate) fn read_u64(buf: &[u8], off: usize) -> u64 {
    let mut b = [0u8; 8];
    b.copy_from_slice(&buf[off..off + 8]);
    u64::from_le_bytes(b)
}

/// Parsed fixed header (bytes 0..64).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Header {
    pub version: u32,
    pub flags: u32,
    /// absolute byte offset of the embedded JSON manifest
    pub manifest_off: u64,
    pub manifest_len: u64,
    /// absolute byte offset of the first index record
    pub index_off: u64,
    pub tensor_count: u64,
    /// absolute byte offset of the first tensor blob
    pub data_off: u64,
    /// total file length — lets the reader reject truncation up front
    pub file_len: u64,
}

impl Header {
    pub fn to_bytes(&self) -> [u8; HEADER_LEN] {
        let mut b = [0u8; HEADER_LEN];
        b[..8].copy_from_slice(&MAGIC);
        b[8..12].copy_from_slice(&self.version.to_le_bytes());
        b[12..16].copy_from_slice(&self.flags.to_le_bytes());
        b[16..24].copy_from_slice(&self.manifest_off.to_le_bytes());
        b[24..32].copy_from_slice(&self.manifest_len.to_le_bytes());
        b[32..40].copy_from_slice(&self.index_off.to_le_bytes());
        b[40..48].copy_from_slice(&self.tensor_count.to_le_bytes());
        b[48..56].copy_from_slice(&self.data_off.to_le_bytes());
        b[56..64].copy_from_slice(&self.file_len.to_le_bytes());
        b
    }

    pub fn parse(buf: &[u8]) -> anyhow::Result<Header> {
        anyhow::ensure!(
            buf.len() >= HEADER_LEN,
            "file too short for a .sefp header ({} bytes)",
            buf.len()
        );
        anyhow::ensure!(buf[..8] == MAGIC, "bad magic: not a .sefp artifact");
        let version = read_u32(buf, 8);
        anyhow::ensure!(
            version == VERSION,
            "unsupported .sefp format version {version} (this reader supports v{VERSION})"
        );
        let flags = read_u32(buf, 12);
        anyhow::ensure!(
            flags == 0,
            "unsupported .sefp flags {flags:#x} (v1 reserves the flag field zero; a set \
             flag means a layout this reader would misinterpret)"
        );
        Ok(Header {
            version,
            flags,
            manifest_off: read_u64(buf, 16),
            manifest_len: read_u64(buf, 24),
            index_off: read_u64(buf, 32),
            tensor_count: read_u64(buf, 40),
            data_off: read_u64(buf, 48),
            file_len: read_u64(buf, 56),
        })
    }
}

/// How a tensor's blob is stored.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TensorKind {
    /// SEFP bit-planes (quantized weights): exponents + sign + mantissa
    /// planes, truncatable at load by taking a plane prefix.
    Packed,
    /// Raw little-endian f32 (non-quantized tensors: norm gains,
    /// pos_embed) — stored once, never per rung.
    RawF32,
}

impl TensorKind {
    pub const fn code(self) -> u32 {
        match self {
            TensorKind::Packed => 0,
            TensorKind::RawF32 => 1,
        }
    }

    pub fn from_code(code: u32) -> anyhow::Result<Self> {
        match code {
            0 => Ok(TensorKind::Packed),
            1 => Ok(TensorKind::RawF32),
            other => anyhow::bail!("unknown tensor kind {other}"),
        }
    }
}

/// One fixed-size index record (48 bytes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IndexEntry {
    pub kind: TensorKind,
    /// logical element count
    pub len: u64,
    /// SEFP group count (0 for raw f32)
    pub n_groups: u64,
    /// absolute byte offset of this tensor's blob
    pub data_off: u64,
    /// blob length in bytes (excludes alignment padding)
    pub data_len: u64,
    /// FNV-1a 64 of the blob bytes
    pub checksum: u64,
}

impl IndexEntry {
    pub fn to_bytes(&self) -> [u8; INDEX_ENTRY_LEN] {
        let mut b = [0u8; INDEX_ENTRY_LEN];
        b[..4].copy_from_slice(&self.kind.code().to_le_bytes());
        // bytes 4..8 reserved (zero)
        b[8..16].copy_from_slice(&self.len.to_le_bytes());
        b[16..24].copy_from_slice(&self.n_groups.to_le_bytes());
        b[24..32].copy_from_slice(&self.data_off.to_le_bytes());
        b[32..40].copy_from_slice(&self.data_len.to_le_bytes());
        b[40..48].copy_from_slice(&self.checksum.to_le_bytes());
        b
    }

    /// Parse one record from exactly [`INDEX_ENTRY_LEN`] bytes.
    pub fn parse(buf: &[u8]) -> anyhow::Result<IndexEntry> {
        anyhow::ensure!(buf.len() == INDEX_ENTRY_LEN, "index record must be 48 bytes");
        anyhow::ensure!(read_u32(buf, 4) == 0, "reserved index bytes must be zero in v1");
        Ok(IndexEntry {
            kind: TensorKind::from_code(read_u32(buf, 0))?,
            len: read_u64(buf, 8),
            n_groups: read_u64(buf, 16),
            data_off: read_u64(buf, 24),
            data_len: read_u64(buf, 32),
            checksum: read_u64(buf, 40),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_roundtrip() {
        let h = Header {
            version: VERSION,
            flags: 0,
            manifest_off: 64,
            manifest_len: 123,
            index_off: 192,
            tensor_count: 3,
            data_off: 336,
            file_len: 4096,
        };
        assert_eq!(Header::parse(&h.to_bytes()).unwrap(), h);
    }

    #[test]
    fn header_rejects_bad_magic_and_version() {
        let h = Header {
            version: VERSION,
            flags: 0,
            manifest_off: 64,
            manifest_len: 0,
            index_off: 64,
            tensor_count: 0,
            data_off: 64,
            file_len: 64,
        };
        let mut b = h.to_bytes();
        b[0] ^= 0xff;
        assert!(Header::parse(&b).is_err());
        let mut b = h.to_bytes();
        b[8] = 2; // version 2
        assert!(Header::parse(&b).is_err());
        let mut b = h.to_bytes();
        b[12] = 1; // v1 reserves flags zero — a set flag must be refused
        assert!(Header::parse(&b).is_err());
        assert!(Header::parse(&h.to_bytes()[..32]).is_err());
    }

    #[test]
    fn index_roundtrip_and_kind_codes() {
        let e = IndexEntry {
            kind: TensorKind::RawF32,
            len: 16,
            n_groups: 0,
            data_off: 512,
            data_len: 64,
            checksum: 0xdead_beef_cafe_f00d,
        };
        assert_eq!(IndexEntry::parse(&e.to_bytes()).unwrap(), e);
        assert!(TensorKind::from_code(2).is_err());
        let mut b = e.to_bytes();
        b[4] = 1; // reserved bytes must stay zero
        assert!(IndexEntry::parse(&b).is_err());
    }

    #[test]
    fn blob_len_arithmetic() {
        // 100 elems, 2 groups, m=4: exp = ceil(10/8) = 2, stride = 13,
        // planes = (1 sign + 4 mantissa) * 13
        assert_eq!(packed_blob_len(100, 2, Precision::of(4)), 2 + 13 * 5);
        assert_eq!(packed_blob_len(0, 0, Precision::of(8)), 0);
        assert_eq!(align_up(0), 0);
        assert_eq!(align_up(1), 8);
        assert_eq!(align_up(8), 8);
        assert_eq!(align_up(9), 16);
    }
}
