//! The `.sefp` on-device artifact: a versioned packed-weight container
//! for the single SEFP master, with **zero-copy truncate-at-load**.
//!
//! OTARo's deployment premise is that ONE stored model yields every
//! bit-width by mantissa truncation (paper fig. 1).  The f32 checkpoint
//! path (`init_params.bin` + JSON sidecar) stores 4 bytes/weight and
//! must re-encode to SEFP on every startup; this container stores the
//! packed planes themselves, so the on-device artifact is the paper's
//! `(1+m)/elem + 5/group` bits, the reader never materializes an f32
//! master, and a view at a lower rung borrows (and gathers) strictly
//! fewer bytes — the file is read and checksummed once, whole, at open.
//!
//! # Container layout (format v1, little-endian, frozen)
//!
//! ```text
//! offset               section
//! 0                    header, 64 bytes:
//!                        0..8   magic  "OTARSEFP"
//!                        8..12  u32 version (= 1)
//!                        12..16 u32 flags   (= 0 in v1)
//!                        16..24 u64 manifest_off   24..32 u64 manifest_len
//!                        32..40 u64 index_off      40..48 u64 tensor_count
//!                        48..56 u64 data_off       56..64 u64 file_len
//! manifest_off         embedded JSON manifest: group_size, rounding,
//!                      ladder top precision, tensor names/shapes/
//!                      quantized flags, optional model config
//! index_off            tensor_count x 48-byte index records:
//!                        u32 kind (0 packed / 1 raw f32), u32 reserved,
//!                        u64 len, u64 n_groups, u64 data_off,
//!                        u64 data_len, u64 checksum (FNV-1a 64 of blob)
//! data_off             tensor blobs, each 8-byte aligned:
//!                        packed:  exponent plane (5 bits/group,
//!                                 LSB-first, byte-padded)
//!                                 sign plane     (1 bit/elem)
//!                                 mantissa planes, top.m() of them,
//!                                 MSB FIRST, each ceil(len/8) bytes
//!                        raw f32: len x f32 LE
//! ```
//!
//! The mantissa bit-planes are stored most-significant-bit first, so a
//! view at rung `p` borrows the exponent plane, the sign plane, and the
//! first `p.m()` mantissa planes — a plane *prefix*.  That makes
//! truncate-at-load literally free: the integer shift
//! `sig >> (top.m() - p.m())` is performed by *not borrowing* the low
//! planes, and under `Rounding::Trunc` the result is bit-identical to
//! re-encoding the original weights at `p` (the `SefpCodec`
//! ladder-exactness contract, property-tested in
//! `rust/tests/artifact_props.rs`).
//!
//! # Versioning policy
//!
//! v1 is frozen: byte-level stability is enforced by the golden test in
//! `rust/tests/artifact_golden.rs` (hand-computed plane bytes + FNV
//! known-answer vectors).  Any layout change bumps `version` and keeps
//! this reader refusing unknown versions loudly; `flags` is reserved
//! zero in v1 so v1 readers also refuse files that set it (reserved
//! index bytes likewise).  Integrity is per-tensor: a flipped bit
//! anywhere in a blob fails that tensor's FNV-1a 64 check at open.
//!
//! # Wiring
//!
//! * [`writer::pack_params`] / [`writer::write_artifact`] — f32 master
//!   in, container bytes out (deterministic).
//! * [`reader::Artifact`] — validate once, then [`reader::Artifact::view`]
//!   hands out borrowed [`reader::TensorView`]s at any rung.
//! * `serve::PrecisionLadder::from_artifact` builds the serving ladder
//!   straight from the container (integer plane gather, no f32).
//! * `coordinator::Trainer::save_checkpoint` writes the `.sefp` next to
//!   every f32 checkpoint; `runtime::Manifest` records the artifact
//!   under the `sefp_master` key.
//! * CLI: `otaro pack` (f32 checkpoint -> `.sefp`) and `otaro inspect`
//!   (header/index/ladder report); `benches/bench_artifact.rs` measures
//!   pack/open/view against the f32-parse-then-encode path.

pub mod checksum;
pub mod format;
pub mod reader;
pub mod writer;

pub use checksum::fnv1a64;
pub use format::{
    align_up, packed_blob_len, Header, IndexEntry, TensorKind, ALIGN, HEADER_LEN,
    INDEX_ENTRY_LEN, MAGIC, VERSION,
};
pub use reader::{Artifact, TensorView};
pub use writer::{pack_params, write_artifact, ArtifactMeta, TensorMeta};
