//! `.sefp` writer: encode an f32 `ParamStore` once at the ladder top and
//! lay the planes out in the v1 container.
//!
//! Packing is the ONLY place f32 weights are touched; everything
//! downstream of the written file is integer work.  The output is fully
//! deterministic — same weights + same [`ArtifactMeta`] produce
//! byte-identical files (frozen by `rust/tests/artifact_golden.rs`).

use std::path::Path;

use crate::json::{self, Value};
use crate::runtime::manifest::ModelConfig;
use crate::runtime::ParamStore;
use crate::sefp::packed::BitVec;
use crate::sefp::{Precision, Rounding, SefpSpec, SefpTensor, EXP_MIN};

use super::checksum::fnv1a64;
use super::format::{
    align_up, packed_blob_len, Header, IndexEntry, TensorKind, HEADER_LEN, INDEX_ENTRY_LEN,
    VERSION,
};

/// Per-tensor metadata carried in the embedded manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    /// SEFP-packed (true) vs raw f32 passthrough (false) — mirrors the
    /// training graph's quantization rule
    pub quantized: bool,
}

/// Container-level metadata: what the packed master IS.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// ladder top: the precision the mantissa planes are stored at;
    /// every rung at or below it opens zero-copy
    pub top: Precision,
    pub group_size: usize,
    /// rounding the master was encoded with (truncate-at-load equals
    /// re-encoding only under `Rounding::Trunc` — the ladder-exactness
    /// contract)
    pub rounding: Rounding,
    /// model architecture, when packing from a training manifest
    pub config: Option<ModelConfig>,
}

impl ArtifactMeta {
    /// Repo defaults at `top`: group size 64, round-toward-zero, no
    /// model config.
    pub fn new(top: Precision) -> Self {
        ArtifactMeta {
            top,
            group_size: crate::sefp::GROUP_SIZE,
            rounding: Rounding::Trunc,
            config: None,
        }
    }

    /// The codec spec this artifact's planes were produced with.
    pub fn spec(&self) -> SefpSpec {
        SefpSpec::new(self.top)
            .with_group_size(self.group_size)
            .with_rounding(self.rounding)
    }
}

/// Serialize the embedded manifest (deterministic: object keys are
/// emitted sorted).
fn manifest_json(meta: &ArtifactMeta, tensors: &[TensorMeta]) -> String {
    let mut fields: Vec<(&str, Value)> = Vec::new();
    if let Some(cfg) = &meta.config {
        fields.push(("config", cfg.to_json()));
    }
    fields.push(("group_size", json::n(meta.group_size as f64)));
    fields.push(("rounding", json::s(meta.rounding.to_string())));
    fields.push((
        "tensors",
        json::arr(
            tensors
                .iter()
                .map(|t| {
                    json::obj(vec![
                        ("name", json::s(t.name.clone())),
                        ("quantized", Value::Bool(t.quantized)),
                        (
                            "shape",
                            json::arr(t.shape.iter().map(|&d| json::n(d as f64)).collect()),
                        ),
                    ])
                })
                .collect(),
        ),
    ));
    fields.push(("top", json::n(meta.top.m() as f64)));
    json::obj(fields).to_string()
}

/// Bit-plane layout of one quantized tensor: 5-bit shared exponents,
/// then the sign plane, then `m` mantissa planes ordered most
/// significant bit first — so that opening at a lower rung is a plane
/// *prefix*, not a re-pack.
fn pack_planes(t: &SefpTensor) -> Vec<u8> {
    let m = t.precision.m() as usize;
    let stride = t.len.div_ceil(8);
    let exp_bytes = (t.n_groups() * 5).div_ceil(8);
    let mut blob = vec![0u8; exp_bytes + stride * (1 + m)];
    let mut exps = BitVec::with_capacity(t.n_groups() * 5);
    for &e in &t.exponents {
        exps.push_bits((e as i32 - EXP_MIN) as u32, 5);
    }
    blob[..exps.data.len()].copy_from_slice(&exps.data);
    let (sign, mant) = blob[exp_bytes..].split_at_mut(stride);
    for (i, &s) in t.significands.iter().enumerate() {
        let byte = i / 8;
        let bit = 1u8 << (i % 8);
        if s < 0 {
            sign[byte] |= bit;
        }
        let mag = s.unsigned_abs();
        for (k, plane) in mant.chunks_exact_mut(stride).enumerate() {
            if (mag >> (m - 1 - k)) & 1 == 1 {
                plane[byte] |= bit;
            }
        }
    }
    debug_assert_eq!(blob.len(), packed_blob_len(t.len, t.n_groups(), t.precision));
    blob
}

/// Pack a full parameter store into v1 container bytes.  Quantized
/// tensors are SEFP-encoded at `meta.top` and stored as bit-planes;
/// non-quantized tensors are stored as raw f32 once.
pub fn pack_params(params: &ParamStore, meta: &ArtifactMeta) -> Vec<u8> {
    assert!(meta.group_size >= 1, "artifact group_size must be positive");
    let spec = meta.spec();
    let tensors: Vec<TensorMeta> = params
        .names
        .iter()
        .zip(&params.shapes)
        .zip(&params.quantized)
        .map(|((name, shape), &quantized)| TensorMeta {
            name: name.clone(),
            shape: shape.clone(),
            quantized,
        })
        .collect();
    let manifest = manifest_json(meta, &tensors);

    let mut blobs: Vec<(TensorKind, u64, u64, Vec<u8>)> = Vec::with_capacity(params.tensors.len());
    for (i, t) in params.tensors.iter().enumerate() {
        if params.quantized[i] {
            let enc = SefpTensor::encode(t, &spec);
            blobs.push((
                TensorKind::Packed,
                t.len() as u64,
                enc.n_groups() as u64,
                pack_planes(&enc),
            ));
        } else {
            let mut raw = Vec::with_capacity(t.len() * 4);
            for v in t {
                raw.extend_from_slice(&v.to_le_bytes());
            }
            blobs.push((TensorKind::RawF32, t.len() as u64, 0, raw));
        }
    }

    let manifest_off = HEADER_LEN;
    let index_off = align_up(manifest_off + manifest.len());
    let data_off = align_up(index_off + blobs.len() * INDEX_ENTRY_LEN);
    let mut index = Vec::with_capacity(blobs.len());
    let mut off = data_off;
    for (kind, len, n_groups, blob) in &blobs {
        index.push(IndexEntry {
            kind: *kind,
            len: *len,
            n_groups: *n_groups,
            data_off: off as u64,
            data_len: blob.len() as u64,
            checksum: fnv1a64(blob),
        });
        off = align_up(off + blob.len());
    }
    // the file ends where its data does — no padding after the final blob
    let file_len = index
        .last()
        .map(|e| (e.data_off + e.data_len) as usize)
        .unwrap_or(data_off);
    let header = Header {
        version: VERSION,
        flags: 0,
        manifest_off: manifest_off as u64,
        manifest_len: manifest.len() as u64,
        index_off: index_off as u64,
        tensor_count: blobs.len() as u64,
        data_off: data_off as u64,
        file_len: file_len as u64,
    };
    let mut out = vec![0u8; file_len];
    out[..HEADER_LEN].copy_from_slice(&header.to_bytes());
    out[manifest_off..manifest_off + manifest.len()].copy_from_slice(manifest.as_bytes());
    for (i, e) in index.iter().enumerate() {
        let at = index_off + i * INDEX_ENTRY_LEN;
        out[at..at + INDEX_ENTRY_LEN].copy_from_slice(&e.to_bytes());
    }
    for (e, (_, _, _, blob)) in index.iter().zip(&blobs) {
        let at = e.data_off as usize;
        out[at..at + blob.len()].copy_from_slice(blob);
    }
    out
}

/// Pack and write to `path` (directories created as needed).  Returns
/// the number of bytes written.
pub fn write_artifact(
    path: &Path,
    params: &ParamStore,
    meta: &ArtifactMeta,
) -> anyhow::Result<u64> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let bytes = pack_params(params, meta);
    std::fs::write(path, &bytes)
        .map_err(|e| anyhow::anyhow!("cannot write artifact {path:?}: {e}"))?;
    Ok(bytes.len() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_is_sorted_and_minimal() {
        let meta = ArtifactMeta::new(Precision::of(8));
        let tensors = [TensorMeta { name: "w".into(), shape: vec![2, 3], quantized: true }];
        let m = manifest_json(&meta, &tensors);
        assert_eq!(
            m,
            r#"{"group_size":64,"rounding":"trunc","tensors":[{"name":"w","quantized":true,"shape":[2,3]}],"top":8}"#
        );
        // config is present when provided, and keys stay sorted
        let meta = ArtifactMeta {
            config: Some(ModelConfig {
                vocab_size: 320,
                d_model: 128,
                n_heads: 4,
                n_layers: 2,
                d_ff: 384,
                max_seq: 64,
                batch_size: 8,
                group_size: 64,
                rounding: "trunc".into(),
            }),
            ..ArtifactMeta::new(Precision::of(8))
        };
        let m = manifest_json(&meta, &tensors);
        assert!(m.starts_with(r#"{"config":{"batch_size":8,"#), "{m}");
        assert!(crate::json::parse(&m).is_ok());
    }

    #[test]
    fn plane_layout_hand_example() {
        // two weights [1.0, -0.5] at m=2, group 2: E=0, step=0.5,
        // sigs = [2, -1]; exp plane = [14] (E-EXP_MIN, 5-bit LSB-first),
        // sign plane = [0b10], mantissa planes MSB->LSB = [0b01, 0b10]
        let spec = SefpSpec::new(Precision::of(2)).with_group_size(2);
        let t = SefpTensor::encode(&[1.0, -0.5], &spec);
        assert_eq!(t.significands, vec![2, -1]);
        assert_eq!(pack_planes(&t), vec![14, 2, 1, 2]);
    }

    #[test]
    fn empty_store_packs_to_skeleton() {
        let params = ParamStore {
            tensors: vec![],
            names: vec![],
            shapes: vec![],
            quantized: vec![],
        };
        let bytes = pack_params(&params, &ArtifactMeta::new(Precision::of(8)));
        let h = Header::parse(&bytes).unwrap();
        assert_eq!(h.tensor_count, 0);
        assert_eq!(h.file_len as usize, bytes.len());
    }
}
