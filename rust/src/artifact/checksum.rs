//! FNV-1a 64 — the `.sefp` container's per-tensor integrity checksum.
//!
//! Chosen over CRC32 because it is a handful of lines with no lookup
//! table, has a published reference (the 64-bit FNV-1a variant) pinned
//! by known-answer tests in `rust/tests/artifact_golden.rs`, and one
//! 8-byte word per tensor is cheap next to the plane data it guards.
//! This is an integrity check against torn writes and bit rot, not a
//! cryptographic authenticator.

/// FNV-1a 64-bit offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a 64 over `bytes` (hash of the empty slice is [`FNV_OFFSET`]).
#[inline]
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn published_vectors() {
        // reference vectors from the FNV specification
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"abc"), 0xe71f_a219_0541_574b);
    }

    #[test]
    fn sensitive_to_every_byte() {
        let a = fnv1a64(&[1, 2, 3, 4]);
        assert_ne!(a, fnv1a64(&[1, 2, 3, 5]));
        assert_ne!(a, fnv1a64(&[0, 2, 3, 4]));
        assert_ne!(a, fnv1a64(&[1, 2, 3, 4, 0]));
    }
}
