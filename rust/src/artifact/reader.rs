//! `.sefp` reader: one contiguous buffer, borrowed zero-copy tensor
//! views, truncate-at-load.
//!
//! [`Artifact::from_bytes`] validates the whole container up front —
//! header bounds, manifest/index consistency, per-tensor blob geometry,
//! and FNV-1a checksums — after which [`Artifact::view`] is pure
//! pointer arithmetic: a [`TensorView`] borrows the exponent plane, the
//! sign plane, and a *prefix* of the mantissa planes, so opening the
//! master at any lower rung borrows strictly fewer bytes and allocates
//! nothing.  (The container file itself is read and checksummed once,
//! whole, at open — the per-rung saving is in what is borrowed,
//! gathered, and kept hot, not in file I/O.)  No f32 master is ever
//! materialized; dequantization is an explicit, separate step.

use std::path::Path;

use crate::json;
use crate::runtime::manifest::ModelConfig;
use crate::sefp::packed::BitVec;
use crate::sefp::{PackedSefp, Precision, Rounding, SefpTensor, EXP_MIN};

use super::checksum::fnv1a64;
use super::format::{
    checked_packed_blob_len, packed_blob_len, Header, IndexEntry, TensorKind, HEADER_LEN,
    INDEX_ENTRY_LEN,
};
use super::writer::{ArtifactMeta, TensorMeta};

/// An open `.sefp` container: the file bytes plus the validated
/// skeleton parsed out of them.
pub struct Artifact {
    buf: Vec<u8>,
    header: Header,
    meta: ArtifactMeta,
    tensors: Vec<TensorMeta>,
    index: Vec<IndexEntry>,
}

impl Artifact {
    /// Read and validate an artifact file.
    pub fn open(path: &Path) -> anyhow::Result<Self> {
        let buf = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("cannot read artifact {path:?}: {e}"))?;
        Self::from_bytes(buf).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
    }

    /// Validate container bytes: header, section bounds, manifest/index
    /// agreement, blob geometry, and every tensor checksum.  After this
    /// returns `Ok`, views are infallible except for caller errors
    /// (bad index, rung above the stored top).
    pub fn from_bytes(buf: Vec<u8>) -> anyhow::Result<Self> {
        let header = Header::parse(&buf)?;
        anyhow::ensure!(
            header.file_len as usize == buf.len(),
            "file is {} bytes but the header records {} (truncated?)",
            buf.len(),
            header.file_len
        );
        let m_off = header.manifest_off as usize;
        let m_len = header.manifest_len as usize;
        let idx_off = header.index_off as usize;
        let count = header.tensor_count as usize;
        let data_off = header.data_off as usize;
        let m_end = m_off
            .checked_add(m_len)
            .ok_or_else(|| anyhow::anyhow!("manifest range overflows"))?;
        let idx_end = count
            .checked_mul(INDEX_ENTRY_LEN)
            .and_then(|n| idx_off.checked_add(n))
            .ok_or_else(|| anyhow::anyhow!("index range overflows"))?;
        anyhow::ensure!(
            m_off >= HEADER_LEN
                && m_end <= idx_off
                && idx_end <= data_off
                && data_off <= buf.len(),
            "corrupt .sefp section layout (manifest {m_off}+{m_len}, index {idx_off}x{count}, \
             data {data_off}, file {})",
            buf.len()
        );

        let mtext = std::str::from_utf8(&buf[m_off..m_end])
            .map_err(|_| anyhow::anyhow!("embedded manifest is not UTF-8"))?;
        let v = json::parse(mtext).map_err(|e| anyhow::anyhow!("embedded manifest: {e}"))?;
        let group_size = v.req_usize("group_size")?;
        anyhow::ensure!(group_size >= 1, "manifest group_size must be positive");
        let rounding: Rounding = v
            .req_str("rounding")?
            .parse()
            .map_err(|e: String| anyhow::anyhow!("manifest rounding: {e}"))?;
        let top = Precision::from_num(
            v.req("top")?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("manifest top not a number"))?,
        )
        .map_err(|e| anyhow::anyhow!("manifest top: {e}"))?;
        let config = match v.get("config") {
            None => None,
            Some(c) => Some(ModelConfig::from_json(c)?),
        };
        let mut tensors = Vec::with_capacity(count);
        for t in v
            .req("tensors")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("manifest tensors not an array"))?
        {
            let mut shape = Vec::new();
            for d in t
                .req("shape")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("tensor shape not an array"))?
            {
                shape.push(
                    d.as_usize().ok_or_else(|| anyhow::anyhow!("shape dim not a number"))?,
                );
            }
            tensors.push(TensorMeta {
                name: t.req_str("name")?,
                quantized: t
                    .req("quantized")?
                    .as_bool()
                    .ok_or_else(|| anyhow::anyhow!("tensor quantized not a bool"))?,
                shape,
            });
        }
        anyhow::ensure!(
            tensors.len() == count,
            "manifest lists {} tensors, header records {count}",
            tensors.len()
        );

        let mut index = Vec::with_capacity(count);
        // walk the index as fixed-size chunks of the checked
        // [idx_off, idx_end) range — no per-record offset arithmetic on
        // the untrusted header fields (chunks_exact yields exactly
        // `count` records, matching `tensors` by the ensure above)
        let records = buf[idx_off..idx_end].chunks_exact(INDEX_ENTRY_LEN);
        for (tm, rec) in tensors.iter().zip(records) {
            let e = IndexEntry::parse(rec)
                .map_err(|err| anyhow::anyhow!("tensor {:?}: {err}", tm.name))?;
            let len = e.len as usize;
            // every arithmetic step below runs on untrusted fields:
            // checked, so a crafted container errors instead of panicking
            let numel = tm
                .shape
                .iter()
                .try_fold(1usize, |acc, &d| acc.checked_mul(d))
                .ok_or_else(|| {
                    anyhow::anyhow!("tensor {:?}: shape {:?} overflows", tm.name, tm.shape)
                })?;
            anyhow::ensure!(
                numel == len,
                "tensor {:?}: shape {:?} has {numel} elements, index records {len}",
                tm.name,
                tm.shape
            );
            let start = e.data_off as usize;
            let end = start
                .checked_add(e.data_len as usize)
                .ok_or_else(|| anyhow::anyhow!("tensor {:?}: blob range overflows", tm.name))?;
            anyhow::ensure!(
                start >= data_off && end <= buf.len(),
                "tensor {:?}: blob [{start}, {end}) out of bounds",
                tm.name
            );
            match e.kind {
                TensorKind::Packed => {
                    anyhow::ensure!(
                        tm.quantized,
                        "tensor {:?}: packed blob but manifest says not quantized",
                        tm.name
                    );
                    let n_groups = len.div_ceil(group_size);
                    anyhow::ensure!(
                        e.n_groups as usize == n_groups,
                        "tensor {:?}: {} groups recorded, {n_groups} expected for {len} \
                         elements at group size {group_size}",
                        tm.name,
                        e.n_groups
                    );
                    let expect =
                        checked_packed_blob_len(len, n_groups, top).ok_or_else(|| {
                            anyhow::anyhow!("tensor {:?}: plane layout size overflows", tm.name)
                        })?;
                    anyhow::ensure!(
                        e.data_len as usize == expect,
                        "tensor {:?}: blob is {} bytes, plane layout expects {expect}",
                        tm.name,
                        e.data_len
                    );
                }
                TensorKind::RawF32 => {
                    anyhow::ensure!(
                        !tm.quantized,
                        "tensor {:?}: raw f32 blob but manifest says quantized",
                        tm.name
                    );
                    anyhow::ensure!(
                        e.n_groups == 0,
                        "tensor {:?}: raw f32 blob cannot have groups",
                        tm.name
                    );
                    let expect = len.checked_mul(4).ok_or_else(|| {
                        anyhow::anyhow!("tensor {:?}: raw f32 size overflows", tm.name)
                    })?;
                    anyhow::ensure!(
                        e.data_len as usize == expect,
                        "tensor {:?}: raw blob is {} bytes, {len} f32 need {expect}",
                        tm.name,
                        e.data_len
                    );
                }
            }
            let got = fnv1a64(&buf[start..end]);
            anyhow::ensure!(
                got == e.checksum,
                "tensor {:?}: checksum mismatch (stored {:#018x}, computed {got:#018x}) — \
                 artifact corrupt",
                tm.name,
                e.checksum
            );
            index.push(e);
        }
        Ok(Artifact {
            buf,
            header,
            meta: ArtifactMeta { top, group_size, rounding, config },
            tensors,
            index,
        })
    }

    pub fn header(&self) -> &Header {
        &self.header
    }

    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    /// Per-tensor manifest entries, in storage order.
    pub fn tensors(&self) -> &[TensorMeta] {
        &self.tensors
    }

    /// Per-tensor index records, in storage order.
    pub fn index(&self) -> &[IndexEntry] {
        &self.index
    }

    pub fn tensor_count(&self) -> usize {
        self.index.len()
    }

    /// Total container size in bytes.
    pub fn file_len(&self) -> usize {
        self.buf.len()
    }

    /// Total packed payload bytes (sum of tensor blobs, no padding).
    pub fn data_bytes(&self) -> usize {
        self.index.iter().map(|e| e.data_len as usize).sum()
    }

    /// Slice tensor `e`'s blob out of the container buffer.  `e` comes
    /// from `self.index`, so its range was bounds- and overflow-checked
    /// against the file in `from_bytes`.
    fn blob(&self, e: &IndexEntry) -> &[u8] {
        // lint: allow(untrusted-checked-arith, reason = "blob bounds validated at open: from_bytes checked data_off + data_len against the file with checked_add")
        &self.buf[e.data_off as usize..(e.data_off + e.data_len) as usize]
    }

    /// THE truncate-at-load entry point: a borrowed view of quantized
    /// tensor `i` at rung `p`.  Pure pointer arithmetic — the view
    /// aliases the exponent plane, the sign plane, and the first
    /// `p.m()` mantissa planes of the container buffer; a lower rung
    /// simply borrows fewer planes.  Errors if `i` is raw f32 or `p`
    /// exceeds the stored top (mantissa bits cannot be invented).
    pub fn view(&self, i: usize, p: Precision) -> anyhow::Result<TensorView<'_>> {
        let e = self
            .index
            .get(i)
            .ok_or_else(|| {
                anyhow::anyhow!("tensor index {i} out of range ({})", self.index.len())
            })?;
        anyhow::ensure!(
            e.kind == TensorKind::Packed,
            "tensor {:?} is raw f32 — use raw_f32",
            self.tensors[i].name
        );
        anyhow::ensure!(
            p <= self.meta.top,
            "rung {p} above the stored {} master",
            self.meta.top
        );
        let len = e.len as usize;
        let n_groups = e.n_groups as usize;
        let stride = len.div_ceil(8);
        // lint: allow(untrusted-checked-arith, reason = "validated at open: from_bytes ran this exact arithmetic through checked_packed_blob_len")
        let exp_bytes = (n_groups * 5).div_ceil(8);
        let blob = self.blob(e);
        let (exp, rest) = blob.split_at(exp_bytes);
        let (sign, mant) = rest.split_at(stride);
        Ok(TensorView {
            precision: p,
            top: self.meta.top,
            group_size: self.meta.group_size,
            len,
            n_groups,
            exp,
            sign,
            planes: &mant[..p.m() as usize * stride],
        })
    }

    /// Copy out a non-quantized tensor (norm gains, pos_embed).
    pub fn raw_f32(&self, i: usize) -> anyhow::Result<Vec<f32>> {
        let e = self
            .index
            .get(i)
            .ok_or_else(|| {
                anyhow::anyhow!("tensor index {i} out of range ({})", self.index.len())
            })?;
        anyhow::ensure!(
            e.kind == TensorKind::RawF32,
            "tensor {:?} is SEFP-packed — use view",
            self.tensors[i].name
        );
        Ok(self
            .blob(e)
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    /// Bytes an open at rung `p` actually touches: exponent + sign
    /// planes plus `p.m()` mantissa planes per packed tensor, and raw
    /// f32 tensors whole — the per-rung deployment footprint `inspect`
    /// reports.
    pub fn view_bytes_at(&self, p: Precision) -> usize {
        self.index
            .iter()
            .map(|e| match e.kind {
                // a view at rung p borrows exactly the blob a p-top
                // master would occupy — exp + sign + p.m() planes
                TensorKind::Packed => {
                    packed_blob_len(e.len as usize, e.n_groups as usize, p)
                }
                TensorKind::RawF32 => e.data_len as usize,
            })
            .sum()
    }
}

/// A borrowed, zero-copy view of one packed tensor at a chosen rung.
/// Holds three slices into the artifact buffer and nothing else;
/// materializing [`SefpTensor`] / f32 is explicit.
#[derive(Debug, Clone, Copy)]
pub struct TensorView<'a> {
    /// the rung this view was opened at
    pub precision: Precision,
    /// the precision the planes are stored at
    pub top: Precision,
    pub group_size: usize,
    pub len: usize,
    pub n_groups: usize,
    exp: &'a [u8],
    sign: &'a [u8],
    /// first `precision.m()` mantissa planes (MSB first), each
    /// `len.div_ceil(8)` bytes
    planes: &'a [u8],
}

impl TensorView<'_> {
    /// Bytes this view borrows from the artifact buffer — its entire
    /// footprint; nothing is allocated.
    pub fn borrowed_bytes(&self) -> usize {
        self.exp.len() + self.sign.len() + self.planes.len()
    }

    /// Materialize the working representation: plane gather + shared
    /// exponent unpack, pure integer work.  Because the planes are MSB
    /// first, gathering only the borrowed prefix IS the mantissa shift
    /// `sig >> (top.m() - precision.m())` — bit-identical to
    /// `SefpTensor::truncate` on a fully-loaded master.
    pub fn to_tensor(&self) -> SefpTensor {
        let m = self.precision.m() as usize;
        let stride = self.len.div_ceil(8);
        let mut exponents = Vec::with_capacity(self.n_groups);
        for g in 0..self.n_groups {
            exponents.push((BitVec::read_bits_in(self.exp, g * 5, 5) as i32 + EXP_MIN) as i8);
        }
        let mut significands = Vec::with_capacity(self.len);
        // gather byte-column-wise: hoist the m plane bytes covering 8
        // elements once, then compose each element's magnitude from
        // registers — this is the artifact load's hot loop
        let mut col = [0u8; Precision::MAX.m() as usize];
        for byte in 0..stride {
            for (k, c) in col.iter_mut().take(m).enumerate() {
                *c = self.planes[k * stride + byte];
            }
            let sb = self.sign[byte];
            let lo = byte * 8;
            let hi = (lo + 8).min(self.len);
            for bit in 0..hi - lo {
                let mut mag = 0u16;
                for &c in col.iter().take(m) {
                    mag = (mag << 1) | ((c >> bit) & 1) as u16;
                }
                let neg = (sb >> bit) & 1 == 1;
                significands.push(if neg { -(mag as i16) } else { mag as i16 });
            }
        }
        SefpTensor {
            precision: self.precision,
            group_size: self.group_size,
            len: self.len,
            exponents,
            significands,
        }
    }

    /// Re-pack into the interleaved `PackedSefp` bitstream — bit-exact
    /// with `PackedSefp::encode` at this rung when the master was
    /// stored with `Rounding::Trunc` (the ladder-exactness contract,
    /// property-tested in `rust/tests/artifact_props.rs`).
    pub fn to_packed(&self) -> PackedSefp {
        PackedSefp::from_tensor(&self.to_tensor())
    }

    /// Dequantize to f32 — explicit and last, never implicit on the
    /// load path.
    pub fn decode(&self) -> Vec<f32> {
        self.to_tensor().decode()
    }
}

#[cfg(test)]
mod tests {
    use super::super::writer::{pack_params, ArtifactMeta};
    use super::*;
    use crate::runtime::ParamStore;
    use crate::sefp::SefpSpec;

    fn params() -> ParamStore {
        let mut rng = crate::data::Rng::new(7);
        ParamStore {
            tensors: vec![
                (0..200).map(|_| rng.normal() as f32 * 0.2).collect(),
                vec![1.0, -2.0, 0.5],
            ],
            names: vec!["w".into(), "ln".into()],
            shapes: vec![vec![10, 20], vec![3]],
            quantized: vec![true, false],
        }
    }

    #[test]
    fn roundtrip_and_views() {
        let p = params();
        let meta = ArtifactMeta::new(Precision::of(8));
        let a = Artifact::from_bytes(pack_params(&p, &meta)).unwrap();
        assert_eq!(a.tensor_count(), 2);
        assert_eq!(a.meta().top, Precision::of(8));
        let direct = SefpTensor::encode(&p.tensors[0], &SefpSpec::new(Precision::of(8)));
        assert_eq!(a.view(0, Precision::of(8)).unwrap().to_tensor(), direct);
        assert_eq!(a.raw_f32(1).unwrap(), p.tensors[1]);
        // truncate-at-load: fewer borrowed bytes at a lower rung
        let v8 = a.view(0, Precision::of(8)).unwrap();
        let v3 = a.view(0, Precision::of(3)).unwrap();
        assert!(v3.borrowed_bytes() < v8.borrowed_bytes());
        assert_eq!(v3.to_tensor(), direct.truncate(Precision::of(3)));
    }

    #[test]
    fn kind_and_rung_errors() {
        let a = Artifact::from_bytes(pack_params(&params(), &ArtifactMeta::new(Precision::of(6))))
            .unwrap();
        assert!(a.view(1, Precision::of(4)).is_err(), "raw tensor has no packed view");
        assert!(a.raw_f32(0).is_err(), "packed tensor is not raw");
        assert!(a.view(0, Precision::of(8)).is_err(), "rung above stored top");
        assert!(a.view(2, Precision::of(4)).is_err(), "index out of range");
    }

    #[test]
    fn view_bytes_at_matches_borrowed_bytes() {
        let p = params();
        let a = Artifact::from_bytes(pack_params(&p, &ArtifactMeta::new(Precision::of(8))))
            .unwrap();
        for rung in [Precision::of(8), Precision::of(4)] {
            let borrowed = a.view(0, rung).unwrap().borrowed_bytes() + p.tensors[1].len() * 4;
            assert_eq!(a.view_bytes_at(rung), borrowed);
        }
    }
}
