//! Serve-time telemetry: per-`(TaskClass, Precision)` sliding windows.
//!
//! Every completed request lands one observation in its *lane* — the
//! (task class, served precision) pair.  A lane keeps a fixed-capacity
//! ring of end-to-end latencies (queue + compute) with exact p50/p95/p99
//! queries, throughput counters, the queue depth seen at completion, and
//! an EMA of shadow-probe token agreement.  The
//! [`SloController`](super::SloController) reads lanes at its decision
//! points; nothing here allocates on the observation hot path once a
//! lane's ring is full.

use std::collections::BTreeMap;

use crate::metrics::percentile_of;
use crate::sefp::Precision;
use crate::serve::TaskClass;

use super::probe::ProbeResult;

/// Fixed-capacity sliding window over `f64` samples (ring buffer).
///
/// Percentile queries are exact over the retained window — the newest
/// `cap` samples — which is the horizon an SLO controller should react
/// to (a run-lifetime mean would let ancient good latencies mask a
/// current violation).
///
/// With [`with_threshold`](Window::with_threshold), the window also
/// maintains the count of retained samples above the threshold in
/// O(1) per push.  [`frac_over`](Window::frac_over) is then the cheap
/// per-observation SLO test the controller polls: nearest-rank
/// `p95 > threshold` is equivalent to more than 5% of the window lying
/// above it, so the decision hot path never sorts — the exact
/// percentile queries stay available for reporting.
#[derive(Debug, Clone)]
pub struct Window {
    buf: Vec<f64>,
    cap: usize,
    /// next write position once the ring has wrapped
    head: usize,
    /// threshold for the incremental over-count (None = not tracked)
    threshold: Option<f64>,
    /// retained samples strictly above `threshold`
    over: usize,
}

impl Window {
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1, "window capacity must be positive");
        Window { buf: Vec::with_capacity(cap), cap, head: 0, threshold: None, over: 0 }
    }

    /// Track the fraction of retained samples above `t` incrementally.
    pub fn with_threshold(mut self, t: f64) -> Self {
        self.threshold = Some(t);
        self
    }

    pub fn push(&mut self, x: f64) {
        if self.threshold.is_some_and(|t| x > t) {
            self.over += 1;
        }
        if self.buf.len() < self.cap {
            self.buf.push(x);
        } else {
            if self.threshold.is_some_and(|t| self.buf[self.head] > t) {
                self.over -= 1;
            }
            self.buf[self.head] = x;
            self.head = (self.head + 1) % self.cap;
        }
    }

    /// Fraction of the retained window strictly above the threshold
    /// (0.0 when empty or no threshold is tracked) — O(1).
    pub fn frac_over(&self) -> f64 {
        if self.buf.is_empty() {
            0.0
        } else {
            self.over as f64 / self.buf.len() as f64
        }
    }

    /// Samples currently retained (≤ capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn mean(&self) -> f64 {
        if self.buf.is_empty() {
            return 0.0;
        }
        self.buf.iter().sum::<f64>() / self.buf.len() as f64
    }

    /// Exact nearest-rank percentile over the retained window
    /// (`q` in [0, 100]); 0.0 when empty.
    pub fn percentile(&self, q: f64) -> f64 {
        percentile_of(&self.buf, q)
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

/// One telemetry lane: the sliding-window state for a
/// `(TaskClass, Precision)` pair.
#[derive(Debug, Clone)]
pub struct Lane {
    /// end-to-end latency (queue + compute) per completed request, ms
    pub latency_ms: Window,
    /// completed requests observed on this lane
    pub served: u64,
    /// tokens generated on this lane
    pub tokens: u64,
    /// queue depth seen at the most recent completion
    pub queue_depth: usize,
    /// EMA of shadow-probe token agreement (None until the first probe)
    pub agreement: Option<f64>,
    /// shadow probes scored on this lane
    pub probes: u64,
}

impl Lane {
    fn new(window: usize, slo_ms: f64) -> Self {
        Lane {
            latency_ms: Window::new(window).with_threshold(slo_ms),
            served: 0,
            tokens: 0,
            queue_depth: 0,
            agreement: None,
            probes: 0,
        }
    }
}

/// EMA factor for probe agreement: heavy enough on the newest probe to
/// react within a few samples, light enough that one outlier cannot
/// flip a promotion decision by itself.
const AGREEMENT_EMA: f64 = 0.5;

/// Per-`(TaskClass, Precision)` sliding-window statistics.  `BTreeMap`
/// keyed, so iteration (and therefore any reporting built on it) is
/// deterministic.  Every lane's latency ring tracks the over-`slo_ms`
/// fraction incrementally, so the controller's per-observation SLO test
/// is O(1).
#[derive(Debug, Clone)]
pub struct Telemetry {
    window: usize,
    /// latency SLO the lanes' over-fraction counters are keyed to, ms
    slo_ms: f64,
    lanes: BTreeMap<(TaskClass, Precision), Lane>,
}

impl Telemetry {
    pub fn new(window: usize, slo_ms: f64) -> Self {
        Telemetry { window: window.max(1), slo_ms, lanes: BTreeMap::new() }
    }

    /// Record one completed request.
    pub fn observe(
        &mut self,
        class: TaskClass,
        precision: Precision,
        latency_ms: f64,
        tokens: usize,
        queue_depth: usize,
    ) {
        let lane = self
            .lanes
            .entry((class, precision))
            .or_insert_with(|| Lane::new(self.window, self.slo_ms));
        lane.latency_ms.push(latency_ms.max(0.0));
        lane.served += 1;
        lane.tokens += tokens as u64;
        lane.queue_depth = queue_depth;
    }

    /// Record one shadow-probe result.
    pub fn observe_probe(&mut self, class: TaskClass, precision: Precision, probe: &ProbeResult) {
        let lane = self
            .lanes
            .entry((class, precision))
            .or_insert_with(|| Lane::new(self.window, self.slo_ms));
        lane.probes += 1;
        lane.agreement = Some(match lane.agreement {
            Some(prev) => AGREEMENT_EMA * probe.agreement + (1.0 - AGREEMENT_EMA) * prev,
            None => probe.agreement,
        });
    }

    pub fn lane(&self, class: TaskClass, precision: Precision) -> Option<&Lane> {
        self.lanes.get(&(class, precision))
    }

    /// Latency-window fill of a lane (0 when the lane does not exist).
    pub fn samples(&self, class: TaskClass, precision: Precision) -> usize {
        self.lane(class, precision).map_or(0, |l| l.latency_ms.len())
    }

    /// All lanes, deterministically ordered — reporting/debugging.
    pub fn lanes(&self) -> impl Iterator<Item = (&(TaskClass, Precision), &Lane)> {
        self.lanes.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn probe(agreement: f64) -> ProbeResult {
        ProbeResult { agreement, mean_divergence: 0.0, divergence_amplitude: 0.0, positions: 1 }
    }

    #[test]
    fn window_ring_keeps_newest() {
        let mut w = Window::new(4);
        assert!(w.is_empty());
        for x in 1..=6 {
            w.push(x as f64);
        }
        // retained: {3, 4, 5, 6}
        assert_eq!(w.len(), 4);
        assert_eq!(w.capacity(), 4);
        assert_eq!(w.p50(), 4.0);
        assert_eq!(w.percentile(100.0), 6.0);
        assert!((w.mean() - 4.5).abs() < 1e-12);
    }

    #[test]
    fn window_percentiles_track_tail() {
        let mut w = Window::new(100);
        for _ in 0..95 {
            w.push(1.0);
        }
        for _ in 0..5 {
            w.push(50.0);
        }
        assert_eq!(w.p50(), 1.0);
        assert_eq!(w.p99(), 50.0, "the tail must be visible at p99");
    }

    #[test]
    fn threshold_fraction_is_incremental_and_slides() {
        let mut w = Window::new(4).with_threshold(10.0);
        assert_eq!(w.frac_over(), 0.0);
        for x in [1.0, 20.0, 30.0, 2.0] {
            w.push(x);
        }
        assert_eq!(w.frac_over(), 0.5);
        // overwriting the oldest (1.0, under) with an over sample
        w.push(40.0); // retained: {20, 30, 2, 40}
        assert_eq!(w.frac_over(), 0.75);
        // overwriting an over sample (20.0) with an under sample
        w.push(3.0); // retained: {30, 2, 40, 3}
        assert_eq!(w.frac_over(), 0.5);
        // the nearest-rank equivalence the controller relies on:
        // frac_over > 0.05 <=> p95 > threshold
        assert!(w.p95() > 10.0);
        let mut calm = Window::new(100).with_threshold(10.0);
        for _ in 0..100 {
            calm.push(1.0);
        }
        assert_eq!(calm.frac_over(), 0.0);
        assert!(calm.p95() <= 10.0);
    }

    #[test]
    fn lanes_are_keyed_by_class_and_precision() {
        let mut t = Telemetry::new(8, 25.0);
        t.observe(TaskClass::Understanding, Precision::of(4), 2.0, 1, 3);
        t.observe(TaskClass::Understanding, Precision::of(3), 9.0, 1, 0);
        t.observe(TaskClass::Generation, Precision::of(8), 30.0, 4, 1);
        let u4 = t.lane(TaskClass::Understanding, Precision::of(4)).unwrap();
        assert_eq!(u4.served, 1);
        assert_eq!(u4.queue_depth, 3);
        assert_eq!(u4.latency_ms.p95(), 2.0);
        assert_eq!(u4.latency_ms.frac_over(), 0.0);
        let g8 = t.lane(TaskClass::Generation, Precision::of(8)).unwrap();
        assert_eq!(g8.latency_ms.frac_over(), 1.0, "30 ms > the 25 ms SLO");
        assert_eq!(t.samples(TaskClass::Understanding, Precision::of(3)), 1);
        assert!(t.lane(TaskClass::Other, Precision::of(4)).is_none());
        assert_eq!(t.lanes().count(), 3);
    }

    #[test]
    fn probe_agreement_is_an_ema() {
        let mut t = Telemetry::new(8, 25.0);
        let (c, p) = (TaskClass::Understanding, Precision::of(4));
        t.observe_probe(c, p, &probe(1.0));
        assert_eq!(t.lane(c, p).unwrap().agreement, Some(1.0));
        t.observe_probe(c, p, &probe(0.0));
        let a = t.lane(c, p).unwrap().agreement.unwrap();
        assert!((a - 0.5).abs() < 1e-12, "EMA halves toward the new probe: {a}");
        assert_eq!(t.lane(c, p).unwrap().probes, 2);
    }
}
