//! SLO-driven precision feedback controller.
//!
//! Ports the paper's BPS exploitation–exploration scoring (eq. 5) from
//! fine-tuning to serve time.  At training time BPS scores a bit-width
//! `λ·sqrt(ln t / t_b) − L_b` and follows the argmax; here the loss term
//! becomes an *SLO cost* — normalized p95 latency plus a quality penalty
//! from shadow-probe agreement — and the controller moves a task class
//! ONE rung at a time toward the better-scoring width:
//!
//! * **demote** (fewer mantissa bits, faster) when the class's p95
//!   latency violates its SLO — detected O(1) via the telemetry ring's
//!   over-SLO fraction (see [`LaneSignal`]) — *and* probe agreement
//!   shows quality headroom (`agreement ≥ floor + headroom`) *and* the
//!   candidate rung outscores the current one — an unvisited candidate
//!   scores `+inf`, exactly like an unvisited width in BPS, so pressure
//!   always gets one exploratory demotion before real telemetry takes
//!   over;
//! * **promote** (more mantissa bits, higher fidelity) whenever probe
//!   agreement drops below the quality floor — a safety move that needs
//!   no scoring and no minimum window;
//! * **hysteresis + cooldown**: demotion requires the full headroom band
//!   above the floor (so a class cannot demote and immediately
//!   promote), every switch starts a cooldown of `cooldown` decision
//!   ticks, and decisions need `min_samples` latency observations.
//!
//! Output is hard-clamped by construction: the state is an *index into
//! the configured ladder*, so the controller can never emit a precision
//! outside it regardless of the observation sequence (property-tested in
//! `rust/tests/policy_adaptive.rs`).

use std::collections::BTreeMap;

use crate::config::PolicyConfig;
use crate::sefp::Precision;
use crate::serve::TaskClass;

/// What the controller saw about one lane at a decision point.
///
/// The latency signal is the fraction of the lane's window above the
/// SLO, maintained incrementally by the telemetry ring — O(1) to read
/// on every observation, and equivalent to the nearest-rank p95 test
/// (`p95 > SLO` ⇔ more than 5% of the window lies above the SLO), so
/// the per-request hot path never sorts a window.
#[derive(Debug, Clone, Copy, Default)]
pub struct LaneSignal {
    /// fraction of the lane's latency window above the SLO, [0, 1]
    pub frac_over_slo: f64,
    /// shadow-probe token-agreement EMA (None = never probed)
    pub agreement: Option<f64>,
    /// latency observations currently in the lane's window
    pub samples: usize,
}

/// One controller decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    Hold,
    /// moved one rung down the ladder (lower precision, lower latency)
    Demote { from: Precision, to: Precision },
    /// moved one rung up the ladder (higher precision, higher fidelity)
    Promote { from: Precision, to: Precision },
}

#[derive(Debug, Clone)]
struct ClassState {
    /// index into `ladder` (0 = highest precision)
    rung: usize,
    /// decision ticks left before the next switch is allowed
    cooldown: u64,
    /// decision ticks observed for this class (the BPS `t`)
    ticks: u64,
    /// ticks spent at each rung (the BPS `t_b`)
    visits: Vec<u64>,
}

/// The per-class SLO feedback controller.  See the module docs for the
/// decision rules.
#[derive(Debug, Clone)]
pub struct SloController {
    /// configured ladder, highest precision first, deduped
    ladder: Vec<Precision>,
    cfg: PolicyConfig,
    classes: BTreeMap<TaskClass, ClassState>,
    /// total demotions/promotions across all classes
    pub demotions: u64,
    pub promotions: u64,
}

impl SloController {
    /// `ladder` is canonicalized (sorted highest-first, deduped) and must
    /// be non-empty.  `min_samples` is clamped to the telemetry window —
    /// a demotion gate that can never fill would otherwise silently
    /// disable the controller.
    pub fn new(ladder: &[Precision], mut cfg: PolicyConfig) -> Self {
        assert!(!ladder.is_empty(), "controller ladder must be non-empty");
        let mut ladder = ladder.to_vec();
        Precision::canonicalize_ladder(&mut ladder);
        cfg.min_samples = cfg.min_samples.min(cfg.window.max(1));
        SloController { ladder, cfg, classes: BTreeMap::new(), demotions: 0, promotions: 0 }
    }

    pub fn ladder(&self) -> &[Precision] {
        &self.ladder
    }

    /// Pin a class's starting rung to the ladder rung nearest `p` (the
    /// next rung up when `p` falls between rungs, the bounds when it
    /// falls outside).  Classes never initialized start at the top.
    pub fn init_class(&mut self, class: TaskClass, p: Precision) {
        let rung = self.nearest_rung(p);
        let n = self.ladder.len();
        self.classes
            .entry(class)
            .or_insert_with(|| ClassState { rung, cooldown: 0, ticks: 0, visits: vec![0; n] })
            .rung = rung;
    }

    fn nearest_rung(&self, p: Precision) -> usize {
        // the shared snap rule, then its index in the canonical ladder;
        // snap always returns a member, so the fallback (top rung) is
        // unreachable — it exists to keep this path panic-free
        let snapped = Precision::snap_to_ladder(&self.ladder, p);
        self.ladder.iter().position(|&w| w == snapped).unwrap_or(0)
    }

    /// The precision this class currently serves at.
    pub fn current(&self, class: TaskClass) -> Precision {
        self.classes.get(&class).map_or(self.ladder[0], |s| self.ladder[s.rung])
    }

    /// BPS score of a rung (eq. 5 shape): `λ·sqrt(ln t / t_b) − cost`,
    /// `+inf` when the rung was never visited, where the training-time
    /// loss `L_b` is replaced by the serve-time SLO cost — the lane's
    /// over-SLO window fraction plus a heavily-weighted quality
    /// shortfall.
    fn score(cfg: &PolicyConfig, st: &ClassState, rung: usize, signal: LaneSignal) -> f64 {
        let visits = st.visits[rung];
        if visits == 0 {
            return f64::INFINITY;
        }
        let t = st.ticks.max(1) as f64;
        let explore = cfg.lambda * (t.ln().max(0.0) / visits as f64).sqrt();
        let latency = signal.frac_over_slo * LATENCY_COST_WEIGHT;
        let quality = (cfg.quality_floor - signal.agreement.unwrap_or(1.0)).max(0.0);
        // a quality shortfall must dominate any latency win: the floor
        // is a constraint, not a term to trade against
        explore - (latency + quality * QUALITY_COST_WEIGHT)
    }

    /// One decision tick for `class`: `current` is the lane the class is
    /// serving on, `candidate` the lane one rung down (if any data
    /// exists for it).  Returns what the controller did.
    pub fn tick(
        &mut self,
        class: TaskClass,
        current: LaneSignal,
        candidate: LaneSignal,
    ) -> Decision {
        // destructured so the single `st` borrow of `classes` serves the
        // whole tick — no panicking re-lookups on the decision path
        let SloController { ladder, cfg, classes, demotions, promotions } = self;
        let n = ladder.len();
        let st = classes
            .entry(class)
            .or_insert_with(|| ClassState { rung: 0, cooldown: 0, ticks: 0, visits: vec![0; n] });
        st.ticks += 1;
        st.visits[st.rung] += 1;
        if st.cooldown > 0 {
            st.cooldown -= 1;
            return Decision::Hold;
        }

        // safety first: probe agreement under the floor promotes
        // unconditionally (no minimum window, no scoring)
        let quality_collapsed = current.agreement.is_some_and(|a| a < cfg.quality_floor);
        if quality_collapsed && st.rung > 0 {
            let from = ladder[st.rung];
            st.rung -= 1;
            st.cooldown = cfg.cooldown;
            let to = ladder[st.rung];
            *promotions += 1;
            return Decision::Promote { from, to };
        }

        if current.samples < cfg.min_samples || st.rung + 1 >= n {
            return Decision::Hold;
        }
        let slo_violated = current.frac_over_slo > SLO_VIOLATION_FRACTION;
        let headroom = current
            .agreement
            .is_none_or(|a| a >= cfg.quality_floor + cfg.quality_headroom);
        if !(slo_violated && headroom) {
            return Decision::Hold;
        }
        // exploitation–exploration: demote only when the rung below
        // outscores the current one (an unvisited rung always does)
        let cur_score = Self::score(cfg, st, st.rung, current);
        let cand_score = Self::score(cfg, st, st.rung + 1, candidate);
        if cand_score <= cur_score {
            return Decision::Hold;
        }
        let from = ladder[st.rung];
        st.rung += 1;
        st.cooldown = cfg.cooldown;
        let to = ladder[st.rung];
        *demotions += 1;
        Decision::Demote { from, to }
    }
}

/// The nearest-rank p95 test: `p95 > SLO` ⇔ strictly more than 5% of
/// the window lies above the SLO.
const SLO_VIOLATION_FRACTION: f64 = 0.05;

/// Scales the over-SLO window fraction (≤ 1.0) into a cost comparable
/// to the exploration term at the paper's λ = 5.
const LATENCY_COST_WEIGHT: f64 = 10.0;

/// Weight turning a probe-agreement shortfall (≤ 1.0) into an SLO cost
/// that dominates any realistic latency term.
const QUALITY_COST_WEIGHT: f64 = 100.0;

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PolicyConfig {
        PolicyConfig {
            slo_p95_ms: 10.0,
            quality_floor: 0.8,
            quality_headroom: 0.05,
            min_samples: 4,
            cooldown: 2,
            ..PolicyConfig::default()
        }
    }

    fn ctl() -> SloController {
        let mut c = SloController::new(&Precision::LADDER, cfg());
        c.init_class(TaskClass::Understanding, Precision::of(4));
        c
    }

    fn pressured(samples: usize) -> LaneSignal {
        LaneSignal { frac_over_slo: 1.0, agreement: Some(0.95), samples }
    }

    #[test]
    fn init_snaps_to_nearest_rung() {
        let mut c = SloController::new(
            &[Precision::of(8), Precision::of(6), Precision::of(3)],
            cfg(),
        );
        c.init_class(TaskClass::Other, Precision::of(5)); // between 6 and 3
        assert_eq!(c.current(TaskClass::Other), Precision::of(6));
        c.init_class(TaskClass::Other, Precision::of(1)); // below the ladder
        assert_eq!(c.current(TaskClass::Other), Precision::of(3));
        c.init_class(TaskClass::Other, Precision::of(14)); // above the ladder
        assert_eq!(c.current(TaskClass::Other), Precision::of(8));
        // a never-initialized class serves at the top
        assert_eq!(c.current(TaskClass::Generation), Precision::of(8));
    }

    #[test]
    fn demotes_under_slo_violation_with_quality_headroom() {
        let mut c = ctl();
        let mut demoted = false;
        for _ in 0..8 {
            if let Decision::Demote { from, to } =
                c.tick(TaskClass::Understanding, pressured(8), LaneSignal::default())
            {
                assert_eq!(from, Precision::of(4));
                assert_eq!(to, Precision::of(3));
                demoted = true;
                break;
            }
        }
        assert!(demoted, "sustained violation with headroom must demote");
        assert_eq!(c.current(TaskClass::Understanding), Precision::of(3));
        assert_eq!(c.demotions, 1);
    }

    #[test]
    fn holds_without_enough_samples_or_without_violation() {
        let mut c = ctl();
        assert_eq!(
            c.tick(TaskClass::Understanding, pressured(2), LaneSignal::default()),
            Decision::Hold,
            "below min_samples"
        );
        let healthy = LaneSignal { frac_over_slo: 0.0, agreement: Some(0.95), samples: 8 };
        for _ in 0..8 {
            assert_eq!(
                c.tick(TaskClass::Understanding, healthy, LaneSignal::default()),
                Decision::Hold,
                "no SLO violation, no move"
            );
        }
        assert_eq!(c.current(TaskClass::Understanding), Precision::of(4));
    }

    #[test]
    fn quality_floor_blocks_demotion_and_forces_promotion() {
        let mut c = ctl();
        // violated SLO but agreement inside the hysteresis band: hold
        let tight = LaneSignal { frac_over_slo: 1.0, agreement: Some(0.82), samples: 8 };
        assert_eq!(
            c.tick(TaskClass::Understanding, tight, LaneSignal::default()),
            Decision::Hold
        );
        // agreement under the floor: promote regardless of latency
        let bad = LaneSignal { frac_over_slo: 0.0, agreement: Some(0.5), samples: 1 };
        let d = c.tick(TaskClass::Understanding, bad, LaneSignal::default());
        assert_eq!(
            d,
            Decision::Promote { from: Precision::of(4), to: Precision::of(5) }
        );
        assert_eq!(c.promotions, 1);
    }

    #[test]
    fn cooldown_spaces_out_switches() {
        let mut c = ctl();
        // drive to a demotion
        while c.current(TaskClass::Understanding) != Precision::of(3) {
            c.tick(TaskClass::Understanding, pressured(8), LaneSignal::default());
        }
        // quality collapse right after: cooldown must absorb 2 ticks
        let bad = LaneSignal { frac_over_slo: 0.0, agreement: Some(0.1), samples: 8 };
        assert_eq!(c.tick(TaskClass::Understanding, bad, LaneSignal::default()), Decision::Hold);
        assert_eq!(c.tick(TaskClass::Understanding, bad, LaneSignal::default()), Decision::Hold);
        assert!(matches!(
            c.tick(TaskClass::Understanding, bad, LaneSignal::default()),
            Decision::Promote { .. }
        ));
    }

    #[test]
    fn bottom_rung_never_demotes_top_never_promotes() {
        let mut c = SloController::new(&[Precision::of(4), Precision::of(3)], cfg());
        c.init_class(TaskClass::Other, Precision::of(3));
        for _ in 0..20 {
            c.tick(TaskClass::Other, pressured(8), pressured(8));
            assert_eq!(c.current(TaskClass::Other), Precision::of(3));
        }
        c.init_class(TaskClass::Other, Precision::of(4));
        let bad = LaneSignal { frac_over_slo: 0.0, agreement: Some(0.0), samples: 0 };
        for _ in 0..20 {
            c.tick(TaskClass::Other, bad, LaneSignal::default());
            assert_eq!(c.current(TaskClass::Other), Precision::of(4));
        }
    }

    #[test]
    fn visited_candidate_uses_real_telemetry() {
        // after the exploratory demotion, a candidate whose own lane is
        // ALSO violated (and now visited) must not win the score again
        // once the exploration bonus decays — the controller settles
        // instead of oscillating down a ladder that cannot help.
        let mut c = SloController::new(&Precision::LADDER, cfg());
        c.init_class(TaskClass::Other, Precision::of(8));
        let mut demotions_seen = 0;
        for _ in 0..200 {
            if let Decision::Demote { .. } =
                c.tick(TaskClass::Other, pressured(8), pressured(8))
            {
                demotions_seen += 1;
            }
        }
        // every rung gets its exploratory visit (ladder has 6 rungs), but
        // the walk is bounded by the ladder — never more demotions than
        // rungs below the start
        assert!(demotions_seen <= 5, "{demotions_seen} demotions on a 6-rung ladder");
        assert!(c.current(TaskClass::Other) >= Precision::of(3));
    }
}
