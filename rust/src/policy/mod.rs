//! Adaptive precision control plane — the serve-time feedback loop.
//!
//! The paper's deployment thesis is that ONE SEFP master should switch
//! precisions *in response to real scenarios*: understanding traffic
//! tolerates low bit-widths, generation does not (intro, fig. 1).  The
//! static `serve::Router` config encodes that as a frozen 3-arm lookup;
//! this module closes the loop so the serving stack decides for itself:
//!
//! ```text
//!             decide(class)                observe / observe_probe
//!   Router ──────────────────► PrecisionPolicy ◄────────────────── Server
//!                                   │
//!              AdaptivePolicy = Telemetry + ProbeSampler + SloController
//!                                   │
//!          telemetry::Lane p50/p95/p99 windows per (class, precision)
//!          probe::shadow_probe  master-precision re-scoring (sampled)
//!          controller::SloController  BPS-scored demote/promote + clamps
//! ```
//!
//! * [`telemetry`] — per-`(TaskClass, Precision)` sliding windows:
//!   exact-percentile latency rings, throughput, queue depth, probe
//!   agreement EMA.
//! * [`probe`] — shadow quality probes: a sampled fraction of completed
//!   requests is re-scored teacher-forced at the ladder master and at
//!   the served precision; token agreement and logit divergence come
//!   back as the online quality signal.
//! * [`controller`] — the SLO feedback controller: demote on latency
//!   violation with quality headroom, promote on probe-agreement
//!   collapse, BPS exploitation–exploration scoring, hysteresis +
//!   cooldown, output hard-clamped to the configured ladder.
//! * [`PrecisionPolicy`] — the trait `serve::Router` delegates to, with
//!   [`StaticPolicy`] (the old config lookup, still the default) and
//!   [`AdaptivePolicy`] (the full control plane) implementations.

pub mod controller;
pub mod probe;
pub mod telemetry;

pub use controller::{Decision, LaneSignal, SloController};
pub use probe::{shadow_probe, ProbeResult, ProbeSampler, ProbeTask};
pub use telemetry::{Lane, Telemetry, Window};

use crate::config::ServeConfig;
use crate::sefp::Precision;
use crate::serve::TaskClass;

/// One completed request, as the policy layer sees it.
#[derive(Debug, Clone, Copy)]
pub struct Observation {
    pub class: TaskClass,
    /// precision the request was served at
    pub precision: Precision,
    pub queue_ms: f64,
    pub compute_ms: f64,
    /// tokens generated
    pub tokens: usize,
    /// batcher depth at completion time
    pub queue_depth: usize,
}

impl Observation {
    /// End-to-end latency the SLO is judged on.
    pub fn latency_ms(&self) -> f64 {
        self.queue_ms + self.compute_ms
    }
}

/// One rung move the controller just made, surfaced so the server can
/// attach a `policy_decision` trace event to the request whose
/// observation (or probe) triggered it.  `score_pm` is the signal that
/// justified the move, in permille: the over-SLO fraction for demotes,
/// the probe agreement for promotes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PolicyMove {
    pub demote: bool,
    pub from: Precision,
    pub to: Precision,
    pub score_pm: i32,
}

/// Decision counters a policy exposes to `ServeStats`.
#[derive(Debug, Clone, Copy, Default)]
pub struct PolicySnapshot {
    /// `decide` calls answered
    pub decisions: u64,
    /// controller moves to a lower precision
    pub demotions: u64,
    /// controller moves to a higher precision
    pub promotions: u64,
    /// shadow probes scored
    pub probes: u64,
}

/// The precision policy a [`Router`](crate::serve::Router) delegates
/// non-forced routing to.  `decide` is the per-request hot path;
/// `observe`/`observe_probe` are the feedback edges the
/// [`Server`](crate::serve::Server) drives after each completion.
pub trait PrecisionPolicy: std::fmt::Debug + Send {
    /// Precision this request class should be served at, right now.
    fn decide(&mut self, class: TaskClass) -> Precision;

    /// Feed one completed request back into the policy.  Returns the
    /// rung move this observation triggered, if any, so the caller can
    /// trace cause → effect.
    fn observe(&mut self, obs: &Observation) -> Option<PolicyMove>;

    /// Feed one shadow-probe result back into the policy.  Returns the
    /// rung move this probe triggered, if any.
    fn observe_probe(
        &mut self,
        class: TaskClass,
        precision: Precision,
        probe: &ProbeResult,
    ) -> Option<PolicyMove>;

    /// Should the server shadow-probe this completion?  Stateful (the
    /// sampler advances its cadence counter on every call).
    fn wants_probe(&mut self, class: TaskClass, precision: Precision) -> bool;

    /// Decision counters for stats surfacing.
    fn snapshot(&self) -> PolicySnapshot;
}

/// Today's behavior, unchanged: a static class → precision config
/// lookup.  No telemetry, no probes, no switches — and therefore zero
/// overhead beyond three copies.
#[derive(Debug, Clone)]
pub struct StaticPolicy {
    generation: Precision,
    understanding: Precision,
    default: Precision,
    decisions: u64,
}

impl StaticPolicy {
    pub fn new(cfg: &ServeConfig) -> Self {
        StaticPolicy {
            generation: cfg.generation_precision,
            understanding: cfg.understanding_precision,
            default: cfg.default_precision,
            decisions: 0,
        }
    }
}

impl PrecisionPolicy for StaticPolicy {
    fn decide(&mut self, class: TaskClass) -> Precision {
        self.decisions += 1;
        match class {
            TaskClass::Generation => self.generation,
            TaskClass::Understanding => self.understanding,
            TaskClass::Other => self.default,
        }
    }

    fn observe(&mut self, _obs: &Observation) -> Option<PolicyMove> {
        None
    }

    fn observe_probe(
        &mut self,
        _class: TaskClass,
        _precision: Precision,
        _probe: &ProbeResult,
    ) -> Option<PolicyMove> {
        None
    }

    fn wants_probe(&mut self, _class: TaskClass, _precision: Precision) -> bool {
        false
    }

    fn snapshot(&self) -> PolicySnapshot {
        PolicySnapshot { decisions: self.decisions, ..PolicySnapshot::default() }
    }
}

/// The adaptive control plane: telemetry windows feeding an SLO
/// controller, with shadow probes supplying the quality signal.  Each
/// class starts at its static config precision (clamped to the
/// configured ladder) and moves one rung at a time from there.
#[derive(Debug)]
pub struct AdaptivePolicy {
    telemetry: Telemetry,
    controller: SloController,
    sampler: ProbeSampler,
    decisions: u64,
    probes: u64,
}

impl AdaptivePolicy {
    /// Panics if `cfg.policy.probe_rate` is 0: shadow probes are the
    /// adaptive loop's only quality signal — without them demotion
    /// would run blind and promotion could never trigger.  (The JSON
    /// config path rejects this combination at parse time.)
    pub fn new(cfg: &ServeConfig) -> Self {
        assert!(
            cfg.policy.probe_rate > 0.0,
            "AdaptivePolicy requires probe_rate > 0 (shadow probes are the quality guard)"
        );
        let mut controller = SloController::new(&cfg.ladder, cfg.policy.clone());
        controller.init_class(TaskClass::Generation, cfg.generation_precision);
        controller.init_class(TaskClass::Understanding, cfg.understanding_precision);
        controller.init_class(TaskClass::Other, cfg.default_precision);
        AdaptivePolicy {
            telemetry: Telemetry::new(cfg.policy.window, cfg.policy.slo_p95_ms),
            controller,
            sampler: ProbeSampler::new(cfg.policy.probe_rate),
            decisions: 0,
            probes: 0,
        }
    }

    /// Read access for reporting/tests.
    pub fn telemetry(&self) -> &Telemetry {
        &self.telemetry
    }

    pub fn controller(&self) -> &SloController {
        &self.controller
    }

    /// O(1): the over-SLO fraction is maintained incrementally by the
    /// lane's ring — no sorting or allocation on the observation path.
    fn signal(&self, class: TaskClass, p: Precision) -> LaneSignal {
        match self.telemetry.lane(class, p) {
            Some(l) => LaneSignal {
                frac_over_slo: l.latency_ms.frac_over(),
                agreement: l.agreement,
                samples: l.latency_ms.len(),
            },
            None => LaneSignal::default(),
        }
    }

    /// Run one controller decision for `class` at its current rung,
    /// reporting the move (if any) with the signal that justified it.
    fn tick(&mut self, class: TaskClass) -> Option<PolicyMove> {
        let current = self.controller.current(class);
        let ladder = self.controller.ladder();
        let below = ladder
            .iter()
            .position(|&w| w == current)
            .and_then(|i| ladder.get(i + 1))
            .copied();
        let cur_signal = self.signal(class, current);
        let cand_signal = below.map(|p| self.signal(class, p)).unwrap_or_default();
        match self.controller.tick(class, cur_signal, cand_signal) {
            Decision::Hold => None,
            Decision::Demote { from, to } => Some(PolicyMove {
                demote: true,
                from,
                to,
                score_pm: crate::obs::permille(cur_signal.frac_over_slo),
            }),
            Decision::Promote { from, to } => Some(PolicyMove {
                demote: false,
                from,
                to,
                score_pm: crate::obs::permille(cur_signal.agreement.unwrap_or(0.0)),
            }),
        }
    }
}

impl PrecisionPolicy for AdaptivePolicy {
    fn decide(&mut self, class: TaskClass) -> Precision {
        self.decisions += 1;
        self.controller.current(class)
    }

    fn observe(&mut self, obs: &Observation) -> Option<PolicyMove> {
        self.telemetry.observe(
            obs.class,
            obs.precision,
            obs.latency_ms(),
            obs.tokens,
            obs.queue_depth,
        );
        // decide-by-observation: every completion is a controller tick
        // for its class (cooldown inside the controller spaces out the
        // actual switches)
        self.tick(obs.class)
    }

    fn observe_probe(
        &mut self,
        class: TaskClass,
        precision: Precision,
        probe: &ProbeResult,
    ) -> Option<PolicyMove> {
        self.probes += 1;
        self.telemetry.observe_probe(class, precision, probe);
        // quality reacts immediately — a collapsed probe must not wait
        // for the next latency observation to promote
        self.tick(class)
    }

    fn wants_probe(&mut self, class: TaskClass, precision: Precision) -> bool {
        self.sampler.should_probe(class, precision)
    }

    fn snapshot(&self) -> PolicySnapshot {
        PolicySnapshot {
            decisions: self.decisions,
            demotions: self.controller.demotions,
            promotions: self.controller.promotions,
            probes: self.probes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ServeConfig {
        ServeConfig {
            policy: crate::config::PolicyConfig {
                adaptive: true,
                slo_p95_ms: 5.0,
                min_samples: 4,
                cooldown: 0,
                ..crate::config::PolicyConfig::default()
            },
            ..ServeConfig::default()
        }
    }

    fn obs(class: TaskClass, p: Precision, ms: f64) -> Observation {
        Observation {
            class,
            precision: p,
            queue_ms: ms / 2.0,
            compute_ms: ms / 2.0,
            tokens: 1,
            queue_depth: 0,
        }
    }

    #[test]
    fn static_policy_matches_config() {
        let c = ServeConfig::default();
        let mut p = StaticPolicy::new(&c);
        assert_eq!(p.decide(TaskClass::Generation), c.generation_precision);
        assert_eq!(p.decide(TaskClass::Understanding), c.understanding_precision);
        assert_eq!(p.decide(TaskClass::Other), c.default_precision);
        assert!(!p.wants_probe(TaskClass::Generation, Precision::of(4)));
        let snap = p.snapshot();
        assert_eq!(snap.decisions, 3);
        assert_eq!(snap.demotions + snap.promotions + snap.probes, 0);
    }

    #[test]
    fn adaptive_starts_at_static_precisions() {
        let c = cfg();
        let mut p = AdaptivePolicy::new(&c);
        assert_eq!(p.decide(TaskClass::Generation), c.generation_precision);
        assert_eq!(p.decide(TaskClass::Understanding), c.understanding_precision);
        assert_eq!(p.decide(TaskClass::Other), c.default_precision);
    }

    #[test]
    fn latency_pressure_demotes_a_class() {
        let c = cfg();
        let mut p = AdaptivePolicy::new(&c);
        let start = p.decide(TaskClass::Understanding);
        for _ in 0..16 {
            let at = p.decide(TaskClass::Understanding);
            let _ = p.observe(&obs(TaskClass::Understanding, at, 40.0));
        }
        let now = p.decide(TaskClass::Understanding);
        assert!(now < start, "sustained SLO violation must demote ({start} -> {now})");
        assert!(p.snapshot().demotions >= 1);
        // the untouched class did not move
        assert_eq!(p.decide(TaskClass::Generation), c.generation_precision);
    }

    #[test]
    fn probe_collapse_promotes_a_class() {
        let c = cfg();
        let mut p = AdaptivePolicy::new(&c);
        let start = p.decide(TaskClass::Understanding);
        let bad = ProbeResult {
            agreement: 0.1,
            mean_divergence: 1.0,
            divergence_amplitude: 0.5,
            positions: 4,
        };
        let mv = p.observe_probe(TaskClass::Understanding, start, &bad);
        let now = p.decide(TaskClass::Understanding);
        assert_eq!(mv, Some(PolicyMove { demote: false, from: start, to: now, score_pm: 100 }));
        assert!(now > start, "collapsed agreement must promote ({start} -> {now})");
        assert_eq!(p.snapshot().promotions, 1);
        assert_eq!(p.snapshot().probes, 1);
    }
}
