//! Shadow quality probes: re-score served generations at master
//! precision.
//!
//! Serving at a truncated precision is only safe while its outputs stay
//! close to the master's — the very robustness OTARo fine-tunes for.
//! The probe measures that *online*: for a sampled fraction of completed
//! requests, every decode position is re-scored **teacher-forced**
//! (conditioning on the tokens that were actually served) at both the
//! served precision and the ladder master, through the same
//! [`LogitsBackend`] that served the traffic.  Two signals come out:
//!
//! * **token agreement** — the fraction of positions where the greedy
//!   argmax at the served precision matches the master's (computed with
//!   [`sampling::argmax`], the exact tie-breaking the serving loop
//!   uses);
//! * **logit divergence** — mean |Δlogit| per position over the vocab,
//!   summarized by its mean and by the peak-to-peak
//!   [`amplitude`](crate::analysis::epsilon::amplitude) of the
//!   per-position curve (the same machinery that quantifies the ε(ω)
//!   sawtooth the paper attributes precision noise to).
//!
//! Probes run *between* generation runs (never mid-run — they swap the
//! backend's loaded view), teacher-forcing keeps them independent of
//! sampling temperature, and batching packs up to `batch_shape().0`
//! positions per `logits_step`, so one probe costs about
//! `2 · ceil(new_tokens / batch_rows)` extra forward steps.

use crate::data::tokenizer::PAD;
use crate::infer::sampling;
use crate::sefp::Precision;
use crate::serve::{LogitsBackend, PrecisionLadder, TaskClass};

/// A completed request queued for shadow re-scoring.
#[derive(Debug, Clone)]
pub struct ProbeTask {
    /// id of the request whose completion is being re-scored, so probe
    /// and policy-decision trace events land on the right trace
    pub id: u64,
    pub class: TaskClass,
    /// precision the request was served at
    pub precision: Precision,
    /// prompt followed by the served generation
    pub context: Vec<i32>,
    /// how many trailing tokens of `context` were generated
    pub n_gen: usize,
}

/// What a shadow probe measured.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ProbeResult {
    /// fraction of decode positions where the served precision's greedy
    /// token equals the master's (1.0 when nothing was generated)
    pub agreement: f64,
    /// mean |Δlogit| between the two precisions, averaged over vocab
    /// and positions
    pub mean_divergence: f64,
    /// peak-to-peak amplitude of the per-position divergence curve
    pub divergence_amplitude: f64,
    /// decode positions scored
    pub positions: usize,
}

impl ProbeResult {
    fn trivial() -> Self {
        ProbeResult {
            agreement: 1.0,
            mean_divergence: 0.0,
            divergence_amplitude: 0.0,
            positions: 0,
        }
    }
}

/// Teacher-forced logits for every decode position of `task`, at one
/// precision.  Positions are packed `batch_rows` at a time; each row's
/// window is the last `seq_len` tokens of the context prefix ending
/// just before that position's token.
fn position_logits<B: LogitsBackend>(
    backend: &mut B,
    ladder: &mut PrecisionLadder,
    task: &ProbeTask,
    p: Precision,
) -> anyhow::Result<Vec<Vec<f32>>> {
    let (bsz, seq_len) = backend.batch_shape();
    let vocab = backend.vocab_size();
    let view = ladder.view_at(p)?;
    backend.load_view(&view)?;
    drop(view);

    let prompt_len = task.context.len() - task.n_gen;
    let mut out = Vec::with_capacity(task.n_gen);
    let mut tokens = vec![PAD; bsz * seq_len];
    let mut last_pos = vec![0usize; bsz];
    for start in (0..task.n_gen).step_by(bsz.max(1)) {
        let end = (start + bsz).min(task.n_gen);
        tokens.fill(PAD);
        for (ri, i) in (start..end).enumerate() {
            let prefix = &task.context[..prompt_len + i];
            let n = prefix.len().min(seq_len);
            tokens[ri * seq_len..ri * seq_len + n]
                .copy_from_slice(&prefix[prefix.len() - n..]);
            last_pos[ri] = n - 1;
        }
        let logits = backend.logits_step(&tokens)?;
        for (ri, &lp) in last_pos.iter().take(end - start).enumerate() {
            let off = (ri * seq_len + lp) * vocab;
            out.push(logits[off..off + vocab].to_vec());
        }
    }
    Ok(out)
}

/// Run one shadow probe: re-score `task` teacher-forced at its served
/// precision and at the ladder master, and compare.  Leaves the
/// backend's loaded view at the master — callers (the serve loop)
/// reload their own view at the start of every run.
pub fn shadow_probe<B: LogitsBackend>(
    backend: &mut B,
    ladder: &mut PrecisionLadder,
    task: &ProbeTask,
) -> anyhow::Result<ProbeResult> {
    let master = ladder.top();
    if task.n_gen == 0 || task.precision >= master {
        return Ok(ProbeResult::trivial());
    }
    anyhow::ensure!(
        task.n_gen < task.context.len(),
        "probe task needs a non-empty prompt before its generated tokens"
    );
    let served = position_logits(backend, ladder, task, task.precision)?;
    let reference = position_logits(backend, ladder, task, master)?;

    let mut matches = 0usize;
    let mut curve = Vec::with_capacity(task.n_gen);
    for (i, (lo, hi)) in served.iter().zip(&reference).enumerate() {
        if sampling::argmax(lo) == sampling::argmax(hi) {
            matches += 1;
        }
        let div = lo
            .iter()
            .zip(hi)
            .map(|(a, b)| (a - b).abs() as f64)
            .sum::<f64>()
            / lo.len().max(1) as f64;
        curve.push((i as f32, div as f32));
    }
    let mean_divergence = crate::analysis::epsilon::mean_ordinate(&curve) as f64;
    let divergence_amplitude = if curve.len() > 1 {
        crate::analysis::epsilon::amplitude(&curve) as f64
    } else {
        0.0
    };
    Ok(ProbeResult {
        agreement: matches as f64 / task.n_gen as f64,
        mean_divergence,
        divergence_amplitude,
        positions: task.n_gen,
    })
}

/// Deterministic probe cadence: a per-`(TaskClass, Precision)`
/// fractional accumulator adds `rate` per completion and fires whenever
/// it crosses 1.0, so the probed fraction matches the configured rate
/// exactly for ANY rate in (0, 1] (an integer `1/rate` cadence would
/// round 0.7 up to probing every completion).  A counter, not an RNG
/// draw — probe timing is reproducible run-to-run, which the
/// integration tests and any trace replay depend on.
#[derive(Debug, Clone)]
pub struct ProbeSampler {
    /// target probed fraction in [0, 1]; 0 = probing disabled
    rate: f64,
    accumulators: std::collections::BTreeMap<(TaskClass, Precision), f64>,
}

impl ProbeSampler {
    pub fn new(rate: f64) -> Self {
        ProbeSampler {
            rate: rate.clamp(0.0, 1.0),
            accumulators: std::collections::BTreeMap::new(),
        }
    }

    /// Should this completion be shadow-probed?
    pub fn should_probe(&mut self, class: TaskClass, precision: Precision) -> bool {
        if self.rate <= 0.0 {
            return false;
        }
        let acc = self.accumulators.entry((class, precision)).or_insert(0.0);
        *acc += self.rate;
        if *acc >= 1.0 {
            *acc -= 1.0;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ParamStore;
    use crate::serve::SimBackend;

    fn ladder() -> PrecisionLadder {
        let params = ParamStore {
            tensors: vec![vec![0.25; 64]],
            names: vec!["w".into()],
            shapes: vec![vec![8, 8]],
            quantized: vec![true],
        };
        PrecisionLadder::from_params(&params)
    }

    fn task(m: u8, context: Vec<i32>, n_gen: usize) -> ProbeTask {
        ProbeTask { id: 0, class: TaskClass::Understanding, precision: Precision::of(m), context, n_gen }
    }

    #[test]
    fn high_fidelity_backend_scores_full_agreement() {
        // quality_noise small enough that no argmax flips: the served
        // precision tracks the master everywhere
        let mut b = SimBackend::new(2, 8, 16).with_quality_model(1e-4);
        let mut l = ladder();
        let r = shadow_probe(&mut b, &mut l, &task(4, vec![1, 2, 3, 4, 5], 3)).unwrap();
        assert_eq!(r.positions, 3);
        assert_eq!(r.agreement, 1.0);
        assert!(r.mean_divergence > 0.0, "precisions still differ in logit space");
        // 3 positions at 2 rows/step = 2 steps per precision, 2 precisions
        assert_eq!(b.calls, 4);
    }

    #[test]
    fn degraded_backend_scores_low_agreement() {
        let mut b = SimBackend::new(2, 8, 16).with_quality_model(20.0);
        let mut l = ladder();
        let r = shadow_probe(&mut b, &mut l, &task(3, (0..12).collect(), 8)).unwrap();
        assert_eq!(r.positions, 8);
        assert!(
            r.agreement < 0.8,
            "noise 20.0 swamps the base logits, agreement {} should collapse",
            r.agreement
        );
        assert!(r.mean_divergence > 0.0);
    }

    #[test]
    fn probe_is_deterministic() {
        let run = || {
            let mut b = SimBackend::new(2, 8, 16).with_quality_model(0.5);
            let mut l = ladder();
            shadow_probe(&mut b, &mut l, &task(3, (0..10).collect(), 6)).unwrap()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn master_precision_probe_is_trivial() {
        let mut b = SimBackend::new(2, 8, 16).with_quality_model(1.0);
        let mut l = ladder();
        let r = shadow_probe(&mut b, &mut l, &task(8, vec![1, 2, 3], 2)).unwrap();
        assert_eq!(r.agreement, 1.0);
        assert_eq!(r.positions, 0);
        assert_eq!(b.calls, 0, "nothing to compare against itself");
    }

    #[test]
    fn sampler_cadence_is_deterministic_per_lane() {
        let mut s = ProbeSampler::new(0.25);
        let lane = (TaskClass::Understanding, Precision::of(4));
        let fired: Vec<bool> =
            (0..8).map(|_| s.should_probe(lane.0, lane.1)).collect();
        assert_eq!(fired, vec![false, false, false, true, false, false, false, true]);
        // independent lanes have independent counters
        assert!(!s.should_probe(TaskClass::Generation, Precision::of(4)));
        // rate 0 never probes; rate 1 always probes
        assert!(!ProbeSampler::new(0.0).should_probe(lane.0, lane.1));
        assert!(ProbeSampler::new(1.0).should_probe(lane.0, lane.1));
    }

    #[test]
    fn sampler_hits_fractional_rates_exactly() {
        // a rate whose reciprocal is not an integer must still probe the
        // configured fraction, not round up to every completion
        for (rate, expect) in [(0.7, 700), (0.6, 600), (0.4, 400), (0.1, 100)] {
            let mut s = ProbeSampler::new(rate);
            let fired = (0..1000)
                .filter(|_| s.should_probe(TaskClass::Other, Precision::of(4)))
                .count();
            assert!(
                (fired as i64 - expect).abs() <= 1,
                "rate {rate}: fired {fired}, expected ~{expect}"
            );
        }
    }
}
