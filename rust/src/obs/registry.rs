//! The metrics registry: pre-registered handles, allocation-free
//! recording, deterministic JSON snapshots.
//!
//! Registration (startup path, allocates): [`Registry::counter`] /
//! [`Registry::gauge`] / [`Registry::histogram`] validate the name and
//! bucket bounds and return a typed index handle.  Recording (hot path,
//! never allocates): [`MetricSink::add`] / [`MetricSink::set`] /
//! [`MetricSink::observe`] resolve the handle by direct `Vec` index.
//! A handle from one registry used against another is a harmless no-op
//! (out-of-range index) rather than a panic — this module sits on the
//! request path.

use crate::json::{arr, n, obj, s, Value};
use crate::metrics::Summary;

/// Handle to a registered monotonic counter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Counter(u32);

/// Handle to a registered gauge (last-write-wins f64).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Gauge(u32);

/// Handle to a registered fixed-bucket histogram.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Histo(u32);

/// Default latency buckets, milliseconds (upper bounds; values above
/// the last bound land in the overflow bucket).
pub const LATENCY_MS_BUCKETS: &[f64] = &[
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0, 1000.0, 2500.0,
];

/// Buckets for ratios in [0, 1] (batch fill, agreement fractions).
pub const RATIO_BUCKETS: &[f64] = &[0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875, 1.0];

/// Buckets for probe token-agreement in [0, 1], finer near the top
/// where the quality floor lives.
pub const AGREEMENT_BUCKETS: &[f64] = &[0.25, 0.5, 0.7, 0.8, 0.9, 0.95, 0.99, 1.0];

/// The emit interface serve/policy/infer record through.  All methods
/// are infallible and allocation-free; implementors other than
/// [`Registry`] (e.g. [`NullSink`]) let tests and benches drop the
/// overhead entirely.
pub trait MetricSink {
    /// Add `by` to a counter.
    fn add(&mut self, c: Counter, by: u64);
    /// Set a gauge to `x`.
    fn set(&mut self, g: Gauge, x: f64);
    /// Record one histogram sample.
    fn observe(&mut self, h: Histo, x: f64);
    /// Increment a counter by one.
    fn inc(&mut self, c: Counter) {
        self.add(c, 1);
    }
}

/// A sink that discards every event.
#[derive(Debug, Default, Clone, Copy)]
pub struct NullSink;

impl MetricSink for NullSink {
    fn add(&mut self, _c: Counter, _by: u64) {}
    fn set(&mut self, _g: Gauge, _x: f64) {}
    fn observe(&mut self, _h: Histo, _x: f64) {}
}

#[derive(Debug, Clone)]
struct CounterSlot {
    name: String,
    value: u64,
}

#[derive(Debug, Clone)]
struct GaugeSlot {
    name: String,
    value: f64,
}

#[derive(Debug, Clone)]
struct HistoSlot {
    name: String,
    /// strictly increasing upper bounds; `counts[i]` holds samples with
    /// `x <= bounds[i]` (first matching bucket — NOT cumulative)
    bounds: Vec<f64>,
    counts: Vec<u64>,
    /// samples above the last bound (and non-finite samples)
    overflow: u64,
    sum: f64,
    /// exact-percentile window over the same stream (pre-allocated ring)
    summary: Summary,
}

impl HistoSlot {
    /// Bucket index for `x`: the first bound with `x <= bound`.  A
    /// value exactly on a bound lands in that bound's bucket,
    /// deterministically; values above every bound (or NaN, which
    /// compares false) return `None` → overflow.
    fn bucket_of(&self, x: f64) -> Option<usize> {
        self.bounds.iter().position(|&b| x <= b)
    }
}

/// The typed metrics registry.  See the module docs for the
/// registration/record split.
#[derive(Debug, Default, Clone)]
pub struct Registry {
    counters: Vec<CounterSlot>,
    gauges: Vec<GaugeSlot>,
    histos: Vec<HistoSlot>,
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    fn check_name(&self, name: &str) {
        assert!(!name.is_empty(), "metric name must be non-empty");
        let taken = self.counters.iter().any(|c| c.name == name)
            || self.gauges.iter().any(|g| g.name == name)
            || self.histos.iter().any(|h| h.name == name);
        assert!(!taken, "metric name {name:?} registered twice");
    }

    /// Register a monotonic counter; the returned handle is the only
    /// way to record into it.
    pub fn counter(&mut self, name: &str) -> Counter {
        self.check_name(name);
        self.counters.push(CounterSlot { name: String::from(name), value: 0 });
        Counter((self.counters.len() - 1) as u32)
    }

    /// Register a gauge (last-write-wins).
    pub fn gauge(&mut self, name: &str) -> Gauge {
        self.check_name(name);
        self.gauges.push(GaugeSlot { name: String::from(name), value: 0.0 });
        Gauge((self.gauges.len() - 1) as u32)
    }

    /// Register a fixed-bucket histogram.  `bounds` are upper bucket
    /// bounds and must be finite, non-empty, and strictly increasing
    /// (validated here, at registration, so the record path never has
    /// to).
    pub fn histogram(&mut self, name: &str, bounds: &[f64]) -> Histo {
        self.check_name(name);
        assert!(!bounds.is_empty(), "histogram {name:?} needs at least one bucket bound");
        for w in bounds.windows(2) {
            assert!(w[0] < w[1], "histogram {name:?} bounds must be strictly increasing");
        }
        assert!(bounds.iter().all(|b| b.is_finite()), "histogram {name:?} bounds must be finite");
        self.histos.push(HistoSlot {
            name: String::from(name),
            bounds: bounds.into(),
            counts: bounds.iter().map(|_| 0).collect(),
            overflow: 0,
            sum: 0.0,
            summary: Summary::preallocated(),
        });
        Histo((self.histos.len() - 1) as u32)
    }

    /// Current value of a counter (0 for a foreign handle).
    pub fn counter_value(&self, c: Counter) -> u64 {
        self.counters.get(c.0 as usize).map_or(0, |slot| slot.value)
    }

    /// Current value of a gauge (0.0 for a foreign handle).
    pub fn gauge_value(&self, g: Gauge) -> f64 {
        self.gauges.get(g.0 as usize).map_or(0.0, |slot| slot.value)
    }

    /// Total samples a histogram has recorded (buckets + overflow).
    pub fn histo_count(&self, h: Histo) -> u64 {
        self.histos
            .get(h.0 as usize)
            .map_or(0, |slot| slot.overflow + slot.counts.iter().sum::<u64>())
    }

    /// Clone of the exact-percentile [`Summary`] a histogram keeps
    /// alongside its buckets (empty for a foreign handle).  Reporting
    /// path — the clone allocates, `observe` does not.
    pub fn histo_summary(&self, h: Histo) -> Summary {
        self.histos.get(h.0 as usize).map_or_else(Summary::new, |slot| slot.summary.clone())
    }

    /// Number of registered counters (flight-recorder attach path).
    pub fn n_counters(&self) -> usize {
        self.counters.len()
    }

    /// Number of registered gauges.
    pub fn n_gauges(&self) -> usize {
        self.gauges.len()
    }

    /// Number of registered histograms.
    pub fn n_histos(&self) -> usize {
        self.histos.len()
    }

    /// Name of the `i`-th counter, in registration order.
    pub fn counter_name(&self, i: usize) -> Option<&str> {
        self.counters.get(i).map(|c| c.name.as_str())
    }

    /// Name of the `i`-th gauge, in registration order.
    pub fn gauge_name(&self, i: usize) -> Option<&str> {
        self.gauges.get(i).map(|g| g.name.as_str())
    }

    /// Name of the `i`-th histogram, in registration order.
    pub fn histo_name(&self, i: usize) -> Option<&str> {
        self.histos.get(i).map(|h| h.name.as_str())
    }

    // Indexed reads for the flight-recorder sampling loop: scalar
    // returns and borrowed slices only, so a sampler iterating a
    // registration-frozen index range never allocates.

    /// Value of the `i`-th counter (0 out of range).
    pub fn counter_at(&self, i: usize) -> u64 {
        self.counters.get(i).map_or(0, |c| c.value)
    }

    /// Value of the `i`-th gauge (0.0 out of range).
    pub fn gauge_at(&self, i: usize) -> f64 {
        self.gauges.get(i).map_or(0.0, |g| g.value)
    }

    /// Bucket bounds of the `i`-th histogram (empty out of range).
    pub fn histo_bounds_at(&self, i: usize) -> &[f64] {
        self.histos.get(i).map_or(&[], |h| h.bounds.as_slice())
    }

    /// Count in bucket `b` of the `i`-th histogram; `b == bounds.len()`
    /// addresses the overflow bucket (0 out of range).
    pub fn histo_bucket_at(&self, i: usize, b: usize) -> u64 {
        self.histos.get(i).map_or(0, |h| {
            if b == h.bounds.len() {
                h.overflow
            } else {
                h.counts.get(b).copied().unwrap_or(0)
            }
        })
    }

    /// Running sum of finite samples of the `i`-th histogram.
    pub fn histo_sum_at(&self, i: usize) -> f64 {
        self.histos.get(i).map_or(0.0, |h| h.sum)
    }

    /// Serialize every registered metric, deterministically: the JSON
    /// object sorts keys (`json::Value::Obj` is a `BTreeMap`), so two
    /// registries in identical states snapshot to identical bytes.
    pub fn snapshot(&self) -> Value {
        let counters: Vec<(&str, Value)> =
            self.counters.iter().map(|c| (c.name.as_str(), n(c.value as f64))).collect();
        let gauges: Vec<(&str, Value)> =
            self.gauges.iter().map(|g| (g.name.as_str(), n(g.value))).collect();
        let histos: Vec<(&str, Value)> =
            self.histos.iter().map(|h| (h.name.as_str(), histo_json(h))).collect();
        obj(vec![
            ("counters", obj(counters)),
            ("gauges", obj(gauges)),
            ("histograms", obj(histos)),
            ("schema", s("otaro.metrics.v1")),
        ])
    }
}

fn histo_json(h: &HistoSlot) -> Value {
    let count = h.overflow + h.counts.iter().sum::<u64>();
    // an empty summary reports ±inf min/max, which is not valid JSON —
    // clamp the empty case to zeros
    let (min, max) = if count == 0 { (0.0, 0.0) } else { (h.summary.min, h.summary.max) };
    obj(vec![
        ("bounds", arr(h.bounds.iter().map(|&b| n(b)).collect())),
        ("counts", arr(h.counts.iter().map(|&c| n(c as f64)).collect())),
        ("overflow", n(h.overflow as f64)),
        ("count", n(count as f64)),
        ("sum", n(h.sum)),
        ("min", n(min)),
        ("max", n(max)),
        ("mean", n(h.summary.mean())),
        ("p50", n(h.summary.p50())),
        ("p95", n(h.summary.p95())),
        ("p99", n(h.summary.p99())),
    ])
}

// The record path: handle-indexed, branch-light, and allocation-free —
// `Summary::push` writes into its pre-allocated ring, bucket search is
// a linear scan over a handful of registration-frozen bounds.
// lint: region(no_alloc)
impl MetricSink for Registry {
    fn add(&mut self, c: Counter, by: u64) {
        if let Some(slot) = self.counters.get_mut(c.0 as usize) {
            slot.value = slot.value.wrapping_add(by);
        }
    }

    fn set(&mut self, g: Gauge, x: f64) {
        if let Some(slot) = self.gauges.get_mut(g.0 as usize) {
            slot.value = x;
        }
    }

    fn observe(&mut self, h: Histo, x: f64) {
        if let Some(slot) = self.histos.get_mut(h.0 as usize) {
            match slot.bucket_of(x) {
                Some(i) => slot.counts[i] += 1,
                None => slot.overflow += 1,
            }
            // non-finite samples are counted (overflow) but kept out of
            // sum/summary — one NaN must not poison the aggregates or
            // make the snapshot unserializable
            if x.is_finite() {
                slot.sum += x;
                slot.summary.push(x);
            }
        }
    }
}
// lint: end_region

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_record_through_handles() {
        let mut r = Registry::new();
        let c = r.counter("serve.served");
        let g = r.gauge("queue.depth");
        r.inc(c);
        r.add(c, 4);
        r.set(g, 7.0);
        r.set(g, 3.0);
        assert_eq!(r.counter_value(c), 5);
        assert_eq!(r.gauge_value(g), 3.0);
    }

    #[test]
    fn foreign_handles_are_harmless_noops() {
        let mut a = Registry::new();
        let mut b = Registry::new();
        let c = a.counter("only.in.a");
        // b never registered anything: the handle is out of range there
        b.add(c, 100);
        assert_eq!(b.counter_value(c), 0);
        assert_eq!(a.counter_value(c), 0);
    }

    #[test]
    #[should_panic(expected = "registered twice")]
    fn duplicate_names_are_a_registration_error() {
        let mut r = Registry::new();
        let _ = r.counter("x");
        let _ = r.gauge("x");
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn histogram_bounds_must_increase() {
        let mut r = Registry::new();
        let _ = r.histogram("h", &[1.0, 1.0]);
    }

    #[test]
    fn histogram_empty_window() {
        let mut r = Registry::new();
        let h = r.histogram("lat", LATENCY_MS_BUCKETS);
        assert_eq!(r.histo_count(h), 0);
        let sum = r.histo_summary(h);
        assert_eq!(sum.n, 0);
        assert_eq!(sum.p95(), 0.0);
        // empty min/max must serialize as zeros, not ±inf
        let snap = r.snapshot().to_string();
        assert!(!snap.contains("inf"), "{snap}");
        assert!(crate::json::parse(&snap).is_ok());
    }

    #[test]
    fn histogram_single_and_identical_samples() {
        let mut r = Registry::new();
        let h = r.histogram("lat", &[1.0, 2.0, 4.0]);
        r.observe(h, 1.5);
        assert_eq!(r.histo_count(h), 1);
        let one = r.histo_summary(h);
        assert_eq!(one.p50(), 1.5);
        assert_eq!(one.p99(), 1.5);
        assert_eq!((one.min, one.max), (1.5, 1.5));
        for _ in 0..9 {
            r.observe(h, 1.5);
        }
        let same = r.histo_summary(h);
        assert_eq!(same.n, 10);
        assert_eq!(same.std(), 0.0);
        assert_eq!(same.p95(), 1.5);
    }

    #[test]
    fn bucket_boundaries_are_deterministic() {
        // a value exactly on a bound lands in THAT bound's bucket
        // (x <= bound, first match), never split or rounded across
        let mut r = Registry::new();
        let h = r.histogram("b", &[1.0, 2.0, 4.0]);
        for x in [1.0, 2.0, 4.0] {
            r.observe(h, x);
        }
        r.observe(h, 0.5); // below the first bound -> bucket 0
        r.observe(h, 1.0000001); // just past a bound -> next bucket
        r.observe(h, 4.0000001); // past the last bound -> overflow
        r.observe(h, f64::NAN); // NaN compares false everywhere -> overflow
        let snap = r.snapshot();
        let counts = snap
            .get("histograms")
            .and_then(|h| h.get("b"))
            .and_then(|b| b.get("counts"))
            .and_then(|c| c.as_arr())
            .unwrap();
        let counts: Vec<u64> = counts.iter().map(|v| v.as_f64().unwrap() as u64).collect();
        assert_eq!(counts, vec![2, 2, 1]);
        let overflow = snap
            .get("histograms")
            .and_then(|h| h.get("b"))
            .and_then(|b| b.get("overflow"))
            .and_then(|v| v.as_f64())
            .unwrap();
        assert_eq!(overflow as u64, 2);
    }

    #[test]
    fn snapshots_are_deterministic_bytes() {
        let build = || {
            let mut r = Registry::new();
            let c = r.counter("a.count");
            let g = r.gauge("z.gauge");
            let h = r.histogram("m.hist", RATIO_BUCKETS);
            r.add(c, 3);
            r.set(g, 0.25);
            for x in [0.1, 0.5, 0.5, 0.875, 1.0] {
                r.observe(h, x);
            }
            r.snapshot().to_string()
        };
        let a = build();
        assert_eq!(a, build());
        // and the snapshot round-trips through the in-repo parser
        let v = crate::json::parse(&a).unwrap();
        assert_eq!(v.get("schema").and_then(|x| x.as_str()), Some("otaro.metrics.v1"));
    }

    #[test]
    fn indexed_reads_mirror_handle_reads() {
        let mut r = Registry::new();
        let c = r.counter("c0");
        let g = r.gauge("g0");
        let h = r.histogram("h0", &[1.0, 2.0]);
        r.add(c, 7);
        r.set(g, 2.5);
        r.observe(h, 0.5);
        r.observe(h, 1.5);
        r.observe(h, 9.0);
        assert_eq!((r.n_counters(), r.n_gauges(), r.n_histos()), (1, 1, 1));
        assert_eq!(r.counter_name(0), Some("c0"));
        assert_eq!(r.gauge_name(0), Some("g0"));
        assert_eq!(r.histo_name(0), Some("h0"));
        assert_eq!(r.counter_at(0), r.counter_value(c));
        assert_eq!(r.gauge_at(0), r.gauge_value(g));
        assert_eq!(r.histo_bounds_at(0), &[1.0, 2.0]);
        // bucket index bounds.len() addresses the overflow bucket
        let buckets = [r.histo_bucket_at(0, 0), r.histo_bucket_at(0, 1), r.histo_bucket_at(0, 2)];
        assert_eq!(buckets, [1, 1, 1]);
        assert_eq!(r.histo_sum_at(0), 11.0);
        // out of range: zeros and empties, never a panic
        assert_eq!(r.counter_at(9), 0);
        assert_eq!(r.gauge_name(9), None);
        assert!(r.histo_bounds_at(9).is_empty());
        assert_eq!(r.histo_bucket_at(0, 9), 0);
    }

    #[test]
    fn null_sink_and_trait_objects() {
        let mut r = Registry::new();
        let c = r.counter("c");
        {
            let sink: &mut dyn MetricSink = &mut r;
            sink.inc(c);
        }
        assert_eq!(r.counter_value(c), 1);
        let mut null = NullSink;
        null.inc(c);
        null.set(Gauge(0), 1.0);
        null.observe(Histo(0), 1.0);
    }
}
