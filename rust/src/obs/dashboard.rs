//! Generated dashboard definitions over a registry snapshot.
//!
//! [`dashboard`] turns an `otaro.metrics.v1` snapshot (from
//! [`Registry::snapshot`](super::Registry::snapshot)) into a
//! deterministic `otaro.dashboard.v1` JSON spec: rows of panels keyed by
//! registry metric names, with one row per serve rung (its latency
//! histogram + served/shed counters side by side) plus serving, policy
//! (probe-agreement), ladder, and backend rows.  The spec depends only
//! on the *metric set* — two snapshots of the same registered metrics
//! produce byte-identical specs, so a golden-file test can pin the
//! output and any rename/addition shows up as a review-visible diff.
//!
//! The pattern follows the sequencer-style `dashboard_definitions`
//! approach named in the ROADMAP: dashboards are build artifacts derived
//! from the code's own metric registrations, never hand-synced.
//!
//! [`timeline_dashboard`] is the time-axis counterpart: it takes an
//! `otaro.flight.v1` timeline (from
//! [`FlightRecorder`](super::FlightRecorder)) instead of a point-in-time
//! snapshot and emits per-frame series panels — queue depth, per-rung
//! tokens per frame, and per-rung stage p95s estimated from the frame's
//! histogram bucket deltas — with the timeline's marks passed through so
//! a renderer can pin config flips onto the time axis.

use crate::json::{arr, n, obj, s, Value};

/// Row a metric lands in, in display order.
fn row_for(name: &str) -> String {
    if let Some(rest) = name.strip_prefix("serve.rung.") {
        let rung = rest.split('.').next().unwrap_or(rest);
        return format!("rung {rung}");
    }
    for prefix in ["serve", "profile", "policy", "ladder", "backend"] {
        if name.starts_with(prefix) && name[prefix.len()..].starts_with('.') {
            return prefix.to_string();
        }
    }
    "other".to_string()
}

/// Short panel title: the last dotted segment of the metric name.
fn panel_title(name: &str) -> &str {
    name.rsplit('.').next().unwrap_or(name)
}

/// Build a deterministic `otaro.dashboard.v1` spec from an
/// `otaro.metrics.v1` snapshot.  Unknown or missing sections are
/// skipped; an empty snapshot yields an empty `rows` array.
pub fn dashboard(snapshot: &Value) -> Value {
    // (row, metric, panel type) for every registered metric
    let mut panels: Vec<(String, String, &'static str)> = Vec::new();
    for (section, ty) in
        [("counters", "counter"), ("gauges", "gauge"), ("histograms", "histogram")]
    {
        if let Some(map) = snapshot.get(section).and_then(|v| v.as_obj()) {
            // Value::Obj is a BTreeMap: keys arrive sorted
            for name in map.keys() {
                panels.push((row_for(name), name.clone(), ty));
            }
        }
    }
    panels.sort_by(|a, b| (&a.0, &a.1).cmp(&(&b.0, &b.1)));

    let mut rung_rows: Vec<String> =
        panels.iter().map(|(row, _, _)| row.clone()).filter(|r| r.starts_with("rung ")).collect();
    rung_rows.sort();
    rung_rows.dedup();
    let mut order: Vec<String> = vec!["serve".to_string()];
    order.extend(rung_rows);
    order.extend(
        ["profile", "policy", "ladder", "backend", "other"].into_iter().map(str::to_string),
    );

    let rows: Vec<Value> = order
        .iter()
        .filter_map(|row| {
            let row_panels: Vec<Value> = panels
                .iter()
                .filter(|(r, _, _)| r == row)
                .map(|(_, metric, ty)| {
                    obj(vec![
                        ("metric", s(metric.as_str())),
                        ("title", s(panel_title(metric))),
                        ("type", s(*ty)),
                    ])
                })
                .collect();
            if row_panels.is_empty() {
                return None;
            }
            Some(obj(vec![
                ("panels", arr(row_panels)),
                ("title", s(row.as_str())),
            ]))
        })
        .collect();

    obj(vec![
        ("rows", arr(rows)),
        ("schema", s("otaro.dashboard.v1")),
        ("title", s("otaro serve")),
        ("panels_total", n(panels.len() as f64)),
    ])
}

/// Estimated p95 of one frame's observations: the smallest bucket bound
/// covering 95% of the frame's count deltas.  The overflow bucket
/// reports the top bound — the histogram cannot resolve beyond it — and
/// an empty frame reports 0.
fn p95_from_deltas(bounds: &[f64], buckets: &[u64]) -> f64 {
    let total: u64 = buckets.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let need = (total * 95).div_ceil(100);
    let mut cum = 0u64;
    for (i, &b) in buckets.iter().enumerate() {
        cum += b;
        if cum >= need {
            return bounds.get(i).or(bounds.last()).copied().unwrap_or(0.0);
        }
    }
    bounds.last().copied().unwrap_or(0.0)
}

fn index_of(names: &[Value], want: &str) -> Option<usize> {
    names.iter().position(|v| v.as_str() == Some(want))
}

/// Build a deterministic `otaro.timeline_dashboard.v1` spec from an
/// `otaro.flight.v1` timeline: one tick axis, per-frame series panels
/// (queue depth, per-rung tokens/frame from counter deltas, per-rung
/// stage p95s from histogram bucket deltas — the latter only when the
/// timeline carries its histogram planes, i.e. the full timeline, not
/// the det subset), and the timeline's marks passed through verbatim.
pub fn timeline_dashboard(timeline: &Value) -> Value {
    let frames = timeline.get("frames").and_then(|v| v.as_arr()).unwrap_or(&[]);
    let gauges = timeline.get("gauges").and_then(|v| v.as_arr()).unwrap_or(&[]);
    let counters = timeline.get("counters").and_then(|v| v.as_arr()).unwrap_or(&[]);
    let histos = timeline.get("histograms").and_then(|v| v.as_arr()).unwrap_or(&[]);

    let ticks: Vec<Value> = frames
        .iter()
        .map(|f| n(f.get("tick").and_then(|t| t.as_f64()).unwrap_or(0.0)))
        .collect();
    // one point per frame out of the named plane ("c" counter deltas,
    // "g" gauge values), zero-filled where a frame is malformed
    let series_from = |plane: &str, idx: usize| -> Vec<Value> {
        frames
            .iter()
            .map(|f| {
                let v = f
                    .get(plane)
                    .and_then(|p| p.as_arr())
                    .and_then(|p| p.get(idx))
                    .and_then(|x| x.as_f64())
                    .unwrap_or(0.0);
                n(v)
            })
            .collect()
    };

    let mut panels: Vec<Value> = Vec::new();
    for (name, title) in
        [("serve.queue_depth", "queue depth"), ("serve.queue_depth_peak", "queue depth peak")]
    {
        if let Some(gi) = index_of(gauges, name) {
            panels.push(obj(vec![
                ("metric", s(name)),
                ("series", arr(series_from("g", gi))),
                ("title", s(title)),
                ("type", s("timeseries")),
            ]));
        }
    }
    // counter frames already carry deltas, so the series IS tokens/frame
    for (ci, cname) in counters.iter().enumerate() {
        let Some(name) = cname.as_str() else { continue };
        let Some(rest) = name.strip_prefix("serve.rung.") else { continue };
        let Some(rung) = rest.strip_suffix(".tokens") else { continue };
        panels.push(obj(vec![
            ("metric", s(name)),
            ("series", arr(series_from("c", ci))),
            ("title", s(format!("{rung} tokens/frame"))),
            ("type", s("timeseries")),
        ]));
    }
    for (hi, h) in histos.iter().enumerate() {
        let Some(name) = h.get("name").and_then(|x| x.as_str()) else { continue };
        let Some(rest) = name.strip_prefix("profile.rung.") else { continue };
        let bounds: Vec<f64> = h
            .get("bounds")
            .and_then(|b| b.as_arr())
            .map(|b| b.iter().filter_map(|x| x.as_f64()).collect())
            .unwrap_or_default();
        let series: Vec<Value> = frames
            .iter()
            .map(|f| {
                let buckets: Vec<u64> = f
                    .get("h")
                    .and_then(|p| p.as_arr())
                    .and_then(|p| p.get(hi))
                    .and_then(|b| b.as_arr())
                    .map(|b| b.iter().filter_map(|x| x.as_f64()).map(|x| x as u64).collect())
                    .unwrap_or_default();
                n(p95_from_deltas(&bounds, &buckets))
            })
            .collect();
        panels.push(obj(vec![
            ("metric", s(name)),
            ("series", arr(series)),
            ("title", s(format!("{} p95", rest.replace('.', " ")))),
            ("type", s("timeseries")),
        ]));
    }

    let marks = timeline.get("marks").cloned().unwrap_or_else(|| Value::Arr(Vec::new()));
    obj(vec![
        ("marks", marks),
        ("panels", arr(panels)),
        ("schema", s("otaro.timeline_dashboard.v1")),
        ("ticks", arr(ticks)),
        ("title", s("otaro soak timeline")),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry::{MetricSink, Registry, LATENCY_MS_BUCKETS};

    #[test]
    fn golden_spec_for_a_small_registry() {
        let mut reg = Registry::new();
        let _ = reg.counter("serve.rung.e5m4.served");
        let _ = reg.counter("serve.rung.e5m8.served");
        let _ = reg.counter("serve.served");
        let _ = reg.gauge("policy.demotions");
        let _ = reg.histogram("serve.rung.e5m4.step_ms", LATENCY_MS_BUCKETS);
        let spec = dashboard(&reg.snapshot()).to_string();
        // the golden string: any metric rename or row reshuffle must be
        // an intentional, review-visible diff
        let want = concat!(
            "{\"panels_total\":5,",
            "\"rows\":[",
            "{\"panels\":[{\"metric\":\"serve.served\",\"title\":\"served\",\"type\":\"counter\"}],\"title\":\"serve\"},",
            "{\"panels\":[",
            "{\"metric\":\"serve.rung.e5m4.served\",\"title\":\"served\",\"type\":\"counter\"},",
            "{\"metric\":\"serve.rung.e5m4.step_ms\",\"title\":\"step_ms\",\"type\":\"histogram\"}",
            "],\"title\":\"rung e5m4\"},",
            "{\"panels\":[{\"metric\":\"serve.rung.e5m8.served\",\"title\":\"served\",\"type\":\"counter\"}],\"title\":\"rung e5m8\"},",
            "{\"panels\":[{\"metric\":\"policy.demotions\",\"title\":\"demotions\",\"type\":\"gauge\"}],\"title\":\"policy\"}",
            "],",
            "\"schema\":\"otaro.dashboard.v1\",",
            "\"title\":\"otaro serve\"}"
        );
        assert_eq!(spec, want);

        // the spec depends on the metric SET, not the values
        let mut reg2 = Registry::new();
        let c2 = reg2.counter("serve.rung.e5m4.served");
        let _ = reg2.counter("serve.rung.e5m8.served");
        let _ = reg2.counter("serve.served");
        let _ = reg2.gauge("policy.demotions");
        let _ = reg2.histogram("serve.rung.e5m4.step_ms", LATENCY_MS_BUCKETS);
        reg2.add(c2, 17);
        assert_eq!(dashboard(&reg2.snapshot()).to_string(), want);
    }

    #[test]
    fn full_serve_metric_set_builds_per_rung_rows() {
        use crate::sefp::Precision;
        use crate::serve::ServeMetrics;
        let m = ServeMetrics::for_ladder(&[Precision::of(8), Precision::of(4)]);
        let spec = dashboard(&m.snapshot());
        let rows = spec.get("rows").and_then(|v| v.as_arr()).unwrap();
        let titles: Vec<&str> =
            rows.iter().filter_map(|r| r.get("title").and_then(|t| t.as_str())).collect();
        assert_eq!(titles, ["serve", "rung e5m4", "rung e5m8", "profile", "policy", "ladder"]);
        // the profile row carries every stage histogram for every rung
        let profile = rows.iter().find(|r| {
            r.get("title").and_then(|t| t.as_str()) == Some("profile")
        });
        let stage_metrics: Vec<&str> = profile
            .and_then(|r| r.get("panels"))
            .and_then(|p| p.as_arr())
            .unwrap()
            .iter()
            .filter_map(|p| p.get("metric").and_then(|m| m.as_str()))
            .collect();
        assert_eq!(stage_metrics.len(), 10, "{stage_metrics:?}");
        assert!(stage_metrics.contains(&"profile.rung.e5m4.matmul_ms"), "{stage_metrics:?}");
        assert!(stage_metrics.contains(&"profile.rung.e5m8.probe_ms"), "{stage_metrics:?}");
        // each rung row carries its latency histogram and shed counter
        for row in rows {
            let title = row.get("title").and_then(|t| t.as_str()).unwrap();
            if !title.starts_with("rung ") {
                continue;
            }
            let metrics: Vec<&str> = row
                .get("panels")
                .and_then(|p| p.as_arr())
                .unwrap()
                .iter()
                .filter_map(|p| p.get("metric").and_then(|m| m.as_str()))
                .collect();
            assert!(metrics.iter().any(|m| m.ends_with(".step_ms")), "{metrics:?}");
            assert!(metrics.iter().any(|m| m.ends_with(".shed")), "{metrics:?}");
        }
    }

    #[test]
    fn empty_snapshot_yields_empty_rows() {
        let spec = dashboard(&Registry::new().snapshot());
        assert_eq!(spec.get("rows").and_then(|v| v.as_arr()).unwrap().len(), 0);
    }

    #[test]
    fn golden_timeline_spec_from_a_flight_timeline() {
        use crate::obs::FlightRecorder;
        let mut reg = Registry::new();
        let c = reg.counter("serve.rung.e5m4.tokens");
        let g = reg.gauge("serve.queue_depth");
        let h = reg.histogram("profile.rung.e5m4.matmul_ms", &[1.0, 10.0]);
        let mut fr = FlightRecorder::attach(&reg, 8);
        fr.mark(0, "flip: policy_toggle");
        reg.add(c, 3);
        reg.set(g, 2.0);
        reg.observe(h, 0.5);
        reg.observe(h, 0.5);
        fr.sample(0, &reg);
        reg.add(c, 4);
        reg.set(g, 1.0);
        reg.observe(h, 5.0);
        fr.sample(1, &reg);

        let spec = timeline_dashboard(&fr.timeline()).to_string();
        // frame p95s: two sub-1ms observations pin bucket bound 1; the
        // single 5ms observation pins bound 10
        let want = concat!(
            "{\"marks\":[{\"label\":\"flip: policy_toggle\",\"tick\":0}],",
            "\"panels\":[",
            "{\"metric\":\"serve.queue_depth\",\"series\":[2,1],\"title\":\"queue depth\",\"type\":\"timeseries\"},",
            "{\"metric\":\"serve.rung.e5m4.tokens\",\"series\":[3,4],\"title\":\"e5m4 tokens/frame\",\"type\":\"timeseries\"},",
            "{\"metric\":\"profile.rung.e5m4.matmul_ms\",\"series\":[1,10],\"title\":\"e5m4 matmul_ms p95\",\"type\":\"timeseries\"}",
            "],",
            "\"schema\":\"otaro.timeline_dashboard.v1\",",
            "\"ticks\":[0,1],",
            "\"title\":\"otaro soak timeline\"}"
        );
        assert_eq!(spec, want);

        // the det timeline has no histogram planes: stage panels drop
        // out, the counter/gauge panels and marks survive
        let det_spec = timeline_dashboard(&fr.det_timeline());
        let panels = det_spec.get("panels").and_then(|v| v.as_arr()).unwrap();
        let metrics: Vec<&str> =
            panels.iter().filter_map(|p| p.get("metric").and_then(|m| m.as_str())).collect();
        assert_eq!(metrics, ["serve.queue_depth", "serve.rung.e5m4.tokens"]);
    }

    #[test]
    fn empty_timeline_yields_empty_panels() {
        let spec = timeline_dashboard(&obj(vec![]));
        assert_eq!(spec.get("panels").and_then(|v| v.as_arr()).unwrap().len(), 0);
        assert_eq!(spec.get("ticks").and_then(|v| v.as_arr()).unwrap().len(), 0);
    }
}
