//! Generated dashboard definitions over a registry snapshot.
//!
//! [`dashboard`] turns an `otaro.metrics.v1` snapshot (from
//! [`Registry::snapshot`](super::Registry::snapshot)) into a
//! deterministic `otaro.dashboard.v1` JSON spec: rows of panels keyed by
//! registry metric names, with one row per serve rung (its latency
//! histogram + served/shed counters side by side) plus serving, policy
//! (probe-agreement), ladder, and backend rows.  The spec depends only
//! on the *metric set* — two snapshots of the same registered metrics
//! produce byte-identical specs, so a golden-file test can pin the
//! output and any rename/addition shows up as a review-visible diff.
//!
//! The pattern follows the sequencer-style `dashboard_definitions`
//! approach named in the ROADMAP: dashboards are build artifacts derived
//! from the code's own metric registrations, never hand-synced.

use crate::json::{arr, n, obj, s, Value};

/// Row a metric lands in, in display order.
fn row_for(name: &str) -> String {
    if let Some(rest) = name.strip_prefix("serve.rung.") {
        let rung = rest.split('.').next().unwrap_or(rest);
        return format!("rung {rung}");
    }
    for prefix in ["serve", "policy", "ladder", "backend"] {
        if name.starts_with(prefix) && name[prefix.len()..].starts_with('.') {
            return prefix.to_string();
        }
    }
    "other".to_string()
}

/// Short panel title: the last dotted segment of the metric name.
fn panel_title(name: &str) -> &str {
    name.rsplit('.').next().unwrap_or(name)
}

/// Build a deterministic `otaro.dashboard.v1` spec from an
/// `otaro.metrics.v1` snapshot.  Unknown or missing sections are
/// skipped; an empty snapshot yields an empty `rows` array.
pub fn dashboard(snapshot: &Value) -> Value {
    // (row, metric, panel type) for every registered metric
    let mut panels: Vec<(String, String, &'static str)> = Vec::new();
    for (section, ty) in
        [("counters", "counter"), ("gauges", "gauge"), ("histograms", "histogram")]
    {
        if let Some(map) = snapshot.get(section).and_then(|v| v.as_obj()) {
            // Value::Obj is a BTreeMap: keys arrive sorted
            for name in map.keys() {
                panels.push((row_for(name), name.clone(), ty));
            }
        }
    }
    panels.sort_by(|a, b| (&a.0, &a.1).cmp(&(&b.0, &b.1)));

    let mut rung_rows: Vec<String> =
        panels.iter().map(|(row, _, _)| row.clone()).filter(|r| r.starts_with("rung ")).collect();
    rung_rows.sort();
    rung_rows.dedup();
    let mut order: Vec<String> = vec!["serve".to_string()];
    order.extend(rung_rows);
    order.extend(
        ["policy", "ladder", "backend", "other"].into_iter().map(str::to_string),
    );

    let rows: Vec<Value> = order
        .iter()
        .filter_map(|row| {
            let row_panels: Vec<Value> = panels
                .iter()
                .filter(|(r, _, _)| r == row)
                .map(|(_, metric, ty)| {
                    obj(vec![
                        ("metric", s(metric.as_str())),
                        ("title", s(panel_title(metric))),
                        ("type", s(*ty)),
                    ])
                })
                .collect();
            if row_panels.is_empty() {
                return None;
            }
            Some(obj(vec![
                ("panels", arr(row_panels)),
                ("title", s(row.as_str())),
            ]))
        })
        .collect();

    obj(vec![
        ("rows", arr(rows)),
        ("schema", s("otaro.dashboard.v1")),
        ("title", s("otaro serve")),
        ("panels_total", n(panels.len() as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry::{MetricSink, Registry, LATENCY_MS_BUCKETS};

    #[test]
    fn golden_spec_for_a_small_registry() {
        let mut reg = Registry::new();
        let _ = reg.counter("serve.rung.e5m4.served");
        let _ = reg.counter("serve.rung.e5m8.served");
        let _ = reg.counter("serve.served");
        let _ = reg.gauge("policy.demotions");
        let _ = reg.histogram("serve.rung.e5m4.step_ms", LATENCY_MS_BUCKETS);
        let spec = dashboard(&reg.snapshot()).to_string();
        // the golden string: any metric rename or row reshuffle must be
        // an intentional, review-visible diff
        let want = concat!(
            "{\"panels_total\":5,",
            "\"rows\":[",
            "{\"panels\":[{\"metric\":\"serve.served\",\"title\":\"served\",\"type\":\"counter\"}],\"title\":\"serve\"},",
            "{\"panels\":[",
            "{\"metric\":\"serve.rung.e5m4.served\",\"title\":\"served\",\"type\":\"counter\"},",
            "{\"metric\":\"serve.rung.e5m4.step_ms\",\"title\":\"step_ms\",\"type\":\"histogram\"}",
            "],\"title\":\"rung e5m4\"},",
            "{\"panels\":[{\"metric\":\"serve.rung.e5m8.served\",\"title\":\"served\",\"type\":\"counter\"}],\"title\":\"rung e5m8\"},",
            "{\"panels\":[{\"metric\":\"policy.demotions\",\"title\":\"demotions\",\"type\":\"gauge\"}],\"title\":\"policy\"}",
            "],",
            "\"schema\":\"otaro.dashboard.v1\",",
            "\"title\":\"otaro serve\"}"
        );
        assert_eq!(spec, want);

        // the spec depends on the metric SET, not the values
        let mut reg2 = Registry::new();
        let c2 = reg2.counter("serve.rung.e5m4.served");
        let _ = reg2.counter("serve.rung.e5m8.served");
        let _ = reg2.counter("serve.served");
        let _ = reg2.gauge("policy.demotions");
        let _ = reg2.histogram("serve.rung.e5m4.step_ms", LATENCY_MS_BUCKETS);
        reg2.add(c2, 17);
        assert_eq!(dashboard(&reg2.snapshot()).to_string(), want);
    }

    #[test]
    fn full_serve_metric_set_builds_per_rung_rows() {
        use crate::sefp::Precision;
        use crate::serve::ServeMetrics;
        let m = ServeMetrics::for_ladder(&[Precision::of(8), Precision::of(4)]);
        let spec = dashboard(&m.snapshot());
        let rows = spec.get("rows").and_then(|v| v.as_arr()).unwrap();
        let titles: Vec<&str> =
            rows.iter().filter_map(|r| r.get("title").and_then(|t| t.as_str())).collect();
        assert_eq!(titles, ["serve", "rung e5m4", "rung e5m8", "policy", "ladder"]);
        // each rung row carries its latency histogram and shed counter
        for row in rows {
            let title = row.get("title").and_then(|t| t.as_str()).unwrap();
            if !title.starts_with("rung ") {
                continue;
            }
            let metrics: Vec<&str> = row
                .get("panels")
                .and_then(|p| p.as_arr())
                .unwrap()
                .iter()
                .filter_map(|p| p.get("metric").and_then(|m| m.as_str()))
                .collect();
            assert!(metrics.iter().any(|m| m.ends_with(".step_ms")), "{metrics:?}");
            assert!(metrics.iter().any(|m| m.ends_with(".shed")), "{metrics:?}");
        }
    }

    #[test]
    fn empty_snapshot_yields_empty_rows() {
        let spec = dashboard(&Registry::new().snapshot());
        assert_eq!(spec.get("rows").and_then(|v| v.as_arr()).unwrap().len(), 0);
    }
}
