//! Deterministic latency/fault injection for any [`LogitsBackend`].
//!
//! SLO scenarios over [`SimBackend`](crate::serve::SimBackend) finish in
//! microseconds, so the adaptive controller never sees a p95 violation
//! and never demotes — the feedback loop goes untested.  This module
//! wraps a backend in an [`InjectedBackend`] driven by a declarative
//! [`LatencyPlan`]:
//!
//! * **Delay rules** — per-(precision, step-range) schedules.  A rule
//!   `{precision: Some(E5M4), from_step: 0, to_step: MAX, delay_ms: 40}`
//!   sleeps 40 ms on every E5M4 decode step, which is unambiguously over
//!   a 25 ms SLO while un-injected steps stay unambiguously under —
//!   over/under-SLO classification is deterministic even though the
//!   sleep itself is wall time.
//! * **Fault rules** — `fault_every: k` raises a transient backend error
//!   on every k-th matching step.  The wrapper retries internally up to
//!   [`LatencyPlan::max_retries`] times (the retry deterministically
//!   succeeds — the fault is transient by construction); with retries
//!   exhausted the error surfaces to the caller.
//!
//! Every injection is **trace-visible**: the wrapper queues an
//! [`InjectEvent`] per affected step, the server drains them via
//! [`LogitsBackend::take_injected`] and records them as
//! `injected{width, step, delay_ms, fault}` trace events — so a traced
//! demotion can be matched to the exact injected violations that forced
//! it.  Step counters are kept per precision in a `BTreeMap` (iteration
//! order and therefore event order is deterministic).

use std::collections::BTreeMap;

use crate::json::Value;
use crate::obs::profile::StageSample;
use crate::sefp::Precision;
use crate::serve::{LadderView, LogitsBackend};

/// One injection occurrence, drained by the server for tracing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InjectEvent {
    pub precision: Precision,
    /// per-precision decode-step index the injection hit
    pub step: u64,
    pub delay_ms: u64,
    pub fault: bool,
}

/// A delay/fault schedule matching (precision, step-range).
#[derive(Debug, Clone, Copy)]
pub struct LatencyRule {
    /// `None` matches every precision
    pub precision: Option<Precision>,
    /// first matching per-precision step (inclusive)
    pub from_step: u64,
    /// end of the matching range (exclusive; `u64::MAX` = open-ended)
    pub to_step: u64,
    /// synthetic latency added to each matching step
    pub delay_ms: u64,
    /// raise a transient fault on every k-th matching step (0 = never)
    pub fault_every: u64,
}

impl LatencyRule {
    fn matches(&self, p: Precision, step: u64) -> bool {
        let precision_ok = match self.precision {
            Some(rp) => rp == p,
            None => true,
        };
        precision_ok && step >= self.from_step && step < self.to_step
    }

    fn faults_at(&self, step: u64) -> bool {
        self.fault_every > 0 && (step - self.from_step) % self.fault_every == 0
    }
}

/// The full injection schedule for a run.
#[derive(Debug, Clone, Default)]
pub struct LatencyPlan {
    pub rules: Vec<LatencyRule>,
    /// transient-fault retries absorbed internally before the error
    /// surfaces (0 = every injected fault fails the step)
    pub max_retries: usize,
}

impl LatencyPlan {
    /// A plan with no rules: the wrapper is transparent.
    pub fn none() -> Self {
        LatencyPlan::default()
    }

    /// Constant `delay_ms` on every step of `precision`, open-ended,
    /// with a transient fault every `fault_every` steps (0 = never).
    pub fn flat(precision: Precision, delay_ms: u64, fault_every: u64) -> Self {
        LatencyPlan {
            rules: vec![LatencyRule {
                precision: Some(precision),
                from_step: 0,
                to_step: u64::MAX,
                delay_ms,
                fault_every,
            }],
            max_retries: 2,
        }
    }

    /// Parse a plan from a config file, so scenarios can declare their
    /// own fault schedules instead of hardcoding them:
    ///
    /// ```json
    /// {"max_retries": 2,
    ///  "rules": [{"precision": 4, "from_step": 0, "delay_ms": 40, "fault_every": 5}]}
    /// ```
    ///
    /// Defaults per rule: `precision` omitted matches every precision,
    /// `from_step` 0, `to_step` open-ended, `delay_ms`/`fault_every` 0
    /// — but a rule that injects nothing (both zero) is rejected, as
    /// are inverted step ranges.  `max_retries` defaults to 0 (every
    /// injected fault surfaces).  An empty object is the transparent
    /// plan.
    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        fn u64_field(rule: &Value, key: &str, default: u64, i: usize) -> anyhow::Result<u64> {
            match rule.get(key) {
                None | Some(Value::Null) => Ok(default),
                Some(x) => x.as_usize().map(|u| u as u64).ok_or_else(|| {
                    anyhow::anyhow!("injection rule {i}: {key} must be a non-negative integer")
                }),
            }
        }
        let mut plan = LatencyPlan::none();
        if let Some(x) = v.get("max_retries") {
            plan.max_retries = x
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("max_retries must be a non-negative integer"))?;
        }
        let Some(rules) = v.get("rules") else { return Ok(plan) };
        let rules =
            rules.as_arr().ok_or_else(|| anyhow::anyhow!("injection rules must be an array"))?;
        for (i, r) in rules.iter().enumerate() {
            let precision = match r.get("precision") {
                None | Some(Value::Null) => None,
                Some(x) => {
                    let m = x.as_usize().ok_or_else(|| {
                        anyhow::anyhow!("injection rule {i}: precision must be a mantissa width")
                    })?;
                    anyhow::ensure!(
                        (1..=16).contains(&m),
                        "injection rule {i}: precision width {m} out of range"
                    );
                    Some(Precision::of(m as u8))
                }
            };
            let from_step = u64_field(r, "from_step", 0, i)?;
            let to_step = u64_field(r, "to_step", u64::MAX, i)?;
            let delay_ms = u64_field(r, "delay_ms", 0, i)?;
            let fault_every = u64_field(r, "fault_every", 0, i)?;
            anyhow::ensure!(
                from_step < to_step,
                "injection rule {i}: from_step {from_step} must be below to_step {to_step}"
            );
            anyhow::ensure!(
                delay_ms > 0 || fault_every > 0,
                "injection rule {i}: rule injects nothing (set delay_ms and/or fault_every)"
            );
            plan.rules.push(LatencyRule { precision, from_step, to_step, delay_ms, fault_every });
        }
        Ok(plan)
    }
}

/// A [`LogitsBackend`] decorator applying a [`LatencyPlan`].
#[derive(Debug)]
pub struct InjectedBackend<B: LogitsBackend> {
    inner: B,
    plan: LatencyPlan,
    loaded: Option<Precision>,
    /// per-precision decode-step counters (deterministic order)
    steps: BTreeMap<Precision, u64>,
    /// injections since the last `take_injected` drain
    pending: Vec<InjectEvent>,
    delays: u64,
    faults: u64,
}

impl<B: LogitsBackend> InjectedBackend<B> {
    pub fn new(inner: B, plan: LatencyPlan) -> Self {
        InjectedBackend {
            inner,
            plan,
            loaded: None,
            steps: BTreeMap::new(),
            pending: Vec::new(),
            delays: 0,
            faults: 0,
        }
    }

    pub fn inner(&self) -> &B {
        &self.inner
    }

    /// Total injected delay occurrences so far.
    pub fn delays(&self) -> u64 {
        self.delays
    }

    /// Total injected transient faults so far (absorbed or surfaced).
    pub fn faults(&self) -> u64 {
        self.faults
    }
}

impl<B: LogitsBackend> LogitsBackend for InjectedBackend<B> {
    fn batch_shape(&self) -> (usize, usize) {
        self.inner.batch_shape()
    }

    fn vocab_size(&self) -> usize {
        self.inner.vocab_size()
    }

    fn load_view(&mut self, view: &LadderView) -> anyhow::Result<()> {
        self.loaded = Some(view.precision);
        self.inner.load_view(view)
    }

    fn logits_step(&mut self, tokens: &[i32]) -> anyhow::Result<Vec<f32>> {
        let p = self
            .loaded
            .ok_or_else(|| anyhow::anyhow!("injected logits_step before load_view"))?;
        let counter = self.steps.entry(p).or_insert(0);
        let step = *counter;
        *counter += 1;

        let mut delay_ms = 0u64;
        let mut fault = false;
        for rule in &self.plan.rules {
            if rule.matches(p, step) {
                delay_ms += rule.delay_ms;
                fault = fault || rule.faults_at(step);
            }
        }
        if delay_ms > 0 || fault {
            if delay_ms > 0 {
                self.delays += 1;
                std::thread::sleep(std::time::Duration::from_millis(delay_ms));
            }
            if fault {
                self.faults += 1;
            }
            self.pending.push(InjectEvent { precision: p, step, delay_ms, fault });
            if fault && self.plan.max_retries == 0 {
                anyhow::bail!(
                    "injected transient fault at {p} step {step} (retries exhausted)"
                );
            }
            // with retries available the transient fault is absorbed:
            // the retry deterministically succeeds on the same step
        }
        self.inner.logits_step(tokens)
    }

    fn obs_gauges(&self) -> Vec<(&'static str, f64)> {
        let mut g = self.inner.obs_gauges();
        g.push(("injected_delays", self.delays as f64));
        g.push(("injected_faults", self.faults as f64));
        g
    }

    fn take_injected(&mut self) -> Vec<InjectEvent> {
        std::mem::take(&mut self.pending)
    }

    fn set_profiling(&mut self, on: bool) {
        self.inner.set_profiling(on);
    }

    fn take_profile(&mut self) -> Vec<StageSample> {
        self.inner.take_profile()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ParamStore;
    use crate::serve::{PrecisionLadder, SimBackend};

    fn ladder() -> PrecisionLadder {
        let params = ParamStore {
            tensors: vec![vec![0.25; 64]],
            names: vec!["w".into()],
            shapes: vec![vec![8, 8]],
            quantized: vec![true],
        };
        PrecisionLadder::from_params(&params)
    }

    fn step_at(b: &mut InjectedBackend<SimBackend>, l: &mut PrecisionLadder, m: u8) {
        let view = l.view_at(Precision::of(m)).unwrap();
        b.load_view(&view).unwrap();
        let (bsz, seq) = b.batch_shape();
        b.logits_step(&vec![1; bsz * seq]).unwrap();
    }

    #[test]
    fn plan_matches_precision_and_step_range() {
        let mut l = ladder();
        let plan = LatencyPlan {
            rules: vec![LatencyRule {
                precision: Some(Precision::of(4)),
                from_step: 1,
                to_step: 3,
                delay_ms: 1,
                fault_every: 0,
            }],
            max_retries: 0,
        };
        let mut b = InjectedBackend::new(SimBackend::new(2, 4, 16), plan);
        // e5m8 never matches
        step_at(&mut b, &mut l, 8);
        assert!(b.take_injected().is_empty());
        // e5m4 steps 0..4: only steps 1 and 2 are in range
        for _ in 0..4 {
            step_at(&mut b, &mut l, 4);
        }
        let evs = b.take_injected();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0], InjectEvent { precision: Precision::of(4), step: 1, delay_ms: 1, fault: false });
        assert_eq!(evs[1].step, 2);
        assert_eq!(b.delays(), 2);
        // drained: a second take is empty
        assert!(b.take_injected().is_empty());
    }

    #[test]
    fn faults_are_absorbed_with_retries_and_surface_without() {
        let mut l = ladder();
        let mut plan = LatencyPlan::flat(Precision::of(4), 0, 2);
        let mut absorbed = InjectedBackend::new(SimBackend::new(1, 4, 16), plan.clone());
        for _ in 0..4 {
            step_at(&mut absorbed, &mut l, 4); // faults at steps 0, 2 — absorbed
        }
        assert_eq!(absorbed.faults(), 2);
        let evs = absorbed.take_injected();
        assert!(evs.iter().all(|e| e.fault));

        plan.max_retries = 0;
        let mut surfacing = InjectedBackend::new(SimBackend::new(1, 4, 16), plan);
        let view = l.view_at(Precision::of(4)).unwrap();
        surfacing.load_view(&view).unwrap();
        let (bsz, seq) = surfacing.batch_shape();
        let err = surfacing.logits_step(&vec![1; bsz * seq]);
        assert!(err.is_err(), "max_retries = 0 surfaces the injected fault");
    }

    #[test]
    fn plans_parse_from_json_with_defaults() {
        let v = crate::json::parse(
            r#"{"max_retries": 1, "rules": [
                {"precision": 4, "delay_ms": 40, "fault_every": 5},
                {"from_step": 2, "to_step": 6, "delay_ms": 3}
            ]}"#,
        )
        .unwrap();
        let plan = LatencyPlan::from_json(&v).unwrap();
        assert_eq!(plan.max_retries, 1);
        assert_eq!(plan.rules.len(), 2);
        assert_eq!(plan.rules[0].precision, Some(Precision::of(4)));
        assert_eq!((plan.rules[0].from_step, plan.rules[0].to_step), (0, u64::MAX));
        assert_eq!((plan.rules[0].delay_ms, plan.rules[0].fault_every), (40, 5));
        assert_eq!(plan.rules[1].precision, None);
        assert_eq!((plan.rules[1].from_step, plan.rules[1].to_step), (2, 6));
        // an empty object is the transparent plan
        let empty = LatencyPlan::from_json(&crate::json::parse("{}").unwrap()).unwrap();
        assert!(empty.rules.is_empty() && empty.max_retries == 0);
        // dead rules and inverted step ranges are config errors
        let dead = crate::json::parse(r#"{"rules": [{"precision": 4}]}"#).unwrap();
        assert!(LatencyPlan::from_json(&dead).is_err());
        let inverted =
            crate::json::parse(r#"{"rules": [{"from_step": 6, "to_step": 2, "delay_ms": 1}]}"#)
                .unwrap();
        assert!(LatencyPlan::from_json(&inverted).is_err());
    }

    #[test]
    fn empty_plan_is_transparent_and_deterministic() {
        let mut l = ladder();
        let mut run = || {
            let mut b = InjectedBackend::new(SimBackend::new(1, 4, 16), LatencyPlan::none());
            let view = l.view_at(Precision::of(8)).unwrap();
            b.load_view(&view).unwrap();
            let (bsz, seq) = b.batch_shape();
            b.logits_step(&vec![1; bsz * seq]).unwrap()
        };
        assert_eq!(run(), run());
    }
}
