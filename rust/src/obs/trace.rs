//! Per-request span/event tracing with a pre-allocated ring buffer.
//!
//! Where [`registry`](super::registry) answers "how many / how fast in
//! aggregate", this module answers "where did *this* request's time go":
//! every request admitted by [`Server`](crate::serve::Server) leaves a
//! causal chain of typed events — `admitted → queued → scheduled →
//! decode_step* → delivered` (or `shed{reason}`), with `probe` and
//! `policy_decision` events attached when the adaptive controller acts —
//! so an SLO demotion can be audited span-by-span back to the latency
//! violation that caused it.
//!
//! Design rules, same discipline as the metrics registry:
//!
//! * **Allocation-free record path.**  [`Tracer`] pre-allocates a ring
//!   of [`MAX_TRACES`-ish] trace slots, each with a fixed event budget;
//!   recording is index arithmetic plus a bounded `push` into reserved
//!   capacity, inside a `no_alloc` lint region.  Ring overflow evicts
//!   the **oldest whole trace** (never a partial one) and counts the
//!   drop; per-trace overflow drops the event and marks the trace
//!   `truncated`.
//! * **Deterministic timestamps.**  Events carry a monotone logical
//!   tick (one global counter, +1 per event), never wall time.  Under
//!   [`SimBackend`](crate::serve::SimBackend) a trace is a pure function
//!   of (seed, config): two runs produce byte-identical
//!   `otaro.trace.v1` snapshots.
//! * **Swappable sink.**  The serve stack records through
//!   `Box<dyn TraceSink>`; the default [`NullTrace`] makes tracing
//!   zero-cost when off.
//!
//! Snapshots serialize through the in-repo [`json`](crate::json) module
//! (`Value::Obj` is a `BTreeMap`, so keys come out sorted).  The
//! injection side that gives traces something worth looking at lives in
//! [`super::inject`]; the CLI that prints waterfalls from these
//! snapshots is `otaro trace` (see [`crate::workload`]).

use crate::json::{arr, n, obj, s, Value};
use crate::sefp::Precision;
use crate::serve::TaskClass;

/// Why a request was shed instead of served.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// empty prompt or PAD in the prompt
    InvalidPrompt,
    /// forced precision above the ladder master
    PrecisionAboveMaster,
    /// admission queue at capacity
    QueueFull,
}

impl ShedReason {
    pub fn name(self) -> &'static str {
        match self {
            ShedReason::InvalidPrompt => "invalid_prompt",
            ShedReason::PrecisionAboveMaster => "precision_above_master",
            ShedReason::QueueFull => "queue_full",
        }
    }
}

/// One typed trace event.  Everything is `Copy` and fixed-size so the
/// record path never touches the allocator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// request entered `submit` (opens the trace)
    Admitted { class: TaskClass },
    /// request accepted into the admission queue at `depth`
    Queued { precision: Precision, depth: u32 },
    /// request rejected (closes the trace)
    Shed { reason: ShedReason, precision: Option<Precision> },
    /// request placed into a decode batch row
    Scheduled { batch_row: u32 },
    /// request produced its `n`-th token at `precision`
    DecodeStep { n: u32, precision: Precision },
    /// shadow probe scored this request's completion (agreement in
    /// permille — integers keep the snapshot byte-stable)
    Probe { agreement_pm: i32 },
    /// the policy moved a rung in response to this request's
    /// observation or probe (`score_pm`: the signal that justified it,
    /// in permille — frac-over-SLO for demotes, agreement for promotes)
    PolicyDecision { demote: bool, from: Precision, to: Precision, score_pm: i32 },
    /// response returned to the caller (closes the trace)
    Delivered { tokens: u32 },
    /// synthetic latency/fault from [`super::inject`] (global event:
    /// injection hits a batch, not one request)
    Injected { precision: Precision, step: u64, delay_ms: u64, fault: bool },
}

/// Scale a `[0, 1]`-ish signal to integer permille for trace fields.
pub fn permille(x: f64) -> i32 {
    (x * 1000.0).round() as i32
}

/// A timestamped event record.
#[derive(Debug, Clone, Copy)]
pub struct EventRec {
    pub tick: u64,
    pub kind: EventKind,
}

/// The emit interface the serve stack records through.
pub trait TraceSink: std::fmt::Debug + Send {
    /// False for [`NullTrace`]: callers may skip building event data.
    fn enabled(&self) -> bool;
    /// Record a per-request event.  `Admitted` opens a trace; other
    /// kinds for an unknown/evicted `req` are silently dropped.
    fn event(&mut self, req: u64, kind: EventKind);
    /// Record a global (not-per-request) event, e.g. injected latency.
    fn global(&mut self, kind: EventKind);
    /// Deterministic `otaro.trace.v1` snapshot; `None` when disabled.
    fn snapshot(&self) -> Option<Value>;
}

/// The default sink: tracing off, every record a no-op.
#[derive(Debug, Default)]
pub struct NullTrace;

impl TraceSink for NullTrace {
    fn enabled(&self) -> bool {
        false
    }

    fn event(&mut self, _req: u64, _kind: EventKind) {}

    fn global(&mut self, _kind: EventKind) {}

    fn snapshot(&self) -> Option<Value> {
        None
    }
}

/// One ring slot holding one request's whole trace.
#[derive(Debug)]
struct TraceSlot {
    req: u64,
    start_tick: u64,
    used: bool,
    /// per-trace event budget hit: later events were dropped
    truncated: bool,
    /// saw a terminal event (`Delivered` or `Shed`)
    complete: bool,
    /// pre-reserved to `events_per_trace`; never grows past it
    events: Vec<EventRec>,
}

/// Ring-buffered tracer: fixed trace slots, fixed per-trace event
/// budget, monotone logical tick, deterministic snapshots.
#[derive(Debug)]
pub struct Tracer {
    slots: Vec<TraceSlot>,
    /// next ring slot an `Admitted` claims (round-robin ⇒ the claimed
    /// slot always holds the oldest live trace)
    next: usize,
    /// global logical clock: +1 per recorded event
    tick: u64,
    events_per_trace: usize,
    /// whole traces evicted by ring overflow
    dropped: u64,
    /// events dropped by the per-trace budget
    truncated_events: u64,
    /// global (injected) events, bounded by `injected_cap`
    injected: Vec<EventRec>,
    injected_cap: usize,
    injected_dropped: u64,
}

impl Tracer {
    /// `traces` ring slots, `events_per_trace` events each (both
    /// clamped to ≥ 1).  All capacity is allocated here, up front.
    pub fn new(traces: usize, events_per_trace: usize) -> Self {
        let traces = traces.max(1);
        let events_per_trace = events_per_trace.max(1);
        let injected_cap = traces * 4;
        Tracer {
            slots: (0..traces)
                .map(|_| TraceSlot {
                    req: 0,
                    start_tick: 0,
                    used: false,
                    truncated: false,
                    complete: false,
                    events: Vec::with_capacity(events_per_trace),
                })
                .collect(),
            next: 0,
            tick: 0,
            events_per_trace,
            dropped: 0,
            truncated_events: 0,
            injected: Vec::with_capacity(injected_cap),
            injected_cap,
            injected_dropped: 0,
        }
    }

    /// Whole traces evicted by ring overflow so far.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Events dropped by the per-trace budget so far.
    pub fn truncated_events(&self) -> u64 {
        self.truncated_events
    }

    /// Current logical tick (the timestamp of the last event).
    pub fn tick(&self) -> u64 {
        self.tick
    }

    /// Live (non-evicted) traces currently in the ring.
    pub fn live_traces(&self) -> usize {
        self.slots.iter().filter(|slot| slot.used).count()
    }

    /// Deterministic `otaro.trace.v1` snapshot: traces sorted by start
    /// tick, events in record order, sorted keys throughout.  This is
    /// the reporting path — allocation is fine here.
    pub fn snapshot_value(&self) -> Value {
        let mut live: Vec<&TraceSlot> = self.slots.iter().filter(|slot| slot.used).collect();
        live.sort_by_key(|slot| slot.start_tick);
        let traces = live
            .iter()
            .map(|slot| {
                obj(vec![
                    ("req", n(slot.req as f64)),
                    ("complete", Value::Bool(slot.complete)),
                    ("truncated", Value::Bool(slot.truncated)),
                    ("events", arr(slot.events.iter().map(event_json).collect())),
                ])
            })
            .collect();
        obj(vec![
            ("schema", s("otaro.trace.v1")),
            ("dropped", n(self.dropped as f64)),
            ("truncated_events", n(self.truncated_events as f64)),
            ("injected", arr(self.injected.iter().map(event_json).collect())),
            ("injected_dropped", n(self.injected_dropped as f64)),
            ("traces", arr(traces)),
        ])
    }
}

impl TraceSink for Tracer {
    fn enabled(&self) -> bool {
        true
    }

    fn event(&mut self, req: u64, kind: EventKind) {
        // lint: region(no_alloc)
        self.tick += 1;
        let rec = EventRec { tick: self.tick, kind };
        if matches!(kind, EventKind::Admitted { .. }) {
            // claim the next ring slot; evicting a live trace drops it
            // WHOLE (events are cleared, the drop is counted) — a
            // snapshot never shows a partial suffix of an old trace
            let i = self.next;
            self.next = (self.next + 1) % self.slots.len();
            let slot = &mut self.slots[i];
            if slot.used {
                self.dropped += 1;
            }
            slot.req = req;
            slot.start_tick = self.tick;
            slot.used = true;
            slot.truncated = false;
            slot.complete = false;
            slot.events.clear();
            slot.events.push(rec);
            return;
        }
        let cap = self.events_per_trace;
        if let Some(slot) = self.slots.iter_mut().find(|slot| slot.used && slot.req == req) {
            if slot.events.len() < cap {
                slot.events.push(rec);
            } else {
                slot.truncated = true;
                self.truncated_events += 1;
            }
            if matches!(kind, EventKind::Delivered { .. } | EventKind::Shed { .. }) {
                slot.complete = true;
            }
        }
        // lint: end_region
    }

    fn global(&mut self, kind: EventKind) {
        // lint: region(no_alloc)
        self.tick += 1;
        if self.injected.len() < self.injected_cap {
            self.injected.push(EventRec { tick: self.tick, kind });
        } else {
            self.injected_dropped += 1;
        }
        // lint: end_region
    }

    fn snapshot(&self) -> Option<Value> {
        Some(self.snapshot_value())
    }
}

fn class_name(c: TaskClass) -> &'static str {
    match c {
        TaskClass::Generation => "generation",
        TaskClass::Understanding => "understanding",
        TaskClass::Other => "other",
    }
}

fn width_json(p: Option<Precision>) -> Value {
    match p {
        Some(p) => n(p.m() as f64),
        None => Value::Null,
    }
}

fn event_json(rec: &EventRec) -> Value {
    let mut pairs = vec![("tick", n(rec.tick as f64))];
    match rec.kind {
        EventKind::Admitted { class } => {
            pairs.push(("kind", s("admitted")));
            pairs.push(("class", s(class_name(class))));
        }
        EventKind::Queued { precision, depth } => {
            pairs.push(("kind", s("queued")));
            pairs.push(("width", n(precision.m() as f64)));
            pairs.push(("depth", n(depth as f64)));
        }
        EventKind::Shed { reason, precision } => {
            pairs.push(("kind", s("shed")));
            pairs.push(("reason", s(reason.name())));
            pairs.push(("width", width_json(precision)));
        }
        EventKind::Scheduled { batch_row } => {
            pairs.push(("kind", s("scheduled")));
            pairs.push(("row", n(batch_row as f64)));
        }
        EventKind::DecodeStep { n: step_n, precision } => {
            pairs.push(("kind", s("decode_step")));
            pairs.push(("n", n(step_n as f64)));
            pairs.push(("width", n(precision.m() as f64)));
        }
        EventKind::Probe { agreement_pm } => {
            pairs.push(("kind", s("probe")));
            pairs.push(("agreement_pm", n(agreement_pm as f64)));
        }
        EventKind::PolicyDecision { demote, from, to, score_pm } => {
            pairs.push(("kind", s("policy_decision")));
            pairs.push(("move", s(if demote { "demote" } else { "promote" })));
            pairs.push(("from", n(from.m() as f64)));
            pairs.push(("to", n(to.m() as f64)));
            pairs.push(("score_pm", n(score_pm as f64)));
        }
        EventKind::Delivered { tokens } => {
            pairs.push(("kind", s("delivered")));
            pairs.push(("tokens", n(tokens as f64)));
        }
        EventKind::Injected { precision, step, delay_ms, fault } => {
            pairs.push(("kind", s("injected")));
            pairs.push(("width", n(precision.m() as f64)));
            pairs.push(("step", n(step as f64)));
            pairs.push(("delay_ms", n(delay_ms as f64)));
            pairs.push(("fault", Value::Bool(fault)));
        }
    }
    obj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn deliver(t: &mut Tracer, req: u64) {
        t.event(req, EventKind::Admitted { class: TaskClass::Other });
        t.event(req, EventKind::Queued { precision: Precision::of(6), depth: 1 });
        t.event(req, EventKind::Delivered { tokens: 2 });
    }

    #[test]
    fn ticks_are_monotone_and_traces_complete() {
        let mut t = Tracer::new(4, 8);
        deliver(&mut t, 7);
        deliver(&mut t, 8);
        assert_eq!(t.tick(), 6);
        assert_eq!(t.live_traces(), 2);
        assert_eq!(t.dropped(), 0);
        let snap = t.snapshot_value();
        let traces = snap.get("traces").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(traces.len(), 2);
        let mut last = 0.0;
        for tr in traces {
            assert_eq!(tr.get("complete").and_then(|v| v.as_bool()), Some(true));
            for ev in tr.get("events").and_then(|v| v.as_arr()).unwrap() {
                let tick = ev.get("tick").and_then(|v| v.as_f64()).unwrap();
                assert!(tick > last, "ticks strictly increase across a snapshot");
                last = tick;
            }
        }
    }

    #[test]
    fn ring_overflow_evicts_oldest_whole_trace() {
        let mut t = Tracer::new(2, 8);
        deliver(&mut t, 1);
        deliver(&mut t, 2);
        deliver(&mut t, 3); // evicts req 1 wholesale
        assert_eq!(t.dropped(), 1);
        assert_eq!(t.live_traces(), 2);
        let snap = t.snapshot_value();
        let traces = snap.get("traces").and_then(|v| v.as_arr()).unwrap();
        let reqs: Vec<f64> =
            traces.iter().map(|tr| tr.get("req").and_then(|v| v.as_f64()).unwrap()).collect();
        assert_eq!(reqs, [2.0, 3.0], "oldest trace gone, survivors whole");
        for tr in traces {
            assert_eq!(tr.get("events").and_then(|v| v.as_arr()).unwrap().len(), 3);
        }
        // events for the evicted request are silently dropped
        t.event(1, EventKind::Delivered { tokens: 1 });
        assert_eq!(t.live_traces(), 2);
    }

    #[test]
    fn per_trace_budget_truncates_and_counts() {
        let mut t = Tracer::new(2, 2);
        t.event(5, EventKind::Admitted { class: TaskClass::Generation });
        t.event(5, EventKind::Queued { precision: Precision::of(8), depth: 1 });
        t.event(5, EventKind::Scheduled { batch_row: 0 }); // over budget
        assert_eq!(t.truncated_events(), 1);
        let snap = t.snapshot_value();
        let tr = &snap.get("traces").and_then(|v| v.as_arr()).unwrap()[0];
        assert_eq!(tr.get("truncated").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(tr.get("events").and_then(|v| v.as_arr()).unwrap().len(), 2);
    }

    #[test]
    fn snapshot_is_deterministic() {
        let run = || {
            let mut t = Tracer::new(4, 8);
            deliver(&mut t, 1);
            t.event(2, EventKind::Admitted { class: TaskClass::Understanding });
            t.event(
                2,
                EventKind::Shed { reason: ShedReason::QueueFull, precision: Some(Precision::of(4)) },
            );
            t.global(EventKind::Injected {
                precision: Precision::of(4),
                step: 3,
                delay_ms: 40,
                fault: false,
            });
            t.snapshot_value().to_string()
        };
        let a = run();
        assert_eq!(a, run());
        assert!(a.starts_with("{\"dropped\":0"), "sorted keys: {a}");
        assert!(a.contains("\"schema\":\"otaro.trace.v1\""));
        assert!(a.contains("\"reason\":\"queue_full\""));
        assert!(a.contains("\"kind\":\"injected\""));
    }

    #[test]
    fn null_trace_is_inert() {
        let mut t = NullTrace;
        assert!(!t.enabled());
        t.event(1, EventKind::Delivered { tokens: 1 });
        t.global(EventKind::Probe { agreement_pm: 990 });
        assert!(t.snapshot().is_none());
    }

    #[test]
    fn permille_rounds() {
        assert_eq!(permille(0.95), 950);
        assert_eq!(permille(1.0), 1000);
        assert_eq!(permille(0.0515), 52);
    }
}
