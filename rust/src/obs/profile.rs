//! Hot-path profiling hooks: scoped stage timers behind a
//! zero-cost-when-disabled recorder.
//!
//! The serve loop has five stages worth timing per rung — prefill,
//! decode step, matmul, ladder switch, quality probe — but the code
//! that *knows* the stage boundaries (`infer::DecoderSim`, the
//! backends) cannot hold handles into the server's [`Registry`]
//! (that would invert the layering and require a shared sink).  So the
//! same drain pattern injection uses: stages record into a local
//! [`StageRecorder`] as plain [`StageSample`]s, and the server drains
//! them via [`LogitsBackend::take_profile`] into its pre-registered
//! per-rung `profile.rung.<rung>.<stage>_ms` histograms — which the
//! flight recorder then samples for free.
//!
//! Cost discipline: a disabled recorder takes no timestamps and the
//! record call is a single branch; an enabled recorder pushes into a
//! buffer pre-reserved at construction (`record` sits in a `no_alloc`
//! lint region), counting — not growing — past capacity.
//!
//! [`Registry`]: crate::obs::Registry
//! [`LogitsBackend::take_profile`]: crate::serve::LogitsBackend::take_profile

use crate::sefp::Precision;

/// A serve-loop stage with a per-rung cost histogram.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// per-row context replay before a fresh row can decode
    Prefill,
    /// one whole batched `logits_step` (includes injected delays)
    DecodeStep,
    /// kernel time of one batched layer-stack step (projections,
    /// attention, head) — `DecodeStep` minus dispatch and injection
    Matmul,
    /// `view_at` + `load_view` when a batch runs at a new precision
    LadderSwitch,
    /// one shadow quality probe (served rung + master replay)
    Probe,
}

impl Stage {
    /// Every stage, in histogram registration order.
    pub const ALL: [Stage; 5] =
        [Stage::Prefill, Stage::DecodeStep, Stage::Matmul, Stage::LadderSwitch, Stage::Probe];

    /// Metric-name suffix (`profile.rung.<rung>.<name()>`).
    pub fn name(&self) -> &'static str {
        match self {
            Stage::Prefill => "prefill_ms",
            Stage::DecodeStep => "decode_step_ms",
            Stage::Matmul => "matmul_ms",
            Stage::LadderSwitch => "ladder_switch_ms",
            Stage::Probe => "probe_ms",
        }
    }

    /// Index into [`Stage::ALL`]-ordered arrays.
    pub fn index(&self) -> usize {
        match self {
            Stage::Prefill => 0,
            Stage::DecodeStep => 1,
            Stage::Matmul => 2,
            Stage::LadderSwitch => 3,
            Stage::Probe => 4,
        }
    }
}

/// One timed stage occurrence, stamped with the rung it ran at.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageSample {
    pub stage: Stage,
    pub precision: Precision,
    pub ms: f64,
}

/// A bounded sample buffer stages record into and the server drains.
///
/// Disabled (the default) it is a no-op shell: [`enabled`] returns
/// `false`, callers skip their `Instant` reads entirely, and `record`
/// is one early-returning branch.
///
/// [`enabled`]: StageRecorder::enabled
#[derive(Debug, Clone, Default)]
pub struct StageRecorder {
    on: bool,
    samples: Vec<StageSample>,
    cap: usize,
    /// samples discarded because the buffer was full between drains
    dropped: u64,
}

impl StageRecorder {
    /// Samples buffered between drains when enabled.
    pub const DEFAULT_CAP: usize = 1024;

    /// The no-op shell: records nothing, owns no buffer.
    pub fn disabled() -> Self {
        StageRecorder::default()
    }

    /// A live recorder buffering up to `cap` samples between drains.
    pub fn with_capacity(cap: usize) -> Self {
        StageRecorder { on: true, samples: Vec::with_capacity(cap), cap, dropped: 0 }
    }

    /// Whether stages should bother reading clocks at all.
    pub fn enabled(&self) -> bool {
        self.on
    }

    /// Turn recording on (allocating the buffer on first enable) or
    /// off (keeping the buffer for a later re-enable).
    pub fn set_enabled(&mut self, on: bool) {
        self.on = on;
        if on && self.cap == 0 {
            self.cap = Self::DEFAULT_CAP;
            self.samples.reserve(self.cap);
        }
    }

    /// Samples discarded because the buffer was full.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    // One branch when disabled; an indexed push into pre-reserved
    // storage when enabled — this sits inside the decode hot loop.
    // lint: region(no_alloc)
    /// Record one stage occurrence (no-op when disabled; counted, not
    /// grown, past capacity).
    pub fn record(&mut self, stage: Stage, precision: Precision, ms: f64) {
        if !self.on {
            return;
        }
        if self.samples.len() < self.cap {
            self.samples.push(StageSample { stage, precision, ms });
        } else {
            self.dropped += 1;
        }
    }
    // lint: end_region

    /// Take every buffered sample, leaving a fresh pre-reserved buffer
    /// behind (reporting path — this is the one place that allocates).
    pub fn drain(&mut self) -> Vec<StageSample> {
        if self.samples.is_empty() {
            return Vec::new();
        }
        std::mem::replace(&mut self.samples, Vec::with_capacity(self.cap))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_is_inert() {
        let mut r = StageRecorder::disabled();
        assert!(!r.enabled());
        r.record(Stage::Matmul, Precision::of(4), 1.0);
        assert!(r.drain().is_empty());
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn records_until_cap_then_counts_drops() {
        let mut r = StageRecorder::with_capacity(2);
        for i in 0..5 {
            r.record(Stage::DecodeStep, Precision::of(8), i as f64);
        }
        let taken = r.drain();
        assert_eq!(taken.len(), 2);
        assert_eq!(taken[0], StageSample { stage: Stage::DecodeStep, precision: Precision::of(8), ms: 0.0 });
        assert_eq!(r.dropped(), 3);
        // the drain hands back capacity: recording resumes
        r.record(Stage::Probe, Precision::of(4), 9.0);
        assert_eq!(r.drain().len(), 1);
    }

    #[test]
    fn enable_after_default_allocates_a_buffer() {
        let mut r = StageRecorder::disabled();
        r.set_enabled(true);
        for _ in 0..3 {
            r.record(Stage::Prefill, Precision::of(6), 0.5);
        }
        assert_eq!(r.drain().len(), 3);
        r.set_enabled(false);
        r.record(Stage::Prefill, Precision::of(6), 0.5);
        assert!(r.drain().is_empty());
    }

    #[test]
    fn stage_names_and_indices_line_up() {
        for (i, st) in Stage::ALL.iter().enumerate() {
            assert_eq!(st.index(), i);
            assert!(st.name().ends_with("_ms"));
        }
    }
}
