//! The flight recorder: a fixed-capacity ring of periodic delta frames
//! sampled from a [`Registry`], serializing to a deterministic
//! `otaro.flight.v1` JSON timeline.
//!
//! Point-in-time snapshots (`otaro.metrics.v1`) answer "what is the
//! state now"; drift — creeping queue depth, ladder-cache churn, slow
//! agreement decay after a demote — only shows up *over time*.  The
//! recorder attaches to a registry, freezes the registered metric set,
//! and on every [`FlightRecorder::sample`] call writes one **delta
//! frame**:
//!
//! * counter deltas since the previous frame (wrapping subtraction —
//!   counters are monotonic, so a frame's deltas sum back to the final
//!   counter values when no frames were evicted),
//! * gauge values at sample time (gauges are last-write-wins levels,
//!   not rates — deltas would destroy the signal),
//! * histogram bucket deltas (buckets plus the overflow slot) and the
//!   delta of the running sum, so per-frame means and tail mass are
//!   recoverable without storing samples.
//!
//! The sampling loop is handle-indexed over the attach-time metric
//! set inside a `no_alloc` lint region: every frame buffer and every
//! previous-value array is pre-allocated at attach, so a sample is
//! pure index arithmetic.  Metrics registered *after* attach are not
//! sampled (the index range is frozen) — attach after the registry is
//! fully populated.  When the ring is full the oldest frame is evicted
//! and counted in `frames_dropped`: the recorder is safe to leave
//! running for arbitrarily long soaks.
//!
//! [`FlightRecorder::mark`] pins a labeled logical tick into the
//! timeline (config flips, phase boundaries) without consuming a
//! frame; marks are how the soak harness correlates an applied flip
//! with the frame-delta inflection it must cause.
//!
//! Two serializations: [`FlightRecorder::timeline`] is the full
//! record; [`FlightRecorder::det_timeline`] drops the histogram planes
//! (latency histograms carry wall time) and keeps counters + gauges +
//! marks — the byte-identical-across-seeded-runs artifact the bench
//! diff gate compares.

use crate::json::{arr, n, obj, s, Value};
use crate::obs::Registry;

/// Labeled ticks kept per recorder; later marks are counted, not kept.
pub const MARK_CAP: usize = 64;

/// One sampled delta frame (pre-allocated; rewritten in place when the
/// ring wraps).
#[derive(Debug, Clone)]
struct Frame {
    tick: u64,
    /// per-counter delta since the previous frame
    counters: Vec<u64>,
    /// per-gauge value at sample time
    gauges: Vec<f64>,
    /// per-histogram bucket deltas; the last slot is the overflow bucket
    histos: Vec<Vec<u64>>,
    /// per-histogram delta of the running sum of finite samples
    histo_sums: Vec<f64>,
}

/// See the module docs.
#[derive(Debug, Clone)]
pub struct FlightRecorder {
    counter_names: Vec<String>,
    gauge_names: Vec<String>,
    histo_names: Vec<String>,
    histo_bounds: Vec<Vec<f64>>,
    /// cumulative values at the previous sample (deltas are computed
    /// against these, then they are advanced)
    prev_counters: Vec<u64>,
    prev_histos: Vec<Vec<u64>>,
    prev_histo_sums: Vec<f64>,
    /// the frame ring, fully pre-allocated at attach
    frames: Vec<Frame>,
    /// ring index of the oldest live frame
    head: usize,
    /// live frames (≤ ring capacity)
    len: usize,
    frames_dropped: u64,
    marks: Vec<(u64, String)>,
    marks_dropped: u64,
}

impl FlightRecorder {
    /// Attach to `reg`, freezing its current metric set, with room for
    /// `capacity` frames before the ring starts evicting.
    pub fn attach(reg: &Registry, capacity: usize) -> Self {
        assert!(capacity > 0, "flight recorder needs at least one frame");
        let n_c = reg.n_counters();
        let n_g = reg.n_gauges();
        let n_h = reg.n_histos();
        let counter_names =
            (0..n_c).map(|i| String::from(reg.counter_name(i).unwrap_or(""))).collect();
        let gauge_names = (0..n_g).map(|i| String::from(reg.gauge_name(i).unwrap_or(""))).collect();
        let histo_names = (0..n_h).map(|i| String::from(reg.histo_name(i).unwrap_or(""))).collect();
        let histo_bounds: Vec<Vec<f64>> = (0..n_h).map(|i| reg.histo_bounds_at(i).to_vec()).collect();
        let histo_zeros: Vec<Vec<u64>> =
            histo_bounds.iter().map(|b| vec![0u64; b.len() + 1]).collect();
        let frame = Frame {
            tick: 0,
            counters: vec![0; n_c],
            gauges: vec![0.0; n_g],
            histos: histo_zeros.clone(),
            histo_sums: vec![0.0; n_h],
        };
        FlightRecorder {
            counter_names,
            gauge_names,
            histo_names,
            histo_bounds,
            prev_counters: vec![0; n_c],
            prev_histos: histo_zeros,
            prev_histo_sums: vec![0.0; n_h],
            frames: vec![frame; capacity],
            head: 0,
            len: 0,
            frames_dropped: 0,
            marks: Vec::with_capacity(MARK_CAP),
            marks_dropped: 0,
        }
    }

    // The sampling loop: pure index arithmetic over buffers sized at
    // attach — a soak samples thousands of frames on the serve path and
    // none of them may allocate.
    // lint: region(no_alloc)
    /// Record one delta frame at logical time `tick`, evicting the
    /// oldest frame (and counting the drop) when the ring is full.
    pub fn sample(&mut self, tick: u64, reg: &Registry) {
        let cap = self.frames.len();
        let slot = if self.len < cap {
            self.len += 1;
            (self.head + self.len - 1) % cap
        } else {
            let oldest = self.head;
            self.head = (self.head + 1) % cap;
            self.frames_dropped += 1;
            oldest
        };
        let frame = &mut self.frames[slot];
        frame.tick = tick;
        for i in 0..self.prev_counters.len() {
            let cur = reg.counter_at(i);
            frame.counters[i] = cur.wrapping_sub(self.prev_counters[i]);
            self.prev_counters[i] = cur;
        }
        for i in 0..frame.gauges.len() {
            frame.gauges[i] = reg.gauge_at(i);
        }
        for i in 0..self.prev_histos.len() {
            for b in 0..self.prev_histos[i].len() {
                let cur = reg.histo_bucket_at(i, b);
                frame.histos[i][b] = cur.wrapping_sub(self.prev_histos[i][b]);
                self.prev_histos[i][b] = cur;
            }
            let sum = reg.histo_sum_at(i);
            frame.histo_sums[i] = sum - self.prev_histo_sums[i];
            self.prev_histo_sums[i] = sum;
        }
    }
    // lint: end_region

    /// Pin a labeled logical tick into the timeline (reporting path —
    /// bounded by [`MARK_CAP`], overflow is counted, never grows).
    pub fn mark(&mut self, tick: u64, label: &str) {
        if self.marks.len() < MARK_CAP {
            self.marks.push((tick, String::from(label)));
        } else {
            self.marks_dropped += 1;
        }
    }

    /// Live frames currently in the ring.
    pub fn frames_len(&self) -> usize {
        self.len
    }

    /// Ring capacity in frames.
    pub fn capacity(&self) -> usize {
        self.frames.len()
    }

    /// Frames evicted so far.
    pub fn frames_dropped(&self) -> u64 {
        self.frames_dropped
    }

    /// Index of a counter by name in the attach-time set.
    pub fn counter_index(&self, name: &str) -> Option<usize> {
        self.counter_names.iter().position(|c| c == name)
    }

    /// Index of a gauge by name in the attach-time set.
    pub fn gauge_index(&self, name: &str) -> Option<usize> {
        self.gauge_names.iter().position(|g| g == name)
    }

    /// Index of a histogram by name in the attach-time set.
    pub fn histo_index(&self, name: &str) -> Option<usize> {
        self.histo_names.iter().position(|h| h == name)
    }

    fn frame(&self, i: usize) -> Option<&Frame> {
        if i < self.len {
            self.frames.get((self.head + i) % self.frames.len())
        } else {
            None
        }
    }

    /// Logical tick of the `i`-th live frame, oldest first.
    pub fn frame_tick(&self, i: usize) -> u64 {
        self.frame(i).map_or(0, |f| f.tick)
    }

    /// Counter delta recorded by frame `i` for counter index `c`.
    pub fn counter_delta(&self, i: usize, c: usize) -> u64 {
        self.frame(i).and_then(|f| f.counters.get(c)).copied().unwrap_or(0)
    }

    /// Gauge value recorded by frame `i` for gauge index `g`.
    pub fn gauge_at(&self, i: usize, g: usize) -> f64 {
        self.frame(i).and_then(|f| f.gauges.get(g)).copied().unwrap_or(0.0)
    }

    /// Total sample-count delta (all buckets + overflow) recorded by
    /// frame `i` for histogram index `h`.
    pub fn histo_count_delta(&self, i: usize, h: usize) -> u64 {
        self.frame(i)
            .and_then(|f| f.histos.get(h))
            .map_or(0, |b| b.iter().sum())
    }

    /// Sum-of-samples delta recorded by frame `i` for histogram `h`.
    pub fn histo_sum_delta(&self, i: usize, h: usize) -> f64 {
        self.frame(i).and_then(|f| f.histo_sums.get(h)).copied().unwrap_or(0.0)
    }

    fn marks_json(&self) -> Value {
        Value::Arr(
            self.marks
                .iter()
                .map(|(t, l)| obj(vec![("label", s(l)), ("tick", n(*t as f64))]))
                .collect(),
        )
    }

    fn names_json(names: &[String]) -> Value {
        arr(names.iter().map(|x| s(x)).collect())
    }

    /// The full `otaro.flight.v1` timeline: metric name tables, marks,
    /// drop accounting, and every live frame oldest-first (counters
    /// `c`, gauges `g`, histogram bucket deltas `h`, sum deltas `hs`).
    pub fn timeline(&self) -> Value {
        let mut frames = Vec::with_capacity(self.len);
        for i in 0..self.len {
            let Some(f) = self.frame(i) else { continue };
            frames.push(obj(vec![
                ("c", arr(f.counters.iter().map(|&v| n(v as f64)).collect())),
                ("g", arr(f.gauges.iter().map(|&v| n(v)).collect())),
                (
                    "h",
                    Value::Arr(
                        f.histos
                            .iter()
                            .map(|b| arr(b.iter().map(|&v| n(v as f64)).collect()))
                            .collect(),
                    ),
                ),
                ("hs", arr(f.histo_sums.iter().map(|&v| n(v)).collect())),
                ("tick", n(f.tick as f64)),
            ]));
        }
        let histograms = Value::Arr(
            self.histo_names
                .iter()
                .zip(&self.histo_bounds)
                .map(|(name, bounds)| {
                    obj(vec![
                        ("bounds", arr(bounds.iter().map(|&b| n(b)).collect())),
                        ("name", s(name)),
                    ])
                })
                .collect(),
        );
        obj(vec![
            ("counters", Self::names_json(&self.counter_names)),
            ("frames", Value::Arr(frames)),
            ("frames_dropped", n(self.frames_dropped as f64)),
            ("gauges", Self::names_json(&self.gauge_names)),
            ("histograms", histograms),
            ("marks", self.marks_json()),
            ("marks_dropped", n(self.marks_dropped as f64)),
            ("schema", s("otaro.flight.v1")),
        ])
    }

    /// The deterministic subset of [`timeline`](Self::timeline):
    /// counters, gauges, and marks only.  Histogram planes record wall
    /// time (stage and queue latencies), so they are excluded — this is
    /// the byte-identical-across-seeded-runs artifact.
    pub fn det_timeline(&self) -> Value {
        let mut frames = Vec::with_capacity(self.len);
        for i in 0..self.len {
            let Some(f) = self.frame(i) else { continue };
            frames.push(obj(vec![
                ("c", arr(f.counters.iter().map(|&v| n(v as f64)).collect())),
                ("g", arr(f.gauges.iter().map(|&v| n(v)).collect())),
                ("tick", n(f.tick as f64)),
            ]));
        }
        obj(vec![
            ("counters", Self::names_json(&self.counter_names)),
            ("frames", Value::Arr(frames)),
            ("frames_dropped", n(self.frames_dropped as f64)),
            ("gauges", Self::names_json(&self.gauge_names)),
            ("marks", self.marks_json()),
            ("schema", s("otaro.flight.v1")),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::MetricSink;

    fn small_registry() -> (Registry, crate::obs::Counter, crate::obs::Gauge, crate::obs::Histo) {
        let mut r = Registry::new();
        let c = r.counter("t.count");
        let g = r.gauge("t.level");
        let h = r.histogram("t.lat_ms", &[1.0, 10.0]);
        (r, c, g, h)
    }

    #[test]
    fn frames_carry_deltas_not_cumulatives() {
        let (mut r, c, g, h) = small_registry();
        let mut fr = FlightRecorder::attach(&r, 8);
        r.add(c, 3);
        r.set(g, 5.0);
        r.observe(h, 0.5);
        fr.sample(0, &r);
        r.add(c, 4);
        r.set(g, 2.0);
        r.observe(h, 100.0); // overflow bucket
        fr.sample(1, &r);
        let ci = fr.counter_index("t.count").unwrap();
        let gi = fr.gauge_index("t.level").unwrap();
        let hi = fr.histo_index("t.lat_ms").unwrap();
        assert_eq!(fr.frames_len(), 2);
        assert_eq!((fr.counter_delta(0, ci), fr.counter_delta(1, ci)), (3, 4));
        assert_eq!((fr.gauge_at(0, gi), fr.gauge_at(1, gi)), (5.0, 2.0));
        assert_eq!((fr.histo_count_delta(0, hi), fr.histo_count_delta(1, hi)), (1, 1));
        assert_eq!(fr.histo_sum_delta(0, hi), 0.5);
        // frame-delta sum equals the final counter value
        let total: u64 = (0..fr.frames_len()).map(|i| fr.counter_delta(i, ci)).sum();
        assert_eq!(total, r.counter_value(c));
    }

    #[test]
    fn ring_overflow_evicts_oldest_and_counts_drops() {
        let (mut r, c, _g, _h) = small_registry();
        let mut fr = FlightRecorder::attach(&r, 3);
        for tick in 0..5 {
            r.inc(c);
            fr.sample(tick, &r);
        }
        assert_eq!(fr.frames_len(), 3);
        assert_eq!(fr.frames_dropped(), 2);
        // the survivors are the three newest frames, oldest first
        let ticks: Vec<u64> = (0..3).map(|i| fr.frame_tick(i)).collect();
        assert_eq!(ticks, vec![2, 3, 4]);
        let v = fr.timeline();
        assert_eq!(v.get("frames_dropped").and_then(|x| x.as_f64()), Some(2.0));
    }

    #[test]
    fn metrics_registered_after_attach_are_invisible() {
        let (mut r, c, _g, _h) = small_registry();
        let mut fr = FlightRecorder::attach(&r, 4);
        let late = r.counter("t.late");
        r.inc(c);
        r.add(late, 9);
        fr.sample(0, &r);
        assert_eq!(fr.counter_index("t.late"), None);
        let v = fr.timeline();
        let names = v.get("counters").and_then(|x| x.as_arr()).unwrap();
        assert_eq!(names.len(), 1, "attach-time set is frozen: {v}");
    }

    #[test]
    fn timelines_serialize_deterministically_and_round_trip() {
        let build = || {
            let (mut r, c, g, h) = small_registry();
            let mut fr = FlightRecorder::attach(&r, 4);
            for tick in 0..6u64 {
                r.add(c, tick);
                r.set(g, tick as f64);
                r.observe(h, 0.5);
                fr.sample(tick, &r);
            }
            fr.mark(3, "flip: test");
            (fr.timeline().to_string(), fr.det_timeline().to_string())
        };
        let (full_a, det_a) = build();
        let (full_b, det_b) = build();
        assert_eq!(full_a, full_b);
        assert_eq!(det_a, det_b);
        let v = crate::json::parse(&full_a).unwrap();
        assert_eq!(v.get("schema").and_then(|x| x.as_str()), Some("otaro.flight.v1"));
        // det drops the histogram planes but keeps marks
        let d = crate::json::parse(&det_a).unwrap();
        assert!(d.get("histograms").is_none());
        assert!(!det_a.contains("\"h\""));
        let marks = d.get("marks").and_then(|x| x.as_arr()).unwrap();
        assert_eq!(marks[0].get("label").and_then(|x| x.as_str()), Some("flip: test"));
    }

    #[test]
    fn marks_are_bounded() {
        let (r, ..) = small_registry();
        let mut fr = FlightRecorder::attach(&r, 2);
        for i in 0..(MARK_CAP as u64 + 5) {
            fr.mark(i, "m");
        }
        assert_eq!(fr.timeline().get("marks").and_then(|v| v.as_arr()).unwrap().len(), MARK_CAP);
        assert_eq!(fr.timeline().get("marks_dropped").and_then(|v| v.as_f64()), Some(5.0));
    }
}
