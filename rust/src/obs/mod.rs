//! Observability plane: a typed, first-class metrics registry.
//!
//! PRs 1–6 grew an ad-hoc pile of counters (`ServeStats` fields, pub
//! `calls`/`loads` on backends) that could only be read by whoever held
//! the owning struct.  This module makes metrics a subsystem of their
//! own:
//!
//! * [`Registry`] — named counters, gauges, and fixed-bucket histograms.
//!   Metrics are **pre-registered**: registration returns a typed handle
//!   ([`Counter`], [`Gauge`], [`Histo`]) that is a plain index, so the
//!   record path is handle-indexed arithmetic — no name hashing, no map
//!   lookup, no allocation per event (the `hot-loop-no-alloc` lint
//!   guards the record impl, and `decision-path-determinism` bans hash
//!   collections from the module wholesale).
//! * [`MetricSink`] — the emit interface ([`inc`](MetricSink::inc) /
//!   [`add`](MetricSink::add) / [`set`](MetricSink::set) /
//!   [`observe`](MetricSink::observe)).  Serve, policy, and infer code
//!   take `&mut dyn MetricSink` (or a concrete [`Registry`]) so tests
//!   can swap in [`NullSink`].
//! * [`Registry::snapshot`] — serializes every metric deterministically
//!   through the in-repo `json` module (`json::Value::Obj` is a
//!   `BTreeMap`, so keys come out sorted; identical metric states
//!   produce byte-identical snapshots).
//!
//! Histograms carry fixed, registration-time bucket bounds *and* an
//! embedded [`metrics::Summary`](crate::metrics::Summary) (pre-allocated
//! ring, so `observe` never allocates) — buckets feed dashboards and
//! snapshots, the summary feeds exact p50/p95/p99 for SLO checks.
//!
//! Alongside the aggregate registry, this plane now carries the
//! per-request causal view and the means to stress it:
//!
//! * [`trace`] — ring-buffered span/event tracing ([`Tracer`] behind
//!   [`TraceSink`]): every request's `admitted → queued → scheduled →
//!   decode_step* → delivered/shed` chain with monotone logical ticks,
//!   serialized to deterministic `otaro.trace.v1` snapshots.
//! * [`inject`] — [`LatencyPlan`]-driven latency/fault injection
//!   ([`InjectedBackend`] wraps any `LogitsBackend`) so SLO scenarios
//!   can force p95 violations and every controller demotion is
//!   explained by a traced violation.
//! * [`dashboard`] — deterministic JSON dashboard definitions generated
//!   from a registry snapshot, plus timeline panels generated from a
//!   flight-recorder timeline.
//! * [`flight`] — the flight recorder ([`FlightRecorder`]): a
//!   fixed-capacity ring of periodic delta frames sampled from the
//!   registry on a logical-tick cadence, serializing to byte-identical
//!   `otaro.flight.v1` timelines — the time-series layer drift
//!   invariants and soak runs read from.
//! * [`profile`] — scoped stage timers ([`StageRecorder`], [`Stage`]):
//!   prefill / decode-step / matmul / ladder-switch / probe costs
//!   recorded per rung behind a zero-cost-when-disabled handle and
//!   drained into registry histograms.
//!
//! The serve stack's concrete handle set lives in
//! [`serve::ServeMetrics`](crate::serve::ServeMetrics); the trace-driven
//! load harness that reads these snapshots lives in [`crate::workload`].
//!
//! This module also owns the **frozen-schema registry**: [`SCHEMAS`]
//! declares every `otaro.<name>.v<N>` snapshot schema the crate may
//! emit.  The `schema-registry` lint resolves each such string literal
//! in the crate against this table — emitting an undeclared schema, or
//! silently bumping a version without declaring the new one here, is a
//! lint error.  Versions only ever move by adding a new row.

pub mod dashboard;
pub mod flight;
pub mod inject;
pub mod profile;
pub mod registry;
pub mod trace;

pub use dashboard::{dashboard, timeline_dashboard};
pub use flight::FlightRecorder;
pub use inject::{InjectEvent, InjectedBackend, LatencyPlan, LatencyRule};
pub use profile::{Stage, StageRecorder, StageSample};
pub use registry::{
    Counter, Gauge, Histo, MetricSink, NullSink, Registry, AGREEMENT_BUCKETS, LATENCY_MS_BUCKETS,
    RATIO_BUCKETS,
};
pub use trace::{permille, EventKind, EventRec, NullTrace, ShedReason, TraceSink, Tracer};

/// One declared frozen snapshot schema: the only sanctioned source of
/// `otaro.<name>.v<N>` literals in non-test code.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SchemaDef {
    /// schema name between `otaro.` and `.v<N>`, e.g. `metrics`
    pub name: &'static str,
    /// declared (frozen) version
    pub version: u32,
    /// canonical emitting module, as a source path relative to
    /// `rust/src` — the lint checks the module still emits the literal
    pub module: &'static str,
}

impl SchemaDef {
    /// The full literal this row declares, e.g. `otaro.metrics.v1`.
    pub fn literal(&self) -> String {
        format!("otaro.{}.v{}", self.name, self.version)
    }
}

/// Every frozen snapshot schema the crate emits.  Append-only: bumping
/// a version means adding a row (and consciously deciding what happens
/// to consumers of the old one), never editing an existing row.
pub static SCHEMAS: &[SchemaDef] = &[
    SchemaDef { name: "metrics", version: 1, module: "obs/registry.rs" },
    SchemaDef { name: "trace", version: 1, module: "obs/trace.rs" },
    SchemaDef { name: "flight", version: 1, module: "obs/flight.rs" },
    SchemaDef { name: "dashboard", version: 1, module: "obs/dashboard.rs" },
    SchemaDef { name: "timeline_dashboard", version: 1, module: "obs/dashboard.rs" },
    SchemaDef { name: "bench", version: 1, module: "benchutil/mod.rs" },
    SchemaDef { name: "lint", version: 1, module: "lint/mod.rs" },
];
