//! Config system: JSON-backed configs with presets and CLI overrides
//! (serialization via the in-repo `json` substrate — the offline vendor
//! set has no serde).
//!
//! The model architecture config is *read from the artifact manifest*
//! (single source of truth is `python/compile/model.py::param_spec`); the
//! configs here govern everything the Rust side owns: training schedule,
//! BPS/LAA hyper-parameters, serving policy, experiment sweeps.

use std::path::{Path, PathBuf};

use crate::json::{arr, n, obj, s, Value};
use crate::sefp::{Precision, Rounding};

/// Fine-tuning method (paper table 1 rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    /// No fine-tuning at all ("Before Fine-Tuning").
    None,
    /// Full-precision fine-tuning ("FP16 Fine-Tuning" in the paper; f32
    /// masters on this CPU image).
    Fp,
    /// Per-bit-width STE fine-tuning ("Fixed Precision Fine-Tuning") —
    /// one run per bit-width, multiplying total tuning time.
    Fixed,
    /// Uniformly random bit-width sampling (fig. 3 baseline).
    Uniform,
    /// BPS without LAA (ablation, fig. 8).
    BpsOnly,
    /// Full OTARo: BPS + LAA (Algorithm 1).
    Otaro,
}

impl std::str::FromStr for Method {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "none" => Ok(Method::None),
            "fp" => Ok(Method::Fp),
            "fixed" => Ok(Method::Fixed),
            "uniform" => Ok(Method::Uniform),
            "bps_only" => Ok(Method::BpsOnly),
            "otaro" => Ok(Method::Otaro),
            other => Err(format!("unknown method {other:?}")),
        }
    }
}

impl std::fmt::Display for Method {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Method::None => "none",
            Method::Fp => "fp",
            Method::Fixed => "fixed",
            Method::Uniform => "uniform",
            Method::BpsOnly => "bps_only",
            Method::Otaro => "otaro",
        };
        f.write_str(s)
    }
}

/// Training/fine-tuning configuration (paper §Implementation Details).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub method: Method,
    /// SGD learning rate (paper: 1e-5 for LLM-scale; our small models
    /// converge with a larger default, overridable per experiment).
    pub lr: f32,
    pub steps: usize,
    /// Precisions in play (paper: E5M{8..3}), validated at parse time:
    /// out-of-range widths are a config error, duplicates are dropped,
    /// and the ladder is kept sorted highest precision first.
    pub widths: Vec<Precision>,
    /// BPS exploration coefficient λ (paper: 5).
    pub lambda: f64,
    /// LAA delay step N (paper: 10).
    pub delay_n: usize,
    /// Precisions at or below this count as "ultra-low" for LAA (the
    /// paper leaves this open; Ablation A in EXPERIMENTS.md shows the
    /// bottom rung only (E5M3) is best — deferring E5M4 too throttles
    /// its learning).
    pub ultra_low_max: Precision,
    /// For Method::Fixed — which precision this run is fixed to.
    pub fixed_m: Option<Precision>,
    pub seed: u64,
    pub rounding: Rounding,
    /// Evaluate every k steps (0 = only at the end).
    pub eval_every: usize,
    /// Loss EMA horizon used for the BPS score's L_b term.
    pub loss_ema: f64,
    /// LAA delayed update uses the MEAN of the accumulated gradients
    /// (true, default) or the paper's raw sum (eq. 18).  The raw sum is
    /// only stable at LLM-scale learning rates (the paper's η=1e-5); at
    /// this repo's η it multiplies the effective step by N and diverges —
    /// see EXPERIMENTS.md §Deviations.
    pub laa_average: bool,
    /// LAA ablation: apply the partial accumulator whenever the path
    /// leaves the ultra-low zone instead of letting it persist
    /// (DESIGN.md §6).
    pub laa_flush_on_switch: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            method: Method::Otaro,
            lr: 1e-2,
            steps: 300,
            widths: Precision::LADDER.to_vec(),
            lambda: 5.0,
            delay_n: 10,
            ultra_low_max: Precision::of(3),
            fixed_m: None,
            seed: 0,
            rounding: Rounding::Trunc,
            eval_every: 0,
            loss_ema: 0.9,
            laa_average: true,
            laa_flush_on_switch: false,
        }
    }
}

impl TrainConfig {
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("method", s(self.method.to_string())),
            ("lr", n(self.lr as f64)),
            ("steps", n(self.steps as f64)),
            ("widths", arr(self.widths.iter().map(|&w| n(w.m() as f64)).collect())),
            ("lambda", n(self.lambda)),
            ("delay_n", n(self.delay_n as f64)),
            ("ultra_low_max_m", n(self.ultra_low_max.m() as f64)),
            (
                "fixed_m",
                self.fixed_m.map(|p| n(p.m() as f64)).unwrap_or(Value::Null),
            ),
            ("seed", n(self.seed as f64)),
            (
                "rounding",
                s(match self.rounding {
                    Rounding::Trunc => "trunc",
                    Rounding::Nearest => "nearest",
                }),
            ),
            ("eval_every", n(self.eval_every as f64)),
            ("loss_ema", n(self.loss_ema)),
            ("laa_average", Value::Bool(self.laa_average)),
            ("laa_flush_on_switch", Value::Bool(self.laa_flush_on_switch)),
        ])
    }

    /// Parse from JSON; absent fields keep defaults.
    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        let mut c = TrainConfig::default();
        if let Some(m) = v.get("method").and_then(Value::as_str) {
            c.method = m.parse().map_err(|e: String| anyhow::anyhow!(e))?;
        }
        if let Some(x) = v.get("lr").and_then(Value::as_f64) {
            c.lr = x as f32;
        }
        if let Some(x) = v.get("steps").and_then(Value::as_usize) {
            c.steps = x;
        }
        if let Some(ws) = v.get("widths").and_then(Value::as_arr) {
            // validate at parse time: out-of-range widths are a config
            // error (the seed panicked later, deep in `SefpTensor::
            // encode`'s assert); dedupe and sort highest-first so the
            // trainer sees a canonical ladder.
            let mut widths = Vec::with_capacity(ws.len());
            for w in ws {
                let m = w
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("widths entry not a number: {w:?}"))?;
                let p = Precision::from_num(m)
                    .map_err(|e| anyhow::anyhow!("config widths: {e}"))?;
                if !widths.contains(&p) {
                    widths.push(p);
                }
            }
            anyhow::ensure!(!widths.is_empty(), "config widths must be non-empty");
            widths.sort_unstable_by(|a, b| b.cmp(a));
            c.widths = widths;
        }
        if let Some(x) = v.get("lambda").and_then(Value::as_f64) {
            c.lambda = x;
        }
        if let Some(x) = v.get("delay_n").and_then(Value::as_usize) {
            c.delay_n = x;
        }
        if let Some(x) = v.get("ultra_low_max_m").and_then(Value::as_f64) {
            c.ultra_low_max = Precision::from_num(x)
                .map_err(|e| anyhow::anyhow!("config ultra_low_max_m: {e}"))?;
        }
        match v.get("fixed_m") {
            Some(Value::Num(x)) => {
                c.fixed_m = Some(
                    Precision::from_num(*x)
                        .map_err(|e| anyhow::anyhow!("config fixed_m: {e}"))?,
                )
            }
            Some(Value::Null) | None => {}
            Some(other) => anyhow::bail!("fixed_m not a number: {other:?}"),
        }
        if let Some(x) = v.get("seed").and_then(Value::as_f64) {
            c.seed = x as u64;
        }
        if let Some(r) = v.get("rounding").and_then(Value::as_str) {
            c.rounding = r.parse().map_err(|e: String| anyhow::anyhow!(e))?;
        }
        if let Some(x) = v.get("eval_every").and_then(Value::as_usize) {
            c.eval_every = x;
        }
        if let Some(x) = v.get("loss_ema").and_then(Value::as_f64) {
            c.loss_ema = x;
        }
        if let Some(x) = v.get("laa_average").and_then(Value::as_bool) {
            c.laa_average = x;
        }
        if let Some(x) = v.get("laa_flush_on_switch").and_then(Value::as_bool) {
            c.laa_flush_on_switch = x;
        }
        Ok(c)
    }
}

/// Adaptive precision control-plane configuration (`rust/src/policy/`).
///
/// Governs the `AdaptivePolicy` feedback loop: telemetry window sizes,
/// the latency SLO, shadow-probe cadence, the quality floor/hysteresis
/// band, controller cooldown, and the BPS exploration coefficient the
/// serve-time scoring reuses from the paper (eq. 5).
#[derive(Debug, Clone)]
pub struct PolicyConfig {
    /// route through `AdaptivePolicy` (false = today's `StaticPolicy`)
    pub adaptive: bool,
    /// per-class p95 end-to-end latency SLO, milliseconds
    pub slo_p95_ms: f64,
    /// fraction of completions shadow-probed at master precision, [0, 1]
    pub probe_rate: f64,
    /// minimum probe token-agreement before a class is promoted
    pub quality_floor: f64,
    /// demotion additionally requires agreement ≥ floor + headroom —
    /// the hysteresis band that stops demote/promote flapping
    pub quality_headroom: f64,
    /// telemetry sliding-window capacity (samples per lane)
    pub window: usize,
    /// latency observations required before the controller may demote
    pub min_samples: usize,
    /// decision ticks a class holds after any switch
    pub cooldown: u64,
    /// BPS exploration coefficient λ (paper: 5)
    pub lambda: f64,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        PolicyConfig {
            adaptive: false,
            slo_p95_ms: 25.0,
            probe_rate: 0.1,
            quality_floor: 0.9,
            quality_headroom: 0.02,
            window: 128,
            min_samples: 16,
            cooldown: 32,
            lambda: 5.0,
        }
    }
}

impl PolicyConfig {
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("adaptive", Value::Bool(self.adaptive)),
            ("slo_p95_ms", n(self.slo_p95_ms)),
            ("probe_rate", n(self.probe_rate)),
            ("quality_floor", n(self.quality_floor)),
            ("quality_headroom", n(self.quality_headroom)),
            ("window", n(self.window as f64)),
            ("min_samples", n(self.min_samples as f64)),
            ("cooldown", n(self.cooldown as f64)),
            ("lambda", n(self.lambda)),
        ])
    }

    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        let mut c = PolicyConfig::default();
        if let Some(x) = v.get("adaptive").and_then(Value::as_bool) {
            c.adaptive = x;
        }
        if let Some(x) = v.get("slo_p95_ms").and_then(Value::as_f64) {
            anyhow::ensure!(x > 0.0, "policy slo_p95_ms must be positive, got {x}");
            c.slo_p95_ms = x;
        }
        if let Some(x) = v.get("probe_rate").and_then(Value::as_f64) {
            anyhow::ensure!((0.0..=1.0).contains(&x), "policy probe_rate not in [0,1]: {x}");
            c.probe_rate = x;
        }
        if let Some(x) = v.get("quality_floor").and_then(Value::as_f64) {
            anyhow::ensure!((0.0..=1.0).contains(&x), "policy quality_floor not in [0,1]: {x}");
            c.quality_floor = x;
        }
        if let Some(x) = v.get("quality_headroom").and_then(Value::as_f64) {
            anyhow::ensure!(
                (0.0..=1.0).contains(&x),
                "policy quality_headroom not in [0,1]: {x}"
            );
            c.quality_headroom = x;
        }
        if let Some(x) = v.get("window").and_then(Value::as_usize) {
            anyhow::ensure!(x >= 1, "policy window must be at least 1");
            c.window = x;
        }
        if let Some(x) = v.get("min_samples").and_then(Value::as_usize) {
            c.min_samples = x;
        }
        if let Some(x) = v.get("cooldown").and_then(Value::as_usize) {
            c.cooldown = x as u64;
        }
        if let Some(x) = v.get("lambda").and_then(Value::as_f64) {
            c.lambda = x;
        }
        // cross-field contracts: shadow probes are the adaptive loop's
        // only quality guard (without them demotion would run blind and
        // promotion could never trigger), and a demotion gate deeper
        // than the telemetry window could never fill
        anyhow::ensure!(
            !c.adaptive || c.probe_rate > 0.0,
            "adaptive policy requires probe_rate > 0 (shadow probes are the quality guard)"
        );
        anyhow::ensure!(
            c.min_samples <= c.window,
            "policy min_samples ({}) exceeds the telemetry window ({}) — demotion could \
             never trigger",
            c.min_samples,
            c.window
        );
        Ok(c)
    }
}

/// Serving configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// max requests batched into one engine call
    pub max_batch: usize,
    /// queue capacity before backpressure
    pub queue_cap: usize,
    /// default precision when the router has no signal
    pub default_precision: Precision,
    /// precision used for generation-class requests
    pub generation_precision: Precision,
    /// precision used for understanding-class requests
    pub understanding_precision: Precision,
    /// the precisions serving may run at (the deployment ladder):
    /// adaptive-policy switching stays inside it, and forced per-request
    /// precisions are clamped to it by the router.  Validated at parse
    /// time like `TrainConfig::widths` (deduped, sorted highest first).
    pub ladder: Vec<Precision>,
    /// adaptive control-plane knobs (`rust/src/policy/`)
    pub policy: PolicyConfig,
    /// worker threads for the batched decode kernels
    /// (`infer::QuantLinear::matmul` column split, used by
    /// `serve::DecoderBackend`); 1 = serial.  Output is bit-identical
    /// for every value — this is a throughput knob, never a numerics one.
    pub decode_threads: usize,
    /// byte budget for derived-precision residency in the serving
    /// `PrecisionLadder` (the single SEFP master is always resident and
    /// not charged; cached truncated views are LRU-evicted past this)
    pub ladder_budget_bytes: usize,
    /// packed `.sefp` container to serve from (`rust/src/artifact/`):
    /// when set, the serve path builds its ladder with
    /// `PrecisionLadder::from_artifact` — no f32 master parse/encode on
    /// startup — instead of encoding an f32 checkpoint
    pub sefp_artifact: Option<PathBuf>,
    /// scheduler anti-starvation bound: a precision queue whose head has
    /// waited this long is scheduled next regardless of score (in-flight
    /// decodes finish first — see `serve::SchedPolicy`)
    pub max_wait_ms: u64,
    /// scheduler score contribution per second of head-of-queue wait
    /// (fill ratio is in [0, 1], so 1.0 means one second of waiting
    /// outweighs a full batch elsewhere)
    pub age_weight: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 8,
            queue_cap: 256,
            default_precision: Precision::of(6),
            generation_precision: Precision::of(8),
            understanding_precision: Precision::of(4),
            ladder: Precision::LADDER.to_vec(),
            policy: PolicyConfig::default(),
            decode_threads: 1,
            max_wait_ms: 500,
            age_weight: 1.0,
            ladder_budget_bytes: 256 << 20,
            sefp_artifact: None,
        }
    }
}

impl ServeConfig {
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("max_batch", n(self.max_batch as f64)),
            ("queue_cap", n(self.queue_cap as f64)),
            ("default_m", n(self.default_precision.m() as f64)),
            ("generation_m", n(self.generation_precision.m() as f64)),
            ("understanding_m", n(self.understanding_precision.m() as f64)),
            ("ladder_m", arr(self.ladder.iter().map(|&w| n(w.m() as f64)).collect())),
            ("policy", self.policy.to_json()),
            ("decode_threads", n(self.decode_threads as f64)),
            ("max_wait_ms", n(self.max_wait_ms as f64)),
            ("age_weight", n(self.age_weight)),
            ("ladder_budget_bytes", n(self.ladder_budget_bytes as f64)),
            (
                "sefp_artifact",
                match &self.sefp_artifact {
                    Some(p) => s(p.display().to_string()),
                    None => Value::Null,
                },
            ),
        ])
    }

    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        let mut c = ServeConfig::default();
        if let Some(x) = v.get("max_batch").and_then(Value::as_usize) {
            // 0 rows would make the serve loop pop empty batches forever
            anyhow::ensure!(x >= 1, "serve config max_batch must be at least 1");
            c.max_batch = x;
        }
        if let Some(x) = v.get("queue_cap").and_then(Value::as_usize) {
            c.queue_cap = x;
        }
        let precision_field = |key: &str| -> anyhow::Result<Option<Precision>> {
            match v.get(key).and_then(Value::as_f64) {
                None => Ok(None),
                Some(x) => Precision::from_num(x)
                    .map(Some)
                    .map_err(|e| anyhow::anyhow!("serve config {key}: {e}")),
            }
        };
        if let Some(p) = precision_field("default_m")? {
            c.default_precision = p;
        }
        if let Some(p) = precision_field("generation_m")? {
            c.generation_precision = p;
        }
        if let Some(p) = precision_field("understanding_m")? {
            c.understanding_precision = p;
        }
        if let Some(ws) = v.get("ladder_m").and_then(Value::as_arr) {
            // same validation contract as TrainConfig::widths: reject
            // out-of-range widths at parse time, dedupe, sort highest
            // precision first
            let mut ladder = Vec::with_capacity(ws.len());
            for w in ws {
                let m = w
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("ladder_m entry not a number: {w:?}"))?;
                let p = Precision::from_num(m)
                    .map_err(|e| anyhow::anyhow!("serve config ladder_m: {e}"))?;
                if !ladder.contains(&p) {
                    ladder.push(p);
                }
            }
            anyhow::ensure!(!ladder.is_empty(), "serve config ladder_m must be non-empty");
            Precision::canonicalize_ladder(&mut ladder);
            c.ladder = ladder;
        }
        if let Some(p) = v.get("policy") {
            c.policy = PolicyConfig::from_json(p)?;
        }
        if let Some(x) = v.get("decode_threads").and_then(Value::as_usize) {
            anyhow::ensure!(x >= 1, "serve config decode_threads must be at least 1");
            c.decode_threads = x;
        }
        if let Some(x) = v.get("max_wait_ms").and_then(Value::as_usize) {
            c.max_wait_ms = x as u64;
        }
        if let Some(x) = v.get("age_weight").and_then(Value::as_f64) {
            c.age_weight = x;
        }
        if let Some(x) = v.get("ladder_budget_bytes").and_then(Value::as_usize) {
            c.ladder_budget_bytes = x;
        }
        match v.get("sefp_artifact") {
            Some(Value::Str(p)) => c.sefp_artifact = Some(PathBuf::from(p)),
            Some(Value::Null) | None => {}
            Some(other) => anyhow::bail!("sefp_artifact not a path string: {other:?}"),
        }
        Ok(c)
    }
}

/// Top-level experiment config, loadable from JSON.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    pub train: TrainConfig,
    pub serve: ServeConfig,
    pub artifacts: PathBuf,
    pub runs: PathBuf,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: String::new(),
            train: TrainConfig::default(),
            serve: ServeConfig::default(),
            artifacts: PathBuf::from("artifacts"),
            runs: PathBuf::from("runs"),
        }
    }
}

impl ExperimentConfig {
    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&crate::json::parse(&text)?)
    }

    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        let mut c = ExperimentConfig::default();
        if let Some(name) = v.get("name").and_then(Value::as_str) {
            c.name = name.to_string();
        }
        if let Some(t) = v.get("train") {
            c.train = TrainConfig::from_json(t)?;
        }
        if let Some(sv) = v.get("serve") {
            c.serve = ServeConfig::from_json(sv)?;
        }
        if let Some(p) = v.get("artifacts").and_then(Value::as_str) {
            c.artifacts = PathBuf::from(p);
        }
        if let Some(p) = v.get("runs").and_then(Value::as_str) {
            c.runs = PathBuf::from(p);
        }
        Ok(c)
    }

    pub fn to_json(&self) -> Value {
        obj(vec![
            ("name", s(self.name.clone())),
            ("train", self.train.to_json()),
            ("serve", self.serve.to_json()),
            ("artifacts", s(self.artifacts.display().to_string())),
            ("runs", s(self.runs.display().to_string())),
        ])
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    /// Run directory for this experiment (created on demand).
    pub fn run_dir(&self) -> anyhow::Result<PathBuf> {
        let dir = self.runs.join(&self.name);
        std::fs::create_dir_all(&dir)?;
        Ok(dir)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = TrainConfig::default();
        assert_eq!(c.widths, Precision::LADDER.to_vec());
        assert_eq!(c.lambda, 5.0);
        assert_eq!(c.delay_n, 10);
    }

    #[test]
    fn json_roundtrip() {
        let mut c = ExperimentConfig { name: "t".into(), ..ExperimentConfig::default() };
        c.train.method = Method::Fixed;
        c.train.fixed_m = Some(Precision::of(4));
        c.train.lambda = 3.5;
        let text = c.to_json().to_string();
        let d = ExperimentConfig::from_json(&crate::json::parse(&text).unwrap()).unwrap();
        assert_eq!(d.train.method, Method::Fixed);
        assert_eq!(d.train.fixed_m, Some(Precision::of(4)));
        assert_eq!(d.train.lambda, 3.5);
        assert_eq!(d.name, "t");
        assert_eq!(d.serve.default_precision, Precision::of(6));
    }

    #[test]
    fn widths_validated_deduped_sorted() {
        // duplicates dropped, order canonicalized highest-first
        let v = crate::json::parse(r#"{"widths":[3,8,3,5,8]}"#).unwrap();
        let c = TrainConfig::from_json(&v).unwrap();
        assert_eq!(
            c.widths,
            vec![Precision::of(8), Precision::of(5), Precision::of(3)]
        );
        // out-of-range width is a config error, not a later encode panic
        for bad in [r#"{"widths":[8,0]}"#, r#"{"widths":[15]}"#, r#"{"widths":[]}"#] {
            let v = crate::json::parse(bad).unwrap();
            assert!(TrainConfig::from_json(&v).is_err(), "{bad}");
        }
    }

    #[test]
    fn serve_ladder_and_policy_roundtrip() {
        let c = ServeConfig {
            ladder: vec![Precision::of(8), Precision::of(5), Precision::of(3)],
            policy: PolicyConfig {
                adaptive: true,
                slo_p95_ms: 12.5,
                probe_rate: 0.25,
                ..PolicyConfig::default()
            },
            ..ServeConfig::default()
        };
        let d = ServeConfig::from_json(&crate::json::parse(&c.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(d.ladder, c.ladder);
        assert!(d.policy.adaptive);
        assert_eq!(d.policy.slo_p95_ms, 12.5);
        assert_eq!(d.policy.probe_rate, 0.25);
        assert_eq!(d.policy.quality_floor, PolicyConfig::default().quality_floor);
    }

    #[test]
    fn serve_sefp_artifact_roundtrip() {
        let c = ServeConfig {
            sefp_artifact: Some(PathBuf::from("runs/master.sefp")),
            ..ServeConfig::default()
        };
        let d = ServeConfig::from_json(&crate::json::parse(&c.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(d.sefp_artifact, Some(PathBuf::from("runs/master.sefp")));
        // absent and null both mean "no artifact"
        let d = ServeConfig::from_json(&crate::json::parse("{}").unwrap()).unwrap();
        assert_eq!(d.sefp_artifact, None);
        let v = crate::json::parse(r#"{"sefp_artifact":null}"#).unwrap();
        assert_eq!(ServeConfig::from_json(&v).unwrap().sefp_artifact, None);
        let v = crate::json::parse(r#"{"sefp_artifact":42}"#).unwrap();
        assert!(ServeConfig::from_json(&v).is_err());
    }

    #[test]
    fn serve_ladder_validated_deduped_sorted() {
        let v = crate::json::parse(r#"{"ladder_m":[3,8,3,5]}"#).unwrap();
        let c = ServeConfig::from_json(&v).unwrap();
        assert_eq!(c.ladder, vec![Precision::of(8), Precision::of(5), Precision::of(3)]);
        for bad in [r#"{"ladder_m":[]}"#, r#"{"ladder_m":[0]}"#, r#"{"ladder_m":[15]}"#] {
            let v = crate::json::parse(bad).unwrap();
            assert!(ServeConfig::from_json(&v).is_err(), "{bad}");
        }
    }

    #[test]
    fn policy_config_rejects_out_of_range() {
        for bad in [
            r#"{"policy":{"probe_rate":1.5}}"#,
            r#"{"policy":{"quality_floor":-0.1}}"#,
            r#"{"policy":{"slo_p95_ms":0}}"#,
            r#"{"policy":{"window":0}}"#,
            // adaptive without probes would demote blind and never promote
            r#"{"policy":{"adaptive":true,"probe_rate":0}}"#,
            // a demotion gate deeper than the window could never fill
            r#"{"policy":{"window":8,"min_samples":16}}"#,
        ] {
            let v = crate::json::parse(bad).unwrap();
            assert!(ServeConfig::from_json(&v).is_err(), "{bad}");
        }
        // probe_rate 0 stays legal for the static policy
        let v = crate::json::parse(r#"{"policy":{"probe_rate":0}}"#).unwrap();
        assert!(ServeConfig::from_json(&v).is_ok());
    }

    #[test]
    fn serve_decode_threads_roundtrip_and_validated() {
        let c = ServeConfig { decode_threads: 4, ..ServeConfig::default() };
        let d = ServeConfig::from_json(&crate::json::parse(&c.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(d.decode_threads, 4);
        // absent keeps the serial default; zero is a config error
        let d = ServeConfig::from_json(&crate::json::parse("{}").unwrap()).unwrap();
        assert_eq!(d.decode_threads, 1);
        let v = crate::json::parse(r#"{"decode_threads":0}"#).unwrap();
        assert!(ServeConfig::from_json(&v).is_err());
        // zero engine rows would hang the serve loop — config error
        let v = crate::json::parse(r#"{"max_batch":0}"#).unwrap();
        assert!(ServeConfig::from_json(&v).is_err());
    }

    #[test]
    fn serve_precision_fields_validated() {
        let v = crate::json::parse(r#"{"default_m":5}"#).unwrap();
        assert_eq!(
            ServeConfig::from_json(&v).unwrap().default_precision,
            Precision::of(5)
        );
        let v = crate::json::parse(r#"{"generation_m":99}"#).unwrap();
        assert!(ServeConfig::from_json(&v).is_err());
    }

    #[test]
    fn partial_json_uses_defaults() {
        let v = crate::json::parse(r#"{"name":"x","train":{"lr":0.5}}"#).unwrap();
        let d = ExperimentConfig::from_json(&v).unwrap();
        assert_eq!(d.train.lr, 0.5);
        assert_eq!(d.train.delay_n, 10);
    }

    #[test]
    fn method_parse() {
        assert_eq!("otaro".parse::<Method>().unwrap(), Method::Otaro);
        assert!("bogus".parse::<Method>().is_err());
    }
}
