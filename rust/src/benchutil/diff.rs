//! `otaro bench-diff`: the cross-run perf trend gate.
//!
//! Compares two `otaro.bench.v1` files (baseline first, candidate
//! second), matching records by `name`:
//!
//! * `det` sections must be **byte-identical** — they are designed to be
//!   reproducible run to run, so any difference is a behavior change,
//!   not noise.
//! * the wall-side headline metric (`median_ns` for kernel benches,
//!   `wall.wall_secs` for scenario records) is compared within a
//!   tolerance: with `--fail-on-regression PCT`, a candidate slower than
//!   `baseline * (1 + PCT/100)` fails.
//!
//! Without `--fail-on-regression` the command is a pure report (exit 0):
//! safe for local inspection of intentional changes.  With it, det
//! mismatches and over-tolerance slowdowns are fatal — that mode is what
//! CI runs against the previous run's artifact.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::json::Value;

/// One record whose headline wall metric slowed past the tolerance.
#[derive(Debug, Clone)]
pub struct Regression {
    pub name: String,
    /// which metric was compared (`median_ns` or `wall_secs`)
    pub metric: &'static str,
    pub baseline: f64,
    pub candidate: f64,
    /// signed percent change, `+` = slower
    pub delta_pct: f64,
}

/// Everything a comparison found; [`gate`](DiffReport::gate) turns it
/// into pass/fail under a tolerance.
#[derive(Debug, Default)]
pub struct DiffReport {
    /// bench name shared by both files
    pub bench: String,
    /// records present in both files and compared
    pub compared: usize,
    /// record names whose `det` sections differ byte-for-byte
    pub det_mismatches: Vec<String>,
    /// every wall-metric slowdown, regardless of size (the tolerance is
    /// applied at gate time, not collection time)
    pub slowdowns: Vec<Regression>,
    /// records in the baseline only — a bench silently disappeared
    pub missing: Vec<String>,
    /// records in the candidate only — new coverage, never an error
    pub added: Vec<String>,
}

impl DiffReport {
    /// Slowdowns beyond `pct` percent.
    pub fn regressions_over(&self, pct: f64) -> Vec<&Regression> {
        self.slowdowns.iter().filter(|r| r.delta_pct > pct).collect()
    }

    /// Gate verdict: `Err` when a det section changed, a record
    /// vanished, or a slowdown exceeds `pct`.
    pub fn gate(&self, pct: f64) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.det_mismatches.is_empty(),
            "deterministic sections changed for: {}",
            self.det_mismatches.join(", ")
        );
        anyhow::ensure!(
            self.missing.is_empty(),
            "baseline records vanished: {}",
            self.missing.join(", ")
        );
        let over = self.regressions_over(pct);
        anyhow::ensure!(
            over.is_empty(),
            "{} record(s) regressed past {pct}%: {}",
            over.len(),
            over.iter()
                .map(|r| format!("{} ({} {:+.1}%)", r.name, r.metric, r.delta_pct))
                .collect::<Vec<_>>()
                .join(", ")
        );
        Ok(())
    }
}

/// The headline wall metric of one record: kernel benches carry a flat
/// `median_ns`; scenario records carry `wall.wall_secs`.
fn wall_metric(rec: &Value) -> Option<(&'static str, f64)> {
    if let Some(v) = rec.get("median_ns").and_then(|v| v.as_f64()) {
        return Some(("median_ns", v));
    }
    rec.get("wall")
        .and_then(|w| w.get("wall_secs"))
        .and_then(|v| v.as_f64())
        .map(|v| ("wall_secs", v))
}

fn records_by_name(file: &Value) -> anyhow::Result<BTreeMap<String, &Value>> {
    let records = file
        .get("records")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow::anyhow!("bench file has no records array"))?;
    let mut by_name = BTreeMap::new();
    for rec in records {
        let name = rec.req_str("name")?;
        anyhow::ensure!(
            by_name.insert(name.clone(), rec).is_none(),
            "duplicate record name {name:?}"
        );
    }
    Ok(by_name)
}

fn validate_envelope(file: &Value, label: &str) -> anyhow::Result<String> {
    let schema = file.req_str("schema")?;
    anyhow::ensure!(
        schema == "otaro.bench.v1",
        "{label}: unsupported schema {schema:?} (want otaro.bench.v1)"
    );
    file.req_str("bench")
}

/// Compare two parsed `otaro.bench.v1` values (baseline, candidate).
pub fn diff(baseline: &Value, candidate: &Value) -> anyhow::Result<DiffReport> {
    let bench_a = validate_envelope(baseline, "baseline")?;
    let bench_b = validate_envelope(candidate, "candidate")?;
    anyhow::ensure!(
        bench_a == bench_b,
        "bench mismatch: baseline is {bench_a:?}, candidate is {bench_b:?}"
    );
    let old = records_by_name(baseline)?;
    let new = records_by_name(candidate)?;

    let mut rep = DiffReport { bench: bench_a, ..DiffReport::default() };
    for (name, rec_old) in &old {
        let Some(rec_new) = new.get(name) else {
            rep.missing.push(name.clone());
            continue;
        };
        rep.compared += 1;
        // det sections serialize with sorted keys — byte equality IS
        // semantic equality here
        let det_old = rec_old.get("det").map(Value::to_string);
        let det_new = rec_new.get("det").map(Value::to_string);
        if det_old != det_new {
            rep.det_mismatches.push(name.clone());
        }
        if let (Some((metric, a)), Some((_, b))) = (wall_metric(rec_old), wall_metric(rec_new)) {
            if b > a && a > 0.0 {
                rep.slowdowns.push(Regression {
                    name: name.clone(),
                    metric,
                    baseline: a,
                    candidate: b,
                    delta_pct: (b / a - 1.0) * 100.0,
                });
            }
        }
    }
    for name in new.keys() {
        if !old.contains_key(name) {
            rep.added.push(name.clone());
        }
    }
    Ok(rep)
}

fn load(path: &Path) -> anyhow::Result<Value> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    crate::json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

/// `otaro bench-diff` entry point.
pub fn run_cli(baseline: PathBuf, candidate: PathBuf, fail_pct: Option<f64>) -> anyhow::Result<()> {
    let rep = diff(&load(&baseline)?, &load(&candidate)?)?;
    println!(
        "bench-diff [{}]: {} compared, {} det mismatch(es), {} slowdown(s), {} missing, {} added",
        rep.bench,
        rep.compared,
        rep.det_mismatches.len(),
        rep.slowdowns.len(),
        rep.missing.len(),
        rep.added.len()
    );
    for name in &rep.det_mismatches {
        println!("  det changed: {name}");
    }
    for r in &rep.slowdowns {
        println!(
            "  slower: {:<44} {} {:.0} -> {:.0} ({:+.1}%)",
            r.name, r.metric, r.baseline, r.candidate, r.delta_pct
        );
    }
    for name in &rep.missing {
        println!("  missing in candidate: {name}");
    }
    for name in &rep.added {
        println!("  new in candidate: {name}");
    }
    if let Some(pct) = fail_pct {
        rep.gate(pct)?;
        println!("gate passed at {pct}% tolerance");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::{self};

    fn kernel_file(median: f64) -> Value {
        json::obj(vec![
            ("schema", json::s("otaro.bench.v1")),
            ("bench", json::s("kernels")),
            (
                "records",
                Value::Arr(vec![json::obj(vec![
                    ("name", json::s("matmul")),
                    ("median_ns", json::n(median)),
                ])]),
            ),
        ])
    }

    fn scenario_file(shed: f64, wall_secs: f64) -> Value {
        json::obj(vec![
            ("schema", json::s("otaro.bench.v1")),
            ("bench", json::s("serve_scenarios")),
            (
                "records",
                Value::Arr(vec![json::obj(vec![
                    ("name", json::s("burst-storm")),
                    ("det", json::obj(vec![("shed", json::n(shed))])),
                    ("wall", json::obj(vec![("wall_secs", json::n(wall_secs))])),
                ])]),
            ),
        ])
    }

    #[test]
    fn identical_files_pass_any_gate() {
        let rep = diff(&kernel_file(100.0), &kernel_file(100.0)).unwrap();
        assert_eq!(rep.compared, 1);
        assert!(rep.slowdowns.is_empty() && rep.det_mismatches.is_empty());
        rep.gate(0.0).unwrap();
    }

    #[test]
    fn wall_regression_trips_only_past_tolerance() {
        let rep = diff(&kernel_file(100.0), &kernel_file(130.0)).unwrap();
        assert_eq!(rep.slowdowns.len(), 1);
        assert!((rep.slowdowns[0].delta_pct - 30.0).abs() < 1e-9);
        rep.gate(50.0).unwrap();
        assert!(rep.gate(10.0).is_err(), "30% slowdown must fail a 10% gate");
        // faster is never a regression
        let rep = diff(&kernel_file(100.0), &kernel_file(80.0)).unwrap();
        assert!(rep.slowdowns.is_empty());
    }

    #[test]
    fn det_sections_gate_byte_exact_but_wall_jitter_does_not() {
        // wall differs (jitter) but det identical: passes a generous gate
        let rep = diff(&scenario_file(16.0, 1.0), &scenario_file(16.0, 1.4)).unwrap();
        assert!(rep.det_mismatches.is_empty());
        assert_eq!(rep.slowdowns[0].metric, "wall_secs");
        rep.gate(50.0).unwrap();
        // det differs by one count: fails even with infinite tolerance
        let rep = diff(&scenario_file(16.0, 1.0), &scenario_file(17.0, 1.0)).unwrap();
        assert_eq!(rep.det_mismatches, vec!["burst-storm".to_string()]);
        assert!(rep.gate(f64::INFINITY).is_err());
    }

    /// A soak record as `run_soak` emits it: `det` embedding the flight
    /// recorder's deterministic timeline, `wall` carrying `wall_secs`.
    fn soak_file(demotions_gauge: f64, wall_secs: f64) -> Value {
        let timeline = json::obj(vec![
            ("counters", Value::Arr(vec![json::s("serve.served")])),
            (
                "frames",
                Value::Arr(vec![json::obj(vec![
                    ("c", Value::Arr(vec![json::n(7.0)])),
                    ("g", Value::Arr(vec![json::n(demotions_gauge)])),
                    ("tick", json::n(3.0)),
                ])]),
            ),
            ("frames_dropped", json::n(0.0)),
            ("gauges", Value::Arr(vec![json::s("policy.demotions")])),
            (
                "marks",
                Value::Arr(vec![json::obj(vec![
                    ("label", json::s("flip: policy_toggle")),
                    ("tick", json::n(2.0)),
                ])]),
            ),
            ("schema", json::s("otaro.flight.v1")),
        ]);
        json::obj(vec![
            ("schema", json::s("otaro.bench.v1")),
            ("bench", json::s("soak")),
            (
                "records",
                Value::Arr(vec![json::obj(vec![
                    ("name", json::s("soak-storm-flips")),
                    ("det", json::obj(vec![("served", json::n(7.0)), ("timeline", timeline)])),
                    ("wall", json::obj(vec![("wall_secs", json::n(wall_secs))])),
                ])]),
            ),
        ])
    }

    #[test]
    fn soak_records_gate_their_embedded_timeline_byte_exact() {
        // identical timelines, wall jitter only: passes a generous gate
        let rep = diff(&soak_file(2.0, 1.0), &soak_file(2.0, 1.3)).unwrap();
        assert!(rep.det_mismatches.is_empty());
        assert_eq!(rep.slowdowns.len(), 1);
        assert_eq!(rep.slowdowns[0].metric, "wall_secs");
        rep.gate(50.0).unwrap();
        // one gauge value inside one frame differs: det gate trips even
        // with infinite wall tolerance — timeline drift is a behavior
        // change, not noise
        let rep = diff(&soak_file(2.0, 1.0), &soak_file(3.0, 1.0)).unwrap();
        assert_eq!(rep.det_mismatches, vec!["soak-storm-flips".to_string()]);
        assert!(rep.gate(f64::INFINITY).is_err());
    }

    #[test]
    fn missing_records_fail_and_added_records_pass() {
        let empty = json::obj(vec![
            ("schema", json::s("otaro.bench.v1")),
            ("bench", json::s("kernels")),
            ("records", Value::Arr(vec![])),
        ]);
        let rep = diff(&kernel_file(100.0), &empty).unwrap();
        assert_eq!(rep.missing, vec!["matmul".to_string()]);
        assert!(rep.gate(f64::INFINITY).is_err(), "vanished benches must fail the gate");
        let rep = diff(&empty, &kernel_file(100.0)).unwrap();
        assert_eq!(rep.added, vec!["matmul".to_string()]);
        rep.gate(0.0).unwrap();
    }

    #[test]
    fn mismatched_envelopes_are_usage_errors() {
        let wrong_schema = json::obj(vec![
            ("schema", json::s("otaro.bench.v2")),
            ("bench", json::s("kernels")),
            ("records", Value::Arr(vec![])),
        ]);
        assert!(diff(&wrong_schema, &kernel_file(1.0)).is_err());
        let other_bench = json::obj(vec![
            ("schema", json::s("otaro.bench.v1")),
            ("bench", json::s("other")),
            ("records", Value::Arr(vec![])),
        ]);
        assert!(diff(&kernel_file(1.0), &other_bench).is_err());
    }
}
