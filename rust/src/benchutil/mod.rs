//! Mini benchmark harness (the offline vendor set has no criterion).
//!
//! `cargo bench` targets are `harness = false` binaries built on this
//! module: warmup, N timed iterations, median/mean/min reporting, and
//! element-throughput lines — enough to drive the §Perf iteration loop
//! and regenerate the perf rows in EXPERIMENTS.md.
//!
//! [`diff`] compares two emitted `otaro.bench.v1` files across runs —
//! the `otaro bench-diff` trend gate CI runs against the previous
//! artifact.

pub mod diff;

use std::hint::black_box as bb;
use std::path::PathBuf;
use std::time::Instant;

use crate::json::{self, Value};

pub use std::hint::black_box;

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    /// tail latency across timed iterations — a jitter-sensitive
    /// benchmark (locks, allocator, scheduler) shows it here long
    /// before the median moves
    pub p95_ns: f64,
    pub min_ns: f64,
    /// elements per iteration (0 = unset)
    pub elements: u64,
}

impl BenchResult {
    /// One machine-readable record, mirroring the console report line.
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("name", json::s(self.name.clone())),
            ("iters", json::n(self.iters as f64)),
            ("mean_ns", json::n(self.mean_ns)),
            ("median_ns", json::n(self.median_ns)),
            ("p95_ns", json::n(self.p95_ns)),
            ("min_ns", json::n(self.min_ns)),
            ("elements", json::n(self.elements as f64)),
        ])
    }

    pub fn report(&self) {
        let t = fmt_ns(self.median_ns);
        if self.elements > 0 {
            let eps = self.elements as f64 / (self.median_ns * 1e-9);
            println!(
                "{:<44} {:>12}/iter  (p95 {}, min {}, {} iters, {:.1} Melem/s)",
                self.name,
                t,
                fmt_ns(self.p95_ns),
                fmt_ns(self.min_ns),
                self.iters,
                eps / 1e6
            );
        } else {
            println!(
                "{:<44} {:>12}/iter  (p95 {}, min {}, {} iters)",
                self.name,
                t,
                fmt_ns(self.p95_ns),
                fmt_ns(self.min_ns),
                self.iters
            );
        }
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner: targets ~`budget_ms` of total measurement.
pub struct Bench {
    pub warmup_iters: usize,
    pub budget_ms: f64,
    pub max_iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        // max_iters matches the Summary percentile retention window so
        // the reported median/p95 always cover EVERY timed iteration
        Bench {
            warmup_iters: 3,
            budget_ms: 900.0,
            max_iters: crate::metrics::SUMMARY_SAMPLE_CAP,
            results: Vec::new(),
        }
    }
}

/// True when the `OTARO_BENCH_QUICK` env var requests the short CI
/// smoke mode: iteration budgets collapse so a full bench binary runs in
/// seconds while every kernel-regression `assert!` still executes on
/// real (if noisier) medians.
pub fn quick_mode() -> bool {
    std::env::var("OTARO_BENCH_QUICK").is_ok_and(|v| !v.is_empty() && v != "0")
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// `Bench::new()` honoring [`quick_mode`]: CI smoke runs cap warmup
    /// and timed iterations instead of spending the full budget.
    pub fn from_env() -> Self {
        let mut b = Self::default();
        if quick_mode() {
            // enough timed iterations that the asserted-medians stay
            // stable on noisy shared CI runners, while a full bench
            // binary still finishes in seconds
            b.warmup_iters = 1;
            b.budget_ms = 60.0;
            b.max_iters = 20;
        }
        b
    }

    /// Time `f`, auto-scaling iteration count to the budget.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        self.run_n(name, 0, &mut f)
    }

    /// Like `run` but annotates element throughput.
    pub fn run_elems<T>(&mut self, name: &str, elements: u64, mut f: impl FnMut() -> T) -> &BenchResult {
        self.run_n(name, elements, &mut f)
    }

    fn run_n<T>(&mut self, name: &str, elements: u64, f: &mut dyn FnMut() -> T) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            bb(f());
        }
        // estimate per-iter cost
        let t0 = Instant::now();
        bb(f());
        let est_ns = t0.elapsed().as_nanos().max(1) as f64;
        let iters = ((self.budget_ms * 1e6 / est_ns) as usize).clamp(5, self.max_iters);
        // exact percentiles via the shared metrics substrate — median
        // AND tail, not a mean that hides jitter
        let mut samples = crate::metrics::Summary::new();
        for _ in 0..iters {
            let t = Instant::now();
            bb(f());
            samples.push(t.elapsed().as_nanos() as f64);
        }
        let res = BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: samples.mean(),
            median_ns: samples.p50(),
            p95_ns: samples.p95(),
            min_ns: samples.min,
            elements,
        };
        res.report();
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// All results as a JSON array — the `records` payload of a
    /// [`bench_file`].
    pub fn to_records(&self) -> Value {
        Value::Arr(self.results.iter().map(BenchResult::to_json).collect())
    }

    /// Ratio of two named results (a/b, by median) — speedup lines.
    pub fn ratio(&self, a: &str, b: &str) -> Option<f64> {
        let fa = self.results.iter().find(|r| r.name == a)?;
        let fb = self.results.iter().find(|r| r.name == b)?;
        Some(fa.median_ns / fb.median_ns)
    }
}

/// Group header helper for bench binaries.
pub fn group(title: &str) {
    println!("\n== {title} ==");
}

/// Sustained-throughput line for serving benches: `count` events over
/// `secs` of wall clock.
pub fn rate(name: &str, count: u64, secs: f64) {
    let per_sec = if secs > 0.0 { count as f64 / secs } else { 0.0 };
    println!("{name:<44} {per_sec:>12.1}/s  ({count} in {secs:.3} s)");
}

// ---------------------------------------------------------------------------
// machine-readable perf trajectory (BENCH_*.json)
// ---------------------------------------------------------------------------

/// Wrap bench records in the shared `otaro.bench.v1` envelope — the one
/// record shape every `BENCH_*.json` in the repo uses (kernel benches and
/// the `workload` scenario harness alike), so trend tooling parses them
/// uniformly.
pub fn bench_file(bench: &str, records: Value) -> Value {
    json::obj(vec![
        ("schema", json::s("otaro.bench.v1")),
        ("bench", json::s(bench)),
        ("records", records),
    ])
}

/// Output directory requested via the `OTARO_BENCH_JSON` env var
/// (non-empty, not `"0"`).  Unset means console-only: default bench runs
/// never touch the filesystem.
pub fn json_out_dir() -> Option<PathBuf> {
    match std::env::var("OTARO_BENCH_JSON") {
        Ok(v) if !v.is_empty() && v != "0" => Some(PathBuf::from(v)),
        _ => None,
    }
}

/// Serialize `records` into `path` under the [`bench_file`] envelope.
/// Object keys sort on `Display`, so a run is byte-reproducible modulo
/// the timing fields inside the records themselves.
pub fn write_bench_file(path: &std::path::Path, bench: &str, records: Value) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, format!("{}\n", bench_file(bench, records)))?;
    Ok(())
}

/// End-of-binary hook for bench targets: when `OTARO_BENCH_JSON` names a
/// directory, drop `BENCH_<bench>.json` there; otherwise do nothing.  A
/// write failure is reported on stderr but never fails the bench run —
/// the console report already happened.
pub fn maybe_write_json(b: &Bench, bench: &str) {
    let Some(dir) = json_out_dir() else { return };
    let path = dir.join(format!("BENCH_{bench}.json"));
    match write_bench_file(&path, bench, b.to_records()) {
        Ok(()) => println!("bench json: wrote {}", path.display()),
        Err(e) => eprintln!("bench json: failed to write {}: {e}", path.display()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let mut b = Bench { warmup_iters: 1, budget_ms: 5.0, max_iters: 50, results: vec![] };
        b.run("noop", || 1 + 1);
        b.run_elems("vec", 100, || (0..100).sum::<usize>());
        assert_eq!(b.results().len(), 2);
        assert!(b.results()[0].median_ns >= 0.0);
        assert!(b.ratio("vec", "noop").is_some());
        assert!(b.ratio("missing", "noop").is_none());
    }

    #[test]
    fn json_records_roundtrip() {
        let mut b = Bench { warmup_iters: 1, budget_ms: 2.0, max_iters: 10, results: vec![] };
        b.run_elems("elems", 7, || 1 + 1);
        let file = bench_file("unit", b.to_records());
        let text = file.to_string();
        let back = crate::json::parse(&text).unwrap();
        assert_eq!(back.req_str("schema").unwrap(), "otaro.bench.v1");
        assert_eq!(back.req_str("bench").unwrap(), "unit");
        let rec = back.get("records").unwrap().idx(0).unwrap();
        assert_eq!(rec.req_str("name").unwrap(), "elems");
        assert_eq!(rec.get("elements").unwrap().as_f64(), Some(7.0));
        assert!(rec.get("median_ns").unwrap().as_f64().unwrap() >= 0.0);
    }

    #[test]
    fn json_out_dir_honors_env_shape() {
        // can't mutate the process env safely under the parallel test
        // runner; just pin the gating contract on the raw var value
        let gate = |v: &str| !v.is_empty() && v != "0";
        assert!(!gate(""));
        assert!(!gate("0"));
        assert!(gate("target/bench-json"));
    }
}
