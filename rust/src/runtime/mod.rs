//! Runtime layer: PJRT client wrapper (engine), artifact manifest, and
//! the parameter store.  This is the bridge between the AOT-compiled L1/L2
//! stack (`artifacts/*.hlo.txt`) and the L3 coordinator.

pub mod engine;
pub mod manifest;
pub mod params;

pub use engine::{Engine, StepKind, TrainOut};
pub use manifest::{Manifest, Width};
pub use params::{grad_accumulate, grad_l2_norm, ParamStore};
