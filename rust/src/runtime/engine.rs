//! The PJRT engine: loads AOT-compiled HLO artifacts and runs them.
//!
//! One `Engine` owns the PJRT CPU client and a lazy compile cache keyed by
//! (step kind, bit-width).  The hot path is `train_step` / `eval_step` /
//! `logits_step`: upload params + batch as literals, execute, pull the
//! result tuple back.  Python never runs here — the HLO text was produced
//! once by `python/compile/aot.py` (see /opt/xla-example/README.md for the
//! HLO-text-interchange rationale).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use super::manifest::{Manifest, Width};
use super::params::ParamStore;
use crate::data::Batch;

/// Step program kinds exported by aot.py.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StepKind {
    Train,
    Eval,
    Logits,
}

impl StepKind {
    fn name(&self) -> &'static str {
        match self {
            StepKind::Train => "train",
            StepKind::Eval => "eval",
            StepKind::Logits => "logits",
        }
    }
}

pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    artifacts_dir: PathBuf,
    executables: HashMap<(StepKind, Width), xla::PjRtLoadedExecutable>,
    /// cumulative executions per program (metrics)
    pub exec_counts: HashMap<(StepKind, Width), u64>,
}

/// Result of one training step: scalar loss + gradients in manifest order.
pub struct TrainOut {
    pub loss: f32,
    pub grads: Vec<Vec<f32>>,
}

impl Engine {
    pub fn new(artifacts_dir: &Path) -> anyhow::Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
        Ok(Engine {
            client,
            manifest,
            artifacts_dir: artifacts_dir.to_path_buf(),
            executables: HashMap::new(),
            exec_counts: HashMap::new(),
        })
    }

    /// Fresh `ParamStore` from the exported initial parameters.
    pub fn init_params(&self) -> anyhow::Result<ParamStore> {
        ParamStore::from_manifest_bin(&self.manifest, &self.artifacts_dir.join("init_params.bin"))
    }

    /// Compile (or fetch from cache) the program for (kind, width).
    pub fn prepare(&mut self, kind: StepKind, width: Width) -> anyhow::Result<()> {
        if self.executables.contains_key(&(kind, width)) {
            return Ok(());
        }
        let fname = self.manifest.artifact(kind.name(), &width.tag())?.to_string();
        let path = self.artifacts_dir.join(&fname);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse {fname}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow::anyhow!("compile {fname}: {e}"))?;
        self.executables.insert((kind, width), exe);
        Ok(())
    }

    /// Preload every program for the given widths (startup cost, keeps the
    /// training loop jitter-free).
    pub fn preload(&mut self, kinds: &[StepKind], widths: &[Width]) -> anyhow::Result<()> {
        for &k in kinds {
            for &w in widths {
                self.prepare(k, w)?;
            }
        }
        Ok(())
    }

    fn param_literals(&self, params: &ParamStore) -> anyhow::Result<Vec<xla::Literal>> {
        let mut lits = Vec::with_capacity(params.tensors.len() + 2);
        for (t, shape) in params.tensors.iter().zip(&params.shapes) {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            lits.push(
                xla::Literal::vec1(t)
                    .reshape(&dims)
                    .map_err(|e| anyhow::anyhow!("reshape param: {e}"))?,
            );
        }
        Ok(lits)
    }

    fn batch_literal(&self, data: &[i32]) -> anyhow::Result<xla::Literal> {
        let cfg = &self.manifest.config;
        anyhow::ensure!(
            data.len() == cfg.batch_size * cfg.max_seq,
            "batch is {} tokens, engine compiled for {}x{}",
            data.len(),
            cfg.batch_size,
            cfg.max_seq
        );
        xla::Literal::vec1(data)
            .reshape(&[cfg.batch_size as i64, cfg.max_seq as i64])
            .map_err(|e| anyhow::anyhow!("reshape batch: {e}"))
    }

    fn run(
        &mut self,
        kind: StepKind,
        width: Width,
        inputs: &[xla::Literal],
    ) -> anyhow::Result<Vec<xla::Literal>> {
        self.prepare(kind, width)?;
        let exe = &self.executables[&(kind, width)];
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("execute {kind:?}/{width}: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e}"))?;
        *self.exec_counts.entry((kind, width)).or_insert(0) += 1;
        lit.to_tuple().map_err(|e| anyhow::anyhow!("untuple: {e}"))
    }

    /// Forward+backward at `width`: returns loss and gradients.
    pub fn train_step(
        &mut self,
        params: &ParamStore,
        batch: &Batch,
        width: Width,
    ) -> anyhow::Result<TrainOut> {
        let mut inputs = self.param_literals(params)?;
        inputs.push(self.batch_literal(&batch.tokens)?);
        inputs.push(self.batch_literal(&batch.targets)?);
        let out = self.run(StepKind::Train, width, &inputs)?;
        anyhow::ensure!(out.len() == 1 + params.tensors.len(), "train tuple arity");
        let loss = out[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("loss: {e}"))?[0];
        let mut grads = Vec::with_capacity(params.tensors.len());
        for lit in &out[1..] {
            grads.push(lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("grad: {e}"))?);
        }
        Ok(TrainOut { loss, grads })
    }

    /// Loss only (no gradients) at `width`.
    pub fn eval_step(
        &mut self,
        params: &ParamStore,
        batch: &Batch,
        width: Width,
    ) -> anyhow::Result<f32> {
        let mut inputs = self.param_literals(params)?;
        inputs.push(self.batch_literal(&batch.tokens)?);
        inputs.push(self.batch_literal(&batch.targets)?);
        let out = self.run(StepKind::Eval, width, &inputs)?;
        Ok(out[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("loss: {e}"))?[0])
    }

    /// Full logits (B*T*V flat) at `width`.
    pub fn logits_step(
        &mut self,
        params: &ParamStore,
        tokens: &[i32],
        width: Width,
    ) -> anyhow::Result<Vec<f32>> {
        let mut inputs = self.param_literals(params)?;
        inputs.push(self.batch_literal(tokens)?);
        let out = self.run(StepKind::Logits, width, &inputs)?;
        out[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("logits: {e}"))
    }

    /// Wrap the engine for the serving layer's owned-backend API.
    pub fn into_handle(self) -> crate::serve::EngineHandle {
        crate::serve::EngineHandle::new(self)
    }

    pub fn batch_shape(&self) -> (usize, usize) {
        (self.manifest.config.batch_size, self.manifest.config.max_seq)
    }

    pub fn batch_size(&self) -> usize {
        self.manifest.config.batch_size
    }

    pub fn vocab_size(&self) -> usize {
        self.manifest.config.vocab_size
    }

    pub fn compiled_programs(&self) -> usize {
        self.executables.len()
    }
}
