//! Artifact manifest — the contract between `python/compile/aot.py` and
//! the Rust engine.  Parsed from `artifacts/manifest.json` via the
//! in-repo JSON substrate.

use std::collections::HashMap;
use std::path::Path;

use crate::json::{n, obj, s, Value};
use crate::sefp::Precision;

/// Key under [`Manifest::artifacts`] recording the packed single-master
/// `.sefp` container (see `rust/src/artifact/`).
pub const SEFP_MASTER_KEY: &str = "sefp_master";

#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub batch_size: usize,
    pub group_size: usize,
    pub rounding: String,
}

impl ModelConfig {
    /// Parse from a manifest `config` object — shared by the training
    /// manifest and the embedded `.sefp` artifact manifest.
    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        Ok(ModelConfig {
            vocab_size: v.req_usize("vocab_size")?,
            d_model: v.req_usize("d_model")?,
            n_heads: v.req_usize("n_heads")?,
            n_layers: v.req_usize("n_layers")?,
            d_ff: v.req_usize("d_ff")?,
            max_seq: v.req_usize("max_seq")?,
            batch_size: v.req_usize("batch_size")?,
            group_size: v.req_usize("group_size")?,
            rounding: v.req_str("rounding")?,
        })
    }

    /// Serialize back to the same shape `from_json` reads (keys sorted
    /// by the JSON substrate — deterministic, which the `.sefp` golden
    /// bytes rely on).
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("vocab_size", n(self.vocab_size as f64)),
            ("d_model", n(self.d_model as f64)),
            ("n_heads", n(self.n_heads as f64)),
            ("n_layers", n(self.n_layers as f64)),
            ("d_ff", n(self.d_ff as f64)),
            ("max_seq", n(self.max_seq as f64)),
            ("batch_size", n(self.batch_size as f64)),
            ("group_size", n(self.group_size as f64)),
            ("rounding", s(self.rounding.clone())),
        ])
    }
}

#[derive(Debug, Clone)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    /// whether the training graph SEFP-quantizes this tensor (mirrors
    /// model._quant: 2-D weights only, pos_embed excluded)
    pub quantized: bool,
}

impl ParamEntry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub preset: String,
    pub quant_impl: String,
    pub config: ModelConfig,
    pub mantissa_widths: Vec<Precision>,
    pub params: Vec<ParamEntry>,
    pub artifacts: HashMap<String, String>,
    pub init_params_sha256: String,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> anyhow::Result<Self> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot read {path:?} (run `make artifacts`): {e}"))?;
        Self::from_json(&crate::json::parse(&text)?)
    }

    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        let config = ModelConfig::from_json(v.req("config")?)?;
        let mut mantissa_widths = Vec::new();
        for w in v
            .req("mantissa_widths")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("mantissa_widths not an array"))?
        {
            let m = w
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("mantissa width not a number: {w:?}"))?;
            mantissa_widths.push(
                Precision::from_num(m)
                    .map_err(|e| anyhow::anyhow!("manifest mantissa_widths: {e}"))?,
            );
        }
        let mut params = Vec::new();
        for p in v
            .req("params")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("params not an array"))?
        {
            let name = p.req_str("name")?;
            let shape: Vec<usize> = p
                .req("shape")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("shape not an array"))?
                .iter()
                .filter_map(|d| d.as_usize())
                .collect();
            // older manifests lack the flag; fall back to the model rule
            let quantized = p
                .get("quantized")
                .and_then(Value::as_bool)
                .unwrap_or(shape.len() >= 2 && name != "pos_embed");
            params.push(ParamEntry { name, shape, quantized });
        }
        let mut artifacts = HashMap::new();
        for (k, val) in v
            .req("artifacts")?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("artifacts not an object"))?
        {
            artifacts.insert(
                k.clone(),
                val.as_str()
                    .ok_or_else(|| anyhow::anyhow!("artifact path not a string"))?
                    .to_string(),
            );
        }
        Ok(Manifest {
            preset: v.req_str("preset")?,
            quant_impl: v.req_str("quant_impl")?,
            config,
            mantissa_widths,
            params,
            artifacts,
            init_params_sha256: v.req_str("init_params_sha256")?,
        })
    }

    pub fn total_params(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    /// Artifact file name for a step kind and width tag ("fp", "m8".."m3").
    pub fn artifact(&self, kind: &str, tag: &str) -> anyhow::Result<&str> {
        self.artifacts
            .get(&format!("{kind}_{tag}"))
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow::anyhow!("no artifact for {kind}_{tag}"))
    }

    /// Path of the packed single-master `.sefp` container, when the
    /// manifest records one under [`SEFP_MASTER_KEY`] (relative to the
    /// artifacts dir, like every other artifact entry).
    pub fn sefp_artifact(&self) -> Option<&str> {
        self.artifacts.get(SEFP_MASTER_KEY).map(|s| s.as_str())
    }
}

/// Width selector for step programs: `None` = unquantized fp variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Width(pub Option<Precision>);

impl Width {
    pub const FP: Width = Width(None);

    pub fn m(p: Precision) -> Width {
        Width(Some(p))
    }

    pub fn tag(&self) -> String {
        match self.0 {
            None => "fp".to_string(),
            Some(p) => format!("m{}", p.m()),
        }
    }

    /// Paper-style label (E5M4 / FP16-equivalent).
    pub fn label(&self) -> String {
        match self.0 {
            None => "FP".to_string(),
            Some(p) => p.to_string(),
        }
    }
}

impl From<Precision> for Width {
    fn from(p: Precision) -> Width {
        Width(Some(p))
    }
}

impl std::fmt::Display for Width {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn width_tags() {
        assert_eq!(Width::FP.tag(), "fp");
        assert_eq!(Width::m(Precision::of(4)).tag(), "m4");
        assert_eq!(Width::m(Precision::of(4)).label(), "E5M4");
        assert_eq!(Width::from(Precision::of(3)).tag(), "m3");
    }

    #[test]
    fn manifest_rejects_invalid_width() {
        let json = r#"{
            "preset": "tiny", "quant_impl": "pallas",
            "config": {"vocab_size": 320, "d_model": 128, "n_heads": 4,
                       "n_layers": 2, "d_ff": 384, "max_seq": 64,
                       "batch_size": 8, "group_size": 64, "rounding": "trunc"},
            "mantissa_widths": [8,0],
            "params": [],
            "artifacts": {},
            "init_params_sha256": "x"
        }"#;
        let m = Manifest::from_json(&crate::json::parse(json).unwrap());
        assert!(m.is_err(), "width 0 must be rejected at parse time");
    }

    #[test]
    fn manifest_parses() {
        let json = r#"{
            "preset": "tiny", "quant_impl": "pallas",
            "config": {"vocab_size": 320, "d_model": 128, "n_heads": 4,
                       "n_layers": 2, "d_ff": 384, "max_seq": 64,
                       "batch_size": 8, "group_size": 64, "rounding": "trunc"},
            "mantissa_widths": [8,7,6,5,4,3],
            "params": [{"name": "tok_embed", "shape": [320, 128]}],
            "artifacts": {"train_m4": "train_m4.hlo.txt"},
            "init_params_sha256": "x"
        }"#;
        let m = Manifest::from_json(&crate::json::parse(json).unwrap()).unwrap();
        assert_eq!(m.total_params(), 320 * 128);
        assert_eq!(m.artifact("train", "m4").unwrap(), "train_m4.hlo.txt");
        assert!(m.artifact("train", "m9").is_err());
        assert_eq!(m.config.d_model, 128);
    }

    #[test]
    fn model_config_json_roundtrip_and_sefp_key() {
        let cfg = ModelConfig {
            vocab_size: 320,
            d_model: 128,
            n_heads: 4,
            n_layers: 2,
            d_ff: 384,
            max_seq: 64,
            batch_size: 8,
            group_size: 64,
            rounding: "trunc".into(),
        };
        let back =
            ModelConfig::from_json(&crate::json::parse(&cfg.to_json().to_string()).unwrap())
                .unwrap();
        assert_eq!(back.d_model, cfg.d_model);
        assert_eq!(back.rounding, cfg.rounding);

        let json = r#"{
            "preset": "tiny", "quant_impl": "pallas",
            "config": {"vocab_size": 320, "d_model": 128, "n_heads": 4,
                       "n_layers": 2, "d_ff": 384, "max_seq": 64,
                       "batch_size": 8, "group_size": 64, "rounding": "trunc"},
            "mantissa_widths": [8],
            "params": [],
            "artifacts": {"sefp_master": "master.sefp"},
            "init_params_sha256": "x"
        }"#;
        let m = Manifest::from_json(&crate::json::parse(json).unwrap()).unwrap();
        assert_eq!(m.sefp_artifact(), Some("master.sefp"));
    }

    #[test]
    fn manifest_missing_field_errors() {
        let m = Manifest::from_json(&crate::json::parse(r#"{"preset": "x"}"#).unwrap());
        assert!(m.is_err());
    }
}
