//! Parameter store: the f32 master weights the coordinator updates.
//!
//! Plain host-side vectors in manifest order.  Checkpoints are raw f32-LE
//! in manifest order plus a JSON sidecar (same format as
//! `artifacts/init_params.bin`, so the initial checkpoint is loadable
//! directly).

use std::io::Read;
use std::path::Path;

use super::manifest::Manifest;

#[derive(Debug, Clone)]
pub struct ParamStore {
    /// tensor data in manifest order
    pub tensors: Vec<Vec<f32>>,
    pub names: Vec<String>,
    pub shapes: Vec<Vec<usize>>,
    /// which tensors the training graph SEFP-quantizes (from the manifest)
    pub quantized: Vec<bool>,
}

impl ParamStore {
    pub fn from_manifest_bin(manifest: &Manifest, bin_path: &Path) -> anyhow::Result<Self> {
        let mut file = std::fs::File::open(bin_path)
            .map_err(|e| anyhow::anyhow!("cannot open {bin_path:?}: {e}"))?;
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes)?;
        let expect = manifest.total_params() * 4;
        anyhow::ensure!(
            bytes.len() == expect,
            "param file {bin_path:?} is {} bytes, manifest expects {expect}",
            bytes.len()
        );
        let mut tensors = Vec::with_capacity(manifest.params.len());
        let mut off = 0usize;
        for p in &manifest.params {
            let n = p.numel();
            // bulk conversion over 4-byte chunks — the seed parsed
            // element-by-element with a fresh range check per weight,
            // so startup scaled with per-element overhead instead of
            // memory bandwidth (guarded by the load-throughput
            // assertion in benches/bench_artifact.rs)
            let t: Vec<f32> = bytes[off..off + n * 4]
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            off += n * 4;
            tensors.push(t);
        }
        Ok(ParamStore {
            tensors,
            names: manifest.params.iter().map(|p| p.name.clone()).collect(),
            shapes: manifest.params.iter().map(|p| p.shape.clone()).collect(),
            quantized: manifest.params.iter().map(|p| p.quantized).collect(),
        })
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        // single exact-size allocation; the seed grew the buffer
        // element-by-element through Vec doubling
        let total: usize = self.tensors.iter().map(|t| t.len() * 4).sum();
        let mut bytes = Vec::with_capacity(total);
        for t in &self.tensors {
            for v in t {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        }
        std::fs::write(path, &bytes)?;
        Ok(())
    }

    pub fn load_into(&mut self, path: &Path) -> anyhow::Result<()> {
        let bytes = std::fs::read(path)?;
        let expect: usize = self.tensors.iter().map(|t| t.len() * 4).sum();
        anyhow::ensure!(bytes.len() == expect, "checkpoint size mismatch");
        let mut off = 0;
        for t in &mut self.tensors {
            for (v, b) in t.iter_mut().zip(bytes[off..].chunks_exact(4)) {
                *v = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
            }
            off += t.len() * 4;
        }
        Ok(())
    }

    /// SGD update: `w -= lr * g` (the paper's optimizer, §Implementation
    /// Details).  Gradients come in manifest order from the train step.
    pub fn sgd_update(&mut self, grads: &[Vec<f32>], lr: f32) {
        debug_assert_eq!(grads.len(), self.tensors.len());
        for (t, g) in self.tensors.iter_mut().zip(grads) {
            debug_assert_eq!(t.len(), g.len());
            for (w, gv) in t.iter_mut().zip(g) {
                *w -= lr * gv;
            }
        }
    }

    pub fn total_len(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.names.iter().position(|n| n == name)
    }

    /// Global L2 norm (training diagnostics).
    pub fn l2_norm(&self) -> f64 {
        self.tensors
            .iter()
            .flat_map(|t| t.iter())
            .map(|&v| (v as f64) * (v as f64))
            .sum::<f64>()
            .sqrt()
    }
}

/// Gradient utility: flat L2 norm over a grad set.
pub fn grad_l2_norm(grads: &[Vec<f32>]) -> f64 {
    grads
        .iter()
        .flat_map(|g| g.iter())
        .map(|&v| (v as f64) * (v as f64))
        .sum::<f64>()
        .sqrt()
}

/// Accumulate `src` into `dst` (LAA's running sum).
pub fn grad_accumulate(dst: &mut [Vec<f32>], src: &[Vec<f32>]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        for (dv, sv) in d.iter_mut().zip(s) {
            *dv += sv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> ParamStore {
        ParamStore {
            tensors: vec![vec![1.0, 2.0], vec![3.0]],
            names: vec!["a".into(), "b".into()],
            shapes: vec![vec![2], vec![1]],
            quantized: vec![false, false],
        }
    }

    #[test]
    fn sgd_updates() {
        let mut s = store();
        s.sgd_update(&[vec![1.0, 1.0], vec![2.0]], 0.5);
        assert_eq!(s.tensors[0], vec![0.5, 1.5]);
        assert_eq!(s.tensors[1], vec![2.0]);
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("otaro_params_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.bin");
        let s = store();
        s.save(&path).unwrap();
        let mut s2 = store();
        s2.tensors[0][0] = 99.0;
        s2.load_into(&path).unwrap();
        assert_eq!(s2.tensors, s.tensors);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn grad_helpers() {
        let mut a = vec![vec![1.0f32, 2.0]];
        grad_accumulate(&mut a, &[vec![0.5, 0.5]]);
        assert_eq!(a[0], vec![1.5, 2.5]);
        assert!((grad_l2_norm(&[vec![3.0, 4.0]]) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn norm() {
        assert!((store().l2_norm() - (14.0f64).sqrt()).abs() < 1e-9);
    }
}
