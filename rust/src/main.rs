//! `otaro` — CLI launcher for the OTARo reproduction.
//!
//! Lifecycle commands (pretrain/finetune/eval/serve-demo) plus `bench`
//! subcommands that regenerate every table and figure of the paper
//! (DESIGN.md §4 experiment index).  Argument parsing is hand-rolled —
//! the offline vendor set carries no clap.

use std::path::PathBuf;

use otaro::experiments;

const USAGE: &str = "\
otaro — OTARo: Once Tuning for All Precisions (AAAI 2026) reproduction

USAGE: otaro [--artifacts DIR] [--runs DIR] [--seed N] <COMMAND> [ARGS]

COMMANDS:
  info                                  print manifest / artifact info
  pretrain   [--steps N] [--lr X] [--out FILE]
  finetune   [--method M] [--steps N] [--lr X] [--fixed-m K]
             [--dataset tinytext|instruct] [--checkpoint FILE] [--out FILE]
             (methods: none fp fixed uniform bps_only otaro)
  eval       [--checkpoint FILE] [--mc-items N]
  serve-demo [--requests N] [--checkpoint FILE] [--serve-config FILE.json]
             [--backend decoder|engine]
             (decoder = pure-Rust batched SEFP decode engine, default —
             real logits, no PJRT; engine = PJRT AOT artifacts)
  pack       [--checkpoint FILE] [--out FILE] [--top M]
             (f32 checkpoint -> packed .sefp single-master container)
  inspect    FILE.sefp
             (header / tensor index / per-rung footprint report)
  lint       [--src DIR] [--baseline FILE] [--json FILE] [--dead]
             (invariant lint pass: per-file token rules plus crate-wide
             call-graph analyses — transitive panic/alloc reachability,
             determinism taint, otaro.*.vN schema registry; defaults to
             rust/src and rust/lint.baseline. --json writes the
             deterministic otaro.lint.v1 report, --dead lists
             unreferenced pub fns report-only)
  loadgen    [--scenario NAME] [--out FILE]
             (trace-driven load harness: replay the named scenario — or
             the whole catalog — through the real serving stack,
             asserting per-scenario SLO/accounting invariants; writes
             BENCH_serve_scenarios.json unless --out overrides.
             scenarios: steady-mix diurnal-ramp burst-storm
             adversarial-precision)
  trace      [--scenario NAME] [--out FILE] [--dashboard FILE]
             (traced replay: one scenario through the serving stack with
             request-lifecycle tracing ON and the deterministic latency
             injection plan; prints per-request waterfalls and per-rung
             decode histograms, optionally writes the otaro.trace.v1
             snapshot and the otaro.dashboard.v1 spec)
  soak       [--scenario NAME] [--config FILE.json] [--out FILE]
             (long-horizon soak: a catalog scenario stretched ~10x with
             mid-trace config flips — ladder budget re-cap, SLO tighten,
             policy toggle — and latency injection, sampled into a
             flight-recorder timeline whose drift invariants are
             asserted; --config replaces the built-in soak with a JSON
             spec; writes BENCH_soak.json unless --out overrides)
  bench-diff BASELINE.json CANDIDATE.json [--fail-on-regression PCT]
             (compare two otaro.bench.v1 files: det sections must be
             byte-identical, wall medians within PCT; without the flag
             the comparison is report-only)
  bench      <table1|table2|table8|fig3|fig4|fig5|fig6|fig8|fig9|all> [--quick]
";

/// Tiny argument cursor: flags may appear in any order after the command.
struct Args {
    argv: Vec<String>,
}

impl Args {
    fn flag(&mut self, name: &str) -> bool {
        if let Some(i) = self.argv.iter().position(|a| a == name) {
            self.argv.remove(i);
            true
        } else {
            false
        }
    }

    fn opt(&mut self, name: &str) -> Option<String> {
        let i = self.argv.iter().position(|a| a == name)?;
        if i + 1 >= self.argv.len() {
            eprintln!("missing value for {name}");
            std::process::exit(2);
        }
        let v = self.argv.remove(i + 1);
        self.argv.remove(i);
        Some(v)
    }

    fn opt_parse<T: std::str::FromStr>(&mut self, name: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.opt(name) {
            None => default,
            Some(v) => v.parse().unwrap_or_else(|e| {
                eprintln!("bad value for {name}: {e}");
                std::process::exit(2);
            }),
        }
    }

    fn positional(&mut self) -> Option<String> {
        let i = self.argv.iter().position(|a| !a.starts_with('-'))?;
        Some(self.argv.remove(i))
    }

    fn finish(self) {
        if !self.argv.is_empty() {
            eprintln!("unrecognized arguments: {:?}\n\n{USAGE}", self.argv);
            std::process::exit(2);
        }
    }
}

fn main() -> anyhow::Result<()> {
    let mut args = Args { argv: std::env::args().skip(1).collect() };
    if args.flag("--help") || args.flag("-h") {
        println!("{USAGE}");
        return Ok(());
    }
    let ctx = experiments::Ctx {
        artifacts: PathBuf::from(args.opt("--artifacts").unwrap_or_else(|| "artifacts".into())),
        runs: PathBuf::from(args.opt("--runs").unwrap_or_else(|| "runs".into())),
        seed: args.opt_parse("--seed", 0u64),
    };
    let cmd = match args.positional() {
        Some(c) => c,
        None => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    };
    match cmd.as_str() {
        "info" => {
            args.finish();
            experiments::info(&ctx)
        }
        "pretrain" => {
            let steps = args.opt_parse("--steps", 600usize);
            let lr = args.opt_parse("--lr", 3e-2f32);
            let out = args.opt("--out").map(PathBuf::from);
            args.finish();
            experiments::pretrain(&ctx, steps, lr, out)
        }
        "finetune" => {
            let method = args.opt("--method").unwrap_or_else(|| "otaro".into());
            let steps = args.opt_parse("--steps", 300usize);
            let lr = args.opt_parse("--lr", 1e-2f32);
            let fixed_m = args.opt("--fixed-m").map(|v| v.parse().expect("--fixed-m"));
            let dataset = args.opt("--dataset").unwrap_or_else(|| "tinytext".into());
            let checkpoint = args.opt("--checkpoint").map(PathBuf::from);
            let out = args.opt("--out").map(PathBuf::from);
            args.finish();
            experiments::finetune(&ctx, &method, steps, lr, fixed_m, &dataset, checkpoint, out)
        }
        "eval" => {
            let checkpoint = args.opt("--checkpoint").map(PathBuf::from);
            let mc_items = args.opt_parse("--mc-items", 40usize);
            args.finish();
            experiments::eval_checkpoint(&ctx, checkpoint, mc_items)
        }
        "serve-demo" => {
            let requests = args.opt_parse("--requests", 64usize);
            let checkpoint = args.opt("--checkpoint").map(PathBuf::from);
            let serve_config = args.opt("--serve-config").map(PathBuf::from);
            let backend = args.opt("--backend").unwrap_or_else(|| "decoder".into());
            args.finish();
            experiments::serve_demo(&ctx, requests, checkpoint, serve_config, &backend)
        }
        "pack" => {
            let checkpoint = args.opt("--checkpoint").map(PathBuf::from);
            let out = args.opt("--out").map(PathBuf::from);
            let top = args.opt("--top").map(|v| {
                v.parse().unwrap_or_else(|e| {
                    eprintln!("bad value for --top: {e}");
                    std::process::exit(2);
                })
            });
            args.finish();
            experiments::pack_artifact(&ctx, checkpoint, out, top)
        }
        "inspect" => {
            let file = args.positional().unwrap_or_else(|| {
                eprintln!("inspect requires a .sefp file\n\n{USAGE}");
                std::process::exit(2);
            });
            args.finish();
            experiments::inspect_artifact(std::path::Path::new(&file))
        }
        "lint" => {
            let src = args.opt("--src").map(PathBuf::from);
            let baseline = args.opt("--baseline").map(PathBuf::from);
            let json_out = args.opt("--json").map(PathBuf::from);
            let dead = args.flag("--dead");
            args.finish();
            otaro::lint::run_cli(src, baseline, json_out, dead)
        }
        "loadgen" => {
            let scenario = args.opt("--scenario");
            let out = args.opt("--out").map(PathBuf::from);
            args.finish();
            otaro::workload::run_cli(scenario, out)
        }
        "trace" => {
            let scenario = args.opt("--scenario");
            let out = args.opt("--out").map(PathBuf::from);
            let dashboard = args.opt("--dashboard").map(PathBuf::from);
            args.finish();
            otaro::workload::trace_cli(scenario, out, dashboard)
        }
        "soak" => {
            let scenario = args.opt("--scenario");
            let config = args.opt("--config").map(PathBuf::from);
            let out = args.opt("--out").map(PathBuf::from);
            args.finish();
            otaro::workload::soak_cli(scenario, config, out)
        }
        "bench-diff" => {
            let fail_pct = args.opt("--fail-on-regression").map(|v| {
                v.parse::<f64>().unwrap_or_else(|e| {
                    eprintln!("bad value for --fail-on-regression: {e}");
                    std::process::exit(2);
                })
            });
            let (baseline, candidate) = match (args.positional(), args.positional()) {
                (Some(a), Some(b)) => (PathBuf::from(a), PathBuf::from(b)),
                _ => {
                    eprintln!("bench-diff requires BASELINE and CANDIDATE files\n\n{USAGE}");
                    std::process::exit(2);
                }
            };
            args.finish();
            otaro::benchutil::diff::run_cli(baseline, candidate, fail_pct)
        }
        "bench" => {
            let quick = args.flag("--quick");
            let target = args.positional().unwrap_or_else(|| {
                eprintln!("bench requires a target\n\n{USAGE}");
                std::process::exit(2);
            });
            args.finish();
            experiments::bench(&ctx, &target, quick)
        }
        other => {
            eprintln!("unknown command {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
    }
}
