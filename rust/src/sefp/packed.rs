//! `PackedSefp` — the bit-packed wire/storage format.
//!
//! Layout (little-endian bitstream, LSB-first within each byte):
//!   * per group: 5-bit shared exponent (E - EXP_MIN, unsigned)
//!   * per element: 1 sign bit + m magnitude bits
//!
//! This is what "69% memory reduction" (paper table 2) is measured
//! against: `packed_bytes()` is the exact storage footprint.  Truncation
//! to a lower precision re-packs by dropping low magnitude bits — the
//! stream for E5M4 is a strict bit-subset transform of the E5M8 stream,
//! which is the hardware-friendliness claim of SEFP.

use super::{Precision, SefpCodec, SefpSpec, SefpTensor, EXP_MIN};

#[derive(Debug, Clone, PartialEq)]
pub struct PackedSefp {
    pub precision: Precision,
    pub group_size: usize,
    pub len: usize,
    pub n_groups: usize,
    pub bits: BitVec,
}

/// Minimal LSB-first bit vector (no external deps).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct BitVec {
    pub data: Vec<u8>,
    pub len_bits: usize,
}

impl BitVec {
    pub fn with_capacity(bits: usize) -> Self {
        BitVec { data: Vec::with_capacity(bits.div_ceil(8)), len_bits: 0 }
    }

    #[inline]
    pub fn push_bits(&mut self, value: u32, n: u8) {
        debug_assert!(n <= 32);
        debug_assert!(n == 32 || value < (1u32 << n));
        let mut v = value as u64;
        let mut remaining = n as usize;
        while remaining > 0 {
            let byte_idx = self.len_bits / 8;
            let bit_idx = self.len_bits % 8;
            if byte_idx == self.data.len() {
                self.data.push(0);
            }
            let take = (8 - bit_idx).min(remaining);
            self.data[byte_idx] |= ((v & ((1u64 << take) - 1)) as u8) << bit_idx;
            v >>= take;
            self.len_bits += take;
            remaining -= take;
        }
    }

    #[inline]
    pub fn read_bits(&self, pos: usize, n: u8) -> u32 {
        debug_assert!(
            pos + n as usize <= self.len_bits,
            "bit read [{pos}, {pos}+{n}) past stream end {}",
            self.len_bits
        );
        Self::read_bits_in(&self.data, pos, n)
    }

    /// Read `n` bits LSB-first at bit offset `pos` from a raw byte
    /// slice — the borrowed-buffer twin of [`read_bits`](Self::read_bits),
    /// shared with the zero-copy artifact views (`rust/src/artifact/`).
    /// Reading zero bits is always valid and returns 0; a read past the
    /// end of `data` is a caller bug (debug-asserted with a clear
    /// message instead of an opaque index panic).
    #[inline]
    pub fn read_bits_in(data: &[u8], pos: usize, n: u8) -> u32 {
        debug_assert!(n <= 32);
        debug_assert!(
            pos + n as usize <= data.len() * 8,
            "bit read [{pos}, {pos}+{n}) past slice end {}",
            data.len() * 8
        );
        let mut out: u64 = 0;
        let mut got = 0usize;
        let mut p = pos;
        while got < n as usize {
            let byte_idx = p / 8;
            let bit_idx = p % 8;
            let take = (8 - bit_idx).min(n as usize - got);
            let bits = (data[byte_idx] >> bit_idx) as u64 & ((1u64 << take) - 1);
            out |= bits << got;
            got += take;
            p += take;
        }
        out as u32
    }
}

impl PackedSefp {
    /// Pack a working tensor into the bitstream.
    pub fn from_tensor(t: &SefpTensor) -> Self {
        let m = t.precision.m();
        let mut bits = BitVec::with_capacity(t.ideal_bits());
        for (gi, g) in t.significands.chunks(t.group_size).enumerate() {
            let e = (t.exponents[gi] as i32 - EXP_MIN) as u32;
            debug_assert!(e < 32);
            bits.push_bits(e, 5);
            for &s in g {
                let sign = (s < 0) as u32;
                let mag = s.unsigned_abs() as u32;
                bits.push_bits(sign, 1);
                bits.push_bits(mag, m);
            }
        }
        PackedSefp {
            precision: t.precision,
            group_size: t.group_size,
            len: t.len,
            n_groups: t.n_groups(),
            bits,
        }
    }

    /// Encode straight from f32 data under `spec`.
    pub fn encode(w: &[f32], spec: &SefpSpec) -> Self {
        Self::from_tensor(&SefpTensor::encode(w, spec))
    }

    /// Unpack back to the working representation (bit-exact round trip).
    pub fn to_tensor(&self) -> SefpTensor {
        let m = self.precision.m();
        let mut exponents = Vec::with_capacity(self.n_groups);
        let mut significands = Vec::with_capacity(self.len);
        let mut pos = 0usize;
        let mut remaining = self.len;
        for _ in 0..self.n_groups {
            let e = self.bits.read_bits(pos, 5) as i32 + EXP_MIN;
            pos += 5;
            exponents.push(e as i8);
            let in_group = remaining.min(self.group_size);
            for _ in 0..in_group {
                let sign = self.bits.read_bits(pos, 1);
                pos += 1;
                let mag = self.bits.read_bits(pos, m) as i16;
                pos += m as usize;
                significands.push(if sign == 1 { -mag } else { mag });
            }
            remaining -= in_group;
        }
        SefpTensor {
            precision: self.precision,
            group_size: self.group_size,
            len: self.len,
            exponents,
            significands,
        }
    }

    /// Truncate the packed stream to a lower precision — the on-device
    /// precision switch: a single linear re-pack that drops the low
    /// `m - p.m()` bits of every magnitude (no float math at all).
    pub fn truncate(&self, p: Precision) -> Self {
        assert!(p <= self.precision, "can only truncate to a lower precision");
        let m = self.precision.m();
        let shift = m - p.m();
        let mut bits =
            BitVec::with_capacity(self.len * p.bits_per_elem() + self.n_groups * 5);
        let mut pos = 0usize;
        let mut remaining = self.len;
        for _ in 0..self.n_groups {
            bits.push_bits(self.bits.read_bits(pos, 5), 5);
            pos += 5;
            let in_group = remaining.min(self.group_size);
            for _ in 0..in_group {
                let sign = self.bits.read_bits(pos, 1);
                pos += 1;
                let mag = self.bits.read_bits(pos, m);
                pos += m as usize;
                bits.push_bits(sign, 1);
                bits.push_bits(mag >> shift, p.m());
            }
            remaining -= in_group;
        }
        PackedSefp {
            precision: p,
            group_size: self.group_size,
            len: self.len,
            n_groups: self.n_groups,
            bits,
        }
    }

    /// Exact storage footprint in bytes.
    pub fn packed_bytes(&self) -> usize {
        self.bits.data.len()
    }

    /// Footprint of the same tensor in fp16 (the paper's baseline format).
    pub fn fp16_bytes(&self) -> usize {
        self.len * 2
    }

    /// Paper table 2's reduction ratio vs FP16 (0.0 for an empty
    /// tensor, where the ratio is undefined rather than NaN).
    pub fn reduction_vs_fp16(&self) -> f64 {
        if self.len == 0 {
            return 0.0;
        }
        1.0 - self.packed_bytes() as f64 / self.fp16_bytes() as f64
    }
}

impl SefpCodec for PackedSefp {
    fn encode(w: &[f32], spec: &SefpSpec) -> Self {
        PackedSefp::encode(w, spec)
    }

    fn decode(&self) -> Vec<f32> {
        self.to_tensor().decode()
    }

    fn truncate(&self, p: Precision) -> Self {
        PackedSefp::truncate(self, p)
    }

    fn precision(&self) -> Precision {
        self.precision
    }

    fn group_size(&self) -> usize {
        self.group_size
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sefp::GROUP_SIZE;

    fn test_weights(n: usize, seed: u64) -> Vec<f32> {
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        (0..n)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                ((s as i32) as f32) / (i32::MAX as f32) * 2.0
            })
            .collect()
    }

    #[test]
    fn bitvec_roundtrip() {
        let mut bv = BitVec::default();
        let vals = [(5u32, 3u8), (0, 1), (255, 8), (1, 1), (31, 5), (1023, 10)];
        for (v, n) in vals {
            bv.push_bits(v, n);
        }
        let mut pos = 0;
        for (v, n) in vals {
            assert_eq!(bv.read_bits(pos, n), v);
            pos += n as usize;
        }
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let w = test_weights(500, 2);
        for p in Precision::LADDER {
            let t = SefpTensor::encode(&w, &SefpSpec::new(p));
            let packed = PackedSefp::from_tensor(&t);
            assert_eq!(packed.to_tensor(), t, "{p}");
        }
    }

    #[test]
    fn packed_truncate_matches_tensor_truncate() {
        let w = test_weights(640, 4);
        let p8 = PackedSefp::encode(&w, &SefpSpec::new(Precision::of(8)));
        for m in [7u8, 5, 3] {
            let lo = Precision::of(m);
            let a = p8.truncate(lo).to_tensor();
            let b = p8.to_tensor().truncate(lo);
            assert_eq!(a, b, "{lo}");
        }
    }

    #[test]
    fn packed_size_is_ideal() {
        let w = test_weights(4096, 6);
        for p in Precision::LADDER {
            let t = SefpTensor::encode(&w, &SefpSpec::new(p));
            let packed = PackedSefp::from_tensor(&t);
            assert_eq!(packed.packed_bytes(), t.ideal_bits().div_ceil(8));
        }
    }

    #[test]
    fn zero_length_tensor_roundtrips() {
        // the degenerate container cases: no elements means no groups,
        // no bits, and a 0-byte stream — encode/decode/truncate must all
        // be total on it (exercised again through the artifact format in
        // rust/tests/artifact_props.rs)
        for p in [Precision::of(8), Precision::of(3)] {
            let packed = PackedSefp::encode(&[], &SefpSpec::new(p));
            assert_eq!(packed.len, 0);
            assert_eq!(packed.n_groups, 0);
            assert_eq!(packed.packed_bytes(), 0);
            assert_eq!(packed.reduction_vs_fp16(), 0.0);
            let t = packed.to_tensor();
            assert_eq!(t.len, 0);
            assert!(t.decode().is_empty());
            let lo = packed.truncate(Precision::of(1));
            assert_eq!(lo.packed_bytes(), 0);
            assert_eq!(lo.to_tensor().decode(), Vec::<f32>::new());
        }
    }

    #[test]
    fn partial_final_group_roundtrips() {
        // lengths straddling the group boundary: the final short group
        // must pack, unpack, and truncate identically to the working
        // representation
        for n in [1usize, 63, 64, 65, 100, 129] {
            let w = test_weights(n, n as u64);
            let p8 = PackedSefp::encode(&w, &SefpSpec::new(Precision::of(8)));
            assert_eq!(p8.len, n);
            assert_eq!(p8.n_groups, n.div_ceil(GROUP_SIZE));
            let t = p8.to_tensor();
            assert_eq!(t.decode().len(), n);
            assert_eq!(
                p8.truncate(Precision::of(3)).to_tensor(),
                t.truncate(Precision::of(3)),
                "n={n}"
            );
        }
    }

    #[test]
    fn read_bits_in_matches_owned_reader() {
        let mut bv = BitVec::default();
        for (v, n) in [(14u32, 5u8), (1, 1), (175, 8), (0, 3), (12345, 14)] {
            bv.push_bits(v, n);
        }
        let mut pos = 0;
        for (v, n) in [(14u32, 5u8), (1, 1), (175, 8), (0, 3), (12345, 14)] {
            assert_eq!(BitVec::read_bits_in(&bv.data, pos, n), v);
            assert_eq!(bv.read_bits(pos, n), v);
            pos += n as usize;
        }
        // zero-width reads are total, even at the very end of the stream
        assert_eq!(bv.read_bits(pos, 0), 0);
        assert_eq!(BitVec::read_bits_in(&[], 0, 0), 0);
    }

    #[test]
    fn e5m4_memory_reduction_matches_paper() {
        // FP16 -> E5M4: (1+4+5/64)/16 = 0.3174 -> 68.3% reduction; the
        // paper reports 69% (incl. KV-cache effects). Assert the format
        // side lands in the right band.
        let w = test_weights(1 << 16, 8);
        let p = PackedSefp::encode(&w, &SefpSpec::new(Precision::of(4)));
        let red = p.reduction_vs_fp16();
        assert!((0.67..0.70).contains(&red), "reduction={red}");
        assert_eq!(p.group_size, GROUP_SIZE);
    }
}
