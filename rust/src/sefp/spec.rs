//! First-class precision types: [`Precision`], [`SefpSpec`], [`SefpCodec`].
//!
//! The rest of the crate used to thread precision around as a bare
//! `m: u8` plus positional `(m, group_size, rounding)` tuples; an invalid
//! width was only caught by an assert deep inside `encode`.  `Precision`
//! is a validated newtype over the mantissa width (constructible only in
//! `1..=14`), ordered so that *more mantissa bits compares greater*, and
//! displayed in the paper's `E5M{m}` notation.  `SefpSpec` bundles the
//! full codec configuration; every encode/quantize entry point takes a
//! `&SefpSpec` instead of loose scalars.
//!
//! [`SefpCodec`] unifies encode/decode/truncate across the working
//! ([`SefpTensor`](crate::sefp::SefpTensor)) and packed
//! ([`PackedSefp`](crate::sefp::PackedSefp)) representations, with the
//! ladder-exactness contract in its docs (and property-tested in
//! `rust/tests/sefp_props.rs`).

use super::Rounding;

/// Error for an out-of-range mantissa width.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PrecisionError(pub u8);

impl std::fmt::Display for PrecisionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mantissa width {} out of range {}..={}",
            self.0,
            Precision::MIN.m(),
            Precision::MAX.m()
        )
    }
}

impl std::error::Error for PrecisionError {}

/// A validated SEFP mantissa width (the `m` of `E5Mm`).
///
/// Invariant: `1 <= m <= 14` (the i16 significand store caps at 14
/// magnitude bits + sign).  Ordering follows the mantissa width, so
/// `Precision::of(8) > Precision::of(3)` — more bits = higher precision —
/// and `BTreeMap<Precision, _>` iterates lowest width first.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Precision(u8);

impl Precision {
    /// Lowest representable width (E5M1).
    pub const MIN: Precision = Precision(1);
    /// Highest representable width (E5M14, i16 significand bound).
    pub const MAX: Precision = Precision(14);

    /// The paper's precision ladder (table 1): E5Mm, m ∈ {8..3},
    /// highest first.
    pub const LADDER: [Precision; 6] = [
        Precision(8),
        Precision(7),
        Precision(6),
        Precision(5),
        Precision(4),
        Precision(3),
    ];

    /// Validated constructor — the only way to build a `Precision` from
    /// untrusted input (config files, CLI flags, manifests).
    pub fn new(m: u8) -> Result<Self, PrecisionError> {
        if (Self::MIN.0..=Self::MAX.0).contains(&m) {
            Ok(Precision(m))
        } else {
            Err(PrecisionError(m))
        }
    }

    /// Infallible constructor for compile-time-known widths; panics on an
    /// invalid width (usable in `const` position, where the panic becomes
    /// a compile error).
    #[allow(clippy::manual_range_contains)] // RangeInclusive::contains is not const
    pub const fn of(m: u8) -> Self {
        assert!(m >= 1 && m <= 14, "mantissa width out of range 1..=14");
        Precision(m)
    }

    /// The mantissa width `m`.
    pub const fn m(self) -> u8 {
        self.0
    }

    /// Parse a JSON-style number, rejecting fractional and out-of-range
    /// values instead of silently truncating (`7.5 as u8` would quietly
    /// become E5M7) — the shared path for config and manifest parsing.
    pub fn from_num(x: f64) -> Result<Self, String> {
        if x.fract() != 0.0 || !(0.0..=255.0).contains(&x) {
            return Err(format!("mantissa width {x} is not a small integer"));
        }
        Precision::new(x as u8).map_err(|e| e.to_string())
    }

    /// Packed bits per element: 1 sign bit + `m` magnitude bits (the
    /// 5-bit shared exponent is amortized per group).
    pub const fn bits_per_elem(self) -> usize {
        1 + self.0 as usize
    }

    /// Canonicalize a precision ladder in place: sorted highest
    /// precision first, duplicates dropped.  THE ladder normal form —
    /// config parsing, the serve router, and the policy controller all
    /// share it, so "highest first, deduped" is defined exactly once.
    pub fn canonicalize_ladder(ladder: &mut Vec<Precision>) {
        ladder.sort_unstable_by(|a, b| b.cmp(a));
        ladder.dedup();
    }

    /// Snap `p` onto a canonicalized (highest-first) non-empty ladder:
    /// above the top rung snaps down to it, below the bottom snaps up,
    /// and a width strictly inside the range that is not a rung snaps
    /// to the next rung up (quality-preserving).  The single source of
    /// the snap rule shared by router clamping and controller
    /// initialization.
    pub fn snap_to_ladder(ladder: &[Precision], p: Precision) -> Precision {
        assert!(!ladder.is_empty(), "ladder must be non-empty");
        let top = ladder[0];
        let bottom = ladder[ladder.len() - 1];
        if p > top {
            top
        } else if p < bottom {
            bottom
        } else {
            // `top >= p` here, so the scan always finds a rung; the
            // fallback is unreachable but keeps this panic-free (the
            // controller calls this on the live request path)
            ladder.iter().rev().copied().find(|&w| w >= p).unwrap_or(top)
        }
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "E5M{}", self.0)
    }
}

impl std::fmt::Debug for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "E5M{}", self.0)
    }
}

impl From<Precision> for u8 {
    fn from(p: Precision) -> u8 {
        p.0
    }
}

impl TryFrom<u8> for Precision {
    type Error = PrecisionError;
    fn try_from(m: u8) -> Result<Self, PrecisionError> {
        Precision::new(m)
    }
}

impl std::str::FromStr for Precision {
    type Err = String;

    /// Accepts `"4"`, `"m4"`, and `"E5M4"` (prefix matched ASCII
    /// case-insensitively, so `"E5m4"` works too).
    fn from_str(s: &str) -> Result<Self, String> {
        let digits = match s.get(..3) {
            Some(p3) if p3.eq_ignore_ascii_case("e5m") => &s[3..],
            _ => match s.get(..1) {
                Some(p1) if p1.eq_ignore_ascii_case("m") => &s[1..],
                _ => s,
            },
        };
        let m: u8 = digits
            .parse()
            .map_err(|_| format!("cannot parse precision {s:?} (want 4 / m4 / E5M4)"))?;
        Precision::new(m).map_err(|e| e.to_string())
    }
}

/// Full SEFP codec configuration: precision + grouping + rounding.
///
/// Builder-style: `SefpSpec::new(Precision::of(8))` gives the repo
/// defaults (group size 64, round-toward-zero); `.with_group_size(..)` /
/// `.with_rounding(..)` override.  `.at(p)` re-targets the same grouping
/// and rounding to another rung of the ladder.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SefpSpec {
    pub precision: Precision,
    pub group_size: usize,
    pub rounding: Rounding,
}

impl SefpSpec {
    /// Paper defaults at `precision`: group size 64, `Rounding::Trunc`.
    pub fn new(precision: Precision) -> Self {
        SefpSpec { precision, group_size: super::GROUP_SIZE, rounding: Rounding::Trunc }
    }

    pub fn with_group_size(mut self, group_size: usize) -> Self {
        assert!(group_size >= 1, "group size must be positive");
        self.group_size = group_size;
        self
    }

    pub fn with_rounding(mut self, rounding: Rounding) -> Self {
        self.rounding = rounding;
        self
    }

    /// The same spec re-targeted at another precision.
    pub fn at(&self, precision: Precision) -> Self {
        SefpSpec { precision, ..*self }
    }
}

/// The unified SEFP codec interface over the working and packed
/// representations.
///
/// # Ladder-exactness contract
///
/// For every implementor, every weight slice `w`, every spec with
/// `Rounding::Trunc`, and every `lo <= spec.precision`:
///
/// ```text
/// Self::encode(w, spec).truncate(lo)  ==  Self::encode(w, &spec.at(lo))
/// ```
///
/// i.e. dropping low mantissa bits of a higher-precision encoding is
/// *bit-for-bit identical* to encoding the original weights at the lower
/// precision — the property (paper fig. 1) that lets ONE stored master
/// serve the whole ladder.  `truncate` must be pure integer work (shifts
/// on significands / bitstream re-pack), never a float round trip.
/// Property-tested for both implementors over the full {8..3} ladder in
/// `rust/tests/sefp_props.rs`.
pub trait SefpCodec: Sized {
    /// Quantize an f32 slice under `spec`.
    fn encode(w: &[f32], spec: &SefpSpec) -> Self;

    /// Dequantize back to f32 (`sign * s * 2^(E - m + 1)`).
    fn decode(&self) -> Vec<f32>;

    /// Derive a lower-precision encoding by dropping low mantissa bits —
    /// the on-device precision switch.  Panics if `p` exceeds the
    /// current precision (bits cannot be invented).
    fn truncate(&self, p: Precision) -> Self;

    /// The precision this encoding currently holds.
    fn precision(&self) -> Precision;

    /// The group size this encoding was produced with.
    fn group_size(&self) -> usize;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_validates_range() {
        assert!(Precision::new(0).is_err());
        assert!(Precision::new(15).is_err());
        for m in 1..=14u8 {
            assert_eq!(Precision::new(m).unwrap().m(), m);
        }
    }

    #[test]
    fn ordering_follows_width() {
        assert!(Precision::of(8) > Precision::of(3));
        assert!(Precision::of(3) < Precision::of(4));
        let mut l = Precision::LADDER.to_vec();
        l.sort();
        assert_eq!(l.first(), Some(&Precision::of(3)));
        assert_eq!(l.last(), Some(&Precision::of(8)));
    }

    #[test]
    fn display_and_parse() {
        let p = Precision::of(4);
        assert_eq!(p.to_string(), "E5M4");
        assert_eq!(format!("{p:?}"), "E5M4");
        for s in ["4", "m4", "M4", "E5M4", "e5m4", "E5m4", "e5M4"] {
            assert_eq!(s.parse::<Precision>().unwrap(), p, "{s}");
        }
        assert!("0".parse::<Precision>().is_err());
        assert!("wat".parse::<Precision>().is_err());
    }

    #[test]
    fn spec_builder() {
        let spec = SefpSpec::new(Precision::of(8));
        assert_eq!(spec.group_size, crate::sefp::GROUP_SIZE);
        assert_eq!(spec.rounding, Rounding::Trunc);
        let spec = spec.with_group_size(32).with_rounding(Rounding::Nearest);
        assert_eq!(spec.group_size, 32);
        assert_eq!(spec.rounding, Rounding::Nearest);
        let lo = spec.at(Precision::of(3));
        assert_eq!(lo.precision, Precision::of(3));
        assert_eq!(lo.group_size, 32);
        assert_eq!(lo.rounding, Rounding::Nearest);
    }

    #[test]
    fn from_num_rejects_fractional_and_out_of_range() {
        assert_eq!(Precision::from_num(4.0).unwrap(), Precision::of(4));
        assert!(Precision::from_num(7.5).is_err(), "no silent truncation");
        assert!(Precision::from_num(0.0).is_err());
        assert!(Precision::from_num(-1.0).is_err());
        assert!(Precision::from_num(1e9).is_err());
        assert!(Precision::from_num(f64::NAN).is_err());
    }

    #[test]
    fn bits_per_elem() {
        assert_eq!(Precision::of(4).bits_per_elem(), 5);
        assert_eq!(Precision::of(8).bits_per_elem(), 9);
    }

    #[test]
    fn ladder_canonicalize_and_snap() {
        let mut l = vec![Precision::of(3), Precision::of(8), Precision::of(3), Precision::of(6)];
        Precision::canonicalize_ladder(&mut l);
        assert_eq!(l, vec![Precision::of(8), Precision::of(6), Precision::of(3)]);
        // exact rung passes through
        assert_eq!(Precision::snap_to_ladder(&l, Precision::of(6)), Precision::of(6));
        // between rungs: next rung up
        assert_eq!(Precision::snap_to_ladder(&l, Precision::of(4)), Precision::of(6));
        assert_eq!(Precision::snap_to_ladder(&l, Precision::of(7)), Precision::of(8));
        // outside the range: clamped to the bounds
        assert_eq!(Precision::snap_to_ladder(&l, Precision::of(1)), Precision::of(3));
        assert_eq!(Precision::snap_to_ladder(&l, Precision::of(14)), Precision::of(8));
    }
}
