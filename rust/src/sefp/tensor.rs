//! `SefpTensor` — the working (unpacked) SEFP representation.
//!
//! Sign-magnitude significands are stored one-per-`i16` with a per-group
//! `i8` shared exponent.  This is the fast in-memory form used by the
//! serving stack and the pure-rust inference kernel; `PackedSefp` is the
//! bit-exact on-"disk"/on-device form used for the memory accounting of
//! table 2.

use super::{
    quantize_value, shared_exponent, step_for, Precision, SefpCodec, SefpSpec, EXP_MIN,
};

/// One quantized tensor: per-group shared exponents + per-element signed
/// significands.  `significands[i]` is the signed significand
/// (`|sig| < 2^m`).
#[derive(Debug, Clone, PartialEq)]
pub struct SefpTensor {
    pub precision: Precision,
    pub group_size: usize,
    /// logical element count (the final group may be short)
    pub len: usize,
    /// per-group shared exponent E
    pub exponents: Vec<i8>,
    /// signed significand per element, |sig| <= 2^m - 1
    pub significands: Vec<i16>,
}

impl SefpTensor {
    /// Encode an f32 slice under `spec` (paper fig. 2: shared exponent
    /// selection, mantissa alignment, truncation).
    pub fn encode(w: &[f32], spec: &SefpSpec) -> Self {
        // SefpSpec's fields are pub for ergonomic reads; a hand-built
        // spec can bypass `with_group_size`'s check, so fail loudly here
        // instead of div_ceil-by-zero below
        assert!(spec.group_size >= 1, "SefpSpec group_size must be positive");
        let m = spec.precision.m();
        let n_groups = w.len().div_ceil(spec.group_size);
        let mut exponents = Vec::with_capacity(n_groups);
        let mut significands = Vec::with_capacity(w.len());
        for g in w.chunks(spec.group_size) {
            let maxabs = g.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
            let e = if maxabs > 0.0 { shared_exponent(maxabs) } else { EXP_MIN };
            let step = step_for(e, m);
            exponents.push(e as i8);
            for &x in g {
                significands.push(quantize_value(x, step, m, spec.rounding) as i16);
            }
        }
        SefpTensor {
            precision: spec.precision,
            group_size: spec.group_size,
            len: w.len(),
            exponents,
            significands,
        }
    }

    /// Dequantize to f32 (`sign * s * 2^(E - m + 1)`).
    pub fn decode(&self) -> Vec<f32> {
        let m = self.precision.m();
        let mut out = Vec::with_capacity(self.len);
        for (gi, g) in self.significands.chunks(self.group_size).enumerate() {
            let step = step_for(self.exponents[gi] as i32, m);
            for &s in g {
                out.push(s as f32 * step);
            }
        }
        out
    }

    /// THE precision-switch operation (paper fig. 1, red arrows): drop
    /// `self.precision.m() - p.m()` low mantissa bits in place.  O(n)
    /// integer shifts,
    /// no float math, no re-inspection of the weights; exactly equal to
    /// re-encoding the original weights at `p` under `Rounding::Trunc`
    /// (the `SefpCodec` ladder-exactness contract).
    pub fn truncate(&self, p: Precision) -> Self {
        assert!(p <= self.precision, "can only truncate to a lower precision");
        let shift = self.precision.m() - p.m();
        let significands = self
            .significands
            .iter()
            // sign-magnitude shift == round-toward-zero on the value
            .map(|&s| if s >= 0 { s >> shift } else { -((-s) >> shift) })
            .collect();
        SefpTensor {
            precision: p,
            group_size: self.group_size,
            len: self.len,
            exponents: self.exponents.clone(),
            significands,
        }
    }

    /// Working-representation memory in bytes (i16 significands + i8
    /// exponents).  See `PackedSefp::packed_bytes` for the wire format.
    pub fn working_bytes(&self) -> usize {
        self.significands.len() * 2 + self.exponents.len()
    }

    /// Ideal packed size in bits: (1 + m) per element + 5 per group.
    pub fn ideal_bits(&self) -> usize {
        self.len * self.precision.bits_per_elem() + self.exponents.len() * 5
    }

    pub fn n_groups(&self) -> usize {
        self.exponents.len()
    }
}

impl SefpCodec for SefpTensor {
    fn encode(w: &[f32], spec: &SefpSpec) -> Self {
        SefpTensor::encode(w, spec)
    }

    fn decode(&self) -> Vec<f32> {
        SefpTensor::decode(self)
    }

    fn truncate(&self, p: Precision) -> Self {
        SefpTensor::truncate(self, p)
    }

    fn precision(&self) -> Precision {
        self.precision
    }

    fn group_size(&self) -> usize {
        self.group_size
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sefp::{quant_dequant, Rounding};

    fn test_weights(n: usize, seed: u64) -> Vec<f32> {
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        (0..n)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                ((s as i32) as f32) / (i32::MAX as f32)
            })
            .collect()
    }

    #[test]
    fn encode_decode_matches_quant_dequant() {
        let w = test_weights(300, 7);
        for p in Precision::LADDER {
            for r in [Rounding::Trunc, Rounding::Nearest] {
                let spec = SefpSpec::new(p).with_rounding(r);
                let t = SefpTensor::encode(&w, &spec);
                assert_eq!(t.decode(), quant_dequant(&w, &spec));
            }
        }
    }

    #[test]
    fn truncate_equals_direct_encode() {
        let w = test_weights(640, 3);
        let spec = SefpSpec::new(Precision::of(8));
        let hi = SefpTensor::encode(&w, &spec);
        for p in &Precision::LADDER[1..] {
            let direct = SefpTensor::encode(&w, &spec.at(*p));
            let chained = hi.truncate(*p);
            assert_eq!(direct.significands, chained.significands, "{p}");
            assert_eq!(direct.exponents, chained.exponents);
            assert_eq!(direct.decode(), chained.decode());
        }
    }

    #[test]
    fn truncate_chain_associative() {
        // M8 -> M6 -> M3 == M8 -> M3
        let w = test_weights(256, 11);
        let hi = SefpTensor::encode(&w, &SefpSpec::new(Precision::of(8)));
        assert_eq!(
            hi.truncate(Precision::of(6)).truncate(Precision::of(3)),
            hi.truncate(Precision::of(3))
        );
    }

    #[test]
    fn ragged_tail_group() {
        let w = test_weights(100, 5); // 64 + 36
        let t = SefpTensor::encode(&w, &SefpSpec::new(Precision::of(4)));
        assert_eq!(t.n_groups(), 2);
        assert_eq!(t.decode().len(), 100);
    }

    #[test]
    fn significand_bounds() {
        let w = test_weights(512, 9);
        for p in Precision::LADDER {
            let t = SefpTensor::encode(&w, &SefpSpec::new(p));
            let lim = (1i16 << p.m()) - 1;
            assert!(t.significands.iter().all(|&s| s.abs() <= lim));
        }
    }

    #[test]
    fn ideal_bits_accounting() {
        let spec = SefpSpec::new(Precision::of(4));
        let t = SefpTensor::encode(&test_weights(128, 1), &spec);
        assert_eq!(t.ideal_bits(), 128 * 5 + 2 * 5);
    }
}
