//! SEFP — Shared Exponent Floating Point (paper §Related Work, fig. 1-2).
//!
//! The bit-level format at the heart of OTARo: weights are grouped (64 per
//! group in the paper), each group stores ONE shared 5-bit exponent chosen
//! from its largest-magnitude element, and each element stores a sign and
//! an `m`-bit significand.  Dequantized value: `sign * s * 2^(E - m + 1)`.
//!
//! The definition here is bit-for-bit identical to the Python oracle
//! (`python/compile/kernels/ref.py`); `tests/golden_sefp.rs` checks the
//! cross-language golden vectors emitted by `aot.py`.
//!
//! Precision is a first-class type here: [`Precision`] is a validated
//! newtype over the mantissa width, [`SefpSpec`] bundles the full codec
//! configuration (precision + group size + rounding), and the
//! [`SefpCodec`] trait unifies encode/decode/truncate across the working
//! ([`SefpTensor`]) and packed ([`PackedSefp`]) representations.
//!
//! Central deployment property (paper fig. 1): with round-toward-zero, a
//! lower bit-width is obtained from a higher one by *truncating mantissa
//! bits in place* — `encode(w, hi).truncate(lo) == encode(w, lo)`
//! exactly — so ONE stored model serves every precision with no scaling
//! factors and no requantization pass (the `SefpCodec` ladder-exactness
//! contract).

pub mod packed;
pub mod spec;
pub mod tensor;

pub use packed::PackedSefp;
pub use spec::{Precision, PrecisionError, SefpCodec, SefpSpec};
pub use tensor::SefpTensor;

/// Paper's group size (§Implementation Details).
pub const GROUP_SIZE: usize = 64;
/// E5 shared-exponent field range (bias 15): [-14, 16].
pub const EXP_MIN: i32 = -14;
pub const EXP_MAX: i32 = 16;

/// Rounding mode for the mantissa shift (paper fig. 2 step 2).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, Hash)]
pub enum Rounding {
    /// Round toward zero ("forced truncation") — the repo default; the
    /// only mode under which the truncation ladder is exact.
    #[default]
    Trunc,
    /// Round half-to-even (matches `jnp.round`) — ablation mode.
    Nearest,
}

impl std::fmt::Display for Rounding {
    /// The config/manifest spelling (`"trunc"` / `"nearest"`) — the
    /// exact inverse of `FromStr`, so round-tripping through the
    /// `.sefp` artifact manifest is lossless.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Rounding::Trunc => "trunc",
            Rounding::Nearest => "nearest",
        })
    }
}

impl std::str::FromStr for Rounding {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "trunc" => Ok(Rounding::Trunc),
            "nearest" => Ok(Rounding::Nearest),
            other => Err(format!("unknown rounding mode {other:?}")),
        }
    }
}

/// Shared exponent `E` with `2^E <= maxabs < 2^(E+1)` (frexp semantics),
/// clamped to the E5 field; zero groups get `EXP_MIN`.
///
/// Bit-exact with `ref.shared_exponent` / the Pallas `_shared_exp`:
/// normal values read the biased exponent field directly; subnormals
/// resolve the leading mantissa bit (they clamp to `EXP_MIN` anyway, but
/// we compute them honestly).
#[inline]
#[allow(clippy::neg_cmp_op_on_partial_ord)] // `!(x > 0)` must also catch NaN
pub fn shared_exponent(maxabs: f32) -> i32 {
    if !(maxabs > 0.0) {
        return EXP_MIN;
    }
    let bits = maxabs.to_bits();
    let biased = ((bits >> 23) & 0xff) as i32;
    let e = if biased == 0 {
        // subnormal: value = mant * 2^-149
        let mant = bits & 0x7f_ffff;
        (31 - mant.leading_zeros() as i32) - 149
    } else {
        biased - 127
    };
    e.clamp(EXP_MIN, EXP_MAX)
}

/// Quantization step for a group: `2^(E - m + 1)`.
#[inline]
pub fn step_for(e: i32, m: u8) -> f32 {
    (e - (m as i32) + 1).exp2_f32()
}

/// Integer-exponent exp2 helper (exact for the SEFP range).
trait Exp2I {
    fn exp2_f32(self) -> f32;
}
impl Exp2I for i32 {
    #[inline]
    fn exp2_f32(self) -> f32 {
        f32::from_bits((((self + 127) as u32) & 0xff) << 23)
    }
}

/// Quantize one value at step `step`; returns the signed significand
/// clamped to `±(2^m - 1)`.
#[inline]
pub fn quantize_value(w: f32, step: f32, m: u8, rounding: Rounding) -> i32 {
    let q = w / step;
    let q = match rounding {
        Rounding::Trunc => q.trunc(),
        Rounding::Nearest => q.round_ties_even(),
    };
    let lim = ((1i32 << m) - 1) as f32;
    q.clamp(-lim, lim) as i32
}

/// Quantize-dequantize a whole slice under `spec` (fake-quant used by
/// analysis code and the pure-rust inference baseline checks).  Groups
/// run along the flat order; a ragged tail forms a final short group
/// (identical numerics to the zero-padded Python path, since padding
/// zeros never win the max).
pub fn quant_dequant(w: &[f32], spec: &SefpSpec) -> Vec<f32> {
    assert!(spec.group_size >= 1, "SefpSpec group_size must be positive");
    let m = spec.precision.m();
    let mut out = Vec::with_capacity(w.len());
    for g in w.chunks(spec.group_size) {
        let maxabs = g.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        let e = shared_exponent(maxabs);
        let step = step_for(e, m);
        for &x in g {
            out.push(quantize_value(x, step, m, spec.rounding) as f32 * step);
        }
    }
    out
}

/// Absolute quantization error of `Q(w)` vs `w` under `spec`, returned
/// as `(max, mean)` — max first.
pub fn error_stats(w: &[f32], spec: &SefpSpec) -> (f32, f32) {
    let q = quant_dequant(w, spec);
    let mut max = 0.0f32;
    let mut sum = 0.0f64;
    for (a, b) in w.iter().zip(&q) {
        let e = (a - b).abs();
        max = max.max(e);
        sum += e as f64;
    }
    (max, (sum / w.len().max(1) as f64) as f32)
}

/// ε(ω) sawtooth (paper eq. 13, fig. 9): the pointwise quantization error
/// of fixed-point rounding at precision `p`, `ε(ω) = (ω·2^m − [ω·2^m])/2^m`.
/// Exposed here because it is a property of the format, used by
/// `analysis::epsilon` to regenerate fig. 9.
#[inline]
pub fn epsilon_sawtooth(w: f32, p: Precision, rounding: Rounding) -> f32 {
    let scale = (p.m() as i32).exp2_f32();
    let q = match rounding {
        Rounding::Trunc => (w * scale).trunc(),
        Rounding::Nearest => (w * scale).round_ties_even(),
    };
    (w * scale - q) / scale
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shared_exponent_powers_of_two() {
        assert_eq!(shared_exponent(1.0), 0);
        assert_eq!(shared_exponent(2.0), 1);
        assert_eq!(shared_exponent(0.5), -1);
        assert_eq!(shared_exponent(1.5), 0);
        assert_eq!(shared_exponent(0.99), -1);
    }

    #[test]
    fn shared_exponent_edges() {
        assert_eq!(shared_exponent(0.0), EXP_MIN);
        assert_eq!(shared_exponent(-0.0), EXP_MIN);
        assert_eq!(shared_exponent(1e30), EXP_MAX);
        assert_eq!(shared_exponent(1e-30), EXP_MIN);
        assert_eq!(shared_exponent(f32::MIN_POSITIVE / 2.0), EXP_MIN); // subnormal
    }

    #[test]
    fn exp2_exact() {
        for e in -126..=127 {
            assert_eq!(e.exp2_f32(), (e as f32).exp2(), "e={e}");
        }
    }

    #[test]
    fn quantize_max_element_fits() {
        // group max must quantize without clipping: maxabs/step < 2^m
        for p in Precision::LADDER {
            let m = p.m();
            for &v in &[1.0f32, 1.999, 0.7, 123.456] {
                let e = shared_exponent(v);
                let step = step_for(e, m);
                let q = quantize_value(v, step, m, Rounding::Trunc);
                // quantize_value clamps to ±(2^m − 1), so strictly < 2^m
                assert!(q.unsigned_abs() < (1 << m), "{p} v={v} q={q}");
            }
        }
    }

    #[test]
    fn quant_dequant_error_bound() {
        let w: Vec<f32> = (0..256).map(|i| ((i * 37 % 101) as f32 - 50.0) / 17.0).collect();
        for p in Precision::LADDER {
            let q = quant_dequant(&w, &SefpSpec::new(p));
            for (g, qg) in w.chunks(GROUP_SIZE).zip(q.chunks(GROUP_SIZE)) {
                let maxabs = g.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
                let step = step_for(shared_exponent(maxabs), p.m());
                for (a, b) in g.iter().zip(qg) {
                    assert!((a - b).abs() <= step, "{p}");
                }
            }
        }
    }

    #[test]
    fn error_stats_zero_at_exact_multiples() {
        let spec = SefpSpec::new(Precision::of(4));
        let w = vec![0.0f32; 16];
        let (max, mean) = error_stats(&w, &spec);
        assert_eq!(max, 0.0);
        assert_eq!(mean, 0.0);
    }

    #[test]
    fn epsilon_is_sawtooth() {
        // period and amplitude 1/2^m (paper appendix A)
        let p = Precision::of(3);
        let amp = 1.0 / 8.0;
        for i in 0..1000 {
            let w = (i as f32) * 0.001;
            let e = epsilon_sawtooth(w, p, Rounding::Trunc);
            assert!((0.0..amp).contains(&e) || e.abs() < 1e-6, "w={w} e={e}");
        }
    }
}
