//! The synthetic language substrate ("TinyLang").
//!
//! DESIGN.md §Substitutions: we have no LLaMA weights or Alpaca/WikiText2
//! data (repro band 0), so every corpus and benchmark is generated from a
//! small formal language with *learnable regularities*:
//!
//!   * two noun classes with suffix marking: class-A nouns end in "ka",
//!     class-B nouns end in "to";
//!   * verbs agree with the subject class: "-as" (A) vs "-os" (B);
//!   * determiners agree too: "le" (A) vs "ru" (B);
//!   * a fixed relation KB ("X pide Y") and single-digit arithmetic.
//!
//! A byte-level transformer pretrained on TinyLang text demonstrably
//! learns these rules, which gives the eight multiple-choice suites
//! (tasks.rs) enough headroom above chance for the paper's
//! accuracy-vs-bitwidth comparisons to be meaningful.

use super::rng::Rng;

pub const N_NOUNS_PER_CLASS: usize = 24;
pub const N_VERBS: usize = 16;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Class {
    A,
    B,
}

#[derive(Debug, Clone)]
pub struct Lang {
    pub nouns_a: Vec<String>,
    pub nouns_b: Vec<String>,
    pub verb_stems: Vec<String>,
    /// KB: (subject noun index into all_nouns, object noun index)
    pub kb: Vec<(usize, usize)>,
}

const ONSETS: &[&str] = &["m", "p", "v", "s", "n", "d", "b", "g", "f", "l"];
const VOWELS: &[&str] = &["a", "e", "i", "o", "u"];

fn make_stem(rng: &mut Rng, syllables: usize) -> String {
    let mut s = String::new();
    for _ in 0..syllables {
        s.push_str(rng.choose::<&str>(ONSETS));
        s.push_str(rng.choose::<&str>(VOWELS));
    }
    s
}

impl Lang {
    /// Construct the (deterministic) language for a seed.  All corpora and
    /// all task suites share one Lang so the rules are consistent between
    /// pretraining, fine-tuning and evaluation.
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x7A9C_11E5);
        let mut uniq = std::collections::HashSet::new();
        let mut take = |rng: &mut Rng, suffix: &str| loop {
            let stem = make_stem(rng, 2);
            let w = format!("{stem}{suffix}");
            if uniq.insert(w.clone()) {
                return w;
            }
        };
        let nouns_a: Vec<String> = (0..N_NOUNS_PER_CLASS).map(|_| take(&mut rng, "ka")).collect();
        let nouns_b: Vec<String> = (0..N_NOUNS_PER_CLASS).map(|_| take(&mut rng, "to")).collect();
        let verb_stems: Vec<String> = (0..N_VERBS).map(|_| take(&mut rng, "")).collect();
        // KB: every noun relates to exactly one object (function, so yes/no
        // questions have unambiguous answers)
        let total = 2 * N_NOUNS_PER_CLASS;
        let kb = (0..total)
            .map(|s| {
                let mut o = rng.below(total);
                if o == s {
                    o = (o + 1) % total;
                }
                (s, o)
            })
            .collect();
        Lang { nouns_a, nouns_b, verb_stems, kb }
    }

    pub fn noun(&self, idx: usize) -> (&str, Class) {
        if idx < N_NOUNS_PER_CLASS {
            (&self.nouns_a[idx], Class::A)
        } else {
            (&self.nouns_b[idx - N_NOUNS_PER_CLASS], Class::B)
        }
    }

    pub fn n_nouns(&self) -> usize {
        2 * N_NOUNS_PER_CLASS
    }

    pub fn determiner(class: Class) -> &'static str {
        match class {
            Class::A => "le",
            Class::B => "ru",
        }
    }

    pub fn verb(&self, idx: usize, subject_class: Class) -> String {
        let suffix = match subject_class {
            Class::A => "as",
            Class::B => "os",
        };
        format!("{}{}", self.verb_stems[idx], suffix)
    }

    /// Wrong-agreement verb (the contrastive distractor).
    pub fn verb_wrong(&self, idx: usize, subject_class: Class) -> String {
        let flipped = match subject_class {
            Class::A => Class::B,
            Class::B => Class::A,
        };
        self.verb(idx, flipped)
    }

    /// A grammatical sentence: "det subj verb det obj ."
    pub fn sentence(&self, rng: &mut Rng) -> String {
        let s = rng.below(self.n_nouns());
        let o = rng.below(self.n_nouns());
        let v = rng.below(N_VERBS);
        let (sw, sc) = self.noun(s);
        let (ow, oc) = self.noun(o);
        format!(
            "{} {} {} {} {} .",
            Lang::determiner(sc),
            sw,
            self.verb(v, sc),
            Lang::determiner(oc),
            ow
        )
    }

    /// A KB fact sentence: "subj pide obj ." — the relation the BoolQ- and
    /// OBQA-style suites quiz.
    pub fn fact_sentence(&self, s: usize) -> String {
        let (sw, _) = self.noun(s);
        let (ow, _) = self.noun(self.kb[s].1);
        format!("{sw} pide {ow} .")
    }

    /// Arithmetic line "a + b = c ." with a,b single digits.
    pub fn arith_sentence(&self, rng: &mut Rng) -> String {
        let a = rng.below(9) + 1;
        let b = rng.below(9) + 1;
        format!("{a} + {b} = {} .", a + b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = Lang::new(1);
        let b = Lang::new(1);
        assert_eq!(a.nouns_a, b.nouns_a);
        assert_eq!(a.kb, b.kb);
    }

    #[test]
    fn class_suffixes() {
        let l = Lang::new(2);
        assert!(l.nouns_a.iter().all(|w| w.ends_with("ka")));
        assert!(l.nouns_b.iter().all(|w| w.ends_with("to")));
    }

    #[test]
    fn verb_agreement() {
        let l = Lang::new(3);
        assert!(l.verb(0, Class::A).ends_with("as"));
        assert!(l.verb(0, Class::B).ends_with("os"));
        assert_ne!(l.verb(1, Class::A), l.verb_wrong(1, Class::A));
    }

    #[test]
    fn kb_is_function_without_self_loops() {
        let l = Lang::new(4);
        assert_eq!(l.kb.len(), l.n_nouns());
        assert!(l.kb.iter().all(|&(s, o)| s != o && o < l.n_nouns()));
    }

    #[test]
    fn sentence_grammatical() {
        let l = Lang::new(5);
        let mut rng = Rng::new(9);
        for _ in 0..20 {
            let s = l.sentence(&mut rng);
            let toks: Vec<&str> = s.split_whitespace().collect();
            assert_eq!(toks.len(), 6);
            let subj_class = if toks[1].ends_with("ka") { Class::A } else { Class::B };
            assert_eq!(toks[0], Lang::determiner(subj_class));
            match subj_class {
                Class::A => assert!(toks[2].ends_with("as")),
                Class::B => assert!(toks[2].ends_with("os")),
            }
        }
    }
}
