//! Data substrate: deterministic RNG, byte tokenizer, the TinyLang
//! synthetic language, corpus generators (pretrain / TinyText / instruct)
//! and batchers.  Everything is seed-reproducible; see DESIGN.md
//! §Substitutions for how these stand in for the paper's datasets.

pub mod batcher;
pub mod corpus;
pub mod lang;
pub mod rng;
pub mod tasks;
pub mod tokenizer;

pub use batcher::{Batch, PairBatcher, StreamBatcher};
pub use lang::Lang;
pub use rng::Rng;
pub use tasks::{McItem, Suite, ALL_SUITES};
pub use tokenizer::Tokenizer;
