//! Deterministic RNG substrate (SplitMix64) — no external deps, identical
//! streams across platforms, so every experiment in EXPERIMENTS.md is
//! exactly reproducible from its seed.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Zipf-distributed index in [0, n) with exponent `s` (rejection-free
    /// CDF inversion over a precomputed table is overkill; harmonic-walk
    /// inversion is fine at our vocab sizes).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // inverse-CDF by linear walk over unnormalized weights
        let target = self.f64() * zipf_norm(n, s);
        let mut acc = 0.0;
        for k in 0..n {
            acc += 1.0 / ((k + 1) as f64).powf(s);
            if acc >= target {
                return k;
            }
        }
        n - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Pick one element.
    pub fn choose<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.below(v.len())]
    }

    /// Derive an independent stream (for parallel substreams).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }
}

fn zipf_norm(n: usize, s: f64) -> f64 {
    (0..n).map(|k| 1.0 / ((k + 1) as f64).powf(s)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn zipf_skews_low() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..5000 {
            counts[r.zipf(10, 1.2)] += 1;
        }
        assert!(counts[0] > counts[5]);
        assert!(counts[0] > counts[9] * 3);
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..20).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..20).collect::<Vec<_>>());
        assert_ne!(v, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..20000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
