//! Corpus generators: the three datasets of the paper's evaluation,
//! rebuilt over TinyLang (DESIGN.md §Substitutions).
//!
//!   * `pretrain_corpus`  — what the base model is trained on in-repo
//!     (mix of grammatical text, KB facts, arithmetic): stands in for the
//!     LLM pretraining the paper inherits from LLaMA/Qwen checkpoints.
//!   * `tinytext_corpus`  — WikiText2 analogue, train/test split, used by
//!     the task-specific fine-tuning experiments (fig. 7 / table 8).
//!   * `instruct_corpus`  — Alpaca analogue: prompt/answer pairs drawn
//!     from the same task families the MC suites quiz, but from a
//!     disjoint RNG stream (zero-shot experiments, table 1).

use super::lang::Lang;
use super::rng::Rng;
use super::tasks::{Suite, ALL_SUITES};
use super::tokenizer::Tokenizer;

const PRETRAIN_TAG: u64 = 0x11;
const TINYTEXT_TRAIN_TAG: u64 = 0x22;
const TINYTEXT_TEST_TAG: u64 = 0x33;
const INSTRUCT_TAG: u64 = 0x44;

/// One flat token stream (documents joined by EOS).
pub fn pretrain_corpus(lang: &Lang, seed: u64, n_sentences: usize) -> Vec<i32> {
    let mut rng = Rng::new(seed ^ PRETRAIN_TAG);
    let tok = Tokenizer::new();
    let mut out = Vec::new();
    for i in 0..n_sentences {
        let s = match i % 4 {
            0 | 1 => lang.sentence(&mut rng),
            2 => {
                // KB facts are cycled so every fact is seen
                lang.fact_sentence(rng.below(lang.n_nouns()))
            }
            _ => lang.arith_sentence(&mut rng),
        };
        out.extend(tok.encode(&s));
        out.push(super::tokenizer::EOS);
    }
    out
}

/// WikiText2-analogue: pure TinyLang prose, split into train and test.
pub fn tinytext_corpus(lang: &Lang, seed: u64, n_train: usize, n_test: usize) -> (Vec<i32>, Vec<i32>) {
    let tok = Tokenizer::new();
    let gen = |tag: u64, n: usize| {
        let mut rng = Rng::new(seed ^ tag);
        let mut out = Vec::new();
        for _ in 0..n {
            out.extend(tok.encode(&lang.sentence(&mut rng)));
            out.push(super::tokenizer::EOS);
        }
        out
    };
    (gen(TINYTEXT_TRAIN_TAG, n_train), gen(TINYTEXT_TEST_TAG, n_test))
}

/// Alpaca-analogue instruction pairs, already tokenized with
/// BOS/SEP/EOS structure.  Items come from the same eight suites the
/// evaluation uses, but from the INSTRUCT_TAG stream — disjoint from
/// `Suite::eval_set`'s EVAL stream.
pub fn instruct_corpus(lang: &Lang, seed: u64, n_pairs: usize) -> Vec<Vec<i32>> {
    let mut rng = Rng::new(seed ^ INSTRUCT_TAG);
    let tok = Tokenizer::new();
    let mut out = Vec::with_capacity(n_pairs);
    for i in 0..n_pairs {
        let suite: Suite = ALL_SUITES[i % ALL_SUITES.len()];
        let item = suite.item(lang, &mut rng);
        out.push(tok.encode_pair(&item.prompt, &item.choices[item.answer]));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lang() -> Lang {
        Lang::new(42)
    }

    #[test]
    fn pretrain_deterministic_and_nonempty() {
        let l = lang();
        let a = pretrain_corpus(&l, 1, 100);
        let b = pretrain_corpus(&l, 1, 100);
        assert_eq!(a, b);
        assert!(a.len() > 1000);
        assert!(a.iter().all(|&t| (0..320).contains(&t)));
    }

    #[test]
    fn tinytext_split_disjoint_streams() {
        let l = lang();
        let (tr, te) = tinytext_corpus(&l, 1, 50, 50);
        assert_ne!(tr, te);
        assert!(!tr.is_empty() && !te.is_empty());
    }

    #[test]
    fn instruct_pairs_have_structure() {
        let l = lang();
        let pairs = instruct_corpus(&l, 1, 16);
        assert_eq!(pairs.len(), 16);
        for p in &pairs {
            assert_eq!(p[0], super::super::tokenizer::BOS);
            assert_eq!(*p.last().unwrap(), super::super::tokenizer::EOS);
            assert!(p.contains(&super::super::tokenizer::SEP));
        }
    }

    #[test]
    fn different_seed_different_corpus() {
        let l = lang();
        assert_ne!(pretrain_corpus(&l, 1, 50), pretrain_corpus(&l, 2, 50));
    }
}
