//! Byte-level tokenizer with special tokens.
//!
//! Vocabulary layout (total 320, 64-aligned for SEFP groups):
//!   0..=255   raw bytes
//!   256       BOS
//!   257       EOS
//!   258       PAD (never predicted; targets at PAD are masked with -1)
//!   259       SEP (prompt/answer separator for instruction data)
//!   260..=319 reserved

pub const BOS: i32 = 256;
pub const EOS: i32 = 257;
pub const PAD: i32 = 258;
pub const SEP: i32 = 259;
pub const VOCAB_SIZE: usize = 320;

/// Target id used to mask padding positions in the loss (mirrors
/// `model.loss_fn`'s `targets >= 0` check).
pub const IGNORE: i32 = -1;

#[derive(Debug, Clone, Default)]
pub struct Tokenizer;

impl Tokenizer {
    pub fn new() -> Self {
        Tokenizer
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.bytes().map(|b| b as i32).collect()
    }

    pub fn encode_with_bos(&self, text: &str) -> Vec<i32> {
        let mut v = Vec::with_capacity(text.len() + 1);
        v.push(BOS);
        v.extend(text.bytes().map(|b| b as i32));
        v
    }

    /// Prompt SEP answer EOS — the instruction-tuning shape.
    pub fn encode_pair(&self, prompt: &str, answer: &str) -> Vec<i32> {
        let mut v = Vec::with_capacity(prompt.len() + answer.len() + 3);
        v.push(BOS);
        v.extend(prompt.bytes().map(|b| b as i32));
        v.push(SEP);
        v.extend(answer.bytes().map(|b| b as i32));
        v.push(EOS);
        v
    }

    pub fn decode(&self, tokens: &[i32]) -> String {
        let bytes: Vec<u8> = tokens
            .iter()
            .filter(|&&t| (0..256).contains(&t))
            .map(|&t| t as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let t = Tokenizer::new();
        let s = "hello otaro";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn pair_structure() {
        let t = Tokenizer::new();
        let v = t.encode_pair("q", "a");
        assert_eq!(v, vec![BOS, b'q' as i32, SEP, b'a' as i32, EOS]);
    }

    #[test]
    fn decode_skips_specials() {
        let t = Tokenizer::new();
        assert_eq!(t.decode(&[BOS, b'x' as i32, SEP, EOS, PAD]), "x");
    }

    #[test]
    fn vocab_is_64_aligned() {
        assert_eq!(VOCAB_SIZE % 64, 0);
        assert!(SEP < VOCAB_SIZE as i32);
    }
}
