//! The eight synthetic multiple-choice suites — structural analogues of
//! the paper's zero-shot benchmarks (table 1): ARC-Easy, ARC-Challenge,
//! BoolQ, HellaSwag, MathQA, OpenBookQA, PIQA, WinoGrande.
//!
//! Each suite quizzes one TinyLang regularity; items are scored exactly
//! like lm-eval-harness scores the real suites: length-normalized
//! log-likelihood over the answer continuation (eval/mc.rs).
//!
//! Train/eval splits are disjoint by construction (item RNG streams are
//! forked from different tags), so instruction fine-tuning never sees the
//! evaluation items.

use super::lang::{Class, Lang};
use super::rng::Rng;

#[derive(Debug, Clone)]
pub struct McItem {
    pub prompt: String,
    pub choices: Vec<String>,
    pub answer: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Suite {
    /// ARC-Easy analogue: pick the verb with correct subject agreement.
    AgreeEasy,
    /// ARC-Challenge analogue: agreement across an intervening phrase.
    AgreeHard,
    /// BoolQ analogue: yes/no over the relation KB.
    YesNo,
    /// HellaSwag analogue: most plausible sentence continuation.
    Continue,
    /// MathQA analogue: single-digit addition, 5 options.
    Arith,
    /// OpenBookQA analogue: KB completion, 4 options.
    Fact,
    /// PIQA analogue: canonical word order vs scrambled, 2 options.
    Order,
    /// WinoGrande analogue: fill the blank with the class-agreeing noun.
    Fill,
}

pub const ALL_SUITES: [Suite; 8] = [
    Suite::AgreeEasy,
    Suite::AgreeHard,
    Suite::YesNo,
    Suite::Continue,
    Suite::Arith,
    Suite::Fact,
    Suite::Order,
    Suite::Fill,
];

impl Suite {
    pub fn name(&self) -> &'static str {
        match self {
            Suite::AgreeEasy => "agree-e",
            Suite::AgreeHard => "agree-c",
            Suite::YesNo => "yesno",
            Suite::Continue => "continue",
            Suite::Arith => "arith",
            Suite::Fact => "fact",
            Suite::Order => "order",
            Suite::Fill => "fill",
        }
    }

    /// Generate one item.
    pub fn item(&self, lang: &Lang, rng: &mut Rng) -> McItem {
        match self {
            Suite::AgreeEasy => agree_item(lang, rng, false),
            Suite::AgreeHard => agree_item(lang, rng, true),
            Suite::YesNo => yesno_item(lang, rng),
            Suite::Continue => continue_item(lang, rng),
            Suite::Arith => arith_item(rng),
            Suite::Fact => fact_item(lang, rng),
            Suite::Order => order_item(lang, rng),
            Suite::Fill => fill_item(lang, rng),
        }
    }

    /// A deterministic evaluation set (disjoint from training items, which
    /// fork with a different tag in corpus.rs).
    pub fn eval_set(&self, lang: &Lang, n: usize, seed: u64) -> Vec<McItem> {
        let mut rng = Rng::new(seed ^ EVAL_TAG);
        (0..n).map(|_| self.item(lang, &mut rng)).collect()
    }
}

const EVAL_TAG: u64 = 0xE7A1_0001;

fn shuffle_with_answer(rng: &mut Rng, correct: String, mut wrong: Vec<String>) -> (Vec<String>, usize) {
    let mut choices = vec![correct.clone()];
    choices.append(&mut wrong);
    rng.shuffle(&mut choices);
    let answer = choices.iter().position(|c| *c == correct).unwrap();
    (choices, answer)
}

fn agree_item(lang: &Lang, rng: &mut Rng, hard: bool) -> McItem {
    let s = rng.below(lang.n_nouns());
    let (sw, sc) = lang.noun(s);
    let v = rng.below(super::lang::N_VERBS);
    let det = Lang::determiner(sc);
    let prompt = if hard {
        // intervening object phrase of the OPPOSITE class between subject
        // and verb — the model must track the true subject
        let o = match sc {
            Class::A => super::lang::N_NOUNS_PER_CLASS + rng.below(super::lang::N_NOUNS_PER_CLASS),
            Class::B => rng.below(super::lang::N_NOUNS_PER_CLASS),
        };
        let (ow, oc) = lang.noun(o);
        format!("{det} {sw} {} {ow} :", Lang::determiner(oc))
    } else {
        format!("{det} {sw} :")
    };
    let correct = lang.verb(v, sc);
    let mut wrong = vec![lang.verb_wrong(v, sc)];
    // two more distractors from other verbs (both suffixes)
    let v2 = (v + 1 + rng.below(super::lang::N_VERBS - 1)) % super::lang::N_VERBS;
    wrong.push(lang.verb_wrong(v2, sc));
    wrong.push(lang.verb(v2, sc));
    let (choices, answer) = shuffle_with_answer(rng, correct, wrong);
    McItem { prompt, choices, answer }
}

fn yesno_item(lang: &Lang, rng: &mut Rng) -> McItem {
    let s = rng.below(lang.n_nouns());
    let truth = rng.below(2) == 0;
    let o = if truth {
        lang.kb[s].1
    } else {
        // a wrong object
        let mut o = rng.below(lang.n_nouns());
        while o == lang.kb[s].1 {
            o = rng.below(lang.n_nouns());
        }
        o
    };
    let (sw, _) = lang.noun(s);
    let (ow, _) = lang.noun(o);
    let prompt = format!("{sw} pide {ow} ?");
    let correct = if truth { "yes" } else { "no" }.to_string();
    let wrong = vec![if truth { "no" } else { "yes" }.to_string()];
    // fixed order (yes/no) like BoolQ scoring, but keep answer index honest
    let choices = vec!["yes".to_string(), "no".to_string()];
    let answer = choices.iter().position(|c| *c == correct).unwrap();
    let _ = wrong;
    McItem { prompt, choices, answer }
}

fn continue_item(lang: &Lang, rng: &mut Rng) -> McItem {
    let s = rng.below(lang.n_nouns());
    let (sw, sc) = lang.noun(s);
    let v = rng.below(super::lang::N_VERBS);
    let o = rng.below(lang.n_nouns());
    let (ow, oc) = lang.noun(o);
    let prompt = format!("{} {} {}", Lang::determiner(sc), sw, lang.verb(v, sc));
    let correct = format!("{} {} .", Lang::determiner(oc), ow);
    // distractors: bad determiner, bare verb, digit noise
    let wrong = vec![
        format!("{} {} .", Lang::determiner(flip(oc)), ow),
        format!("{} {} .", lang.verb(rng.below(super::lang::N_VERBS), sc), ow),
        format!("{} {} .", rng.below(10), rng.below(10)),
    ];
    let (choices, answer) = shuffle_with_answer(rng, correct, wrong);
    McItem { prompt, choices, answer }
}

fn flip(c: Class) -> Class {
    match c {
        Class::A => Class::B,
        Class::B => Class::A,
    }
}

fn arith_item(rng: &mut Rng) -> McItem {
    let a = rng.below(9) + 1;
    let b = rng.below(9) + 1;
    let prompt = format!("{a} + {b} =");
    let correct = (a + b).to_string();
    let mut wrong = Vec::new();
    let mut d = 1;
    while wrong.len() < 4 {
        let cand = a + b + d;
        if cand <= 18 {
            wrong.push(cand.to_string());
        }
        let low = (a + b).saturating_sub(d);
        if wrong.len() < 4 && low >= 2 && low != a + b {
            wrong.push(low.to_string());
        }
        d += 1;
    }
    let (choices, answer) = shuffle_with_answer(rng, correct, wrong);
    McItem { prompt, choices, answer }
}

fn fact_item(lang: &Lang, rng: &mut Rng) -> McItem {
    let s = rng.below(lang.n_nouns());
    let (sw, _) = lang.noun(s);
    let correct_o = lang.kb[s].1;
    let prompt = format!("{sw} pide");
    let correct = lang.noun(correct_o).0.to_string();
    let mut wrong = Vec::new();
    while wrong.len() < 3 {
        let o = rng.below(lang.n_nouns());
        let w = lang.noun(o).0.to_string();
        if o != correct_o && !wrong.contains(&w) {
            wrong.push(w);
        }
    }
    let (choices, answer) = shuffle_with_answer(rng, correct, wrong);
    McItem { prompt, choices, answer }
}

fn order_item(lang: &Lang, rng: &mut Rng) -> McItem {
    let s = lang.sentence(rng);
    let correct = s.clone();
    let mut words: Vec<&str> = s.split_whitespace().collect();
    // scramble until different
    let mut scr = words.clone();
    loop {
        rng.shuffle(&mut scr);
        if scr != words {
            break;
        }
    }
    let wrong = vec![scr.join(" ")];
    words.clear();
    let (choices, answer) = shuffle_with_answer(rng, correct, wrong);
    McItem { prompt: "ok :".to_string(), choices, answer }
}

fn fill_item(lang: &Lang, rng: &mut Rng) -> McItem {
    // "det _ verb ." — the noun must agree with both det and verb
    let class = if rng.below(2) == 0 { Class::A } else { Class::B };
    let v = rng.below(super::lang::N_VERBS);
    let prompt = format!("{} _ {} . _ =", Lang::determiner(class), lang.verb(v, class));
    let pick = |rng: &mut Rng, c: Class| -> String {
        let i = rng.below(super::lang::N_NOUNS_PER_CLASS);
        match c {
            Class::A => lang.nouns_a[i].clone(),
            Class::B => lang.nouns_b[i].clone(),
        }
    };
    let correct = pick(rng, class);
    let wrong = vec![pick(rng, flip(class))];
    let (choices, answer) = shuffle_with_answer(rng, correct, wrong);
    McItem { prompt, choices, answer }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lang() -> Lang {
        Lang::new(42)
    }

    #[test]
    fn all_suites_generate() {
        let l = lang();
        let mut rng = Rng::new(1);
        for suite in ALL_SUITES {
            for _ in 0..20 {
                let it = suite.item(&l, &mut rng);
                assert!(it.answer < it.choices.len(), "{:?}", suite);
                assert!(!it.prompt.is_empty());
                assert!(it.choices.len() >= 2);
                // answer string must be unique among choices
                let a = &it.choices[it.answer];
                assert_eq!(it.choices.iter().filter(|c| *c == a).count(), 1, "{:?} {:?}", suite, it);
            }
        }
    }

    #[test]
    fn eval_set_deterministic() {
        let l = lang();
        let a = Suite::Arith.eval_set(&l, 10, 7);
        let b = Suite::Arith.eval_set(&l, 10, 7);
        assert_eq!(a.len(), 10);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.answer, y.answer);
        }
    }

    #[test]
    fn arith_correct() {
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let it = arith_item(&mut rng);
            let parts: Vec<&str> = it.prompt.split_whitespace().collect();
            let a: usize = parts[0].parse().unwrap();
            let b: usize = parts[2].parse().unwrap();
            assert_eq!(it.choices[it.answer], (a + b).to_string());
        }
    }

    #[test]
    fn yesno_truthful() {
        let l = lang();
        let mut rng = Rng::new(5);
        for _ in 0..50 {
            let it = yesno_item(&l, &mut rng);
            let words: Vec<&str> = it.prompt.trim_end_matches(" ?").split(" pide ").collect();
            let s_idx = (0..l.n_nouns()).find(|&i| l.noun(i).0 == words[0]).unwrap();
            let is_true = l.noun(l.kb[s_idx].1).0 == words[1];
            assert_eq!(it.choices[it.answer] == "yes", is_true);
        }
    }
}
