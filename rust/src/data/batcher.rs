//! Batching: turn token streams / instruction pairs into the fixed
//! (B, T) i32 tensors the AOT-compiled step programs expect.
//!
//! Targets are inputs shifted by one; positions with no next token (or
//! padding) carry `IGNORE` (-1) and are masked out of the loss by
//! `model.loss_fn`.

use super::rng::Rng;
use super::tokenizer::{IGNORE, PAD};

/// One (B, T) batch in row-major layout, ready for `Literal` upload.
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub batch_size: usize,
    pub seq_len: usize,
}

impl Batch {
    pub fn n_valid_targets(&self) -> usize {
        self.targets.iter().filter(|&&t| t >= 0).count()
    }
}

/// Sliding-window LM batcher over one flat stream (pretraining /
/// TinyText fine-tuning).  Windows are sampled at random offsets (epoch
/// semantics are handled by the trainer's step budget).
pub struct StreamBatcher {
    stream: Vec<i32>,
    pub batch_size: usize,
    pub seq_len: usize,
    rng: Rng,
}

impl StreamBatcher {
    pub fn new(stream: Vec<i32>, batch_size: usize, seq_len: usize, seed: u64) -> Self {
        assert!(stream.len() > seq_len + 1, "stream too short for seq_len");
        StreamBatcher { stream, batch_size, seq_len, rng: Rng::new(seed) }
    }

    pub fn next_batch(&mut self) -> Batch {
        let mut tokens = Vec::with_capacity(self.batch_size * self.seq_len);
        let mut targets = Vec::with_capacity(self.batch_size * self.seq_len);
        let max_start = self.stream.len() - self.seq_len - 1;
        for _ in 0..self.batch_size {
            let s = self.rng.below(max_start + 1);
            tokens.extend_from_slice(&self.stream[s..s + self.seq_len]);
            targets.extend_from_slice(&self.stream[s + 1..s + self.seq_len + 1]);
        }
        Batch { tokens, targets, batch_size: self.batch_size, seq_len: self.seq_len }
    }

    /// Deterministic full coverage of the stream in order — used by the
    /// perplexity evaluator so PPL is batch-order independent.
    pub fn sequential_batches(&self) -> Vec<Batch> {
        let mut out = Vec::new();
        let stride = self.seq_len;
        let mut starts = Vec::new();
        let mut s = 0;
        while s + self.seq_len + 1 <= self.stream.len() {
            starts.push(s);
            s += stride;
        }
        for chunk in starts.chunks(self.batch_size) {
            let mut tokens = Vec::with_capacity(self.batch_size * self.seq_len);
            let mut targets = Vec::with_capacity(self.batch_size * self.seq_len);
            for &st in chunk {
                tokens.extend_from_slice(&self.stream[st..st + self.seq_len]);
                targets.extend_from_slice(&self.stream[st + 1..st + self.seq_len + 1]);
            }
            // pad the ragged final batch with PAD/IGNORE rows
            for _ in chunk.len()..self.batch_size {
                tokens.extend(std::iter::repeat(PAD).take(self.seq_len));
                targets.extend(std::iter::repeat(IGNORE).take(self.seq_len));
            }
            out.push(Batch {
                tokens,
                targets,
                batch_size: self.batch_size,
                seq_len: self.seq_len,
            });
        }
        out
    }
}

/// Batcher over instruction pairs (variable-length documents): packs one
/// document per row, truncating or padding to `seq_len`.
pub struct PairBatcher {
    pairs: Vec<Vec<i32>>,
    pub batch_size: usize,
    pub seq_len: usize,
    rng: Rng,
}

impl PairBatcher {
    pub fn new(pairs: Vec<Vec<i32>>, batch_size: usize, seq_len: usize, seed: u64) -> Self {
        assert!(!pairs.is_empty());
        PairBatcher { pairs, batch_size, seq_len, rng: Rng::new(seed) }
    }

    pub fn next_batch(&mut self) -> Batch {
        let mut tokens = Vec::with_capacity(self.batch_size * self.seq_len);
        let mut targets = Vec::with_capacity(self.batch_size * self.seq_len);
        for _ in 0..self.batch_size {
            let doc = &self.pairs[self.rng.below(self.pairs.len())];
            let n = doc.len().min(self.seq_len + 1);
            // row = doc[..n-1], target = doc[1..n], rest padded
            for i in 0..self.seq_len {
                if i + 1 < n {
                    tokens.push(doc[i]);
                    targets.push(doc[i + 1]);
                } else {
                    tokens.push(PAD);
                    targets.push(IGNORE);
                }
            }
        }
        Batch { tokens, targets, batch_size: self.batch_size, seq_len: self.seq_len }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stream(n: usize) -> Vec<i32> {
        (0..n as i32).map(|i| i % 250).collect()
    }

    #[test]
    fn stream_batch_shapes() {
        let mut b = StreamBatcher::new(stream(1000), 4, 32, 0);
        let batch = b.next_batch();
        assert_eq!(batch.tokens.len(), 4 * 32);
        assert_eq!(batch.targets.len(), 4 * 32);
        // target is input shifted by one
        assert_eq!(batch.targets[0], batch.tokens[1]);
    }

    #[test]
    fn sequential_covers_stream_once() {
        let b = StreamBatcher::new(stream(1000), 4, 32, 0);
        let batches = b.sequential_batches();
        let valid: usize = batches.iter().map(|b| b.n_valid_targets()).sum();
        // floor((1000-1)/32) windows * 32 targets each
        assert_eq!(valid, ((1000 - 1 - 32) / 32 + 1) * 32);
    }

    #[test]
    fn pair_batch_masks_padding() {
        let pairs = vec![vec![256, 65, 66, 259, 67, 257], vec![256, 65, 257]];
        let mut b = PairBatcher::new(pairs, 2, 16, 1);
        let batch = b.next_batch();
        assert_eq!(batch.tokens.len(), 32);
        assert!(batch.n_valid_targets() < 32);
        // all padding rows align
        for (t, g) in batch.tokens.iter().zip(&batch.targets) {
            if *t == PAD {
                assert_eq!(*g, IGNORE);
            }
        }
    }

    #[test]
    fn long_doc_truncated() {
        let pairs = vec![(0..100).collect::<Vec<i32>>()];
        let mut b = PairBatcher::new(pairs, 1, 8, 2);
        let batch = b.next_batch();
        assert_eq!(batch.tokens, (0..8).collect::<Vec<i32>>());
        assert_eq!(batch.targets, (1..9).collect::<Vec<i32>>());
    }

    #[test]
    fn deterministic_by_seed() {
        let mut a = StreamBatcher::new(stream(500), 2, 16, 9);
        let mut b = StreamBatcher::new(stream(500), 2, 16, 9);
        assert_eq!(a.next_batch(), b.next_batch());
    }
}
