//! One function per paper table/figure (DESIGN.md §4).
//!
//! Quick mode shrinks steps/items so `bench all --quick` completes on a
//! laptop-class CPU in minutes; the full runs are what EXPERIMENTS.md
//! records.  Every function prints a markdown table AND writes it to
//! `runs/<target>.md`.

use crate::analysis::{cosine_matrix, epsilon_curve, lsm_fit, norm_error_traces};
use crate::analysis::epsilon::{amplitude, ascii_plot};
use crate::config::{Method, TrainConfig};
use crate::coordinator::Trainer;
use crate::data::{corpus, PairBatcher, StreamBatcher};
use crate::eval::mc::score_items;
use crate::eval::ppl::perplexity;
use crate::eval::tables::{f2, f3, pct, TableBuilder};
use crate::infer::{DecoderSim, DecoderWeights, SimConfig};
use crate::runtime::{Engine, ParamStore, Width};
use crate::sefp::{Precision, Rounding, SefpSpec};

use super::{ladder, Ctx};

const WIDTH_HDR: [&str; 7] = ["method", "E5M8", "E5M7", "E5M6", "E5M5", "E5M4", "E5M3"];

fn save_table(ctx: &Ctx, name: &str, md: &str) {
    let _ = std::fs::create_dir_all(&ctx.runs);
    let _ = std::fs::write(ctx.runs.join(format!("{name}.md")), md);
}

/// Make sure a pretrained checkpoint exists (pretraining once, cached).
fn ensure_pretrained(ctx: &Ctx, quick: bool) -> anyhow::Result<()> {
    if ctx.pretrained_path().exists() {
        return Ok(());
    }
    eprintln!("no pretrained checkpoint — pretraining now");
    super::pretrain(ctx, if quick { 300 } else { 800 }, 3e-2, None)
}

fn ft_steps(quick: bool) -> usize {
    if quick {
        60
    } else {
        600
    }
}

fn mc_items(quick: bool) -> usize {
    if quick {
        12
    } else {
        40
    }
}

/// Fine-tune a fresh copy of the pretrained params with `cfg` on the
/// given dataset ("tinytext" | "instruct"); returns the tuned params.
fn tune(
    ctx: &Ctx,
    engine: &mut Engine,
    dataset: &str,
    cfg: TrainConfig,
) -> anyhow::Result<ParamStore> {
    let mut params = ctx.params(engine, None)?;
    if cfg.method == Method::None || cfg.steps == 0 {
        return Ok(params);
    }
    let lang = ctx.lang();
    let (b, t) = engine.batch_shape();
    let mut sink = crate::metrics::MetricsSink::null();
    match dataset {
        "tinytext" => {
            let (train, _) = corpus::tinytext_corpus(&lang, ctx.seed, 8_000, 1_000);
            let mut batches = StreamBatcher::new(train, b, t, cfg.seed ^ 0x5);
            Trainer::new(engine, &mut params, &mut batches, cfg).run(&mut sink)?;
        }
        "instruct" => {
            let pairs = corpus::instruct_corpus(&lang, ctx.seed, 4_000);
            let mut batches = PairBatcher::new(pairs, b, t, cfg.seed ^ 0x6);
            Trainer::new(engine, &mut params, &mut batches, cfg).run(&mut sink)?;
        }
        other => anyhow::bail!("unknown dataset {other}"),
    }
    Ok(params)
}

fn base_cfg(ctx: &Ctx, method: Method, steps: usize) -> TrainConfig {
    TrainConfig { method, steps, seed: ctx.seed, ..TrainConfig::default() }
}

/// PPL at every ladder width for one param set.
fn ppl_row(engine: &mut Engine, params: &ParamStore, test: &[i32]) -> anyhow::Result<Vec<f64>> {
    ladder()
        .into_iter()
        .map(|w| perplexity(engine, params, test, w))
        .collect()
}

/// Average MC accuracy over all eight suites at every ladder width.
fn acc_row(
    ctx: &Ctx,
    engine: &mut Engine,
    params: &ParamStore,
    items_per_suite: usize,
) -> anyhow::Result<Vec<f64>> {
    let lang = ctx.lang();
    let mut avgs = vec![0.0f64; 6];
    for suite in crate::data::ALL_SUITES {
        let items = suite.eval_set(&lang, items_per_suite, ctx.seed);
        for (i, w) in ladder().into_iter().enumerate() {
            let (acc, _) = score_items(engine, params, w, &items)?;
            avgs[i] += acc / 8.0;
        }
    }
    Ok(avgs)
}

// ---------------------------------------------------------------------------
// Table 8 / fig. 7 — task-specific fine-tuning PPL
// ---------------------------------------------------------------------------

pub fn table8(ctx: &Ctx, quick: bool) -> anyhow::Result<()> {
    ensure_pretrained(ctx, quick)?;
    let mut engine = ctx.engine()?;
    let lang = ctx.lang();
    let (_, test) = corpus::tinytext_corpus(&lang, ctx.seed, 8_000, 1_000);
    let steps = ft_steps(quick);

    let mut hdr: Vec<&str> = WIDTH_HDR.to_vec();
    hdr.push("AVG");
    hdr.push("STD");
    let mut t = TableBuilder::new(
        "Table 8 — task-specific fine-tuning PPL (TinyText, lower is better)",
        &hdr,
    );

    let add_row = |label: &str, vals: Vec<f64>, t: &mut TableBuilder| {
        let mut s = crate::metrics::Summary::new();
        for &v in &vals {
            s.push(v);
        }
        let mut all = vals.clone();
        all.push(s.mean());
        all.push(s.std());
        t.row_f(label, &all, f2);
    };

    // Before fine-tuning
    let params = ctx.params(&engine, None)?;
    add_row("Before Fine-Tuning", ppl_row(&mut engine, &params, &test)?, &mut t);

    // FP fine-tuning
    let params = tune(ctx, &mut engine, "tinytext", base_cfg(ctx, Method::Fp, steps))?;
    add_row("FP Fine-Tuning", ppl_row(&mut engine, &params, &test)?, &mut t);

    // Fixed precision: one run per width, evaluated at its own width
    let mut fixed_vals = Vec::new();
    for w in Precision::LADDER {
        let cfg = TrainConfig { fixed_m: Some(w), ..base_cfg(ctx, Method::Fixed, steps) };
        let params = tune(ctx, &mut engine, "tinytext", cfg)?;
        fixed_vals.push(perplexity(&mut engine, &params, &test, Width::m(w))?);
    }
    add_row("Fixed Precision Fine-Tuning", fixed_vals, &mut t);

    // OTARo
    let params = tune(ctx, &mut engine, "tinytext", base_cfg(ctx, Method::Otaro, steps))?;
    add_row("Ours (OTARo)", ppl_row(&mut engine, &params, &test)?, &mut t);

    let md = t.markdown();
    println!("{md}");
    save_table(ctx, "table8", &md);
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 1 — zero-shot accuracy
// ---------------------------------------------------------------------------

pub fn table1(ctx: &Ctx, quick: bool) -> anyhow::Result<()> {
    ensure_pretrained(ctx, quick)?;
    let mut engine = ctx.engine()?;
    let steps = ft_steps(quick);
    let items = mc_items(quick);

    let mut t = TableBuilder::new(
        "Table 1 — zero-shot avg accuracy over 8 suites (instruction FT)",
        &WIDTH_HDR,
    );

    let params = ctx.params(&engine, None)?;
    t.row_f("Before Fine-Tuning", &acc_row(ctx, &mut engine, &params, items)?, pct);

    let params = tune(ctx, &mut engine, "instruct", base_cfg(ctx, Method::Fp, steps))?;
    t.row_f("FP Fine-Tuning", &acc_row(ctx, &mut engine, &params, items)?, pct);

    let mut fixed_vals = Vec::new();
    let lang = ctx.lang();
    for w in Precision::LADDER {
        let cfg = TrainConfig { fixed_m: Some(w), ..base_cfg(ctx, Method::Fixed, steps) };
        let params = tune(ctx, &mut engine, "instruct", cfg)?;
        let mut acc = 0.0;
        for suite in crate::data::ALL_SUITES {
            let its = suite.eval_set(&lang, items, ctx.seed);
            acc += score_items(&mut engine, &params, Width::m(w), &its)?.0 / 8.0;
        }
        fixed_vals.push(acc);
    }
    t.row_f("Fixed Precision Fine-Tuning", &fixed_vals, pct);

    let params = tune(ctx, &mut engine, "instruct", base_cfg(ctx, Method::Otaro, steps))?;
    t.row_f("Ours (OTARo)", &acc_row(ctx, &mut engine, &params, items)?, pct);

    let md = t.markdown();
    println!("{md}");
    save_table(ctx, "table1", &md);
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 3 — uniform vs BPS sampling vs fixed-precision (ΔPPL)
// ---------------------------------------------------------------------------

pub fn fig3(ctx: &Ctx, quick: bool) -> anyhow::Result<()> {
    ensure_pretrained(ctx, quick)?;
    let mut engine = ctx.engine()?;
    let lang = ctx.lang();
    let (_, test) = corpus::tinytext_corpus(&lang, ctx.seed, 8_000, 1_000);
    let steps = ft_steps(quick);

    // fixed-precision reference PPL per width
    let mut fixed = Vec::new();
    for w in Precision::LADDER {
        let cfg = TrainConfig { fixed_m: Some(w), ..base_cfg(ctx, Method::Fixed, steps) };
        let params = tune(ctx, &mut engine, "tinytext", cfg)?;
        fixed.push(perplexity(&mut engine, &params, &test, Width::m(w))?);
    }
    let uni_params = tune(ctx, &mut engine, "tinytext", base_cfg(ctx, Method::Uniform, steps))?;
    let bps_params = tune(ctx, &mut engine, "tinytext", base_cfg(ctx, Method::BpsOnly, steps))?;
    let uni = ppl_row(&mut engine, &uni_params, &test)?;
    let bps = ppl_row(&mut engine, &bps_params, &test)?;

    let mut t = TableBuilder::new(
        "Fig. 3 — ΔPPL vs fixed-precision fine-tuning (negative = better)",
        &WIDTH_HDR,
    );
    let d_uni: Vec<f64> = uni.iter().zip(&fixed).map(|(a, b)| a - b).collect();
    let d_bps: Vec<f64> = bps.iter().zip(&fixed).map(|(a, b)| a - b).collect();
    t.row_f("uniform sampling", &d_uni, f3);
    t.row_f("BPS sampling", &d_bps, f3);
    let md = t.markdown();
    println!("{md}");
    save_table(ctx, "fig3", &md);
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 4 — gradient cosine similarity across bit-widths
// ---------------------------------------------------------------------------

pub fn fig4(ctx: &Ctx) -> anyhow::Result<()> {
    ensure_pretrained(ctx, false)?;
    let mut engine = ctx.engine()?;
    let params = ctx.params(&engine, None)?;
    let lang = ctx.lang();
    let (b, t) = engine.batch_shape();
    let stream = corpus::pretrain_corpus(&lang, ctx.seed, 2_000);
    let mut batcher = StreamBatcher::new(stream, b, t, ctx.seed ^ 0x44);
    let batch = batcher.next_batch();

    let layer = engine.manifest.config.n_layers - 1;
    let mut out = String::new();
    for proj in ["wq", "wk", "wv", "w_down"] {
        let name = format!("layer{layer}.{proj}");
        let mat = cosine_matrix(&mut engine, &params, &batch, &ladder(), &name)?;
        let mut tb = TableBuilder::new(
            &format!("Fig. 4 — grad cosine sims, {name}"),
            &["width", "E5M8", "E5M7", "E5M6", "E5M5", "E5M4", "E5M3"],
        );
        for (i, w) in ladder().into_iter().enumerate() {
            tb.row_f(&w.label(), &mat[i], f3);
        }
        let md = tb.markdown();
        println!("{md}");
        out.push_str(&md);
        out.push('\n');
    }
    save_table(ctx, "fig4", &out);
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 5 — gradient norm errors per width
// ---------------------------------------------------------------------------

pub fn fig5(ctx: &Ctx, quick: bool) -> anyhow::Result<()> {
    ensure_pretrained(ctx, quick)?;
    let mut engine = ctx.engine()?;
    let params = ctx.params(&engine, None)?;
    let lang = ctx.lang();
    let (b, t) = engine.batch_shape();
    let stream = corpus::pretrain_corpus(&lang, ctx.seed, 4_000);
    let mut batcher = StreamBatcher::new(stream, b, t, ctx.seed ^ 0x55);
    let n_batches = if quick { 10 } else { 30 };
    let layer = engine.manifest.config.n_layers - 1;
    let name = format!("layer{layer}.w_down");
    let widths = ladder();
    let traces = norm_error_traces(&mut engine, &params, &mut batcher, &widths, &name, n_batches)?;

    let mut tb = TableBuilder::new(
        &format!("Fig. 5 — ||∇_sefp||-||∇_fp|| over {n_batches} batches, {name}"),
        &["width", "mean", "std", "min", "max"],
    );
    for (w, trace) in widths.iter().zip(&traces) {
        let mut s = crate::metrics::Summary::new();
        for &v in trace {
            s.push(v);
        }
        tb.row(vec![
            w.label(),
            format!("{:.5}", s.mean()),
            format!("{:.5}", s.std()),
            format!("{:.5}", s.min),
            format!("{:.5}", s.max),
        ]);
    }
    let md = tb.markdown();
    println!("{md}");
    save_table(ctx, "fig5", &md);
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 6 — LSM residual Y, E[Y] ≈ 0
// ---------------------------------------------------------------------------

pub fn fig6(ctx: &Ctx, quick: bool) -> anyhow::Result<()> {
    ensure_pretrained(ctx, quick)?;
    let mut engine = ctx.engine()?;
    let mut params = ctx.params(&engine, None)?;
    let lang = ctx.lang();
    let (b, t) = engine.batch_shape();
    let stream = corpus::pretrain_corpus(&lang, ctx.seed, 4_000);
    let mut batcher = StreamBatcher::new(stream, b, t, ctx.seed ^ 0x66);
    let n_batches = if quick { 20 } else { 60 };
    let n_coords = 30; // paper fig. 6 tracks 30 gradient values
    let layer = engine.manifest.config.n_layers - 1;
    let idx = params
        .index_of(&format!("layer{layer}.w_down"))
        .expect("down projector exists");

    // Gradients are sampled DURING training (as in the paper): the weights
    // move between batches, so each batch lands at a different phase of
    // the ε(ω) sawtooth and the residual Y is genuinely stochastic.  With
    // frozen weights the quantization displacement would be systematic
    // and E[Y] would NOT vanish.
    let mut g_fp: Vec<Vec<f64>> = Vec::with_capacity(n_batches);
    let mut g_sefp: Vec<Vec<f64>> = Vec::with_capacity(n_batches);
    for _ in 0..n_batches {
        let batch = batcher.next_batch();
        let fp = engine.train_step(&params, &batch, Width::FP)?;
        let q = engine.train_step(&params, &batch, Width::m(Precision::of(3)))?;
        // spread tracked coordinates across the tensor
        let len = fp.grads[idx].len();
        let stride = (len / n_coords).max(1);
        g_fp.push((0..n_coords).map(|j| fp.grads[idx][j * stride] as f64).collect());
        g_sefp.push((0..n_coords).map(|j| q.grads[idx][j * stride] as f64).collect());
        // advance along the QUANTIZED path (this is OTARo fine-tuning at
        // m=3, where the paper samples fig. 6)
        params.sgd_update(&q.grads, 2e-2);
    }
    let fit = lsm_fit(&g_fp, &g_sefp);
    let mean_abs_y: f64 =
        fit.y_mean.iter().map(|m| m.abs()).sum::<f64>() / fit.y_mean.len() as f64;
    let mean_std: f64 = fit.y_std.iter().sum::<f64>() / fit.y_std.len() as f64;
    // the paper's visual E[Y] ≈ 0 check is over the whole plotted
    // ensemble (30 traces x batches): the signed global mean
    let global_mean: f64 = fit.y.iter().flatten().sum::<f64>()
        / (fit.y.len() * n_coords) as f64;
    let global_std: f64 = {
        let n = (fit.y.len() * n_coords) as f64;
        let var = fit.y.iter().flatten().map(|v| (v - global_mean).powi(2)).sum::<f64>() / n;
        var.sqrt()
    };

    let mut tb = TableBuilder::new(
        "Fig. 6 — LSM residual Y at E5M3 (E[Y] ≈ 0 check)",
        &["stat", "value"],
    );
    tb.row(vec!["batches".into(), n_batches.to_string()]);
    tb.row(vec!["coords".into(), n_coords.to_string()]);
    tb.row(vec!["mean |E[Y_j]|".into(), format!("{mean_abs_y:.3e}")]);
    tb.row(vec!["mean std(Y_j)".into(), format!("{mean_std:.3e}")]);
    tb.row(vec![
        "per-coord |E[Y_j]|/std(Y_j)".into(),
        format!("{:.4}", fit.relative_mean_residual()),
    ]);
    tb.row(vec!["global E[Y]".into(), format!("{global_mean:.3e}")]);
    tb.row(vec!["global std(Y)".into(), format!("{global_std:.3e}")]);
    tb.row(vec![
        "global |E[Y]|/std(Y)  (paper: ≈0)".into(),
        format!("{:.4}", global_mean.abs() / global_std.max(1e-300)),
    ]);
    tb.row(vec![
        "mean X_j (linear gain)".into(),
        format!("{:.4}", fit.x.iter().sum::<f64>() / fit.x.len() as f64),
    ]);
    let md = tb.markdown();
    println!("{md}");
    save_table(ctx, "fig6", &md);
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 8 — ablations: strategies / λ / N
// ---------------------------------------------------------------------------

pub fn fig8(ctx: &Ctx, quick: bool) -> anyhow::Result<()> {
    ensure_pretrained(ctx, quick)?;
    let mut engine = ctx.engine()?;
    let steps = ft_steps(quick);
    let items = mc_items(quick);
    let mut out = String::new();

    // (a) strategies
    let mut tb = TableBuilder::new("Fig. 8a — strategy ablation (zero-shot avg acc)", &WIDTH_HDR);
    for (label, method) in [
        ("uniform", Method::Uniform),
        ("BPS only", Method::BpsOnly),
        ("BPS + LAA (OTARo)", Method::Otaro),
    ] {
        let params = tune(ctx, &mut engine, "instruct", base_cfg(ctx, method, steps))?;
        tb.row_f(label, &acc_row(ctx, &mut engine, &params, items)?, pct);
    }
    let md = tb.markdown();
    println!("{md}");
    out.push_str(&md);

    // (b) λ sweep — E5M8 accuracy like the paper
    let mut tb = TableBuilder::new("Fig. 8b — λ sweep (avg acc at E5M8 / E5M3)", &["λ", "E5M8", "E5M3"]);
    for lambda in [3.0, 4.0, 5.0, 6.0, 7.0] {
        let cfg = TrainConfig { lambda, ..base_cfg(ctx, Method::Otaro, steps) };
        let params = tune(ctx, &mut engine, "instruct", cfg)?;
        let accs = acc_row(ctx, &mut engine, &params, items)?;
        tb.row_f(&format!("{lambda}"), &[accs[0], accs[5]], pct);
    }
    let md = tb.markdown();
    println!("{md}");
    out.push_str(&md);

    // (c) N sweep
    let mut tb = TableBuilder::new("Fig. 8c — LAA delay N sweep (avg acc at E5M8 / E5M3)", &["N", "E5M8", "E5M3"]);
    for n in [5usize, 10, 20] {
        let cfg = TrainConfig { delay_n: n, ..base_cfg(ctx, Method::Otaro, steps) };
        let params = tune(ctx, &mut engine, "instruct", cfg)?;
        let accs = acc_row(ctx, &mut engine, &params, items)?;
        tb.row_f(&format!("{n}"), &[accs[0], accs[5]], pct);
    }
    let md = tb.markdown();
    println!("{md}");
    out.push_str(&md);
    save_table(ctx, "fig8", &out);
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 9 — ε(ω) sawtooth
// ---------------------------------------------------------------------------

pub fn fig9(ctx: &Ctx) -> anyhow::Result<()> {
    let mut out = String::new();
    let mut tb = TableBuilder::new("Fig. 9 — ε(ω) sawtooth amplitude per mantissa width", &["m", "amplitude", "1/2^m"]);
    for p in Precision::LADDER {
        let curve = epsilon_curve(p, 0.0, 1.0, 8001, Rounding::Trunc);
        tb.row(vec![
            format!("{}", p.m()),
            format!("{:.6}", amplitude(&curve)),
            format!("{:.6}", 1.0 / (1u32 << p.m()) as f64),
        ]);
    }
    let md = tb.markdown();
    println!("{md}");
    out.push_str(&md);
    let curve = epsilon_curve(Precision::of(3), 0.0, 0.6, 400, Rounding::Trunc);
    let plot = ascii_plot(&curve, 10, 72);
    println!("ε(ω) at m=3 over [0, 0.6]:\n{plot}\n");
    out.push_str(&format!("\n```\n{plot}\n```\n"));
    save_table(ctx, "fig9", &out);
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 2 — memory + decode throughput, FP16 vs SEFP-E5M4
// ---------------------------------------------------------------------------

pub fn table2(ctx: &Ctx, quick: bool) -> anyhow::Result<()> {
    // full mode uses scale=4 (612 MB fp32-equivalent weights) so the
    // weight stream is far outside LLC — the bandwidth-bound regime the
    // paper's on-device numbers live in; quick mode stays cache-friendly
    let scale = if quick { 16 } else { 4 };
    let cfg = SimConfig::llama8b_scaled(scale);
    let n_tokens = if quick { 12 } else { 30 };

    let mut dense = DecoderSim::new(cfg, DecoderWeights::Dense, ctx.seed);
    let mut sefp4 = DecoderSim::new(cfg, DecoderWeights::Sefp(Precision::of(4)), ctx.seed);

    // paper setup: 2000-token input already prefilled, then decode
    let prefill = cfg.context;
    let (fp_tps, c1) = dense.decode_throughput_prefilled(n_tokens, prefill, ctx.seed);
    let (q_tps, c2) = sefp4.decode_throughput_prefilled(n_tokens, prefill, ctx.seed);
    assert!(c1.is_finite() && c2.is_finite());

    // memory: weights (analytic fp16 vs packed) + MEASURED cache bytes
    let fp_mem = (dense.weight_bytes() + dense.cache_bytes()) as f64 / (1024.0 * 1024.0);
    let q_mem = (sefp4.weight_bytes() + sefp4.cache_bytes()) as f64 / (1024.0 * 1024.0);

    let mut tb = TableBuilder::new(
        &format!(
            "Table 2 — memory + decode throughput (LLaMA8B/{scale} sim, {} weights, context {})",
            cfg.n_weights(),
            cfg.context
        ),
        &["precision", "Mem (MiB)", "Dec. Thpt (tok/s)", "vs FP16"],
    );
    tb.row(vec![
        "FP16".into(),
        format!("{fp_mem:.2}"),
        format!("{fp_tps:.2}"),
        "1.00x / -0%".into(),
    ]);
    tb.row(vec![
        "SEFP-E5M4".into(),
        format!("{q_mem:.2}"),
        format!("{q_tps:.2}"),
        format!("{:.2}x / -{:.0}%", q_tps / fp_tps, 100.0 * (1.0 - q_mem / fp_mem)),
    ]);
    let md = tb.markdown();
    println!("{md}");
    save_table(ctx, "table2", &md);
    Ok(())
}

// ---------------------------------------------------------------------------
// Extra ablations (DESIGN.md §6) — beyond the paper's fig. 8
// ---------------------------------------------------------------------------

pub fn ablations(ctx: &Ctx, quick: bool) -> anyhow::Result<()> {
    ensure_pretrained(ctx, quick)?;
    let mut engine = ctx.engine()?;
    let lang = ctx.lang();
    let (_, test) = corpus::tinytext_corpus(&lang, ctx.seed, 8_000, 1_000);
    let steps = ft_steps(quick);
    let mut out = String::new();

    // (a) LAA ultra-low threshold: which widths count as "ultra-low"
    let mut tb = TableBuilder::new(
        "Ablation A — LAA ultra-low threshold (PPL, OTARo)",
        &["ultra_low_max_m", "E5M8", "E5M4", "E5M3", "AVG"],
    );
    for ul in [3u8, 4, 5] {
        let cfg = TrainConfig {
            ultra_low_max: Precision::of(ul),
            ..base_cfg(ctx, Method::Otaro, steps)
        };
        let params = tune(ctx, &mut engine, "tinytext", cfg)?;
        let row = ppl_row(&mut engine, &params, &test)?;
        let avg = row.iter().sum::<f64>() / row.len() as f64;
        tb.row_f(&format!("m<={ul}"), &[row[0], row[4], row[5], avg], f2);
    }
    let md = tb.markdown();
    println!("{md}");
    out.push_str(&md);

    // (b) accumulator persistence vs flush-on-switch
    let mut tb = TableBuilder::new(
        "Ablation B — LAA accumulator policy (PPL, OTARo)",
        &["policy", "E5M8", "E5M4", "E5M3", "AVG"],
    );
    for (label, fos) in [("persist (default)", false), ("flush on switch", true)] {
        let cfg = TrainConfig {
            laa_flush_on_switch: fos,
            ..base_cfg(ctx, Method::Otaro, steps)
        };
        let params = tune(ctx, &mut engine, "tinytext", cfg)?;
        let row = ppl_row(&mut engine, &params, &test)?;
        let avg = row.iter().sum::<f64>() / row.len() as f64;
        tb.row_f(label, &[row[0], row[4], row[5], avg], f2);
    }
    let md = tb.markdown();
    println!("{md}");
    out.push_str(&md);

    // (c) delayed update: mean (ours) vs the paper's raw sum (eq. 18) at
    // this repo's learning rate — shows why the deviation was needed
    let mut tb = TableBuilder::new(
        "Ablation C — LAA update normalization (PPL, OTARo)",
        &["update", "E5M8", "E5M4", "E5M3", "AVG"],
    );
    for (label, avg_mode) in [("mean Σ∇/N (repo default)", true), ("raw sum Σ∇ (paper eq.18)", false)] {
        let cfg = TrainConfig { laa_average: avg_mode, ..base_cfg(ctx, Method::Otaro, steps) };
        let params = tune(ctx, &mut engine, "tinytext", cfg)?;
        let row = ppl_row(&mut engine, &params, &test)?;
        let avg = row.iter().sum::<f64>() / row.len() as f64;
        tb.row_f(label, &[row[0], row[4], row[5], avg], f2);
    }
    let md = tb.markdown();
    println!("{md}");
    out.push_str(&md);

    // (d) serving-side rounding mode: encode the (fp-tuned) master with
    // trunc vs nearest and evaluate the switched weights at each width
    let mut tb = TableBuilder::new(
        "Ablation D — SEFP rounding mode at switch time (PPL of rust-quantized weights)",
        &["rounding", "E5M8", "E5M5", "E5M3"],
    );
    let params = tune(ctx, &mut engine, "tinytext", base_cfg(ctx, Method::Fp, steps))?;
    for rounding in [Rounding::Trunc, Rounding::Nearest] {
        let mut row = Vec::new();
        for m in [8u8, 5, 3] {
            let spec = SefpSpec::new(Precision::of(m)).with_rounding(rounding);
            let mut q = params.clone();
            for (i, t) in q.tensors.iter_mut().enumerate() {
                if q.quantized[i] {
                    *t = crate::sefp::quant_dequant(t, &spec);
                }
            }
            row.push(perplexity(&mut engine, &q, &test, Width::FP)?);
        }
        tb.row_f(&format!("{rounding:?}"), &row, f2);
    }
    let md = tb.markdown();
    println!("{md}");
    out.push_str(&md);

    save_table(ctx, "ablations", &out);
    Ok(())
}
