//! Experiment drivers: lifecycle commands and the bench harness that
//! regenerates every table/figure of the paper (DESIGN.md §4).

pub mod benches;

use std::path::PathBuf;

use crate::config::{Method, TrainConfig};
use crate::coordinator::Trainer;
use crate::data::{corpus, Lang, PairBatcher, StreamBatcher};
use crate::eval::mc::score_items;
use crate::eval::ppl::perplexity;
use crate::eval::tables::{f2, pct, TableBuilder};
use crate::metrics::MetricsSink;
use crate::runtime::{Engine, ParamStore, Width};
use crate::sefp::Precision;
use crate::serve::{
    DecoderBackend, DynamicBatcher, LogitsBackend, PrecisionLadder, Request, Router, SchedPolicy,
    Server, TaskClass,
};

/// Shared CLI context.
pub struct Ctx {
    pub artifacts: PathBuf,
    pub runs: PathBuf,
    pub seed: u64,
}

impl Ctx {
    pub fn engine(&self) -> anyhow::Result<Engine> {
        Engine::new(&self.artifacts)
    }

    pub fn lang(&self) -> Lang {
        Lang::new(self.seed ^ 0x1A06)
    }

    pub fn pretrained_path(&self) -> PathBuf {
        self.runs.join("pretrained.bin")
    }

    pub fn sink(&self, name: &str) -> MetricsSink {
        MetricsSink::to_file(&self.runs.join(format!("{name}.jsonl")))
            .unwrap_or_else(|_| MetricsSink::null())
    }

    /// Load params: explicit checkpoint > pretrained.bin > init.
    pub fn params(&self, engine: &Engine, checkpoint: Option<PathBuf>) -> anyhow::Result<ParamStore> {
        self.params_from_manifest(&engine.manifest, checkpoint)
    }

    /// Like [`params`](Self::params) but engine-free: resolves shapes
    /// from the training manifest alone, so the PJRT-free serve path
    /// (`serve-demo --backend decoder`) never constructs an engine.
    pub fn params_from_manifest(
        &self,
        manifest: &crate::runtime::Manifest,
        checkpoint: Option<PathBuf>,
    ) -> anyhow::Result<ParamStore> {
        let mut params =
            ParamStore::from_manifest_bin(manifest, &self.artifacts.join("init_params.bin"))?;
        let path = checkpoint.unwrap_or_else(|| self.pretrained_path());
        if path.exists() {
            params.load_into(&path)?;
            eprintln!("loaded checkpoint {}", path.display());
        } else {
            eprintln!("no checkpoint at {} — using init params", path.display());
        }
        Ok(params)
    }
}

/// The paper's ladder as engine widths.
pub fn ladder() -> Vec<Width> {
    Precision::LADDER.into_iter().map(Width::m).collect()
}

pub fn info(ctx: &Ctx) -> anyhow::Result<()> {
    let engine = ctx.engine()?;
    let m = &engine.manifest;
    println!("preset:       {}", m.preset);
    println!("quant impl:   {}", m.quant_impl);
    println!(
        "model:        d={} h={} L={} ff={} V={} T={} B={}",
        m.config.d_model,
        m.config.n_heads,
        m.config.n_layers,
        m.config.d_ff,
        m.config.vocab_size,
        m.config.max_seq,
        m.config.batch_size
    );
    println!("params:       {} tensors, {} total", m.params.len(), m.total_params());
    println!("widths:       {:?}", m.mantissa_widths);
    println!("artifacts:    {}", m.artifacts.len());
    if let Some(p) = m.sefp_artifact() {
        println!("sefp master:  {p}");
    }
    Ok(())
}

pub fn pretrain(ctx: &Ctx, steps: usize, lr: f32, out: Option<PathBuf>) -> anyhow::Result<()> {
    let mut engine = ctx.engine()?;
    let mut params = engine.init_params()?;
    let lang = ctx.lang();
    let (b, t) = engine.batch_shape();
    let stream = corpus::pretrain_corpus(&lang, ctx.seed, 12_000);
    let mut batches = StreamBatcher::new(stream, b, t, ctx.seed ^ 0x9);
    let cfg = TrainConfig {
        method: Method::Fp,
        lr,
        steps,
        ..TrainConfig::default()
    };
    let mut sink = ctx.sink("pretrain");
    let out = out.unwrap_or_else(|| ctx.pretrained_path());
    let mut trainer = Trainer::new(&mut engine, &mut params, &mut batches, cfg);
    let report = trainer.run(&mut sink)?;
    let sefp = trainer.save_checkpoint(&out)?;
    println!(
        "pretrained {} steps: loss {:.3} -> {:.3} (ema {:.3}), saved {} (+ packed master {})",
        steps,
        report.losses.first().copied().unwrap_or(f32::NAN),
        report.losses.last().copied().unwrap_or(f32::NAN),
        report.final_loss_ema,
        out.display(),
        sefp.display()
    );
    Ok(())
}

#[allow(clippy::too_many_arguments)]
pub fn finetune(
    ctx: &Ctx,
    method: &str,
    steps: usize,
    lr: f32,
    fixed_m: Option<Precision>,
    dataset: &str,
    checkpoint: Option<PathBuf>,
    out: Option<PathBuf>,
) -> anyhow::Result<()> {
    let mut engine = ctx.engine()?;
    let mut params = ctx.params(&engine, checkpoint)?;
    let lang = ctx.lang();
    let (b, t) = engine.batch_shape();
    let cfg = TrainConfig {
        method: method.parse().map_err(|e: String| anyhow::anyhow!(e))?,
        lr,
        steps,
        fixed_m,
        seed: ctx.seed,
        ..TrainConfig::default()
    };
    let mut sink = ctx.sink(&format!("finetune_{method}"));
    let out = out.unwrap_or_else(|| ctx.runs.join(format!("finetuned_{method}.bin")));
    let (report, sefp) = match dataset {
        "tinytext" => {
            let (train, _) = corpus::tinytext_corpus(&lang, ctx.seed, 8_000, 1_000);
            let mut batches = StreamBatcher::new(train, b, t, ctx.seed ^ 0x5);
            let mut trainer = Trainer::new(&mut engine, &mut params, &mut batches, cfg);
            let report = trainer.run(&mut sink)?;
            (report, trainer.save_checkpoint(&out)?)
        }
        "instruct" => {
            let pairs = corpus::instruct_corpus(&lang, ctx.seed, 4_000);
            let mut batches = PairBatcher::new(pairs, b, t, ctx.seed ^ 0x6);
            let mut trainer = Trainer::new(&mut engine, &mut params, &mut batches, cfg);
            let report = trainer.run(&mut sink)?;
            (report, trainer.save_checkpoint(&out)?)
        }
        other => anyhow::bail!("unknown dataset {other:?} (tinytext|instruct)"),
    };
    println!(
        "finetuned [{method}] {} steps, final ema loss {:.3}, path hist {:?}, laa flush/defer {}/{}; saved {} (+ packed master {})",
        steps,
        report.final_loss_ema,
        report.width_histogram,
        report.laa_flushes,
        report.laa_deferred,
        out.display(),
        sefp.display()
    );
    Ok(())
}

pub fn eval_checkpoint(ctx: &Ctx, checkpoint: Option<PathBuf>, mc_items: usize) -> anyhow::Result<()> {
    let mut engine = ctx.engine()?;
    let params = ctx.params(&engine, checkpoint)?;
    let lang = ctx.lang();
    let (_, test) = corpus::tinytext_corpus(&lang, ctx.seed, 8_000, 1_000);

    let mut t = TableBuilder::new("PPL by precision", &["metric", "E5M8", "E5M7", "E5M6", "E5M5", "E5M4", "E5M3", "FP"]);
    let mut vals = Vec::new();
    for w in ladder() {
        vals.push(perplexity(&mut engine, &params, &test, w)?);
    }
    vals.push(perplexity(&mut engine, &params, &test, Width::FP)?);
    t.row_f("ppl", &vals, f2);
    println!("{}", t.markdown());

    let mut t = TableBuilder::new(
        "Zero-shot accuracy by precision",
        &["suite", "E5M8", "E5M7", "E5M6", "E5M5", "E5M4", "E5M3"],
    );
    let mut avgs = vec![0.0; 6];
    for suite in crate::data::ALL_SUITES {
        let items = suite.eval_set(&lang, mc_items, ctx.seed);
        let mut row = Vec::new();
        for (i, w) in ladder().into_iter().enumerate() {
            let (acc, _) = score_items(&mut engine, &params, w, &items)?;
            avgs[i] += acc / 8.0;
            row.push(acc);
        }
        t.row_f(suite.name(), &row, pct);
    }
    t.row_f("AVG", &avgs, pct);
    println!("{}", t.markdown());
    Ok(())
}

/// Resolve the serving master — packed `.sefp` artifact vs f32
/// checkpoint — and build the serving [`PrecisionLadder`].
///
/// A packed master (config `sefp_artifact`, or recorded in the training
/// manifest) skips the f32 parse + encode on startup.  An explicit
/// `--checkpoint` always wins — the artifact may hold other weights; a
/// config-specified artifact must exist (a typo is a config error, not a
/// silent fallback), and a manifest-recorded one may be stale so it
/// falls back with a warning.  When serving packed, `serve_cfg.ladder`
/// is clamped to the artifact top so the router snaps every class to a
/// servable rung instead of erroring at `view_at` time.  `manifest` is
/// optional: the decoder backend can serve a config-specified artifact
/// with no training manifest present at all (the container is
/// self-describing); the f32 path requires one for shapes.
fn build_serve_ladder(
    ctx: &Ctx,
    manifest: Option<&crate::runtime::Manifest>,
    checkpoint: Option<PathBuf>,
    serve_cfg: &mut crate::config::ServeConfig,
) -> anyhow::Result<PrecisionLadder> {
    let artifact_path = if checkpoint.is_some() {
        None
    } else if let Some(p) = serve_cfg.sefp_artifact.clone() {
        anyhow::ensure!(
            p.exists(),
            "configured sefp_artifact {} does not exist",
            p.display()
        );
        Some(p)
    } else {
        match manifest
            .and_then(|m| m.sefp_artifact())
            .map(|p| ctx.artifacts.join(p))
        {
            Some(p) if p.exists() => Some(p),
            Some(p) => {
                eprintln!(
                    "manifest records sefp master {} but it is missing — serving from the \
                     f32 checkpoint instead",
                    p.display()
                );
                None
            }
            None => None,
        }
    };
    let ladder = match artifact_path {
        Some(p) => {
            let a = crate::artifact::Artifact::open(&p)?;
            // the container is self-consistent, but it must also be THIS
            // model: a stale/mismatched artifact would otherwise surface
            // as a shape panic or garbage logits on the first request
            if let Some(manifest) = manifest {
                anyhow::ensure!(
                    a.tensors().len() == manifest.params.len(),
                    "artifact {} holds {} tensors, engine manifest lists {}",
                    p.display(),
                    a.tensors().len(),
                    manifest.params.len()
                );
                for (tm, pe) in a.tensors().iter().zip(&manifest.params) {
                    anyhow::ensure!(
                        tm.name == pe.name && tm.shape == pe.shape,
                        "artifact tensor {:?} {:?} does not match the engine manifest \
                         ({:?} {:?}) — wrong artifact for this model",
                        tm.name,
                        tm.shape,
                        pe.name,
                        pe.shape
                    );
                }
            }
            let top = a.meta().top;
            println!(
                "serving from packed artifact {} ({} KiB at {top})",
                p.display(),
                a.file_len() / 1024
            );
            serve_cfg.ladder.retain(|&w| w <= top);
            anyhow::ensure!(
                !serve_cfg.ladder.is_empty(),
                "serve ladder has no rung at or below the {top} artifact master"
            );
            PrecisionLadder::from_artifact(&a)?
        }
        None => {
            // f32 checkpoint startup: read + parse + encode the master
            let manifest = manifest.ok_or_else(|| {
                anyhow::anyhow!(
                    "no training manifest in {} and no sefp_artifact configured — \
                     nothing to serve",
                    ctx.artifacts.display()
                )
            })?;
            let params = ctx.params_from_manifest(manifest, checkpoint)?;
            PrecisionLadder::from_params(&params)
        }
    }
    .with_budget(serve_cfg.ladder_budget_bytes);
    println!(
        "single-master SEFP ladder: {} KiB (per-precision zoo would be {} KiB)",
        ladder.master_bytes() / 1024,
        ladder.zoo_bytes(&Precision::LADDER) / 1024
    );
    Ok(ladder)
}

pub fn serve_demo(
    ctx: &Ctx,
    n_requests: usize,
    checkpoint: Option<PathBuf>,
    serve_config: Option<PathBuf>,
    backend: &str,
) -> anyhow::Result<()> {
    let mut serve_cfg = match &serve_config {
        Some(p) => {
            let text = std::fs::read_to_string(p)
                .map_err(|e| anyhow::anyhow!("cannot read serve config {p:?}: {e}"))?;
            crate::config::ServeConfig::from_json(&crate::json::parse(&text)?)?
        }
        None => crate::config::ServeConfig::default(),
    };
    match backend {
        // PJRT engine over AOT artifacts (requires a real PJRT plugin)
        "engine" => {
            let engine = ctx.engine()?;
            let ladder =
                build_serve_ladder(ctx, Some(&engine.manifest), checkpoint, &mut serve_cfg)?;
            // from_config honors serve_cfg.policy.adaptive (Router::new
            // would pin StaticPolicy and silently ignore the config flag)
            let router = Router::from_config(serve_cfg.clone());
            let batcher = DynamicBatcher::new(engine.batch_size(), 256)
                .with_policy(SchedPolicy::from_config(&serve_cfg));
            let server = Server::new(engine.into_handle(), ladder, router, batcher);
            drive_serve(ctx, server, n_requests)
        }
        // pure-Rust batched SEFP decode engine: real logits end-to-end,
        // no PJRT and no AOT artifacts needed (the default)
        "decoder" => {
            // a MISSING manifest is fine (a config-specified artifact is
            // self-describing), but a present-yet-unloadable one is an
            // error to surface, not to swallow — silently dropping it
            // would also skip the artifact-vs-manifest cross-check
            let manifest = match crate::runtime::Manifest::load(&ctx.artifacts) {
                Ok(m) => Some(m),
                Err(e) if ctx.artifacts.join("manifest.json").exists() => {
                    anyhow::bail!(
                        "manifest in {} exists but failed to load: {e}",
                        ctx.artifacts.display()
                    )
                }
                Err(_) => None,
            };
            let ladder =
                build_serve_ladder(ctx, manifest.as_ref(), checkpoint, &mut serve_cfg)?;
            let seq_len = manifest.as_ref().map_or(32, |m| m.config.max_seq);
            let backend = DecoderBackend::from_ladder(
                &ladder,
                serve_cfg.max_batch,
                seq_len,
                serve_cfg.decode_threads,
            )?;
            let cfg = backend.sim_config();
            println!(
                "pure-Rust decode backend: {} layers, d={} ff={} V={} \
                 ({} rows x {} window, {} matmul thread(s))",
                cfg.n_layers,
                cfg.d_model,
                cfg.d_ff,
                cfg.vocab,
                serve_cfg.max_batch,
                seq_len,
                serve_cfg.decode_threads
            );
            let router = Router::from_config(serve_cfg.clone());
            let batcher = DynamicBatcher::new(serve_cfg.max_batch, 256)
                .with_policy(SchedPolicy::from_config(&serve_cfg));
            let server = Server::new(backend, ladder, router, batcher);
            drive_serve(ctx, server, n_requests)
        }
        other => anyhow::bail!("unknown serve backend {other:?} (decoder|engine)"),
    }
}

/// Shared serve-demo traffic loop over any [`LogitsBackend`].
fn drive_serve<B: LogitsBackend>(
    ctx: &Ctx,
    mut server: Server<B>,
    n_requests: usize,
) -> anyhow::Result<()> {
    let lang = ctx.lang();
    let tok = crate::data::Tokenizer::new();
    let mut rng = crate::data::Rng::new(ctx.seed ^ 0x53);
    let mut submitted = 0;
    for i in 0..n_requests {
        let class = match i % 3 {
            0 => TaskClass::Generation,
            1 => TaskClass::Understanding,
            _ => TaskClass::Other,
        };
        let prompt = tok.encode_with_bos(&lang.sentence(&mut rng));
        // generation requests decode a few tokens, the rest are
        // next-token — exercises the continuous-batching refill
        let max_new = if matches!(class, TaskClass::Generation) { 4 } else { 1 };
        let req = Request::new(i as u64, class, prompt).with_max_new_tokens(max_new);
        if server.submit(req) {
            submitted += 1;
        }
    }
    let responses = server.process_all()?;
    let stats = server.stats();
    println!(
        "served {}/{} requests ({} tokens, {} decode steps) in {} scheduled runs; \
         {:.1} req/s / {:.1} tok/s",
        responses.len(),
        submitted,
        stats.tokens_generated,
        stats.decode_steps,
        stats.batches,
        stats.throughput_rps(),
        stats.throughput_tps()
    );
    println!(
        "compute ms: mean {:.1} (min {:.1} max {:.1}); widths {:?}",
        stats.compute_ms.mean(),
        stats.compute_ms.min,
        stats.compute_ms.max,
        stats.per_precision
    );
    println!(
        "ladder switches: {} hits / {} misses / {} evictions; resident {} B",
        stats.switch_hits, stats.switch_misses, stats.switch_evictions,
        stats.ladder_resident_bytes
    );
    let mut sink = ctx.sink("serve_demo");
    for r in &responses {
        sink.log(&crate::json::obj(vec![
            ("id", crate::json::n(r.id as f64)),
            ("m", crate::json::n(r.precision.m() as f64)),
            ("next", crate::json::n(r.next_token as f64)),
            ("n_tokens", crate::json::n(r.tokens.len() as f64)),
            ("queue_ms", crate::json::n(r.queue_ms)),
            ("compute_ms", crate::json::n(r.compute_ms)),
        ]));
    }
    Ok(())
}

/// `otaro pack`: f32 checkpoint -> packed `.sefp` container.  Reads the
/// training manifest for shapes/config (no PJRT engine needed), so it
/// runs anywhere the artifacts dir exists.
pub fn pack_artifact(
    ctx: &Ctx,
    checkpoint: Option<PathBuf>,
    out: Option<PathBuf>,
    top: Option<Precision>,
) -> anyhow::Result<()> {
    let manifest = crate::runtime::Manifest::load(&ctx.artifacts)?;
    let bin = match checkpoint {
        Some(p) => p,
        None => {
            let pre = ctx.pretrained_path();
            if pre.exists() {
                pre
            } else {
                ctx.artifacts.join("init_params.bin")
            }
        }
    };
    let params = ParamStore::from_manifest_bin(&manifest, &bin)?;
    let top = top
        .or_else(|| manifest.mantissa_widths.iter().copied().max())
        .unwrap_or(Precision::of(8));
    let meta = crate::artifact::ArtifactMeta {
        top,
        group_size: manifest.config.group_size,
        rounding: manifest
            .config
            .rounding
            .parse()
            .map_err(|e: String| anyhow::anyhow!("manifest rounding: {e}"))?,
        config: Some(manifest.config.clone()),
    };
    let out = out.unwrap_or_else(|| bin.with_extension("sefp"));
    let written = crate::artifact::write_artifact(&out, &params, &meta)?;
    let f32_bytes = params.total_len() * 4 + 4096; // + sidecar order of magnitude
    println!(
        "packed {} tensors ({} weights) at {top} -> {} ({} KiB; f32 checkpoint {} KiB, \
         {:.1}% of f32)",
        params.tensors.len(),
        params.total_len(),
        out.display(),
        written / 1024,
        (params.total_len() * 4) / 1024,
        written as f64 / f32_bytes as f64 * 100.0
    );
    println!(
        "record it in manifest.json under artifacts.{} to serve from it",
        crate::runtime::manifest::SEFP_MASTER_KEY
    );
    Ok(())
}

/// `otaro inspect`: decode a `.sefp` container's header, index, and
/// per-rung deployment footprint without touching any weights.
pub fn inspect_artifact(path: &std::path::Path) -> anyhow::Result<()> {
    let a = crate::artifact::Artifact::open(path)?;
    let h = a.header();
    let meta = a.meta();
    println!("{}", path.display());
    println!(
        "  format v{} · {} bytes (manifest {} B @ {}, index {} tensors @ {}, data @ {})",
        h.version,
        h.file_len,
        h.manifest_len,
        h.manifest_off,
        h.tensor_count,
        h.index_off,
        h.data_off
    );
    println!(
        "  top {} · group_size {} · rounding {} · checksums OK",
        meta.top, meta.group_size, meta.rounding
    );
    if let Some(c) = &meta.config {
        println!(
            "  model: d={} h={} L={} ff={} V={} T={}",
            c.d_model, c.n_heads, c.n_layers, c.d_ff, c.vocab_size, c.max_seq
        );
    }
    println!(
        "  {:<18} {:>12} {:>8} {:>10}  {:<10} checksum",
        "tensor", "elems", "groups", "bytes", "kind"
    );
    for (tm, e) in a.tensors().iter().zip(a.index()) {
        println!(
            "  {:<18} {:>12} {:>8} {:>10}  {:<10} {:#018x}",
            tm.name,
            e.len,
            e.n_groups,
            e.data_len,
            if tm.quantized { "sefp" } else { "raw f32" },
            e.checksum
        );
    }
    println!("  ladder report (borrowed bytes per rung, vs f32 master):");
    let f32_bytes: usize = a.tensors().iter().map(|t| t.shape.iter().product::<usize>() * 4).sum();
    for p in Precision::LADDER {
        if p > meta.top {
            continue;
        }
        let bytes = a.view_bytes_at(p);
        println!(
            "    {p}: {:>10} B  ({:.1}% of f32)",
            bytes,
            bytes as f64 / f32_bytes.max(1) as f64 * 100.0
        );
    }
    Ok(())
}

pub fn bench(ctx: &Ctx, target: &str, quick: bool) -> anyhow::Result<()> {
    match target {
        "table1" => benches::table1(ctx, quick),
        "table2" => benches::table2(ctx, quick),
        "table8" | "fig7" => benches::table8(ctx, quick),
        "fig3" => benches::fig3(ctx, quick),
        "fig4" => benches::fig4(ctx),
        "fig5" => benches::fig5(ctx, quick),
        "fig6" => benches::fig6(ctx, quick),
        "fig8" => benches::fig8(ctx, quick),
        "fig9" => benches::fig9(ctx),
        "ablations" => benches::ablations(ctx, quick),
        "all" => {
            for t in [
                "fig9", "fig4", "fig5", "fig6", "table2", "fig3", "table8", "fig8",
                "table1", "ablations",
            ] {
                println!("\n===== bench {t} =====");
                bench(ctx, t, quick)?;
            }
            Ok(())
        }
        other => anyhow::bail!("unknown bench target {other:?}"),
    }
}
