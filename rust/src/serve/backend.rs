//! Logits backends: the one-step interface the generation engine drives.
//!
//! [`Server`](super::Server) owns its backend (no lifetime-bound
//! `&mut Engine`).  A precision run starts with
//! [`load_view`](LogitsBackend::load_view) — the backend receives the
//! SEFP-domain [`LadderView`](super::LadderView) for the scheduled
//! precision — then drives one
//! [`logits_step`](LogitsBackend::logits_step) per decode iteration.
//!
//! Production uses [`EngineHandle`] over the PJRT engine: `load_view`
//! decodes the view into ONE reusable f32 scratch `ParamStore` (the PJRT
//! ABI takes f32 literals; this is the only float materialization on the
//! serve path, and at most one copy is ever resident — switching
//! precision overwrites it instead of growing a per-width zoo).  Tests
//! and `bench_serve` use [`SimBackend`], a deterministic pure-Rust
//! stand-in, so the scheduler and the continuous-batching decode loop are
//! exercised without AOT artifacts.

use crate::data::tokenizer::PAD;
use crate::data::Rng;
use crate::infer::{proj_dims, DecoderSim, QuantLinear, SimConfig};
use crate::runtime::{Engine, ParamStore, Width};
use crate::sefp::{Precision, SefpTensor};

use super::store::{LadderTensor, LadderView, PrecisionLadder};

/// One forward step over the engine's fixed (B, T) token matrix,
/// returning flat (B, T, V) logits, at the precision loaded by
/// `load_view`.
pub trait LogitsBackend {
    /// (batch rows, sequence length) of one step call.
    fn batch_shape(&self) -> (usize, usize);
    fn vocab_size(&self) -> usize;
    /// Install the weights for the upcoming precision run.
    fn load_view(&mut self, view: &LadderView) -> anyhow::Result<()>;
    /// One decode step at the loaded precision.
    fn logits_step(&mut self, tokens: &[i32]) -> anyhow::Result<Vec<f32>>;
    /// Backend-specific gauges for the obs registry, as (name, value)
    /// pairs; the server surfaces each as `backend.<name>`.  Called at
    /// reporting cadence, never inside the decode loop.
    fn obs_gauges(&self) -> Vec<(&'static str, f64)> {
        Vec::new()
    }
    /// Drain synthetic latency/fault events queued since the last call.
    /// Only [`InjectedBackend`](crate::obs::inject::InjectedBackend)
    /// produces any; real backends inherit this empty default.  The
    /// server drains after each `logits_step` and records the events
    /// into the request trace.
    fn take_injected(&mut self) -> Vec<crate::obs::inject::InjectEvent> {
        Vec::new()
    }
    /// Enable/disable stage profiling ([`obs::profile`]).  Backends
    /// without internal stages inherit this no-op default; wrappers
    /// forward to the wrapped backend.
    ///
    /// [`obs::profile`]: crate::obs::profile
    fn set_profiling(&mut self, _on: bool) {}
    /// Drain stage samples buffered since the last call (empty unless
    /// profiling is enabled and the backend times internal stages).
    /// The server drains after each `logits_step` / probe and records
    /// the samples into its per-rung `profile.*` histograms.
    fn take_profile(&mut self) -> Vec<crate::obs::profile::StageSample> {
        Vec::new()
    }
}

/// Owned handle over the PJRT [`Engine`] — the production backend.
pub struct EngineHandle {
    engine: Engine,
    /// f32 scratch for the currently loaded view, keyed by
    /// (ladder id, precision) so a hot-swapped ladder can never be
    /// served from stale weights (ONE copy, reused)
    loaded: Option<((u64, Precision), ParamStore)>,
}

impl EngineHandle {
    pub fn new(engine: Engine) -> Self {
        EngineHandle { engine, loaded: None }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn engine_mut(&mut self) -> &mut Engine {
        &mut self.engine
    }

    pub fn into_engine(self) -> Engine {
        self.engine
    }
}

impl LogitsBackend for EngineHandle {
    fn batch_shape(&self) -> (usize, usize) {
        self.engine.batch_shape()
    }

    fn vocab_size(&self) -> usize {
        self.engine.vocab_size()
    }

    fn load_view(&mut self, view: &LadderView) -> anyhow::Result<()> {
        // skip the decode when the same view is already loaded (the
        // common continuous-batching case: back-to-back runs at one
        // width); the ladder id keeps a hot-swapped ladder coherent
        let key = (view.ladder_id(), view.precision);
        if self.loaded.as_ref().map(|(k, _)| *k) != Some(key) {
            self.loaded = Some((key, view.to_param_store()));
        }
        Ok(())
    }

    fn logits_step(&mut self, tokens: &[i32]) -> anyhow::Result<Vec<f32>> {
        let ((_, p), params) = self
            .loaded
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("logits_step before load_view"))?;
        self.engine.logits_step(params, tokens, Width::m(*p))
    }
}

/// Deterministic in-process backend for scheduler tests and serving
/// benchmarks: logits are a pure hash of (position token, candidate
/// token, precision), so generations are reproducible bit-for-bit,
/// distinct per precision, and independent of wall clock.
///
/// Two logit models:
/// * default — every precision gets an unrelated hash stream (maximally
///   precision-sensitive; scheduler tests rely on widths disagreeing);
/// * [`with_quality_model`](SimBackend::with_quality_model) — a shared
///   base score plus a per-precision perturbation whose amplitude
///   scales like the SEFP ε(ω) sawtooth, `quality_noise · 2^-m`, so
///   lower widths drift further from the master and the drift is
///   *tunable*.  Policy tests inject quality degradation by raising
///   `quality_noise` mid-run.
pub struct SimBackend {
    pub bsz: usize,
    pub seq_len: usize,
    pub vocab: usize,
    /// logits_step invocations (decode iterations observed)
    pub calls: u64,
    /// load_view invocations (precision runs observed)
    pub loads: u64,
    /// simulated per-step latency — lets scheduler tests and benches
    /// model sustained load in real time (zero = as fast as possible)
    pub step_delay: std::time::Duration,
    /// `Some(noise)` switches to the shared-base quality model
    pub quality_noise: Option<f32>,
    loaded: Option<Precision>,
}

impl SimBackend {
    pub fn new(bsz: usize, seq_len: usize, vocab: usize) -> Self {
        SimBackend {
            bsz,
            seq_len,
            vocab,
            calls: 0,
            loads: 0,
            step_delay: std::time::Duration::ZERO,
            quality_noise: None,
            loaded: None,
        }
    }

    pub fn with_step_delay(mut self, d: std::time::Duration) -> Self {
        self.step_delay = d;
        self
    }

    /// Switch to the quality model: logits become a shared
    /// precision-independent base plus `noise · 2^-m`-scaled
    /// perturbation (see the type docs).
    pub fn with_quality_model(mut self, noise: f32) -> Self {
        self.quality_noise = Some(noise);
        self
    }

    #[inline]
    fn hash(token: i32, cand: usize, salt: u64) -> u64 {
        let mut h = (token as u64)
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add((cand as u64).wrapping_mul(0xBF58476D1CE4E5B9))
            .wrapping_add(salt.wrapping_mul(0x94D049BB133111EB));
        h ^= h >> 29;
        h
    }

    #[inline]
    fn score(token: i32, cand: usize, p: Precision) -> f32 {
        (Self::hash(token, cand, p.m() as u64) % 1000) as f32 / 1000.0
    }

    /// Quality-model score: 24-bit base in [0, 1) shared by every
    /// precision (ties astronomically unlikely, so tiny noise cannot
    /// flip an argmax through a grid collision) + per-precision
    /// perturbation in [-1, 1) scaled by `noise · 2^-m`.
    #[inline]
    fn score_quality(token: i32, cand: usize, p: Precision, noise: f32) -> f32 {
        let base = (Self::hash(token, cand, 0) >> 40) as f32 / (1u64 << 24) as f32;
        let salt = 0x5EFu64 | ((p.m() as u64) << 16);
        let raw = (Self::hash(token, cand, salt) >> 40) as f32 / (1u64 << 23) as f32 - 1.0;
        base + raw * noise * (-(p.m() as f32)).exp2()
    }
}

impl LogitsBackend for SimBackend {
    fn batch_shape(&self) -> (usize, usize) {
        (self.bsz, self.seq_len)
    }

    fn vocab_size(&self) -> usize {
        self.vocab
    }

    fn load_view(&mut self, view: &LadderView) -> anyhow::Result<()> {
        self.loads += 1;
        self.loaded = Some(view.precision);
        Ok(())
    }

    fn logits_step(&mut self, tokens: &[i32]) -> anyhow::Result<Vec<f32>> {
        let p = self
            .loaded
            .ok_or_else(|| anyhow::anyhow!("logits_step before load_view"))?;
        anyhow::ensure!(
            tokens.len() == self.bsz * self.seq_len,
            "SimBackend: batch is {} tokens, shape is {}x{}",
            tokens.len(),
            self.bsz,
            self.seq_len
        );
        self.calls += 1;
        if !self.step_delay.is_zero() {
            std::thread::sleep(self.step_delay);
        }
        let mut out = Vec::with_capacity(tokens.len() * self.vocab);
        match self.quality_noise {
            Some(noise) => {
                for &t in tokens {
                    for v in 0..self.vocab {
                        out.push(Self::score_quality(t, v, p, noise));
                    }
                }
            }
            None => {
                for &t in tokens {
                    for v in 0..self.vocab {
                        out.push(Self::score(t, v, p));
                    }
                }
            }
        }
        Ok(out)
    }

    fn obs_gauges(&self) -> Vec<(&'static str, f64)> {
        vec![("calls", self.calls as f64), ("loads", self.loads as f64)]
    }
}

/// Per-layer projection tensor names, in the decode simulator's
/// projection order (see `infer::DecoderSim::from_quant`) — the naming
/// contract shared with `python/compile/model.py::param_spec`.
const PROJ_NAMES: [&str; 7] = ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"];

/// Pure-Rust SEFP decode backend: a batched [`DecoderSim`] driven
/// straight from [`PrecisionLadder`] views — REAL quantized matmuls and
/// KV-cache attention behind the [`LogitsBackend`] interface, no PJRT
/// artifacts and no f32 weight materialization.
///
/// `load_view` rebuilds the sim's `QuantLinear`s with
/// [`QuantLinear::from_sefp`] (integer copies + step-table lookups) from
/// the view's `tok_embed` and `layer{i}.{wq,wk,wv,wo,w_gate,w_up,w_down}`
/// tensors; the LM head ties to `tok_embed`, and per-token embeddings
/// are dequantized on demand from the head's OWN quantized storage
/// ([`DecoderSim::tied_embed`] — one `d_model` column per token, never a
/// second copy of the largest tensor).
///
/// `logits_step` maps the engine's fixed `(B, T)` token matrix onto the
/// sim's per-row KV caches: a row whose window extends its previous
/// context by one token decodes incrementally (ONE batched step for the
/// whole batch); any other window — a freshly admitted request after a
/// FIFO refill, or a shadow-probe replay — resets that row and replays
/// its prompt through the cache first.  Long contexts keep the cache
/// beyond the sliding window, exactly like the serving loop's rolling
/// window semantics.  Logits are a deterministic function of the call
/// sequence and the loaded view, so scheduler/policy tests that run over
/// [`SimBackend`] run unchanged over this backend.
///
/// Known limit of the stateless `(B, T)` interface: the backend infers
/// continuation-vs-refill from the window alone.  When a dead request's
/// history equals the window length AND a freshly refilled prompt
/// tail-matches its last `T - 1` tokens exactly (generated tokens
/// included), the row is treated as a continuation and conditions on
/// the dead request's pre-window history too.  For histories shorter
/// than the window this is exact (the cache is a pure function of the
/// matched tokens); beyond it the collision needs a `T - 1`-token match
/// against sampled output, which serving traffic does not produce in
/// practice.
pub struct DecoderBackend {
    cfg: SimConfig,
    bsz: usize,
    seq_len: usize,
    threads: usize,
    /// view-tensor index of `tok_embed`
    embed_idx: usize,
    /// view-tensor indices of each layer's projections, `PROJ_NAMES` order
    layer_idx: Vec<[usize; 7]>,
    sim: Option<DecoderSim>,
    /// (ladder id, precision) the sim currently holds — same keying as
    /// [`EngineHandle`], so back-to-back runs at one width skip the rebuild
    loaded: Option<(u64, Precision)>,
    /// full token history decoded into each row's cache
    row_ctx: Vec<Vec<i32>>,
    /// (B × d_model) embedding block for the batched step
    xbuf: Vec<f32>,
    /// single-row embedding scratch for prompt replay
    xrow: Vec<f32>,
    active: Vec<bool>,
    pending: Vec<i32>,
    win_len: Vec<usize>,
    /// `logits_step` invocations (decode iterations observed)
    pub calls: u64,
    /// sim rebuilds (actual precision switches; cache-keyed like
    /// `EngineHandle`, so repeat loads at one width do not count)
    pub loads: u64,
    /// stage profiling requested — re-applied to the sim's recorder on
    /// every rebuild (`load_view` replaces the sim wholesale)
    profiling: bool,
}

impl DecoderBackend {
    /// Derive the model shape from `ladder`'s master view and bind the
    /// engine geometry: `bsz` batch rows, `seq_len` window, `threads`
    /// matmul workers (1 = serial; output is thread-count independent).
    pub fn from_ladder(
        ladder: &PrecisionLadder,
        bsz: usize,
        seq_len: usize,
        threads: usize,
    ) -> anyhow::Result<Self> {
        let master = ladder.master_view();
        let names = master.names();
        let shapes = master.shapes();
        let find = |name: &str| names.iter().position(|n| n == name);
        let embed_idx = find("tok_embed").ok_or_else(|| {
            anyhow::anyhow!("ladder has no tok_embed tensor — not a decoder model")
        })?;
        let eshape = &shapes[embed_idx];
        anyhow::ensure!(eshape.len() == 2, "tok_embed must be 2-D, got {eshape:?}");
        let (vocab, d_model) = (eshape[0], eshape[1]);
        let w_gate0 = find("layer0.w_gate")
            .ok_or_else(|| anyhow::anyhow!("ladder has no layer0.w_gate tensor"))?;
        anyhow::ensure!(
            shapes[w_gate0].len() == 2 && shapes[w_gate0][0] == d_model,
            "layer0.w_gate shape {:?} does not match d_model {d_model}",
            shapes[w_gate0]
        );
        let d_ff = shapes[w_gate0][1];
        // the shared layer-shape contract: infer::proj_dims is the ONE
        // source of the seven projections' (in_dim, out_dim) shapes
        let dims = proj_dims(d_model, d_ff);
        let mut layer_idx = Vec::new();
        for li in 0usize.. {
            if find(&format!("layer{li}.wq")).is_none() {
                break;
            }
            let mut idx = [0usize; 7];
            for (pi, pname) in PROJ_NAMES.iter().enumerate() {
                let name = format!("layer{li}.{pname}");
                let i = find(&name)
                    .ok_or_else(|| anyhow::anyhow!("ladder is missing tensor {name}"))?;
                let w = [dims[pi].0, dims[pi].1];
                anyhow::ensure!(
                    shapes[i] == w,
                    "{name} shape {:?}, want {w:?}",
                    shapes[i]
                );
                anyhow::ensure!(
                    matches!(master.tensors()[i], LadderTensor::Quant(_)),
                    "{name} is not SEFP-quantized in the ladder"
                );
                idx[pi] = i;
            }
            layer_idx.push(idx);
        }
        anyhow::ensure!(!layer_idx.is_empty(), "ladder has no layer0.* projection tensors");
        anyhow::ensure!(
            matches!(master.tensors()[embed_idx], LadderTensor::Quant(_)),
            "tok_embed is not SEFP-quantized in the ladder"
        );
        let bsz = bsz.max(1);
        let cfg = SimConfig { d_model, d_ff, n_layers: layer_idx.len(), vocab, context: seq_len };
        Ok(DecoderBackend {
            cfg,
            bsz,
            seq_len: seq_len.max(1),
            threads: threads.max(1),
            embed_idx,
            layer_idx,
            sim: None,
            loaded: None,
            row_ctx: vec![Vec::new(); bsz],
            xbuf: vec![0.0; bsz * d_model],
            xrow: vec![0.0; d_model],
            active: vec![false; bsz],
            pending: vec![PAD; bsz],
            win_len: vec![0; bsz],
            calls: 0,
            loads: 0,
            profiling: false,
        })
    }

    /// The derived model shape.
    pub fn sim_config(&self) -> SimConfig {
        self.cfg
    }
}

/// The SEFP tensor behind a quantized view slot (passthrough slots are
/// a wiring error for the decode backend).
fn view_quant<'a>(view: &'a LadderView, i: usize) -> anyhow::Result<&'a SefpTensor> {
    match &view.tensors()[i] {
        LadderTensor::Quant(t) => Ok(t),
        LadderTensor::Pass(_) => {
            anyhow::bail!("view tensor {} is not SEFP-quantized", view.names()[i])
        }
    }
}

/// Rebuild one `QuantLinear` from a view slot via the zero-float
/// `from_sefp` path, validating shape and group alignment first so a
/// malformed ladder errors instead of tripping an assert.
fn view_linear(
    view: &LadderView,
    i: usize,
    in_dim: usize,
    out_dim: usize,
) -> anyhow::Result<QuantLinear> {
    let t = view_quant(view, i)?;
    anyhow::ensure!(
        t.len == in_dim * out_dim,
        "view tensor {} holds {} elements, want {in_dim}x{out_dim}",
        view.names()[i],
        t.len
    );
    anyhow::ensure!(
        in_dim % t.group_size == 0,
        "view tensor {}: in_dim {in_dim} not aligned to group size {}",
        view.names()[i],
        t.group_size
    );
    Ok(QuantLinear::from_sefp(t, in_dim, out_dim))
}

/// Head column index for a token id — out-of-range ids wrap
/// deterministically (the tied head's columns ARE the embeddings).
fn token_col(token: i32, vocab: usize) -> usize {
    token.rem_euclid(vocab as i32) as usize
}

/// Would the server's next window for a row whose decoded history is
/// `ctx`, after appending the one new token `w[last]`, be exactly `w`?
/// (The continuous-batching loop always sends the last `seq_len` tokens
/// of `context`; anything else means the row was refilled or replayed.)
fn window_extends(ctx: &[i32], w: &[i32], seq_len: usize) -> bool {
    let n = (ctx.len() + 1).min(seq_len);
    w.len() == n && ctx[ctx.len() - (n - 1)..] == w[..n - 1]
}

impl LogitsBackend for DecoderBackend {
    fn batch_shape(&self) -> (usize, usize) {
        (self.bsz, self.seq_len)
    }

    fn vocab_size(&self) -> usize {
        self.cfg.vocab
    }

    fn load_view(&mut self, view: &LadderView) -> anyhow::Result<()> {
        let key = (view.ladder_id(), view.precision);
        if self.loaded == Some(key) {
            return Ok(());
        }
        let (d, v) = (self.cfg.d_model, self.cfg.vocab);
        let dims = proj_dims(d, self.cfg.d_ff);
        let mut layers = Vec::with_capacity(self.layer_idx.len());
        for idx in &self.layer_idx {
            let mut projs = Vec::with_capacity(7);
            for (pi, &i) in idx.iter().enumerate() {
                projs.push(view_linear(view, i, dims[pi].0, dims[pi].1)?);
            }
            layers.push(projs);
        }
        // tied embedding head: logits[t] = x · embed(t); token
        // embeddings come back out of this same QuantLinear
        let head = view_linear(view, self.embed_idx, d, v)?;
        let mut sim =
            DecoderSim::from_quant(self.cfg, layers, head, self.bsz)?.with_threads(self.threads);
        sim.profile.set_enabled(self.profiling);
        self.sim = Some(sim);
        // a different view invalidates every row's cache contents
        for c in &mut self.row_ctx {
            c.clear();
        }
        self.loaded = Some(key);
        self.loads += 1;
        Ok(())
    }

    fn logits_step(&mut self, tokens: &[i32]) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(
            tokens.len() == self.bsz * self.seq_len,
            "DecoderBackend: batch is {} tokens, shape is {}x{}",
            tokens.len(),
            self.bsz,
            self.seq_len
        );
        self.calls += 1;
        let d = self.cfg.d_model;
        let vocab = self.cfg.vocab;
        let Some(sim) = self.sim.as_mut() else {
            anyhow::bail!("logits_step before load_view");
        };
        for ri in 0..self.bsz {
            let win = &tokens[ri * self.seq_len..(ri + 1) * self.seq_len];
            let wlen = win.iter().rposition(|&t| t != PAD).map_or(0, |p| p + 1);
            let win = &win[..wlen];
            self.win_len[ri] = wlen;
            if wlen == 0 {
                // empty row: drop any finished request's cache so the
                // row is cold for the next admission
                self.active[ri] = false;
                if !self.row_ctx[ri].is_empty() {
                    sim.reset_row(ri);
                    self.row_ctx[ri].clear();
                }
                self.xbuf[ri * d..(ri + 1) * d].fill(0.0);
                continue;
            }
            self.active[ri] = true;
            if !window_extends(&self.row_ctx[ri], win, self.seq_len) {
                // fresh or replayed row: rebuild its cache from the window
                sim.reset_row(ri);
                self.row_ctx[ri].clear();
                for &t in &win[..wlen - 1] {
                    sim.tied_embed(token_col(t, vocab), &mut self.xrow);
                    sim.prefill_row_step(ri, &mut self.xrow);
                    self.row_ctx[ri].push(t);
                }
            }
            // wlen > 0 here (the empty-row arm continues above), so this
            // bail is unreachable in practice but keeps the request path
            // panic-free
            let Some(&t) = win.last() else {
                anyhow::bail!("empty window on an active row");
            };
            self.pending[ri] = t;
            sim.tied_embed(token_col(t, vocab), &mut self.xbuf[ri * d..(ri + 1) * d]);
        }
        sim.decode_batch_step_masked(&mut self.xbuf, &self.active);
        let logits = sim.logits();
        let mut out = vec![0.0f32; self.bsz * self.seq_len * vocab];
        for ri in 0..self.bsz {
            if !self.active[ri] {
                continue;
            }
            let off = (ri * self.seq_len + self.win_len[ri] - 1) * vocab;
            out[off..off + vocab].copy_from_slice(&logits[ri * vocab..(ri + 1) * vocab]);
            self.row_ctx[ri].push(self.pending[ri]);
        }
        Ok(out)
    }

    fn obs_gauges(&self) -> Vec<(&'static str, f64)> {
        let mut g = vec![("calls", self.calls as f64), ("loads", self.loads as f64)];
        if let Some(sim) = &self.sim {
            g.push(("sim_steps", sim.steps as f64));
            g.push(("sim_prefill_steps", sim.prefill_steps as f64));
        }
        g
    }

    fn set_profiling(&mut self, on: bool) {
        self.profiling = on;
        if let Some(sim) = &mut self.sim {
            sim.profile.set_enabled(on);
        }
    }

    fn take_profile(&mut self) -> Vec<crate::obs::profile::StageSample> {
        self.sim.as_mut().map(|s| s.profile.drain()).unwrap_or_default()
    }
}

/// Deterministic model-shaped parameter set (`tok_embed`, `pos_embed`,
/// per-layer projections and norm gains under the
/// `python/compile/model.py::param_spec` naming contract) — the shared
/// substrate for tests, benches and examples that drive
/// [`DecoderBackend`] without training artifacts.
pub fn demo_decoder_params(cfg: &SimConfig, seed: u64) -> ParamStore {
    let mut rng = Rng::new(seed);
    let mut params = ParamStore {
        tensors: Vec::new(),
        names: Vec::new(),
        shapes: Vec::new(),
        quantized: Vec::new(),
    };
    fn push(params: &mut ParamStore, name: String, shape: Vec<usize>, quant: bool, rng: &mut Rng) {
        let n: usize = shape.iter().product();
        let t = if quant {
            (0..n).map(|_| rng.normal() as f32 * 0.05).collect()
        } else {
            vec![1.0f32; n]
        };
        params.tensors.push(t);
        params.names.push(name);
        params.shapes.push(shape);
        params.quantized.push(quant);
    }
    push(&mut params, "tok_embed".into(), vec![cfg.vocab, cfg.d_model], true, &mut rng);
    push(&mut params, "pos_embed".into(), vec![8, cfg.d_model], false, &mut rng);
    for li in 0..cfg.n_layers {
        let p = format!("layer{li}.");
        push(&mut params, format!("{p}ln1"), vec![cfg.d_model], false, &mut rng);
        for wname in ["wq", "wk", "wv", "wo"] {
            let shape = vec![cfg.d_model, cfg.d_model];
            push(&mut params, format!("{p}{wname}"), shape, true, &mut rng);
        }
        push(&mut params, format!("{p}ln2"), vec![cfg.d_model], false, &mut rng);
        push(&mut params, format!("{p}w_gate"), vec![cfg.d_model, cfg.d_ff], true, &mut rng);
        push(&mut params, format!("{p}w_up"), vec![cfg.d_model, cfg.d_ff], true, &mut rng);
        push(&mut params, format!("{p}w_down"), vec![cfg.d_ff, cfg.d_model], true, &mut rng);
    }
    push(&mut params, "ln_f".into(), vec![cfg.d_model], false, &mut rng);
    params
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::PrecisionLadder;

    fn view(ladder: &mut PrecisionLadder, raw: u8) -> std::sync::Arc<LadderView> {
        ladder.view_at(Precision::of(raw)).unwrap()
    }

    #[test]
    fn sim_backend_is_deterministic_and_precision_sensitive() {
        let mut b = SimBackend::new(2, 4, 8);
        let params = ParamStore {
            tensors: vec![vec![0.5; 8]],
            names: vec!["w".into()],
            shapes: vec![vec![8]],
            quantized: vec![false],
        };
        let mut ladder = PrecisionLadder::from_params(&params);
        let tokens = vec![1i32; 8];
        assert!(b.logits_step(&tokens).is_err(), "must load a view first");
        b.load_view(&view(&mut ladder, 4)).unwrap();
        let a = b.logits_step(&tokens).unwrap();
        let c = b.logits_step(&tokens).unwrap();
        b.load_view(&view(&mut ladder, 3)).unwrap();
        let d = b.logits_step(&tokens).unwrap();
        assert_eq!(a, c);
        assert_ne!(a, d);
        assert_eq!(a.len(), 2 * 4 * 8);
        assert_eq!(b.calls, 3);
        assert_eq!(b.loads, 2);
        assert!(b.logits_step(&tokens[..4]).is_err());
    }

    fn decoder_cfg() -> SimConfig {
        SimConfig { d_model: 64, d_ff: 128, n_layers: 2, vocab: 256, context: 8 }
    }

    fn decoder_ladder() -> PrecisionLadder {
        PrecisionLadder::from_params(&demo_decoder_params(&decoder_cfg(), 5))
    }

    fn win(w: &[i32], seq_len: usize) -> Vec<i32> {
        let mut t = vec![PAD; seq_len];
        t[..w.len()].copy_from_slice(w);
        t
    }

    #[test]
    fn decoder_backend_serves_real_deterministic_logits() {
        let mut ladder = decoder_ladder();
        let mut b = DecoderBackend::from_ladder(&ladder, 2, 8, 1).unwrap();
        assert_eq!(b.batch_shape(), (2, 8));
        assert_eq!(b.vocab_size(), 256);
        let mut tokens = win(&[1, 2, 3], 8);
        tokens.resize(16, PAD); // row 1 inactive
        assert!(b.logits_step(&tokens).is_err(), "must load a view first");
        b.load_view(&ladder.view_at(Precision::of(4)).unwrap()).unwrap();
        let a = b.logits_step(&tokens).unwrap();
        assert_eq!(a.len(), 2 * 8 * 256);
        // row 0 logits at the last prompt position are real and finite
        let off = 2 * 256;
        assert!(a[off..off + 256].iter().all(|v| v.is_finite()));
        assert!(a[off..off + 256].iter().any(|&v| v != 0.0));
        // the inactive row contributes nothing
        assert!(a[8 * 256..].iter().all(|&v| v == 0.0));
        // an identical fresh backend reproduces them bit-for-bit
        let mut ladder2 = decoder_ladder();
        let mut b2 = DecoderBackend::from_ladder(&ladder2, 2, 8, 1).unwrap();
        b2.load_view(&ladder2.view_at(Precision::of(4)).unwrap()).unwrap();
        assert_eq!(b2.logits_step(&tokens).unwrap(), a);
        // a lower-precision view yields different logits (real SEFP
        // truncation error, not a hash salt)
        b.load_view(&ladder.view_at(Precision::of(3)).unwrap()).unwrap();
        assert_ne!(b.logits_step(&tokens).unwrap(), a);
        assert_eq!(b.loads, 2, "same-width reloads are cached by (ladder, precision)");
        assert_eq!(b.calls, 2);
    }

    #[test]
    fn incremental_decode_matches_fresh_replay() {
        // the KV-cache fast path (window extends the row's context) must
        // be bit-identical to a cold prompt replay of the same window —
        // the matvec prefill and the batched matmul step share numerics
        let mut ladder = decoder_ladder();
        let v = ladder.view_at(Precision::of(4)).unwrap();
        let mut a = DecoderBackend::from_ladder(&ladder, 1, 8, 1).unwrap();
        a.load_view(&v).unwrap();
        let _ = a.logits_step(&win(&[5], 8)).unwrap();
        let _ = a.logits_step(&win(&[5, 9], 8)).unwrap();
        let la = a.logits_step(&win(&[5, 9, 1], 8)).unwrap();
        let mut b = DecoderBackend::from_ladder(&ladder, 1, 8, 1).unwrap();
        b.load_view(&v).unwrap();
        let lb = b.logits_step(&win(&[5, 9, 1], 8)).unwrap();
        assert_eq!(la, lb);
    }

    #[test]
    fn decoder_backend_is_thread_count_invariant() {
        let mut ladder = decoder_ladder();
        let v = ladder.view_at(Precision::of(5)).unwrap();
        let run = |threads: usize| {
            let mut b = DecoderBackend::from_ladder(&ladder, 2, 8, threads).unwrap();
            b.load_view(&v).unwrap();
            let mut tokens = win(&[1, 2, 3, 4], 8);
            tokens.extend(win(&[7, 7], 8));
            b.logits_step(&tokens).unwrap()
        };
        assert_eq!(run(1), run(4));
    }

    #[test]
    fn decoder_backend_profiles_stages_when_enabled() {
        use crate::obs::profile::Stage;
        let mut ladder = decoder_ladder();
        let mut b = DecoderBackend::from_ladder(&ladder, 1, 8, 1).unwrap();
        b.set_profiling(true);
        b.load_view(&ladder.view_at(Precision::of(4)).unwrap()).unwrap();
        let _ = b.logits_step(&win(&[5, 9, 1], 8)).unwrap();
        let samples = b.take_profile();
        // a fresh 3-token window replays 2 prompt tokens (Prefill) and
        // runs one batched decode step (Matmul, accumulated)
        assert_eq!(samples.iter().filter(|s| s.stage == Stage::Prefill).count(), 2);
        assert_eq!(samples.iter().filter(|s| s.stage == Stage::Matmul).count(), 1);
        assert!(samples.iter().all(|s| s.precision == Precision::of(4) && s.ms >= 0.0));
        // drained: a second take is empty
        assert!(b.take_profile().is_empty());
        // profiling survives a view switch (the sim is rebuilt)
        b.load_view(&ladder.view_at(Precision::of(3)).unwrap()).unwrap();
        let _ = b.logits_step(&win(&[5, 9, 1], 8)).unwrap();
        assert!(!b.take_profile().is_empty());
        // disabled by default: no samples, no timing
        let mut c = DecoderBackend::from_ladder(&ladder, 1, 8, 1).unwrap();
        c.load_view(&ladder.view_at(Precision::of(4)).unwrap()).unwrap();
        let _ = c.logits_step(&win(&[5, 9, 1], 8)).unwrap();
        assert!(c.take_profile().is_empty());
    }

    #[test]
    fn decoder_backend_rejects_non_decoder_ladders() {
        // the scheduler tests' synthetic two-tensor ladder has no
        // tok_embed / layer structure — construction must error, not
        // panic at serve time
        let params = ParamStore {
            tensors: vec![vec![0.5; 64]],
            names: vec!["w".into()],
            shapes: vec![vec![8, 8]],
            quantized: vec![true],
        };
        let ladder = PrecisionLadder::from_params(&params);
        assert!(DecoderBackend::from_ladder(&ladder, 2, 8, 1).is_err());
    }

    #[test]
    fn quality_model_noise_scales_with_width() {
        // the quality model shares one base across precisions, so the
        // distance from the master shrinks as noise shrinks and as the
        // width grows — unlike the default fully-keyed model
        let params = ParamStore {
            tensors: vec![vec![0.5; 8]],
            names: vec!["w".into()],
            shapes: vec![vec![8]],
            quantized: vec![false],
        };
        let mut ladder = PrecisionLadder::from_params(&params);
        let tokens = vec![7i32; 8];
        let logits_at = |noise: f32, m: u8, ladder: &mut PrecisionLadder| {
            let mut b = SimBackend::new(2, 4, 8).with_quality_model(noise);
            b.load_view(&ladder.view_at(Precision::of(m)).unwrap()).unwrap();
            b.logits_step(&tokens).unwrap()
        };
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
        };
        let m8 = logits_at(1.0, 8, &mut ladder);
        let m4 = logits_at(1.0, 4, &mut ladder);
        let m3 = logits_at(1.0, 3, &mut ladder);
        assert!(dist(&m3, &m8) > dist(&m4, &m8), "lower width drifts further");
        // shrinking the noise shrinks the drift at a fixed width
        let m3_quiet = logits_at(0.01, 3, &mut ladder);
        let m8_quiet = logits_at(0.01, 8, &mut ladder);
        assert!(dist(&m3_quiet, &m8_quiet) < dist(&m3, &m8));
        // still deterministic
        assert_eq!(logits_at(1.0, 3, &mut ladder), m3);
    }
}
