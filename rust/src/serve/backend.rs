//! Logits backends: the one-step interface the generation engine drives.
//!
//! [`Server`](super::Server) owns its backend (no lifetime-bound
//! `&mut Engine` — the seed's borrow made it impossible to hand the
//! server to a thread or embed it in a long-lived service struct).
//! Production uses [`EngineHandle`] over the PJRT engine; tests and
//! `bench_serve` use [`SimBackend`], a deterministic pure-Rust stand-in,
//! so the scheduler and the continuous-batching decode loop are
//! exercised without AOT artifacts.

use crate::runtime::{Engine, ParamStore, Width};

/// One forward step over the engine's fixed (B, T) token matrix,
/// returning flat (B, T, V) logits.
pub trait LogitsBackend {
    /// (batch rows, sequence length) of one step call.
    fn batch_shape(&self) -> (usize, usize);
    fn vocab_size(&self) -> usize;
    fn logits_step(
        &mut self,
        params: &ParamStore,
        tokens: &[i32],
        width: Width,
    ) -> anyhow::Result<Vec<f32>>;
}

/// Owned handle over the PJRT [`Engine`] — the production backend.
pub struct EngineHandle {
    engine: Engine,
}

impl EngineHandle {
    pub fn new(engine: Engine) -> Self {
        EngineHandle { engine }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn engine_mut(&mut self) -> &mut Engine {
        &mut self.engine
    }

    pub fn into_engine(self) -> Engine {
        self.engine
    }
}

impl LogitsBackend for EngineHandle {
    fn batch_shape(&self) -> (usize, usize) {
        self.engine.batch_shape()
    }

    fn vocab_size(&self) -> usize {
        self.engine.vocab_size()
    }

    fn logits_step(
        &mut self,
        params: &ParamStore,
        tokens: &[i32],
        width: Width,
    ) -> anyhow::Result<Vec<f32>> {
        self.engine.logits_step(params, tokens, width)
    }
}

/// Deterministic in-process backend for scheduler tests and serving
/// benchmarks: logits are a pure hash of (position token, candidate
/// token, width), so generations are reproducible bit-for-bit, distinct
/// per precision, and independent of wall clock.
pub struct SimBackend {
    pub bsz: usize,
    pub seq_len: usize,
    pub vocab: usize,
    /// logits_step invocations (decode iterations observed)
    pub calls: u64,
    /// simulated per-step latency — lets scheduler tests and benches
    /// model sustained load in real time (zero = as fast as possible)
    pub step_delay: std::time::Duration,
}

impl SimBackend {
    pub fn new(bsz: usize, seq_len: usize, vocab: usize) -> Self {
        SimBackend { bsz, seq_len, vocab, calls: 0, step_delay: std::time::Duration::ZERO }
    }

    pub fn with_step_delay(mut self, d: std::time::Duration) -> Self {
        self.step_delay = d;
        self
    }

    #[inline]
    fn score(token: i32, cand: usize, width: Width) -> f32 {
        let w = match width {
            Width(Some(m)) => m as u64,
            Width(None) => 9,
        };
        let mut h = (token as u64)
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add((cand as u64).wrapping_mul(0xBF58476D1CE4E5B9))
            .wrapping_add(w.wrapping_mul(0x94D049BB133111EB));
        h ^= h >> 29;
        (h % 1000) as f32 / 1000.0
    }
}

impl LogitsBackend for SimBackend {
    fn batch_shape(&self) -> (usize, usize) {
        (self.bsz, self.seq_len)
    }

    fn vocab_size(&self) -> usize {
        self.vocab
    }

    fn logits_step(
        &mut self,
        _params: &ParamStore,
        tokens: &[i32],
        width: Width,
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(
            tokens.len() == self.bsz * self.seq_len,
            "SimBackend: batch is {} tokens, shape is {}x{}",
            tokens.len(),
            self.bsz,
            self.seq_len
        );
        self.calls += 1;
        if !self.step_delay.is_zero() {
            std::thread::sleep(self.step_delay);
        }
        let mut out = Vec::with_capacity(tokens.len() * self.vocab);
        for &t in tokens {
            for v in 0..self.vocab {
                out.push(Self::score(t, v, width));
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_backend_is_deterministic_and_width_sensitive() {
        let mut b = SimBackend::new(2, 4, 8);
        let params = ParamStore {
            tensors: vec![],
            names: vec![],
            shapes: vec![],
            quantized: vec![],
        };
        let tokens = vec![1i32; 8];
        let a = b.logits_step(&params, &tokens, Width::m(4)).unwrap();
        let c = b.logits_step(&params, &tokens, Width::m(4)).unwrap();
        let d = b.logits_step(&params, &tokens, Width::m(3)).unwrap();
        assert_eq!(a, c);
        assert_ne!(a, d);
        assert_eq!(a.len(), 2 * 4 * 8);
        assert_eq!(b.calls, 3);
        assert!(b.logits_step(&params, &tokens[..4], Width::m(4)).is_err());
    }
}
