//! Logits backends: the one-step interface the generation engine drives.
//!
//! [`Server`](super::Server) owns its backend (no lifetime-bound
//! `&mut Engine`).  A precision run starts with
//! [`load_view`](LogitsBackend::load_view) — the backend receives the
//! SEFP-domain [`LadderView`](super::LadderView) for the scheduled
//! precision — then drives one
//! [`logits_step`](LogitsBackend::logits_step) per decode iteration.
//!
//! Production uses [`EngineHandle`] over the PJRT engine: `load_view`
//! decodes the view into ONE reusable f32 scratch `ParamStore` (the PJRT
//! ABI takes f32 literals; this is the only float materialization on the
//! serve path, and at most one copy is ever resident — switching
//! precision overwrites it instead of growing a per-width zoo).  Tests
//! and `bench_serve` use [`SimBackend`], a deterministic pure-Rust
//! stand-in, so the scheduler and the continuous-batching decode loop are
//! exercised without AOT artifacts.

use crate::runtime::{Engine, ParamStore, Width};
use crate::sefp::Precision;

use super::store::LadderView;

/// One forward step over the engine's fixed (B, T) token matrix,
/// returning flat (B, T, V) logits, at the precision loaded by
/// `load_view`.
pub trait LogitsBackend {
    /// (batch rows, sequence length) of one step call.
    fn batch_shape(&self) -> (usize, usize);
    fn vocab_size(&self) -> usize;
    /// Install the weights for the upcoming precision run.
    fn load_view(&mut self, view: &LadderView) -> anyhow::Result<()>;
    /// One decode step at the loaded precision.
    fn logits_step(&mut self, tokens: &[i32]) -> anyhow::Result<Vec<f32>>;
}

/// Owned handle over the PJRT [`Engine`] — the production backend.
pub struct EngineHandle {
    engine: Engine,
    /// f32 scratch for the currently loaded view, keyed by
    /// (ladder id, precision) so a hot-swapped ladder can never be
    /// served from stale weights (ONE copy, reused)
    loaded: Option<((u64, Precision), ParamStore)>,
}

impl EngineHandle {
    pub fn new(engine: Engine) -> Self {
        EngineHandle { engine, loaded: None }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn engine_mut(&mut self) -> &mut Engine {
        &mut self.engine
    }

    pub fn into_engine(self) -> Engine {
        self.engine
    }
}

impl LogitsBackend for EngineHandle {
    fn batch_shape(&self) -> (usize, usize) {
        self.engine.batch_shape()
    }

    fn vocab_size(&self) -> usize {
        self.engine.vocab_size()
    }

    fn load_view(&mut self, view: &LadderView) -> anyhow::Result<()> {
        // skip the decode when the same view is already loaded (the
        // common continuous-batching case: back-to-back runs at one
        // width); the ladder id keeps a hot-swapped ladder coherent
        let key = (view.ladder_id(), view.precision);
        if self.loaded.as_ref().map(|(k, _)| *k) != Some(key) {
            self.loaded = Some((key, view.to_param_store()));
        }
        Ok(())
    }

    fn logits_step(&mut self, tokens: &[i32]) -> anyhow::Result<Vec<f32>> {
        let ((_, p), params) = self
            .loaded
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("logits_step before load_view"))?;
        self.engine.logits_step(params, tokens, Width::m(*p))
    }
}

/// Deterministic in-process backend for scheduler tests and serving
/// benchmarks: logits are a pure hash of (position token, candidate
/// token, precision), so generations are reproducible bit-for-bit,
/// distinct per precision, and independent of wall clock.
///
/// Two logit models:
/// * default — every precision gets an unrelated hash stream (maximally
///   precision-sensitive; scheduler tests rely on widths disagreeing);
/// * [`with_quality_model`](SimBackend::with_quality_model) — a shared
///   base score plus a per-precision perturbation whose amplitude
///   scales like the SEFP ε(ω) sawtooth, `quality_noise · 2^-m`, so
///   lower widths drift further from the master and the drift is
///   *tunable*.  Policy tests inject quality degradation by raising
///   `quality_noise` mid-run.
pub struct SimBackend {
    pub bsz: usize,
    pub seq_len: usize,
    pub vocab: usize,
    /// logits_step invocations (decode iterations observed)
    pub calls: u64,
    /// load_view invocations (precision runs observed)
    pub loads: u64,
    /// simulated per-step latency — lets scheduler tests and benches
    /// model sustained load in real time (zero = as fast as possible)
    pub step_delay: std::time::Duration,
    /// `Some(noise)` switches to the shared-base quality model
    pub quality_noise: Option<f32>,
    loaded: Option<Precision>,
}

impl SimBackend {
    pub fn new(bsz: usize, seq_len: usize, vocab: usize) -> Self {
        SimBackend {
            bsz,
            seq_len,
            vocab,
            calls: 0,
            loads: 0,
            step_delay: std::time::Duration::ZERO,
            quality_noise: None,
            loaded: None,
        }
    }

    pub fn with_step_delay(mut self, d: std::time::Duration) -> Self {
        self.step_delay = d;
        self
    }

    /// Switch to the quality model: logits become a shared
    /// precision-independent base plus `noise · 2^-m`-scaled
    /// perturbation (see the type docs).
    pub fn with_quality_model(mut self, noise: f32) -> Self {
        self.quality_noise = Some(noise);
        self
    }

    #[inline]
    fn hash(token: i32, cand: usize, salt: u64) -> u64 {
        let mut h = (token as u64)
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add((cand as u64).wrapping_mul(0xBF58476D1CE4E5B9))
            .wrapping_add(salt.wrapping_mul(0x94D049BB133111EB));
        h ^= h >> 29;
        h
    }

    #[inline]
    fn score(token: i32, cand: usize, p: Precision) -> f32 {
        (Self::hash(token, cand, p.m() as u64) % 1000) as f32 / 1000.0
    }

    /// Quality-model score: 24-bit base in [0, 1) shared by every
    /// precision (ties astronomically unlikely, so tiny noise cannot
    /// flip an argmax through a grid collision) + per-precision
    /// perturbation in [-1, 1) scaled by `noise · 2^-m`.
    #[inline]
    fn score_quality(token: i32, cand: usize, p: Precision, noise: f32) -> f32 {
        let base = (Self::hash(token, cand, 0) >> 40) as f32 / (1u64 << 24) as f32;
        let salt = 0x5EFu64 | ((p.m() as u64) << 16);
        let raw = (Self::hash(token, cand, salt) >> 40) as f32 / (1u64 << 23) as f32 - 1.0;
        base + raw * noise * (-(p.m() as f32)).exp2()
    }
}

impl LogitsBackend for SimBackend {
    fn batch_shape(&self) -> (usize, usize) {
        (self.bsz, self.seq_len)
    }

    fn vocab_size(&self) -> usize {
        self.vocab
    }

    fn load_view(&mut self, view: &LadderView) -> anyhow::Result<()> {
        self.loads += 1;
        self.loaded = Some(view.precision);
        Ok(())
    }

    fn logits_step(&mut self, tokens: &[i32]) -> anyhow::Result<Vec<f32>> {
        let p = self
            .loaded
            .ok_or_else(|| anyhow::anyhow!("logits_step before load_view"))?;
        anyhow::ensure!(
            tokens.len() == self.bsz * self.seq_len,
            "SimBackend: batch is {} tokens, shape is {}x{}",
            tokens.len(),
            self.bsz,
            self.seq_len
        );
        self.calls += 1;
        if !self.step_delay.is_zero() {
            std::thread::sleep(self.step_delay);
        }
        let mut out = Vec::with_capacity(tokens.len() * self.vocab);
        match self.quality_noise {
            Some(noise) => {
                for &t in tokens {
                    for v in 0..self.vocab {
                        out.push(Self::score_quality(t, v, p, noise));
                    }
                }
            }
            None => {
                for &t in tokens {
                    for v in 0..self.vocab {
                        out.push(Self::score(t, v, p));
                    }
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::PrecisionLadder;

    fn view(ladder: &mut PrecisionLadder, raw: u8) -> std::sync::Arc<LadderView> {
        ladder.view_at(Precision::of(raw)).unwrap()
    }

    #[test]
    fn sim_backend_is_deterministic_and_precision_sensitive() {
        let mut b = SimBackend::new(2, 4, 8);
        let params = ParamStore {
            tensors: vec![vec![0.5; 8]],
            names: vec!["w".into()],
            shapes: vec![vec![8]],
            quantized: vec![false],
        };
        let mut ladder = PrecisionLadder::from_params(&params);
        let tokens = vec![1i32; 8];
        assert!(b.logits_step(&tokens).is_err(), "must load a view first");
        b.load_view(&view(&mut ladder, 4)).unwrap();
        let a = b.logits_step(&tokens).unwrap();
        let c = b.logits_step(&tokens).unwrap();
        b.load_view(&view(&mut ladder, 3)).unwrap();
        let d = b.logits_step(&tokens).unwrap();
        assert_eq!(a, c);
        assert_ne!(a, d);
        assert_eq!(a.len(), 2 * 4 * 8);
        assert_eq!(b.calls, 3);
        assert_eq!(b.loads, 2);
        assert!(b.logits_step(&tokens[..4]).is_err());
    }

    #[test]
    fn quality_model_noise_scales_with_width() {
        // the quality model shares one base across precisions, so the
        // distance from the master shrinks as noise shrinks and as the
        // width grows — unlike the default fully-keyed model
        let params = ParamStore {
            tensors: vec![vec![0.5; 8]],
            names: vec!["w".into()],
            shapes: vec![vec![8]],
            quantized: vec![false],
        };
        let mut ladder = PrecisionLadder::from_params(&params);
        let tokens = vec![7i32; 8];
        let logits_at = |noise: f32, m: u8, ladder: &mut PrecisionLadder| {
            let mut b = SimBackend::new(2, 4, 8).with_quality_model(noise);
            b.load_view(&ladder.view_at(Precision::of(m)).unwrap()).unwrap();
            b.logits_step(&tokens).unwrap()
        };
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
        };
        let m8 = logits_at(1.0, 8, &mut ladder);
        let m4 = logits_at(1.0, 4, &mut ladder);
        let m3 = logits_at(1.0, 3, &mut ladder);
        assert!(dist(&m3, &m8) > dist(&m4, &m8), "lower width drifts further");
        // shrinking the noise shrinks the drift at a fixed width
        let m3_quiet = logits_at(0.01, 3, &mut ladder);
        let m8_quiet = logits_at(0.01, 8, &mut ladder);
        assert!(dist(&m3_quiet, &m8_quiet) < dist(&m3, &m8));
        // still deterministic
        assert_eq!(logits_at(1.0, 3, &mut ladder), m3);
    }
}
