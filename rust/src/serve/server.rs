//! The serving front-end: router + precision ladder + scheduler over an
//! owned logits backend, with a continuous-batching generation loop.
//! Synchronous core (deterministic, unit-testable); the
//! `multi_precision_serving` example wraps it in threads for a
//! concurrent client demo.
//!
//! Request path: `submit` routes a request to a precision queue;
//! `process_all` repeatedly asks the scheduler for the next precision
//! batch and hands it to the generation loop.  Each run starts with a
//! `PrecisionLadder::view_at` switch (SEFP-domain, cached under the byte
//! budget) and a `load_view` on the backend; the loop then decodes every
//! admitted row for up to `max_new_tokens` tokens (greedy or temperature
//! sampling, EOS stops early), one `logits_step` per decode iteration
//! over the engine's fixed (B, T) matrix; rows freed by finished
//! requests are refilled FIFO from the same precision queue between
//! iterations — continuous batching — unless another precision has
//! crossed the scheduler's anti-starvation bound, in which case the run
//! winds down so the overdue precision is served next.

use std::time::Instant;

use crate::data::tokenizer::{EOS, PAD};
use crate::data::Rng;
use crate::infer::sampling;
use crate::metrics::Summary;
use crate::obs::profile::Stage;
use crate::obs::trace::{permille, EventKind, NullTrace, ShedReason, TraceSink, Tracer};
use crate::policy::{shadow_probe, Observation, PolicyMove, ProbeTask};
use crate::sefp::Precision;

use super::backend::{EngineHandle, LogitsBackend};
use super::batcher::QueuedRequest;
use super::metrics::ServeMetrics;
use super::{DynamicBatcher, PrecisionLadder, Request, Response, Router, TaskClass};

/// Aggregated serving statistics.
///
/// Since the obs refactor this is a *derived view*: the server records
/// every event into a [`ServeMetrics`](super::ServeMetrics) registry,
/// and [`Server::stats`] re-derives this struct from the registry (plus
/// the live ladder/router state) on demand.  The flat-struct shape is
/// kept for callers; the registry snapshot
/// ([`Server::metrics_snapshot`]) carries the same data as
/// deterministic JSON with bucketed histograms.
#[derive(Debug, Default, Clone)]
pub struct ServeStats {
    pub served: u64,
    /// requests shed by queue backpressure (bounded-queue overflow)
    pub rejected: u64,
    /// requests refused by validation (empty prompt)
    pub invalid: u64,
    /// scheduled precision runs (pop_batch dispatches)
    pub batches: u64,
    /// engine forward calls (decode iterations across all runs)
    pub decode_steps: u64,
    pub tokens_generated: u64,
    pub queue_ms: Summary,
    pub compute_ms: Summary,
    pub per_precision: Vec<(Precision, u64)>,
    /// per-rung backpressure sheds (ascending precision, zeros elided)
    pub shed_per_precision: Vec<(Precision, u64)>,
    /// high-water mark of the batcher queue depth
    pub queue_peak_depth: u64,
    /// precision switches answered from the ladder cache (or the master)
    pub switch_hits: u64,
    /// precision switches that derived a new view by truncation
    pub switch_misses: u64,
    /// ladder views evicted to keep residency under the byte budget
    pub switch_evictions: u64,
    /// per-miss view derivation latency, milliseconds
    pub switch_ms: Summary,
    /// bytes of derived ladder views currently resident
    pub ladder_resident_bytes: usize,
    /// shadow quality probes scored (policy layer)
    pub probes_run: u64,
    /// probe token-agreement per probe (exact percentiles available)
    pub probe_agreement: Summary,
    /// policy moves to a higher precision (quality floor violated)
    pub promotions: u64,
    /// policy moves to a lower precision (latency SLO violated)
    pub demotions: u64,
    /// forced per-request precisions snapped into the configured ladder
    pub forced_clamps: u64,
    /// wall time from the FIRST dispatched work to the end of the last
    /// `process_all` — idle time before traffic arrives is not counted,
    /// so `throughput_rps` reflects serving, not server uptime.
    pub wall_secs: f64,
}

impl ServeStats {
    pub fn throughput_rps(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.served as f64 / self.wall_secs
        } else {
            0.0
        }
    }

    pub fn throughput_tps(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.tokens_generated as f64 / self.wall_secs
        } else {
            0.0
        }
    }
}

/// One in-flight batch row of the generation loop.
struct ActiveRow {
    id: u64,
    class: TaskClass,
    /// prompt + generated tokens; the last `seq_len` form the window
    context: Vec<i32>,
    generated: Vec<i32>,
    max_new_tokens: usize,
    temperature: f32,
    queue_ms: f64,
    compute_ms: f64,
}

impl ActiveRow {
    fn admit(q: QueuedRequest) -> Self {
        let queue_ms = q.enqueued_at.elapsed().as_secs_f64() * 1e3;
        let req = q.req;
        ActiveRow {
            id: req.id,
            class: req.class,
            context: req.prompt,
            generated: Vec::new(),
            max_new_tokens: req.max_new_tokens.max(1),
            temperature: req.temperature,
            queue_ms,
            compute_ms: 0.0,
        }
    }
}

pub struct Server<B: LogitsBackend = EngineHandle> {
    backend: B,
    pub ladder: PrecisionLadder,
    pub router: Router,
    pub batcher: DynamicBatcher,
    /// the obs registry every serving event records into
    metrics: ServeMetrics,
    /// set when the first batch is dispatched (NOT at construction —
    /// measuring from `Server::new` would deflate throughput whenever
    /// the server idled before traffic arrived)
    first_work: Option<Instant>,
    /// completions sampled for shadow probing, run BETWEEN generation
    /// runs (a probe swaps the backend's loaded view, so it can never
    /// run while rows are still decoding at the serving precision)
    pending_probes: Vec<ProbeTask>,
    /// per-request span sink ([`NullTrace`] unless [`Server::with_tracer`])
    trace: Box<dyn TraceSink>,
    /// when set, stage timers record into the per-rung
    /// `profile.rung.<rung>.<stage>_ms` histograms (off by default —
    /// disabled, no clocks are read and no backend samples drained)
    profiling: bool,
    rng: Rng,
}

impl<B: LogitsBackend> Server<B> {
    pub fn new(
        backend: B,
        ladder: PrecisionLadder,
        router: Router,
        batcher: DynamicBatcher,
    ) -> Self {
        let metrics = ServeMetrics::for_ladder(router.ladder());
        Server {
            backend,
            ladder,
            router,
            batcher,
            metrics,
            first_work: None,
            pending_probes: Vec::new(),
            trace: Box::new(NullTrace),
            profiling: false,
            rng: Rng::new(0x5EED),
        }
    }

    /// Reseed the sampling RNG (temperature > 0 paths).
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.rng = Rng::new(seed);
        self
    }

    /// Record every request's span chain into `tracer` (the default is
    /// the inert [`NullTrace`]).
    pub fn with_tracer(mut self, tracer: Tracer) -> Self {
        self.trace = Box::new(tracer);
        self
    }

    /// Enable hot-path stage profiling: the server times its own stages
    /// (decode step, ladder switch, quality probe) and drains the
    /// backend's ([`Stage::Prefill`] / [`Stage::Matmul`]) into the
    /// pre-registered `profile.rung.<rung>.<stage>_ms` histograms.
    pub fn with_profiling(mut self, on: bool) -> Self {
        self.profiling = on;
        self.backend.set_profiling(on);
        self
    }

    /// Deterministic `otaro.trace.v1` snapshot of the recorded traces;
    /// `None` when tracing is off.
    pub fn trace_snapshot(&self) -> Option<crate::json::Value> {
        self.trace.snapshot()
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    pub fn backend_mut(&mut self) -> &mut B {
        &mut self.backend
    }

    /// Enqueue a request (routing decides the precision).  `false` =
    /// rejected: empty prompts, prompts containing the reserved PAD id
    /// (the padding sentinel of the engine's token matrix — a prompt
    /// carrying it would desync every backend's window recovery), and
    /// precisions above the ladder master are invalid (there is no
    /// position to read logits from / no mantissa bits to invent), and
    /// a full queue sheds by backpressure.
    pub fn submit(&mut self, req: Request) -> bool {
        self.trace.event(req.id, EventKind::Admitted { class: req.class });
        if req.prompt.is_empty() || req.prompt.contains(&PAD) {
            self.metrics.record_invalid();
            self.trace.event(
                req.id,
                EventKind::Shed { reason: ShedReason::InvalidPrompt, precision: None },
            );
            return false;
        }
        let p = self.router.route(req.class, req.precision);
        if p > self.ladder.top() {
            // reject here so one bad request cannot poison a whole
            // popped batch when view_at errors mid-run
            self.metrics.record_invalid();
            self.trace.event(
                req.id,
                EventKind::Shed { reason: ShedReason::PrecisionAboveMaster, precision: Some(p) },
            );
            return false;
        }
        let id = req.id;
        match self.batcher.push(req, p) {
            Ok(()) => {
                self.metrics.record_queue_depth(self.batcher.len());
                self.trace.event(
                    id,
                    EventKind::Queued { precision: p, depth: self.batcher.len() as u32 },
                );
                true
            }
            Err(_) => {
                self.metrics.record_shed(p);
                // a shed is an admission-time depth sample too: the
                // burst that filled the queue inside one decode
                // iteration must show in the peak gauge, not just in
                // between-iteration samples
                self.metrics.record_queue_depth(self.batcher.len());
                self.trace.event(
                    id,
                    EventKind::Shed { reason: ShedReason::QueueFull, precision: Some(p) },
                );
                false
            }
        }
    }

    /// Drain the queue completely: schedule precision runs until empty,
    /// generating every admitted request to completion.
    pub fn process_all(&mut self) -> anyhow::Result<Vec<Response>> {
        let mut out = Vec::new();
        let mut dispatched = false;
        while let Some((p, batch)) = self.batcher.pop_batch() {
            dispatched = true;
            if self.first_work.is_none() {
                self.first_work = Some(Instant::now());
            }
            out.extend(self.run_generation(p, batch)?);
        }
        // only stamp the wall clock when this call did work — a no-op
        // poll on an idle server must not stretch wall_secs and deflate
        // throughput (the same bug class as measuring from `new`)
        if dispatched {
            if let Some(t) = self.first_work {
                self.metrics.wall_secs = t.elapsed().as_secs_f64();
            }
            self.sync_policy_stats();
        }
        Ok(out)
    }

    /// The continuous-batching generation loop for one precision run.
    fn run_generation(
        &mut self,
        p: Precision,
        batch: Vec<QueuedRequest>,
    ) -> anyhow::Result<Vec<Response>> {
        let (bsz, seq_len) = self.backend.batch_shape();
        let vocab = self.backend.vocab_size();
        anyhow::ensure!(batch.len() <= bsz, "batch exceeds engine rows");
        // single-master precision switch — the OTARo deployment property
        // in action: no reload, no f32 zoo; a (cached) integer truncation
        let t_switch = if self.profiling { Some(Instant::now()) } else { None };
        let view = self.ladder.view_at(p)?;
        self.backend.load_view(&view)?;
        drop(view);
        if let Some(t0) = t_switch {
            self.metrics.record_stage(p, Stage::LadderSwitch, t0.elapsed().as_secs_f64() * 1e3);
        }
        self.sync_ladder_stats();
        self.metrics.record_dispatch(batch.len() as f64 / bsz as f64, self.batcher.len());

        let mut rows: Vec<Option<ActiveRow>> = Vec::with_capacity(bsz);
        for (ri, q) in batch.into_iter().enumerate() {
            self.trace.event(q.req.id, EventKind::Scheduled { batch_row: ri as u32 });
            rows.push(Some(ActiveRow::admit(q)));
        }
        rows.resize_with(bsz, || None);

        let mut out = Vec::new();
        let mut tokens = vec![PAD; bsz * seq_len];
        while rows.iter().any(Option::is_some) {
            // build the token matrix from each row's context window
            tokens.fill(PAD);
            let mut last_pos = vec![0usize; bsz];
            for (ri, row) in rows.iter().enumerate() {
                let Some(r) = row else { continue };
                let n = r.context.len().min(seq_len);
                tokens[ri * seq_len..ri * seq_len + n]
                    .copy_from_slice(&r.context[r.context.len() - n..]);
                last_pos[ri] = n.saturating_sub(1);
            }

            let t0 = Instant::now();
            let mut logits = self.backend.logits_step(&tokens)?;
            let step_ms = t0.elapsed().as_secs_f64() * 1e3;
            // synthetic latency/faults the backend wrapper injected into
            // that step become trace-visible global events, so an SLO
            // violation seen below is attributable to its injection
            for ev in self.backend.take_injected() {
                self.trace.global(EventKind::Injected {
                    precision: ev.precision,
                    step: ev.step,
                    delay_ms: ev.delay_ms,
                    fault: ev.fault,
                });
            }
            if self.profiling {
                self.metrics.record_stage(p, Stage::DecodeStep, step_ms);
                // backend-side samples (prefill / matmul) come out
                // stamped with the rung the sim actually ran at
                for s in self.backend.take_profile() {
                    self.metrics.record_stage(s.precision, s.stage, s.ms);
                }
            }
            let mut step_tokens = 0u64;

            // sample one token per active row; finalize finished rows
            for ri in 0..bsz {
                let mut finished = false;
                if let Some(r) = rows[ri].as_mut() {
                    let off = (ri * seq_len + last_pos[ri]) * vocab;
                    // PAD is a reserved padding id, never a legal
                    // emission: when the vocab is large enough to
                    // contain it, mask it so a sampled PAD can never
                    // enter a context window (backends recover each
                    // row's window by stripping trailing PADs)
                    if (PAD as usize) < vocab {
                        logits[off + PAD as usize] = f32::NEG_INFINITY;
                    }
                    let next = sampling::sample(
                        &logits[off..off + vocab],
                        r.temperature,
                        &mut self.rng,
                    ) as i32;
                    r.context.push(next);
                    r.generated.push(next);
                    r.compute_ms += step_ms;
                    step_tokens += 1;
                    finished = r.generated.len() >= r.max_new_tokens || next == EOS;
                    let row_id = r.id;
                    let n_gen = r.generated.len() as u32;
                    self.trace.event(row_id, EventKind::DecodeStep { n: n_gen, precision: p });
                }
                if finished {
                    // `finished` is only set while the row is Some, so
                    // take() always yields here
                    if let Some(r) = rows[ri].take() {
                        self.finalize(p, r, &mut out);
                    }
                }
            }

            self.metrics.record_step(p, step_ms, step_tokens);

            // continuous batching: refill freed rows FIFO from the same
            // precision queue — unless another precision is overdue, then
            // let this run wind down so the scheduler can serve it.
            let now = Instant::now();
            let yield_to_other =
                self.batcher.starving_width(now).is_some_and(|w| w != p);
            if !yield_to_other {
                let mut refilled = false;
                for ri in 0..bsz {
                    if rows[ri].is_none() {
                        if let Some(q) = self.batcher.pop_for_width(p, 1).pop() {
                            self.trace
                                .event(q.req.id, EventKind::Scheduled { batch_row: ri as u32 });
                            rows[ri] = Some(ActiveRow::admit(q));
                            refilled = true;
                        }
                    }
                }
                if refilled {
                    // the drained depth is a sample in its own right —
                    // without it the gauge would hold the pre-refill
                    // value until the next dispatch
                    self.metrics.record_queue_depth(self.batcher.len());
                }
            }
        }
        // the run is over and no rows reference the loaded view: safe
        // to let sampled shadow probes swap precisions on the backend
        self.run_pending_probes()?;
        Ok(out)
    }

    /// Score every completion sampled for shadow probing during the
    /// run that just ended, and feed the results back to the policy.
    fn run_pending_probes(&mut self) -> anyhow::Result<()> {
        if self.pending_probes.is_empty() {
            return Ok(());
        }
        for task in std::mem::take(&mut self.pending_probes) {
            let t_probe = if self.profiling { Some(Instant::now()) } else { None };
            let result = shadow_probe(&mut self.backend, &mut self.ladder, &task)?;
            if let Some(t0) = t_probe {
                self.metrics
                    .record_stage(task.precision, Stage::Probe, t0.elapsed().as_secs_f64() * 1e3);
                // the probe's replay steps (served rung + master) land
                // in the backend buffer too — attribute them now
                for s in self.backend.take_profile() {
                    self.metrics.record_stage(s.precision, s.stage, s.ms);
                }
            }
            // probe re-scoring steps can be injected too
            for ev in self.backend.take_injected() {
                self.trace.global(EventKind::Injected {
                    precision: ev.precision,
                    step: ev.step,
                    delay_ms: ev.delay_ms,
                    fault: ev.fault,
                });
            }
            self.metrics.record_probe(result.agreement);
            self.trace
                .event(task.id, EventKind::Probe { agreement_pm: permille(result.agreement) });
            let mv = self.router.policy_mut().observe_probe(task.class, task.precision, &result);
            self.trace_policy_move(task.id, mv);
        }
        // probe replays go through the ladder cache like any switch
        self.sync_ladder_stats();
        Ok(())
    }

    /// Attach a `policy_decision` span to the request whose observation
    /// or probe triggered the move (no-op on `Hold`).
    fn trace_policy_move(&mut self, req: u64, mv: Option<PolicyMove>) {
        if let Some(mv) = mv {
            self.trace.event(
                req,
                EventKind::PolicyDecision {
                    demote: mv.demote,
                    from: mv.from,
                    to: mv.to,
                    score_pm: mv.score_pm,
                },
            );
        }
    }

    /// Mirror the policy's decision counters into the registry gauges
    /// (the derived [`ServeStats`] reads these live from the router).
    fn sync_policy_stats(&mut self) {
        let snap = self.router.policy().snapshot();
        self.metrics.sync_policy(snap.promotions, snap.demotions, self.router.forced_clamps());
        self.metrics.set_backend_gauges(&self.backend.obs_gauges());
    }

    /// Mirror the ladder's switch statistics into the registry gauges.
    fn sync_ladder_stats(&mut self) {
        let ls = &self.ladder.stats;
        self.metrics.sync_ladder(ls.hits, ls.misses, ls.evictions, self.ladder.resident_bytes());
    }

    fn finalize(&mut self, p: Precision, mut row: ActiveRow, out: &mut Vec<Response>) {
        self.metrics.record_served(p, row.queue_ms.max(0.0), row.compute_ms);
        // close the control loop: every completion is an observation,
        // and a sampled fraction below the master is queued for shadow
        // probing (run after this precision run winds down)
        let obs = Observation {
            class: row.class,
            precision: p,
            queue_ms: row.queue_ms.max(0.0),
            compute_ms: row.compute_ms,
            tokens: row.generated.len(),
            queue_depth: self.batcher.len(),
        };
        let mv = self.router.policy_mut().observe(&obs);
        self.trace_policy_move(row.id, mv);
        if p < self.ladder.top() && self.router.policy_mut().wants_probe(row.class, p) {
            // the context is dead after finalize (the Response only
            // keeps the generation), so the probe task takes it by move
            self.pending_probes.push(ProbeTask {
                id: row.id,
                class: row.class,
                precision: p,
                context: std::mem::take(&mut row.context),
                n_gen: row.generated.len(),
            });
        }
        self.trace.event(row.id, EventKind::Delivered { tokens: row.generated.len() as u32 });
        out.push(Response {
            id: row.id,
            precision: p,
            next_token: row.generated.first().copied().unwrap_or(PAD),
            tokens: row.generated,
            queue_ms: row.queue_ms.max(0.0),
            compute_ms: row.compute_ms,
        });
    }

    /// Serving statistics, re-derived on demand: the counter/histogram
    /// fields come from the obs registry, the ladder-switch and policy
    /// decision fields straight from the live ladder/router (which own
    /// that state — the registry carries sync-cadence gauge mirrors).
    pub fn stats(&self) -> ServeStats {
        let mut st = self.metrics.stats();
        let ls = &self.ladder.stats;
        st.switch_hits = ls.hits;
        st.switch_misses = ls.misses;
        st.switch_evictions = ls.evictions;
        st.switch_ms = ls.switch_ms.clone();
        st.ladder_resident_bytes = self.ladder.resident_bytes();
        let snap = self.router.policy().snapshot();
        st.promotions = snap.promotions;
        st.demotions = snap.demotions;
        st.forced_clamps = self.router.forced_clamps();
        st
    }

    /// The obs metric set the server records into.
    pub fn metrics(&self) -> &ServeMetrics {
        &self.metrics
    }

    /// Deterministic JSON snapshot of the full metric registry, with
    /// the ladder/policy/backend gauges freshly synced first.
    pub fn metrics_snapshot(&mut self) -> crate::json::Value {
        self.sync_ladder_stats();
        self.sync_policy_stats();
        self.metrics.snapshot()
    }
}
