//! The serving front-end: router + precision store + dynamic batcher over
//! the PJRT engine.  Synchronous core (deterministic, unit-testable); the
//! `multi_precision_serving` example wraps it in threads for a concurrent
//! client demo.

use std::time::Instant;

use crate::data::tokenizer::PAD;
use crate::metrics::Summary;
use crate::runtime::{Engine, Width};

use super::{DynamicBatcher, PrecisionStore, Request, Response, Router};

#[derive(Debug, Default, Clone)]
pub struct ServeStats {
    pub served: u64,
    pub rejected: u64,
    pub batches: u64,
    pub queue_ms: Summary,
    pub compute_ms: Summary,
    pub per_width: Vec<(u8, u64)>,
    pub wall_secs: f64,
}

impl ServeStats {
    pub fn throughput_rps(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.served as f64 / self.wall_secs
        } else {
            0.0
        }
    }
}

pub struct Server<'a> {
    pub engine: &'a mut Engine,
    pub store: PrecisionStore,
    pub router: Router,
    pub batcher: DynamicBatcher,
    stats: ServeStats,
    started: Instant,
}

impl<'a> Server<'a> {
    pub fn new(
        engine: &'a mut Engine,
        store: PrecisionStore,
        router: Router,
        batcher: DynamicBatcher,
    ) -> Self {
        Server {
            engine,
            store,
            router,
            batcher,
            stats: ServeStats::default(),
            started: Instant::now(),
        }
    }

    /// Enqueue a request (routing decides the precision).  `false` =
    /// rejected by backpressure.
    pub fn submit(&mut self, req: Request) -> bool {
        let m = self.router.route(req.class, req.force_m);
        match self.batcher.push(req, m) {
            Ok(()) => true,
            Err(_) => {
                self.stats.rejected += 1;
                false
            }
        }
    }

    /// Drain the queue completely, dispatching batches until empty.
    pub fn process_all(&mut self) -> anyhow::Result<Vec<Response>> {
        let mut out = Vec::new();
        while let Some((m, batch)) = self.batcher.pop_batch() {
            out.extend(self.dispatch(m, batch)?);
        }
        self.stats.wall_secs = self.started.elapsed().as_secs_f64();
        Ok(out)
    }

    fn dispatch(
        &mut self,
        m: u8,
        batch: Vec<super::batcher::QueuedRequest>,
    ) -> anyhow::Result<Vec<Response>> {
        let (bsz, seq_len) = self.engine.batch_shape();
        let vocab = self.engine.vocab_size();
        anyhow::ensure!(batch.len() <= bsz, "batch exceeds engine rows");
        let t0 = Instant::now();
        // single-master precision switch — this is the OTARo deployment
        // property in action: no reload, just (cached) truncation
        let params = self.store.params_at(m).clone();
        // build the token matrix; remember each row's last valid position
        let mut tokens = vec![PAD; bsz * seq_len];
        let mut last_pos = Vec::with_capacity(batch.len());
        for (ri, q) in batch.iter().enumerate() {
            let p = &q.req.prompt;
            let n = p.len().min(seq_len);
            tokens[ri * seq_len..ri * seq_len + n].copy_from_slice(&p[p.len() - n..]);
            last_pos.push(n.saturating_sub(1));
        }
        let logits = self
            .engine
            .logits_step(&params, &tokens, Width::m(m))?;
        let compute_ms = t0.elapsed().as_secs_f64() * 1e3;

        self.stats.batches += 1;
        let mut out = Vec::with_capacity(batch.len());
        for (ri, q) in batch.into_iter().enumerate() {
            let off = (ri * seq_len + last_pos[ri]) * vocab;
            let row = &logits[off..off + vocab];
            let next = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0 as i32;
            let queue_ms = q.enqueued_at.elapsed().as_secs_f64() * 1e3 - compute_ms;
            self.stats.served += 1;
            self.stats.queue_ms.push(queue_ms.max(0.0));
            self.stats.compute_ms.push(compute_ms);
            if let Some(e) = self.stats.per_width.iter_mut().find(|e| e.0 == m) {
                e.1 += 1;
            } else {
                self.stats.per_width.push((m, 1));
            }
            out.push(Response {
                id: q.req.id,
                width_m: m,
                next_token: next,
                queue_ms: queue_ms.max(0.0),
                compute_ms,
            });
        }
        Ok(out)
    }

    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }
}
