//! Precision router: maps request classes to [`Precision`]s.
//!
//! The paper's motivation (intro): generation tasks trade latency for
//! precision, understanding tasks want immediate answers at lower
//! precision; prefill/decode can also run at different widths.  The
//! router encodes that policy and is the single place deployment tuning
//! happens.

use crate::config::ServeConfig;
use crate::sefp::Precision;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskClass {
    /// free-form continuation (quality-sensitive -> high precision)
    Generation,
    /// classification / scoring (latency-sensitive -> low precision)
    Understanding,
    /// anything else
    Other,
}

#[derive(Debug, Clone)]
pub struct Router {
    cfg: ServeConfig,
}

impl Router {
    pub fn new(cfg: ServeConfig) -> Self {
        Router { cfg }
    }

    /// Decide the precision for a request.
    pub fn route(&self, class: TaskClass, force: Option<Precision>) -> Precision {
        if let Some(p) = force {
            return p;
        }
        match class {
            TaskClass::Generation => self.cfg.generation_precision,
            TaskClass::Understanding => self.cfg.understanding_precision,
            TaskClass::Other => self.cfg.default_precision,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_by_class() {
        let r = Router::new(ServeConfig::default());
        assert_eq!(r.route(TaskClass::Generation, None), Precision::of(8));
        assert_eq!(r.route(TaskClass::Understanding, None), Precision::of(4));
        assert_eq!(r.route(TaskClass::Other, None), Precision::of(6));
    }

    #[test]
    fn force_overrides() {
        let r = Router::new(ServeConfig::default());
        assert_eq!(
            r.route(TaskClass::Generation, Some(Precision::of(3))),
            Precision::of(3)
        );
    }
}
