//! Precision router: maps request classes to [`Precision`]s.
//!
//! The paper's motivation (intro): generation tasks trade latency for
//! precision, understanding tasks want immediate answers at lower
//! precision; prefill/decode can also run at different widths.  The
//! router is the single place that decision is made — but the decision
//! itself is delegated to a [`PrecisionPolicy`]:
//! [`StaticPolicy`] (the default) reproduces the frozen 3-arm config
//! lookup, [`AdaptivePolicy`](crate::policy::AdaptivePolicy) closes the
//! loop from serve-time telemetry and shadow quality probes
//! (`rust/src/policy/`).
//!
//! Routing output is always a rung of the configured ladder
//! (`ServeConfig::ladder`), on BOTH paths.  Forced per-request
//! precisions do not bypass validation: below the bottom rung snaps up
//! to it, above the top rung snaps down, a width strictly inside the
//! ladder's range that is not a rung snaps to the next rung up
//! (quality-preserving); every forced snap is counted and surfaced
//! through `ServeStats::forced_clamps`.  Non-forced policy decisions
//! snap the same way (uncounted), so a `StaticPolicy` class precision
//! configured off-ladder cannot escape it either.

use crate::config::ServeConfig;
use crate::policy::{PrecisionPolicy, StaticPolicy};
use crate::sefp::Precision;

/// Request class, ordered so policy/telemetry maps keyed on it iterate
/// deterministically.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum TaskClass {
    /// free-form continuation (quality-sensitive -> high precision)
    Generation,
    /// classification / scoring (latency-sensitive -> low precision)
    Understanding,
    /// anything else
    Other,
}

#[derive(Debug)]
pub struct Router {
    /// configured ladder, highest precision first, deduped
    ladder: Vec<Precision>,
    policy: Box<dyn PrecisionPolicy>,
    /// forced precisions snapped into the configured ladder
    clamps: u64,
}

impl Router {
    /// Static routing from the config's three class precisions — today's
    /// behavior and the default.
    pub fn new(cfg: ServeConfig) -> Self {
        let policy = Box::new(StaticPolicy::new(&cfg));
        Self::with_policy(cfg, policy)
    }

    /// Route through an explicit policy implementation.
    pub fn with_policy(cfg: ServeConfig, policy: Box<dyn PrecisionPolicy>) -> Self {
        let mut ladder = cfg.ladder.clone();
        assert!(!ladder.is_empty(), "serve ladder must be non-empty");
        Precision::canonicalize_ladder(&mut ladder);
        Router { ladder, policy, clamps: 0 }
    }

    /// Build from config, choosing
    /// [`AdaptivePolicy`](crate::policy::AdaptivePolicy) when
    /// `cfg.policy.adaptive` is set, [`StaticPolicy`] otherwise.
    pub fn from_config(cfg: ServeConfig) -> Self {
        if cfg.policy.adaptive {
            let policy = Box::new(crate::policy::AdaptivePolicy::new(&cfg));
            Self::with_policy(cfg, policy)
        } else {
            Self::new(cfg)
        }
    }

    /// Decide the precision for a request.  `force` pins the request to
    /// an explicit width, clamped to the configured ladder (and
    /// counted); non-forced decisions honor the ladder too — a
    /// `StaticPolicy` class precision configured outside it snaps
    /// silently (`AdaptivePolicy` output is in-ladder by construction),
    /// so `route` can never return an off-ladder width through either
    /// path.
    pub fn route(&mut self, class: TaskClass, force: Option<Precision>) -> Precision {
        match force {
            Some(p) => {
                let snapped = self.snap(p);
                if snapped != p {
                    self.clamps += 1;
                }
                snapped
            }
            None => {
                let p = self.policy.decide(class);
                self.snap(p)
            }
        }
    }

    /// Snap a precision into the configured ladder — the shared
    /// [`Precision::snap_to_ladder`] rule (next rung up inside the
    /// range, clamped at the bounds).
    fn snap(&self, p: Precision) -> Precision {
        Precision::snap_to_ladder(&self.ladder, p)
    }

    /// The canonicalized serve ladder (highest precision first).
    pub fn ladder(&self) -> &[Precision] {
        &self.ladder
    }

    /// Forced precisions snapped into the ladder so far.
    pub fn forced_clamps(&self) -> u64 {
        self.clamps
    }

    /// The active policy — the server feeds completion observations and
    /// probe results through this.
    pub fn policy(&self) -> &dyn PrecisionPolicy {
        self.policy.as_ref()
    }

    pub fn policy_mut(&mut self) -> &mut dyn PrecisionPolicy {
        self.policy.as_mut()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_by_class() {
        let mut r = Router::new(ServeConfig::default());
        assert_eq!(r.route(TaskClass::Generation, None), Precision::of(8));
        assert_eq!(r.route(TaskClass::Understanding, None), Precision::of(4));
        assert_eq!(r.route(TaskClass::Other, None), Precision::of(6));
        assert_eq!(r.policy().snapshot().decisions, 3);
    }

    #[test]
    fn force_on_a_rung_passes_through() {
        let mut r = Router::new(ServeConfig::default());
        for p in Precision::LADDER {
            assert_eq!(r.route(TaskClass::Generation, Some(p)), p);
        }
        assert_eq!(r.forced_clamps(), 0, "exact rungs are not clamps");
    }

    #[test]
    fn force_outside_the_ladder_is_clamped() {
        let mut r = Router::new(ServeConfig::default()); // ladder {8..3}
        // below the bottom rung: snaps up to it
        assert_eq!(
            r.route(TaskClass::Understanding, Some(Precision::of(1))),
            Precision::of(3)
        );
        // above the top rung: snaps down to it
        assert_eq!(
            r.route(TaskClass::Generation, Some(Precision::of(12))),
            Precision::of(8)
        );
        assert_eq!(r.forced_clamps(), 2);
    }

    #[test]
    fn force_between_rungs_snaps_to_the_next_rung_up() {
        let cfg = ServeConfig {
            ladder: vec![Precision::of(8), Precision::of(6), Precision::of(3)],
            ..ServeConfig::default()
        };
        let mut r = Router::with_policy(cfg.clone(), Box::new(StaticPolicy::new(&cfg)));
        // 4 and 5 are inside the range but not rungs -> snap up to 6
        assert_eq!(r.route(TaskClass::Other, Some(Precision::of(4))), Precision::of(6));
        assert_eq!(r.route(TaskClass::Other, Some(Precision::of(5))), Precision::of(6));
        // exact rungs still pass through
        assert_eq!(r.route(TaskClass::Other, Some(Precision::of(3))), Precision::of(3));
        assert_eq!(r.forced_clamps(), 2);
    }

    #[test]
    fn non_forced_decisions_honor_the_ladder_too() {
        // a StaticPolicy class precision configured outside the ladder
        // must snap into it on the non-forced path (uncounted — nothing
        // was forced), so route output is always an in-ladder rung
        let cfg = ServeConfig {
            ladder: vec![Precision::of(7), Precision::of(5), Precision::of(4)],
            ..ServeConfig::default() // generation 8, default 6 — off-ladder
        };
        let mut r = Router::from_config(cfg);
        assert_eq!(r.route(TaskClass::Generation, None), Precision::of(7));
        assert_eq!(r.route(TaskClass::Other, None), Precision::of(7));
        assert_eq!(r.route(TaskClass::Understanding, None), Precision::of(4));
        assert_eq!(r.forced_clamps(), 0, "nothing was forced");
    }

    #[test]
    fn from_config_selects_the_policy_kind() {
        let r = Router::from_config(ServeConfig::default());
        assert!(format!("{:?}", r.policy()).contains("StaticPolicy"));
        let cfg = ServeConfig {
            policy: crate::config::PolicyConfig {
                adaptive: true,
                ..crate::config::PolicyConfig::default()
            },
            ..ServeConfig::default()
        };
        let r = Router::from_config(cfg);
        assert!(format!("{:?}", r.policy()).contains("AdaptivePolicy"));
    }
}
