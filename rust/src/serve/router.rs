//! Precision router: maps request classes to bit-widths.
//!
//! The paper's motivation (intro): generation tasks trade latency for
//! precision, understanding tasks want immediate answers at lower
//! precision; prefill/decode can also run at different widths.  The
//! router encodes that policy and is the single place deployment tuning
//! happens.

use crate::config::ServeConfig;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskClass {
    /// free-form continuation (quality-sensitive -> high precision)
    Generation,
    /// classification / scoring (latency-sensitive -> low precision)
    Understanding,
    /// anything else
    Other,
}

#[derive(Debug, Clone)]
pub struct Router {
    cfg: ServeConfig,
}

impl Router {
    pub fn new(cfg: ServeConfig) -> Self {
        Router { cfg }
    }

    /// Decide the mantissa width for a request.
    pub fn route(&self, class: TaskClass, force_m: Option<u8>) -> u8 {
        if let Some(m) = force_m {
            return m;
        }
        match class {
            TaskClass::Generation => self.cfg.generation_m,
            TaskClass::Understanding => self.cfg.understanding_m,
            TaskClass::Other => self.cfg.default_m,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_by_class() {
        let r = Router::new(ServeConfig::default());
        assert_eq!(r.route(TaskClass::Generation, None), 8);
        assert_eq!(r.route(TaskClass::Understanding, None), 4);
        assert_eq!(r.route(TaskClass::Other, None), 6);
    }

    #[test]
    fn force_overrides() {
        let r = Router::new(ServeConfig::default());
        assert_eq!(r.route(TaskClass::Generation, Some(3)), 3);
    }
}
