//! Multi-precision serving — the deployment story OTARo enables (paper
//! fig. 1 and table 2): ONE stored model, per-request precision switching
//! by mantissa truncation, no model zoo and no requantization pass.
//!
//! * [`store`]   — `PrecisionStore`: master weights kept ONCE in SEFP
//!   E5M8; any lower precision is derived by `truncate()` and cached.
//! * [`router`]  — task-class → precision policy (generation vs
//!   understanding, paper intro).
//! * [`batcher`] — dynamic batcher: queued requests are grouped by
//!   precision and dispatched as full engine batches.
//! * [`server`]  — ties the three together over the PJRT engine and
//!   collects latency/throughput stats.

pub mod batcher;
pub mod router;
pub mod server;
pub mod store;

pub use batcher::DynamicBatcher;
pub use router::{Router, TaskClass};
pub use server::{Server, ServeStats};
pub use store::PrecisionStore;

/// A serving request: classify-or-continue over a token prompt.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub class: TaskClass,
    pub prompt: Vec<i32>,
    /// explicit precision override (None = router decides)
    pub force_m: Option<u8>,
}

/// The response: next-token argmax plus timing.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub width_m: u8,
    pub next_token: i32,
    pub queue_ms: f64,
    pub compute_ms: f64,
}
