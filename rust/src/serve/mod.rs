//! Multi-precision serving — the deployment story OTARo enables (paper
//! fig. 1 and table 2): ONE stored model, per-request precision switching
//! by mantissa truncation, no model zoo and no requantization pass.
//!
//! * [`store`]   — [`PrecisionLadder`]: master weights kept ONCE in SEFP
//!   E5M8; any lower precision is a [`LadderView`] derived by integer
//!   truncation, cached under a byte budget with LRU eviction (no f32
//!   round trip on the switch path, no per-width model zoo).
//! * [`router`]  — task-class → [`Precision`] routing.  The decision is
//!   delegated to a [`PrecisionPolicy`](crate::policy::PrecisionPolicy):
//!   [`StaticPolicy`](crate::policy::StaticPolicy) is the frozen config
//!   lookup (default), [`AdaptivePolicy`](crate::policy::AdaptivePolicy)
//!   closes the loop from serve-time telemetry and shadow quality
//!   probes (the `policy` control plane).  Forced per-request
//!   precisions are clamped to the configured ladder, never passed
//!   through unvalidated.
//! * [`batcher`] — dynamic batcher + deadline/age-aware scheduler.
//!   Each non-empty precision queue is scored
//!   `fill_ratio + age_weight * oldest_wait_secs`; any queue whose head
//!   has waited `max_wait` is scheduled next regardless of score (the
//!   anti-starvation bound — in-flight decodes still finish first), and
//!   every tie breaks on the lowest precision over `BTreeMap` iteration —
//!   the schedule is bit-for-bit deterministic.
//! * [`backend`] — [`LogitsBackend`]: `load_view` installs the SEFP view
//!   for a precision run, `logits_step` is the one-step logits interface
//!   the server generates through.  [`EngineHandle`] adapts the owned
//!   PJRT engine; [`DecoderBackend`] serves REAL SEFP logits from the
//!   pure-Rust batched decode engine (`infer::DecoderSim` + ladder
//!   views via the zero-float `QuantLinear::from_sefp` path — per-row KV
//!   caches map onto the continuous-batching refill, no PJRT artifacts
//!   needed); [`SimBackend`] is a deterministic hash stand-in for
//!   scheduler tests that want precision-keyed but weightless logits.
//! * [`server`]  — continuous-batching generation engine.  A scheduled
//!   batch is decoded for up to `max_new_tokens` tokens via repeated
//!   `logits_step` calls (greedy or temperature sampling); rows freed by
//!   finished requests are refilled FIFO from the same precision queue
//!   between decode iterations, unless another precision has crossed the
//!   anti-starvation bound — then the run ends and the scheduler picks
//!   the overdue precision.  Every completion is fed back to the
//!   routing policy as an [`Observation`](crate::policy::Observation);
//!   a sampled fraction is re-scored at master precision between runs
//!   ([`shadow_probe`](crate::policy::shadow_probe)).  Ladder switch
//!   stats (hit/miss/evict/latency) and policy decision counters
//!   (promotions/demotions/probe agreement/forced clamps) surface
//!   through [`ServeStats`].
//! * [`metrics`] — [`ServeMetrics`]: the serve stack's pre-registered
//!   handle set over the [`obs`](crate::obs) registry.  Every serving
//!   event (admission, shed, dispatch, decode step, completion, probe)
//!   records through typed handles with no allocation; [`ServeStats`]
//!   is re-derived from the registry, and
//!   [`Server::metrics_snapshot`] serializes the whole metric plane as
//!   deterministic JSON for the [`workload`](crate::workload) harness.

pub mod backend;
pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;
pub mod store;

pub use backend::{demo_decoder_params, DecoderBackend, EngineHandle, LogitsBackend, SimBackend};
pub use batcher::{DynamicBatcher, SchedPolicy};
pub use metrics::ServeMetrics;
pub use router::{Router, TaskClass};
pub use server::{Server, ServeStats};
pub use store::{LadderStats, LadderTensor, LadderView, PrecisionLadder};

use crate::sefp::Precision;

/// A serving request: generate up to `max_new_tokens` tokens from a
/// token prompt (1 = classic next-token serving).
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub class: TaskClass,
    pub prompt: Vec<i32>,
    /// explicit precision override (None = router decides)
    pub precision: Option<Precision>,
    /// decode budget; generation stops early at EOS
    pub max_new_tokens: usize,
    /// 0.0 = greedy argmax; > 0 = softmax temperature sampling
    pub temperature: f32,
}

impl Request {
    /// A single-token (next-token) request — the common case.
    pub fn new(id: u64, class: TaskClass, prompt: Vec<i32>) -> Self {
        Request { id, class, prompt, precision: None, max_new_tokens: 1, temperature: 0.0 }
    }

    pub fn with_precision(mut self, p: Precision) -> Self {
        self.precision = Some(p);
        self
    }

    pub fn with_max_new_tokens(mut self, n: usize) -> Self {
        self.max_new_tokens = n.max(1);
        self
    }

    pub fn with_temperature(mut self, t: f32) -> Self {
        self.temperature = t;
        self
    }
}

/// The response: the generated tokens plus timing.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// precision this request was served at
    pub precision: Precision,
    /// first generated token (kept for next-token callers)
    pub next_token: i32,
    /// the full generation, `next_token` included
    pub tokens: Vec<i32>,
    pub queue_ms: f64,
    pub compute_ms: f64,
}
