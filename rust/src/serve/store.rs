//! `PrecisionLadder` — single-master multi-precision weights, SEFP-native.
//!
//! The fine-tuned f32 master is encoded ONCE into SEFP E5M8 (the top of
//! the ladder).  Every other precision is derived by `SefpTensor::truncate`
//! — pure integer shifts on significands, no access to the original
//! floats — exactly the on-device switch conventional quantization cannot
//! do (paper fig. 1).
//!
//! Unlike the old `PrecisionStore`, which cached a **full dequantized f32
//! `ParamStore` per width** (a 6-wide ladder meant six f32 copies — the
//! very "model zoo" memory cost the paper eliminates), the ladder stays
//! in the SEFP domain end to end: [`view_at`](PrecisionLadder::view_at)
//! returns a [`LadderView`] whose quantized tensors are `SefpTensor`s
//! consumable directly by `QuantLinear::from_sefp` / `DecoderSim`, and
//! non-quantized tensors (1-D norm gains) are `Arc`-shared across every
//! view instead of being placeholder-encoded per width.
//!
//! Cached residency of derived views is governed by a configurable byte
//! budget with LRU eviction; per-switch hit/miss/evict/latency stats are
//! kept in [`LadderStats`] and surfaced through `serve::ServeStats`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::metrics::Summary;
use crate::runtime::ParamStore;
use crate::sefp::{Precision, SefpSpec, SefpTensor};

/// One tensor slot of a [`LadderView`].
#[derive(Debug, Clone)]
pub enum LadderTensor {
    /// SEFP-quantized weight at the view's precision.
    Quant(SefpTensor),
    /// Non-quantized tensor (norm gains, pos embed) — `Arc`-shared across
    /// the master and every derived view, never copied per width.
    Pass(Arc<Vec<f32>>),
}

/// SEFP-domain weights at one precision, aligned with the manifest's
/// tensor order.  Produced by [`PrecisionLadder::view_at`]; quantized
/// slots feed `QuantLinear::from_sefp` directly, and
/// [`to_param_store`](LadderView::to_param_store) bridges to the f32 ABI
/// the PJRT engine requires (the only place a float round trip happens,
/// and only for that backend).
#[derive(Debug, Clone)]
pub struct LadderView {
    pub precision: Precision,
    /// identity of the ladder this view was derived from (see
    /// [`LadderView::ladder_id`])
    ladder_id: u64,
    tensors: Vec<LadderTensor>,
    names: Arc<Vec<String>>,
    shapes: Arc<Vec<Vec<usize>>>,
    quantized: Arc<Vec<bool>>,
}

impl LadderView {
    pub fn tensors(&self) -> &[LadderTensor] {
        &self.tensors
    }

    /// Process-unique id of the originating [`PrecisionLadder`].
    /// Backends key their prepared-weights scratch on
    /// `(ladder_id, precision)` so that swapping in a NEW ladder (a hot
    /// weight update) can never be served from weights prepared for the
    /// old one.
    pub fn ladder_id(&self) -> u64 {
        self.ladder_id
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn shapes(&self) -> &[Vec<usize>] {
        &self.shapes
    }

    /// Derive the view one or more rungs down — integer shifts only.
    fn truncate(&self, p: Precision) -> LadderView {
        LadderView {
            precision: p,
            ladder_id: self.ladder_id,
            tensors: self
                .tensors
                .iter()
                .map(|t| match t {
                    LadderTensor::Quant(q) => LadderTensor::Quant(q.truncate(p)),
                    LadderTensor::Pass(f) => LadderTensor::Pass(f.clone()),
                })
                .collect(),
            names: self.names.clone(),
            shapes: self.shapes.clone(),
            quantized: self.quantized.clone(),
        }
    }

    /// Bytes of SEFP working state this view owns (what the ladder budget
    /// charges).  Passthrough tensors are shared with the master and cost
    /// nothing per view.
    pub fn sefp_bytes(&self) -> usize {
        self.tensors
            .iter()
            .map(|t| match t {
                LadderTensor::Quant(q) => q.working_bytes(),
                LadderTensor::Pass(_) => 0,
            })
            .sum()
    }

    /// Materialize an f32 `ParamStore` — the ABI bridge for the PJRT
    /// engine backend, which takes f32 parameter literals.  Serving code
    /// holds at most ONE of these at a time (the backend's scratch),
    /// never one per width.
    pub fn to_param_store(&self) -> ParamStore {
        ParamStore {
            tensors: self
                .tensors
                .iter()
                .map(|t| match t {
                    LadderTensor::Quant(q) => q.decode(),
                    LadderTensor::Pass(f) => (**f).clone(),
                })
                .collect(),
            names: (*self.names).clone(),
            shapes: (*self.shapes).clone(),
            quantized: (*self.quantized).clone(),
        }
    }
}

/// Per-switch statistics of a [`PrecisionLadder`].
#[derive(Debug, Clone, Default)]
pub struct LadderStats {
    /// `view_at` calls answered from cache (or by the master itself)
    pub hits: u64,
    /// `view_at` calls that had to derive a view by truncation
    pub misses: u64,
    /// views dropped to keep residency under the byte budget
    pub evictions: u64,
    /// derivation latency per miss, milliseconds
    pub switch_ms: Summary,
    /// (precision, derivation ms) of the most recent misses, oldest
    /// first, capped at [`SWITCH_LOG_CAP`] — under a tight budget every
    /// switch can be a miss, so an unbounded log would leak on a
    /// long-running server (`switch_ms` keeps the full-run aggregates)
    pub switch_log: Vec<(Precision, f64)>,
}

/// Retention bound for [`LadderStats::switch_log`].
pub const SWITCH_LOG_CAP: usize = 256;

/// Monotonic source of [`LadderView::ladder_id`]s.
static LADDER_IDS: AtomicU64 = AtomicU64::new(0);

/// The serving-side precision ladder: one SEFP master + budget-governed
/// cache of truncated views.
pub struct PrecisionLadder {
    master: Arc<LadderView>,
    budget_bytes: usize,
    /// derived views with their last-use tick (LRU); BTreeMap so every
    /// traversal — eviction scans, resident listings — runs in
    /// precision order and decisions never depend on hash iteration
    cache: BTreeMap<Precision, (Arc<LadderView>, u64)>,
    tick: u64,
    pub stats: LadderStats,
}

impl PrecisionLadder {
    /// Encode the fine-tuned master at the top of the paper's ladder
    /// (E5M8).  The manifest's `quantized` flags say exactly which
    /// tensors the training graph fake-quantized (2-D weights; pos_embed
    /// and norm gains stay f32) — the ladder mirrors that, so the
    /// serving-side switch reproduces training numerics.
    pub fn from_params(params: &ParamStore) -> Self {
        Self::from_params_at(params, Precision::of(8))
    }

    /// Encode the master at an explicit top precision.
    pub fn from_params_at(params: &ParamStore, top: Precision) -> Self {
        let spec = SefpSpec::new(top);
        let tensors = params
            .tensors
            .iter()
            .enumerate()
            .map(|(i, t)| {
                if params.quantized[i] {
                    LadderTensor::Quant(SefpTensor::encode(t, &spec))
                } else {
                    LadderTensor::Pass(Arc::new(t.clone()))
                }
            })
            .collect();
        PrecisionLadder {
            master: Arc::new(LadderView {
                precision: top,
                ladder_id: LADDER_IDS.fetch_add(1, Ordering::Relaxed),
                tensors,
                names: Arc::new(params.names.clone()),
                shapes: Arc::new(params.shapes.clone()),
                quantized: Arc::new(params.quantized.clone()),
            }),
            budget_bytes: usize::MAX,
            cache: BTreeMap::new(),
            tick: 0,
            stats: LadderStats::default(),
        }
    }

    /// Build the serving ladder straight from a packed `.sefp` container
    /// at its stored top precision — no f32 master is ever materialized:
    /// quantized tensors come off the artifact's bit-planes as integer
    /// gathers, and passthrough tensors are copied out of the raw-f32
    /// region once.
    pub fn from_artifact(a: &crate::artifact::Artifact) -> anyhow::Result<Self> {
        Self::from_artifact_at(a, a.meta().top)
    }

    /// Like [`from_artifact`](Self::from_artifact) but opened at an
    /// explicit rung — truncate-at-load: the artifact's lower mantissa
    /// planes are simply never borrowed or gathered, so a deployment
    /// pinned below the stored top materializes exactly the bits it
    /// serves (the container itself was read and checksummed whole at
    /// open).  Errors if `top` exceeds the artifact's stored precision.
    pub fn from_artifact_at(
        a: &crate::artifact::Artifact,
        top: Precision,
    ) -> anyhow::Result<Self> {
        let metas = a.tensors();
        let mut tensors = Vec::with_capacity(metas.len());
        for (i, tm) in metas.iter().enumerate() {
            if tm.quantized {
                tensors.push(LadderTensor::Quant(a.view(i, top)?.to_tensor()));
            } else {
                tensors.push(LadderTensor::Pass(Arc::new(a.raw_f32(i)?)));
            }
        }
        Ok(PrecisionLadder {
            master: Arc::new(LadderView {
                precision: top,
                ladder_id: LADDER_IDS.fetch_add(1, Ordering::Relaxed),
                tensors,
                names: Arc::new(metas.iter().map(|t| t.name.clone()).collect()),
                shapes: Arc::new(metas.iter().map(|t| t.shape.clone()).collect()),
                quantized: Arc::new(metas.iter().map(|t| t.quantized).collect()),
            }),
            budget_bytes: usize::MAX,
            cache: BTreeMap::new(),
            tick: 0,
            stats: LadderStats::default(),
        })
    }

    /// Cap the bytes of derived views kept resident (the master is always
    /// resident and is not charged — it IS the model).
    pub fn with_budget(mut self, budget_bytes: usize) -> Self {
        self.budget_bytes = budget_bytes;
        self
    }

    /// Re-cap the residency budget on a LIVE ladder (the soak harness's
    /// mid-run "memory pressure" flip).  Shrinking below current
    /// residency evicts LRU-first immediately — the cap is enforced at
    /// the moment it changes, not lazily at the next switch.
    pub fn set_budget(&mut self, budget_bytes: usize) {
        self.budget_bytes = budget_bytes;
        self.evict_to_budget(self.master.precision);
    }

    /// Top-of-ladder precision the master is stored at.
    pub fn top(&self) -> Precision {
        self.master.precision
    }

    /// The always-resident master view itself (top precision).  Unlike
    /// [`view_at`](Self::view_at) this takes `&self` and touches no
    /// cache state — backends use it to inspect tensor names/shapes at
    /// construction time.
    pub fn master_view(&self) -> Arc<LadderView> {
        self.master.clone()
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// THE precision switch: SEFP-domain weights at `p`.  Cache hit =
    /// free; miss = one truncation pass (integer shifts), then the view
    /// is retained under the byte budget with LRU eviction.  Asking for
    /// a precision above the master is an error — mantissa bits cannot
    /// be invented.
    pub fn view_at(&mut self, p: Precision) -> anyhow::Result<Arc<LadderView>> {
        anyhow::ensure!(
            p <= self.master.precision,
            "precision {p} above the {} master",
            self.master.precision
        );
        self.tick += 1;
        if p == self.master.precision {
            self.stats.hits += 1;
            return Ok(self.master.clone());
        }
        if let Some((view, last_used)) = self.cache.get_mut(&p) {
            *last_used = self.tick;
            self.stats.hits += 1;
            return Ok(view.clone());
        }
        let start = Instant::now();
        let view = Arc::new(self.master.truncate(p));
        let ms = start.elapsed().as_secs_f64() * 1e3;
        self.stats.misses += 1;
        self.stats.switch_ms.push(ms);
        self.stats.switch_log.push((p, ms));
        if self.stats.switch_log.len() > SWITCH_LOG_CAP {
            self.stats.switch_log.remove(0);
        }
        self.cache.insert(p, (view.clone(), self.tick));
        self.evict_to_budget(p);
        Ok(view)
    }

    /// Evict least-recently-used views until residency fits the budget.
    /// The just-requested precision is evicted only as a last resort —
    /// when it alone exceeds the budget it is simply not retained (the
    /// budget is a hard cap, not advisory; the caller still gets its
    /// `Arc`, it just re-derives next time).
    ///
    /// Victim selection is total-ordered on `(last_used, precision)`:
    /// when two views share a last-use tick the LOWER precision goes
    /// first (cheapest to re-derive), so identical cache states always
    /// evict the identical victim regardless of insertion history.
    fn evict_to_budget(&mut self, keep: Precision) {
        while self.resident_bytes() > self.budget_bytes {
            let victim = self
                .cache
                .iter()
                .filter(|(&p, _)| p != keep)
                .min_by_key(|(&p, &(_, last_used))| (last_used, p))
                .map(|(&p, _)| p);
            let victim = victim.unwrap_or(keep);
            if self.cache.remove(&victim).is_some() {
                self.stats.evictions += 1;
            } else {
                break;
            }
        }
    }

    /// Bytes of derived views currently resident (excludes the master).
    pub fn resident_bytes(&self) -> usize {
        self.cache.values().map(|(v, _)| v.sefp_bytes()).sum()
    }

    /// Storage bytes of the single master copy: packed SEFP bits for the
    /// quantized tensors + the passthrough f32 tensors once.
    pub fn master_bytes(&self) -> usize {
        self.master
            .tensors
            .iter()
            .map(|t| match t {
                LadderTensor::Quant(q) => q.ideal_bits().div_ceil(8),
                LadderTensor::Pass(f) => f.len() * 4,
            })
            .sum()
    }

    /// Bytes a per-precision model zoo would need for the same ladder —
    /// the storage overhead OTARo eliminates.  Every zoo entry is a
    /// complete deployable model, so the non-quantized f32 tensors are
    /// charged once per width too (the seed omitted them and understated
    /// the zoo footprint the paper's table compares against).
    pub fn zoo_bytes(&self, widths: &[Precision]) -> usize {
        widths
            .iter()
            .map(|&p| {
                self.master
                    .tensors
                    .iter()
                    .map(|t| match t {
                        LadderTensor::Quant(q) => {
                            (q.len * p.bits_per_elem() + q.n_groups() * 5).div_ceil(8)
                        }
                        LadderTensor::Pass(f) => f.len() * 4,
                    })
                    .sum::<usize>()
            })
            .sum()
    }

    /// Cold-switch cost: derive `p` from the master and materialize f32
    /// (the full engine-backend switch path), cache bypassed.
    pub fn switch_cost_ms(&self, p: Precision) -> f64 {
        let start = Instant::now();
        let mut total = 0usize;
        for t in &self.master.tensors {
            if let LadderTensor::Quant(q) = t {
                total += q.truncate(p).decode().len();
            }
        }
        let ms = start.elapsed().as_secs_f64() * 1e3;
        // a model with quantized tensors must have produced work; checked
        // in debug only so release benchmarks don't carry the branch
        debug_assert!(
            total > 0
                || !self
                    .master
                    .tensors
                    .iter()
                    .any(|t| matches!(t, LadderTensor::Quant(_))),
            "cold switch touched no weights"
        );
        ms
    }

    /// Precisions currently resident in the derived-view cache (sorted
    /// ascending — the map is ordered; the master's own precision is not
    /// listed).
    pub fn cached_precisions(&self) -> Vec<Precision> {
        self.cache.keys().copied().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::QuantLinear;

    fn params() -> ParamStore {
        let mut rng = crate::data::Rng::new(1);
        ParamStore {
            tensors: vec![
                (0..256).map(|_| rng.normal() as f32 * 0.1).collect(),
                vec![1.0; 16],
            ],
            names: vec!["w".into(), "ln".into()],
            shapes: vec![vec![16, 16], vec![16]],
            quantized: vec![true, false],
        }
    }

    #[test]
    fn switch_derives_truncated_weights() {
        let p = params();
        let mut ladder = PrecisionLadder::from_params(&p);
        let v4 = ladder.view_at(Precision::of(4)).unwrap();
        // 2-D tensor quantized at m=4 == direct encode (ladder exactness)
        let direct = SefpTensor::encode(&p.tensors[0], &SefpSpec::new(Precision::of(4)));
        match &v4.tensors()[0] {
            LadderTensor::Quant(q) => assert_eq!(*q, direct),
            other => panic!("expected quant slot, got {other:?}"),
        }
        // 1-D passthrough untouched and shared, not re-encoded
        match &v4.tensors()[1] {
            LadderTensor::Pass(f) => assert_eq!(**f, p.tensors[1]),
            other => panic!("expected passthrough slot, got {other:?}"),
        }
        // the f32 ABI bridge decodes the same numbers
        let ps = v4.to_param_store();
        assert_eq!(ps.tensors[0], direct.decode());
        assert_eq!(ps.tensors[1], p.tensors[1]);
        assert_eq!(ps.names, p.names);
    }

    #[test]
    fn cache_hits_after_first_switch() {
        let mut ladder = PrecisionLadder::from_params(&params());
        let _ = ladder.view_at(Precision::of(5)).unwrap();
        let _ = ladder.view_at(Precision::of(5)).unwrap();
        assert_eq!(ladder.stats.misses, 1);
        assert_eq!(ladder.stats.hits, 1);
        assert_eq!(ladder.stats.switch_log.len(), 1);
        assert_eq!(ladder.cached_precisions(), vec![Precision::of(5)]);
        // the master itself is a hit, not a derivation
        let top = ladder.view_at(Precision::of(8)).unwrap();
        assert_eq!(top.precision, Precision::of(8));
        assert_eq!(ladder.stats.misses, 1);
        assert_eq!(ladder.stats.hits, 2);
    }

    #[test]
    fn view_above_master_is_an_error() {
        let mut ladder =
            PrecisionLadder::from_params_at(&params(), Precision::of(6));
        assert!(ladder.view_at(Precision::of(8)).is_err());
        assert!(ladder.view_at(Precision::of(6)).is_ok());
    }

    #[test]
    fn budget_bounds_residency_across_full_ladder() {
        // Acceptance scenario: walk the whole {8,7,6,5,4,3} ladder twice
        // under a budget that holds ~2 derived views; residency must stay
        // under the budget after every switch and evictions must be
        // recorded.  (Each derived view here is 256*2 + 4 = 516 bytes.)
        let mut ladder = PrecisionLadder::from_params(&params()).with_budget(1200);
        for _ in 0..2 {
            for p in Precision::LADDER {
                let v = ladder.view_at(p).unwrap();
                assert_eq!(v.precision, p);
                assert!(
                    ladder.resident_bytes() <= ladder.budget_bytes(),
                    "resident {} exceeds budget {} after switch to {p}",
                    ladder.resident_bytes(),
                    ladder.budget_bytes()
                );
            }
        }
        assert!(ladder.stats.evictions > 0, "budget must have forced evictions");
        assert_eq!(ladder.stats.hits + ladder.stats.misses, 12);
        assert!(ladder.stats.misses > 5, "evicted views must re-derive");
        assert!(ladder.stats.switch_ms.n >= ladder.stats.misses);
        assert!(ladder.cached_precisions().len() <= 2);
    }

    #[test]
    fn zero_budget_caches_nothing() {
        // "cache nothing" must be expressible: a view larger than the
        // budget is handed out but never retained, so residency stays at
        // zero instead of silently exceeding the cap forever
        let mut ladder = PrecisionLadder::from_params(&params()).with_budget(0);
        for _ in 0..3 {
            let v = ladder.view_at(Precision::of(4)).unwrap();
            assert_eq!(v.precision, Precision::of(4));
            assert_eq!(ladder.resident_bytes(), 0);
        }
        assert!(ladder.cached_precisions().is_empty());
        assert_eq!(ladder.stats.misses, 3, "nothing retained, every switch derives");
        assert_eq!(ladder.stats.evictions, 3);
    }

    #[test]
    fn shrinking_a_live_budget_evicts_immediately() {
        // the soak flip: a generous budget holds the whole derived set,
        // then a live set_budget shrink must evict LRU-first right away
        let mut ladder = PrecisionLadder::from_params(&params()).with_budget(usize::MAX);
        let _ = ladder.view_at(Precision::of(5)).unwrap();
        let _ = ladder.view_at(Precision::of(4)).unwrap();
        let _ = ladder.view_at(Precision::of(3)).unwrap();
        assert_eq!(ladder.cached_precisions().len(), 3);
        let one_view = ladder.view_at(Precision::of(3)).unwrap().sefp_bytes();
        ladder.set_budget(one_view);
        assert!(ladder.resident_bytes() <= one_view);
        assert!(ladder.stats.evictions >= 2, "shrink must evict, not defer");
        // the most recently used view survives
        assert_eq!(ladder.cached_precisions(), vec![Precision::of(3)]);
        // growing back is lazy — nothing re-derives until asked
        ladder.set_budget(usize::MAX);
        assert_eq!(ladder.cached_precisions(), vec![Precision::of(3)]);
    }

    #[test]
    fn views_carry_the_ladder_identity() {
        // two ladders over identical params must hand out distinguishable
        // views — backends key prepared weights on (ladder_id, precision)
        let p = params();
        let mut a = PrecisionLadder::from_params(&p);
        let mut b = PrecisionLadder::from_params(&p);
        let va = a.view_at(Precision::of(4)).unwrap();
        let vb = b.view_at(Precision::of(4)).unwrap();
        assert_ne!(va.ladder_id(), vb.ladder_id());
        // and a view keeps its ladder's id down the whole ladder
        let va3 = a.view_at(Precision::of(3)).unwrap();
        assert_eq!(va.ladder_id(), va3.ladder_id());
    }

    #[test]
    fn lru_keeps_recently_used_views() {
        // budget for two views: touching m=5 before inserting m=3 must
        // evict m=4 (the least recently used), not m=5
        let mut ladder = PrecisionLadder::from_params(&params()).with_budget(1200);
        let _ = ladder.view_at(Precision::of(5)).unwrap();
        let _ = ladder.view_at(Precision::of(4)).unwrap();
        let _ = ladder.view_at(Precision::of(5)).unwrap(); // refresh 5
        let _ = ladder.view_at(Precision::of(3)).unwrap(); // evicts 4
        assert_eq!(
            ladder.cached_precisions(),
            vec![Precision::of(3), Precision::of(5)]
        );
        assert_eq!(ladder.stats.evictions, 1);
    }

    #[test]
    fn eviction_tie_break_is_insertion_order_independent() {
        // two derived views with EQUAL last-used ticks: the victim must
        // come from the explicit (last_used, precision) ordering — the
        // lower precision — not from map iteration order, so both
        // insertion orders leave the same survivor
        let p = params();
        for flip in [false, true] {
            let base = PrecisionLadder::from_params(&p);
            let v4 = Arc::new(base.master.truncate(Precision::of(4)));
            let v5 = Arc::new(base.master.truncate(Precision::of(5)));
            // budget holds exactly one of the two resident views
            let mut ladder = base.with_budget(v5.sefp_bytes());
            if flip {
                ladder.cache.insert(Precision::of(5), (v5, 7));
                ladder.cache.insert(Precision::of(4), (v4, 7));
            } else {
                ladder.cache.insert(Precision::of(4), (v4, 7));
                ladder.cache.insert(Precision::of(5), (v5, 7));
            }
            // keep = a precision not in the cache, so both views compete
            ladder.evict_to_budget(Precision::of(3));
            assert_eq!(
                ladder.cached_precisions(),
                vec![Precision::of(5)],
                "flip={flip}: tie must evict the lower precision"
            );
            assert_eq!(ladder.stats.evictions, 1, "flip={flip}");
        }
    }

    #[test]
    fn from_artifact_matches_from_params() {
        use crate::artifact::{pack_params, Artifact, ArtifactMeta};
        let p = params();
        let a = Artifact::from_bytes(pack_params(&p, &ArtifactMeta::new(Precision::of(8))))
            .unwrap();
        let mut from_art = PrecisionLadder::from_artifact(&a).unwrap();
        let mut from_par = PrecisionLadder::from_params(&p);
        assert_eq!(from_art.top(), from_par.top());
        for rung in Precision::LADDER {
            let va = from_art.view_at(rung).unwrap();
            let vp = from_par.view_at(rung).unwrap();
            for (ta, tp) in va.tensors().iter().zip(vp.tensors()) {
                match (ta, tp) {
                    (LadderTensor::Quant(qa), LadderTensor::Quant(qp)) => assert_eq!(qa, qp),
                    (LadderTensor::Pass(fa), LadderTensor::Pass(fp)) => assert_eq!(fa, fp),
                    other => panic!("slot kind mismatch at {rung}: {other:?}"),
                }
            }
        }
        assert_eq!(from_art.master.names(), from_par.master.names());
        // truncate-at-load: a ladder opened two rungs down equals the
        // full master truncated there
        let low = PrecisionLadder::from_artifact_at(&a, Precision::of(6)).unwrap();
        let direct = SefpTensor::encode(&p.tensors[0], &SefpSpec::new(Precision::of(6)));
        match &low.master.tensors()[0] {
            LadderTensor::Quant(q) => assert_eq!(*q, direct),
            other => panic!("expected quant slot, got {other:?}"),
        }
        assert!(
            PrecisionLadder::from_artifact_at(&a, Precision::of(9)).is_err(),
            "rung above the stored master must be rejected"
        );
    }

    #[test]
    fn master_smaller_than_zoo() {
        let ladder = PrecisionLadder::from_params(&params());
        assert!(ladder.master_bytes() < ladder.zoo_bytes(&Precision::LADDER));
    }

    #[test]
    fn zoo_charges_passthrough_per_width() {
        // quant: 256 elems in 4 groups; pass: 16 f32 = 64 bytes per entry
        let ladder = PrecisionLadder::from_params(&params());
        let widths = [Precision::of(8), Precision::of(4)];
        let quant8 = (256 * 9 + 4 * 5usize).div_ceil(8);
        let quant4 = (256 * 5 + 4 * 5usize).div_ceil(8);
        assert_eq!(ladder.zoo_bytes(&widths), quant8 + quant4 + 2 * 64);
    }

    #[test]
    fn views_feed_quant_linear_without_f32() {
        // SEFP-native consumption: a ladder view slots straight into
        // QuantLinear; the matvec matches the decode-then-dense reference
        let p = params();
        let mut ladder = PrecisionLadder::from_params(&p);
        let v = ladder.view_at(Precision::of(4)).unwrap();
        let LadderTensor::Quant(t) = &v.tensors()[0] else {
            panic!("quant slot expected")
        };
        let q = QuantLinear::from_sefp(t, 64, 4);
        let x: Vec<f32> = (0..64).map(|i| (i as f32) * 0.01).collect();
        let mut y = vec![0.0f32; 4];
        q.matvec(&x, &mut y);
        let dec = t.decode();
        for (n, yv) in y.iter().enumerate() {
            let expect: f32 = x
                .iter()
                .zip(&dec[n * 64..(n + 1) * 64])
                .map(|(a, b)| a * b)
                .sum();
            assert!((yv - expect).abs() < 1e-4, "col {n}: {yv} vs {expect}");
        }
    }
}
