//! `PrecisionStore` — single-master multi-precision weights.
//!
//! The fine-tuned f32 master is encoded ONCE into SEFP E5M8 (the top of
//! the ladder).  Every other precision is derived by `SefpTensor::truncate`
//! — pure integer shifts, no access to the original floats — exactly the
//! on-device switch conventional quantization cannot do (paper fig. 1).
//! Dequantized `ParamStore`s per precision are cached so repeated switches
//! are free; `switch_cost_ms` exposes the cold-switch latency for the
//! serving benchmarks.

use std::collections::HashMap;

use crate::runtime::ParamStore;
use crate::sefp::{Rounding, SefpTensor, GROUP_SIZE};

pub struct PrecisionStore {
    /// E5M8 master, one entry per parameter tensor
    master: Vec<SefpTensor>,
    names: Vec<String>,
    shapes: Vec<Vec<usize>>,
    quantized: Vec<bool>,
    /// non-quantized tensors (1-D norm gains) pass through unchanged
    passthrough: Vec<Option<Vec<f32>>>,
    cache: HashMap<u8, ParamStore>,
    pub switch_log: Vec<(u8, f64)>,
}

impl PrecisionStore {
    /// Encode the fine-tuned master.  The manifest's `quantized` flags say
    /// exactly which tensors the training graph fake-quantized (2-D
    /// weights; pos_embed and norm gains stay f32) — the store mirrors
    /// that, so the serving-side switch reproduces training numerics.
    pub fn from_params(params: &ParamStore) -> Self {
        let mut master = Vec::with_capacity(params.tensors.len());
        let mut passthrough = Vec::with_capacity(params.tensors.len());
        for (i, t) in params.tensors.iter().enumerate() {
            if params.quantized[i] {
                master.push(SefpTensor::encode(t, 8, GROUP_SIZE, Rounding::Trunc));
                passthrough.push(None);
            } else {
                // placeholder tensor keeps indices aligned
                master.push(SefpTensor::encode(&[], 8, GROUP_SIZE, Rounding::Trunc));
                passthrough.push(Some(t.clone()));
            }
        }
        PrecisionStore {
            master,
            names: params.names.clone(),
            shapes: params.shapes.clone(),
            quantized: params.quantized.clone(),
            passthrough,
            cache: HashMap::new(),
            switch_log: Vec::new(),
        }
    }

    /// Storage bytes of the single master copy (ideal packed bits).
    pub fn master_bytes(&self) -> usize {
        let quant: usize = self.master.iter().map(|t| t.ideal_bits()).sum::<usize>() / 8;
        let pass: usize = self
            .passthrough
            .iter()
            .flatten()
            .map(|t| t.len() * 4)
            .sum();
        quant + pass
    }

    /// Bytes a per-precision model zoo would need for the same ladder —
    /// the storage overhead OTARo eliminates.  Each tensor's significand
    /// and exponent bits are summed and rounded up to bytes ONCE,
    /// matching per-tensor `packed_bytes()` accounting — the seed's
    /// separate integer divisions floored away fractional significand
    /// and exponent bytes twice per tensor.
    pub fn zoo_bytes(&self, widths: &[u8]) -> usize {
        widths
            .iter()
            .map(|&m| {
                self.master
                    .iter()
                    .map(|t| (t.len * (1 + m as usize) + t.n_groups() * 5).div_ceil(8))
                    .sum::<usize>()
            })
            .sum()
    }

    /// Get (deriving + caching if needed) the engine-ready params at
    /// mantissa width `m`.
    pub fn params_at(&mut self, m: u8) -> &ParamStore {
        if !self.cache.contains_key(&m) {
            let start = std::time::Instant::now();
            let mut tensors = Vec::with_capacity(self.master.len());
            for (i, t) in self.master.iter().enumerate() {
                if let Some(p) = &self.passthrough[i] {
                    tensors.push(p.clone());
                } else {
                    let tm = if m == t.m { t.clone() } else { t.truncate(m) };
                    tensors.push(tm.decode());
                }
            }
            let ps = ParamStore {
                tensors,
                names: self.names.clone(),
                shapes: self.shapes.clone(),
                quantized: self.quantized.clone(),
            };
            self.switch_log.push((m, start.elapsed().as_secs_f64() * 1e3));
            self.cache.insert(m, ps);
        }
        &self.cache[&m]
    }

    /// Cold-switch cost: derive `m` from scratch (cache bypassed).
    pub fn switch_cost_ms(&self, m: u8) -> f64 {
        let start = std::time::Instant::now();
        let mut total = 0usize;
        for (i, t) in self.master.iter().enumerate() {
            if self.passthrough[i].is_none() {
                let d = t.truncate(m).decode();
                total += d.len();
            }
        }
        let ms = start.elapsed().as_secs_f64() * 1e3;
        assert!(total > 0 || self.master.is_empty());
        ms
    }

    pub fn cached_widths(&self) -> Vec<u8> {
        let mut v: Vec<u8> = self.cache.keys().copied().collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> ParamStore {
        let mut rng = crate::data::Rng::new(1);
        ParamStore {
            tensors: vec![
                (0..256).map(|_| rng.normal() as f32 * 0.1).collect(),
                vec![1.0; 16],
            ],
            names: vec!["w".into(), "ln".into()],
            shapes: vec![vec![16, 16], vec![16]],
            quantized: vec![true, false],
        }
    }

    #[test]
    fn switch_derives_truncated_weights() {
        let p = params();
        let mut store = PrecisionStore::from_params(&p);
        let p4 = store.params_at(4).clone();
        // 2-D tensor quantized at m=4 == direct encode (ladder exactness)
        let direct = SefpTensor::encode(&p.tensors[0], 4, GROUP_SIZE, Rounding::Trunc).decode();
        assert_eq!(p4.tensors[0], direct);
        // 1-D passthrough untouched
        assert_eq!(p4.tensors[1], p.tensors[1]);
    }

    #[test]
    fn cache_hits_after_first_switch() {
        let mut store = PrecisionStore::from_params(&params());
        let _ = store.params_at(5);
        let _ = store.params_at(5);
        assert_eq!(store.switch_log.len(), 1);
        assert_eq!(store.cached_widths(), vec![5]);
    }

    #[test]
    fn master_smaller_than_zoo() {
        let store = PrecisionStore::from_params(&params());
        let widths = [8, 7, 6, 5, 4, 3];
        assert!(store.master_bytes() < store.zoo_bytes(&widths));
    }
}
