//! Dynamic batcher + deadline/age-aware scheduler.
//!
//! Requests queue per precision and are dispatched as full engine
//! batches (the engine's (B, T) shape is fixed at AOT time, so batching
//! = filling rows; underfull batches are padded).
//!
//! Scheduling policy (see [`SchedPolicy`]):
//!
//! * every non-empty queue is scored
//!   `score = fill_ratio + age_weight * oldest_wait_secs`, where
//!   `fill_ratio = min(len, max_batch) / max_batch` — so deep queues win
//!   when everything is fresh (batch-fill efficiency) and waiting
//!   queues win as their head request ages;
//! * **anti-starvation bound**: any queue whose head has waited at
//!   least `max_wait` is scheduled next regardless of score (oldest
//!   head first), so a minority precision cannot be starved by
//!   sustained traffic on another width.  The bound governs
//!   *scheduling*: generations already in flight finish their decode
//!   first, so the worst-case wait is `max_wait` plus the current
//!   run's wind-down (refill stops as soon as the bound trips);
//! * all ties break on the LOWEST width.  Queues live in a `BTreeMap`
//!   and comparisons are strict, so the schedule is bit-for-bit
//!   deterministic — no `HashMap` iteration-order dependence.
//!
//! Backpressure: the queue refuses new work beyond `queue_cap` — callers
//! see `Err` and retry/shed, which keeps worst-case memory bounded.

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

use crate::sefp::Precision;

use super::Request;

pub struct QueuedRequest {
    pub req: Request,
    pub precision: Precision,
    pub enqueued_at: Instant,
}

/// Scheduler knobs; see the module docs for the scoring formula.
#[derive(Debug, Clone, Copy)]
pub struct SchedPolicy {
    /// Score contribution per second of head-of-queue wait.  The fill
    /// ratio is in [0, 1], so at the default 1.0 one second of waiting
    /// outweighs a full batch elsewhere.
    pub age_weight: f64,
    /// Anti-starvation bound: a queue whose head has waited this long
    /// is scheduled next regardless of score.  In-flight decodes are
    /// not preempted, so the worst-case wait adds the current run's
    /// wind-down on top.
    pub max_wait: Duration,
}

impl Default for SchedPolicy {
    fn default() -> Self {
        SchedPolicy { age_weight: 1.0, max_wait: Duration::from_millis(500) }
    }
}

impl SchedPolicy {
    pub fn from_config(cfg: &crate::config::ServeConfig) -> Self {
        SchedPolicy {
            age_weight: cfg.age_weight,
            max_wait: Duration::from_millis(cfg.max_wait_ms),
        }
    }
}

pub struct DynamicBatcher {
    pub max_batch: usize,
    pub queue_cap: usize,
    pub policy: SchedPolicy,
    queues: BTreeMap<Precision, VecDeque<QueuedRequest>>,
    len: usize,
}

impl DynamicBatcher {
    pub fn new(max_batch: usize, queue_cap: usize) -> Self {
        DynamicBatcher {
            max_batch,
            queue_cap,
            policy: SchedPolicy::default(),
            queues: BTreeMap::new(),
            len: 0,
        }
    }

    pub fn with_policy(mut self, policy: SchedPolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Enqueue; `Err` = backpressure (queue full).
    pub fn push(&mut self, req: Request, precision: Precision) -> Result<(), Request> {
        self.push_at(req, precision, Instant::now())
    }

    /// Enqueue with an explicit arrival time.  `push` delegates here;
    /// tests and trace replay use it to construct exact queue states
    /// without sleeping.
    pub fn push_at(
        &mut self,
        req: Request,
        precision: Precision,
        enqueued_at: Instant,
    ) -> Result<(), Request> {
        if self.len >= self.queue_cap {
            return Err(req);
        }
        self.queues
            .entry(precision)
            .or_default()
            .push_back(QueuedRequest { req, precision, enqueued_at });
        self.len += 1;
        Ok(())
    }

    /// Pop the next batch to dispatch under the scheduling policy, up to
    /// `max_batch` rows, FIFO within a precision.
    pub fn pop_batch(&mut self) -> Option<(Precision, Vec<QueuedRequest>)> {
        self.pop_batch_at(Instant::now())
    }

    /// `pop_batch` with an explicit clock — the deterministic core.
    pub fn pop_batch_at(&mut self, now: Instant) -> Option<(Precision, Vec<QueuedRequest>)> {
        let precision = self.schedule(now)?;
        let batch = self.pop_for_width(precision, self.max_batch);
        Some((precision, batch))
    }

    /// Decide which width runs next.  Forced (over-`max_wait`) queues
    /// take absolute priority, oldest head first; otherwise the highest
    /// score wins.  Strict comparisons over the width-ordered map make
    /// every tie resolve to the lowest width.
    fn schedule(&self, now: Instant) -> Option<Precision> {
        if let Some(w) = self.starving_width(now) {
            return Some(w);
        }
        let mut best: Option<(f64, Precision)> = None;
        for (&w, q) in &self.queues {
            let Some(head) = q.front() else { continue };
            let fill = q.len().min(self.max_batch) as f64 / self.max_batch.max(1) as f64;
            let wait = now.saturating_duration_since(head.enqueued_at).as_secs_f64();
            let score = fill + self.policy.age_weight * wait;
            if best.is_none_or(|(s, _)| score > s) {
                best = Some((score, w));
            }
        }
        best.map(|(_, w)| w)
    }

    /// The width whose head request has exceeded the anti-starvation
    /// bound, if any (oldest head first, ties to the lowest width).
    /// The server's continuous-batching refill consults this to stop
    /// extending the current width's run when another width is overdue.
    pub fn starving_width(&self, now: Instant) -> Option<Precision> {
        let mut worst: Option<(Duration, Precision)> = None;
        for (&w, q) in &self.queues {
            let Some(head) = q.front() else { continue };
            let wait = now.saturating_duration_since(head.enqueued_at);
            if wait >= self.policy.max_wait && worst.is_none_or(|(d, _)| wait > d) {
                worst = Some((wait, w));
            }
        }
        worst.map(|(_, w)| w)
    }

    /// Pop up to `k` requests of one width, FIFO — the continuous
    /// batching refill path.
    pub fn pop_for_width(&mut self, precision: Precision, k: usize) -> Vec<QueuedRequest> {
        let Some(q) = self.queues.get_mut(&precision) else { return Vec::new() };
        let take = q.len().min(k);
        let batch: Vec<QueuedRequest> = q.drain(..take).collect();
        self.len -= batch.len();
        batch
    }

    /// Queue depth per precision (metrics).
    pub fn depths(&self) -> Vec<(Precision, usize)> {
        let mut v: Vec<(Precision, usize)> =
            self.queues.iter().map(|(&w, q)| (w, q.len())).collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::TaskClass;

    fn req(id: u64) -> Request {
        Request::new(id, TaskClass::Other, vec![65])
    }

    fn p(raw: u8) -> Precision {
        Precision::of(raw)
    }

    #[test]
    fn batches_same_precision_fifo() {
        let mut b = DynamicBatcher::new(4, 100);
        for i in 0..6 {
            b.push(req(i), p(4)).unwrap();
        }
        let (w, batch) = b.pop_batch().unwrap();
        assert_eq!(w, p(4));
        assert_eq!(batch.len(), 4);
        assert_eq!(batch[0].req.id, 0);
        let (_, rest) = b.pop_batch().unwrap();
        assert_eq!(rest.len(), 2);
        assert!(b.pop_batch().is_none());
    }

    #[test]
    fn longest_queue_first() {
        let mut b = DynamicBatcher::new(8, 100);
        b.push(req(0), p(8)).unwrap();
        for i in 1..4 {
            b.push(req(i), p(4)).unwrap();
        }
        let (w, _) = b.pop_batch().unwrap();
        assert_eq!(w, p(4));
    }

    #[test]
    fn backpressure() {
        let mut b = DynamicBatcher::new(4, 2);
        b.push(req(0), p(4)).unwrap();
        b.push(req(1), p(4)).unwrap();
        assert!(b.push(req(2), p(4)).is_err());
        let _ = b.pop_batch();
        b.push(req(3), p(4)).unwrap();
    }

    #[test]
    fn equal_depth_ties_break_on_lowest_width() {
        // same arrival instant and depth for every queue -> scores are
        // exactly equal -> ascending width order, deterministically.
        let t0 = Instant::now();
        let mut b = DynamicBatcher::new(4, 100);
        for (i, w) in [8u8, 5, 3, 4].into_iter().enumerate() {
            b.push_at(req(i as u64), p(w), t0).unwrap();
        }
        let now = t0 + Duration::from_millis(5);
        let mut order = Vec::new();
        while let Some((w, _)) = b.pop_batch_at(now) {
            order.push(w);
        }
        assert_eq!(order, vec![p(3), p(4), p(5), p(8)]);
    }

    #[test]
    fn schedule_is_deterministic_across_runs() {
        // identical queue states must produce identical schedules,
        // bit for bit — the seed batcher's HashMap broke this.
        let t0 = Instant::now();
        let build = || {
            let mut b = DynamicBatcher::new(2, 100);
            for i in 0..4u64 {
                b.push_at(req(i), p(4), t0 + Duration::from_millis(i)).unwrap();
            }
            for i in 4..6u64 {
                b.push_at(req(i), p(3), t0 + Duration::from_millis(i)).unwrap();
            }
            b.push_at(req(6), p(8), t0).unwrap();
            b
        };
        let drain = |mut b: DynamicBatcher| {
            let now = t0 + Duration::from_millis(50);
            let mut order = Vec::new();
            while let Some((w, batch)) = b.pop_batch_at(now) {
                for q in &batch {
                    order.push((w, q.req.id));
                }
            }
            order
        };
        assert_eq!(drain(build()), drain(build()));
    }

    #[test]
    fn starving_queue_is_forced_to_front() {
        // one lone m=3 request past max_wait beats a full m=4 queue.
        let now = Instant::now();
        let old = now.checked_sub(Duration::from_millis(600)).unwrap();
        let fresh = now.checked_sub(Duration::from_millis(1)).unwrap();
        let mut b = DynamicBatcher::new(8, 100);
        b.push_at(req(0), p(3), old).unwrap();
        for i in 1..9 {
            b.push_at(req(i), p(4), fresh).unwrap();
        }
        assert_eq!(b.starving_width(now), Some(p(3)));
        let (w, batch) = b.pop_batch_at(now).unwrap();
        assert_eq!(w, p(3));
        assert_eq!(batch[0].req.id, 0);
        // once the starving request is out the deep queue runs again
        let (w, _) = b.pop_batch_at(now).unwrap();
        assert_eq!(w, p(4));
    }

    #[test]
    fn pop_for_width_is_fifo_and_bounded() {
        let mut b = DynamicBatcher::new(8, 100);
        for i in 0..5 {
            b.push(req(i), p(6)).unwrap();
        }
        let got = b.pop_for_width(p(6), 3);
        assert_eq!(got.iter().map(|q| q.req.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(b.len(), 2);
        assert!(b.pop_for_width(p(7), 3).is_empty());
    }
}
