//! Dynamic batcher: requests queue per precision and are dispatched as
//! full engine batches (the engine's (B, T) shape is fixed at AOT time,
//! so batching = filling rows; underfull batches are padded).
//!
//! Backpressure: the queue refuses new work beyond `queue_cap` — callers
//! see `Err` and retry/shed, which keeps worst-case memory bounded.

use std::collections::{HashMap, VecDeque};
use std::time::Instant;

use super::Request;

pub struct QueuedRequest {
    pub req: Request,
    pub width_m: u8,
    pub enqueued_at: Instant,
}

pub struct DynamicBatcher {
    pub max_batch: usize,
    pub queue_cap: usize,
    queues: HashMap<u8, VecDeque<QueuedRequest>>,
    len: usize,
}

impl DynamicBatcher {
    pub fn new(max_batch: usize, queue_cap: usize) -> Self {
        DynamicBatcher { max_batch, queue_cap, queues: HashMap::new(), len: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Enqueue; `Err` = backpressure (queue full).
    pub fn push(&mut self, req: Request, width_m: u8) -> Result<(), Request> {
        if self.len >= self.queue_cap {
            return Err(req);
        }
        self.queues
            .entry(width_m)
            .or_default()
            .push_back(QueuedRequest { req, width_m, enqueued_at: Instant::now() });
        self.len += 1;
        Ok(())
    }

    /// Pop the next batch to dispatch: the precision with the LONGEST
    /// queue goes first (maximizes batch fill), up to `max_batch` rows,
    /// FIFO within a precision.
    pub fn pop_batch(&mut self) -> Option<(u8, Vec<QueuedRequest>)> {
        let (&width, _) = self
            .queues
            .iter()
            .filter(|(_, q)| !q.is_empty())
            .max_by_key(|(_, q)| q.len())?;
        let q = self.queues.get_mut(&width).unwrap();
        let take = q.len().min(self.max_batch);
        let batch: Vec<QueuedRequest> = q.drain(..take).collect();
        self.len -= batch.len();
        Some((width, batch))
    }

    /// Queue depth per precision (metrics).
    pub fn depths(&self) -> Vec<(u8, usize)> {
        let mut v: Vec<(u8, usize)> =
            self.queues.iter().map(|(&w, q)| (w, q.len())).collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::TaskClass;

    fn req(id: u64) -> Request {
        Request { id, class: TaskClass::Other, prompt: vec![65], force_m: None }
    }

    #[test]
    fn batches_same_precision_fifo() {
        let mut b = DynamicBatcher::new(4, 100);
        for i in 0..6 {
            b.push(req(i), 4).unwrap();
        }
        let (w, batch) = b.pop_batch().unwrap();
        assert_eq!(w, 4);
        assert_eq!(batch.len(), 4);
        assert_eq!(batch[0].req.id, 0);
        let (_, rest) = b.pop_batch().unwrap();
        assert_eq!(rest.len(), 2);
        assert!(b.pop_batch().is_none());
    }

    #[test]
    fn longest_queue_first() {
        let mut b = DynamicBatcher::new(8, 100);
        b.push(req(0), 8).unwrap();
        for i in 1..4 {
            b.push(req(i), 4).unwrap();
        }
        let (w, _) = b.pop_batch().unwrap();
        assert_eq!(w, 4);
    }

    #[test]
    fn backpressure() {
        let mut b = DynamicBatcher::new(4, 2);
        b.push(req(0), 4).unwrap();
        b.push(req(1), 4).unwrap();
        assert!(b.push(req(2), 4).is_err());
        let _ = b.pop_batch();
        b.push(req(3), 4).unwrap();
    }
}
