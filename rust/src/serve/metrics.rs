//! The serve stack's concrete metric handle set over
//! [`obs::Registry`](crate::obs::Registry).
//!
//! Every counter the server used to keep as an ad-hoc `ServeStats`
//! field is now a pre-registered metric: the server emits through the
//! typed handles here (allocation-free — handle-indexed, no name
//! lookups per event), and [`ServeStats`](super::ServeStats) is
//! *re-derived* from the registry by [`ServeMetrics::stats`].  The
//! registry snapshot ([`ServeMetrics::snapshot`]) is the same data in
//! deterministic JSON, which is what the `workload` replay driver and
//! `otaro loadgen` consume.
//!
//! Per-rung metrics (served / shed / decode step latency) are
//! registered once per configured ladder rung at construction, so the
//! paper's per-precision serving split is visible without any dynamic
//! registration on the request path.

use crate::metrics::Summary;
use crate::obs::profile::Stage;
use crate::obs::{
    Counter, Gauge, Histo, MetricSink, Registry, AGREEMENT_BUCKETS, LATENCY_MS_BUCKETS,
    RATIO_BUCKETS,
};
use crate::sefp::Precision;

use super::server::ServeStats;

/// Handles for one ladder rung's per-precision metrics.
#[derive(Debug, Clone, Copy)]
struct RungMetrics {
    precision: Precision,
    served: Counter,
    shed: Counter,
    /// tokens produced by generation-loop decode steps at this rung
    /// (probe re-scoring steps do NOT count — this counter is the
    /// registry side of the span/counter cross-check: per-rung
    /// `decode_step` trace events must sum to exactly this)
    tokens: Counter,
    step_ms: Histo,
    /// per-stage cost histograms (`profile.rung.<rung>.<stage>_ms`),
    /// indexed by [`Stage::index`] in [`Stage::ALL`] order
    stage_ms: [Histo; 5],
}

/// The serving plane's registered metric handles plus the registry they
/// index into.  Construction registers everything; recording is pure
/// handle arithmetic.
#[derive(Debug)]
pub struct ServeMetrics {
    reg: Registry,
    c_served: Counter,
    c_shed: Counter,
    c_invalid: Counter,
    c_batches: Counter,
    c_decode_steps: Counter,
    c_tokens: Counter,
    c_probes: Counter,
    h_queue_ms: Histo,
    h_compute_ms: Histo,
    h_step_ms: Histo,
    h_batch_fill: Histo,
    h_probe_agreement: Histo,
    g_queue_depth: Gauge,
    g_queue_peak: Gauge,
    g_switch_hits: Gauge,
    g_switch_misses: Gauge,
    g_switch_evictions: Gauge,
    g_ladder_resident: Gauge,
    g_promotions: Gauge,
    g_demotions: Gauge,
    g_forced_clamps: Gauge,
    /// per configured ladder rung, ascending by precision
    rungs: Vec<RungMetrics>,
    /// backend-reported gauges, registered lazily on first sight
    /// (reporting path, not the record path)
    backend_gauges: Vec<(String, Gauge)>,
    /// wall time from first dispatched work to the end of the last
    /// working `process_all` (same semantics as the old `ServeStats`
    /// field — idle time before traffic is not counted)
    pub wall_secs: f64,
    /// high-water mark of the batcher queue depth
    peak_depth: u64,
}

impl ServeMetrics {
    /// Register the full serve metric set, with per-rung metrics for
    /// every rung of the configured router ladder.
    pub fn for_ladder(ladder: &[Precision]) -> Self {
        let mut reg = Registry::new();
        let c_served = reg.counter("serve.served");
        let c_shed = reg.counter("serve.shed");
        let c_invalid = reg.counter("serve.invalid");
        let c_batches = reg.counter("serve.batches");
        let c_decode_steps = reg.counter("serve.decode_steps");
        let c_tokens = reg.counter("serve.tokens");
        let c_probes = reg.counter("policy.probes_run");
        let h_queue_ms = reg.histogram("serve.queue_ms", LATENCY_MS_BUCKETS);
        let h_compute_ms = reg.histogram("serve.compute_ms", LATENCY_MS_BUCKETS);
        let h_step_ms = reg.histogram("serve.step_ms", LATENCY_MS_BUCKETS);
        let h_batch_fill = reg.histogram("serve.batch_fill", RATIO_BUCKETS);
        let h_probe_agreement = reg.histogram("policy.probe_agreement", AGREEMENT_BUCKETS);
        let g_queue_depth = reg.gauge("serve.queue_depth");
        let g_queue_peak = reg.gauge("serve.queue_depth_peak");
        let g_switch_hits = reg.gauge("ladder.switch_hits");
        let g_switch_misses = reg.gauge("ladder.switch_misses");
        let g_switch_evictions = reg.gauge("ladder.switch_evictions");
        let g_ladder_resident = reg.gauge("ladder.resident_bytes");
        let g_promotions = reg.gauge("policy.promotions");
        let g_demotions = reg.gauge("policy.demotions");
        let g_forced_clamps = reg.gauge("policy.forced_clamps");
        let mut rung_ps: Vec<Precision> = ladder.to_vec();
        rung_ps.sort();
        let rungs = rung_ps
            .into_iter()
            .map(|p| RungMetrics {
                precision: p,
                served: reg.counter(&format!("serve.rung.e5m{}.served", p.m())),
                shed: reg.counter(&format!("serve.rung.e5m{}.shed", p.m())),
                tokens: reg.counter(&format!("serve.rung.e5m{}.tokens", p.m())),
                step_ms: reg
                    .histogram(&format!("serve.rung.e5m{}.step_ms", p.m()), LATENCY_MS_BUCKETS),
                stage_ms: Stage::ALL.map(|st| {
                    reg.histogram(
                        &format!("profile.rung.e5m{}.{}", p.m(), st.name()),
                        LATENCY_MS_BUCKETS,
                    )
                }),
            })
            .collect();
        ServeMetrics {
            reg,
            c_served,
            c_shed,
            c_invalid,
            c_batches,
            c_decode_steps,
            c_tokens,
            c_probes,
            h_queue_ms,
            h_compute_ms,
            h_step_ms,
            h_batch_fill,
            h_probe_agreement,
            g_queue_depth,
            g_queue_peak,
            g_switch_hits,
            g_switch_misses,
            g_switch_evictions,
            g_ladder_resident,
            g_promotions,
            g_demotions,
            g_forced_clamps,
            rungs,
            backend_gauges: Vec::new(),
            wall_secs: 0.0,
            peak_depth: 0,
        }
    }

    fn rung(&self, p: Precision) -> Option<RungMetrics> {
        self.rungs.iter().find(|r| r.precision == p).copied()
    }

    // ------------------------------------------------------------------
    // The record path.  Everything below runs per request / per decode
    // step, so it is held to the hot-loop contract: handle-indexed
    // registry writes only, no allocation.
    // lint: region(no_alloc)

    /// A request refused by validation (empty prompt, PAD in prompt,
    /// precision above the ladder master).
    pub fn record_invalid(&mut self) {
        self.reg.inc(self.c_invalid);
    }

    /// A request shed by queue backpressure at precision `p`.
    pub fn record_shed(&mut self, p: Precision) {
        self.reg.inc(self.c_shed);
        if let Some(r) = self.rung(p) {
            self.reg.inc(r.shed);
        }
    }

    /// Queue depth after an admission or dispatch, tracking the peak.
    pub fn record_queue_depth(&mut self, depth: usize) {
        self.reg.set(self.g_queue_depth, depth as f64);
        if depth as u64 > self.peak_depth {
            self.peak_depth = depth as u64;
            self.reg.set(self.g_queue_peak, depth as f64);
        }
    }

    /// A scheduled precision run dispatched with `fill` = admitted rows
    /// over engine rows.
    pub fn record_dispatch(&mut self, fill: f64, depth_after: usize) {
        self.reg.inc(self.c_batches);
        self.reg.observe(self.h_batch_fill, fill);
        self.record_queue_depth(depth_after);
    }

    /// One engine forward call at precision `p` that produced `tokens`
    /// tokens across the active rows.
    pub fn record_step(&mut self, p: Precision, step_ms: f64, tokens: u64) {
        self.reg.inc(self.c_decode_steps);
        self.reg.add(self.c_tokens, tokens);
        self.reg.observe(self.h_step_ms, step_ms);
        if let Some(r) = self.rung(p) {
            self.reg.observe(r.step_ms, step_ms);
            self.reg.add(r.tokens, tokens);
        }
    }

    /// A request served to completion at precision `p`.
    pub fn record_served(&mut self, p: Precision, queue_ms: f64, compute_ms: f64) {
        self.reg.inc(self.c_served);
        self.reg.observe(self.h_queue_ms, queue_ms);
        self.reg.observe(self.h_compute_ms, compute_ms);
        if let Some(r) = self.rung(p) {
            self.reg.inc(r.served);
        }
    }

    /// One shadow probe scored with token-agreement `agreement`.
    pub fn record_probe(&mut self, agreement: f64) {
        self.reg.inc(self.c_probes);
        self.reg.observe(self.h_probe_agreement, agreement);
    }

    /// One drained profiling sample: `stage` cost at rung `p`.  Off-
    /// ladder precisions degrade to a no-op (same contract as the other
    /// per-rung records).
    pub fn record_stage(&mut self, p: Precision, stage: Stage, ms: f64) {
        if let Some(r) = self.rung(p) {
            self.reg.observe(r.stage_ms[stage.index()], ms);
        }
    }

    /// Mirror the ladder's switch statistics into the gauge set.
    pub fn sync_ladder(&mut self, hits: u64, misses: u64, evictions: u64, resident_bytes: usize) {
        self.reg.set(self.g_switch_hits, hits as f64);
        self.reg.set(self.g_switch_misses, misses as f64);
        self.reg.set(self.g_switch_evictions, evictions as f64);
        self.reg.set(self.g_ladder_resident, resident_bytes as f64);
    }

    /// Mirror the policy's decision counters into the gauge set.
    pub fn sync_policy(&mut self, promotions: u64, demotions: u64, forced_clamps: u64) {
        self.reg.set(self.g_promotions, promotions as f64);
        self.reg.set(self.g_demotions, demotions as f64);
        self.reg.set(self.g_forced_clamps, forced_clamps as f64);
    }

    // lint: end_region
    // ------------------------------------------------------------------

    /// Set backend-reported gauges (engine call/load counters), each
    /// surfaced as `backend.<name>`.  Names are registered lazily on
    /// first sight — this is a reporting-cadence path, not the record
    /// path, so the registration allocation is fine.
    pub fn set_backend_gauges(&mut self, pairs: &[(&'static str, f64)]) {
        for &(name, value) in pairs {
            let g = match self.backend_gauges.iter().find(|(n, _)| n == name) {
                Some(&(_, g)) => g,
                None => {
                    let g = self.reg.gauge(&format!("backend.{name}"));
                    self.backend_gauges.push((String::from(name), g));
                    g
                }
            };
            self.reg.set(g, value);
        }
    }

    /// The underlying registry (read access for callers that want raw
    /// metric values).
    pub fn registry(&self) -> &Registry {
        &self.reg
    }

    /// Deterministic JSON snapshot of every registered metric.
    pub fn snapshot(&self) -> crate::json::Value {
        self.reg.snapshot()
    }

    /// Per-rung served counts (ascending precision, zero rungs elided)
    /// — the registry-derived replacement for the old upsert Vec.
    pub fn per_precision(&self) -> Vec<(Precision, u64)> {
        self.rungs
            .iter()
            .map(|r| (r.precision, self.reg.counter_value(r.served)))
            .filter(|&(_, n)| n > 0)
            .collect()
    }

    /// Per-rung generation-loop token counts, same shape — the exact
    /// registry counterpart of per-rung `decode_step` trace events.
    pub fn tokens_per_precision(&self) -> Vec<(Precision, u64)> {
        self.rungs
            .iter()
            .map(|r| (r.precision, self.reg.counter_value(r.tokens)))
            .filter(|&(_, n)| n > 0)
            .collect()
    }

    /// Per-rung shed (backpressure) counts, same shape.
    pub fn shed_per_precision(&self) -> Vec<(Precision, u64)> {
        self.rungs
            .iter()
            .map(|r| (r.precision, self.reg.counter_value(r.shed)))
            .filter(|&(_, n)| n > 0)
            .collect()
    }

    /// Re-derive a [`ServeStats`] from the registry.  The ladder switch
    /// and policy decision fields are left zeroed — the server overlays
    /// those from the live ladder/router (they own that state; the
    /// gauges here are sync-cadence mirrors for the JSON snapshot).
    pub fn stats(&self) -> ServeStats {
        ServeStats {
            served: self.reg.counter_value(self.c_served),
            rejected: self.reg.counter_value(self.c_shed),
            invalid: self.reg.counter_value(self.c_invalid),
            batches: self.reg.counter_value(self.c_batches),
            decode_steps: self.reg.counter_value(self.c_decode_steps),
            tokens_generated: self.reg.counter_value(self.c_tokens),
            queue_ms: self.reg.histo_summary(self.h_queue_ms),
            compute_ms: self.reg.histo_summary(self.h_compute_ms),
            per_precision: self.per_precision(),
            shed_per_precision: self.shed_per_precision(),
            queue_peak_depth: self.peak_depth,
            switch_hits: 0,
            switch_misses: 0,
            switch_evictions: 0,
            switch_ms: Summary::new(),
            ladder_resident_bytes: 0,
            probes_run: self.reg.counter_value(self.c_probes),
            probe_agreement: self.reg.histo_summary(self.h_probe_agreement),
            promotions: 0,
            demotions: 0,
            forced_clamps: 0,
            wall_secs: self.wall_secs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ladder() -> Vec<Precision> {
        vec![Precision::of(8), Precision::of(4), Precision::of(3)]
    }

    #[test]
    fn per_rung_accounting_is_ascending_and_elides_zeros() {
        let mut m = ServeMetrics::for_ladder(&ladder());
        m.record_served(Precision::of(4), 0.1, 1.0);
        m.record_served(Precision::of(4), 0.1, 1.0);
        m.record_served(Precision::of(8), 0.1, 1.0);
        m.record_shed(Precision::of(3));
        assert_eq!(
            m.per_precision(),
            vec![(Precision::of(4), 2), (Precision::of(8), 1)]
        );
        assert_eq!(m.shed_per_precision(), vec![(Precision::of(3), 1)]);
        let st = m.stats();
        assert_eq!(st.served, 3);
        assert_eq!(st.rejected, 1);
        assert_eq!(st.queue_ms.n, 3);
    }

    #[test]
    fn unknown_rung_still_counts_the_totals() {
        // a precision outside the registered ladder can't happen through
        // the router, but the metrics layer must degrade to totals-only
        // rather than panic (request path)
        let mut m = ServeMetrics::for_ladder(&ladder());
        m.record_shed(Precision::of(6));
        m.record_step(Precision::of(6), 0.5, 2);
        assert_eq!(m.stats().rejected, 1);
        assert_eq!(m.stats().decode_steps, 1);
        assert_eq!(m.stats().tokens_generated, 2);
        assert!(m.shed_per_precision().is_empty());
    }

    #[test]
    fn rung_tokens_follow_decode_steps() {
        let mut m = ServeMetrics::for_ladder(&ladder());
        m.record_step(Precision::of(4), 0.5, 3);
        m.record_step(Precision::of(4), 0.5, 2);
        m.record_step(Precision::of(8), 0.5, 1);
        assert_eq!(
            m.tokens_per_precision(),
            vec![(Precision::of(4), 5), (Precision::of(8), 1)]
        );
        let snap = m.snapshot().to_string();
        assert!(snap.contains("\"serve.rung.e5m4.tokens\":5"), "{snap}");
        assert!(snap.contains("\"serve.rung.e5m3.tokens\":0"), "{snap}");
    }

    #[test]
    fn stage_records_land_in_the_right_rung_histogram() {
        let mut m = ServeMetrics::for_ladder(&ladder());
        m.record_stage(Precision::of(4), Stage::Matmul, 1.5);
        m.record_stage(Precision::of(4), Stage::Matmul, 2.5);
        m.record_stage(Precision::of(8), Stage::Probe, 0.5);
        // off-ladder precision degrades to a no-op, not a panic
        m.record_stage(Precision::of(6), Stage::Prefill, 9.0);
        let snap = m.snapshot().to_string();
        assert!(snap.contains("\"profile.rung.e5m4.matmul_ms\":{"), "{snap}");
        let r4 = m.rung(Precision::of(4)).unwrap();
        assert_eq!(m.reg.histo_summary(r4.stage_ms[Stage::Matmul.index()]).n, 2);
        assert_eq!(m.reg.histo_summary(r4.stage_ms[Stage::Prefill.index()]).n, 0);
        let r8 = m.rung(Precision::of(8)).unwrap();
        assert_eq!(m.reg.histo_summary(r8.stage_ms[Stage::Probe.index()]).n, 1);
    }

    #[test]
    fn queue_peak_tracks_the_high_water_mark() {
        let mut m = ServeMetrics::for_ladder(&ladder());
        m.record_queue_depth(3);
        m.record_queue_depth(9);
        m.record_queue_depth(1);
        assert_eq!(m.stats().queue_peak_depth, 9);
        let snap = m.snapshot().to_string();
        assert!(snap.contains("\"serve.queue_depth_peak\":9"), "{snap}");
        assert!(snap.contains("\"serve.queue_depth\":1"), "{snap}");
    }

    #[test]
    fn backend_gauges_register_once_and_update() {
        let mut m = ServeMetrics::for_ladder(&ladder());
        m.set_backend_gauges(&[("calls", 1.0), ("loads", 2.0)]);
        m.set_backend_gauges(&[("calls", 5.0)]);
        let snap = m.snapshot().to_string();
        assert!(snap.contains("\"backend.calls\":5"), "{snap}");
        assert!(snap.contains("\"backend.loads\":2"), "{snap}");
    }

    #[test]
    fn snapshot_is_deterministic_and_parseable() {
        let build = || {
            let mut m = ServeMetrics::for_ladder(&ladder());
            m.record_dispatch(0.75, 4);
            m.record_step(Precision::of(4), 1.25, 4);
            m.record_served(Precision::of(4), 0.5, 1.25);
            m.record_probe(0.95);
            m.sync_ladder(2, 1, 0, 4096);
            m.sync_policy(0, 1, 2);
            m.snapshot().to_string()
        };
        let a = build();
        assert_eq!(a, build());
        assert!(crate::json::parse(&a).is_ok());
    }
}
