//! Minimal JSON substrate (parse + serialize), built in-repo because the
//! offline vendor set has no serde_json.  Covers everything this project
//! needs: the artifact manifest, configs, golden vectors, and JSONL
//! metrics.  Strict enough for round-trips; not a general-purpose
//! validator.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Value> {
        match self {
            Value::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required-field accessors with contextual errors.
    pub fn req(&self, key: &str) -> anyhow::Result<&Value> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing field {key:?}"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<String> {
        Ok(self
            .req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("field {key:?} not a string"))?
            .to_string())
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("field {key:?} not a number"))
    }
}

/// Convenience builder for object literals.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn s(v: impl Into<String>) -> Value {
    Value::Str(v.into())
}

pub fn n(v: impl Into<f64>) -> Value {
    Value::Num(v.into())
}

pub fn arr(v: Vec<Value>) -> Value {
    Value::Arr(v)
}

// ---------------------------------------------------------------------------
// serialize
// ---------------------------------------------------------------------------

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => f.write_str("null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Value::Str(s) => write_escaped(f, s),
            Value::Arr(v) => {
                f.write_str("[")?;
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{e}")?;
                }
                f.write_str("]")
            }
            Value::Obj(m) => {
                f.write_str("{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    f.write_str(":")?;
                    write!(f, "{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

// ---------------------------------------------------------------------------
// parse
// ---------------------------------------------------------------------------

pub fn parse(text: &str) -> anyhow::Result<Value> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    anyhow::ensure!(pos == bytes.len(), "trailing garbage at byte {pos}");
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> anyhow::Result<Value> {
    skip_ws(b, pos);
    anyhow::ensure!(*pos < b.len(), "unexpected end of input");
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Value::Str(parse_string(b, pos)?)),
        b't' => lit(b, pos, "true", Value::Bool(true)),
        b'f' => lit(b, pos, "false", Value::Bool(false)),
        b'n' => lit(b, pos, "null", Value::Null),
        _ => parse_num(b, pos),
    }
}

fn lit(b: &[u8], pos: &mut usize, word: &str, v: Value) -> anyhow::Result<Value> {
    anyhow::ensure!(
        b[*pos..].starts_with(word.as_bytes()),
        "bad literal at byte {pos}",
        pos = *pos
    );
    *pos += word.len();
    Ok(v)
}

fn parse_num(b: &[u8], pos: &mut usize) -> anyhow::Result<Value> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    Ok(Value::Num(s.parse::<f64>().map_err(|e| anyhow::anyhow!("bad number {s:?}: {e}"))?))
}

fn parse_string(b: &[u8], pos: &mut usize) -> anyhow::Result<String> {
    anyhow::ensure!(
        *pos < b.len() && b[*pos] == b'"',
        "expected string at byte {pos}",
        pos = *pos
    );
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                anyhow::ensure!(*pos < b.len(), "bad escape");
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        anyhow::ensure!(*pos + 4 < b.len(), "bad \\u escape");
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let code = u32::from_str_radix(hex, 16)?;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    c => anyhow::bail!("unknown escape \\{}", c as char),
                }
                *pos += 1;
            }
            _ => {
                // consume one UTF-8 scalar
                let rest = std::str::from_utf8(&b[*pos..])
                    .map_err(|_| anyhow::anyhow!("invalid utf8 in string"))?;
                let c = rest
                    .chars()
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("unterminated string"))?;
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
    anyhow::bail!("unterminated string")
}

fn parse_arr(b: &[u8], pos: &mut usize) -> anyhow::Result<Value> {
    *pos += 1; // [
    let mut v = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Value::Arr(v));
    }
    loop {
        v.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        anyhow::ensure!(*pos < b.len(), "unterminated array");
        match b[*pos] {
            b',' => *pos += 1,
            b']' => {
                *pos += 1;
                return Ok(Value::Arr(v));
            }
            c => anyhow::bail!("expected , or ] got {}", c as char),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> anyhow::Result<Value> {
    *pos += 1; // {
    let mut m = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Value::Obj(m));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        anyhow::ensure!(*pos < b.len() && b[*pos] == b':', "expected :");
        *pos += 1;
        let val = parse_value(b, pos)?;
        m.insert(key, val);
        skip_ws(b, pos);
        anyhow::ensure!(*pos < b.len(), "unterminated object");
        match b[*pos] {
            b',' => *pos += 1,
            b'}' => {
                *pos += 1;
                return Ok(Value::Obj(m));
            }
            c => anyhow::bail!("expected , or }} got {}", c as char),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse(r#""hi\n""#).unwrap(), Value::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().req_str("b").unwrap(), "x");
        assert_eq!(v.get("c").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn roundtrip() {
        let v = obj(vec![
            ("name", s("otaro")),
            ("widths", arr(vec![n(8.0), n(3.0)])),
            ("nested", obj(vec![("ok", Value::Bool(true))])),
            ("esc", s("a\"b\\c\nd")),
        ]);
        let text = v.to_string();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse(r#""héllo é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo é");
    }
}
