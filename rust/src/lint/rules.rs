//! The six invariant rules, each encoding a contract a prior PR
//! promised in prose.  Rules are pure functions over a parsed
//! [`SourceFile`]; scoping is by module path relative to the source
//! root, matching is by exact code-channel token so strings, comments,
//! and longer identifiers (`unwrap_or_else`) can never trip a rule.

use super::lexer::{has_ident, has_seq, tokens, Tok};
use super::source::SourceFile;
use super::Violation;

/// One registered rule.
pub struct RuleDef {
    pub name: &'static str,
    /// one-line contract statement (shown by `otaro lint --rules`)
    pub summary: &'static str,
    pub check: fn(&SourceFile, &mut Vec<Violation>),
}

/// The rule registry, in documentation order.
pub const RULES: &[RuleDef] = &[
    RuleDef {
        name: "raw-mantissa",
        summary: "raw `m: u8` bit-widths are confined to sefp/ — everywhere \
                  else precision is the `Precision` type",
        check: raw_mantissa,
    },
    RuleDef {
        name: "unsafe-needs-safety",
        summary: "every `unsafe` block/impl/fn carries a `// SAFETY:` comment \
                  on or contiguously above it",
        check: unsafe_needs_safety,
    },
    RuleDef {
        name: "hot-loop-no-alloc",
        summary: "no allocation inside `// lint: region(no_alloc)` spans \
                  (decode/matmul/attend hot loops)",
        check: hot_loop_no_alloc,
    },
    RuleDef {
        name: "request-path-no-panic",
        summary: "no unwrap()/expect()/panic! in non-test serve/, policy/, \
                  obs/, workload/ and benchutil/diff code — request-path \
                  failures propagate as Results",
        check: request_path_no_panic,
    },
    RuleDef {
        name: "decision-path-determinism",
        summary: "no HashMap/HashSet in serve/, policy/, obs/, workload/ and \
                  benchutil/diff — scheduling, eviction, replay and trend-gate \
                  decisions must not depend on iteration order",
        check: decision_path_determinism,
    },
    RuleDef {
        name: "untrusted-checked-arith",
        summary: "artifact/reader.rs may not do unchecked `+`/`*` on \
                  untrusted length/offset fields",
        check: untrusted_checked_arith,
    },
];

/// Names of all registered rules *and* graph analyses — the combined
/// set `allow` directives and baseline entries validate against.
pub fn rule_names() -> Vec<&'static str> {
    let mut names: Vec<&'static str> = RULES.iter().map(|r| r.name).collect();
    names.extend(super::analyses::analysis_names());
    names
}

fn in_dirs(module: &str, dirs: &[&str]) -> bool {
    dirs.iter().any(|d| module.starts_with(d))
}

fn push(
    out: &mut Vec<Violation>,
    f: &SourceFile,
    rule: &'static str,
    i: usize,
    message: String,
) {
    if !f.allowed(rule, i) {
        out.push(Violation {
            rule,
            module: f.module.clone(),
            line: i + 1,
            message,
            chain: Vec::new(),
        });
    }
}

/// PR 2 contract: `Precision` is the only way a mantissa width moves
/// through the system.  A raw `m: u8` parameter or field outside
/// `sefp/` reintroduces the unvalidated-width bugs the newtype killed.
fn raw_mantissa(f: &SourceFile, out: &mut Vec<Violation>) {
    if f.module == "sefp.rs" || in_dirs(&f.module, &["sefp/"]) {
        return;
    }
    const PAT: [Tok<'_>; 3] = [Tok::Ident("m"), Tok::Punct(':'), Tok::Ident("u8")];
    for (i, line) in f.lines.iter().enumerate() {
        if f.is_code(i) && has_seq(&tokens(&line.code), &PAT) {
            push(
                out,
                f,
                "raw-mantissa",
                i,
                "raw mantissa width `m: u8` outside sefp/ — take a \
                 `Precision` and call `.m()` at the byte boundary"
                    .into(),
            );
        }
    }
}

/// Every `unsafe` site must state its safety argument where the
/// reviewer reads it: a comment containing `SAFETY` on the same line or
/// on the contiguous comment block directly above (attribute lines like
/// `#[inline]` are looked through; a blank line breaks contiguity).
fn unsafe_needs_safety(f: &SourceFile, out: &mut Vec<Violation>) {
    for (i, line) in f.lines.iter().enumerate() {
        if !has_ident(&tokens(&line.code), "unsafe") {
            continue;
        }
        if has_safety_comment(f, i) {
            continue;
        }
        push(
            out,
            f,
            "unsafe-needs-safety",
            i,
            "`unsafe` without a `// SAFETY:` comment on or directly above it".into(),
        );
    }
}

fn has_safety_comment(f: &SourceFile, i: usize) -> bool {
    if f.lines[i].comment.contains("SAFETY") {
        return true;
    }
    let mut j = i;
    while j > 0 {
        j -= 1;
        let l = &f.lines[j];
        let code = l.code.trim();
        if code.is_empty() {
            if l.comment.contains("SAFETY") {
                return true;
            }
            if l.comment.trim().is_empty() {
                return false; // blank line breaks the comment block
            }
            // a comment line without SAFETY: keep walking up the block
        } else if code.starts_with("#[") || code.starts_with("#!") {
            // attributes sit between an item and its docs; look through
            if l.comment.contains("SAFETY") {
                return true;
            }
        } else {
            return false; // a code line ends the search
        }
    }
    false
}

/// PR 5 contract: the decode/matmul/attend hot loops are allocation
/// free — all scratch is persistent.  Inside a `no_alloc` region the
/// allocating idioms are banned outright.
fn hot_loop_no_alloc(f: &SourceFile, out: &mut Vec<Violation>) {
    const BANNED_IDENTS: &[&str] = &["clone", "collect", "to_vec", "to_owned", "to_string"];
    const BANNED_MACROS: &[&str] = &["format", "vec"];
    const BANNED_PATHS: &[&str] = &["Vec", "Box", "String", "BTreeMap", "HashMap", "VecDeque"];
    for region in f.regions.iter().filter(|r| r.kind == "no_alloc") {
        for i in region.start..=region.end.min(f.lines.len().saturating_sub(1)) {
            let toks = tokens(&f.lines[i].code);
            let hit = BANNED_IDENTS
                .iter()
                .find(|&&id| has_ident(&toks, id))
                .copied()
                .or_else(|| {
                    BANNED_MACROS
                        .iter()
                        .find(|&&mc| has_seq(&toks, &[Tok::Ident(mc), Tok::Punct('!')]))
                        .copied()
                })
                .or_else(|| {
                    // `Vec::…` / `Box::…` constructor paths (a bare
                    // `Vec<f32>` type mention does not allocate)
                    BANNED_PATHS
                        .iter()
                        .find(|&&p| {
                            has_seq(&toks, &[Tok::Ident(p), Tok::Punct(':'), Tok::Punct(':')])
                        })
                        .copied()
                });
            if let Some(tok) = hit {
                push(
                    out,
                    f,
                    "hot-loop-no-alloc",
                    i,
                    format!("`{tok}` allocates inside a no_alloc hot-loop region"),
                );
            }
        }
    }
}

/// Request-path modules return `Result`; a panic in serve/ or policy/
/// kills every in-flight generation on the box.  The obs registry and
/// the workload replay driver sit on the same paths (every serving
/// event records; the harness drives real traffic), so they carry the
/// same contract — as does the `bench-diff` trend gate, whose verdict
/// CI acts on.  Test modules are exempt; hard `assert!`s are not
/// banned (they guard memory safety in the kernels and are part of the
/// contract).
fn request_path_no_panic(f: &SourceFile, out: &mut Vec<Violation>) {
    if !in_dirs(&f.module, super::analyses::PATH_DIRS) {
        return;
    }
    const CALLS: &[&str] = &["unwrap", "expect"];
    const MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];
    for (i, line) in f.lines.iter().enumerate() {
        if !f.is_code(i) {
            continue;
        }
        let toks = tokens(&line.code);
        let hit = CALLS
            .iter()
            .find(|&&c| has_seq(&toks, &[Tok::Ident(c), Tok::Punct('(')]))
            .copied()
            .or_else(|| {
                MACROS
                    .iter()
                    .find(|&&m| has_seq(&toks, &[Tok::Ident(m), Tok::Punct('!')]))
                    .copied()
            });
        if let Some(tok) = hit {
            push(
                out,
                f,
                "request-path-no-panic",
                i,
                format!("`{tok}` on the request path — propagate an error instead"),
            );
        }
    }
}

/// The batcher/router/controller determinism contract: identical
/// queue/cache states must produce identical decisions, bit for bit.
/// `HashMap`/`HashSet` iteration order varies per process, so the types
/// are banned from serve/ and policy/ wholesale — `BTreeMap` keyed on
/// `Precision`/`TaskClass` is the house idiom.  obs/ (snapshot key
/// order is the determinism promise of the metric plane), workload/
/// (byte-identical `det` sections run to run) and `benchutil/diff`
/// (the trend gate compares those det sections) inherit the ban.
fn decision_path_determinism(f: &SourceFile, out: &mut Vec<Violation>) {
    if !in_dirs(&f.module, super::analyses::PATH_DIRS) {
        return;
    }
    for (i, line) in f.lines.iter().enumerate() {
        if !f.is_code(i) {
            continue;
        }
        let toks = tokens(&line.code);
        for ty in ["HashMap", "HashSet"] {
            if has_ident(&toks, ty) {
                push(
                    out,
                    f,
                    "decision-path-determinism",
                    i,
                    format!(
                        "`{ty}` in a decision-path module — iteration order is \
                         nondeterministic; use BTreeMap/BTreeSet"
                    ),
                );
            }
        }
    }
}

/// Index/header fields a `.sefp` reader must treat as hostile.
const UNTRUSTED: &[&str] = &[
    "m_off",
    "m_len",
    "m_end",
    "idx_off",
    "idx_end",
    "manifest_off",
    "manifest_len",
    "index_off",
    "data_off",
    "data_len",
    "n_groups",
    "tensor_count",
    "file_len",
];

/// PR 4 hardening, made permanent: in `artifact/reader.rs`, `+`/`*` on
/// an untrusted length/offset field must go through `checked_*` (or a
/// reviewed `allow` stating why overflow is impossible) — a crafted
/// container must produce a validation error, never an arithmetic
/// panic or a wrapped offset.
fn untrusted_checked_arith(f: &SourceFile, out: &mut Vec<Violation>) {
    if f.module != "artifact/reader.rs" {
        return;
    }
    for (i, line) in f.lines.iter().enumerate() {
        if !f.is_code(i) {
            continue;
        }
        let toks = tokens(&line.code);
        let has_op =
            toks.iter().any(|t| matches!(t, Tok::Punct('+') | Tok::Punct('*')));
        if !has_op {
            continue;
        }
        let untrusted = UNTRUSTED.iter().find(|&&u| has_ident(&toks, u));
        let Some(&field) = untrusted else { continue };
        let checked = toks.iter().any(|t| {
            matches!(t, Tok::Ident(s)
                if s.starts_with("checked_") || s.starts_with("saturating_"))
        });
        if checked {
            continue;
        }
        push(
            out,
            f,
            "untrusted-checked-arith",
            i,
            format!(
                "unchecked `+`/`*` on untrusted field `{field}` — use checked \
                 arithmetic (or an allow stating why overflow is impossible)"
            ),
        );
    }
}
