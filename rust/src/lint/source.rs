//! Parsed view of one source file: classified lines, `#[cfg(test)]`
//! spans, `// lint: region(...)` spans, and `// lint: allow(...)`
//! suppressions.
//!
//! Directive grammar (all inside ordinary `//` comments):
//!
//! ```text
//! // lint: allow(<rule>, reason = "<non-empty text>")
//! // lint: region(no_alloc)
//! // lint: end_region
//! ```
//!
//! An `allow` on a code line suppresses that line; on a comment-only
//! line it suppresses the next code line.  The reason is **mandatory**
//! — an allow without one is a hard parse error, as are unknown rule
//! names, unknown directives, nested regions, `end_region` without an
//! open region, and a region left open at end of file.  Malformed
//! suppressions failing loudly is the point: a typo must never silently
//! disable a rule.

use super::lexer::{self, Line};

/// A `// lint: region(<kind>)` … `// lint: end_region` span.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Region {
    pub kind: String,
    /// first line index inside the region (0-based)
    pub start: usize,
    /// last line index inside the region (0-based, inclusive)
    pub end: usize,
}

/// One `allow` suppression, resolved to the line it covers.
#[derive(Debug, Clone)]
pub struct Allow {
    pub rule: String,
    /// 0-based line index this allow suppresses
    pub target: usize,
    /// line the directive itself sits on (0-based), for diagnostics
    pub at: usize,
    /// the mandatory reason text — surfaced in the `--json` allow
    /// inventory so every suppression stays reviewable
    pub reason: String,
}

/// Region kinds the engine understands.
pub const REGION_KINDS: &[&str] = &["no_alloc"];

#[derive(Debug)]
pub struct SourceFile {
    /// path relative to the source root, `/`-separated
    /// (e.g. `serve/store.rs`)
    pub module: String,
    pub lines: Vec<Line>,
    /// per-line: inside a `#[cfg(test)]` module
    pub is_test: Vec<bool>,
    pub regions: Vec<Region>,
    pub allows: Vec<Allow>,
}

impl SourceFile {
    /// Classify and parse `text`.  `rule_names` is the set of known rule
    /// names, used to reject `allow` directives for rules that do not
    /// exist.
    pub fn parse(module: &str, text: &str, rule_names: &[&str]) -> anyhow::Result<SourceFile> {
        let lines = lexer::classify(text);
        let is_test = test_spans(&lines);
        let (regions, allows) = parse_directives(module, &lines, rule_names)?;
        Ok(SourceFile { module: module.to_string(), lines, is_test, regions, allows })
    }

    /// True when line `i` (0-based) is non-test code.
    pub fn is_code(&self, i: usize) -> bool {
        !self.is_test[i]
    }

    /// True when an `allow(rule)` covers line `i`.
    pub fn allowed(&self, rule: &str, i: usize) -> bool {
        self.allows.iter().any(|a| a.rule == rule && a.target == i)
    }
}

/// Mark every line inside a `#[cfg(test)] mod …` span.  Brace counting
/// runs over the code channel, so braces in strings or comments cannot
/// skew the depth.
fn test_spans(lines: &[Line]) -> Vec<bool> {
    let n = lines.len();
    let mut is_test = vec![false; n];
    let mut i = 0;
    while i < n {
        if !lines[i].code.contains("#[cfg(test)]") {
            i += 1;
            continue;
        }
        // find the `mod` item this attribute gates (attributes and
        // blank lines may sit between)
        let mut m = None;
        for (j, l) in lines.iter().enumerate().skip(i).take(8) {
            if lexer::has_ident(&lexer::tokens(&l.code), "mod") {
                m = Some(j);
                break;
            }
        }
        let Some(ms) = m else {
            // `#[cfg(test)]` gating a non-mod item: treat the single
            // following item line as test code and move on
            i += 1;
            continue;
        };
        let mut depth: i64 = 0;
        let mut entered = false;
        let mut k = ms;
        while k < n {
            for c in lines[k].code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        entered = true;
                    }
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            is_test[k] = true;
            if entered && depth <= 0 {
                break;
            }
            k += 1;
        }
        is_test[i..ms].iter_mut().for_each(|t| *t = true);
        i = k + 1;
    }
    is_test
}

fn parse_directives(
    module: &str,
    lines: &[Line],
    rule_names: &[&str],
) -> anyhow::Result<(Vec<Region>, Vec<Allow>)> {
    let mut regions = Vec::new();
    let mut allows = Vec::new();
    let mut open: Option<(String, usize)> = None;
    for (i, line) in lines.iter().enumerate() {
        // a directive is a whole `//` comment of the form `// lint: …` —
        // doc comments (`//! // lint: …`) and prose that merely quote
        // the syntax do not parse as directives
        let body = line.comment.trim_start_matches('/').trim_start();
        let Some(directive) = body.strip_prefix("lint:") else { continue };
        let directive = directive.trim();
        let lineno = i + 1;
        if let Some(rest) = directive.strip_prefix("allow(") {
            let close = rest.rfind(')').ok_or_else(|| {
                anyhow::anyhow!("{module}:{lineno}: malformed lint allow: missing ')'")
            })?;
            let body = &rest[..close];
            let (rule, reason) = body.split_once(',').ok_or_else(|| {
                anyhow::anyhow!(
                    "{module}:{lineno}: lint allow without a reason — write \
                     `lint: allow(<rule>, reason = \"why\")`; the reason is mandatory"
                )
            })?;
            let rule = rule.trim();
            anyhow::ensure!(
                rule_names.contains(&rule),
                "{module}:{lineno}: lint allow names unknown rule {rule:?}"
            );
            let reason = reason.trim();
            let quoted = reason
                .strip_prefix("reason")
                .map(|r| r.trim_start())
                .and_then(|r| r.strip_prefix('='))
                .map(|r| r.trim())
                .and_then(|r| r.strip_prefix('"'))
                .and_then(|r| r.rfind('"').map(|q| &r[..q]));
            let text = quoted.ok_or_else(|| {
                anyhow::anyhow!(
                    "{module}:{lineno}: lint allow reason must be `reason = \"...\"`"
                )
            })?;
            anyhow::ensure!(
                !text.trim().is_empty(),
                "{module}:{lineno}: lint allow reason must not be empty"
            );
            // a trailing allow covers its own line; a comment-only allow
            // covers the next code line
            let target = if !line.code.trim().is_empty() {
                i
            } else {
                let mut t = i + 1;
                while t < lines.len() && lines[t].code.trim().is_empty() {
                    t += 1;
                }
                anyhow::ensure!(
                    t < lines.len(),
                    "{module}:{lineno}: lint allow suppresses nothing (no code follows)"
                );
                t
            };
            allows.push(Allow {
                rule: rule.to_string(),
                target,
                at: i,
                reason: text.trim().to_string(),
            });
        } else if let Some(rest) = directive.strip_prefix("region(") {
            let kind = rest.split(')').next().unwrap_or("").trim();
            anyhow::ensure!(
                REGION_KINDS.contains(&kind),
                "{module}:{lineno}: unknown lint region kind {kind:?} \
                 (known: {REGION_KINDS:?})"
            );
            anyhow::ensure!(
                open.is_none(),
                "{module}:{lineno}: nested lint region (previous region still open)"
            );
            open = Some((kind.to_string(), i + 1));
        } else if directive.starts_with("end_region") {
            let (kind, start) = open.take().ok_or_else(|| {
                anyhow::anyhow!("{module}:{lineno}: lint end_region without an open region")
            })?;
            regions.push(Region { kind, start, end: i.saturating_sub(1) });
        } else {
            anyhow::bail!(
                "{module}:{lineno}: unknown lint directive {directive:?} \
                 (known: allow(rule, reason = \"...\"), region(kind), end_region)"
            );
        }
    }
    if let Some((kind, start)) = open {
        anyhow::bail!(
            "{module}:{start}: lint region({kind}) opened here is never closed — \
             add `// lint: end_region`"
        );
    }
    Ok((regions, allows))
}

#[cfg(test)]
mod tests {
    use super::*;

    const RULES: &[&str] = &["request-path-no-panic", "hot-loop-no-alloc"];

    #[test]
    fn test_mod_span_is_marked() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn after() {}\n";
        let f = SourceFile::parse("x.rs", src, RULES).unwrap();
        assert!(!f.is_test[0]);
        assert!(f.is_test[1] && f.is_test[2] && f.is_test[3] && f.is_test[4]);
        assert!(!f.is_test[5]);
    }

    #[test]
    fn region_and_allow_parse() {
        let src = "\
// lint: region(no_alloc)
fn hot() {}
// lint: end_region
x(); // lint: allow(request-path-no-panic, reason = \"startup only\")
// lint: allow(hot-loop-no-alloc, reason = \"scratch reuse\")
y();
";
        let f = SourceFile::parse("x.rs", src, RULES).unwrap();
        assert_eq!(f.regions, vec![Region { kind: "no_alloc".into(), start: 1, end: 1 }]);
        assert!(f.allowed("request-path-no-panic", 3));
        assert!(f.allowed("hot-loop-no-alloc", 5));
        assert!(!f.allowed("hot-loop-no-alloc", 4));
    }

    #[test]
    fn allow_without_reason_is_rejected() {
        let src = "x(); // lint: allow(request-path-no-panic)\n";
        let err = SourceFile::parse("x.rs", src, RULES).unwrap_err().to_string();
        assert!(err.contains("reason"), "{err}");
        let src = "x(); // lint: allow(request-path-no-panic, reason = \"\")\n";
        assert!(SourceFile::parse("x.rs", src, RULES).is_err());
    }

    #[test]
    fn unknown_rule_and_directive_are_rejected() {
        let src = "x(); // lint: allow(no-such-rule, reason = \"hm\")\n";
        assert!(SourceFile::parse("x.rs", src, RULES).is_err());
        let src = "x(); // lint: frobnicate\n";
        assert!(SourceFile::parse("x.rs", src, RULES).is_err());
    }

    #[test]
    fn unclosed_region_is_a_hard_error() {
        let src = "// lint: region(no_alloc)\nfn hot() {}\n";
        let err = SourceFile::parse("x.rs", src, RULES).unwrap_err().to_string();
        assert!(err.contains("never closed"), "{err}");
        let src = "fn f() {}\n// lint: end_region\n";
        assert!(SourceFile::parse("x.rs", src, RULES).is_err());
    }

    #[test]
    fn directive_in_string_is_ignored() {
        let src = "let s = \"// lint: region(no_alloc)\";\n";
        let f = SourceFile::parse("x.rs", src, RULES).unwrap();
        assert!(f.regions.is_empty());
    }
}
