//! Crate-wide call graph over [`super::parse::FileFacts`].
//!
//! Nodes are the non-test `fn` definitions of every file handed to
//! [`Graph::build`]; edges come from resolving each call site with a
//! deliberately *conservative* scope discipline:
//!
//! * `Type::name(..)` — exact `(owner, name)` match anywhere in the
//!   crate (`Self` resolves to the surrounding impl owner first);
//! * `alias::name(..)` (lowercase qualifier) — free fns in modules
//!   whose file-stem or parent-directory alias matches the qualifier;
//! * `recv.name(..)` — methods with that name, kept only when the
//!   calling file could plausibly see them: same module, or the owner
//!   type / trait name is mentioned somewhere in the calling file; a
//!   globally unique method name resolves unconditionally;
//! * bare `name(..)` — same-file definitions first, then free fns whose
//!   module alias is mentioned in the calling file, then a globally
//!   unique free fn.
//!
//! Anything else — std/external calls, macro-expanded items, truly
//! ambiguous names — produces **no edge**.  The analyses built on top
//! are therefore "what the graph proves reachable" checks: a missing
//! edge can hide a chain (the per-file token rules still guard the
//! direct cases) but a reported chain is real, which keeps violations
//! actionable and the baseline shrink-only.
//!
//! Everything is ordered (`BTreeMap`/`BTreeSet`, index-ordered BFS
//! queues) so reports and the `--json` output are byte-stable.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use super::parse::{Call, FileFacts, FnDef};

/// Aliases under which a module can be referenced from another file:
/// its file stem (except `mod`/`lib`/`main`) and its parent directory
/// name — e.g. `serve/store.rs` → `store`, `serve`; `obs/mod.rs` →
/// `obs`.
pub fn module_aliases(module: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let stem = module.rsplit('/').next().unwrap_or(module);
    let stem = stem.strip_suffix(".rs").unwrap_or(stem);
    if !matches!(stem, "mod" | "lib" | "main") {
        out.push(stem);
    }
    if let Some(pos) = module.rfind('/') {
        let parent = &module[..pos];
        let pname = parent.rsplit('/').next().unwrap_or(parent);
        if !pname.is_empty() && !out.contains(&pname) {
            out.push(pname);
        }
    }
    out
}

/// Multi-source BFS result: hop distance and BFS-tree parent per node.
pub struct Reach {
    pub dist: Vec<Option<u32>>,
    pub parent: Vec<Option<usize>>,
}

/// The resolved call graph.
pub struct Graph<'a> {
    /// all fns of all files, in file order then definition order
    pub fns: Vec<&'a FnDef>,
    /// resolved target node ids per call: `call_targets[k][ci]`
    /// parallels `fns[k].calls[ci]` (empty for test fns)
    pub call_targets: Vec<Vec<Vec<usize>>>,
    /// adjacency: union of a fn's resolved non-test targets
    pub edges: Vec<BTreeSet<usize>>,
}

struct Maps<'a> {
    /// `(owner, name)` → methods
    owner_name: BTreeMap<(&'a str, &'a str), Vec<usize>>,
    /// free fns by name
    free_by_name: BTreeMap<&'a str, Vec<usize>>,
    /// methods by name
    method_by_name: BTreeMap<&'a str, Vec<usize>>,
    /// `(module, name)` → all fns defined in that file
    same_file: BTreeMap<(&'a str, &'a str), Vec<usize>>,
    /// alias → modules it can refer to
    mod_alias: BTreeMap<&'a str, BTreeSet<&'a str>>,
    /// module → identifier mentions in that file
    mentions: BTreeMap<&'a str, &'a BTreeMap<String, usize>>,
}

impl<'a> Maps<'a> {
    fn build(facts: &'a [FileFacts], fns: &[&'a FnDef]) -> Maps<'a> {
        let mut m = Maps {
            owner_name: BTreeMap::new(),
            free_by_name: BTreeMap::new(),
            method_by_name: BTreeMap::new(),
            same_file: BTreeMap::new(),
            mod_alias: BTreeMap::new(),
            mentions: BTreeMap::new(),
        };
        for (k, f) in fns.iter().enumerate() {
            m.same_file.entry((f.module.as_str(), f.name.as_str())).or_default().push(k);
            match &f.owner {
                Some(o) => {
                    m.owner_name.entry((o.as_str(), f.name.as_str())).or_default().push(k);
                    m.method_by_name.entry(f.name.as_str()).or_default().push(k);
                }
                None => m.free_by_name.entry(f.name.as_str()).or_default().push(k),
            }
        }
        for ff in facts {
            for a in module_aliases(&ff.module) {
                m.mod_alias.entry(a).or_default().insert(ff.module.as_str());
            }
            m.mentions.insert(ff.module.as_str(), &ff.mentions);
        }
        m
    }

    fn resolve(&self, fns: &[&FnDef], caller: usize, call: &Call) -> Vec<usize> {
        let f = fns[caller];
        let name = call.name.as_str();
        let mut qual = call.qual.as_deref();
        if qual == Some("Self") {
            qual = f.owner.as_deref();
        }
        if let Some(q) = qual {
            if q.starts_with(|c: char| c.is_uppercase()) {
                return self.owner_name.get(&(q, name)).cloned().unwrap_or_default();
            }
            let Some(mods) = self.mod_alias.get(q) else { return Vec::new() };
            return self
                .free_by_name
                .get(name)
                .map(|c| {
                    c.iter().copied().filter(|&k| mods.contains(fns[k].module.as_str())).collect()
                })
                .unwrap_or_default();
        }
        let ment = self.mentions.get(f.module.as_str());
        let mentioned = |s: &str| ment.is_some_and(|m| m.contains_key(s));
        if call.is_method {
            let Some(cands) = self.method_by_name.get(name) else { return Vec::new() };
            let vis: Vec<usize> = cands
                .iter()
                .copied()
                .filter(|&k| {
                    let c = fns[k];
                    c.module == f.module
                        || c.owner.as_deref().is_some_and(&mentioned)
                        || c.trait_name.as_deref().is_some_and(&mentioned)
                })
                .collect();
            if !vis.is_empty() {
                return vis;
            }
            if cands.len() == 1 {
                return cands.clone();
            }
            return Vec::new();
        }
        if let Some(local) = self.same_file.get(&(f.module.as_str(), name)) {
            return local.clone();
        }
        let Some(cands) = self.free_by_name.get(name) else { return Vec::new() };
        let vis: Vec<usize> = cands
            .iter()
            .copied()
            .filter(|&k| module_aliases(&fns[k].module).iter().any(|a| mentioned(a)))
            .collect();
        if !vis.is_empty() {
            vis
        } else if cands.len() == 1 {
            cands.clone()
        } else {
            Vec::new()
        }
    }
}

impl<'a> Graph<'a> {
    /// Build the graph over every file's facts.  Test fns neither
    /// resolve their calls nor receive edges — the analyses reason
    /// about shipped code only.
    pub fn build(facts: &'a [FileFacts]) -> Graph<'a> {
        let mut fns: Vec<&'a FnDef> = Vec::new();
        for ff in facts {
            fns.extend(ff.fns.iter());
        }
        let maps = Maps::build(facts, &fns);
        let mut call_targets: Vec<Vec<Vec<usize>>> = Vec::with_capacity(fns.len());
        let mut edges: Vec<BTreeSet<usize>> = vec![BTreeSet::new(); fns.len()];
        for (k, f) in fns.iter().enumerate() {
            if f.is_test {
                call_targets.push(vec![Vec::new(); f.calls.len()]);
                continue;
            }
            let mut per_call = Vec::with_capacity(f.calls.len());
            for call in &f.calls {
                let mut targets = maps.resolve(&fns, k, call);
                targets.retain(|&t| !fns[t].is_test);
                for &t in &targets {
                    edges[k].insert(t);
                }
                per_call.push(targets);
            }
            call_targets.push(per_call);
        }
        Graph { fns, call_targets, edges }
    }

    /// Multi-source BFS from `entries` (processed in the given order,
    /// so shortest chains are reported and ties break by entry order).
    pub fn reach(&self, entries: &[usize]) -> Reach {
        let n = self.fns.len();
        let mut dist: Vec<Option<u32>> = vec![None; n];
        let mut parent: Vec<Option<usize>> = vec![None; n];
        let mut q = VecDeque::new();
        for &e in entries {
            if dist[e].is_none() {
                dist[e] = Some(0);
                q.push_back(e);
            }
        }
        while let Some(u) = q.pop_front() {
            let du = dist[u].unwrap_or(0);
            for &v in &self.edges[u] {
                if dist[v].is_none() {
                    dist[v] = Some(du + 1);
                    parent[v] = Some(u);
                    q.push_back(v);
                }
            }
        }
        Reach { dist, parent }
    }

    /// Shortest path from `start` to the first node satisfying `stop`
    /// (including `start` itself), as node ids in call order.
    pub fn find_path<F: Fn(usize) -> bool>(&self, start: usize, stop: F) -> Option<Vec<usize>> {
        if stop(start) {
            return Some(vec![start]);
        }
        let n = self.fns.len();
        let mut parent: Vec<Option<usize>> = vec![None; n];
        let mut seen = vec![false; n];
        seen[start] = true;
        let mut q = VecDeque::from([start]);
        while let Some(u) = q.pop_front() {
            for &v in &self.edges[u] {
                if seen[v] {
                    continue;
                }
                seen[v] = true;
                parent[v] = Some(u);
                if stop(v) {
                    return Some(walk_back(&parent, v));
                }
                q.push_back(v);
            }
        }
        None
    }

    /// Labels of the BFS-tree chain entry → … → `end`.
    pub fn chain_labels(&self, parent: &[Option<usize>], end: usize) -> Vec<String> {
        walk_back(parent, end).into_iter().map(|k| self.fns[k].label()).collect()
    }
}

fn walk_back(parent: &[Option<usize>], end: usize) -> Vec<usize> {
    let mut path = vec![end];
    let mut cur = end;
    while let Some(p) = parent[cur] {
        path.push(p);
        cur = p;
    }
    path.reverse();
    path
}

#[cfg(test)]
mod tests {
    use super::super::parse;
    use super::super::source::SourceFile;
    use super::*;

    fn facts_of(sources: &[(&str, &str)]) -> Vec<FileFacts> {
        let names = super::super::rules::rule_names();
        sources
            .iter()
            .map(|(m, s)| {
                let f = SourceFile::parse(m, s, &names).expect("fixture parses");
                parse::extract(&f)
            })
            .collect()
    }

    fn label_of(g: &Graph<'_>, k: usize) -> String {
        g.fns[k].label()
    }

    #[test]
    fn bare_call_resolves_same_file_first() {
        let facts = facts_of(&[("a/x.rs", "fn f() { g(); }\nfn g() {}\n")]);
        let g = Graph::build(&facts);
        assert_eq!(g.edges[0], BTreeSet::from([1]));
    }

    #[test]
    fn cross_file_call_needs_a_module_mention() {
        let src_caller = "use crate::util;\nfn f() { helper(); }\n";
        let src_blind = "fn f2() { helper(); }\nfn helper_local() {}\nfn helper2() {}\n";
        let facts = facts_of(&[
            ("serve/x.rs", src_caller),
            ("other/y.rs", src_blind),
            ("util/mod.rs", "pub fn helper() {}\npub fn helper_unused() {}\n"),
            ("noise/z.rs", "pub fn helper() {}\n"),
        ]);
        let g = Graph::build(&facts);
        // caller mentions `util` → resolves to util's helper only
        let f_id = g.fns.iter().position(|f| f.label() == "serve/x.rs::f").expect("f");
        let util_helper =
            g.fns.iter().position(|f| f.label() == "util/mod.rs::helper").expect("helper");
        assert_eq!(g.edges[f_id], BTreeSet::from([util_helper]));
        // a file with no mention and two global candidates gets no edge
        let f2 = g.fns.iter().position(|f| f.label() == "other/y.rs::f2").expect("f2");
        assert!(g.edges[f2].is_empty(), "{:?}", g.edges[f2]);
    }

    #[test]
    fn qualified_and_method_calls_resolve() {
        let facts = facts_of(&[
            (
                "serve/x.rs",
                "use crate::store::Store;\nfn f(s: &Store) { s.get(); store::free(); }\n",
            ),
            (
                "store/mod.rs",
                "pub struct Store;\nimpl Store { pub fn get(&self) {} }\npub fn free() {}\n",
            ),
            ("elsewhere/w.rs", "struct Other;\nimpl Other { fn get(&self) {} }\n"),
        ]);
        let g = Graph::build(&facts);
        let f = g.fns.iter().position(|f| f.label() == "serve/x.rs::f").expect("f");
        let labels: Vec<String> = g.edges[f].iter().map(|&k| label_of(&g, k)).collect();
        // `s.get()` sees Store::get (Store is mentioned) but not
        // Other::get; `store::free()` resolves by module alias
        assert_eq!(labels, ["store/mod.rs::Store::get", "store/mod.rs::free"]);
    }

    #[test]
    fn test_fns_are_outside_the_graph() {
        let facts = facts_of(&[(
            "a/x.rs",
            "fn live() { used(); }\nfn used() {}\n#[test]\nfn t() { live(); }\n",
        )]);
        let g = Graph::build(&facts);
        let t = g.fns.iter().position(|f| f.name == "t").expect("t");
        assert!(g.fns[t].is_test);
        assert!(g.edges[t].is_empty());
    }

    #[test]
    fn reach_and_find_path_produce_chains() {
        let facts = facts_of(&[
            ("serve/x.rs", "use crate::mid;\npub fn entry() { mid::step(); }\n"),
            ("mid/mod.rs", "use crate::leaf;\npub fn step() { leaf::boom(); }\n"),
            ("leaf/mod.rs", "pub fn boom(x: Option<u8>) { x.unwrap(); }\n"),
        ]);
        let g = Graph::build(&facts);
        let entry = g.fns.iter().position(|f| f.name == "entry").expect("entry");
        let boom = g.fns.iter().position(|f| f.name == "boom").expect("boom");
        let r = g.reach(&[entry]);
        assert_eq!(r.dist[boom], Some(2));
        let chain = g.chain_labels(&r.parent, boom);
        assert_eq!(chain, ["serve/x.rs::entry", "mid/mod.rs::step", "leaf/mod.rs::boom"]);
        let path = g.find_path(entry, |k| !g.fns[k].panics.is_empty()).expect("path");
        assert_eq!(path.last(), Some(&boom));
    }

    #[test]
    fn module_aliases_cover_stem_and_parent() {
        assert_eq!(module_aliases("serve/store.rs"), ["store", "serve"]);
        assert_eq!(module_aliases("obs/mod.rs"), ["obs"]);
        assert_eq!(module_aliases("main.rs"), Vec::<&str>::new());
    }
}
