//! Comment/string/char-literal-aware line classification — the lexical
//! substrate every rule stands on.
//!
//! [`classify`] splits a Rust source file into per-line (code, comment)
//! channels: string and char literal *contents* are blanked out of the
//! code channel (the delimiting quotes remain as placeholders), and
//! comment text — line, doc, and nested block comments — lands in the
//! comment channel.  Rules that scan for tokens like `unwrap` or
//! `unsafe` therefore can never be fooled by a string literal or a
//! comment that merely *mentions* them, and rules that look for
//! `// SAFETY:` or `// lint:` directives read the comment channel
//! without tripping over `"// not a comment"` inside a string.
//!
//! [`tokens`] then splits a code channel into identifier/punctuation
//! tokens so rules match *exact* identifiers: `unwrap` does not match
//! `unwrap_or_else`, `m` does not match `m_bits`.

/// One source line split into its code, comment, and string channels.
#[derive(Debug, Clone, Default)]
pub struct Line {
    /// Code text with string/char-literal contents blanked (quotes kept).
    pub code: String,
    /// Comment text, including the `//` / `/*` markers.
    pub comment: String,
    /// String-literal contents (the text blanked out of `code`), with a
    /// space between adjacent literals so they can never concatenate
    /// into a false match.  The schema-registry analysis reads this
    /// channel: an `otaro.*.v1` literal in a string is an emission,
    /// while the same text in a comment or doc is prose.
    pub strings: String,
}

/// A code-channel token: an identifier-like word (identifiers, keywords,
/// numeric literals) or a single punctuation character.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tok<'a> {
    Ident(&'a str),
    Punct(char),
}

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Tokenize one line's code channel.
pub fn tokens(code: &str) -> Vec<Tok<'_>> {
    let mut out = Vec::new();
    let mut chars = code.char_indices().peekable();
    while let Some((i, c)) = chars.next() {
        if c.is_whitespace() {
            continue;
        }
        if is_ident_char(c) {
            let mut end = i + c.len_utf8();
            while let Some(&(j, d)) = chars.peek() {
                if is_ident_char(d) {
                    end = j + d.len_utf8();
                    chars.next();
                } else {
                    break;
                }
            }
            out.push(Tok::Ident(&code[i..end]));
        } else {
            out.push(Tok::Punct(c));
        }
    }
    out
}

/// True when `toks` contains `pat` as a consecutive subsequence.
pub fn has_seq(toks: &[Tok<'_>], pat: &[Tok<'_>]) -> bool {
    !pat.is_empty() && toks.windows(pat.len()).any(|w| w == pat)
}

/// True when `toks` contains the exact identifier `name`.
pub fn has_ident(toks: &[Tok<'_>], name: &str) -> bool {
    toks.iter().any(|t| matches!(t, Tok::Ident(s) if *s == name))
}

/// Lexer state across lines.
enum State {
    Code,
    LineComment,
    /// nesting depth (Rust block comments nest)
    BlockComment(u32),
    Str,
    /// number of `#`s delimiting the raw string
    RawStr(usize),
    CharLit,
}

/// Split a whole source file into per-line code/comment channels.
pub fn classify(text: &str) -> Vec<Line> {
    let chars: Vec<char> = text.chars().collect();
    let n = chars.len();
    let mut lines = Vec::new();
    let mut cur = Line::default();
    let mut state = State::Code;
    let mut i = 0;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            if matches!(state, State::LineComment) {
                state = State::Code;
            }
            lines.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    state = State::LineComment;
                    cur.comment.push_str("//");
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    state = State::BlockComment(1);
                    cur.comment.push_str("/*");
                    i += 2;
                } else if c == '"' {
                    state = State::Str;
                    cur.code.push('"');
                    i += 1;
                } else if c == 'r' && !prev_is_ident(&chars, i) {
                    // raw string: r"..." or r#"..."# (any hash count)
                    let mut j = i + 1;
                    while chars.get(j) == Some(&'#') {
                        j += 1;
                    }
                    if chars.get(j) == Some(&'"') {
                        state = State::RawStr(j - (i + 1));
                        cur.code.push('"');
                        i = j + 1;
                    } else {
                        cur.code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // char literal vs lifetime: 'x' / '\n' are literals,
                    // 'a (no closing quote right after) is a lifetime
                    if chars.get(i + 1) == Some(&'\\') {
                        state = State::CharLit;
                        cur.code.push_str("''");
                        // skip quote, backslash AND the escaped char, so
                        // '\'' and '\\' cannot terminate one char early
                        i += 3;
                    } else if chars.get(i + 2) == Some(&'\'') {
                        cur.code.push_str("''");
                        i += 3;
                    } else {
                        cur.code.push(c);
                        i += 1;
                    }
                } else {
                    cur.code.push(c);
                    i += 1;
                }
            }
            State::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            State::BlockComment(d) => {
                if c == '*' && chars.get(i + 1) == Some(&'/') {
                    cur.comment.push_str("*/");
                    state = if d == 1 { State::Code } else { State::BlockComment(d - 1) };
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    cur.comment.push_str("/*");
                    state = State::BlockComment(d + 1);
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    // escaped char, never a terminator — but leave a
                    // line-continuation `\<newline>` for the top of the
                    // loop, so reported line numbers stay exact
                    i += if chars.get(i + 1) == Some(&'\n') { 1 } else { 2 };
                } else if c == '"' {
                    cur.code.push('"');
                    cur.strings.push(' ');
                    state = State::Code;
                    i += 1;
                } else {
                    cur.strings.push(c);
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' && (1..=hashes).all(|k| chars.get(i + k) == Some(&'#')) {
                    cur.code.push('"');
                    cur.strings.push(' ');
                    state = State::Code;
                    i += hashes + 1;
                } else {
                    cur.strings.push(c);
                    i += 1;
                }
            }
            State::CharLit => {
                if c == '\\' {
                    i += 2;
                } else if c == '\'' {
                    state = State::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
        }
    }
    if !cur.code.is_empty() || !cur.comment.is_empty() || !cur.strings.is_empty() {
        lines.push(cur);
    }
    lines
}

fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i > 0 && is_ident_char(chars[i - 1])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(text: &str) -> Vec<String> {
        classify(text).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn strings_are_blanked_from_code() {
        let lines = classify("let x = \"unsafe { unwrap() }\";\n");
        assert_eq!(lines[0].code, "let x = \"\";");
        assert!(lines[0].comment.is_empty());
    }

    #[test]
    fn comments_go_to_the_comment_channel() {
        let lines = classify("foo(); // SAFETY: fine\n");
        assert_eq!(lines[0].code, "foo(); ");
        assert!(lines[0].comment.contains("SAFETY"));
    }

    #[test]
    fn nested_block_comments() {
        let lines = classify("a /* x /* y */ z */ b\n");
        assert_eq!(lines[0].code.replace(' ', ""), "ab");
        assert!(lines[0].comment.contains("y"));
    }

    #[test]
    fn raw_strings_and_escapes() {
        let c = code_of("let s = r#\"no \"comment\" // here\"#; done\n");
        assert_eq!(c[0], "let s = \"\"; done");
        let c = code_of("let q = \"esc \\\" quote\"; after\n");
        assert_eq!(c[0], "let q = \"\"; after");
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let c = code_of("let a: &'a str = x; let c = '\"'; let d = '\\'';\n");
        // the quote char literal must not open a string
        assert!(c[0].contains("&'a str"));
        assert!(c[0].ends_with("let d = '';") || c[0].contains("let d = ''"));
    }

    #[test]
    fn multi_line_strings_stay_blanked() {
        let c = code_of("let s = \"line one\nunwrap() inside\";\nreal();\n");
        assert_eq!(c[1], ";");
        assert_eq!(c[2], "real();");
    }

    #[test]
    fn string_line_continuation_keeps_line_count() {
        // `\<newline>` inside a string must still yield one Line per
        // source line, or every later line number would drift
        let lines = classify("let s = \"one \\\ntwo\";\nafter();\n");
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[2].code, "after();");
    }

    #[test]
    fn string_contents_land_in_the_strings_channel() {
        let lines = classify("let s = \"otaro.metrics.v1\"; // otaro.fake.v9\n");
        assert!(lines[0].strings.contains("otaro.metrics.v1"));
        assert!(!lines[0].strings.contains("otaro.fake.v9"));
        // adjacent literals never concatenate into a false match
        let lines = classify("f(\"otaro.me\", \"trics.v1\");\n");
        assert!(!lines[0].strings.contains("otaro.metrics.v1"));
        // raw strings are captured too
        let lines = classify("let r = r#\"otaro.flight.v1\"#;\n");
        assert!(lines[0].strings.contains("otaro.flight.v1"));
    }

    #[test]
    fn exact_identifier_tokens() {
        let toks = tokens("x.unwrap_or_else(|| y.unwrap())");
        assert!(has_ident(&toks, "unwrap_or_else"));
        assert!(has_ident(&toks, "unwrap"));
        assert!(!has_ident(&toks, "unwrap_or"));
        assert!(has_seq(&toks, &[Tok::Ident("unwrap"), Tok::Punct('(')]));
        assert!(!has_seq(&toks, &[Tok::Ident("unwrap_or_else"), Tok::Punct('.')]));
    }
}
