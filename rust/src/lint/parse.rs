//! Item-level parser: from classified lines to functions and call sites.
//!
//! [`extract`] walks a parsed [`SourceFile`]'s code-channel tokens once
//! and produces [`FileFacts`]: every `fn` definition (module path, impl
//! owner, implemented trait, `#[test]`/`#[cfg(test)]` marking, `pub`
//! visibility, body span) together with the call sites, panic tokens,
//! allocating idioms, hash-collection mentions, and indexing sites
//! inside each body, plus the file's identifier-mention counts and its
//! `otaro.<name>.v<N>` schema literals (read from the string channel,
//! so prose in comments never counts as an emission).
//!
//! This is deliberately *not* a Rust grammar: it is a brace/paren-depth
//! item scanner over the comment/string-aware token stream, precise
//! enough to build a call graph for the reachability analyses in
//! [`super::analyses`] while staying a few hundred lines and well
//! inside the tier-1 2 s lint budget.  Constructs it does not model
//! (macro-generated items, trait default bodies resolved through
//! generics) simply contribute no nodes or edges — the analyses are
//! conservative in what they *prove*, and the per-file token rules
//! still see every line.

use std::collections::BTreeMap;

use super::lexer::{self, Tok};
use super::source::SourceFile;

/// Panic-family calls (`name(`) — the same token set as the direct
/// `request-path-no-panic` rule, shared here so the transitive analysis
/// can never drift from it.
pub const PANIC_CALLS: &[&str] = &["unwrap", "expect"];
/// Panic-family macros (`name!`).
pub const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];
/// Allocating method idents (`name(`) — mirrors `hot-loop-no-alloc`.
pub const ALLOC_IDENTS: &[&str] = &["clone", "collect", "to_vec", "to_owned", "to_string"];
/// Allocating macros (`name!`).
pub const ALLOC_MACROS: &[&str] = &["format", "vec"];
/// Allocating constructor paths (`Name::`).
pub const ALLOC_PATHS: &[&str] = &["Vec", "Box", "String", "BTreeMap", "HashMap", "VecDeque"];

const KEYWORDS: &[&str] = &[
    "if", "while", "for", "match", "return", "fn", "loop", "let", "as", "in", "move", "ref",
    "mut", "else", "unsafe", "impl", "pub", "use", "mod", "struct", "enum", "trait", "type",
    "const", "static", "where", "break", "continue", "crate", "self", "Self", "super", "dyn",
    "box", "true", "false", "async", "await",
];

fn is_keyword(s: &str) -> bool {
    KEYWORDS.contains(&s)
}

/// Identifier-like token that can name an item (not a numeric literal).
fn starts_ident(s: &str) -> bool {
    s.starts_with(|c: char| c.is_alphabetic() || c == '_')
}

/// One call site inside a function body.
#[derive(Debug, Clone)]
pub struct Call {
    /// path qualifier directly before `::name(`, e.g. `Type` in
    /// `Type::name(..)` or `helpers` in `helpers::name(..)`; `Self` is
    /// kept verbatim and resolved against the impl owner later
    pub qual: Option<String>,
    pub name: String,
    /// 1-based line of the call
    pub line: usize,
    /// `.name(..)` receiver-method syntax (only when unqualified)
    pub is_method: bool,
}

/// One `fn` definition with everything the graph analyses need.
#[derive(Debug, Clone)]
pub struct FnDef {
    /// module path relative to the source root (e.g. `serve/server.rs`)
    pub module: String,
    /// impl owner type for methods (`impl Server { fn f }` → `Server`)
    pub owner: Option<String>,
    /// implemented trait for `impl Trait for Type` methods
    pub trait_name: Option<String>,
    pub name: String,
    /// 1-based line of the fn name
    pub line: usize,
    /// 1-based last line of the body (decl line for unfinished spans)
    pub end_line: usize,
    /// inside a `#[cfg(test)]` span or directly under a test attribute
    pub is_test: bool,
    pub is_pub: bool,
    pub calls: Vec<Call>,
    /// panic-family tokens in the body: (line, token)
    pub panics: Vec<(usize, String)>,
    /// allocating idioms in the body: (line, token)
    pub allocs: Vec<(usize, String)>,
    /// lines mentioning `HashMap`/`HashSet` in the body
    pub hash_lines: Vec<usize>,
    /// `expr[idx]`-style indexing sites in the body (assert-class bounds
    /// contract — counted for the report, not flagged as violations)
    pub index_sites: usize,
}

impl FnDef {
    /// Display label: `module::Owner::name` (owner omitted for free fns).
    pub fn label(&self) -> String {
        match &self.owner {
            Some(o) => format!("{}::{}::{}", self.module, o, self.name),
            None => format!("{}::{}", self.module, self.name),
        }
    }
}

/// One `otaro.<name>.v<N>` literal found in the string channel of a
/// non-test line.
#[derive(Debug, Clone)]
pub struct SchemaSite {
    /// 1-based line
    pub line: usize,
    pub name: String,
    pub version: u32,
    /// the full literal text, e.g. `otaro.metrics.v1`
    pub text: String,
}

/// Everything [`extract`] learns about one file.
#[derive(Debug)]
pub struct FileFacts {
    pub module: String,
    pub fns: Vec<FnDef>,
    /// code-channel identifier occurrence counts (all lines, tests
    /// included) — the visibility proxy for call resolution and the
    /// reference count for the dead-item pass
    pub mentions: BTreeMap<String, usize>,
    /// non-test schema literals anywhere in the file (consts included)
    pub schemas: Vec<SchemaSite>,
}

struct ImplCtx {
    owner: Option<String>,
    trait_name: Option<String>,
    open_depth: i64,
}

/// Extract item-level facts from a parsed source file.
pub fn extract(file: &SourceFile) -> FileFacts {
    let mut toks: Vec<(Tok<'_>, usize)> = Vec::new();
    let mut mentions: BTreeMap<String, usize> = BTreeMap::new();
    for (i, line) in file.lines.iter().enumerate() {
        for t in lexer::tokens(&line.code) {
            if let Tok::Ident(s) = t {
                if starts_ident(s) {
                    *mentions.entry(s.to_string()).or_insert(0) += 1;
                }
            }
            toks.push((t, i));
        }
    }

    let mut fns: Vec<FnDef> = Vec::new();
    let mut impl_stack: Vec<ImplCtx> = Vec::new();
    let mut fn_stack: Vec<(usize, i64)> = Vec::new();
    let mut depth: i64 = 0;
    let n = toks.len();
    let mut i = 0usize;
    while i < n {
        let (t, ln) = toks[i];
        match t {
            Tok::Punct('{') => {
                depth += 1;
                i += 1;
                continue;
            }
            Tok::Punct('}') => {
                depth -= 1;
                if impl_stack.last().is_some_and(|c| depth < c.open_depth) {
                    impl_stack.pop();
                }
                if let Some(&(fi, od)) = fn_stack.last() {
                    if depth < od {
                        fns[fi].end_line = ln + 1;
                        fn_stack.pop();
                    }
                }
                i += 1;
                continue;
            }
            Tok::Ident("impl") => {
                let mut seg: Vec<Tok<'_>> = Vec::new();
                let mut j = i + 1;
                while j < n && !matches!(toks[j].0, Tok::Punct('{') | Tok::Punct(';')) {
                    seg.push(toks[j].0);
                    j += 1;
                }
                let (owner, trait_name) = impl_header(&seg);
                if j < n && matches!(toks[j].0, Tok::Punct('{')) {
                    impl_stack.push(ImplCtx { owner, trait_name, open_depth: depth + 1 });
                    depth += 1;
                }
                i = j + 1;
                continue;
            }
            Tok::Ident("fn") => {
                if let Some(&(Tok::Ident(name), name_ln)) = toks.get(i + 1) {
                    if starts_ident(name) {
                        if let Some(rest) = start_fn(file, &toks, i, name, name_ln, &impl_stack) {
                            fns.push(rest);
                            fn_stack.push((fns.len() - 1, depth + 1));
                            depth += 1;
                            // jump past the signature to the body `{`
                            i = body_open(&toks, i + 2).map_or(n, |b| b + 1);
                            continue;
                        }
                        // bodyless signature (trait method): skip it
                        i = sig_end(&toks, i + 2);
                        continue;
                    }
                }
            }
            _ => {}
        }
        if let Some(&(fi, _)) = fn_stack.last() {
            record_body_token(&mut fns[fi], &toks, i);
        }
        i += 1;
    }
    let last_line = file.lines.len();
    for (fi, _) in fn_stack {
        fns[fi].end_line = last_line.max(fns[fi].line);
    }

    let mut schemas = Vec::new();
    for (i, line) in file.lines.iter().enumerate() {
        if file.is_test.get(i).copied().unwrap_or(false) {
            continue;
        }
        for (name, version) in scan_schemas(&line.strings) {
            let text = format!("otaro.{name}.v{version}");
            schemas.push(SchemaSite { line: i + 1, name, version, text });
        }
    }

    FileFacts { module: file.module.clone(), fns, mentions, schemas }
}

/// Owner type and trait name from the tokens between `impl` and `{`.
fn impl_header(seg: &[Tok<'_>]) -> (Option<String>, Option<String>) {
    let name_of = |t: &Tok<'_>| match t {
        Tok::Ident(s) if starts_ident(s) && !is_keyword(s) => Some(s.to_string()),
        _ => None,
    };
    if let Some(fp) = seg.iter().position(|t| matches!(t, Tok::Ident("for"))) {
        // `impl Trait for Type`: the trait path's last segment sits
        // directly before `for`, the owner is the first type ident after
        let trait_name = seg[..fp].iter().rev().find_map(name_of);
        let owner = seg[fp + 1..].iter().find_map(name_of);
        return (owner, trait_name);
    }
    // inherent impl: first type ident after an optional generic group
    let mut start = 0;
    if matches!(seg.first(), Some(Tok::Punct('<'))) {
        let mut gd = 0i64;
        for (k, t) in seg.iter().enumerate() {
            match t {
                Tok::Punct('<') => gd += 1,
                Tok::Punct('>') => gd -= 1,
                _ => {}
            }
            if gd == 0 {
                start = k + 1;
                break;
            }
        }
    }
    (seg[start.min(seg.len())..].iter().find_map(name_of), None)
}

/// Build the [`FnDef`] for a definition that has a body; `None` for
/// bodyless trait-method signatures.
fn start_fn(
    file: &SourceFile,
    toks: &[(Tok<'_>, usize)],
    i: usize,
    name: &str,
    name_ln: usize,
    impl_stack: &[ImplCtx],
) -> Option<FnDef> {
    body_open(toks, i + 2)?;
    let is_pub = toks[i.saturating_sub(6)..i]
        .iter()
        .any(|(t, _)| matches!(t, Tok::Ident("pub")));
    let (owner, trait_name) = match impl_stack.last() {
        Some(c) => (c.owner.clone(), c.trait_name.clone()),
        None => (None, None),
    };
    Some(FnDef {
        module: file.module.clone(),
        owner,
        trait_name,
        name: name.to_string(),
        line: name_ln + 1,
        end_line: name_ln + 1,
        is_test: fn_is_test(file, name_ln),
        is_pub,
        calls: Vec::new(),
        panics: Vec::new(),
        allocs: Vec::new(),
        hash_lines: Vec::new(),
        index_sites: 0,
    })
}

/// Token index of the body `{` of the signature starting at `from`, or
/// `None` when a `;` ends it first (paren depth guards closure params).
fn body_open(toks: &[(Tok<'_>, usize)], from: usize) -> Option<usize> {
    let mut pdepth = 0i64;
    for (j, (t, _)) in toks.iter().enumerate().skip(from) {
        match t {
            Tok::Punct('(') => pdepth += 1,
            Tok::Punct(')') => pdepth -= 1,
            Tok::Punct(';') if pdepth == 0 => return None,
            Tok::Punct('{') if pdepth == 0 => return Some(j),
            _ => {}
        }
    }
    None
}

/// Token index just past a bodyless signature's terminating `;`.
fn sig_end(toks: &[(Tok<'_>, usize)], from: usize) -> usize {
    let mut pdepth = 0i64;
    for (j, (t, _)) in toks.iter().enumerate().skip(from) {
        match t {
            Tok::Punct('(') => pdepth += 1,
            Tok::Punct(')') => pdepth -= 1,
            Tok::Punct(';') | Tok::Punct('{') if pdepth == 0 => return j + 1,
            _ => {}
        }
    }
    toks.len()
}

/// Test marking for the fn named at line `name_ln`: inside a
/// `#[cfg(test)]` mod span, or directly under a `#[test]` /
/// `#[cfg(test)]` attribute (looking up through attributes and comments).
fn fn_is_test(file: &SourceFile, name_ln: usize) -> bool {
    if file.is_test.get(name_ln).copied().unwrap_or(false) {
        return true;
    }
    let mut k = name_ln;
    while k > 0 {
        k -= 1;
        let code = file.lines[k].code.trim();
        if code.is_empty() {
            if file.lines[k].comment.trim().is_empty() {
                return false;
            }
            continue; // comment line: keep walking up
        }
        if code.starts_with("#[") || code.starts_with("#!") {
            if code.contains("#[test]") || code.contains("#[cfg(test)]") {
                return true;
            }
            continue;
        }
        return false;
    }
    false
}

/// Record one body token into the innermost open fn.
fn record_body_token(f: &mut FnDef, toks: &[(Tok<'_>, usize)], i: usize) {
    let (t, ln) = toks[i];
    let prev = i.checked_sub(1).map(|p| toks[p].0);
    let next = toks.get(i + 1).map(|&(t, _)| t);
    match t {
        Tok::Ident(name) if starts_ident(name) && !is_keyword(name) => {
            match next {
                Some(Tok::Punct('(')) => {
                    if PANIC_CALLS.contains(&name) {
                        f.panics.push((ln + 1, name.to_string()));
                    }
                    if ALLOC_IDENTS.contains(&name) {
                        f.allocs.push((ln + 1, name.to_string()));
                    }
                    let qual = match (prev, i.checked_sub(2), i.checked_sub(3)) {
                        (Some(Tok::Punct(':')), Some(p2), Some(p3))
                            if matches!(toks[p2].0, Tok::Punct(':')) =>
                        {
                            match toks[p3].0 {
                                Tok::Ident(q) if starts_ident(q) => Some(q.to_string()),
                                _ => None,
                            }
                        }
                        _ => None,
                    };
                    let is_method = qual.is_none() && matches!(prev, Some(Tok::Punct('.')));
                    f.calls.push(Call { qual, name: name.to_string(), line: ln + 1, is_method });
                }
                Some(Tok::Punct('!')) => {
                    if PANIC_MACROS.contains(&name) {
                        f.panics.push((ln + 1, format!("{name}!")));
                    }
                    if ALLOC_MACROS.contains(&name) {
                        f.allocs.push((ln + 1, format!("{name}!")));
                    }
                }
                Some(Tok::Punct(':')) if ALLOC_PATHS.contains(&name) => {
                    f.allocs.push((ln + 1, format!("{name}::")));
                }
                _ => {}
            }
            if name == "HashMap" || name == "HashSet" {
                f.hash_lines.push(ln + 1);
            }
        }
        Tok::Punct('[') => {
            // `expr[idx]` (an ident, `)`, or `]` directly before `[`);
            // attribute and slice-type brackets don't match this shape
            if matches!(prev, Some(Tok::Ident(_)) | Some(Tok::Punct(')')) | Some(Tok::Punct(']')))
            {
                f.index_sites += 1;
            }
        }
        _ => {}
    }
}

/// All `otaro.<name>.v<N>` literals in one line's string channel.
fn scan_schemas(text: &str) -> Vec<(String, u32)> {
    let b = text.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    while let Some(off) = text[i..].find("otaro.") {
        let start = i + off;
        let name_start = start + 6;
        let mut j = name_start;
        while j < b.len() && (b[j].is_ascii_lowercase() || b[j] == b'_') {
            j += 1;
        }
        if j > name_start && text[j..].starts_with(".v") {
            let vstart = j + 2;
            let mut k = vstart;
            while k < b.len() && b[k].is_ascii_digit() {
                k += 1;
            }
            if k > vstart {
                if let Ok(version) = text[vstart..k].parse::<u32>() {
                    out.push((text[name_start..j].to_string(), version));
                    i = k;
                    continue;
                }
            }
        }
        i = name_start;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn facts(module: &str, src: &str) -> FileFacts {
        let names = super::super::rules::rule_names();
        let file = SourceFile::parse(module, src, &names).expect("fixture parses");
        extract(&file)
    }

    #[test]
    fn free_fns_methods_and_trait_impls() {
        let src = "\
pub fn top() {}
struct S;
impl S {
    fn m(&self) { helper(); }
}
impl std::fmt::Display for S {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result { write!(f, \"s\") }
}
fn helper() {}
";
        let ff = facts("x/y.rs", src);
        let names: Vec<&str> = ff.fns.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, ["top", "m", "fmt", "helper"]);
        assert!(ff.fns[0].is_pub && ff.fns[0].owner.is_none());
        assert_eq!(ff.fns[1].owner.as_deref(), Some("S"));
        assert_eq!(ff.fns[2].trait_name.as_deref(), Some("Display"));
        assert_eq!(ff.fns[2].owner.as_deref(), Some("S"));
        assert_eq!(ff.fns[1].calls.len(), 1);
        assert_eq!(ff.fns[1].calls[0].name, "helper");
        assert!(!ff.fns[1].calls[0].is_method);
        assert_eq!(ff.fns[0].label(), "x/y.rs::top");
        assert_eq!(ff.fns[1].label(), "x/y.rs::S::m");
    }

    #[test]
    fn call_qualifiers_and_method_syntax() {
        let src = "\
fn f(x: Opt, s: &S) {
    x.go();
    S::go(s);
    Self::own();
    util::free();
    plain();
}
";
        let ff = facts("x/y.rs", src);
        let calls = &ff.fns[0].calls;
        assert_eq!(calls.len(), 5);
        assert!(calls[0].is_method && calls[0].qual.is_none());
        assert_eq!(calls[1].qual.as_deref(), Some("S"));
        assert_eq!(calls[2].qual.as_deref(), Some("Self"));
        assert_eq!(calls[3].qual.as_deref(), Some("util"));
        assert!(calls[4].qual.is_none() && !calls[4].is_method);
    }

    #[test]
    fn panic_alloc_hash_and_index_sites() {
        let src = "\
fn f(x: Option<u8>, v: &[u8], m: &Q) -> u8 {
    let a = x.unwrap();
    let b = v.to_vec();
    let c = format!(\"{a}\");
    let d = Vec::with_capacity(4);
    let e: HashMap<u8, u8> = HashMap::new();
    panic!(\"{b:?} {c} {d:?} {e:?}\");
    v[0]
}
";
        let ff = facts("x/y.rs", src);
        let f = &ff.fns[0];
        assert_eq!(f.panics, [(2, "unwrap".to_string()), (7, "panic!".to_string())]);
        assert_eq!(f.allocs.len(), 3, "{:?}", f.allocs);
        assert_eq!(f.hash_lines, [6, 6]);
        assert_eq!(f.index_sites, 1);
        assert!(f.end_line >= 8);
    }

    #[test]
    fn test_markers_are_detected() {
        let src = "\
fn live() {}
#[test]
fn attr_test() {}
#[cfg(test)]
mod tests {
    fn in_mod() {}
}
";
        let ff = facts("x/y.rs", src);
        let by: std::collections::BTreeMap<&str, bool> =
            ff.fns.iter().map(|f| (f.name.as_str(), f.is_test)).collect();
        assert!(!by["live"]);
        assert!(by["attr_test"]);
        assert!(by["in_mod"]);
    }

    #[test]
    fn schema_literals_come_from_strings_not_comments() {
        let src = "\
// otaro.prose.v1 in a comment is not an emission
const HDR: &str = \"otaro.metrics.v1\";
#[cfg(test)]
mod tests {
    fn t() { let s = \"otaro.testonly.v9\"; }
}
";
        let ff = facts("x/y.rs", src);
        let texts: Vec<&str> = ff.schemas.iter().map(|s| s.text.as_str()).collect();
        assert_eq!(texts, ["otaro.metrics.v1"]);
        assert_eq!(ff.schemas[0].name, "metrics");
        assert_eq!(ff.schemas[0].version, 1);
        assert_eq!(ff.schemas[0].line, 2);
    }

    #[test]
    fn bodyless_trait_signatures_define_no_fn() {
        let src = "\
trait T {
    fn sig(&self) -> u8;
    fn with_default(&self) -> u8 { 1 }
}
";
        let ff = facts("x/y.rs", src);
        let names: Vec<&str> = ff.fns.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, ["with_default"]);
    }
}
