//! `otaro-lint` — the in-crate invariant lint engine.
//!
//! PRs 1–5 established contracts that lived only in prose: precision is
//! a type and raw `m: u8` never leaves `sefp/` (PR 2); `.sefp` readers
//! do only checked arithmetic on untrusted fields (PR 4); the decode
//! hot loops are allocation-free, the `ColOut` raw-pointer writes carry
//! a safety argument, and scheduling never depends on hash iteration
//! order (PR 5).  This module enforces all of them mechanically: a
//! comment/string/char-literal-aware lexer ([`lexer`]) feeds a
//! file model with `#[cfg(test)]` spans, hot-loop region markers, and
//! inline suppressions ([`source`]); six rules ([`rules`]) walk the
//! token stream; a checked-in baseline ([`baseline`]) carries
//! documented legacy debt without letting it grow.
//!
//! The pass runs three ways, all through [`run`]:
//!
//! * `otaro lint` — the CLI subcommand ([`run_cli`]);
//! * `rust/tests/lint_source.rs` — a tier-1 test, so `cargo test`
//!   fails on any non-baselined violation;
//! * a CI step, so the gate is machine-enforced on every push.
//!
//! Suppression is inline, per line, and always carries a reason:
//! `# lint: allow(rule, reason = "…")` written with `//` in place of
//! `#` (spelled indirectly here so this very doc comment does not
//! parse as a directive).  Hot-loop spans are bracketed by
//! `region(no_alloc)` / `end_region` directives in the same style.
//! Malformed directives — a missing reason, an unknown rule, an
//! unclosed region — are hard errors, not warnings: a typo must never
//! silently disable a rule.

pub mod baseline;
pub mod lexer;
pub mod rules;
pub mod source;

use std::path::{Path, PathBuf};
use std::time::Instant;

use baseline::Baseline;
use source::SourceFile;

/// One rule violation at one source line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub rule: &'static str,
    /// module path relative to the source root (e.g. `serve/store.rs`)
    pub module: String,
    /// 1-based line number
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.module, self.line, self.rule, self.message)
    }
}

/// Outcome of a full lint pass.
#[derive(Debug, Default)]
pub struct Report {
    /// violations neither suppressed inline nor baselined — these fail
    /// the pass
    pub violations: Vec<Violation>,
    /// baseline entries naming modules that no longer exist — these
    /// fail the pass too (no debt records for deleted files)
    pub stale_baseline: Vec<(String, String)>,
    /// baseline entries that matched no violation (paid-down debt;
    /// informational)
    pub unused_baseline: Vec<(String, String)>,
    /// violations waived by inline `allow` directives
    pub suppressed: usize,
    /// violations waived by the baseline
    pub baselined: usize,
    pub files: usize,
    pub lines: usize,
    pub elapsed_ms: f64,
}

impl Report {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty() && self.stale_baseline.is_empty()
    }

    /// Human-readable summary (multi-line).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for v in &self.violations {
            out.push_str(&format!("{v}\n"));
        }
        for (rule, module) in &self.stale_baseline {
            out.push_str(&format!(
                "baseline: entry `{rule} {module}` names a module that no longer \
                 exists — delete the entry\n"
            ));
        }
        for (rule, module) in &self.unused_baseline {
            out.push_str(&format!(
                "note: baseline entry `{rule} {module}` matched nothing — debt \
                 paid, entry can be deleted\n"
            ));
        }
        out.push_str(&format!(
            "otaro lint: {} file(s), {} lines, {} rule(s) in {:.0} ms — {} \
             violation(s), {} suppressed, {} baselined",
            self.files,
            self.lines,
            rules::RULES.len(),
            self.elapsed_ms,
            self.violations.len(),
            self.suppressed,
            self.baselined,
        ));
        out
    }
}

/// Lint a single in-memory source file.  Returns the violations that
/// survive inline suppression (the fixture-test entry point; [`run`]
/// uses the same path per file).  Errors on malformed directives.
pub fn check_source(module: &str, text: &str) -> anyhow::Result<Vec<Violation>> {
    let (kept, _suppressed) = check_source_counted(module, text)?;
    Ok(kept)
}

fn check_source_counted(
    module: &str,
    text: &str,
) -> anyhow::Result<(Vec<Violation>, usize)> {
    let names = rules::rule_names();
    let file = SourceFile::parse(module, text, &names)?;
    let mut raw = Vec::new();
    for rule in rules::RULES {
        (rule.check)(&file, &mut raw);
    }
    // rules::push already drops allowed lines; count suppressions by
    // re-running the allow filter over what the rules *would* have
    // reported is not observable from here, so count honored allows
    // instead: each allow that points at a line some rule checks is a
    // suppression the reviewer signed off on.
    let suppressed = file.allows.len();
    raw.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    Ok((raw, suppressed))
}

/// Walk `src_root` (every `*.rs`, deterministic order), run all rules,
/// and apply the baseline at `baseline_path` (if any).
pub fn run(src_root: &Path, baseline_path: Option<&Path>) -> anyhow::Result<Report> {
    let start = Instant::now();
    let names = rules::rule_names();
    let base = match baseline_path {
        None => Baseline::default(),
        Some(p) => {
            let text = std::fs::read_to_string(p)
                .map_err(|e| anyhow::anyhow!("cannot read baseline {}: {e}", p.display()))?;
            Baseline::parse(&text, &names)?
        }
    };

    let mut files = Vec::new();
    collect_rs(src_root, src_root, &mut files)?;
    files.sort();

    let mut report = Report { files: files.len(), ..Report::default() };
    let mut matched = std::collections::BTreeSet::new();
    let mut modules = std::collections::BTreeSet::new();
    for (module, path) in &files {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read {}: {e}", path.display()))?;
        report.lines += text.lines().count();
        modules.insert(module.clone());
        let (violations, suppressed) = check_source_counted(module, &text)?;
        report.suppressed += suppressed;
        for v in violations {
            if base.covers(v.rule, &v.module) {
                matched.insert((v.rule.to_string(), v.module.clone()));
                report.baselined += 1;
            } else {
                report.violations.push(v);
            }
        }
    }
    for (rule, module) in &base.entries {
        if !modules.contains(module) {
            report.stale_baseline.push((rule.clone(), module.clone()));
        } else if !matched.contains(&(rule.clone(), module.clone())) {
            report.unused_baseline.push((rule.clone(), module.clone()));
        }
    }
    report.elapsed_ms = start.elapsed().as_secs_f64() * 1e3;
    Ok(report)
}

fn collect_rs(
    root: &Path,
    dir: &Path,
    out: &mut Vec<(String, PathBuf)>,
) -> anyhow::Result<()> {
    for entry in std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("cannot read source dir {}: {e}", dir.display()))?
    {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(root, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            out.push((rel, path));
        }
    }
    Ok(())
}

/// `otaro lint`: run the pass over the crate sources and print the
/// report; non-clean exits with an error.  Defaults match the repo
/// layout (`rust/src`, baseline at `rust/lint.baseline`); `--src` /
/// `--baseline` override for out-of-tree runs.
pub fn run_cli(src: Option<PathBuf>, baseline: Option<PathBuf>) -> anyhow::Result<()> {
    let src = match src {
        Some(s) => s,
        None => {
            let default = PathBuf::from("rust/src");
            anyhow::ensure!(
                default.is_dir(),
                "no --src given and {} does not exist — run from the repo root \
                 or pass --src DIR",
                default.display()
            );
            default
        }
    };
    let baseline = baseline.or_else(|| {
        let p = PathBuf::from("rust/lint.baseline");
        p.is_file().then_some(p)
    });
    let report = run(&src, baseline.as_deref())?;
    println!("{}", report.render());
    anyhow::ensure!(
        report.is_clean(),
        "lint failed: {} violation(s), {} stale baseline entr(ies)",
        report.violations.len(),
        report.stale_baseline.len()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_source_reports_nothing() {
        let v = check_source("serve/x.rs", "fn f() -> i32 { 1 }\n").unwrap();
        assert!(v.is_empty());
    }

    #[test]
    fn violations_sort_by_line() {
        let src = "use std::collections::HashMap;\nfn f(x: Option<u8>) { x.unwrap(); }\n";
        let v = check_source("serve/x.rs", src).unwrap();
        assert_eq!(v.len(), 2);
        assert!(v[0].line <= v[1].line);
    }

    #[test]
    fn display_is_clickable() {
        let v = Violation {
            rule: "raw-mantissa",
            module: "infer/mod.rs".into(),
            line: 7,
            message: "msg".into(),
        };
        assert_eq!(v.to_string(), "infer/mod.rs:7: [raw-mantissa] msg");
    }
}
