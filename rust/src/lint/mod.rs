//! `otaro-lint` — the in-crate invariant lint engine.
//!
//! PRs 1–5 established contracts that lived only in prose: precision is
//! a type and raw `m: u8` never leaves `sefp/` (PR 2); `.sefp` readers
//! do only checked arithmetic on untrusted fields (PR 4); the decode
//! hot loops are allocation-free, the `ColOut` raw-pointer writes carry
//! a safety argument, and scheduling never depends on hash iteration
//! order (PR 5).  v1 of this module enforced those contracts
//! *textually*, per file; v2 makes the request-path contracts
//! **reachability-based**: a comment/string/char-literal-aware lexer
//! ([`lexer`]) feeds a file model with `#[cfg(test)]` spans, hot-loop
//! region markers, and inline suppressions ([`source`]); six token
//! rules ([`rules`]) walk each file; an item-level parser ([`parse`])
//! extracts every fn definition and call site; a conservatively
//! resolved call graph ([`graph`]) connects them crate-wide; and four
//! graph analyses ([`analyses`]) chase panics, allocations, and
//! hash-iteration taint across module boundaries and resolve every
//! frozen `otaro.<name>.v<N>` schema literal against
//! [`obs::SCHEMAS`](crate::obs::SCHEMAS).  A checked-in baseline
//! ([`baseline`]) carries documented legacy debt without letting it
//! grow.
//!
//! The pass runs three ways, all through [`run`]:
//!
//! * `otaro lint` — the CLI subcommand ([`run_cli`]), with `--json`
//!   emitting a deterministic `otaro.lint.v1` report (wrapped in the
//!   shared bench envelope so `bench-diff` can compare runs) and
//!   `--dead` listing report-only unreferenced pub fns;
//! * `rust/tests/lint_source.rs` — a tier-1 test, so `cargo test`
//!   fails on any non-baselined violation;
//! * a CI step, so the gate is machine-enforced on every push.
//!
//! Suppression is inline, per line, and always carries a reason:
//! `# lint: allow(rule, reason = "…")` written with `//` in place of
//! `#` (spelled indirectly here so this very doc comment does not
//! parse as a directive).  Hot-loop spans are bracketed by
//! `region(no_alloc)` / `end_region` directives in the same style.
//! Malformed directives — a missing reason, an unknown rule, an
//! unclosed region — are hard errors, not warnings: a typo must never
//! silently disable a rule.  Graph-analysis violations carry the full
//! call chain (entry → … → offending fn) in the message, so a report
//! is actionable without re-deriving the reachability by hand.

pub mod analyses;
pub mod baseline;
pub mod graph;
pub mod lexer;
pub mod parse;
pub mod rules;
pub mod source;

use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::{benchutil, json, obs};

use baseline::Baseline;
use source::SourceFile;

/// One rule violation at one source line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub rule: &'static str,
    /// module path relative to the source root (e.g. `serve/store.rs`)
    pub module: String,
    /// 1-based line number
    pub line: usize,
    pub message: String,
    /// for graph analyses: fn labels entry → … → offending fn (also
    /// embedded in `message`); empty for per-file token rules
    pub chain: Vec<String>,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.module, self.line, self.rule, self.message)
    }
}

/// Outcome of a full lint pass.
#[derive(Debug, Default)]
pub struct Report {
    /// violations neither suppressed inline nor baselined — these fail
    /// the pass
    pub violations: Vec<Violation>,
    /// baseline entries naming modules that no longer exist — these
    /// fail the pass too (no debt records for deleted files)
    pub stale_baseline: Vec<(String, String)>,
    /// baseline entries that matched no violation (paid-down debt;
    /// informational)
    pub unused_baseline: Vec<(String, String)>,
    /// violations waived by inline `allow` directives
    pub suppressed: usize,
    /// violations waived by the baseline
    pub baselined: usize,
    pub files: usize,
    pub lines: usize,
    /// fn definitions the item parser extracted
    pub fns: usize,
    /// non-test fns reachable from request-path entry points
    pub reachable_fns: usize,
    /// `expr[idx]` sites inside those reachable fns (informational)
    pub reachable_index_sites: usize,
    /// non-test `otaro.*.vN` literal sites resolved against the registry
    pub schema_sites: usize,
    /// report-only dead-item candidates (`--dead`)
    pub dead: Vec<String>,
    /// inline allow inventory, sorted `(module, rule, reason)` — every
    /// suppression in the crate, reviewable from the `--json` report
    pub allows: Vec<(String, String, String)>,
    pub elapsed_ms: f64,
}

impl Report {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty() && self.stale_baseline.is_empty()
    }

    /// Human-readable summary (multi-line).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for v in &self.violations {
            out.push_str(&format!("{v}\n"));
        }
        for (rule, module) in &self.stale_baseline {
            out.push_str(&format!(
                "baseline: entry `{rule} {module}` names a module that no longer \
                 exists — delete the entry\n"
            ));
        }
        for (rule, module) in &self.unused_baseline {
            out.push_str(&format!(
                "note: baseline entry `{rule} {module}` matched nothing — debt \
                 paid, entry can be deleted\n"
            ));
        }
        out.push_str(&format!(
            "otaro lint: {} file(s), {} lines, {} rule(s) + {} analyses in \
             {:.0} ms — {} violation(s), {} suppressed, {} baselined\n",
            self.files,
            self.lines,
            rules::RULES.len(),
            analyses::ANALYSES.len(),
            self.elapsed_ms,
            self.violations.len(),
            self.suppressed,
            self.baselined,
        ));
        out.push_str(&format!(
            "graph: {} fn(s), {} reachable from the request path, {} reachable \
             index site(s), {} schema literal site(s)",
            self.fns, self.reachable_fns, self.reachable_index_sites, self.schema_sites,
        ));
        out
    }

    /// Human-readable dead-item listing (`--dead`; report-only).
    pub fn render_dead(&self) -> String {
        if self.dead.is_empty() {
            return "dead: no unreferenced pub fns".to_string();
        }
        let mut out = format!(
            "dead: {} pub fn(s) never referenced outside their definitions \
             (report-only):\n",
            self.dead.len()
        );
        for d in &self.dead {
            out.push_str(&format!("  {d}\n"));
        }
        out.pop();
        out
    }

    /// The deterministic `otaro.lint.v1` report object.  Contains no
    /// timing — byte-identical across runs on identical sources, so
    /// `bench-diff` flags any drift in violations, allows, schemas, or
    /// dead items between CI runs.
    pub fn to_json(&self) -> json::Value {
        let violations = self
            .violations
            .iter()
            .map(|v| {
                json::obj(vec![
                    ("rule", json::s(v.rule)),
                    ("module", json::s(v.module.as_str())),
                    ("line", json::n(v.line as f64)),
                    ("message", json::s(v.message.as_str())),
                    (
                        "chain",
                        json::Value::Arr(v.chain.iter().map(|c| json::s(c.as_str())).collect()),
                    ),
                ])
            })
            .collect();
        let pairs = |entries: &[(String, String)]| {
            json::Value::Arr(
                entries.iter().map(|(rule, module)| json::s(format!("{rule} {module}"))).collect(),
            )
        };
        let allows = self
            .allows
            .iter()
            .map(|(module, rule, reason)| {
                json::obj(vec![
                    ("module", json::s(module.as_str())),
                    ("rule", json::s(rule.as_str())),
                    ("reason", json::s(reason.as_str())),
                ])
            })
            .collect();
        let schemas = obs::SCHEMAS
            .iter()
            .map(|d| {
                json::obj(vec![
                    ("name", json::s(d.name)),
                    ("version", json::n(d.version as f64)),
                    ("module", json::s(d.module)),
                ])
            })
            .collect();
        json::obj(vec![
            ("schema", json::s("otaro.lint.v1")),
            ("files", json::n(self.files as f64)),
            ("lines", json::n(self.lines as f64)),
            ("rules", json::n(rules::RULES.len() as f64)),
            ("analyses", json::n(analyses::ANALYSES.len() as f64)),
            ("fns", json::n(self.fns as f64)),
            ("reachable_fns", json::n(self.reachable_fns as f64)),
            ("reachable_index_sites", json::n(self.reachable_index_sites as f64)),
            ("schema_sites", json::n(self.schema_sites as f64)),
            ("violations", json::Value::Arr(violations)),
            ("stale_baseline", pairs(&self.stale_baseline)),
            ("unused_baseline", pairs(&self.unused_baseline)),
            ("suppressed", json::n(self.suppressed as f64)),
            ("baselined", json::n(self.baselined as f64)),
            ("allows", json::Value::Arr(allows)),
            ("schemas", json::Value::Arr(schemas)),
            ("dead", json::Value::Arr(self.dead.iter().map(|d| json::s(d.as_str())).collect())),
        ])
    }

    /// Write the report as a `BENCH_*.json`-style artifact: one record
    /// named `lint` whose `det` section is [`Report::to_json`] and whose
    /// `wall` section carries the elapsed seconds, wrapped in the shared
    /// `otaro.bench.v1` envelope so `bench-diff` compares lint reports
    /// exactly like bench results.
    pub fn write_json(&self, path: &Path) -> anyhow::Result<()> {
        let record = json::obj(vec![
            ("name", json::s("lint")),
            ("det", self.to_json()),
            ("wall", json::obj(vec![("wall_secs", json::n(self.elapsed_ms / 1e3))])),
        ]);
        benchutil::write_bench_file(path, "lint", json::Value::Arr(vec![record]))
    }
}

/// Lint a single in-memory source file: token rules plus the graph
/// analyses over the one-file "crate" (the fixture-test entry point;
/// [`run`] uses the same machinery over all files at once).  Errors on
/// malformed directives.
pub fn check_source(module: &str, text: &str) -> anyhow::Result<Vec<Violation>> {
    check_crate(&[(module, text)])
}

/// Lint a set of in-memory source files as one crate: per-file token
/// rules plus the cross-module graph analyses, resolving schema
/// literals against the real [`obs::SCHEMAS`].  Schema-table staleness
/// is not checked here (the file set need not span the whole crate).
pub fn check_crate(sources: &[(&str, &str)]) -> anyhow::Result<Vec<Violation>> {
    check_crate_with_schemas(sources, obs::SCHEMAS, false)
}

/// [`check_crate`] with an explicit schema table; `coverage` also
/// verifies each declared emitting module still emits its literal
/// (only meaningful when `sources` spans every module the table names).
pub fn check_crate_with_schemas(
    sources: &[(&str, &str)],
    schemas: &[obs::SchemaDef],
    coverage: bool,
) -> anyhow::Result<Vec<Violation>> {
    let names = rules::rule_names();
    let files = sources
        .iter()
        .map(|(m, t)| SourceFile::parse(m, t, &names))
        .collect::<anyhow::Result<Vec<_>>>()?;
    let (violations, _) = run_parsed(&files, schemas, coverage);
    Ok(violations)
}

/// Per-pass statistics beyond the violation list.
struct PassStats {
    suppressed: usize,
    fns: usize,
    reachable_fns: usize,
    reachable_index_sites: usize,
    schema_sites: usize,
    dead: Vec<String>,
}

/// The single lint pipeline every entry point funnels through: token
/// rules per file, then the graph analyses over all files together.
fn run_parsed(
    files: &[SourceFile],
    schemas: &[obs::SchemaDef],
    coverage: bool,
) -> (Vec<Violation>, PassStats) {
    let facts: Vec<parse::FileFacts> = files.iter().map(parse::extract).collect();
    let mut raw = Vec::new();
    for f in files {
        for rule in rules::RULES {
            (rule.check)(f, &mut raw);
        }
    }
    let outcome = analyses::run(files, &facts, schemas, coverage);
    let stats = PassStats {
        // rules::push and the analyses drop allowed lines before they
        // are observable here; count honored allows instead — each one
        // is a suppression a reviewer signed off on
        suppressed: files.iter().map(|f| f.allows.len()).sum(),
        fns: facts.iter().map(|ff| ff.fns.len()).sum(),
        reachable_fns: outcome.reachable_fns,
        reachable_index_sites: outcome.reachable_index_sites,
        schema_sites: outcome.schema_sites,
        dead: outcome.dead,
    };
    raw.extend(outcome.violations);
    raw.sort_by(|a, b| {
        (a.module.as_str(), a.line, a.rule).cmp(&(b.module.as_str(), b.line, b.rule))
    });
    (raw, stats)
}

/// Walk `src_root` (every `*.rs`, deterministic order), run all rules
/// and analyses, and apply the baseline at `baseline_path` (if any).
pub fn run(src_root: &Path, baseline_path: Option<&Path>) -> anyhow::Result<Report> {
    let start = Instant::now();
    let names = rules::rule_names();
    let base = match baseline_path {
        None => Baseline::default(),
        Some(p) => {
            let text = std::fs::read_to_string(p)
                .map_err(|e| anyhow::anyhow!("cannot read baseline {}: {e}", p.display()))?;
            Baseline::parse(&text, &names)?
        }
    };

    let mut files = Vec::new();
    collect_rs(src_root, src_root, &mut files)?;
    files.sort();

    let mut report = Report { files: files.len(), ..Report::default() };
    let mut sources = Vec::new();
    for (module, path) in &files {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read {}: {e}", path.display()))?;
        report.lines += text.lines().count();
        sources.push((module.clone(), text));
    }
    let parsed = sources
        .iter()
        .map(|(m, t)| SourceFile::parse(m, t, &names))
        .collect::<anyhow::Result<Vec<_>>>()?;
    let (violations, stats) = run_parsed(&parsed, obs::SCHEMAS, true);
    report.suppressed = stats.suppressed;
    report.fns = stats.fns;
    report.reachable_fns = stats.reachable_fns;
    report.reachable_index_sites = stats.reachable_index_sites;
    report.schema_sites = stats.schema_sites;
    report.dead = stats.dead;
    for f in &parsed {
        for a in &f.allows {
            report.allows.push((f.module.clone(), a.rule.clone(), a.reason.clone()));
        }
    }
    report.allows.sort();
    report.allows.dedup();

    let mut matched = std::collections::BTreeSet::new();
    let modules: std::collections::BTreeSet<String> =
        parsed.iter().map(|f| f.module.clone()).collect();
    for v in violations {
        if base.covers(v.rule, &v.module) {
            matched.insert((v.rule.to_string(), v.module.clone()));
            report.baselined += 1;
        } else {
            report.violations.push(v);
        }
    }
    for (rule, module) in &base.entries {
        if !modules.contains(module) {
            report.stale_baseline.push((rule.clone(), module.clone()));
        } else if !matched.contains(&(rule.clone(), module.clone())) {
            report.unused_baseline.push((rule.clone(), module.clone()));
        }
    }
    report.elapsed_ms = start.elapsed().as_secs_f64() * 1e3;
    Ok(report)
}

fn collect_rs(
    root: &Path,
    dir: &Path,
    out: &mut Vec<(String, PathBuf)>,
) -> anyhow::Result<()> {
    for entry in std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("cannot read source dir {}: {e}", dir.display()))?
    {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(root, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            out.push((rel, path));
        }
    }
    Ok(())
}

/// `otaro lint`: run the pass over the crate sources and print the
/// report; non-clean exits with an error.  Defaults match the repo
/// layout (`rust/src`, baseline at `rust/lint.baseline`); `--src` /
/// `--baseline` override for out-of-tree runs.  `--json FILE` writes
/// the `otaro.lint.v1` report (written even when the pass fails, so CI
/// can diff a failing run); `--dead` prints the report-only
/// unreferenced-pub-fn listing.
pub fn run_cli(
    src: Option<PathBuf>,
    baseline: Option<PathBuf>,
    json_out: Option<PathBuf>,
    dead: bool,
) -> anyhow::Result<()> {
    let src = match src {
        Some(s) => s,
        None => {
            let default = PathBuf::from("rust/src");
            anyhow::ensure!(
                default.is_dir(),
                "no --src given and {} does not exist — run from the repo root \
                 or pass --src DIR",
                default.display()
            );
            default
        }
    };
    let baseline = baseline.or_else(|| {
        let p = PathBuf::from("rust/lint.baseline");
        p.is_file().then_some(p)
    });
    let report = run(&src, baseline.as_deref())?;
    println!("{}", report.render());
    if dead {
        println!("{}", report.render_dead());
    }
    if let Some(path) = &json_out {
        report.write_json(path)?;
        println!("lint json: wrote {}", path.display());
    }
    anyhow::ensure!(
        report.is_clean(),
        "lint failed: {} violation(s), {} stale baseline entr(ies)",
        report.violations.len(),
        report.stale_baseline.len()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_source_reports_nothing() {
        let v = check_source("serve/x.rs", "fn f() -> i32 { 1 }\n").unwrap();
        assert!(v.is_empty());
    }

    #[test]
    fn violations_sort_by_line() {
        let src = "use std::collections::HashMap;\nfn f(x: Option<u8>) { x.unwrap(); }\n";
        let v = check_source("serve/x.rs", src).unwrap();
        assert_eq!(v.len(), 2);
        assert!(v[0].line <= v[1].line);
    }

    #[test]
    fn display_is_clickable() {
        let v = Violation {
            rule: "raw-mantissa",
            module: "infer/mod.rs".into(),
            line: 7,
            message: "msg".into(),
            chain: Vec::new(),
        };
        assert_eq!(v.to_string(), "infer/mod.rs:7: [raw-mantissa] msg");
    }

    #[test]
    fn lint_report_json_is_deterministic_and_registered() {
        let report = Report {
            violations: vec![Violation {
                rule: "schema-registry",
                module: "a/b.rs".into(),
                line: 3,
                message: "msg".into(),
                chain: vec!["a/b.rs::f".into()],
            }],
            allows: vec![("a/b.rs".into(), "raw-mantissa".into(), "why".into())],
            dead: vec!["a/b.rs:1: a/b.rs::unused".into()],
            elapsed_ms: 12.5,
            ..Report::default()
        };
        let a = report.to_json().to_string();
        let b = report.to_json().to_string();
        assert_eq!(a, b);
        // the report's own schema is declared in obs::SCHEMAS
        assert!(a.contains("\"otaro.lint.v1\""));
        assert!(obs::SCHEMAS.iter().any(|d| d.name == "lint" && d.version == 1));
        // timing stays out of the det section
        assert!(!a.contains("12.5"));
    }
}
