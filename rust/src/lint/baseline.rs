//! Checked-in baseline: documented legacy debt the lint pass tolerates.
//!
//! Format — one entry per line, `#` comments and blank lines ignored:
//!
//! ```text
//! <rule-name> <module-path>
//! ```
//!
//! An entry waives every violation of `<rule-name>` in
//! `<module-path>` (relative to the source root).  The waiver is
//! file-granular on purpose: line numbers would churn on every edit,
//! and per-file debt is what gets paid down as a unit.
//!
//! Two staleness guards keep the baseline honest:
//!
//! * an entry naming a module that no longer exists **fails** the pass
//!   (no debt records for deleted files), and
//! * an entry that matched no violation is reported as unused (the debt
//!   was paid — delete the entry) without failing the pass.

use std::collections::BTreeSet;

/// Parsed baseline entries as `(rule, module)` pairs.
#[derive(Debug, Clone, Default)]
pub struct Baseline {
    pub entries: BTreeSet<(String, String)>,
}

impl Baseline {
    pub fn parse(text: &str, known_rules: &[&str]) -> anyhow::Result<Baseline> {
        let mut entries = BTreeSet::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let (Some(rule), Some(module), None) =
                (parts.next(), parts.next(), parts.next())
            else {
                anyhow::bail!(
                    "baseline line {}: expected `<rule> <module>`, got {line:?}",
                    ln + 1
                );
            };
            anyhow::ensure!(
                known_rules.contains(&rule),
                "baseline line {}: unknown rule {rule:?}",
                ln + 1
            );
            entries.insert((rule.to_string(), module.to_string()));
        }
        Ok(Baseline { entries })
    }

    pub fn covers(&self, rule: &str, module: &str) -> bool {
        self.entries.contains(&(rule.to_string(), module.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const RULES: &[&str] = &["raw-mantissa", "request-path-no-panic"];

    #[test]
    fn parses_entries_and_comments() {
        let b = Baseline::parse(
            "# debt ledger\n\nraw-mantissa coordinator/mod.rs\n",
            RULES,
        )
        .unwrap();
        assert!(b.covers("raw-mantissa", "coordinator/mod.rs"));
        assert!(!b.covers("raw-mantissa", "serve/store.rs"));
        assert!(!b.covers("request-path-no-panic", "coordinator/mod.rs"));
    }

    #[test]
    fn rejects_malformed_and_unknown() {
        assert!(Baseline::parse("just-one-field\n", RULES).is_err());
        assert!(Baseline::parse("a b c\n", RULES).is_err());
        assert!(Baseline::parse("no-such-rule serve/store.rs\n", RULES).is_err());
    }
}
