//! Crate-wide graph analyses — the reachability-based counterparts of
//! the per-file token rules in [`super::rules`].
//!
//! The token rules guard *direct* violations: an `unwrap()` typed into
//! `serve/`, a `format!` typed into a `no_alloc` region.  The analyses
//! here close the cross-module gap by walking the call graph built in
//! [`super::graph`]:
//!
//! * `transitive-request-path-no-panic` — every non-test fn in a
//!   request-path module ([`PATH_DIRS`]) is an entry point; any panic
//!   token (`unwrap`/`expect` calls, `panic!`-family macros) in a fn
//!   reachable from one — in *any* module — is a violation, reported
//!   with the full entry → … → offender chain.
//! * `transitive-hot-loop-no-alloc` — a call inside a
//!   `// lint: region(no_alloc)` span may not reach a fn containing an
//!   allocating idiom through any chain.  "Allocating" means a crate fn
//!   the direct rule would flag; std methods (e.g. `Vec::push` on a
//!   pre-sized scratch buffer) contribute no graph node and are the
//!   direct rule's business.
//! * `determinism-taint` — a fn mentioning `HashMap`/`HashSet` (in a
//!   module the direct determinism rule does not already cover) may not
//!   reach a fn that emits a frozen `otaro.<name>.v<N>` snapshot
//!   literal: iteration order must never feed a byte-frozen artifact.
//! * `schema-registry` — every `otaro.<name>.v<N>` literal in non-test
//!   code must resolve against [`crate::obs::SCHEMAS`]; an undeclared
//!   name is an unregistered snapshot format and a declared-name /
//!   different-version site is a silent version bump.  Under full-crate
//!   coverage the table is also checked for staleness (each declared
//!   emitting module must still contain its literal).
//!
//! All four honor inline `allow(rule, reason = …)` directives at the
//! violation line and the shrink-only baseline, like every token rule.
//! The report-only dead-item pass (surfaced by `otaro lint --dead`)
//! also lives here: pub fns whose name is never mentioned outside fn
//! definitions — candidates for deletion, listed but never failed on.

use std::collections::BTreeMap;

use crate::obs::SchemaDef;

use super::graph::Graph;
use super::parse::FileFacts;
use super::source::SourceFile;
use super::Violation;

/// Transitive panic reachability (graph form of `request-path-no-panic`).
pub const TRANSITIVE_PANIC: &str = "transitive-request-path-no-panic";
/// Transitive allocation reachability from `no_alloc` regions.
pub const TRANSITIVE_ALLOC: &str = "transitive-hot-loop-no-alloc";
/// Hash-iteration taint flowing into frozen snapshot emitters.
pub const DETERMINISM_TAINT: &str = "determinism-taint";
/// `otaro.<name>.v<N>` literals must resolve against `obs::SCHEMAS`.
pub const SCHEMA_REGISTRY: &str = "schema-registry";

/// One registered analysis (the graph-level analogue of
/// [`super::rules::RuleDef`]).
pub struct AnalysisDef {
    pub name: &'static str,
    /// one-line contract statement
    pub summary: &'static str,
}

/// The analysis registry, in documentation order.
pub const ANALYSES: &[AnalysisDef] = &[
    AnalysisDef {
        name: TRANSITIVE_PANIC,
        summary: "no panic token anywhere in the crate is reachable from a \
                  request-path entry point through the call graph",
    },
    AnalysisDef {
        name: TRANSITIVE_ALLOC,
        summary: "calls inside no_alloc regions reach no allocating crate fn \
                  through any call chain",
    },
    AnalysisDef {
        name: DETERMINISM_TAINT,
        summary: "HashMap/HashSet usage never flows into a fn emitting a \
                  frozen otaro.*.vN snapshot",
    },
    AnalysisDef {
        name: SCHEMA_REGISTRY,
        summary: "every otaro.<name>.v<N> literal resolves against \
                  obs::SCHEMAS; versions never bump silently",
    },
];

/// Names of all registered analyses (for directive validation).
pub fn analysis_names() -> Vec<&'static str> {
    ANALYSES.iter().map(|a| a.name).collect()
}

/// Request-path module prefixes — shared with the direct
/// `request-path-no-panic` / `decision-path-determinism` rules.
pub const PATH_DIRS: &[&str] = &["serve/", "policy/", "obs/", "workload/", "benchutil/diff"];

/// True when `module` is a request-path module.
pub fn in_path(module: &str) -> bool {
    PATH_DIRS.iter().any(|d| module.starts_with(d))
}

/// Everything one analysis pass produces beyond violations.
#[derive(Debug, Default)]
pub struct Outcome {
    pub violations: Vec<Violation>,
    /// non-test fns reachable from request-path entry points
    pub reachable_fns: usize,
    /// `expr[idx]` sites inside those reachable fns (informational:
    /// each is an assert-class bounds contract on the request path)
    pub reachable_index_sites: usize,
    /// non-test `otaro.*.vN` literal sites checked against the registry
    pub schema_sites: usize,
    /// report-only dead-item candidates, `module:line: label` sorted
    pub dead: Vec<String>,
}

/// Run all graph analyses over the parsed crate.  `files` and `facts`
/// are parallel (one entry per source file); `schemas` is the declared
/// registry; `coverage` enables the staleness direction of the schema
/// check and must only be set when `facts` spans the whole crate.
pub fn run(
    files: &[SourceFile],
    facts: &[FileFacts],
    schemas: &[SchemaDef],
    coverage: bool,
) -> Outcome {
    debug_assert_eq!(files.len(), facts.len());
    let mut out = Outcome::default();
    let graph = Graph::build(facts);
    let file_of: BTreeMap<&str, usize> =
        files.iter().enumerate().map(|(i, f)| (f.module.as_str(), i)).collect();
    let allowed = |rule: &str, module: &str, line: usize| -> bool {
        file_of
            .get(module)
            .is_some_and(|&i| line >= 1 && files[i].allowed(rule, line - 1))
    };
    let mut base = Vec::with_capacity(facts.len());
    let mut acc = 0usize;
    for ff in facts {
        base.push(acc);
        acc += ff.fns.len();
    }

    // ── transitive-request-path-no-panic ────────────────────────────
    let entries: Vec<usize> = (0..graph.fns.len())
        .filter(|&k| !graph.fns[k].is_test && in_path(&graph.fns[k].module))
        .collect();
    let reach = graph.reach(&entries);
    for k in 0..graph.fns.len() {
        if reach.dist[k].is_none() {
            continue;
        }
        let f = graph.fns[k];
        out.reachable_fns += 1;
        out.reachable_index_sites += f.index_sites;
        if in_path(&f.module) {
            // the direct token rule owns panic sites inside path modules
            continue;
        }
        for (line, tok) in &f.panics {
            if allowed(TRANSITIVE_PANIC, &f.module, *line) {
                continue;
            }
            let chain = graph.chain_labels(&reach.parent, k);
            out.violations.push(Violation {
                rule: TRANSITIVE_PANIC,
                module: f.module.clone(),
                line: *line,
                message: format!(
                    "`{tok}` is reachable from the request path — propagate an \
                     error instead; chain: {}",
                    chain.join(" -> ")
                ),
                chain,
            });
        }
    }

    // ── transitive-hot-loop-no-alloc ────────────────────────────────
    for (fi, file) in files.iter().enumerate() {
        for region in file.regions.iter().filter(|r| r.kind == "no_alloc") {
            for (kl, f) in facts[fi].fns.iter().enumerate() {
                if f.is_test {
                    continue;
                }
                let k = base[fi] + kl;
                for (ci, call) in f.calls.iter().enumerate() {
                    let li = call.line.saturating_sub(1);
                    if li < region.start || li > region.end {
                        continue;
                    }
                    if allowed(TRANSITIVE_ALLOC, &file.module, call.line) {
                        continue;
                    }
                    let mut hit: Option<Vec<usize>> = None;
                    for &t in &graph.call_targets[k][ci] {
                        if let Some(p) =
                            graph.find_path(t, |u| !graph.fns[u].allocs.is_empty())
                        {
                            hit = Some(p);
                            break;
                        }
                    }
                    let Some(path) = hit else { continue };
                    let Some(&sink) = path.last() else { continue };
                    let Some((aline, atok)) = graph.fns[sink].allocs.first() else { continue };
                    let mut chain = vec![f.label()];
                    chain.extend(path.iter().map(|&u| graph.fns[u].label()));
                    out.violations.push(Violation {
                        rule: TRANSITIVE_ALLOC,
                        module: file.module.clone(),
                        line: call.line,
                        message: format!(
                            "`{}()` inside a no_alloc region reaches `{atok}` \
                             ({}:{aline}); chain: {}",
                            call.name,
                            graph.fns[sink].module,
                            chain.join(" -> ")
                        ),
                        chain,
                    });
                }
            }
        }
    }

    // ── determinism-taint ───────────────────────────────────────────
    // emitters: innermost non-test fn enclosing each schema literal
    let mut emitters: BTreeMap<usize, String> = BTreeMap::new();
    for (fi, ff) in facts.iter().enumerate() {
        for site in &ff.schemas {
            let mut best: Option<(usize, usize)> = None; // (span, local idx)
            for (kl, f) in ff.fns.iter().enumerate() {
                if f.is_test || site.line < f.line || site.line > f.end_line {
                    continue;
                }
                let span = f.end_line - f.line;
                if best.is_none_or(|(s, _)| span < s) {
                    best = Some((span, kl));
                }
            }
            if let Some((_, kl)) = best {
                emitters.entry(base[fi] + kl).or_insert_with(|| site.text.clone());
            }
        }
    }
    for k in 0..graph.fns.len() {
        let f = graph.fns[k];
        if f.is_test || in_path(&f.module) || f.hash_lines.is_empty() {
            // path modules: the direct determinism rule bans the types
            continue;
        }
        let Some(&hline) = f.hash_lines.first() else { continue };
        if allowed(DETERMINISM_TAINT, &f.module, hline) {
            continue;
        }
        let Some(path) = graph.find_path(k, |u| emitters.contains_key(&u)) else { continue };
        let Some(&sink) = path.last() else { continue };
        let schema = emitters.get(&sink).cloned().unwrap_or_default();
        let chain: Vec<String> = path.iter().map(|&u| graph.fns[u].label()).collect();
        out.violations.push(Violation {
            rule: DETERMINISM_TAINT,
            module: f.module.clone(),
            line: hline,
            message: format!(
                "`HashMap`/`HashSet` iteration here can taint the frozen \
                 snapshot `{schema}` emitted by {} — use BTreeMap/BTreeSet; \
                 chain: {}",
                graph.fns[sink].label(),
                chain.join(" -> ")
            ),
            chain,
        });
    }

    // ── schema-registry ─────────────────────────────────────────────
    for ff in facts {
        for site in &ff.schemas {
            out.schema_sites += 1;
            if allowed(SCHEMA_REGISTRY, &ff.module, site.line) {
                continue;
            }
            if schemas.iter().any(|d| d.name == site.name && d.version == site.version) {
                continue;
            }
            let declared =
                schemas.iter().filter(|d| d.name == site.name).map(|d| d.version).max();
            let message = match declared {
                Some(v) => format!(
                    "`{}` silently bumps frozen schema `{}` past declared v{v} — \
                     schema versions change by adding a row to obs::SCHEMAS, \
                     never silently",
                    site.text, site.name
                ),
                None => format!(
                    "`{}` is not declared in obs::SCHEMAS — register every \
                     frozen snapshot schema (name, version, emitting module)",
                    site.text
                ),
            };
            out.violations.push(Violation {
                rule: SCHEMA_REGISTRY,
                module: ff.module.clone(),
                line: site.line,
                message,
                chain: Vec::new(),
            });
        }
    }
    if coverage {
        for d in schemas {
            let stale = match file_of.get(d.module) {
                None => Some(format!(
                    "obs::SCHEMAS declares `{}` emitted by `{}`, but that module \
                     is not in the linted tree — fix or delete the row",
                    d.literal(),
                    d.module
                )),
                Some(&fi) => {
                    let present = facts[fi]
                        .schemas
                        .iter()
                        .any(|s| s.name == d.name && s.version == d.version);
                    (!present).then(|| {
                        format!(
                            "obs::SCHEMAS declares `{}` emitted by `{}`, but the \
                             module never emits the literal — stale row; update \
                             or delete it",
                            d.literal(),
                            d.module
                        )
                    })
                }
            };
            if let Some(message) = stale {
                out.violations.push(Violation {
                    rule: SCHEMA_REGISTRY,
                    module: d.module.to_string(),
                    line: 1,
                    message,
                    chain: Vec::new(),
                });
            }
        }
    }

    // ── report-only dead-item pass ──────────────────────────────────
    let mut name_count: BTreeMap<&str, usize> = BTreeMap::new();
    for ff in facts {
        for (name, c) in &ff.mentions {
            *name_count.entry(name.as_str()).or_insert(0) += c;
        }
    }
    let mut decl_count: BTreeMap<&str, usize> = BTreeMap::new();
    for f in &graph.fns {
        *decl_count.entry(f.name.as_str()).or_insert(0) += 1;
    }
    let mut dead: Vec<(&str, usize, String)> = Vec::new();
    for f in &graph.fns {
        if f.is_test || !f.is_pub || f.trait_name.is_some() || f.name == "main" {
            continue;
        }
        // every definition site mentions the name once; any further
        // mention (call, re-export, reference) keeps the fn alive
        let uses = name_count.get(f.name.as_str()).copied().unwrap_or(0);
        let decls = decl_count.get(f.name.as_str()).copied().unwrap_or(0);
        if uses <= decls {
            dead.push((f.module.as_str(), f.line, f.label()));
        }
    }
    dead.sort();
    out.dead =
        dead.into_iter().map(|(m, line, label)| format!("{m}:{line}: {label}")).collect();

    out
}
