//! BPS — Exploitation-Exploration Bit-Width Path Search (paper eq. 5-9).
//!
//! At every batch the coordinator scores each bit-width
//!
//! ```text
//! Score(b) = λ · sqrt(ln t / t_b) − L_b
//! ```
//!
//! and selects the argmax.  `t` is the global batch count, `t_b` the
//! number of times `b` was selected, and `L_b` the most recent (EMA) loss
//! observed at `b`.  The UCB exploration term guarantees every width keeps
//! being visited; as t grows the loss term dominates and the path
//! converges toward the higher bit-widths (smaller loss, eq. 9) whose
//! gradients align best with the rest of the ladder (paper fig. 4).

use std::collections::HashMap;

use crate::sefp::Precision;

#[derive(Debug, Clone)]
pub struct Bps {
    pub widths: Vec<Precision>,
    pub lambda: f64,
    /// EMA factor for L_b (1.0 = keep only the latest loss).
    pub ema: f64,
    t: u64,
    counts: HashMap<Precision, u64>,
    losses: HashMap<Precision, f64>,
}

impl Bps {
    pub fn new(widths: &[Precision], lambda: f64, ema: f64) -> Self {
        assert!(!widths.is_empty());
        Bps {
            widths: widths.to_vec(),
            lambda,
            ema,
            t: 0,
            counts: HashMap::new(),
            losses: HashMap::new(),
        }
    }

    /// Score(b) at the current step (eq. 5).  Unvisited widths score +inf
    /// so each gets sampled at least once up front.
    pub fn score(&self, b: Precision) -> f64 {
        let t_b = *self.counts.get(&b).unwrap_or(&0);
        if t_b == 0 {
            return f64::INFINITY;
        }
        let t = (self.t.max(1)) as f64;
        let explore = self.lambda * (t.ln().max(0.0) / t_b as f64).sqrt();
        let loss = *self.losses.get(&b).unwrap_or(&0.0);
        explore - loss
    }

    /// Select the next bit-width (argmax score; ties break toward the
    /// HIGHER width, consistent with the paper's convergence argument).
    pub fn select(&mut self) -> Precision {
        self.t += 1;
        let mut best = self.widths[0];
        let mut best_score = f64::NEG_INFINITY;
        for &b in &self.widths {
            let s = self.score(b);
            if s > best_score || (s == best_score && b > best) {
                best_score = s;
                best = b;
            }
        }
        *self.counts.entry(best).or_insert(0) += 1;
        best
    }

    /// Report the observed loss for the width just trained.
    pub fn update(&mut self, b: Precision, loss: f64) {
        let e = self.losses.entry(b).or_insert(loss);
        *e = self.ema * loss + (1.0 - self.ema) * *e;
    }

    pub fn count(&self, b: Precision) -> u64 {
        *self.counts.get(&b).unwrap_or(&0)
    }

    pub fn t(&self) -> u64 {
        self.t
    }

    /// Selection frequencies (path histogram, logged per run).
    pub fn histogram(&self) -> Vec<(Precision, u64)> {
        let mut v: Vec<(Precision, u64)> =
            self.widths.iter().map(|&b| (b, self.count(b))).collect();
        v.sort_by_key(|&(b, _)| std::cmp::Reverse(b));
        v
    }
}

/// Uniform sampler baseline (paper fig. 3, "uniform sampling").
#[derive(Debug, Clone)]
pub struct UniformSampler {
    widths: Vec<Precision>,
    rng: crate::data::Rng,
}

impl UniformSampler {
    pub fn new(widths: &[Precision], seed: u64) -> Self {
        UniformSampler { widths: widths.to_vec(), rng: crate::data::Rng::new(seed) }
    }

    pub fn select(&mut self) -> Precision {
        *self.rng.choose(&self.widths)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const WIDTHS: [Precision; 6] = Precision::LADDER;

    #[test]
    fn visits_every_width_first() {
        let mut bps = Bps::new(&WIDTHS, 5.0, 1.0);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..WIDTHS.len() {
            let b = bps.select();
            bps.update(b, 5.0);
            seen.insert(b);
        }
        assert_eq!(seen.len(), WIDTHS.len());
    }

    #[test]
    fn converges_to_lower_loss_width() {
        // synthetic losses: lower m -> higher loss (paper's premise);
        // λ=5 (the paper's setting) keeps low widths explored while the
        // path drifts to the high end (see eq. 7-9 analysis)
        let mut bps = Bps::new(&WIDTHS, 5.0, 1.0);
        for _ in 0..600 {
            let b = bps.select();
            let loss = 2.0 + (8 - b.m()) as f64 * 0.3;
            bps.update(b, loss);
        }
        // high widths must dominate the tail counts (paper eq. 9)
        let (hi, lo) = (bps.count(Precision::of(8)), bps.count(Precision::of(3)));
        assert!(hi > lo * 2, "{:?}", bps.histogram());
        // but every width keeps being explored
        for b in WIDTHS {
            assert!(bps.count(b) >= 5, "b={b} {:?}", bps.histogram());
        }
    }

    #[test]
    fn large_lambda_explores_more() {
        let run = |lambda: f64| {
            let mut bps = Bps::new(&WIDTHS, lambda, 1.0);
            for _ in 0..300 {
                let b = bps.select();
                bps.update(b, 2.0 + (8 - b.m()) as f64 * 0.5);
            }
            bps.count(Precision::of(3))
        };
        assert!(run(20.0) > run(0.1));
    }

    #[test]
    fn score_decreases_with_count() {
        let mut bps = Bps::new(&WIDTHS, 5.0, 1.0);
        for _ in 0..50 {
            let b = bps.select();
            bps.update(b, 1.0);
        }
        let s1 = bps.score(Precision::of(8));
        for _ in 0..50 {
            // keep selecting; t grows, t_8 grows proportionally more if
            // chosen — simply verify the exploration term shrinks
            let b = bps.select();
            bps.update(b, if b == Precision::of(8) { 1.0 } else { 1.2 });
        }
        assert!(bps.score(Precision::of(8)) <= s1 + 1e6); // sanity (non-NaN, finite)
        assert!(bps.score(Precision::of(8)).is_finite());
    }

    #[test]
    fn uniform_covers_all() {
        let mut u = UniformSampler::new(&WIDTHS, 1);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..200 {
            seen.insert(u.select());
        }
        assert_eq!(seen.len(), WIDTHS.len());
    }
}
