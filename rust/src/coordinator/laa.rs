//! LAA — Low-Precision Asynchronous Accumulation (paper eq. 10-18,
//! Algorithm 1 lines 6-17).
//!
//! Low bit-widths make the SEFP quantization error a large-amplitude
//! sawtooth in the weights (eq. 13), which injects a near-zero-mean
//! perturbation `Y` into the gradients (`∇_sefp = X·∇_fp + Y`, fig. 6).
//! LAA suppresses it by accumulating gradients over `N` batches while the
//! path sits at ultra-low widths and applying ONE delayed update — the
//! perturbation cancels at rate 1/√N (eq. 17) while the signal adds
//! coherently.
//!
//! Design decision (ablatable, DESIGN.md §6): the accumulator *persists*
//! across interleaved high-width steps — high widths update immediately
//! and the partial low-width sum keeps waiting for its N-th contribution.
//! `flush_on_switch = true` instead applies the partial sum whenever the
//! path leaves the ultra-low zone.

use crate::runtime::{grad_accumulate, Width};
use crate::sefp::Precision;

/// What the trainer should do with the gradients of the current batch.
#[derive(Debug, PartialEq)]
pub enum LaaAction {
    /// Apply this gradient now (standard update, Algorithm 1 line 18).
    /// The grads are handed back to the caller unchanged.
    Apply(Vec<Vec<f32>>),
    /// Absorbed into the accumulator; do not update weights this batch.
    Deferred { filled: usize },
    /// The accumulator just completed: apply the returned summed gradient
    /// (`count` = number of accumulated batches, for mean-normalization).
    Flush { grads: Vec<Vec<f32>>, count: usize },
}

#[derive(Debug)]
pub struct Laa {
    /// delay step N (paper: 10)
    pub delay_n: usize,
    /// precisions at or below this are "ultra-low" and get accumulated
    pub ultra_low_max: Precision,
    /// ablation switch, see module docs
    pub flush_on_switch: bool,
    acc: Option<Vec<Vec<f32>>>,
    filled: usize,
    /// statistics
    pub deferred_total: u64,
    pub flushes: u64,
}

impl Laa {
    pub fn new(delay_n: usize, ultra_low_max: Precision) -> Self {
        assert!(delay_n >= 1);
        Laa {
            delay_n,
            ultra_low_max,
            flush_on_switch: false,
            acc: None,
            filled: 0,
            deferred_total: 0,
            flushes: 0,
        }
    }

    /// Whether `width` counts as ultra-low (FP never does).
    pub fn is_ultra_low(&self, width: Width) -> bool {
        width.0.is_some_and(|p| p <= self.ultra_low_max)
    }

    /// Feed the gradients produced at `width`; decides apply/defer.
    pub fn observe(&mut self, width: Width, grads: Vec<Vec<f32>>) -> LaaAction {
        if !self.is_ultra_low(width) {
            if self.flush_on_switch && self.acc.is_some() {
                // ablation path: the partial sum is merged into this
                // apply, so no gradient contribution is lost
                let count = self.filled + 1;
                let mut pending = self.take_acc();
                grad_accumulate(&mut pending, &grads);
                self.flushes += 1;
                return LaaAction::Flush { grads: pending, count };
            }
            return LaaAction::Apply(grads);
        }
        // ultra-low: accumulate (Algorithm 1 lines 7-11)
        match &mut self.acc {
            None => {
                self.acc = Some(grads);
                self.filled = 1;
            }
            Some(acc) => {
                grad_accumulate(acc, &grads);
                self.filled += 1;
            }
        }
        self.deferred_total += 1;
        if self.filled >= self.delay_n {
            // delayed update (lines 13-16)
            self.flushes += 1;
            let count = self.filled;
            LaaAction::Flush { grads: self.take_acc(), count }
        } else {
            LaaAction::Deferred { filled: self.filled }
        }
    }

    /// Pending partial sum, if any (flushed by the trainer at run end so
    /// no gradient contribution is dropped).  Returns (grads, count).
    pub fn drain(&mut self) -> Option<(Vec<Vec<f32>>, usize)> {
        if self.acc.is_some() {
            self.flushes += 1;
            let count = self.filled;
            Some((self.take_acc(), count))
        } else {
            None
        }
    }

    pub fn pending(&self) -> usize {
        self.filled * (self.acc.is_some() as usize)
    }

    fn take_acc(&mut self) -> Vec<Vec<f32>> {
        self.filled = 0;
        self.acc.take().expect("accumulator present")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g(v: f32) -> Vec<Vec<f32>> {
        vec![vec![v, v]]
    }

    fn w(raw: u8) -> Width {
        Width::m(Precision::of(raw))
    }

    #[test]
    fn fp_is_never_ultra_low() {
        let mut laa = Laa::new(2, Precision::of(4));
        assert!(!laa.is_ultra_low(Width::FP));
        assert!(laa.is_ultra_low(w(3)));
        assert!(!laa.is_ultra_low(w(5)));
        assert_eq!(laa.observe(Width::FP, g(1.0)), LaaAction::Apply(g(1.0)));
    }

    #[test]
    fn high_width_applies_immediately() {
        let mut laa = Laa::new(10, Precision::of(4));
        assert_eq!(laa.observe(w(8), g(1.0)), LaaAction::Apply(g(1.0)));
        assert_eq!(laa.pending(), 0);
    }

    #[test]
    fn ultra_low_defers_until_n() {
        let mut laa = Laa::new(3, Precision::of(4));
        assert!(matches!(laa.observe(w(3), g(1.0)), LaaAction::Deferred { filled: 1 }));
        assert!(matches!(laa.observe(w(4), g(2.0)), LaaAction::Deferred { filled: 2 }));
        match laa.observe(w(3), g(3.0)) {
            LaaAction::Flush { grads, count } => {
                assert_eq!(grads, vec![vec![6.0, 6.0]]);
                assert_eq!(count, 3);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(laa.pending(), 0);
        assert_eq!(laa.flushes, 1);
    }

    #[test]
    fn accumulator_persists_across_high_steps() {
        let mut laa = Laa::new(2, Precision::of(4));
        assert!(matches!(laa.observe(w(3), g(1.0)), LaaAction::Deferred { .. }));
        // high width in between: immediate apply, accumulator untouched
        assert_eq!(laa.observe(w(8), g(9.0)), LaaAction::Apply(g(9.0)));
        assert_eq!(laa.pending(), 1);
        match laa.observe(w(4), g(1.0)) {
            LaaAction::Flush { grads, count } => {
                assert_eq!(grads, vec![vec![2.0, 2.0]]);
                assert_eq!(count, 2);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn flush_on_switch_merges_partial() {
        let mut laa = Laa::new(5, Precision::of(4));
        laa.flush_on_switch = true;
        assert!(matches!(laa.observe(w(3), g(1.0)), LaaAction::Deferred { .. }));
        match laa.observe(w(8), g(10.0)) {
            LaaAction::Flush { grads, count } => {
                assert_eq!(grads, vec![vec![11.0, 11.0]]);
                assert_eq!(count, 2);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(laa.pending(), 0);
    }

    #[test]
    fn drain_returns_partial() {
        let mut laa = Laa::new(10, Precision::of(4));
        let _ = laa.observe(w(3), g(1.0));
        let _ = laa.observe(w(3), g(2.0));
        assert_eq!(laa.drain().unwrap(), (vec![vec![3.0, 3.0]], 2));
        assert!(laa.drain().is_none());
    }
}
