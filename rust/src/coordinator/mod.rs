//! L3 coordinator — the paper's system contribution.
//!
//! * [`bps`]     — Exploitation-Exploration Bit-Width Path Search (eq. 5-9)
//! * [`laa`]     — Low-Precision Asynchronous Accumulation (eq. 10-18)
//! * [`trainer`] — Algorithm 1 plus all evaluation baselines
//!
//! The coordinator runs entirely in Rust against AOT-compiled HLO; the
//! bit-width schedule, the delayed-update bookkeeping and the SGD
//! optimizer all live here (L2's train step only produces loss+grads).

pub mod bps;
pub mod laa;
pub mod trainer;

pub use bps::{Bps, UniformSampler};
pub use laa::{Laa, LaaAction};
pub use trainer::{eval_loss, BatchSource, TrainReport, Trainer};
