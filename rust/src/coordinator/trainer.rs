//! The OTARo trainer — Algorithm 1, plus every baseline in the paper's
//! evaluation (table 1 rows and fig. 3/8 ablation arms).
//!
//! The trainer owns the loop; the engine owns the compute.  One `run()`
//! executes `cfg.steps` batches: select a bit-width (method-dependent),
//! run the AOT train step at that width, route the gradients through LAA
//! (full OTARo only), and apply SGD updates to the f32 master weights.

use std::path::{Path, PathBuf};

use crate::artifact::{write_artifact, ArtifactMeta};
use crate::config::{Method, TrainConfig};
use crate::data::Batch;
use crate::metrics::{MetricsSink, Timer};
use crate::runtime::{grad_l2_norm, Engine, ParamStore, StepKind, Width};
use crate::sefp::Precision;

use super::bps::{Bps, UniformSampler};
use super::laa::{Laa, LaaAction};

/// Anything that can feed batches to the trainer.
pub trait BatchSource {
    fn next_batch(&mut self) -> Batch;
}

impl BatchSource for crate::data::StreamBatcher {
    fn next_batch(&mut self) -> Batch {
        crate::data::StreamBatcher::next_batch(self)
    }
}

impl BatchSource for crate::data::PairBatcher {
    fn next_batch(&mut self) -> Batch {
        crate::data::PairBatcher::next_batch(self)
    }
}

/// Per-run outcome.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub losses: Vec<f32>,
    /// selected precision per step (`None` = fp step)
    pub path: Vec<Option<Precision>>,
    pub width_histogram: Vec<(Precision, u64)>,
    pub laa_flushes: u64,
    pub laa_deferred: u64,
    pub wall_secs: f64,
    pub final_loss_ema: f64,
}

pub struct Trainer<'a, B: BatchSource> {
    pub engine: &'a mut Engine,
    pub params: &'a mut ParamStore,
    pub batches: &'a mut B,
    pub cfg: TrainConfig,
}

impl<'a, B: BatchSource> Trainer<'a, B> {
    pub fn new(
        engine: &'a mut Engine,
        params: &'a mut ParamStore,
        batches: &'a mut B,
        cfg: TrainConfig,
    ) -> Self {
        Trainer { engine, params, batches, cfg }
    }

    fn width_for_step(
        &self,
        bps: &mut Option<Bps>,
        uniform: &mut Option<UniformSampler>,
    ) -> Width {
        match self.cfg.method {
            Method::None => unreachable!("Method::None runs zero steps"),
            Method::Fp => Width::FP,
            Method::Fixed => Width::m(
                self.cfg
                    .fixed_m
                    .expect("Method::Fixed requires fixed_m"),
            ),
            Method::Uniform => Width::m(uniform.as_mut().unwrap().select()),
            Method::BpsOnly | Method::Otaro => Width::m(bps.as_mut().unwrap().select()),
        }
    }

    /// Run the fine-tuning loop (Algorithm 1).  `sink` receives one JSONL
    /// record per step.
    pub fn run(&mut self, sink: &mut MetricsSink) -> anyhow::Result<TrainReport> {
        let timer = Timer::start();
        let method = self.cfg.method;
        if method == Method::None {
            return Ok(TrainReport {
                losses: vec![],
                path: vec![],
                width_histogram: vec![],
                laa_flushes: 0,
                laa_deferred: 0,
                wall_secs: 0.0,
                final_loss_ema: f64::NAN,
            });
        }

        let mut bps = matches!(method, Method::BpsOnly | Method::Otaro)
            .then(|| Bps::new(&self.cfg.widths, self.cfg.lambda, self.cfg.loss_ema));
        let mut uniform = (method == Method::Uniform)
            .then(|| UniformSampler::new(&self.cfg.widths, self.cfg.seed ^ UNIFORM_TAG));
        let mut laa = (method == Method::Otaro).then(|| {
            let mut l = Laa::new(self.cfg.delay_n, self.cfg.ultra_low_max);
            l.flush_on_switch = self.cfg.laa_flush_on_switch;
            l
        });

        let mut losses = Vec::with_capacity(self.cfg.steps);
        let mut path = Vec::with_capacity(self.cfg.steps);
        let mut ema = f64::NAN;

        for step in 0..self.cfg.steps {
            let width = self.width_for_step(&mut bps, &mut uniform);
            let batch = self.batches.next_batch();
            let out = self.engine.train_step(self.params, &batch, width)?;
            let loss = out.loss;
            losses.push(loss);
            path.push(width.0);
            if let Some(b) = &mut bps {
                if let Some(p) = width.0 {
                    b.update(p, loss as f64);
                }
            }
            ema = if ema.is_nan() { loss as f64 } else { 0.95 * ema + 0.05 * loss as f64 };

            let gnorm = grad_l2_norm(&out.grads);
            let laa_event = match &mut laa {
                Some(l) => match l.observe(width, out.grads) {
                    LaaAction::Apply(g) => {
                        self.params.sgd_update(&g, self.cfg.lr);
                        "apply"
                    }
                    LaaAction::Deferred { .. } => "defer",
                    LaaAction::Flush { grads, count } => {
                        let lr = if self.cfg.laa_average {
                            self.cfg.lr / count.max(1) as f32
                        } else {
                            self.cfg.lr // paper eq. 18 raw sum
                        };
                        self.params.sgd_update(&grads, lr);
                        "flush"
                    }
                },
                None => {
                    self.params.sgd_update(&out.grads, self.cfg.lr);
                    "apply"
                }
            };
            sink.log(&crate::json::obj(vec![
                ("step", crate::json::n(step as f64)),
                ("method", crate::json::s(method.to_string())),
                ("width", crate::json::s(width.tag())),
                ("loss", crate::json::n(loss as f64)),
                ("grad_norm", crate::json::n(gnorm)),
                ("laa", crate::json::s(laa_event)),
            ]));
        }
        // flush any pending LAA partial sum so its gradients are not lost
        if let Some(l) = &mut laa {
            if let Some((acc, count)) = l.drain() {
                let lr = if self.cfg.laa_average {
                    self.cfg.lr / count.max(1) as f32
                } else {
                    self.cfg.lr
                };
                self.params.sgd_update(&acc, lr);
            }
        }
        sink.flush();

        Ok(TrainReport {
            losses,
            path,
            width_histogram: bps.map(|b| b.histogram()).unwrap_or_default(),
            laa_flushes: laa.as_ref().map(|l| l.flushes).unwrap_or(0),
            laa_deferred: laa.as_ref().map(|l| l.deferred_total).unwrap_or(0),
            wall_secs: timer.secs(),
            final_loss_ema: ema,
        })
    }
}

impl<B: BatchSource> Trainer<'_, B> {
    /// Persist the run's weights twice: the raw f32 checkpoint at `out`
    /// (loadable by `ParamStore::load_into`, unchanged format) and the
    /// packed single-master `.sefp` artifact next to it (same stem,
    /// `.sefp` extension) — so every training run yields the on-device
    /// container the serve layer can open with
    /// `PrecisionLadder::from_artifact`, without a separate pack step.
    ///
    /// The artifact's ladder top is the highest width the run trained
    /// with; group size and rounding come from the engine manifest.
    /// Returns the artifact path.
    pub fn save_checkpoint(&self, out: &Path) -> anyhow::Result<PathBuf> {
        self.params.save(out)?;
        let model = &self.engine.manifest.config;
        let meta = ArtifactMeta {
            // max(), not first(): widths are canonicalized highest-first
            // only by the config parser, and the field is pub
            top: self.cfg.widths.iter().copied().max().unwrap_or(Precision::of(8)),
            group_size: model.group_size,
            rounding: model
                .rounding
                .parse()
                .map_err(|e: String| anyhow::anyhow!("manifest rounding: {e}"))?,
            config: Some(model.clone()),
        };
        let sefp = out.with_extension("sefp");
        write_artifact(&sefp, &*self.params, &meta)?;
        Ok(sefp)
    }
}

const UNIFORM_TAG: u64 = 0x0451;

/// Evaluate mean loss at `width` over `n_batches` freshly drawn batches.
pub fn eval_loss<B: BatchSource>(
    engine: &mut Engine,
    params: &ParamStore,
    batches: &mut B,
    width: Width,
    n_batches: usize,
) -> anyhow::Result<f64> {
    let mut total = 0.0f64;
    for _ in 0..n_batches {
        let b = batches.next_batch();
        total += engine.eval_step(params, &b, width)? as f64;
    }
    Ok(total / n_batches as f64)
}

// keep StepKind referenced so the import is obviously intentional
const _: StepKind = StepKind::Train;
