//! Least-squares decomposition ∇_sefp = X·∇_fp + Y (paper appendix B,
//! fig. 6).
//!
//! The appendix writes X as a d×d mapping estimated from N batches, but
//! with N ≪ d that system is wildly underdetermined; the fitted object
//! the figures actually need is the per-coordinate linear gain.  We
//! therefore fit a DIAGONAL X by least squares per coordinate j over the
//! batch window:
//!
//! ```text
//! X_j = Σ_i g_fp[i,j]·g_sefp[i,j] / Σ_i g_fp[i,j]²
//! Y_[i,j] = g_sefp[i,j] − X_j·g_fp[i,j]
//! ```
//!
//! which removes the cross-batch linear scaling exactly as the appendix
//! intends ("eliminates the linear scaling effect caused by gradient
//! magnitude variation across batches") while staying well-posed.  The
//! validated property is eq. 15: E[Y] ≈ 0.

#[derive(Debug, Clone)]
pub struct LsmFit {
    /// diagonal gains X_j (one per tracked coordinate)
    pub x: Vec<f64>,
    /// residuals Y[i][j]: batch-major
    pub y: Vec<Vec<f64>>,
    /// per-coordinate residual means (fig. 6's E[Y] check)
    pub y_mean: Vec<f64>,
    /// per-coordinate residual std
    pub y_std: Vec<f64>,
}

/// Fit over `g_fp[i][j]` / `g_sefp[i][j]` (i = batch, j = coordinate).
pub fn lsm_fit(g_fp: &[Vec<f64>], g_sefp: &[Vec<f64>]) -> LsmFit {
    assert_eq!(g_fp.len(), g_sefp.len());
    assert!(!g_fp.is_empty());
    let n = g_fp.len();
    let d = g_fp[0].len();
    let mut x = vec![0.0f64; d];
    for j in 0..d {
        let mut num = 0.0;
        let mut den = 0.0;
        for i in 0..n {
            num += g_fp[i][j] * g_sefp[i][j];
            den += g_fp[i][j] * g_fp[i][j];
        }
        x[j] = if den > 0.0 { num / den } else { 0.0 };
    }
    let mut y = vec![vec![0.0f64; d]; n];
    for i in 0..n {
        for j in 0..d {
            y[i][j] = g_sefp[i][j] - x[j] * g_fp[i][j];
        }
    }
    let mut y_mean = vec![0.0f64; d];
    let mut y_std = vec![0.0f64; d];
    for j in 0..d {
        let mean: f64 = y.iter().map(|r| r[j]).sum::<f64>() / n as f64;
        let var: f64 = y.iter().map(|r| (r[j] - mean).powi(2)).sum::<f64>() / n as f64;
        y_mean[j] = mean;
        y_std[j] = var.sqrt();
    }
    LsmFit { x, y, y_mean, y_std }
}

impl LsmFit {
    /// Scale-relative mean residual: |E[Y_j]| / std(Y_j), averaged over
    /// coordinates — should be ≪ 1 if E[Y] ≈ 0 (paper eq. 15).
    pub fn relative_mean_residual(&self) -> f64 {
        let mut acc = 0.0;
        let mut k = 0usize;
        for (m, s) in self.y_mean.iter().zip(&self.y_std) {
            if *s > 0.0 {
                acc += m.abs() / s;
                k += 1;
            }
        }
        if k == 0 {
            0.0
        } else {
            acc / k as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    #[test]
    fn recovers_diagonal_gain() {
        // g_sefp = 2*g_fp + zero-mean noise -> X ≈ 2, E[Y] ≈ 0
        let mut rng = Rng::new(1);
        let n = 400;
        let d = 8;
        let mut g_fp = Vec::new();
        let mut g_sefp = Vec::new();
        for _ in 0..n {
            let f: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            let s: Vec<f64> = f.iter().map(|&v| 2.0 * v + 0.1 * rng.normal()).collect();
            g_fp.push(f);
            g_sefp.push(s);
        }
        let fit = lsm_fit(&g_fp, &g_sefp);
        for &xj in &fit.x {
            assert!((xj - 2.0).abs() < 0.1, "x={xj}");
        }
        assert!(fit.relative_mean_residual() < 0.15);
    }

    #[test]
    fn residual_strips_linear_part() {
        // pure linear relation -> Y exactly zero
        let g_fp = vec![vec![1.0, 2.0], vec![2.0, -1.0], vec![-1.0, 0.5]];
        let g_sefp: Vec<Vec<f64>> =
            g_fp.iter().map(|r| r.iter().map(|v| 3.0 * v).collect()).collect();
        let fit = lsm_fit(&g_fp, &g_sefp);
        for row in &fit.y {
            for v in row {
                assert!(v.abs() < 1e-12);
            }
        }
    }

    #[test]
    fn zero_fp_gradient_handled() {
        let g_fp = vec![vec![0.0], vec![0.0]];
        let g_sefp = vec![vec![1.0], vec![-1.0]];
        let fit = lsm_fit(&g_fp, &g_sefp);
        assert_eq!(fit.x[0], 0.0);
        assert!(fit.y_mean[0].abs() < 1e-12);
    }
}
