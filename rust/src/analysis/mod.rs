//! Gradient & format analysis — regenerates the paper's diagnostic
//! figures:
//!
//! * fig. 4 — cosine similarities between gradients at different widths
//! * fig. 5 — gradient-norm errors ||∇_sefp|| − ||∇_fp|| over batches
//! * fig. 6 — LSM residual Y of ∇_sefp = X·∇_fp + Y (appendix B)
//! * fig. 9 — the ε(ω) sawtooth (appendix A)

pub mod epsilon;
pub mod grads;
pub mod lsm;

pub use epsilon::epsilon_curve;
pub use grads::{cosine, cosine_matrix, norm_error_traces};
pub use lsm::{lsm_fit, LsmFit};
