//! ε(ω) sawtooth sampler (paper eq. 13, appendix A fig. 9).
//!
//! ε(ω) = (ω·2^m − [ω·2^m]) / 2^m — period AND amplitude 1/2^m, so lower
//! mantissa widths oscillate harder: the mechanism behind the gradient
//! noise LAA suppresses.

use crate::sefp::{epsilon_sawtooth, Precision, Rounding};

/// Sample ε(ω) on a uniform grid over [lo, hi]; returns (ω, ε) pairs.
pub fn epsilon_curve(
    p: Precision,
    lo: f32,
    hi: f32,
    n: usize,
    rounding: Rounding,
) -> Vec<(f32, f32)> {
    assert!(n >= 2);
    (0..n)
        .map(|i| {
            let w = lo + (hi - lo) * i as f32 / (n - 1) as f32;
            (w, epsilon_sawtooth(w, p, rounding))
        })
        .collect()
}

/// Peak-to-peak amplitude of a sampled curve.
pub fn amplitude(curve: &[(f32, f32)]) -> f32 {
    let max = curve.iter().map(|&(_, e)| e).fold(f32::NEG_INFINITY, f32::max);
    let min = curve.iter().map(|&(_, e)| e).fold(f32::INFINITY, f32::min);
    max - min
}

/// Mean ordinate of a sampled curve (0.0 when empty).  Shared by the
/// ε(ω) analysis and the serving-side shadow probes, whose per-position
/// logit-divergence curves are summarized with the same machinery.
pub fn mean_ordinate(curve: &[(f32, f32)]) -> f32 {
    if curve.is_empty() {
        return 0.0;
    }
    curve.iter().map(|&(_, e)| e).sum::<f32>() / curve.len() as f32
}

/// Crude ASCII rendering for terminal output of fig. 9.
pub fn ascii_plot(curve: &[(f32, f32)], rows: usize, cols: usize) -> String {
    let (min_e, max_e) = curve.iter().fold((f32::INFINITY, f32::NEG_INFINITY), |(lo, hi), &(_, e)| {
        (lo.min(e), hi.max(e))
    });
    let span = (max_e - min_e).max(1e-12);
    let mut grid = vec![vec![b' '; cols]; rows];
    for (i, &(_, e)) in curve.iter().enumerate() {
        let c = i * cols / curve.len();
        let r = ((max_e - e) / span * (rows - 1) as f32).round() as usize;
        grid[r.min(rows - 1)][c.min(cols - 1)] = b'*';
    }
    grid.into_iter()
        .map(|row| String::from_utf8(row).unwrap())
        .collect::<Vec<_>>()
        .join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn amplitude_scales_with_width() {
        // amplitude(m) ≈ 1/2^m under rounding (±half step) and truncation
        let a3 = amplitude(&epsilon_curve(Precision::of(3), 0.0, 1.0, 4001, Rounding::Trunc));
        let a5 = amplitude(&epsilon_curve(Precision::of(5), 0.0, 1.0, 4001, Rounding::Trunc));
        let a8 = amplitude(&epsilon_curve(Precision::of(8), 0.0, 1.0, 4001, Rounding::Trunc));
        assert!(a3 > a5 && a5 > a8, "{a3} {a5} {a8}");
        assert!((a3 - 1.0 / 8.0).abs() < 0.02, "{a3}");
    }

    #[test]
    fn periodicity() {
        // ε repeats with period 1/2^m
        let p = Precision::of(4);
        let period = 1.0 / 16.0;
        for k in 0..10 {
            let w = 0.013 + k as f32 * period;
            let e0 = crate::sefp::epsilon_sawtooth(0.013, p, Rounding::Trunc);
            let ek = crate::sefp::epsilon_sawtooth(w, p, Rounding::Trunc);
            assert!((e0 - ek).abs() < 1e-5, "k={k}");
        }
    }

    #[test]
    fn mean_ordinate_basics() {
        assert_eq!(mean_ordinate(&[]), 0.0);
        let curve = [(0.0, 1.0), (1.0, 2.0), (2.0, 6.0)];
        assert!((mean_ordinate(&curve) - 3.0).abs() < 1e-6);
    }

    #[test]
    fn ascii_plot_shape() {
        let p = ascii_plot(&epsilon_curve(Precision::of(3), 0.0, 0.5, 200, Rounding::Trunc), 8, 60);
        assert_eq!(p.lines().count(), 8);
        assert!(p.contains('*'));
    }
}
