//! Gradient geometry across the precision ladder (paper figs. 4 & 5).
//!
//! The empirical backbone of BPS: gradients at different bit-widths are
//! similar overall, and each width aligns better with *higher* widths
//! than with lower ones — so a path that drifts toward high precision
//! keeps its updates useful for every width.

use crate::coordinator::BatchSource;
use crate::runtime::{Engine, ParamStore, Width};

/// Cosine similarity of two flat vectors.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
    for (&x, &y) in a.iter().zip(b) {
        dot += x as f64 * y as f64;
        na += x as f64 * x as f64;
        nb += y as f64 * y as f64;
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// Gradients for one batch at several widths, restricted to one named
/// parameter (e.g. "layer1.wq" — fig. 4 uses q/k/v/down projectors).
/// Returns the row-major cosine matrix over `widths`.
pub fn cosine_matrix(
    engine: &mut Engine,
    params: &ParamStore,
    batch: &crate::data::Batch,
    widths: &[Width],
    param_name: &str,
) -> anyhow::Result<Vec<Vec<f64>>> {
    let idx = params
        .index_of(param_name)
        .ok_or_else(|| anyhow::anyhow!("unknown param {param_name}"))?;
    let mut grads = Vec::with_capacity(widths.len());
    for &w in widths {
        let out = engine.train_step(params, batch, w)?;
        grads.push(out.grads[idx].clone());
    }
    let n = widths.len();
    let mut mat = vec![vec![0.0; n]; n];
    for i in 0..n {
        for j in 0..n {
            mat[i][j] = cosine(&grads[i], &grads[j]);
        }
    }
    Ok(mat)
}

/// Per-batch gradient-norm errors ||∇_sefp|| − ||∇_fp|| for each width
/// over `n_batches` (fig. 5 traces).  Restricted to `param_name` like the
/// paper (layer-15 down projector there).
pub fn norm_error_traces<B: BatchSource>(
    engine: &mut Engine,
    params: &ParamStore,
    batches: &mut B,
    widths: &[Width],
    param_name: &str,
    n_batches: usize,
) -> anyhow::Result<Vec<Vec<f64>>> {
    let idx = params
        .index_of(param_name)
        .ok_or_else(|| anyhow::anyhow!("unknown param {param_name}"))?;
    let mut traces = vec![Vec::with_capacity(n_batches); widths.len()];
    for _ in 0..n_batches {
        let batch = batches.next_batch();
        let fp = engine.train_step(params, &batch, Width::FP)?;
        let fp_norm = l2(&fp.grads[idx]);
        for (wi, &w) in widths.iter().enumerate() {
            let out = engine.train_step(params, &batch, w)?;
            traces[wi].push(l2(&out.grads[idx]) - fp_norm);
        }
    }
    Ok(traces)
}

fn l2(v: &[f32]) -> f64 {
    v.iter().map(|&x| x as f64 * x as f64).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!((cosine(&[1.0, 0.0], &[0.0, 1.0])).abs() < 1e-12);
        assert!((cosine(&[1.0, 1.0], &[-1.0, -1.0]) + 1.0).abs() < 1e-12);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }
}
